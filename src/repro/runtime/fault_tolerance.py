"""Fault-tolerant training loop: auto-resume, fault injection, goodput.

Designed for thousands of nodes, demonstrated on one:

  * **checkpoint/restart** — the loop always starts by probing the
    CheckpointManager; any crash (or SIGTERM from a preemption) resumes
    from the newest *valid* checkpoint (corrupt/truncated ones are skipped
    with a warning — checkpoint/checkpoint.py verifies per-leaf crc32s).
    ``FailureInjector`` lets tests kill the loop at an exact step — by
    exception, by hard process death (``os._exit``, the host-dies-mid-step
    case), by dying *inside* a checkpoint write (the torn-write case the
    atomic rename protects against), or by a SIGTERM delivered during the
    save — and assert bit-identical continuation.
  * **straggler watchdog** — per-step wall times feed an EMA; steps slower
    than ``threshold x EMA`` increment a straggler counter and are logged.
    On real pods this signal feeds the scheduler's replace-node decision;
    here it is surfaced in metrics (tested with an artificial delay).
  * **goodput accounting** — a :class:`GoodputMeter` persists a per-step
    heartbeat next to the checkpoints, so a *resumed* run knows how far the
    dead one got: ``goodput = useful_time / wall_clock`` where useful time
    is only the step time that survived into a checkpoint or the final
    state, with explicit ``time_lost_to_restart`` and ``recomputed_steps``
    breakdowns.  Emitted as ``ft/*`` rows in BENCH_engine.json and printed
    by ``launch/train.py --instrument``; the injected-failure scenario's
    goodput is floor-gated in CI (ft-gates).
  * **elastic re-sharding** — checkpoints are logical (see checkpoint/), so
    ``reshard`` places a restored tree onto any new mesh: scale from N to M
    hosts between runs without conversion tools.
"""

from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager

__all__ = [
    "FailureInjector", "StragglerWatchdog", "GoodputMeter", "TrainLoop",
    "reshard",
]


class FailureInjector:
    """Deterministic fault injection for tests — one-shot per instance.

    Modes (all fire at ``fail_at_step`` and only once — ``fired`` is the
    one-shot latch, so a loop that survives the fault does not re-die):

    * ``"raise"``        — raise RuntimeError before the step runs (the
      in-process crash; ``finally`` blocks and async flushes still run).
    * ``"die"``          — ``os._exit(exit_code)`` before the step runs:
      hard host death, no cleanup, no checkpoint flush.  Use from a worker
      subprocess (runtime/elastic.py).
    * ``"sigterm"``      — deliver a real SIGTERM to this process before
      the step: with ``TrainLoop(handle_sigterm=True)`` the loop finishes
      the step, checkpoints, and exits cleanly (the preemption path).
    * ``"ckpt_crash"``   — die *inside* the first checkpoint write at or
      after ``fail_at_step``: a torn ``.tmp`` payload is left behind and
      the process hard-exits mid-save.  The atomic-rename contract means
      resume must land on the previous complete checkpoint.

    Serving modes (consumed by ``serving/scheduler.py`` via :meth:`fires`;
    no-ops in the training loop — see docs/serving.md for the detection
    and recovery each one exercises):

    * ``"nan_logits"``    — poison the decode output of one slot at the
      ``fail_at_step``-th batched decode step (NaN logits, the FP8
      scale-overflow failure shape).
    * ``"kv_corrupt"``    — bit-flip the stored KV rows of one slot after
      the ``fail_at_step``-th decode step (caught by the checksum audit).
    * ``"prefill_crash"`` — raise inside the ``fail_at_step``-th prefill
      dispatch (the scheduler retries; one-shot, so the retry succeeds).

    ``target`` optionally names the victim request id for the serving
    modes; ``None`` lets the scheduler pick the lowest-rid active slot.
    """

    SERVING_MODES = ("nan_logits", "kv_corrupt", "prefill_crash")
    MODES = ("raise", "die", "sigterm", "ckpt_crash") + SERVING_MODES

    def __init__(self, fail_at_step: Optional[int] = None,
                 mode: str = "raise", exit_code: int = 13,
                 target: Optional[int] = None):
        if mode not in self.MODES:
            raise ValueError(
                f"unknown failure mode {mode!r}; known: {self.MODES}")
        self.fail_at_step = fail_at_step
        self.mode = mode
        self.exit_code = exit_code
        self.target = target
        self.fired = False

    def _armed(self, step: int) -> bool:
        return (self.fail_at_step is not None and not self.fired
                and step >= self.fail_at_step)

    def fires(self, step: int, mode: str) -> bool:
        """One-shot serving-fault trigger: True exactly once, at the first
        call whose ``step`` counter has reached ``fail_at_step`` with a
        matching ``mode``.  The serving scheduler owns the counters —
        ``prefill_crash`` counts prefill attempts, ``nan_logits`` and
        ``kv_corrupt`` count batched decode steps (both 1-based)."""
        if self.mode != mode or not self._armed(step):
            return False
        self.fired = True
        return True

    def maybe_fail(self, step: int) -> None:
        """Called by the loop at the top of each step."""
        if self.mode not in ("raise", "die", "sigterm"):
            return
        if self.fail_at_step is None or self.fired or step != self.fail_at_step:
            return
        self.fired = True
        if self.mode == "raise":
            raise RuntimeError(f"injected failure at step {step}")
        if self.mode == "sigterm":
            os.kill(os.getpid(), signal.SIGTERM)
            return  # the handler only sets a flag; the loop drains cleanly
        os._exit(self.exit_code)  # "die": host death, no cleanup

    def maybe_fail_save(self, step: int, ckpt: CheckpointManager) -> None:
        """Called by the loop just before a checkpoint save for ``step``.
        ``ckpt_crash`` writes a torn ``.tmp`` payload (what a mid-write
        crash leaves on disk) and hard-exits."""
        if self.mode != "ckpt_crash" or not self._armed(step):
            return
        self.fired = True
        tmp = ckpt._dir(step) + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            f.write(b"PK\x03\x04torn-mid-write")  # a truncated zip header
        os._exit(self.exit_code)


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 3.0
    ema_decay: float = 0.9
    ema: Optional[float] = None
    straggler_steps: int = 0

    def observe(self, step_time: float) -> bool:
        is_straggler = self.ema is not None and step_time > self.threshold * self.ema
        if is_straggler:
            self.straggler_steps += 1
        # stragglers don't poison the EMA
        if self.ema is None:
            self.ema = step_time
        elif not is_straggler:
            self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * step_time
        return is_straggler


# --------------------------------------------------------------------- #
# Goodput accounting
# --------------------------------------------------------------------- #
class GoodputMeter:
    """Useful-work / wall-clock accounting that survives process death.

    A tiny ``heartbeat.json`` is atomically rewritten in ``root`` every
    step.  A crashed process cannot report its own loss, so the *next*
    process reads the heartbeat on startup and accounts for it:

    * ``recomputed_steps`` — steps the dead run executed past its last
      checkpoint; the resumed run must redo them (step/data determinism
      makes the redo bit-identical, but the first run's time was wasted).
    * ``time_lost_to_restart`` — the dead run's post-checkpoint step time
      plus the gap between its last heartbeat and the resumed run's start
      (scheduler delay, re-init, recompile).
    * ``useful_time`` — per-step time that became durable: it survived
      into a checkpoint or into the final returned state.
    * ``goodput = useful_time / (now - first_start)`` across *all*
      incarnations of the run, not just the surviving one.
    """

    HEARTBEAT = "heartbeat.json"

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.useful_time = 0.0
        self.useful_at_ckpt = 0.0
        self.time_lost_to_restart = 0.0
        self.recomputed_steps = 0
        self.restarts = 0
        self.first_start = time.time()
        self.step = 0

    # -- persistence ---------------------------------------------- #
    @property
    def _path(self) -> str:
        return os.path.join(self.root, self.HEARTBEAT)

    def _beat(self) -> None:
        tmp = self._path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({
                "step": self.step,
                "wall": time.time(),
                "first_start": self.first_start,
                "useful_time": self.useful_time,
                "useful_at_ckpt": self.useful_at_ckpt,
                "time_lost_to_restart": self.time_lost_to_restart,
                "recomputed_steps": self.recomputed_steps,
                "restarts": self.restarts,
            }, f)
        os.replace(tmp, self._path)

    # -- lifecycle ------------------------------------------------ #
    def start_run(self, start_step: int) -> None:
        """Attach to a (possibly restarted) run resuming at ``start_step``.
        Reads the previous incarnation's heartbeat, if any, and books its
        losses."""
        if not os.path.exists(self._path):
            self.step = start_step
            return
        try:
            with open(self._path) as f:
                hb = json.load(f)
        except (OSError, json.JSONDecodeError):
            self.step = start_step
            return  # a torn heartbeat only costs telemetry, never the run
        now = time.time()
        self.restarts = int(hb.get("restarts", 0)) + 1
        self.first_start = float(hb.get("first_start", now))
        self.useful_at_ckpt = float(hb.get("useful_at_ckpt", 0.0))
        # work past the last checkpoint died with the process
        self.useful_time = self.useful_at_ckpt
        self.recomputed_steps = int(hb.get("recomputed_steps", 0)) + max(
            0, int(hb.get("step", start_step)) - start_step)
        self.time_lost_to_restart = (
            float(hb.get("time_lost_to_restart", 0.0))
            + (float(hb.get("useful_time", 0.0)) - self.useful_at_ckpt)
            + max(0.0, now - float(hb.get("wall", now))))
        self.step = start_step

    def observe_step(self, step: int, dt: float) -> None:
        self.useful_time += dt
        self.step = step + 1  # the next step to run if we die right now
        self._beat()

    def on_checkpoint(self, step: int) -> None:
        """All useful time so far is now durable."""
        self.useful_at_ckpt = self.useful_time
        self._beat()

    def report(self) -> Dict[str, float]:
        wall = max(time.time() - self.first_start, 1e-9)
        return {
            "goodput": self.useful_time / wall,
            "wall_time": wall,
            "useful_time": self.useful_time,
            "time_lost_to_restart": self.time_lost_to_restart,
            "recomputed_steps": self.recomputed_steps,
            "restarts": self.restarts,
        }


def reshard(tree: Any, mesh, specs) -> Any:
    """Place a host-resident tree onto a mesh under PartitionSpecs."""
    from jax.sharding import NamedSharding

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree, specs)


class TrainLoop:
    """Generic fault-tolerant step loop.

    step_fn: (state, batch) -> (state, metrics);  state is any pytree.
    """

    def __init__(
        self,
        step_fn: Callable,
        ckpt: CheckpointManager,
        *,
        save_every: int = 50,
        async_save: bool = True,
        watchdog: Optional[StragglerWatchdog] = None,
        injector: Optional[FailureInjector] = None,
        handle_sigterm: bool = False,
        goodput: Optional[GoodputMeter] = None,
    ):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.save_every = save_every
        self.async_save = async_save
        self.watchdog = watchdog or StragglerWatchdog()
        self.injector = injector
        self.goodput = goodput
        self._preempted = False
        if handle_sigterm:
            signal.signal(signal.SIGTERM, self._on_sigterm)

    def _on_sigterm(self, signum, frame):
        self._preempted = True

    def run(
        self,
        init_state: Any,
        batches,
        num_steps: int,
        *,
        log_every: int = 10,
        log: Callable[[str], None] = print,
    ) -> Dict[str, Any]:
        """``batches``: either an iterator (fresh runs only) or a callable
        ``step -> batch`` (preferred: replays the exact stream after
        restart, matching the deterministic pipeline's contract).  Resuming
        from a checkpoint with a plain iterator is rejected: the iterator
        would replay from batch 0 against a state at ``start_step``,
        silently corrupting data/step alignment."""
        # ---- auto-resume (skipping past corrupt checkpoints) ----
        state = init_state
        start_step = 0
        restored = self.ckpt.restore_latest(init_state, log=log)
        if restored is not None:
            start_step, state, meta = restored
            log(f"[ft] resumed from checkpoint step {start_step}")
        if start_step > 0 and not callable(batches):
            raise TypeError(
                "TrainLoop.run is resuming from checkpoint step "
                f"{start_step} but `batches` is a plain iterator, which "
                "would replay the stream from batch 0 and misalign data "
                "with the restored state. Pass a callable `step -> batch` "
                "(e.g. the deterministic pipeline's `.batch`) so the "
                "stream replays from the resume step.")

        meter = self.goodput or GoodputMeter(self.ckpt.root)
        meter.start_run(start_step)
        if meter.restarts:
            log(f"[ft] restart #{meter.restarts}: "
                f"{meter.recomputed_steps} step(s) to recompute, "
                f"{meter.time_lost_to_restart:.2f}s lost so far")

        history = []
        step = start_step
        try:
            for step in range(start_step, num_steps):
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                batch = batches(step) if callable(batches) else next(batches)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(metrics)
                dt = time.perf_counter() - t0
                straggler = self.watchdog.observe(dt)
                meter.observe_step(step, dt)
                if step % log_every == 0:
                    m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                    log(f"[step {step}] {m} ({dt*1e3:.1f} ms)"
                        + (" STRAGGLER" if straggler else ""))
                history.append({k: float(np.asarray(v)) for k, v in metrics.items()})
                next_step = step + 1
                if next_step % self.save_every == 0 or self._preempted:
                    if self.injector is not None:
                        self.injector.maybe_fail_save(next_step, self.ckpt)
                    saver = self.ckpt.save_async if self.async_save else self.ckpt.save
                    saver(next_step, state, {"wall_time": time.time()})
                    meter.on_checkpoint(next_step)
                    if self._preempted:
                        self.ckpt.wait()
                        log(f"[ft] preempted: checkpointed at step {next_step}, "
                            "exiting")
                        break
        finally:
            # a crash must never lose an in-flight async checkpoint
            self.ckpt.wait()
        return {
            "final_state": state,
            "history": history,
            "last_step": step,
            "straggler_steps": self.watchdog.straggler_steps,
            "preempted": self._preempted,
            "goodput": meter.report(),
        }
