"""Fault-tolerant training loop: auto-resume, straggler watchdog, elasticity.

Designed for thousands of nodes, demonstrated on one:

  * **checkpoint/restart** — the loop always starts by probing the
    CheckpointManager; any crash (or SIGTERM from a preemption) resumes from
    the last complete step.  ``FailureInjector`` lets tests kill the loop at
    an exact step and assert bit-identical continuation.
  * **straggler watchdog** — per-step wall times feed an EMA; steps slower
    than ``threshold x EMA`` increment a straggler counter and are logged.
    On real pods this signal feeds the scheduler's replace-node decision;
    here it is surfaced in metrics (tested with an artificial delay).
  * **elastic re-sharding** — checkpoints are logical (see checkpoint/), so
    ``reshard`` places a restored tree onto any new mesh: scale from N to M
    hosts between runs without conversion tools.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager

__all__ = ["FailureInjector", "StragglerWatchdog", "TrainLoop", "reshard"]


class FailureInjector:
    """Deterministic fault injection for tests: raises at a given step."""

    def __init__(self, fail_at_step: Optional[int] = None):
        self.fail_at_step = fail_at_step
        self.fired = False

    def maybe_fail(self, step: int) -> None:
        if self.fail_at_step is not None and step == self.fail_at_step and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 3.0
    ema_decay: float = 0.9
    ema: Optional[float] = None
    straggler_steps: int = 0

    def observe(self, step_time: float) -> bool:
        is_straggler = self.ema is not None and step_time > self.threshold * self.ema
        if is_straggler:
            self.straggler_steps += 1
        # stragglers don't poison the EMA
        if self.ema is None:
            self.ema = step_time
        elif not is_straggler:
            self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * step_time
        return is_straggler


def reshard(tree: Any, mesh, specs) -> Any:
    """Place a host-resident tree onto a mesh under PartitionSpecs."""
    from jax.sharding import NamedSharding

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree, specs)


class TrainLoop:
    """Generic fault-tolerant step loop.

    step_fn: (state, batch) -> (state, metrics);  state is any pytree.
    """

    def __init__(
        self,
        step_fn: Callable,
        ckpt: CheckpointManager,
        *,
        save_every: int = 50,
        async_save: bool = True,
        watchdog: Optional[StragglerWatchdog] = None,
        injector: Optional[FailureInjector] = None,
        handle_sigterm: bool = False,
    ):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.save_every = save_every
        self.async_save = async_save
        self.watchdog = watchdog or StragglerWatchdog()
        self.injector = injector
        self._preempted = False
        if handle_sigterm:
            signal.signal(signal.SIGTERM, self._on_sigterm)

    def _on_sigterm(self, signum, frame):
        self._preempted = True

    def run(
        self,
        init_state: Any,
        batches,
        num_steps: int,
        *,
        log_every: int = 10,
        log: Callable[[str], None] = print,
    ) -> Dict[str, Any]:
        """``batches``: either an iterator (caller guarantees step alignment
        after resume) or a callable ``step -> batch`` (preferred: replays
        the exact stream after restart, matching the deterministic
        pipeline's contract)."""
        # ---- auto-resume ----
        state = init_state
        start_step = 0
        restored = self.ckpt.restore_latest(init_state)
        if restored is not None:
            start_step, state, meta = restored
            log(f"[ft] resumed from checkpoint step {start_step}")

        history = []
        step = start_step
        try:
            for step in range(start_step, num_steps):
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                batch = batches(step) if callable(batches) else next(batches)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(metrics)
                dt = time.perf_counter() - t0
                straggler = self.watchdog.observe(dt)
                if step % log_every == 0:
                    m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                    log(f"[step {step}] {m} ({dt*1e3:.1f} ms)"
                        + (" STRAGGLER" if straggler else ""))
                history.append({k: float(np.asarray(v)) for k, v in metrics.items()})
                next_step = step + 1
                if next_step % self.save_every == 0 or self._preempted:
                    saver = self.ckpt.save_async if self.async_save else self.ckpt.save
                    saver(next_step, state, {"wall_time": time.time()})
                    if self._preempted:
                        self.ckpt.wait()
                        log(f"[ft] preempted: checkpointed at step {next_step}, "
                            "exiting")
                        break
        finally:
            # a crash must never lose an in-flight async checkpoint
            self.ckpt.wait()
        return {
            "final_state": state,
            "history": history,
            "last_step": step,
            "straggler_steps": self.watchdog.straggler_steps,
        }
