"""Logical-axis sharding rules (DP / TP / EP / SP / FSDP).

Model code annotates parameters and activations with *logical* axis names
("batch", "embed", "heads", "experts", ...).  This module maps them onto the
physical mesh axes ``("pod", "data", "model")`` built by ``launch/mesh.py``:

* DP   — "batch" over ``("pod", "data")``;
* TP   — "heads"/"ff"/"vocab" over ``"model"`` (Megatron column/row pairs
         around every RedMulE GEMM);
* EP   — "experts" over ``"model"``;
* SP   — "seq_sharded" over ``"model"`` (sequence parallelism for the
         norm/residual segments between TP blocks — enabled per-config);
* FSDP — "embed" additionally over ``("pod", "data")`` (ZeRO-3 style) when
         ``fsdp=True`` (a hillclimb option, off in the paper-faithful
         baseline).

Rules are carried in a thread-local context so model code stays functional:
``with use_rules(Rules(...)): ...``; outside any context, annotations are
no-ops (single-device tests).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.runtime import compat

__all__ = [
    "Rules",
    "use_rules",
    "current_rules",
    "logical_spec",
    "constrain",
    "DATA_AXES",
    "MODEL_AXIS",
]

DATA_AXES: Tuple[str, ...] = ("pod", "data")
MODEL_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class Rules:
    """Logical-axis -> mesh-axes mapping."""

    fsdp: bool = False
    sequence_parallel: bool = False
    # decode-time: pin attention dots to the sequence-sharded KV layout
    serve_attention: bool = False
    # overrides win over the built-in table (hillclimb hook)
    overrides: Tuple[Tuple[str, Optional[Tuple[str, ...]]], ...] = ()

    def table(self) -> Dict[str, Optional[Tuple[str, ...]]]:
        t: Dict[str, Optional[Tuple[str, ...]]] = {
            "batch": DATA_AXES,
            "seq": None,
            "seq_sharded": (MODEL_AXIS,) if self.sequence_parallel else None,
            "embed": DATA_AXES if self.fsdp else None,
            "embed_unsharded": None,
            "vocab": (MODEL_AXIS,),
            "heads": (MODEL_AXIS,),
            "kv_heads": (MODEL_AXIS,),
            "head_dim": None,
            "ff": (MODEL_AXIS,),
            "experts": (MODEL_AXIS,),
            "expert_ff": None,
            "kv_rank": None,
            # decode-time KV cache sequence dim; serve rules override to
            # ("model",) so 32k-500k caches shard over TP (KV heads are
            # almost always < 16 and replicate)
            "kv_seq": None,
            "state": None,
            "layers": None,
            "ae_hidden": None,
            None: None,
        }
        t.update(dict(self.overrides))
        return t


_state = threading.local()


def current_rules() -> Optional[Rules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    old = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = old


def logical_spec(axes: Tuple[Optional[str], ...], rules: Optional[Rules] = None) -> P:
    """Translate logical axis names to a PartitionSpec under the rules."""
    rules = rules if rules is not None else current_rules()
    if rules is None:
        return P()
    table = rules.table()
    parts = []
    used: set = set()
    for a in axes:
        mesh_axes = table.get(a)
        if mesh_axes is None:
            parts.append(None)
            continue
        free = tuple(m for m in mesh_axes if m not in used)
        used.update(free)
        parts.append(free if len(free) != 1 else free[0])
        if not free:
            parts[-1] = None
    return P(*parts)


def _filter_known(part, mesh):
    """Drop mesh-axis names the mesh doesn't have (e.g. 'pod' on single-pod)."""
    if part is None:
        return None
    if isinstance(part, tuple):
        kept = tuple(n for n in part if n in mesh.shape)
        if not kept:
            return None
        return kept[0] if len(kept) == 1 else kept
    return part if part in mesh.shape else None


def _axis_size(mesh, name) -> int:
    if isinstance(name, tuple):
        s = 1
        for n in name:
            s *= mesh.shape[n]
        return s
    return mesh.shape[name]


def sanitize_spec(spec: P, shape: Tuple[int, ...], mesh) -> P:
    """Drop mesh axes the mesh doesn't define, and spec entries that don't
    divide the dimension (e.g. 5 KV heads on a 16-way model axis fall back
    to replication, the Megatron rule)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, part in zip(shape, parts):
        part = _filter_known(part, mesh)
        if part is None:
            out.append(None)
        elif dim % _axis_size(mesh, part) == 0:
            out.append(part)
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def constrain_fb(x: jax.Array, fwd_axes: Tuple[Optional[str], ...],
                 bwd_axes: Optional[Tuple[Optional[str], ...]] = None) -> jax.Array:
    """Constrain the value (fwd_axes) AND its cotangent (bwd_axes).

    GSPMD re-propagates shardings through the transposed (backward)
    scatter/gathers of remat'd regions and can pick cross-shard layouts
    (observed: the MoE dispatch-gather's transpose all-reducing full fp32
    slot tensors).  At a *layout-change* point the two directions need
    different pins: e.g. the MoE dispatch buffer is expert-sharded going
    forward but its cotangent must be batch-local going backward."""
    bwd_axes = bwd_axes if bwd_axes is not None else fwd_axes

    @jax.custom_vjp
    def _ident(v):
        return constrain(v, *fwd_axes)

    def _fwd(v):
        return constrain(v, *fwd_axes), None

    def _bwd(_, g):
        return (constrain(g, *bwd_axes),)

    _ident.defvjp(_fwd, _bwd)
    return _ident(x)


def constrain_both(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain the value and its cotangent to the same layout."""
    return constrain_fb(x, axes)


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the current rules.

    No-op outside a rules context or outside a mesh; mesh-axis entries that
    don't divide the corresponding dimension are dropped (replicated)."""
    rules = current_rules()
    if rules is None:
        return x
    mesh = compat.current_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = sanitize_spec(logical_spec(axes, rules), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, spec)
