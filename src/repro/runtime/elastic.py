"""Elastic multi-process data-parallel training worker.

The process-level half of the fault-tolerance story: `fault_tolerance.py`
hardens one process's step loop; this module is the *unit that dies*.  A
worker is a real OS process (spawned by tests, a shell, or a cluster
scheduler) that runs a data-parallel train job over a simulated multi-host
mesh (``--dp N`` sets ``XLA_FLAGS=--xla_force_host_platform_device_count``
before JAX initializes — imports here are lazy for exactly that reason)
with the full resilience stack: compressed gradient all-reduce
(``--compress fp8`` etc., optim/compression.py), checksum-verified
checkpoints, the goodput heartbeat, and deterministic fault injection
(``--fail-step/--fail-mode``).

Crash-tested contracts (tests/test_ft_gates.py, CI ft-gates):

* **kill-and-resume** — SIGKILL-grade death (``--fail-mode die``) at step
  k, relaunch with the same flags: the resumed worker restores the last
  checkpoint, replays the step-indexed batch stream, and reaches a final
  state **bit-identical** to an uninterrupted run — on the fp32 wire and
  on the FP8-compressed wire (error feedback and delayed-scale windows are
  part of the checkpointed state, so the wire's history survives too).
* **torn checkpoint write** (``--fail-mode ckpt_crash``) — dying mid-save
  leaves only a ``.tmp`` payload; resume lands on the previous complete
  checkpoint.
* **elastic resume** — relaunch with a different ``--dp``: checkpoints are
  logical; params/opt are replicated over the data axis, while the
  per-host compression state (error-feedback residuals, FP8 amax windows)
  is stored with an explicit leading host axis and *regrouped* on attach —
  residuals are summed within each merge group (total uncommunicated
  gradient mass is conserved) and scale statistics take the group max — so
  a 4-process checkpoint continues on a 2-process mesh (gradient *means*
  are mathematically identical across regroupings; bit-level identity is
  only promised at fixed mesh shape).
* **preemption** — SIGTERM (external, or ``--fail-mode sigterm``) makes
  the loop checkpoint and exit 0; the result file records ``preempted``.

The model is deliberately tiny (a 2-layer MLP regression on step-indexed
synthetic data): what is under test is the distributed loop, the wire, and
the recovery machinery, not the FLOPs.  ``launch/train.py --compress
--dp-procs`` drives the same machinery with the real LM/AE models.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Any, Optional

__all__ = ["run_worker", "main", "WorkerConfig"]

_MODEL_DIMS = (8, 32, 8)  # in -> hidden -> out


def _build(args):
    """Construct (step_fn, init_state, batch_fn) — lazy jax imports."""
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.optim import AdamW, Compressor
    from repro.runtime import compat

    ndev = len(jax.devices())
    if ndev < args.dp:
        raise SystemExit(
            f"worker needs {args.dp} devices but jax sees {ndev}; spawn "
            "with XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{args.dp} (or pass --dp {ndev})")
    mesh = compat.make_mesh((args.dp,), ("data",))
    comp = Compressor(args.compress)
    opt = AdamW(lr=1e-2, warmup_steps=0)

    din, dh, dout = _MODEL_DIMS
    k0 = jax.random.PRNGKey(args.seed)
    kw1, kw2, ka = jax.random.split(k0, 3)
    target_A = jax.random.normal(ka, (din, dout), jnp.float32)

    def init_state(dp: Optional[int] = None):
        params = {
            "w1": jax.random.normal(kw1, (din, dh), jnp.float32) * 0.3,
            "b1": jnp.zeros((dh,), jnp.float32),
            "w2": jax.random.normal(kw2, (dh, dout), jnp.float32) * 0.3,
            "b2": jnp.zeros((dout,), jnp.float32),
        }
        # Compression state (EF residual + fp8 scale windows) is genuinely
        # per-host — each host accumulates the residual of *its* batch
        # shard — so it carries an explicit leading host axis, sharded
        # P("data").  Storing it "replicated" would silently checkpoint
        # only host 0's residual (shard_map's check_rep=False stamps the
        # out-spec without verifying it), breaking bit-identical resume.
        ef = comp.init(params)
        if ef is not None:
            ef = jax.tree.map(lambda l: jnp.stack([l] * (dp or args.dp)), ef)
        return {"params": params, "opt": opt.init(params), "ef": ef}

    def loss_fn(params, batch):
        x, y = batch["x"], batch["y"]
        h = jnp.tanh(x @ params["w1"] + params["b1"])
        pred = h @ params["w2"] + params["b2"]
        return jnp.mean((pred - y) ** 2)

    def batch_fn(step: int):
        kx = jax.random.fold_in(jax.random.PRNGKey(args.seed + 1), step)
        x = jax.random.normal(kx, (args.batch, din), jnp.float32)
        return {"x": x, "y": x @ target_A}

    def local(params, ef_hosts, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if comp.kind == "none":
            mean_g = jax.tree.map(
                lambda g: jax.lax.pmean(g.astype(jnp.float32), ("data",)),
                grads)
            ef2_hosts = ef_hosts
        else:
            # strip this host's slot off the leading host axis, compress,
            # and put the new residual back in the same slot
            ef = jax.tree.map(lambda x: x[0], ef_hosts)
            wire, ef2 = comp.compress(grads, ef)
            mean_g = comp.psum_wire(wire, ("data",))
            ef2_hosts = jax.tree.map(lambda x: x[None], ef2)
        return mean_g, ef2_hosts, jax.lax.pmean(loss, ("data",))

    state0 = jax.eval_shape(init_state)
    pspec = jax.tree.map(lambda _: P(), state0["params"])
    espec = jax.tree.map(lambda _: P("data"), state0["ef"])
    bspec = {"x": P("data"), "y": P("data")}

    sharded_local = shard_map(
        local, mesh,
        in_specs=(pspec, espec, bspec),
        out_specs=(pspec, espec, P()),
        check_rep=False)

    def step_fn(state, batch):
        mean_g, ef2, loss = sharded_local(state["params"], state["ef"], batch)
        updates, new_opt = opt.update(mean_g, state["opt"], state["params"])
        new_params = opt.apply(state["params"], updates)
        return ({"params": new_params, "opt": new_opt, "ef": ef2},
                {"loss": loss})

    # Canonical placement — the bit-identical-resume invariant.  A resumed
    # process's first step receives host (np) arrays from the checkpoint
    # while a clean run's steps receive the previous step's device
    # outputs; pinned in_/out_shardings force every step of every
    # incarnation — fresh, resumed, re-meshed — through one executable and
    # one placement per mesh shape.
    from jax.sharding import NamedSharding
    rep = NamedSharding(mesh, P())
    dp_sh = NamedSharding(mesh, P("data"))
    state_sh = {
        "params": jax.tree.map(lambda _: rep, state0["params"]),
        "opt": jax.tree.map(lambda _: rep, state0["opt"]),
        "ef": jax.tree.map(lambda _: dp_sh, state0["ef"]),
    }
    jitted = jax.jit(step_fn,
                     in_shardings=(state_sh, {"x": dp_sh, "y": dp_sh}),
                     out_shardings=(state_sh, rep))

    def canonical_step(state, batch):
        out = jitted(state, batch)
        if args.step_ms > 0:
            import time
            time.sleep(args.step_ms / 1e3)  # SIGTERM-mid-run test hook
        return out

    return canonical_step, init_state, batch_fn, mesh


def _digest(tree) -> str:
    """Order-stable sha256 over the float bytes of every leaf."""
    import jax
    import numpy as np

    h = hashlib.sha256()
    for leaf in jax.tree.leaves(tree):
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    return h.hexdigest()


def _regroup_axis0(x, dp_new: int, how: str):
    """Regroup a per-host-stacked array onto ``dp_new`` hosts.

    ``how="sum"`` (EF residuals): conserves the total along axis 0 — merge
    groups are summed, split groups divide evenly — so the uncommunicated
    gradient mass survives any resize.  ``how="max"`` (fp8 scale stats,
    amax windows, step counts): conservative group maximum.
    """
    import numpy as np

    x = np.asarray(x)
    dp_old = x.shape[0]
    if dp_old == dp_new:
        return x
    if dp_old % dp_new == 0:
        g = x.reshape((dp_new, dp_old // dp_new) + x.shape[1:])
        return g.sum(axis=1) if how == "sum" else g.max(axis=1)
    if dp_new % dp_old == 0:
        r = dp_new // dp_old
        rep = np.repeat(x, r, axis=0)
        return rep / np.asarray(r, x.dtype) if how == "sum" else rep
    # non-divisible resize: collapse to one logical host, pad the rest
    tot = x.sum(axis=0) if how == "sum" else x.max(axis=0)
    out = np.zeros((dp_new,) + x.shape[1:], x.dtype)
    out[0] = tot
    if how == "max":
        out[:] = tot
    return out


def _regroup_ef(ef, dp_new: int):
    """Regroup the per-host compression-state tree onto ``dp_new`` hosts."""
    import jax

    from repro.optim import Fp8LeafState

    if ef is None:
        return None

    def one(node):
        if isinstance(node, Fp8LeafState):
            return Fp8LeafState(
                ef=_regroup_axis0(node.ef, dp_new, "sum"),
                scale=jax.tree.map(
                    lambda s: _regroup_axis0(s, dp_new, "max"), node.scale))
        return _regroup_axis0(node, dp_new, "sum")

    return jax.tree.map(one, ef,
                        is_leaf=lambda n: isinstance(n, Fp8LeafState))


def _maybe_migrate_elastic(ckpt, init_state, dp_new: int, log=print) -> None:
    """Elastic attach: if the newest valid checkpoint was written by a
    job with a different ``--dp``, regroup its per-host compression state
    onto this job's host count and rewrite the checkpoint in place (the
    atomic save makes the migration itself crash-safe).  Params/opt are
    replicated and pass through untouched."""
    import jax

    from repro.checkpoint import CheckpointCorruptError

    like_new = jax.eval_shape(init_state)
    if like_new["ef"] is None:
        return  # no per-host state on the fp32 wire
    ef_leaf0 = jax.tree.leaves(like_new["ef"])[0]
    # leaf index of the first ef leaf within the flattened state
    idx = jax.tree.leaves(like_new).index(ef_leaf0)
    for step in reversed(ckpt.all_steps()):
        try:
            arrays, manifest = ckpt._load_verified(step)
        except CheckpointCorruptError:
            continue  # restore_latest will warn about this one
        dp_old = int(manifest["shapes"][f"leaf_{idx}"][0])
        if dp_old == dp_new:
            return
        log(f"[ft] elastic attach: regrouping step-{step} checkpoint "
            f"from dp={dp_old} to dp={dp_new}")
        state, meta = ckpt.restore(step, init_state(dp_old))
        state["ef"] = _regroup_ef(state["ef"], dp_new)
        meta = dict(meta)
        meta["elastic_migrated_from_dp"] = dp_old
        ckpt.save(step, state, meta)
        return


def run_worker(args) -> dict:
    from repro.checkpoint import CheckpointManager
    from repro.runtime.fault_tolerance import (FailureInjector,
                                               StragglerWatchdog, TrainLoop)

    step_fn, init_state, batch_fn, mesh = _build(args)
    ckpt = CheckpointManager(args.ckpt, keep=args.keep)
    _maybe_migrate_elastic(ckpt, init_state, args.dp)
    injector = None
    if args.fail_step is not None:
        injector = FailureInjector(fail_at_step=args.fail_step,
                                   mode=args.fail_mode)
    loop = TrainLoop(
        step_fn,
        ckpt,
        save_every=args.save_every,
        injector=injector,
        handle_sigterm=args.handle_sigterm,
        watchdog=StragglerWatchdog(threshold=100.0),  # no flakes in CI
    )
    out = loop.run(init_state(), batch_fn, args.steps,
                   log_every=args.log_every)
    result = {
        "last_step": int(out["last_step"]),
        "loss": float(out["history"][-1]["loss"]) if out["history"] else None,
        "digest": _digest(out["final_state"]["params"]),
        "preempted": bool(out["preempted"]),
        "goodput": out["goodput"],
        "dp": args.dp,
        "compress": args.compress,
    }
    if args.result:
        tmp = args.result + ".tmp"
        with open(tmp, "w") as f:
            json.dump(result, f)
        os.replace(tmp, args.result)
    return result


def main(argv: Optional[Any] = None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--ckpt", required=True)
    p.add_argument("--steps", type=int, default=10)
    p.add_argument("--save-every", type=int, default=2)
    p.add_argument("--keep", type=int, default=3)
    p.add_argument("--dp", type=int, default=1,
                   help="data-parallel processes to simulate (host devices)")
    p.add_argument("--compress", default="none",
                   help="gradient wire: none|fp16|int8|fp8|fp8_e4m3|fp8_e5m2")
    p.add_argument("--batch", type=int, default=8,
                   help="global batch (must divide by --dp)")
    p.add_argument("--fail-step", type=int, default=None)
    p.add_argument("--fail-mode", default="die",
                   choices=("raise", "die", "sigterm", "ckpt_crash"))
    p.add_argument("--handle-sigterm", action="store_true")
    p.add_argument("--step-ms", type=int, default=0,
                   help="artificial per-step delay (signal-delivery tests)")
    p.add_argument("--result", default="",
                   help="write the final {digest, loss, goodput} JSON here")
    p.add_argument("--log-every", type=int, default=1000)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    if args.batch % args.dp:
        raise SystemExit(f"--batch {args.batch} must divide by --dp {args.dp}")
    # must happen before the first jax import anywhere in this process
    if args.dp > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.dp}")
    run_worker(args)


if __name__ == "__main__":
    main()
