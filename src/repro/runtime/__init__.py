"""Distributed runtime: sharding rules, fault tolerance, elasticity.

``fault_tolerance`` hardens one process's step loop (auto-resume from
checksum-verified checkpoints, fault injection, straggler watchdog,
goodput accounting that survives process death); ``elastic`` is the
multi-process data-parallel worker that dies and comes back — including
onto a different mesh shape.  See docs/fault_tolerance.md for the failure
model and the bit-identical-resume contract.
"""
