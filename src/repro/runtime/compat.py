"""Version-compat shims for the jax mesh/sharding API.

The framework targets the current jax mesh surface (``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``, ``AxisType``-typed meshes); older
releases (<= 0.4.x) expose the same capabilities under different names and
signatures.  Everything mesh-related routes through this module so the
difference lives in exactly one place.
"""

from __future__ import annotations

import contextlib
from typing import Sequence

import jax

__all__ = ["make_mesh", "abstract_mesh", "set_mesh", "current_abstract_mesh"]

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where supported."""
    if _HAS_AXIS_TYPE:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axes)))
    return jax.make_mesh(tuple(shape), tuple(axes))


def abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Shape-only mesh stand-in (device-free spec sanitization in tests)."""
    if _HAS_AXIS_TYPE:
        return jax.sharding.AbstractMesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axes)))
    return jax.sharding.AbstractMesh(tuple(zip(tuple(axes), tuple(shape))))


@contextlib.contextmanager
def set_mesh(mesh):
    """``jax.set_mesh`` context; falls back to the Mesh context manager."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def current_abstract_mesh():
    """The active mesh, or None/empty outside any mesh context.

    New jax returns the abstract mesh from the sharding context; the old-API
    fallback returns the *physical* mesh entered via :func:`set_mesh` — it
    exposes the same ``.empty`` / ``.shape`` surface and, unlike its
    ``.abstract_mesh`` view, is accepted by ``shard_map`` on old jax."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src.mesh import thread_resources

    return thread_resources.env.physical_mesh
