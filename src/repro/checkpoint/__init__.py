"""Atomic / async / elastic / checksum-verified checkpointing."""

from repro.checkpoint.checkpoint import CheckpointCorruptError, CheckpointManager

__all__ = ["CheckpointManager", "CheckpointCorruptError"]
