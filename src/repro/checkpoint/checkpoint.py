"""Atomic, async, elastic checkpointing.

Layout (one directory per step):

    <root>/step_000123.tmp/...   (while writing)
    <root>/step_000123/
        manifest.json            tree structure, shapes, dtypes, metadata
        arrays.npz               flattened leaves (host-local shard or full)

Guarantees:
  * **atomic** — written to ``.tmp`` then ``os.replace``d, so a crash never
    leaves a half checkpoint visible; ``latest()`` only sees complete dirs;
  * **async**  — ``save_async`` snapshots to host RAM synchronously (so
    training can mutate buffers) and writes on a background thread;
  * **elastic** — arrays are stored with their *logical* tree paths, not
    device layouts; ``restore`` yields host arrays the caller re-shards onto
    any mesh (``jax.device_put`` with new NamedShardings), so an N-host
    checkpoint restores onto an M-host job;
  * **bounded** — ``keep`` most recent checkpoints are retained.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}, treedef


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- #
    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.root, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------- #
    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None) -> str:
        """Synchronous atomic save."""
        arrays, treedef = _flatten(tree)
        final = self._dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(arrays),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def save_async(self, step: int, tree: Any, metadata: Optional[Dict] = None) -> None:
        """Snapshot now (host copy), write in the background."""
        self.wait()  # one in flight at a time
        snapshot = jax.tree.map(lambda x: np.asarray(x).copy(), tree)

        def run():
            try:
                self.save(step, snapshot, metadata)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------- #
    def restore(self, step: int, like: Any) -> Tuple[Any, Dict]:
        """Restore into the structure of ``like`` (any mesh/sharding — the
        caller re-shards with device_put)."""
        d = self._dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves, treedef = jax.tree.flatten(like)
        if len(leaves) != manifest["n_leaves"]:
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"restore target has {len(leaves)}")
        out = []
        for i, leaf in enumerate(leaves):
            arr = data[f"leaf_{i}"]
            want = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != want:
                raise ValueError(f"leaf_{i}: checkpoint {arr.shape} vs target {want}")
            out.append(arr)
        return jax.tree.unflatten(treedef, out), manifest["metadata"]

    def restore_latest(self, like: Any) -> Optional[Tuple[int, Any, Dict]]:
        step = self.latest()
        if step is None:
            return None
        tree, meta = self.restore(step, like)
        return step, tree, meta

    # ------------------------------------------------------------- #
    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._dir(s), ignore_errors=True)
