"""Atomic, async, elastic, *verified* checkpointing.

Layout (one directory per step):

    <root>/step_000123.tmp/...   (while writing)
    <root>/step_000123/
        manifest.json            tree structure, shapes, dtypes, per-leaf
                                 crc32 checksums, metadata
        arrays.npz               flattened leaves (host-local shard or full)

Guarantees:
  * **atomic** — written to ``.tmp`` then ``os.replace``d, so a crash never
    leaves a half checkpoint visible; ``latest()`` only sees complete dirs;
  * **verified** — the manifest carries a crc32 per leaf, written from the
    exact bytes that went into ``arrays.npz``; ``restore`` recomputes them
    on read, so silent corruption (a truncated file that still unzips, a
    flipped block) surfaces as :class:`CheckpointCorruptError` instead of
    NaNs ten thousand steps later;
  * **self-healing** — ``restore_latest`` walks checkpoints newest-first
    and *skips past* corrupt or truncated ones to the newest valid step
    (with a logged warning), so one bad write costs ``save_every`` steps,
    not the run;
  * **async**  — ``save_async`` snapshots to host RAM synchronously (so
    training can mutate buffers) and writes on a background thread;
  * **elastic** — arrays are stored with their *logical* tree paths, not
    device layouts; ``restore`` yields host arrays the caller re-shards onto
    any mesh (``jax.device_put`` with new NamedShardings), so an N-host
    checkpoint restores onto an M-host job;
  * **bounded** — ``keep`` most recent checkpoints are retained.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import sys
import threading
import zipfile
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager", "CheckpointCorruptError"]

_STEP_RE = re.compile(r"^step_(\d{9})$")


class CheckpointCorruptError(RuntimeError):
    """A checkpoint directory exists but its payload cannot be trusted:
    truncated/undecodable arrays, a missing leaf, or a checksum mismatch.
    ``restore_latest`` treats this (and only this) as "fall back to the
    previous step"; structural mismatches against the restore target stay
    hard ``ValueError``s — they mean the *caller* changed, not the disk."""


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}, treedef


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- #
    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.root, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------- #
    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None) -> str:
        """Synchronous atomic save (with per-leaf checksums)."""
        arrays, treedef = _flatten(tree)
        final = self._dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(arrays),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "checksums": {k: _crc(v) for k, v in arrays.items()},
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def save_async(self, step: int, tree: Any, metadata: Optional[Dict] = None) -> None:
        """Snapshot now (host copy), write in the background."""
        self.wait()  # one in flight at a time
        snapshot = jax.tree.map(lambda x: np.asarray(x).copy(), tree)

        def run():
            try:
                self.save(step, snapshot, metadata)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------- #
    def _load_verified(self, step: int) -> Tuple[Dict[str, np.ndarray], Dict]:
        """Read + checksum-verify one checkpoint's payload.

        Raises :class:`CheckpointCorruptError` for anything untrustworthy
        on disk (unreadable manifest, truncated/undecodable npz, missing
        leaves, checksum mismatch)."""
        d = self._dir(step)
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            arrays: Dict[str, np.ndarray] = {}
            with np.load(os.path.join(d, "arrays.npz")) as data:
                for i in range(manifest["n_leaves"]):
                    arrays[f"leaf_{i}"] = data[f"leaf_{i}"]
        except (OSError, EOFError, KeyError, ValueError,
                zipfile.BadZipFile, json.JSONDecodeError) as e:
            raise CheckpointCorruptError(
                f"checkpoint step {step} at {d} is unreadable "
                f"({type(e).__name__}: {e})") from e
        checksums = manifest.get("checksums")  # absent in pre-PR-7 ckpts
        if checksums:
            for k, arr in arrays.items():
                want = checksums.get(k)
                got = _crc(arr)
                if want is not None and got != want:
                    raise CheckpointCorruptError(
                        f"checkpoint step {step}: checksum mismatch on {k} "
                        f"(manifest {want}, disk {got})")
        return arrays, manifest

    def restore(self, step: int, like: Any) -> Tuple[Any, Dict]:
        """Restore into the structure of ``like`` (any mesh/sharding — the
        caller re-shards with device_put).  Payload is checksum-verified."""
        arrays, manifest = self._load_verified(step)
        leaves, treedef = jax.tree.flatten(like)
        if len(leaves) != manifest["n_leaves"]:
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"restore target has {len(leaves)}")
        out = []
        for i, leaf in enumerate(leaves):
            arr = arrays[f"leaf_{i}"]
            want = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != want:
                raise ValueError(f"leaf_{i}: checkpoint {arr.shape} vs target {want}")
            out.append(arr)
        return jax.tree.unflatten(treedef, out), manifest["metadata"]

    def restore_latest(
        self, like: Any, *, log: Optional[Callable[[str], None]] = None,
    ) -> Optional[Tuple[int, Any, Dict]]:
        """Restore the newest *valid* checkpoint, skipping past corrupt or
        truncated ones (each skip logs a warning).  Returns None when no
        valid checkpoint exists.  Structural mismatches (wrong leaf count
        or shapes vs ``like``) still raise — the target is wrong, not the
        disk."""
        emit = log if log is not None else (
            lambda msg: print(msg, file=sys.stderr))
        for step in reversed(self.all_steps()):
            try:
                tree, meta = self.restore(step, like)
                return step, tree, meta
            except CheckpointCorruptError as e:
                emit(f"[ckpt] WARNING: skipping corrupt checkpoint "
                     f"step {step}: {e}")
        return None

    # ------------------------------------------------------------- #
    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._dir(s), ignore_errors=True)
