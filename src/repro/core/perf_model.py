"""Analytical RedMulE machine model — reproduces the paper's numbers.

This container has no 22 nm silicon, so every throughput / power / area /
energy figure in the paper is reproduced with a cycle-accurate-at-tile-level
analytical model of the engine described in §II of the paper, calibrated
against the published data points and *validated by tests* against every
quantitative claim:

  * 31.6 MAC/cycle peak = 98.8 % of the 32-FMA ideal        (Table I, Fig 4a)
  * 22x speedup over 8-core RISC-V software                  (§III-A)
  * 4.65x energy-efficiency gain over software               (§I, §IV)
  * 688 GFLOPS/W @ 0.65 V / 476 MHz, 462 GFLOPS/W @ 0.8 V    (Table I)
  * 42 GFLOPS @ 666 MHz                                      (Table I)
  * area 0.07 mm^2 = 14 % of the 0.5 mm^2 cluster; 256-FMA
    config ~ cluster area, 512-FMA ~ 2x cluster              (Fig 4b)
  * ports step 9 -> 11 when H: 4 -> 5                        (§III-A)
  * TinyMLPerf AutoEncoder: 2.6x speedup @ B=1 (bwd > fwd),
    ~16x HW throughput gain and 24.4x speedup @ B=16         (Fig 4c/4d)

Model structure (paper §II-B/C):
  The array is L rows x H columns of FMAs with P internal pipeline stages.
  A Z-tile of L rows x H*(P+1) columns is produced per pass; the reduction
  over N advances H elements per "lap" of H*(P+1) cycles around the row
  feedback path; Z is written once at the end of the reduction (store-once).
  Partial tiles occupy full laps with idle slots — this is exactly the
  small/skinny-matrix utilization collapse of Fig 3d and Fig 4c (K == batch).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

__all__ = [
    "RedMulEModel",
    "GEMM",
    "DEFAULT_MODEL",
    "autoencoder_gemms",
    "autoencoder_report",
    "AE_DIMS",
    "TABLE1_PUBLISHED",
    "gemms_from_events",
    "workload_cycles_from_events",
    "workload_cycles_by_direction",
    "workload_hbm_bytes_from_events",
    "dense_forward_gemms",
    "workload_flops",
]


@dataclasses.dataclass(frozen=True)
class GEMM:
    """Z = X @ W with X:(M,N), W:(N,K) — the paper's naming."""

    M: int
    N: int
    K: int

    @property
    def macs(self) -> int:
        return self.M * self.N * self.K


@dataclasses.dataclass(frozen=True)
class RedMulEModel:
    """Calibrated machine model of a PULP cluster + RedMulE instance."""

    # --- architecture parameters (paper: H=4, L=8, P=3 -> 32 FMAs) ---
    H: int = 4
    L: int = 8
    P: int = 3

    # --- calibrated schedule overheads (cycles) ---
    # register-file programming by the cores, per accelerator offload
    hw_startup: int = 100
    # X-buffer preload at the start of each M-row block (L 256-bit beats)
    hw_preload: int = 8

    # --- calibrated software baseline (8x RV32 cores, FP16 SW loops) ---
    sw_cores: int = 8
    # cycles per MAC per core; pinned by the published 22x peak speedup
    sw_cycles_per_mac: float = 5.52
    # per-GEMM fork/join + loop-setup overhead across the cluster
    sw_call_overhead: int = 10000

    # --- operating points (paper §III) ---
    freq_peak_eff_mhz: float = 476.0   # 0.65 V typical corner
    freq_peak_perf_mhz: float = 666.0  # 0.80 V
    vdd_peak_eff: float = 0.65
    vdd_peak_perf: float = 0.80
    cluster_power_peak_eff_mw: float = 43.5
    cluster_power_peak_perf_mw: float = 90.7
    # SW-mode cluster power, pinned by 4.65x efficiency at 22x speedup:
    # P_sw = P_hw * speedup_eff_ratio => 43.5 * 4.65 / 22
    sw_cluster_power_mw: float = 43.5 * 4.65 / 22.0

    # --- area model, least-squares fit to Fig 4b's three published points
    #     (32 FMA -> 0.07 mm^2, 256 -> ~0.5 = cluster, 512 -> ~1.0 = 2x) ---
    area_per_fma_mm2: float = 1.875e-3
    area_per_port_mm2: float = 1.25e-3
    area_fixed_mm2: float = 0.0
    cluster_area_mm2: float = 0.5

    # ------------------------------------------------------------------ #
    # Array geometry
    # ------------------------------------------------------------------ #
    @property
    def n_fmas(self) -> int:
        return self.H * self.L

    @property
    def peak_macs_per_cycle(self) -> int:
        return self.n_fmas

    @property
    def lap_cycles(self) -> int:
        """One trip of the row feedback path: H FMAs x (P+1) slots."""
        return self.H * (self.P + 1)

    @property
    def z_tile_cols(self) -> int:
        """Z columns produced per pass = pipeline slots = H*(P+1)."""
        return self.H * (self.P + 1)

    def ports(self, H: int | None = None, P: int | None = None) -> int:
        """TCDM ports: H*(P+1) 16-bit elements / 32-bit port + 1 alignment
        port (paper: H=4,P=3 -> 9 ports; H=5 -> 11)."""
        H = self.H if H is None else H
        P = self.P if P is None else P
        return (H * (P + 1) * 16) // 32 + 1

    # ------------------------------------------------------------------ #
    # Cycle model
    # ------------------------------------------------------------------ #
    def hw_cycles(self, g: GEMM) -> int:
        """Cycles for RedMulE to compute Z = X @ W."""
        m_tiles = math.ceil(g.M / self.L)
        k_tiles = math.ceil(g.K / self.z_tile_cols)
        laps = math.ceil(g.N / self.H)
        # one Z tile = full N reduction + pipeline fill/drain
        tile = laps * self.lap_cycles + self.lap_cycles
        per_m = self.hw_preload + k_tiles * tile
        return self.hw_startup + m_tiles * per_m

    def sw_cycles(self, g: GEMM) -> float:
        """Cycles for the 8-core RISC-V software GEMM."""
        return g.macs * self.sw_cycles_per_mac / self.sw_cores + self.sw_call_overhead

    def hw_macs_per_cycle(self, g: GEMM) -> float:
        return g.macs / self.hw_cycles(g)

    def utilization(self, g: GEMM) -> float:
        return self.hw_macs_per_cycle(g) / self.peak_macs_per_cycle

    def speedup(self, g: GEMM) -> float:
        return self.sw_cycles(g) / self.hw_cycles(g)

    def workload_cycles(self, gemms: Sequence[GEMM]) -> Tuple[int, float]:
        hw = sum(self.hw_cycles(g) for g in gemms)
        sw = sum(self.sw_cycles(g) for g in gemms)
        return hw, sw

    # ------------------------------------------------------------------ #
    # Throughput / power / energy (paper §III-A, Table I)
    # ------------------------------------------------------------------ #
    def gmacs(self, g: GEMM, freq_mhz: float | None = None) -> float:
        f = (freq_mhz or self.freq_peak_perf_mhz) * 1e6
        return self.hw_macs_per_cycle(g) * f / 1e9

    def gflops(self, g: GEMM, freq_mhz: float | None = None) -> float:
        return 2.0 * self.gmacs(g, freq_mhz)

    def cluster_power_mw(self, g: GEMM, peak_perf: bool = False) -> float:
        """Cluster power at a utilization point: the RedMulE share (69 %)
        scales with array activity, the rest (TCDM/HCI 17.1 %, cores+misc
        13.9 %) is treated as always-on while the offload runs."""
        p = self.cluster_power_peak_perf_mw if peak_perf else self.cluster_power_peak_eff_mw
        u = self.utilization(g)
        return p * (0.69 * u + 0.31)

    def energy_per_mac_pj(self, g: GEMM, peak_perf: bool = False) -> float:
        f = (self.freq_peak_perf_mhz if peak_perf else self.freq_peak_eff_mhz) * 1e6
        p_w = self.cluster_power_mw(g, peak_perf) * 1e-3
        t_s = self.hw_cycles(g) / f
        return p_w * t_s / g.macs * 1e12

    def gflops_per_watt(self, g: GEMM, peak_perf: bool = False) -> float:
        f_mhz = self.freq_peak_perf_mhz if peak_perf else self.freq_peak_eff_mhz
        return self.gflops(g, f_mhz) / (self.cluster_power_mw(g, peak_perf) * 1e-3)

    def sw_gflops_per_watt(self, g: GEMM) -> float:
        f = self.freq_peak_eff_mhz * 1e6
        thr = g.macs / self.sw_cycles(g) * f * 2 / 1e9
        return thr / (self.sw_cluster_power_mw * 1e-3)

    def efficiency_gain_vs_sw(self, g: GEMM) -> float:
        return self.gflops_per_watt(g) / self.sw_gflops_per_watt(g)

    # ------------------------------------------------------------------ #
    # Area model (Fig 4b)
    # ------------------------------------------------------------------ #
    def area_mm2(self, H: int | None = None, L: int | None = None) -> float:
        H = self.H if H is None else H
        L = self.L if L is None else L
        return (
            self.area_per_fma_mm2 * H * L
            + self.area_per_port_mm2 * self.ports(H)
            + self.area_fixed_mm2
        )

    def area_fraction_of_cluster(self) -> float:
        return self.area_mm2() / self.cluster_area_mm2


DEFAULT_MODEL = RedMulEModel()


# ---------------------------------------------------------------------- #
# Engine instrumentation -> machine-model workloads
# ---------------------------------------------------------------------- #
# The Engine (repro.core.engine) emits a GemmEvent per dispatch; instead of
# re-deriving GEMM shapes by hand for every workload, the machine model can
# consume a recorded event stream directly.  Events are duck-typed (anything
# with .spec.{m,n,k,batch,groups} and .count), so there is no engine import.
def gemms_from_events(events) -> List[Tuple[GEMM, int]]:
    """Convert engine ``GemmEvent``s into ``(GEMM, multiplicity)`` pairs.

    Each batched/grouped dispatch counts as ``batch * groups * count``
    independent (M, N, K) problems on the accelerator.  Backward events
    (``matmul_dx`` / ``matmul_dw`` from the Engine's custom-VJP rules) are
    ordinary pairs — a value_and_grad trace yields the full train-step
    workload, fwd and bwd — and ``jax.checkpoint`` recompute events count
    too (the recompute executes at run time).  Epilogue *pass* events
    (``*_dact`` / ``*_dbias``: the two-pass backward fallback's standalone
    ds multiply and bias-grad reduction) carry no MACs and are skipped —
    the cycle model prices GEMM passes on the array, not VPU element-wise
    traffic.  Ragged grouped events keep the dense per-group shape here
    (an upper bound: the cycle model bills the padded tiles the array
    would sweep; the event's own ``flops``/``bytes`` already scale with
    ``valid_rows``)."""
    out: List[Tuple[GEMM, int]] = []
    for ev in events:
        if _is_pass(ev):
            continue
        s = ev.spec
        out.append((GEMM(M=s.m, N=s.n, K=s.k),
                    s.batch * s.groups * ev.count))
    return out


def _is_backward(ev) -> bool:
    # lazy import: this module is pure math with no jax dependency
    from repro.core.engine import is_backward_op

    return is_backward_op(ev.spec.op) or getattr(ev, "recompute", False)


def _is_pass(ev) -> bool:
    # lazy import: this module is pure math with no jax dependency
    from repro.core.engine import is_pass_op

    return is_pass_op(ev.spec.op)


def workload_cycles_from_events(
    model: RedMulEModel, events
) -> Tuple[float, float]:
    """(hw_cycles, sw_cycles) of an instrumented workload on ``model``.

    Includes the backward GEMMs when the events come from a
    ``value_and_grad`` trace — the Engine's VJP rules emit them like any
    other dispatch (use :func:`workload_cycles_by_direction` to split)."""
    pairs = gemms_from_events(events)
    hw = sum(model.hw_cycles(g) * c for g, c in pairs)
    sw = sum(model.sw_cycles(g) * c for g, c in pairs)
    return hw, sw


def workload_cycles_by_direction(
    model: RedMulEModel, events
) -> Dict[str, Tuple[float, float]]:
    """{"fwd": (hw, sw), "bwd": (hw, sw)} — the paper's Fig 4c split
    (bwd > fwd per layer: dX's skinny-K GEMM plus dW's fat-K GEMM),
    straight from an instrumented train-step trace."""
    fwd = [ev for ev in events if not _is_backward(ev)]
    bwd = [ev for ev in events if _is_backward(ev)]
    return {
        "fwd": workload_cycles_from_events(model, fwd),
        "bwd": workload_cycles_from_events(model, bwd),
    }


def workload_flops(pairs: Sequence[Tuple[GEMM, int]]) -> int:
    """Total flops (2 * MACs) of a ``(GEMM, multiplicity)`` workload."""
    return sum(2 * g.macs * c for g, c in pairs)


def workload_hbm_bytes_from_events(events) -> Dict[str, int]:
    """{"total", "fwd", "bwd"} analytic HBM bytes of an instrumented
    workload, priced at each operand's **true storage width**.

    The per-event byte count comes from ``GemmSpec.bytes``, which bills
    the x/w operand slots at their per-operand storage dtypes
    (``GemmSpec.x_dtype`` / ``w_dtype``): under the mixed-precision FP8
    policies the operand streams pay one byte per element while the MAC
    count — and therefore every cycle/throughput figure this model
    produces — is unchanged.  That is the mixed-precision RedMulE's
    proposition in one line: **bytes drop, flops don't.**  Pass events
    (``*_dact``/``*_dbias``/``*_postep``) carry real bytes and are
    included, unlike in the cycle model.  The direction split defers to
    :func:`repro.roofline.analysis.bytes_by_direction` — one source of
    truth for the fwd/bwd rule."""
    # lazy import: this module is pure math with no jax dependency
    from repro.roofline.analysis import bytes_by_direction

    d = bytes_by_direction(events)
    return {"total": int(d["fwd"] + d["bwd"]),
            "fwd": int(d["fwd"]), "bwd": int(d["bwd"])}


def dense_forward_gemms(cfg, batch: int, seq: int) -> List[Tuple[GEMM, int]]:
    """Analytic GEMM enumeration of one dense-transformer forward pass.

    The oracle the Engine's instrumentation is validated against
    (``tests/test_engine.py``): every GEMM of a ``block_kind == "attn"``
    GQA forward (no cache, ``seq <= q_chunk``, GLU MLP, with LM head) in
    the Engine's (batch, M, N, K) convention.
    """
    if cfg.block_kind != "attn" or cfg.mla is not None:
        raise ValueError("dense_forward_gemms covers dense GQA archs only")
    if seq > cfg.q_chunk:
        raise ValueError("seq > q_chunk: the q-chunk scan changes the shapes")
    if cfg.mlp != "glu":
        raise ValueError("dense_forward_gemms assumes the GLU MLP")
    B, S, d = batch, seq, cfg.d_model
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    L, ff, V = cfg.n_layers, cfg.d_ff, cfg.vocab_size
    g = hq // hkv
    pairs: List[Tuple[GEMM, int]] = [
        (GEMM(M=S, N=d, K=(hq + 2 * hkv) * hd), B * L),   # fused qkv
        (GEMM(M=S, N=hd, K=S), B * hkv * g * L),          # scores  q @ k^T
        (GEMM(M=S, N=S, K=hd), B * hkv * g * L),          # context p @ v
        (GEMM(M=S, N=hq * hd, K=d), B * L),               # wo
        (GEMM(M=S, N=d, K=2 * ff), B * L),                # mlp w_in (gate|up)
        (GEMM(M=S, N=ff, K=d), B * L),                    # mlp w_out
        (GEMM(M=S, N=d, K=V), B),                         # lm head
    ]
    return pairs


# ---------------------------------------------------------------------- #
# TinyMLPerf AutoEncoder use case (paper §III-B, Fig 4c/4d)
# ---------------------------------------------------------------------- #
# MLPerf Tiny anomaly-detection deep AutoEncoder (ToyADMOS):
# 640 -> [128 x4] -> 8 -> [128 x4] -> 640.
AE_DIMS: Tuple[int, ...] = (640, 128, 128, 128, 128, 8, 128, 128, 128, 128, 640)


def autoencoder_gemms(batch: int) -> Dict[str, List[GEMM]]:
    """Forward + backward GEMMs of the AE at batch size B.

    Forward computes Z(out,B) = W(out,in) @ X(in,B): K == B — the skinny-K
    regime the paper calls out.  Backward per layer:
      dX(in,B)  = W^T(in,out) @ dZ(out,B)        (K == B again)
      dW(out,in) = dZ(out,B)  @ X^T(B,in)        (N == B, K == in: fat K)
    """
    fwd, bwd = [], []
    dims = AE_DIMS
    for i in range(len(dims) - 1):
        d_in, d_out = dims[i], dims[i + 1]
        fwd.append(GEMM(M=d_out, N=d_in, K=batch))
        bwd.append(GEMM(M=d_in, N=d_out, K=batch))   # dX
        bwd.append(GEMM(M=d_out, N=batch, K=d_in))   # dW
    return {"fwd": fwd, "bwd": bwd}


def autoencoder_report(model: RedMulEModel, batch: int) -> Dict[str, float]:
    gs = autoencoder_gemms(batch)
    hw_f, sw_f = model.workload_cycles(gs["fwd"])
    hw_b, sw_b = model.workload_cycles(gs["bwd"])
    macs = sum(g.macs for g in gs["fwd"] + gs["bwd"])
    params = sum(AE_DIMS[i] * AE_DIMS[i + 1] + AE_DIMS[i + 1] for i in range(len(AE_DIMS) - 1))
    acts = batch * sum(AE_DIMS)
    return {
        "batch": batch,
        "hw_cycles": hw_f + hw_b,
        "sw_cycles": sw_f + sw_b,
        "speedup": (sw_f + sw_b) / (hw_f + hw_b),
        "speedup_fwd": sw_f / hw_f,
        "speedup_bwd": sw_b / hw_b,
        "hw_macs_per_cycle": macs / (hw_f + hw_b),
        # fp16 activation + gradient working set (the B-dependent part the
        # paper's "184 kB @ B=16" tracks; params are B-independent and
        # reported separately)
        "footprint_kb": 2 * acts * 2 / 1024.0,
        "params_kb": params * 2 / 1024.0,
    }


# ---------------------------------------------------------------------- #
# Table I published rows (for the SoA benchmark printout)
# ---------------------------------------------------------------------- #
TABLE1_PUBLISHED: Dict[str, Dict[str, object]] = {
    "pulp_redmule_22nm_peak_eff": dict(
        tech_nm=22, area_mm2=0.5, freq_mhz=476, volt=0.65, power_mw=43.5,
        perf_gops=30.0, gops_per_w=688.0, macs=32, precision="FP16"),
    "pulp_redmule_22nm_peak_perf": dict(
        tech_nm=22, area_mm2=0.5, freq_mhz=666, volt=0.80, power_mw=90.7,
        perf_gops=42.0, gops_per_w=462.0, macs=32, precision="FP16"),
    "pulp_redmule_65nm": dict(
        tech_nm=65, area_mm2=3.85, freq_mhz=200, volt=1.2, power_mw=89.1,
        perf_gops=12.6, gops_per_w=152.0, macs=32, precision="FP16"),
    "eyeriss_65nm": dict(
        tech_nm=65, area_mm2=12.25, freq_mhz=250, volt=1.0, power_mw=278.0,
        perf_gops=46.0, gops_per_w=166.0, macs=168, precision="INT16"),
    "anders_14nm_peak_eff": dict(
        tech_nm=14, area_mm2=0.024, freq_mhz=2.1, volt=0.26, power_mw=0.023,
        perf_gops=0.068, gops_per_w=2970.0, macs=16, precision="FP16"),
    "ibm_7nm_peak_eff": dict(
        tech_nm=7, area_mm2=19.6, freq_mhz=1000, volt=0.55, power_mw=4400.0,
        perf_gops=8000.0, gops_per_w=1800.0, macs=4096, precision="FP16"),
}
