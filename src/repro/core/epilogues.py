"""The Engine's epilogue registry — activations fusable into the GEMM store.

RedMulE's follow-up engine work (arXiv:2301.03904) draws the line between a
GEMM *unit* and a GEMM-*layer* unit at exactly this point: whether the
``act(Z + b)`` tail runs inside the accumulation datapath or as a separate
pass over HBM.  This module is the single source of truth for which
epilogues exist, shared by :mod:`repro.core.engine` (post-op fallback path)
and :mod:`repro.kernels.redmule_matmul` (in-kernel fused path) so the two
paths can never drift apart.

Every function here is built from plain ``jnp``/``jax.nn`` primitives that
lower inside a Pallas TPU kernel body (VPU element-wise ops only — no
reductions, no reshapes), which is what makes in-kernel fusion possible.
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

__all__ = ["EPILOGUES", "epilogue_names", "apply_epilogue", "validate_epilogue"]

# name -> element-wise fn, applied in the accumulation dtype
EPILOGUES: Dict[str, Callable[[jax.Array], jax.Array]] = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
}


def epilogue_names() -> tuple:
    return tuple(sorted(EPILOGUES))


def validate_epilogue(name) -> None:
    """Raise ValueError for an unknown epilogue name (None is allowed)."""
    if name is not None and name not in EPILOGUES:
        raise ValueError(
            f"unknown epilogue {name!r}; known: {sorted(EPILOGUES)}")


def apply_epilogue(name, z: jax.Array) -> jax.Array:
    """Apply epilogue ``name`` (or pass through when None)."""
    if name is None:
        return z
    return EPILOGUES[name](z)
