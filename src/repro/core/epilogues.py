"""The Engine's epilogue registry — activations fusable into the GEMM store.

RedMulE's follow-up engine work (arXiv:2301.03904) draws the line between a
GEMM *unit* and a GEMM-*layer* unit at exactly this point: whether the
``act(Z + b)`` tail runs inside the accumulation datapath or as a separate
pass over HBM.  This module is the single source of truth for which
epilogues exist, shared by :mod:`repro.core.engine` (post-op fallback path)
and :mod:`repro.kernels.redmule_matmul` (in-kernel fused path) so the two
paths can never drift apart.

Every function here is built from plain ``jnp``/``jax.nn`` primitives that
lower inside a Pallas TPU kernel body (VPU element-wise ops only — no
reductions, no reshapes), which is what makes in-kernel fusion possible.

Alongside each activation lives its **derivative** (:data:`EPILOGUE_GRADS`),
consumed by the Engine's custom-VJP rules for :func:`repro.core.engine.linear`:
the backward pass needs ``act'(s)`` (``s`` the pre-activation accumulator) to
turn the output cotangent into the pre-activation cotangent ``ds = dz *
act'(s)`` before the two backward GEMMs.  On backends with the
``"fused_bwd_epilogue"`` capability the derivative is applied *inside* the
backward kernels — :mod:`repro.kernels.redmule_matmul` evaluates these
same registry entries on the dZ tile at load time, so (like the forward
:data:`EPILOGUES`) every derivative must be built from plain
``jnp``/``jax.nn`` element-wise primitives that lower in a Pallas kernel
body.  Two flavours are registered:

* ``deriv(s)`` — ``act'`` from the *pre-activation* (always present);
* ``deriv_from_output(z)`` — ``act'`` recovered from the *post-activation*
  output where the activation is invertible enough (relu: ``z > 0``; tanh:
  ``1 - z**2``).  When available, the VJP forward keeps the fully fused
  kernel (bias *and* activation in the store step) and saves only ``z``;
  otherwise it saves the pre-activation ``s`` and applies the activation
  post-op during the forward-for-grad trace.

``relu``'s derivative takes the ``s > 0`` branch, i.e. the subgradient 0 at
the kink — tests exclude inputs at exactly 0.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "EPILOGUES",
    "EPILOGUE_GRADS",
    "EpilogueGrad",
    "epilogue_names",
    "apply_epilogue",
    "validate_epilogue",
    "epilogue_grad",
]

# name -> element-wise fn, applied in the accumulation dtype
EPILOGUES: Dict[str, Callable[[jax.Array], jax.Array]] = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
}


@dataclasses.dataclass(frozen=True)
class EpilogueGrad:
    """Derivative entry for one registered epilogue.

    ``deriv(s)`` returns ``act'(s)`` element-wise from the pre-activation;
    ``deriv_from_output(z)`` (optional) returns the same from ``z = act(s)``
    — registering it lets the Engine's linear VJP keep the fully fused
    forward kernel and save the output instead of the pre-activation."""

    deriv: Callable[[jax.Array], jax.Array]
    deriv_from_output: Optional[Callable[[jax.Array], jax.Array]] = None


def _relu_deriv(s: jax.Array) -> jax.Array:
    return (s > 0).astype(s.dtype)


def _tanh_deriv(s: jax.Array) -> jax.Array:
    t = jnp.tanh(s)
    return 1.0 - t * t


def _silu_deriv(s: jax.Array) -> jax.Array:
    sig = jax.nn.sigmoid(s)
    return sig * (1.0 + s * (1.0 - sig))


_GELU_C = 0.7978845608028654  # sqrt(2 / pi)
_GELU_A = 0.044715


def _gelu_deriv(s: jax.Array) -> jax.Array:
    # derivative of the tanh-approximate gelu (jax.nn.gelu's default form):
    # g(s) = 0.5 s (1 + tanh(u)),  u = sqrt(2/pi) (s + 0.044715 s^3)
    u = _GELU_C * (s + _GELU_A * s * s * s)
    t = jnp.tanh(u)
    du = _GELU_C * (1.0 + 3.0 * _GELU_A * s * s)
    return 0.5 * (1.0 + t) + 0.5 * s * (1.0 - t * t) * du


EPILOGUE_GRADS: Dict[str, EpilogueGrad] = {
    "relu": EpilogueGrad(deriv=_relu_deriv,
                         deriv_from_output=lambda z: (z > 0).astype(z.dtype)),
    "tanh": EpilogueGrad(deriv=_tanh_deriv,
                         deriv_from_output=lambda z: 1.0 - z * z),
    "silu": EpilogueGrad(deriv=_silu_deriv),
    "gelu": EpilogueGrad(deriv=_gelu_deriv),
}


def epilogue_names() -> tuple:
    return tuple(sorted(EPILOGUES))


def validate_epilogue(name) -> None:
    """Raise ValueError for an unknown epilogue name (None is allowed)."""
    if name is not None and name not in EPILOGUES:
        raise ValueError(
            f"unknown epilogue {name!r}; known: {sorted(EPILOGUES)}")


def apply_epilogue(name, z: jax.Array) -> jax.Array:
    """Apply epilogue ``name`` (or pass through when None)."""
    if name is None:
        return z
    return EPILOGUES[name](z)


def epilogue_grad(name: str) -> EpilogueGrad:
    """Derivative entry for epilogue ``name`` (KeyError if unregistered —
    every :data:`EPILOGUES` entry must have a matching grad)."""
    return EPILOGUE_GRADS[name]
