"""The RedMulE Engine — a first-class GEMM surface with pluggable backends.

The paper's thesis is that *one* parametric GEMM engine serves every DL
kernel — inference, training, attention, experts.  This module is that
engine as an API:

* :class:`GemmSpec`   — a frozen description of one contraction (einsum-style
  tag, M/N/K, batching/grouping, precision :class:`~repro.core.precision.Policy`,
  :class:`~repro.core.tiling.TileConfig`).
* :class:`Engine`     — resolves a spec to a backend and dispatches it.  The
  op family covers what the models need: :meth:`Engine.matmul`,
  :meth:`Engine.linear` (fused bias+activation epilogue),
  :meth:`Engine.grouped_matmul` (ragged per-expert GEMM for MoE) and
  :meth:`Engine.einsum2d` (two-operand contractions).
* a **backend registry** — :func:`register_backend` replaces the old
  hard-coded backend tuple; "pallas", "interpret" and "xla" are ordinary
  registered entries and third-party/GPU backends plug in at runtime without
  editing this module.  Each entry carries a set of **capability flags**:
  ``"fused_epilogue"`` means the backend applies bias+activation inside its
  kernel's store step (one HBM write per affine layer — see
  :meth:`Engine.linear`); ``"tiled"`` means it consumes ``spec.tile``.
* **instrumentation** — every dispatch emits a :class:`GemmEvent` (flops,
  bytes, the *resolved* tile, backend, policy) into the thread-local
  :func:`instrument` collector; :mod:`repro.roofline.analysis` and
  :mod:`repro.core.perf_model` consume these instead of re-deriving shapes
  by hand.

Backend resolution precedence: explicit ``backend=`` argument >
:func:`use_backend` context (thread-local) > ``REPRO_MATMUL_BACKEND`` env
var (validated at read time) > platform default ("pallas" on TPU, "xla"
elsewhere).

Tile resolution precedence (per dispatch): explicit ``tile=`` argument >
the :mod:`repro.core.autotune` cache (measured-or-modeled winners keyed on
the canonicalized spec, persisted via ``REPRO_AUTOTUNE_CACHE``) > the
:func:`repro.core.tiling.choose_tiles` heuristic (memoized).  The resolved
tile rides on the emitted :class:`GemmEvent`.

Events are emitted at *trace* time: under ``jax.jit`` a cached executable
re-runs without re-tracing, so wrap the tracing call (``.lower()``,
``jax.eval_shape`` or the first invocation) in :func:`instrument`.  Code
that traces a loop body once but executes it N times (``lax.scan`` layer
stacks, q-chunk loops, grad-accumulation) wraps the scan in
:func:`repeat` so each event carries the right multiplicity.

**The backward contract.**  Every op in the family carries a
``jax.custom_vjp``, so ``jax.grad`` through an Engine op re-enters the
Engine instead of falling back to XLA-derived ``dot_general`` transposes:

* the VJP rules dispatch dX = dZ·Wᵀ and dW = Xᵀ·dZ through the same
  backend registry, as **transpose-layout** specs (``spec.layout`` "nt" /
  "tn") — backends with the ``"layouts"`` capability ("pallas",
  "interpret", "xla") consume the operands in their forward storage with
  no materialized transpose (the Pallas kernels run the same
  X-stationary / store-once schedule with remapped BlockSpecs); for
  backends without it the engine pre-transposes and dispatches an "nn"
  spec;
* backward dispatches emit :class:`GemmEvent`\\ s tagged ``op="matmul_dx"``
  / ``"matmul_dw"`` (whatever the forward op), so instrumented training
  traces carry the full fwd+bwd GEMM workload — three tile-stamped events
  per affine layer;
* **grad dtypes**: residuals (X, W, and the pre-activation for ``linear``
  epilogues without an output-form derivative) are saved in the policy's
  *compute* dtype; backward GEMMs run under the same policy with their
  output held in the *accum* dtype until the final cast to the primal
  operand's dtype.  The bias gradient is the accum-dtype row reduction of
  the pre-activation cotangent;
* **epilogue derivatives** (``linear``): ``ds = dZ * act'(s)`` uses the
  derivative registry in :mod:`repro.core.epilogues`.  relu/tanh recover
  ``act'`` from the fused output (the forward stays fully fused);
  gelu/silu save the pre-activation, so their forward-for-grad applies
  the activation post-op (~2 ulp from the fused inference path, same
  bound as the documented fused-vs-unfused contract);
* **one-pass backward** (the ``"fused_bwd_epilogue"`` capability;
  "pallas"/"interpret", 2D weights): the dX and dW kernels apply ``act'``
  to the dZ tile *on load* — the saved residual rides as a derivative
  operand in the dispatch (``GemmSpec.grad_epilogue`` / ``grad_mode`` /
  ``fused_bwd``) — and the dW kernel accumulates ``db = Σ_rows ds`` into a
  second accum-dtype output in the same pass (``fused_bias_grad``), so the
  pre-activation cotangent ``ds`` never round-trips HBM.  Non-capable
  backends (and batched weights) keep the two-pass fallback, whose
  standalone multiply and separate bias-grad reduction are billed as
  ``linear_dact`` / ``linear_dbias`` *pass events* (zero flops, real
  bytes) so the byte accounting of both paths is comparable;
* **remat**: ``jax.checkpoint`` recompute traces are detected
  automatically (see ``_fwd_trace_kind``: the custom-VJP primal and fwd
  rules both trace under one call context exactly when a region re-traces
  for remat) — recompute events are tagged ``recompute=True``, inherit
  the multiplicity captured at the primal trace, and partial-eval
  artifact re-traces are suppressed, so remat train traces report true
  flops/bytes with no model-code changes;
* backward events inherit the :func:`repeat` multiplicity captured at
  *forward* trace time — a GEMM traced in a scanned layer body gets the
  same ``count`` on its dX/dW events even though JAX traces the backward
  scan outside the ``repeat`` context.

**The mixed-precision contract** (per-operand storage, PR 5).  A
:class:`~repro.core.precision.Policy` may store each operand narrower
than it computes (``x_dtype`` / ``w_dtype`` / ``grad_dtype``; the FP8
policies ``mixed_fp8_e4m3`` / ``mixed_fp8_e5m2``):

* the engine quantizes FP8 operands **per tensor** around every dispatch
  (``q = v / s``, ``s = amax`` — unit-max, so the binary16 datapath
  cannot overflow) and multiplies the scale product
  back into the accumulator afterwards; backends with the
  ``"operand_dtypes"`` capability receive the narrow arrays and upcast
  tiles to the compute dtype *on load* inside their kernels (no HBM-side
  cast pass), others receive the quantized values widened before
  dispatch — the quantization point is backend-invariant, so the same
  policy yields the same numerics on every backend;
* residuals are saved in the dispatch storage (FP8), so the backward
  GEMMs re-read them narrow; the cotangent quantizes to ``grad_dtype``
  (E5M2: range over precision) *after* the activation-derivative
  multiply, once, in the engine — scaled specs therefore always run the
  post-op epilogue and the two-pass backward (``fuse``/``fuse_bwd`` off),
  and the bias grad reduces from the wide cotangent (no FP8 error); the
  forced post-op forward pass is billed honestly as a ``*_postep`` pass
  event (the stored result's HBM round-trip — so FP8 traces compare
  like-for-like against fused FP16 ones);
* ``GemmSpec.x_dtype`` / ``w_dtype`` record what each slot actually
  carried, and the byte accounting prices each operand at its true
  element width — **bytes drop, flops don't** (the paper's successor
  engine's whole point);
* **FP8 tolerance rows** (extending the fused-vs-unfused table in
  :meth:`Engine.linear`): quantize→dequantize round-trips are bounded by
  the format's relative epsilon (E4M3: 2⁻³; E5M2: 2⁻²) for values within
  ~2⁻⁹ of the tensor amax; cross-backend grads under one FP8 policy
  agree to the *compute*-dtype tolerance (fp16 ~2e-2), because the FP8
  rounding itself is deterministic and shared.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
import threading
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune
from repro.core import epilogues as epi
from repro.core import precision as prec
from repro.core import tiling

__all__ = [
    "GemmSpec",
    "GemmEvent",
    "Engine",
    "BackendSpec",
    "register_backend",
    "unregister_backend",
    "registered_backends",
    "get_backend",
    "backend_available",
    "backend_supports",
    "default_backend",
    "set_default_backend",
    "use_backend",
    "matmul",
    "linear",
    "grouped_matmul",
    "einsum2d",
    "attention",
    "linear_attention",
    "is_backward_op",
    "is_pass_op",
    "instrument",
    "repeat",
    "paused",
    "op_scope",
    "total_flops",
    "total_bytes",
    "summarize",
    "DEFAULT_ENGINE",
]

ENV_VAR = "REPRO_MATMUL_BACKEND"


# --------------------------------------------------------------------- #
# Spec / event
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """One contraction, fully described.

    Attributes:
      op: op-family name ("matmul" | "linear" | "grouped_matmul" | "einsum2d").
      tag: einsum-style contraction tag (e.g. ``"mn,nk->mk"``).
      m, n, k: the 2D GEMM problem per batch element per group
        (Z[m,k] = X[m,n] @ W[n,k] — the paper's naming).
      batch: product of leading (vmapped/broadcast) dims.
      groups: expert-group count for grouped GEMMs (1 otherwise).
      policy: resolved precision policy.
      tile: the resolved tile config (explicit arg > autotune cache >
        ``choose_tiles`` heuristic; the Engine resolves it before emitting
        the event, so instrumentation always sees the real block geometry).
      epilogue: fused epilogue activation name for ``linear`` (or None).
      layout: operand storage of the logical contraction — "nn" (forward),
        "nt" (w stored transposed; the dX dispatch) or "tn" (x stored
        transposed; the dW dispatch).  m/n/k keep their *logical* meaning
        in every layout, so flops/bytes are layout-invariant.
      valid_rows: for ragged grouped GEMMs, the total valid rows of the
        ragged dimension summed over groups (``sum(min(group_sizes, dim))``)
        when statically known — replaces ``groups * <ragged dim>`` in the
        flops/bytes accounting so masked rows are not billed.  None means
        dense (or the sizes were traced and unknowable at trace time).
      ragged_dim: which logical dim ``valid_rows`` masks — "m" (forward and
        dX: ragged output rows) or "n" (dW: ragged contraction rows).
      grad_epilogue: on a backward dispatch, the activation whose derivative
        feeds this GEMM (``ds = dZ * act'``); None on forward dispatches
        and epilogue-free backwards.
      grad_mode: how ``act'`` is recovered — "output" (from the fused
        forward output; relu/tanh) or "preact" (from the saved
        pre-activation; gelu/silu).
      fused_bwd: True when the backend applies ``act'`` to the dZ tile *on
        load* inside the kernel (the ``"fused_bwd_epilogue"`` capability) —
        the derivative operand is streamed alongside the GEMM operands and
        ``ds`` is never materialized in HBM.  False on the two-pass
        fallback, whose standalone multiply is billed by a separate
        ``*_dact`` pass event instead.
      fused_bias_grad: True when this (dW) dispatch also accumulates
        ``db = Σ_rows ds`` into a second accum-dtype output in the same
        pass (no separate ``*_dbias`` reduction event).
      x_dtype / w_dtype: per-operand *storage* dtype names the dispatch
        actually carries (None = the policy's compute dtype).  Under a
        mixed-storage policy on an ``"operand_dtypes"``-capable backend
        these are the narrow (FP8) names — the byte accounting prices
        each operand slot at its true element width.  On backward
        dispatches the slots swap roles (the dZ operand rides in the
        *grad* storage: the x slot on dX, the w slot on dW).
      scaled: True when per-tensor scales travel with this dispatch (FP8
        storage): the engine quantizes ``q = v / s`` before the GEMM and
        multiplies the scale product back into the accumulator after —
        scale scalars are metadata here, their bytes are negligible.
      io_bytes: exact HBM operand + result bytes of one execution, when
        the generic per-slot formula below cannot express them.  The
        attention sweeps set this: their operands are shared across many
        per-block GEMMs (Q is read once per Q block, not once per score
        GEMM; the linear-attention state never leaves VMEM until the final
        store), so the engine bills each sweep's true traffic here and
        :attr:`bytes` returns it verbatim.  None (all plain GEMMs) keeps
        the formula.
    """

    op: str
    tag: str
    m: int
    n: int
    k: int
    batch: int = 1
    groups: int = 1
    policy: prec.Policy = prec.TPU_BF16
    tile: Optional[tiling.TileConfig] = None
    epilogue: Optional[str] = None
    # the weight operand is shared across the batch (read once per group)
    w_shared: bool = False
    layout: str = "nn"
    valid_rows: Optional[int] = None
    ragged_dim: str = "m"
    grad_epilogue: Optional[str] = None
    grad_mode: Optional[str] = None
    fused_bwd: bool = False
    fused_bias_grad: bool = False
    x_dtype: Optional[str] = None
    w_dtype: Optional[str] = None
    scaled: bool = False
    io_bytes: Optional[int] = None

    def __post_init__(self):
        if self.layout not in ("nn", "nt", "tn"):
            raise ValueError(
                f"GemmSpec.layout = {self.layout!r}; known: ('nn', 'nt', 'tn')")
        if self.ragged_dim not in ("m", "n"):
            raise ValueError(
                f"GemmSpec.ragged_dim = {self.ragged_dim!r}; known: ('m', 'n')")
        # a typo'd dtype fails here, naming the field, instead of deep in
        # Pallas lowering (one validator shared with Policy)
        for f in ("x_dtype", "w_dtype"):
            prec._validate_dtype("GemmSpec", f, getattr(self, f),
                                 optional=True)

    @property
    def flops(self) -> int:
        """MAC-derived flops of one execution (2 * B * G * M * N * K; for
        ragged grouped GEMMs ``valid_rows`` replaces ``G * <ragged dim>``).
        Pass events (``*_dact`` / ``*_dbias``) carry no MACs."""
        if is_pass_op(self.op):
            return 0
        if self.valid_rows is None:
            return 2 * self.batch * self.groups * self.m * self.n * self.k
        if self.ragged_dim == "m":
            return 2 * self.batch * self.valid_rows * self.n * self.k
        return 2 * self.batch * self.m * self.valid_rows * self.k

    @property
    def dense_flops(self) -> int:
        """Flops of the *dense* contraction this spec lowers to
        (``2 * B * G * M * N * K``), ignoring ragged ``valid_rows``
        billing.

        Ragged grouped GEMMs bill only their valid rows in :attr:`flops`,
        but the ``dot_general`` the XLA backend emits is dense — masking
        happens around it, not inside it.  ``dense_flops`` is therefore
        the quantity the static escape auditor
        (:mod:`repro.analysis.jaxpr_audit`) uses to reconcile engine
        dispatches against the equations found in a traced jaxpr.  Pass
        events (``*_dact`` / ``*_dbias`` / ``*_postep``) lower no
        contraction and report 0."""
        if is_pass_op(self.op):
            return 0
        return 2 * self.batch * self.groups * self.m * self.n * self.k

    @property
    def bytes(self) -> int:
        """HBM-side operand + result bytes of one execution.

        When ``w_shared`` the weight operand is read once per group, not
        once per batch element (weight GEMMs: one (N, K) matrix serves the
        whole batch).  Ragged grouped GEMMs (``valid_rows``) bill only the
        valid rows of the ragged operand(s) and — for ``ragged_dim == "m"``
        — of the output.

        Backward-epilogue traffic is billed where it actually flows:
        ``*_dact`` pass events (the two-pass fallback) pay the full
        ``ds = dZ ⊙ act'`` HBM round-trip (read dZ, read the saved
        activation residual, write ds) and ``*_dbias`` events pay the
        separate bias-grad reduction; fused dispatches instead add the
        streamed derivative operand (``fused_bwd``) and the db output row
        (``fused_bias_grad``) to the GEMM's own operand bytes — strictly
        less than the round-trip they replace.

        Per-operand storage (``x_dtype`` / ``w_dtype``) prices each
        operand slot at its **true element width**: an FP8-stored operand
        pays one byte per element while the output (and the streamed
        derivative residual) stay at the out/compute width — narrower
        storage drops bytes, never flops."""
        if self.io_bytes is not None:
            return self.io_bytes
        cb = jnp.dtype(self.policy.compute_dtype).itemsize
        ob = jnp.dtype(self.policy.out_dtype).itemsize
        ab = jnp.dtype(self.policy.accum_dtype).itemsize
        xb = jnp.dtype(self.x_dtype).itemsize if self.x_dtype else cb
        wb = jnp.dtype(self.w_dtype).itemsize if self.w_dtype else cb
        bg = self.batch * self.groups
        if self.op.endswith("_dact"):
            # standalone ds = dZ * act'(residual) over the (M, K) cotangent:
            # read dZ, read the residual, write ds
            return 3 * bg * self.m * self.k * cb
        if self.op.endswith("_dbias"):
            # separate bias-grad pass: re-read the cotangent, write the row
            return bg * self.m * self.k * cb + self.k * ab
        if self.op.endswith("_postep"):
            # the policy-forced post-op epilogue pass (scaled specs only):
            # the stored GEMM result round-trips HBM around the
            # scale-undo + bias/activation, plus the accum-dtype bias row
            return 2 * bg * self.m * self.k * ob + self.k * ab
        if self.valid_rows is None:
            x_elems = bg * self.m * self.n
            z_elems = bg * self.m * self.k
            w_elems = (self.groups if self.w_shared else bg) * self.n * self.k
        elif self.ragged_dim == "m":
            x_elems = self.batch * self.valid_rows * self.n
            z_elems = self.batch * self.valid_rows * self.k
            w_elems = (self.groups if self.w_shared else bg) * self.n * self.k
        else:  # ragged contraction rows (the dW dispatch)
            x_elems = self.batch * self.m * self.valid_rows
            z_elems = bg * self.m * self.k
            w_elems = (self.groups * self.n if self.w_shared
                       else self.batch * self.valid_rows) * self.k
        total = x_elems * xb + z_elems * ob + w_elems * wb
        if self.fused_bwd and self.grad_epilogue is not None:
            # the streamed derivative operand shadows the dZ operand: the
            # x slot on dX ("nt"), the w slot on dW ("tn"); the residual
            # rides in the compute dtype
            total += (x_elems if self.op.endswith("_dx") else w_elems) * cb
        if self.fused_bias_grad:
            total += self.k * ab   # the fused db output row
        return total


@dataclasses.dataclass(frozen=True)
class GemmEvent:
    """One engine dispatch, as observed by :func:`instrument`.

    ``count`` is the trace-context multiplicity (see :func:`repeat`):
    a GEMM traced inside a 28-layer ``lax.scan`` body appears once with
    ``count=28``.  ``recompute`` marks events emitted during a
    ``jax.checkpoint`` recompute trace — the GEMM re-executes during the
    backward pass (real flops/bytes at run time, but not new forward
    work); such events inherit the multiplicity captured at the *primal*
    forward trace.
    """

    spec: GemmSpec
    backend: str

    count: int = 1
    recompute: bool = False

    @property
    def flops(self) -> int:
        return self.spec.flops

    @property
    def bytes(self) -> int:
        return self.spec.bytes

    @property
    def total_flops(self) -> int:
        return self.spec.flops * self.count

    @property
    def total_bytes(self) -> int:
        return self.spec.bytes * self.count


def is_backward_op(op: str) -> bool:
    """True for op tags emitted by the Engine's VJP rules (dX / dW GEMMs
    and the ``*_dact`` / ``*_dbias`` epilogue pass events of the two-pass
    fallback).

    The single source of truth for the fwd/bwd event split —
    :mod:`repro.roofline.analysis` and :mod:`repro.core.perf_model` both
    defer here."""
    return op.endswith(("_dx", "_dw", "_dact", "_dbias"))


def is_pass_op(op: str) -> bool:
    """True for non-GEMM *pass* events: the standalone ``ds = dZ ⊙ act'``
    multiply (``*_dact``) and the separate bias-grad reduction
    (``*_dbias``) of the two-pass backward fallback, and the
    policy-forced post-op epilogue round-trip of scaled FP8 forwards
    (``*_postep`` — a forward event).  Pass events carry HBM bytes but
    zero MAC flops; cycle models skip them."""
    return op.endswith(("_dact", "_dbias", "_postep"))


def total_flops(events: Sequence[GemmEvent]) -> int:
    return sum(ev.total_flops for ev in events)


def total_bytes(events: Sequence[GemmEvent]) -> int:
    return sum(ev.total_bytes for ev in events)


def dispatch_footprint(events: Sequence[GemmEvent]) -> Dict[int, int]:
    """Map ``dense_flops -> total dispatch count`` over an event stream.

    The trace-capture hook for the static escape auditor: each non-pass
    engine dispatch lowers to exactly one ``dot_general`` on the XLA
    backend, costing :attr:`GemmSpec.dense_flops`, with trace multiplicity
    ``count``.  The auditor subtracts this footprint from the multiset of
    contractions found by walking the same trace's jaxpr; whatever remains
    escaped the Engine."""
    foot: Dict[int, int] = {}
    for ev in events:
        df = ev.spec.dense_flops
        if df <= 0:
            continue
        foot[df] = foot.get(df, 0) + ev.count
    return foot


def summarize(events: Sequence[GemmEvent]) -> Dict[str, Dict[str, float]]:
    """Per-op totals plus a grand total (for CLI printouts)."""
    out: Dict[str, Dict[str, float]] = {}
    for ev in events:
        d = out.setdefault(ev.spec.op, {"calls": 0, "flops": 0, "bytes": 0})
        d["calls"] += ev.count
        d["flops"] += ev.total_flops
        d["bytes"] += ev.total_bytes
    out["total"] = {
        "calls": sum(d["calls"] for d in out.values()),
        "flops": total_flops(events),
        "bytes": total_bytes(events),
    }
    return out


# --------------------------------------------------------------------- #
# Backend registry
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """A registered backend: ``fn(x, w, *, spec) -> array``.

    ``fn`` receives operands already cast to ``spec.policy.compute_dtype``
    (or, with the ``"operand_dtypes"`` capability, to the per-operand
    storage dtypes named by ``spec.x_dtype``/``spec.w_dtype``) with
    ``x: (..., M, N)`` and ``w: (N, K)`` or broadcast-compatible
    ``(..., N, K)``; it returns ``(..., M, K)`` in any float dtype (the
    engine downcasts to ``spec.policy.out_dtype``).

    ``capabilities`` is a frozenset of opt-in flags:

    * ``"fused_epilogue"`` — ``fn`` additionally accepts
      ``fn(x, w, *, spec, bias=None, fuse_epilogue=False)``.  When the
      engine passes ``fuse_epilogue=True`` the backend must apply
      ``spec.epilogue`` (and ``bias``, an accum-dtype ``(K,)`` row when not
      None) to the accumulator *before* its single output store; the
      engine then skips its own post-op epilogue pass.
    * ``"tiled"`` — ``fn`` honors ``spec.tile`` as its block geometry (the
      engine resolves a tile for every dispatch regardless, for
      instrumentation; untiled backends simply ignore it).
    * ``"layouts"`` — ``fn`` honors ``spec.layout`` ("nn" | "nt" | "tn"):
      operands arrive in the storage the layout names (the Engine's
      backward dispatches pass W / X in their forward storage) and the
      backend contracts accordingly without materializing a transpose.
      Backends *without* this flag only ever see "nn" specs — the engine
      pre-transposes backward operands before dispatching to them.
    * ``"fused_bwd_epilogue"`` — ``fn`` additionally accepts
      ``fn(a, b, *, spec, deriv=None, bias_grad=False)`` on backward
      dispatches.  When ``spec.grad_epilogue`` is set, ``deriv`` is the
      activation-derivative operand (the fused forward output when
      ``spec.grad_mode == "output"``, else the saved pre-activation),
      stored exactly like the dZ operand; the backend must apply
      ``ds = dZ * act'(deriv)`` to the dZ tiles *on load*, in the accum
      dtype, so ``ds`` is never materialized in HBM.  With
      ``bias_grad=True`` (only on "tn" dW dispatches) ``fn`` returns
      ``(dW, db)`` where ``db`` is the accum-dtype ``(K,)`` row sum of
      the (derivative-adjusted) dZ rows, accumulated in the same pass.
      Backends without this flag get the engine's two-pass fallback (a
      standalone ``ds`` multiply + separate bias-grad reduction, billed
      as ``*_dact`` / ``*_dbias`` pass events).  Requires ``"layouts"``.
    * ``"operand_dtypes"`` — ``fn`` accepts operands in per-operand
      *storage* dtypes narrower than ``spec.policy.compute_dtype`` (FP8
      under the mixed-precision policies; ``spec.x_dtype`` /
      ``spec.w_dtype`` name what each slot carries) and upcasts them to
      the compute dtype **on load** inside its kernel — the result must
      equal dispatching the pre-upcast operands.  Backends without this
      flag only ever see compute-dtype operands: the engine widens the
      (already-quantized) values before dispatch, an HBM-side cast pass
      billed at the wide width.
    * ``"attention"`` — the backend implements the fused attention sweeps
      and ``attention_fn`` must be provided:
      ``attention_fn(kind, operands, **params)`` where ``kind`` is
      ``"attention"`` (operands ``(q, k, v)`` of shape ``(BH, S, D)`` /
      ``(BH_kv, T, D)``, params ``group / causal / scale / bq / bkv /
      t_valid / q_offset``, returns ``(BH, S, D)``) or
      ``"linear_attention"`` (operands ``(q, k, v, log_g)`` of shape
      ``(BH, S, dk)`` / ``(BH, S, dv)`` / ``(BH, S)``, param ``chunk``,
      returns ``(out (BH, S, dv), state (BH, dk, dv) fp32)``).  Operands
      arrive pre-cast and pre-padded to the block geometry; backends
      without this flag are served by the engine's reference composition
      of :func:`einsum2d` calls, so every backend answers attention.
    """

    name: str
    fn: Callable[..., jax.Array]
    available: Union[bool, Callable[[], bool]] = True
    description: str = ""
    capabilities: frozenset = frozenset()
    attention_fn: Optional[Callable[..., Any]] = None

    def is_available(self) -> bool:
        a = self.available
        return bool(a()) if callable(a) else bool(a)

    def supports(self, capability: str) -> bool:
        return capability in self.capabilities


_REGISTRY: Dict[str, BackendSpec] = {}


def register_backend(
    name: str,
    fn: Callable[..., jax.Array],
    *,
    available: Union[bool, Callable[[], bool]] = True,
    description: str = "",
    capabilities=(),
    attention_fn: Optional[Callable[..., Any]] = None,
) -> BackendSpec:
    """Register (or replace) a GEMM backend under ``name``.

    Third-party backends plug in here at runtime; no edits to core are
    needed for a new backend to be dispatchable by name through
    :func:`matmul` and friends.  ``capabilities`` declares the optional
    contracts the backend implements (see :class:`BackendSpec`); an empty
    set gets the baseline pure-GEMM treatment (the engine applies
    epilogues itself, post-op)."""
    if not name or not isinstance(name, str):
        raise ValueError(f"backend name must be a non-empty string, got {name!r}")
    caps = frozenset(capabilities)
    unknown = caps - {"fused_epilogue", "tiled", "layouts",
                      "fused_bwd_epilogue", "operand_dtypes", "attention"}
    if unknown:
        raise ValueError(f"unknown backend capabilities: {sorted(unknown)}")
    if "attention" in caps and attention_fn is None:
        raise ValueError(
            f"backend {name!r} declares the 'attention' capability but "
            "provides no attention_fn")
    spec = BackendSpec(name=name, fn=fn, available=available,
                       description=description, capabilities=caps,
                       attention_fn=attention_fn)
    _REGISTRY[name] = spec
    return spec


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def registered_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: str) -> BackendSpec:
    try:
        return _REGISTRY[name]
    except KeyError as e:
        raise ValueError(
            f"unknown backend {name!r}; registered: {registered_backends()}"
        ) from e


def backend_available(name: str) -> bool:
    return get_backend(name).is_available()


def backend_supports(name: str, capability: str) -> bool:
    return get_backend(name).supports(capability)


# --------------------------------------------------------------------- #
# Thread-local state: backend override, instrumentation, repeat scopes
# --------------------------------------------------------------------- #
_state = threading.local()


def _thread_backend() -> Optional[str]:
    return getattr(_state, "backend", None)


def _collectors() -> List[List[GemmEvent]]:
    c = getattr(_state, "collectors", None)
    if c is None:
        c = _state.collectors = []
    return c


def _repeat_multiplier() -> int:
    stack = getattr(_state, "repeat", None)
    if not stack:
        return 1
    m = 1
    for n in stack:
        m *= n
    return m


def default_backend() -> str:
    """Thread-local context > env var (validated here) > platform default."""
    b = _thread_backend()
    if b is not None:
        return b
    b = os.environ.get(ENV_VAR)
    if b:
        if b not in _REGISTRY:
            raise ValueError(
                f"environment variable {ENV_VAR}={b!r} names an unknown "
                f"backend; registered backends: {registered_backends()}")
        return b
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def set_default_backend(backend: Optional[str]) -> None:
    if backend is not None:
        get_backend(backend)  # validate against the registry
    _state.backend = backend


@contextlib.contextmanager
def use_backend(backend: str):
    """Thread-locally pin the default backend within the context."""
    old = _thread_backend()
    set_default_backend(backend)
    try:
        yield
    finally:
        set_default_backend(old)


@contextlib.contextmanager
def instrument() -> Iterator[List[GemmEvent]]:
    """Collect every engine dispatch traced in this thread.

        with engine.instrument() as events:
            logits, _, _ = transformer.forward(params, cfg, batch)
        print(engine.summarize(events))

    Nested collectors each observe all events.  Events are emitted at trace
    time — wrap the *tracing* call (first invocation, ``.lower()`` or
    ``jax.eval_shape``), not a cached jit re-execution.  Entering the
    *outermost* collector also resets the per-call primal/recompute
    bookkeeping (``jax.checkpoint`` detection — see ``_fwd_trace_kind``),
    so each instrumented trace classifies forward re-traces afresh."""
    events: List[GemmEvent] = []
    stack = _collectors()
    if not stack:
        _state.fwd_seen = {}
    stack.append(events)
    try:
        yield events
    finally:
        # remove by identity: equal-but-distinct lists (e.g. two empty
        # nested collectors) must not be confused by list.remove()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is events:
                del stack[i]
                break


@contextlib.contextmanager
def paused():
    """Suppress event emission within the context.

    For shape probes and oracle re-traces that would otherwise double-count
    dispatches inside an active :func:`instrument` collector."""
    prev = getattr(_state, "paused", False)
    _state.paused = True
    try:
        yield
    finally:
        _state.paused = prev


@contextlib.contextmanager
def repeat(n: int):
    """Mark a region whose traced dispatches execute ``n`` times.

    Wrap ``lax.scan``/``fori_loop`` calls whose body contains engine ops:
    the body is traced once but runs ``n`` times, so events inside get
    ``count`` multiplied by ``n``.  Nesting multiplies."""
    stack = getattr(_state, "repeat", None)
    if stack is None:
        stack = _state.repeat = []
    stack.append(int(n))
    try:
        yield
    finally:
        stack.pop()


@contextlib.contextmanager
def op_scope(label: str):
    """Tag every event traced in the context with ``label/`` op prefix.

    Serving (and any other subsystem) wraps its traces so the GEMM events
    it dispatches are attributable in a mixed stream: a decode step traced
    under ``op_scope("serve_decode")`` emits ``serve_decode/matmul``,
    ``serve_decode/grouped_matmul``, ... .  Prefixing preserves the op
    *suffix*, so :func:`is_backward_op` / :func:`is_pass_op` (and every
    fwd/bwd split built on them) classify scoped events unchanged.
    Nesting joins with "/" (outermost first)."""
    prev = getattr(_state, "op_scope", None)
    _state.op_scope = label if prev is None else f"{prev}/{label}"
    try:
        yield
    finally:
        _state.op_scope = prev


def _emit(spec: GemmSpec, backend: str,
          count: Optional[int] = None, recompute: bool = False) -> None:
    """Append one event to every active collector.

    ``count`` overrides the live :func:`repeat` multiplier — backward
    dispatches pass the multiplicity captured at *forward* trace time,
    because JAX traces the backward of a scanned body outside the
    ``repeat`` context that wrapped the scan."""
    stack = _collectors()
    if not stack or getattr(_state, "paused", False):
        return
    scope = getattr(_state, "op_scope", None)
    if scope is not None:
        spec = dataclasses.replace(spec, op=f"{scope}/{spec.op}")
    ev = GemmEvent(spec=spec, backend=backend,
                   count=_repeat_multiplier() if count is None else count,
                   recompute=recompute)
    for events in stack:
        events.append(ev)


# --------------------------------------------------------------------- #
# Built-in backends
# --------------------------------------------------------------------- #
def _xla_fn(xc: jax.Array, wc: jax.Array, *, spec: GemmSpec) -> jax.Array:
    """``lax.dot_general`` with the engine's accumulation policy.

    Honors ``spec.layout`` ("layouts" capability): the contraction axis of
    each operand moves with the storage, so transpose-layout backward
    dispatches lower to a single ``dot_general`` — XLA fuses the transposed
    access into the dot, no materialized transpose.  Honors per-operand
    storage dtypes ("operand_dtypes" capability): narrower (FP8) operands
    are widened right at the dot's input, a cast XLA fuses into the
    contraction — HBM-side the operand stays at storage width."""
    policy = spec.policy
    comp = jnp.dtype(policy.compute_dtype)
    if xc.dtype != comp:
        xc = xc.astype(comp)
    if wc.dtype != comp:
        wc = wc.astype(comp)
    # per-layout contraction axis, counted from the end of each operand
    x_coff = 2 if spec.layout == "tn" else 1   # x stored (N, M) under tn
    w_coff = 1 if spec.layout == "nt" else 2   # w stored (K, N) under nt
    if xc.ndim > 2 and wc.ndim == 2 and spec.layout != "tn":
        # weight GEMM: single dot over collapsed leading dims
        return jax.lax.dot_general(
            xc, wc,
            (((xc.ndim - 1,), (wc.ndim - w_coff,)), ((), ())),
            preferred_element_type=policy.accum_dtype,
        )
    x_batch = tuple(range(xc.ndim - 2)) if xc.ndim > 2 else ()
    w_batch = tuple(range(wc.ndim - 2)) if wc.ndim > 2 else ()
    if x_batch != w_batch or xc.shape[:-2] != wc.shape[:-2]:
        lead = np.broadcast_shapes(xc.shape[:-2], wc.shape[:-2])
        xc = jnp.broadcast_to(xc, (*lead, *xc.shape[-2:]))
        wc = jnp.broadcast_to(wc, (*lead, *wc.shape[-2:]))
        x_batch = w_batch = tuple(range(len(lead)))
    return jax.lax.dot_general(
        xc, wc,
        (((xc.ndim - x_coff,), (wc.ndim - w_coff,)), (x_batch, w_batch)),
        preferred_element_type=policy.accum_dtype,
    )


def _pallas_fn(xc: jax.Array, wc: jax.Array, *, spec: GemmSpec,
               interpret: bool = False, bias: Optional[jax.Array] = None,
               fuse_epilogue: bool = False,
               deriv: Optional[jax.Array] = None,
               bias_grad: bool = False):
    """The Pallas RedMulE kernel (X-stationary, W-streamed, store-once Z).

    With ``fuse_epilogue=True`` the bias row and ``spec.epilogue`` are
    folded into the kernel's store-once step (the "fused_epilogue"
    capability contract) — on the 2D *and* the batched-grid kernel.
    ``spec.layout`` selects the transpose-layout kernel entry points
    (the "layouts" capability): backward operands stay in their forward
    storage, the BlockSpec walk changes instead.  ``deriv``/``bias_grad``
    implement the "fused_bwd_epilogue" contract on the 2D kernel: act' is
    applied to the dZ tiles on load and — for ``bias_grad`` — the bias
    grad accumulates as a second kernel output (see
    :mod:`repro.kernels.redmule_matmul`)."""
    from repro.kernels import ops  # local import: kernels depend on core

    policy, tile, layout = spec.policy, spec.tile, spec.layout
    kw = dict(policy=policy, tile=tile, layout=layout, interpret=interpret,
              bias=bias if fuse_epilogue else None,
              epilogue=spec.epilogue if fuse_epilogue else None)
    fused_bwd = deriv is not None or bias_grad
    if fused_bwd:
        kw.update(deriv=deriv, grad_epilogue=spec.grad_epilogue,
                  grad_from_output=spec.grad_mode == "output",
                  bias_grad=bias_grad)
    if wc.ndim == 2 and (xc.ndim == 2 or layout != "tn"):
        # weight GEMM: collapse leading dims into rows (nn/nt store the
        # logical M in x's second-to-last dim, so the collapse is exact)
        lead = xc.shape[:-2]
        x2 = xc.reshape((-1, xc.shape[-1])) if lead else xc
        if deriv is not None and lead:
            kw["deriv"] = deriv.reshape((-1, deriv.shape[-1]))
        out = ops.redmule_matmul(x2, wc, **kw)
        z2, db = out if bias_grad else (out, None)
        m = xc.shape[-1] if layout == "tn" else xc.shape[-2]
        k = wc.shape[-2] if layout == "nt" else wc.shape[-1]
        z = z2.reshape((*lead, m, k))
        return (z, db) if bias_grad else z
    assert not fused_bwd, \
        "fused backward epilogues are a 2D-weight (w_shared) contract"
    lead = np.broadcast_shapes(xc.shape[:-2], wc.shape[:-2])
    xb = jnp.broadcast_to(xc, (*lead, *xc.shape[-2:])).reshape(
        (-1, *xc.shape[-2:]))
    wb = jnp.broadcast_to(wc, (*lead, *wc.shape[-2:])).reshape(
        (-1, *wc.shape[-2:]))
    z = ops.redmule_matmul_batched(xb, wb, **kw)
    m = xc.shape[-1] if layout == "tn" else xc.shape[-2]
    k = wc.shape[-2] if layout == "nt" else wc.shape[-1]
    return z.reshape((*lead, m, k))


def _interpret_fn(xc: jax.Array, wc: jax.Array, *, spec: GemmSpec,
                  bias: Optional[jax.Array] = None,
                  fuse_epilogue: bool = False,
                  deriv: Optional[jax.Array] = None,
                  bias_grad: bool = False):
    return _pallas_fn(xc, wc, spec=spec, interpret=True, bias=bias,
                      fuse_epilogue=fuse_epilogue, deriv=deriv,
                      bias_grad=bias_grad)


def _pallas_attention(kind: str, operands, *, interpret: bool = False,
                      **params):
    """The "attention" capability for the Pallas backends (see
    :class:`BackendSpec`): dispatch to the fused sweep kernels."""
    from repro.kernels import flash_attention, chunked_linear_attention

    if kind == "attention":
        q, k, v = operands
        return flash_attention.flash_attention_pallas(
            q, k, v, interpret=interpret, **params)
    if kind == "linear_attention":
        q, k, v, log_g = operands
        return chunked_linear_attention.chunked_linear_attention_pallas(
            q, k, v, log_g, interpret=interpret, **params)
    raise ValueError(f"unknown attention kind {kind!r}")


def _interpret_attention(kind: str, operands, **params):
    return _pallas_attention(kind, operands, interpret=True, **params)


register_backend(
    "xla", _xla_fn,
    capabilities=("layouts", "operand_dtypes"),
    description="lax.dot_general with the engine's precision policy "
                "(production fallback; XLA:CPU dry-runs; epilogues applied "
                "post-op by the engine; transpose layouts fold into the "
                "dot's dimension numbers; FP8 storage widens at the dot's "
                "input — the cast fuses into the contraction)")
register_backend(
    "pallas", _pallas_fn,
    available=lambda: jax.default_backend() == "tpu",
    capabilities=("fused_epilogue", "tiled", "layouts",
                  "fused_bwd_epilogue", "operand_dtypes", "attention"),
    attention_fn=_pallas_attention,
    description="TPU Pallas RedMulE kernel (double-buffered in-kernel "
                "K-loop, store-once Z with the bias+activation epilogue "
                "fused into the store; nt/tn entry points serve the "
                "backward pass without materialized transposes, with "
                "act' applied to dZ on load and the bias grad accumulated "
                "in the dW pass — ds never touches HBM; FP8 storage tiles "
                "DMA narrow and upcast on load inside the K-loop; fused "
                "flash / chunked-linear attention sweeps)")
register_backend(
    "interpret", _interpret_fn,
    capabilities=("fused_epilogue", "tiled", "layouts",
                  "fused_bwd_epilogue", "operand_dtypes", "attention"),
    attention_fn=_interpret_attention,
    description="the same Pallas kernel body in interpreter mode "
                "(CPU CI; bit-faithful to the kernel's schedule, fused "
                "forward and backward epilogues, transpose layouts, "
                "FP8 upcast-on-load and the attention sweeps included)")


# Fused epilogue registry — shared with the kernels (repro.core.epilogues)
# so the in-kernel and post-op paths can never drift apart.
_EPILOGUES: Dict[str, Callable[[jax.Array], jax.Array]] = epi.EPILOGUES


# --------------------------------------------------------------------- #
# Tile resolution (module-level so the VJP rules can resolve backward
# tiles without an Engine instance)
# --------------------------------------------------------------------- #
def _resolve_tile(
    tile: Optional[tiling.TileConfig],
    *,
    m: int,
    n: int,
    k: int,
    policy: prec.Policy,
    backend: str,
    epilogue: Optional[str] = None,
    layout: str = "nn",
    fused_bwd: bool = False,
    x_dtype: Optional[str] = None,
    w_dtype: Optional[str] = None,
) -> tiling.TileConfig:
    """Tile precedence: explicit arg > autotune cache > heuristic.

    ``fused_bwd`` keys fused-backward-epilogue dispatches separately: the
    streamed derivative operand changes the VMEM working set and the
    DMA-per-FLOP ratio, so their tuned tiles must not collide with plain
    transpose-layout GEMMs of the same shape.  ``x_dtype``/``w_dtype``
    (per-operand storage names) key — and size — mixed-precision
    dispatches: FP8 streams halve their VMEM tiles and DMA bytes."""
    if tile is not None:
        return tile
    t = autotune.cached_tile(m, n, k, policy=policy, backend=backend,
                             epilogue=epilogue, layout=layout,
                             fused_bwd=fused_bwd,
                             x_dtype=x_dtype, w_dtype=w_dtype)
    if t is not None:
        return t
    return tiling.choose_tiles(
        m, n, k, compute_dtype=policy.compute_dtype,
        accum_dtype=policy.accum_dtype, fused_bwd=fused_bwd,
        x_dtype=x_dtype, w_dtype=w_dtype)


# --------------------------------------------------------------------- #
# Per-operand storage: dispatch-dtype resolution and quantization
# --------------------------------------------------------------------- #
def _dispatch_storage(
    policy: prec.Policy, backend: str,
) -> Tuple[Optional[str], Optional[str], Optional[str]]:
    """``(x_store, w_store, grad_store)`` dtype names one dispatch to
    ``backend`` actually carries (None = the compute dtype).

    Mixed-storage policies hand narrow operands only to backends with the
    ``"operand_dtypes"`` capability (which upcast on load inside their
    kernels); other backends receive the quantized values widened to the
    compute dtype before dispatch — numerically identical, but an
    HBM-side cast pass billed at the wide width."""
    if not policy.mixed_storage:
        return None, None, None
    if not get_backend(backend).supports("operand_dtypes"):
        return None, None, None
    comp = jnp.dtype(policy.compute_dtype).name

    def nm(d):
        n = jnp.dtype(d).name
        return None if n == comp else n

    return (nm(policy.x_storage_dtype), nm(policy.w_storage_dtype),
            nm(policy.grad_storage_dtype))


def _prep_operand(v: jax.Array, storage_dtype, store_name: Optional[str],
                  policy: prec.Policy,
                  ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Cast (or per-tensor-quantize) one operand for dispatch.

    Returns ``(array, scale)``: FP8 storage quantizes ``q = v / s`` with
    ``s = amax`` (see :func:`repro.core.precision.quantize_fp8`)
    and returns the f32 scalar scale; everything else casts with
    ``scale=None``.  ``store_name`` is the dtype the dispatch carries
    (None -> compute): when the backend can't consume narrow storage the
    quantized values are widened back to the compute dtype — the
    quantization point (and therefore the numerics) is backend-invariant.
    """
    comp = jnp.dtype(policy.compute_dtype)
    sd = jnp.dtype(storage_dtype)
    if prec.is_fp8(sd):
        q, s = prec.quantize_fp8(v, sd)
        if store_name is None:
            q = q.astype(comp)
        return q, s
    q = v.astype(sd)
    if store_name is None and sd != comp:
        q = q.astype(comp)
    return q, None


def _scale_product(*scales: Optional[jax.Array]) -> Optional[jax.Array]:
    """Product of the non-None per-tensor scales (None when there are
    none — the uniform-precision fast path)."""
    out = None
    for s in scales:
        if s is not None:
            out = s if out is None else out * s
    return out


# --------------------------------------------------------------------- #
# Custom-VJP dispatch: forward AND backward GEMMs through the registry
# --------------------------------------------------------------------- #
@functools.lru_cache(maxsize=None)
def _grad_policy(policy: prec.Policy) -> prec.Policy:
    """The backward-dispatch policy: same datapath, output held in the
    accumulation dtype (the final cast to the primal operand dtype happens
    once, at the custom-VJP boundary)."""
    return dataclasses.replace(policy, name=policy.name + "+grad",
                               output_dtype=policy.accum_dtype)


@dataclasses.dataclass(frozen=True)
class _GradCtx:
    """Static context threaded through a custom-VJP op (hashable: rides as
    a ``nondiff_argnums`` argument).

    ``count`` is the :func:`repeat` multiplicity captured when the engine
    method traced the forward — backward emissions reuse it, because the
    backward of a scanned body is traced after the scan's ``repeat``
    context has exited."""

    spec: GemmSpec
    backend: str
    count: int
    x_dtype: str
    w_dtype: str
    b_dtype: Optional[str] = None
    fuse: bool = False          # linear: backend runs the fused-epilogue path
    fuse_bwd: bool = False      # linear: backend fuses act'/db into dX/dW
    store_g: Optional[str] = None  # grad (dZ) dispatch storage dtype name


def _make_ctx(spec: GemmSpec, backend: str, x, w, b=None,
              fuse: bool = False, fuse_bwd: bool = False) -> _GradCtx:
    _, _, store_g = _dispatch_storage(spec.policy, backend)
    return _GradCtx(
        spec=spec, backend=backend, count=_repeat_multiplier(),
        x_dtype=jnp.dtype(x.dtype).name, w_dtype=jnp.dtype(w.dtype).name,
        b_dtype=None if b is None else jnp.dtype(b.dtype).name,
        fuse=fuse, fuse_bwd=fuse_bwd, store_g=store_g)


def _fwd_trace_kind(ctx: _GradCtx) -> Optional[str]:
    """Classify one forward trace of an engine call (keyed on the call's
    :class:`_GradCtx` identity, which both the custom-VJP primal and its
    fwd rule share).

    JAX traces each engine call's forward exactly once in an ordinary
    program — the primal fun *or* the fwd rule, never both.  Under
    ``jax.checkpoint`` the region is re-traced to stage out the backward
    recompute, so the same ctx sees a **second** forward trace: that one
    is the recompute (it executes during the backward pass at run time)
    and its events are tagged ``recompute=True`` with the multiplicity
    captured at the primal trace.  Any *further* traces of the same ctx
    are partial-eval artifacts (e.g. a scanned remat body re-traced while
    splitting the scan) that never execute — their events are suppressed,
    so a remat train trace reports true flops/bytes.  (Known limitation:
    nested checkpoint regions recompute more than once at run time but are
    still reported once.)

    Returns "primal", "recompute", or None (suppress).  Bookkeeping lives
    per-thread and resets when the outermost :func:`instrument` collector
    is entered; with no active collector nothing is observed and nothing
    is tracked."""
    if not _collectors() or getattr(_state, "paused", False):
        return "primal"
    table = getattr(_state, "fwd_seen", None)
    if table is None:
        table = _state.fwd_seen = {}
    entry = table.get(id(ctx))
    if entry is None:
        table[id(ctx)] = [ctx, 1]   # hold ctx: no id reuse while tracked
        return "primal"
    entry[1] += 1
    return "recompute" if entry[1] == 2 else None


def _emit_fwd(ctx: _GradCtx, spec: Optional[GemmSpec] = None,
              extra_specs: Sequence[GemmSpec] = ()) -> None:
    """Emit one *forward* event for ``ctx``, with remat-recompute
    classification (see :func:`_fwd_trace_kind`).

    ``extra_specs`` ride along with the *same* classification and
    count — companion pass events (the scaled post-op ``*_postep``) must
    be deduplicated, multiplied and recompute-tagged exactly like the
    GEMM event they accompany, and ``_fwd_trace_kind`` is call-counted
    per ctx, so they cannot classify separately."""
    kind = _fwd_trace_kind(ctx)
    if kind == "primal":
        _emit(spec or ctx.spec, ctx.backend)
        for s in extra_specs:
            _emit(s, ctx.backend)
    elif kind == "recompute":
        _emit(spec or ctx.spec, ctx.backend, count=ctx.count,
              recompute=True)
        for s in extra_specs:
            _emit(s, ctx.backend, count=ctx.count, recompute=True)


def _dispatch(ctx: _GradCtx, xc: jax.Array, wc: jax.Array,
              spec: Optional[GemmSpec] = None,
              extra_specs: Sequence[GemmSpec] = ()) -> jax.Array:
    """Emit + run one forward pure-GEMM dispatch on compute-dtype operands;
    returns the backend-native result (xla: accum dtype; pallas: stored
    dtype)."""
    spec = spec or ctx.spec
    _emit_fwd(ctx, spec, extra_specs)
    return get_backend(ctx.backend).fn(xc, wc, spec=spec)


def _static_valid_rows(group_sizes, m: int) -> Optional[int]:
    """``sum(clip(group_sizes, 0, m))`` when concrete at trace time, else
    None (a traced ragged spec falls back to the dense count)."""
    if group_sizes is None:
        return None
    try:
        sizes = np.asarray(group_sizes)
    except Exception:
        return None
    return int(np.clip(sizes, 0, m).sum())


def _unbroadcast(g: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
    """Sum a gradient down to the (possibly broadcast) primal shape."""
    extra = g.ndim - len(shape)
    if extra:
        g = g.sum(axis=tuple(range(extra)))
    axes = tuple(i for i, (gs, ss) in enumerate(zip(g.shape, shape))
                 if ss == 1 and gs != 1)
    if axes:
        g = g.sum(axis=axes, keepdims=True)
    return g


def _grad_dispatch(spec: GemmSpec, backend: str, a: jax.Array, b: jax.Array,
                   count: int, *, deriv: Optional[jax.Array] = None,
                   want_db: bool = False,
                   ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """One backward GEMM through the registry; returns ``(grad, db)``.

    ``spec`` carries a transpose layout; backends without the "layouts"
    capability get pre-transposed operands and an equivalent "nn" spec
    (same logical m/n/k, same event accounting).  ``deriv``/``want_db``
    run the "fused_bwd_epilogue" contract (only ever passed to capable
    backends): act' applied to the dZ tiles on load, and — for
    ``want_db`` — the bias grad accumulated in the same pass (``db`` is
    None otherwise)."""
    if spec.layout != "nn" and not get_backend(backend).supports("layouts"):
        if spec.layout == "nt":
            b = jnp.swapaxes(b, -1, -2)
        else:
            a = jnp.swapaxes(a, -1, -2)
        spec = dataclasses.replace(spec, layout="nn")
    _emit(spec, backend, count=count)
    fn = get_backend(backend).fn
    if spec.fused_bwd or want_db:
        out = fn(a, b, spec=spec, deriv=deriv, bias_grad=want_db)
        db = None
        if want_db:
            out, db = out
        return out.astype(spec.policy.out_dtype), db
    out = fn(a, b, spec=spec)
    return out.astype(spec.policy.out_dtype), None  # grad policy: accum


def _bwd_gemms(ctx: _GradCtx, xc: jax.Array, wc: jax.Array,
               dzc: jax.Array, *, deriv: Optional[jax.Array] = None,
               grad_mode: Optional[str] = None, want_db: bool = False,
               ) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """dX = dZ·Wᵀ ("nt") and dW = Xᵀ·dZ ("tn"), both Engine dispatches;
    returns ``(dx, dw, db)``.

    ``dzc`` is the cotangent in the compute dtype — the *pre-activation*
    cotangent on the two-pass path, the raw output cotangent on the fused
    path (``deriv`` set: the backend kernels apply ``act'(deriv)`` to the
    dZ tiles on load, so ds is never materialized).  ``want_db`` makes the
    dW dispatch accumulate the accum-dtype bias grad in the same pass.
    The returned grads are in the *accum* dtype (the caller casts to the
    primal dtypes)."""
    spec = ctx.spec
    gpol = _grad_policy(spec.policy)
    bk = ctx.backend

    if spec.valid_rows == 0:
        # degenerate ragged backward (every group empty): the masked
        # cotangent is identically zero, so skip the backend dispatches
        # (and their events) entirely — the forward's mirror short-circuit
        dx = jnp.zeros(xc.shape, gpol.out_dtype)
        dw = jnp.zeros(wc.shape, gpol.out_dtype)
        return dx, dw, None

    act = spec.epilogue if deriv is not None else None

    # backward per-slot storage: dZ rides in the grad storage (the x slot
    # on dX "nt", the w slot on dW "tn"); the saved residuals keep the
    # forward dispatch's storage (spec.x_dtype / spec.w_dtype)
    g_store = ctx.store_g

    if wc.ndim == 2:
        # weight GEMM — dW collapses all leading dims into one fat
        # contraction (the X-stationary schedule reads X in its forward
        # storage: no materialized transpose)
        dx_spec = GemmSpec(
            op="matmul_dx", tag="mk,nk->mn", layout="nt",
            m=spec.m, n=spec.k, k=spec.n, batch=spec.batch,
            policy=gpol, w_shared=True,
            valid_rows=spec.valid_rows, ragged_dim="m",
            grad_epilogue=act, grad_mode=grad_mode,
            fused_bwd=deriv is not None,
            x_dtype=g_store, w_dtype=spec.w_dtype, scaled=spec.scaled,
            tile=_resolve_tile(None, m=spec.m, n=spec.k, k=spec.n,
                               policy=gpol, backend=bk, layout="nt",
                               fused_bwd=deriv is not None,
                               x_dtype=g_store, w_dtype=spec.w_dtype),
        )
        dx, _ = _grad_dispatch(dx_spec, bk, dzc, wc, ctx.count, deriv=deriv)

        x2 = xc.reshape((-1, xc.shape[-1]))
        dz2 = dzc.reshape((-1, dzc.shape[-1]))
        d2 = None if deriv is None else deriv.reshape((-1, deriv.shape[-1]))
        rows = x2.shape[0]                      # batch * M
        dw_spec = GemmSpec(
            op="matmul_dw", tag="mn,mk->nk", layout="tn",
            m=spec.n, n=rows, k=spec.k, batch=1,
            policy=gpol, w_shared=False,
            grad_epilogue=act, grad_mode=grad_mode,
            fused_bwd=deriv is not None, fused_bias_grad=want_db,
            x_dtype=spec.x_dtype, w_dtype=g_store, scaled=spec.scaled,
            tile=_resolve_tile(None, m=spec.n, n=rows, k=spec.k,
                               policy=gpol, backend=bk, layout="tn",
                               fused_bwd=deriv is not None or want_db,
                               x_dtype=spec.x_dtype, w_dtype=g_store),
        )
        dw, db = _grad_dispatch(dw_spec, bk, x2, dz2, ctx.count,
                                deriv=d2, want_db=want_db)
        return dx, dw, db

    # batched / grouped GEMM: both grads stay batched; broadcast leading
    # dims are summed back down to the primal shapes afterwards.  (The
    # fused backward epilogue is a 2D-weight contract — callers fall back
    # to the two-pass path here.)
    assert deriv is None and not want_db
    dx_spec = GemmSpec(
        op="matmul_dx", tag="bmk,bnk->bmn", layout="nt",
        m=spec.m, n=spec.k, k=spec.n, batch=spec.batch, groups=spec.groups,
        policy=gpol, w_shared=spec.w_shared,
        valid_rows=spec.valid_rows, ragged_dim="m",
        x_dtype=g_store, w_dtype=spec.w_dtype, scaled=spec.scaled,
        tile=_resolve_tile(None, m=spec.m, n=spec.k, k=spec.n,
                           policy=gpol, backend=bk, layout="nt",
                           x_dtype=g_store, w_dtype=spec.w_dtype),
    )
    dx, _ = _grad_dispatch(dx_spec, bk, dzc, wc, ctx.count)
    dx = _unbroadcast(dx, xc.shape)

    dw_spec = GemmSpec(
        op="matmul_dw", tag="bmn,bmk->bnk", layout="tn",
        m=spec.n, n=spec.m, k=spec.k, batch=spec.batch, groups=spec.groups,
        policy=gpol, w_shared=False,
        valid_rows=spec.valid_rows,
        ragged_dim="n" if spec.valid_rows is not None else "m",
        x_dtype=spec.x_dtype, w_dtype=g_store, scaled=spec.scaled,
        tile=_resolve_tile(None, m=spec.n, n=spec.m, k=spec.k,
                           policy=gpol, backend=bk, layout="tn",
                           x_dtype=spec.x_dtype, w_dtype=g_store),
    )
    dw, _ = _grad_dispatch(dw_spec, bk, xc, dzc, ctx.count)
    dw = _unbroadcast(dw, wc.shape)
    return dx, dw, None


def _prep_xw(ctx: _GradCtx, x: jax.Array, w: jax.Array):
    """Cast/quantize both GEMM operands per the spec's per-operand storage;
    returns ``(xd, wd, sx, sw)`` (scales None on uniform policies)."""
    pol = ctx.spec.policy
    xd, sx = _prep_operand(x, pol.x_storage_dtype, ctx.spec.x_dtype, pol)
    wd, sw = _prep_operand(w, pol.w_storage_dtype, ctx.spec.w_dtype, pol)
    return xd, wd, sx, sw


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _gemm_call(ctx: _GradCtx, x: jax.Array, w: jax.Array) -> jax.Array:
    """Pure-GEMM op with a custom VJP (matmul / grouped_matmul / einsum2d
    inner dispatch / epilogue-free linear)."""
    pol = ctx.spec.policy
    xd, wd, sx, sw = _prep_xw(ctx, x, w)
    z = _dispatch(ctx, xd, wd)
    sp = _scale_product(sx, sw)
    if sp is not None:
        z = z.astype(pol.accum_dtype) * sp
    return z.astype(pol.out_dtype)


def _gemm_fwd(ctx: _GradCtx, x: jax.Array, w: jax.Array):
    pol = ctx.spec.policy
    xd, wd, sx, sw = _prep_xw(ctx, x, w)
    z = _dispatch(ctx, xd, wd)
    sp = _scale_product(sx, sw)
    if sp is not None:
        z = z.astype(pol.accum_dtype) * sp
    # residuals stay in the *dispatch* storage (FP8 on scaled policies —
    # the backward GEMMs re-read them narrow), scales ride alongside
    return z.astype(pol.out_dtype), (xd, wd, sx, sw)


def _quantized_bwd(ctx: _GradCtx, xd: jax.Array, wd: jax.Array,
                   sx: Optional[jax.Array], sw: Optional[jax.Array],
                   dz_wide: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Shared two-pass backward tail: quantize/cast the cotangent to the
    grad storage, run both backward GEMMs, undo the per-tensor scales.

    The scale algebra lives in exactly one place: dX = dZ·Wᵀ undoes the
    dZ and W scales, dW = Xᵀ·dZ undoes the X and dZ scales.  Returns
    ``(dx, dw)`` in the accum dtype (scale products are None — and the
    multiplies skipped — on uniform policies)."""
    pol = ctx.spec.policy
    dzd, sdz = _prep_operand(dz_wide, pol.grad_storage_dtype, ctx.store_g,
                             pol)
    dx, dw, _ = _bwd_gemms(ctx, xd, wd, dzd)
    spx = _scale_product(sdz, sw)
    spw = _scale_product(sx, sdz)
    if spx is not None:
        dx = dx * spx
    if spw is not None:
        dw = dw * spw
    return dx, dw


def _gemm_bwd(ctx: _GradCtx, res, dz: jax.Array):
    xd, wd, sx, sw = res
    dx, dw = _quantized_bwd(ctx, xd, wd, sx, sw, dz)
    return dx.astype(ctx.x_dtype), dw.astype(ctx.w_dtype)


_gemm_call.defvjp(_gemm_fwd, _gemm_bwd)


def _linear_primal_prepped(ctx: _GradCtx, xd: jax.Array, wd: jax.Array,
                           sp: Optional[jax.Array],
                           b: Optional[jax.Array]) -> jax.Array:
    """Inference-path linear on already-prepped operands: fused epilogue
    on capable backends, post-op otherwise (exactly the PR-2 contract).
    ``sp`` is the per-tensor scale product to undo (None on uniform
    policies); scaled dispatches always run post-op — the scale must be
    multiplied back into the accumulator *before* the bias/activation, so
    :meth:`Engine.linear` never sets ``fuse`` for them."""
    spec, bk = ctx.spec, ctx.backend
    pol = spec.policy
    has_epilogue = b is not None or spec.epilogue is not None
    if has_epilogue and ctx.fuse:
        bc = None if b is None else b.astype(pol.accum_dtype)
        _emit_fwd(ctx)
        z = get_backend(bk).fn(xd, wd, spec=spec, bias=bc,
                               fuse_epilogue=True)
        return z.astype(pol.out_dtype)
    # scaled specs *force* the post-op pass on every backend (the
    # scale-undo must precede the bias/activation), so the engine bills
    # its HBM round-trip as a companion pass event — unlike the
    # uniform-policy post-op fallback, which is a backend choice and
    # keeps the PR-2 unbilled convention.  It rides through _dispatch so
    # remat recompute traces classify it exactly like the GEMM event.
    extra = ((dataclasses.replace(spec, op=spec.op + "_postep", tile=None),)
             if has_epilogue and spec.scaled else ())
    z = _dispatch(ctx, xd, wd, extra_specs=extra)
    if sp is not None:
        z = z.astype(pol.accum_dtype) * sp
    if has_epilogue:
        za = z.astype(pol.accum_dtype)
        if b is not None:
            za = za + b.astype(pol.accum_dtype)
        za = epi.apply_epilogue(spec.epilogue, za)
        z = za
    return z.astype(pol.out_dtype)


def _linear_primal(ctx: _GradCtx, x: jax.Array, w: jax.Array,
                   b: Optional[jax.Array]) -> jax.Array:
    xd, wd, sx, sw = _prep_xw(ctx, x, w)
    return _linear_primal_prepped(ctx, xd, wd, _scale_product(sx, sw), b)


def _linear_fwd_core(ctx: _GradCtx, x: jax.Array, w: jax.Array,
                     b: Optional[jax.Array]):
    """Forward-for-grad: decide what to save for the epilogue derivative.

    * no activation — fused/post-op forward unchanged; residual aux=None;
    * activation with an output-form derivative (relu/tanh) — fully fused
      forward unchanged; save the output z;
    * otherwise (gelu/silu) — dispatch with the bias fused but the
      activation post-op, save the pre-activation s (compute dtype).  The
      value differs from the fused inference path by the documented ~2 ulp
      fused-vs-post-op bound.

    Residuals are saved in the *dispatch* storage (FP8 on scaled
    policies, compute dtype otherwise) with the per-tensor scales
    alongside; the epilogue aux (fused output or pre-activation) always
    rides in the out/compute dtype."""
    spec, bk = ctx.spec, ctx.backend
    pol = spec.policy
    act = spec.epilogue
    xd, wd, sx, sw = _prep_xw(ctx, x, w)
    sp = _scale_product(sx, sw)
    if act is None:
        z = _linear_primal_prepped(ctx, xd, wd, sp, b)
        return z, (xd, wd, None, sx, sw)
    grad = epi.epilogue_grad(act)
    if grad.deriv_from_output is not None:
        z = _linear_primal_prepped(ctx, xd, wd, sp, b)
        return z, (xd, wd, z, sx, sw)
    # pre-activation needed: bias-fused (or post-op) GEMM, activation after
    if ctx.fuse:
        bc = None if b is None else b.astype(pol.accum_dtype)
        _emit_fwd(ctx)
        s = get_backend(bk).fn(
            xd, wd, spec=dataclasses.replace(spec, epilogue=None),
            bias=bc, fuse_epilogue=True)
        sa = s.astype(pol.accum_dtype)
    else:
        # the policy-forced post-op pass bills like in
        # _linear_primal_prepped, classified with its GEMM event
        extra = ((dataclasses.replace(spec, op=spec.op + "_postep",
                                      tile=None),)
                 if spec.scaled else ())
        s = _dispatch(ctx, xd, wd, extra_specs=extra)
        sa = s.astype(pol.accum_dtype)
        if sp is not None:
            sa = sa * sp
        if b is not None:
            sa = sa + b.astype(pol.accum_dtype)
    z = epi.apply_epilogue(act, sa).astype(pol.out_dtype)
    return z, (xd, wd, sa.astype(pol.compute_dtype), sx, sw)


def _linear_bwd_core(ctx: _GradCtx, res, dz: jax.Array):
    """Shared linear backward: activation derivative, bias-grad reduction,
    then the two backward GEMMs.

    On backends with the ``"fused_bwd_epilogue"`` capability (2D weights)
    this is **one pass**: the raw output cotangent goes straight into the
    backward GEMMs, which apply ``act'`` to the dZ tiles on load from the
    saved residual and accumulate the bias grad inside the dW kernel — the
    pre-activation cotangent ``ds`` is never materialized in HBM.  Other
    backends (and batched weights) run the two-pass fallback: a standalone
    ``ds = dZ ⊙ act'`` multiply (billed as a ``*_dact`` pass event) and a
    separate accum-dtype bias-grad reduction (a ``*_dbias`` event).

    **Scaled (FP8) policies always take the two-pass path** — the engine
    quantizes the *post-derivative* cotangent ``ds`` to the grad storage
    once, in one place, so the quantization point (and the grads) are
    identical on every backend; the bias grad reduces from the wide
    ``ds`` before quantization, so it carries no FP8 error."""
    xd, wd, aux, sx, sw = res
    spec = ctx.spec
    pol = spec.policy
    act = spec.epilogue

    if ctx.fuse_bwd and (act is not None or ctx.b_dtype is not None):
        deriv = grad_mode = None
        if act is not None:
            grad = epi.epilogue_grad(act)
            grad_mode = ("output" if grad.deriv_from_output is not None
                         else "preact")
            deriv = aux.astype(pol.compute_dtype)
        want_db = ctx.b_dtype is not None
        dx, dw, db = _bwd_gemms(
            ctx, xd, wd, dz.astype(pol.compute_dtype),
            deriv=deriv, grad_mode=grad_mode, want_db=want_db)
        if db is not None:
            db = db.astype(ctx.b_dtype)
        return dx.astype(ctx.x_dtype), dw.astype(ctx.w_dtype), db

    dza = dz.astype(pol.accum_dtype)
    if act is not None:
        grad = epi.epilogue_grad(act)
        if grad.deriv_from_output is not None:
            dza = dza * grad.deriv_from_output(aux.astype(pol.accum_dtype))
        else:
            dza = dza * grad.deriv(aux.astype(pol.accum_dtype))
        # the standalone multiply materializes ds: bill its HBM round-trip
        _emit(dataclasses.replace(spec, op=spec.op + "_dact", tile=None),
              ctx.backend, count=ctx.count)
    db = None
    if ctx.b_dtype is not None:
        # bias grad: accum-dtype reduction over every row of the cotangent
        db = dza.sum(axis=tuple(range(dza.ndim - 1))).astype(ctx.b_dtype)
        _emit(dataclasses.replace(spec, op=spec.op + "_dbias", tile=None),
              ctx.backend, count=ctx.count)
    dx, dw = _quantized_bwd(ctx, xd, wd, sx, sw, dza)
    return dx.astype(ctx.x_dtype), dw.astype(ctx.w_dtype), db


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _linear_call(ctx: _GradCtx, x: jax.Array, w: jax.Array,
                 b: jax.Array) -> jax.Array:
    return _linear_primal(ctx, x, w, b)


def _linear_call_fwd(ctx, x, w, b):
    return _linear_fwd_core(ctx, x, w, b)


def _linear_call_bwd(ctx, res, dz):
    dx, dw, db = _linear_bwd_core(ctx, res, dz)
    return dx, dw, db


_linear_call.defvjp(_linear_call_fwd, _linear_call_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _linear_call_nobias(ctx: _GradCtx, x: jax.Array,
                        w: jax.Array) -> jax.Array:
    return _linear_primal(ctx, x, w, None)


def _linear_nobias_fwd(ctx, x, w):
    return _linear_fwd_core(ctx, x, w, None)


def _linear_nobias_bwd(ctx, res, dz):
    dx, dw, _ = _linear_bwd_core(ctx, res, dz)
    return dx, dw


_linear_call_nobias.defvjp(_linear_nobias_fwd, _linear_nobias_bwd)


# --------------------------------------------------------------------- #
# Attention ops ("attention" capability)
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class _AttnCtx:
    """Static context of one attention dispatch (the custom-VJP
    nondiff argument).  Duck-types :class:`_GradCtx` for
    :func:`_emit_fwd` — ``spec`` / ``backend`` / ``count`` carry the
    same meaning; ``extra`` holds the sweep's companion GEMM specs
    (PV, inter, state-update), emitted with identical classification."""

    kind: str
    spec: GemmSpec
    backend: str
    count: int
    extra: Tuple[GemmSpec, ...] = ()
    group: int = 1
    causal: bool = True
    scale: float = 1.0
    q_offset: int = 0
    t_valid: int = 0
    bq: int = 256
    bkv: int = 512
    chunk: int = 64
    policy: prec.Policy = prec.FP32


def _attention_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         group: int, causal: bool, scale: float,
                         q_offset: int, t_valid: int,
                         policy: prec.Policy, backend: str) -> jax.Array:
    """Reference attention as a composition of :func:`einsum2d` calls.

    Serves backends without the ``"attention"`` capability (XLA) and the
    ``custom_vjp`` backward of the kernel path: both score and PV GEMMs
    re-enter the registry and self-bill, so jaxpr audits reconcile with
    no attention-specific rules.  Numerics match the flash kernel's
    contract: fp32 scores/softmax, fully-masked query rows return exact
    zeros (the kernel's ``l == 0`` guard)."""
    B, Hq, S, D = q.shape
    _, Hkv, T, Dv = v.shape
    qg = q.reshape(B, Hkv, group, S, D)
    scores_pol = dataclasses.replace(
        policy, name=policy.name + "_scores",
        output_dtype=jnp.float32, faithful_accum=False)
    s = DEFAULT_ENGINE.einsum2d("bhgsd,bhtd->bhgst", qg, k,
                                policy=scores_pol, backend=backend)
    s = s * jnp.float32(scale)
    rows = q_offset + jnp.arange(S, dtype=jnp.int32)
    cols = jnp.arange(T, dtype=jnp.int32)
    mask = cols[None, :] < t_valid
    if causal:
        mask = mask & (cols[None, :] <= rows[:, None])
    else:
        mask = jnp.broadcast_to(mask, (S, T))
    s = jnp.where(mask, s, jnp.float32(-1e30))
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(axis=-1)[:, None], p, jnp.float32(0.0))
    out = DEFAULT_ENGINE.einsum2d(
        "bhgst,bhtd->bhgsd", p.astype(policy.compute_dtype), v,
        policy=policy, backend=backend)
    return out.reshape(B, Hq, S, Dv).astype(policy.out_dtype)


def _linear_attention_reference(q: jax.Array, k: jax.Array, v: jax.Array,
                                log_g: jax.Array, *, chunk: int,
                                state: Optional[jax.Array],
                                backend: str) -> Tuple[jax.Array, jax.Array]:
    """Reference chunked linear-attention state sweep (mLSTM/SSD form)
    over ``(B, H, S, d)`` operands, composed of registry dispatches.

    The per-chunk recurrence matches the Pallas kernel exactly: an fp32
    intra-chunk score GEMM with the decay matrix ``A``, an intra-chunk
    PV GEMM, the inter-chunk ``q·exp(L) @ state`` read, and the decayed
    ``k^T·v`` state update.  Returns ``(out fp32, state fp32)``."""
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    f32 = prec.FP32
    pad = (-S) % chunk
    if pad:
        zq = [(0, 0), (0, 0), (0, pad), (0, 0)]
        q = jnp.pad(q, zq)
        k = jnp.pad(k, zq)
        v = jnp.pad(v, [(0, 0), (0, 0), (0, pad), (0, 0)])
        log_g = jnp.pad(log_g, [(0, 0), (0, 0), (0, pad)])
    Sp = S + pad
    n = Sp // chunk
    qf = q.astype(jnp.float32).reshape(B, H, n, chunk, dk)
    kf = k.astype(jnp.float32).reshape(B, H, n, chunk, dk)
    vf = v.astype(jnp.float32).reshape(B, H, n, chunk, dv)
    gf = log_g.astype(jnp.float32).reshape(B, H, n, chunk)
    S0 = (jnp.zeros((B, H, dk, dv), jnp.float32) if state is None
          else state.astype(jnp.float32))
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(S_prev, xs):
        qc, kc, vc, gc = xs
        L = jnp.cumsum(gc, axis=-1)                    # (B, H, chunk)
        Ltot = L[..., -1:]
        Dm = L[..., :, None] - L[..., None, :]
        A = jnp.where(causal[None, None], jnp.exp(Dm), 0.0)
        s = DEFAULT_ENGINE.einsum2d("bhik,bhjk->bhij", qc, kc,
                                    policy=f32, backend=backend) * A
        out = DEFAULT_ENGINE.matmul(s, vc, policy=f32, backend=backend)
        out = out + DEFAULT_ENGINE.matmul(
            qc * jnp.exp(L)[..., None], S_prev, policy=f32, backend=backend)
        kdec = kc * jnp.exp(Ltot - L)[..., None]
        S_new = jnp.exp(Ltot)[..., None] * S_prev + DEFAULT_ENGINE.matmul(
            jnp.swapaxes(kdec, -1, -2), vc, policy=f32, backend=backend)
        return S_new, out

    with repeat(n):
        S_fin, outs = jax.lax.scan(
            step, S0, (jnp.moveaxis(qf, 2, 0), jnp.moveaxis(kf, 2, 0),
                       jnp.moveaxis(vf, 2, 0), jnp.moveaxis(gf, 2, 0)))
    out = jnp.moveaxis(outs, 0, 2).reshape(B, H, Sp, dv)[:, :, :S]
    return out, S_fin


def _attention_specs(*, B: int, Hq: int, S: int, T: int, D: int, Dv: int,
                     bq: int, bkv: int, causal: bool, q_offset: int,
                     policy: prec.Policy) -> Tuple[GemmSpec, ...]:
    """Per-sweep event specs for one flash-attention dispatch.

    ``groups`` is the number of **executed** (Q-block, KV-block) pairs —
    causally skipped blocks are excluded, so billed flops are exact.
    ``io_bytes`` carries the sweep's true HBM traffic: Q is read once
    per Q row, K/V stream once per executed pair, the output stores
    once (the kernel's store-once Z contract)."""
    pairs = autotune._attn_pairs(S, T, bq, bkv, causal=causal,
                                 q_offset=q_offset)
    S_pad = -(-S // bq) * bq
    BHq = B * Hq
    cb = jnp.dtype(policy.compute_dtype).itemsize
    ob = jnp.dtype(policy.out_dtype).itemsize
    tile = tiling.TileConfig(bm=bq, bn=bkv, bk=bkv)
    score = GemmSpec(
        op="attention_score", tag="bsd,btd->bst", m=bq, n=D, k=bkv,
        batch=BHq, groups=pairs, policy=policy, tile=tile,
        io_bytes=BHq * (S_pad * D + pairs * bkv * D) * cb)
    pv = GemmSpec(
        op="attention_pv", tag="bst,btd->bsd", m=bq, n=bkv, k=Dv,
        batch=BHq, groups=pairs, policy=policy, tile=tile,
        io_bytes=BHq * (pairs * bkv * Dv * cb + S_pad * Dv * ob))
    return (score, pv)


def _linear_attention_specs(*, B: int, H: int, S: int, dk: int, dv: int,
                            chunk: int, in_bytes: int) -> Tuple[GemmSpec, ...]:
    """Event specs for one chunked linear-attention sweep: the four
    per-chunk GEMMs (intra-chunk score, intra-chunk PV, inter-chunk
    state read, state update) billed separately, ``groups`` = number of
    chunks.  The running state lives in VMEM across the whole sweep and
    stores once (fp32), exactly like the kernel."""
    S_pad = -(-S // chunk) * chunk
    n = S_pad // chunk
    BH = B * H
    f32 = prec.FP32
    tile = tiling.TileConfig(bm=chunk, bn=chunk, bk=chunk)
    score = GemmSpec(
        op="linear_attention_score", tag="bik,bjk->bij",
        m=chunk, n=dk, k=chunk, batch=BH, groups=n, policy=f32, tile=tile,
        io_bytes=BH * S_pad * (2 * dk * in_bytes + 4))
    pv = GemmSpec(
        op="linear_attention_pv", tag="bij,bjv->biv",
        m=chunk, n=chunk, k=dv, batch=BH, groups=n, policy=f32, tile=tile,
        io_bytes=BH * S_pad * dv * in_bytes)
    inter = GemmSpec(
        op="linear_attention_inter", tag="bik,bkv->biv",
        m=chunk, n=dk, k=dv, batch=BH, groups=n, policy=f32, tile=tile,
        io_bytes=BH * S_pad * dv * in_bytes)
    state = GemmSpec(
        op="linear_attention_state", tag="bki,bkv->biv",
        m=dk, n=chunk, k=dv, batch=BH, groups=n, policy=f32, tile=tile,
        io_bytes=BH * dk * dv * 4)
    return (score, pv, inter, state)


def _attention_kernel_dispatch(actx: _AttnCtx, q: jax.Array, k: jax.Array,
                               v: jax.Array) -> jax.Array:
    """Pad, flatten and hand the operands to the backend's flash kernel,
    emitting the sweep's events with remat classification."""
    pol = actx.policy
    comp = pol.compute_dtype
    B, Hq, S, D = q.shape
    _, Hkv, T, _ = k.shape
    S_pad = -(-S // actx.bq) * actx.bq
    T_pad = -(-T // actx.bkv) * actx.bkv
    qc = q.astype(comp)
    kc = k.astype(comp)
    vc = v.astype(comp)
    if S_pad != S:
        qc = jnp.pad(qc, [(0, 0), (0, 0), (0, S_pad - S), (0, 0)])
    if T_pad != T:
        zt = [(0, 0), (0, 0), (0, T_pad - T), (0, 0)]
        kc = jnp.pad(kc, zt)
        vc = jnp.pad(vc, zt)
    _emit_fwd(actx, actx.spec, actx.extra)
    fn = get_backend(actx.backend).attention_fn
    out = fn("attention",
             (qc.reshape(B * Hq, S_pad, D),
              kc.reshape(B * Hkv, T_pad, D),
              vc.reshape(B * Hkv, T_pad, D)),
             group=actx.group, causal=actx.causal, scale=actx.scale,
             bq=actx.bq, bkv=actx.bkv, t_valid=actx.t_valid,
             q_offset=actx.q_offset)
    out = out.reshape(B, Hq, S_pad, D)[:, :, :S]
    return out.astype(pol.out_dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _attention_call(actx: _AttnCtx, q: jax.Array, k: jax.Array,
                    v: jax.Array) -> jax.Array:
    return _attention_kernel_dispatch(actx, q, k, v)


def _attention_call_fwd(actx, q, k, v):
    return _attention_kernel_dispatch(actx, q, k, v), (q, k, v)


def _attention_call_bwd(actx, res, do):
    # Flash-style backward schedule: recompute the forward as the
    # reference einsum2d composition and differentiate through it — the
    # recompute and all four backward GEMMs re-enter the registry on the
    # same backend, each self-billing its events.
    q, k, v = res

    def ref(q_, k_, v_):
        return _attention_reference(
            q_, k_, v_, group=actx.group, causal=actx.causal,
            scale=actx.scale, q_offset=actx.q_offset, t_valid=actx.t_valid,
            policy=actx.policy, backend=actx.backend)

    with repeat(actx.count):
        _, vjp = jax.vjp(ref, q, k, v)
        dq, dk, dv = vjp(do)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_attention_call.defvjp(_attention_call_fwd, _attention_call_bwd)


def _linear_attention_kernel_dispatch(
        actx: _AttnCtx, q: jax.Array, k: jax.Array, v: jax.Array,
        log_g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    chunk = actx.chunk
    pad = (-S) % chunk
    if pad:
        zs = [(0, 0), (0, 0), (0, pad), (0, 0)]
        q = jnp.pad(q, zs)
        k = jnp.pad(k, zs)
        v = jnp.pad(v, [(0, 0), (0, 0), (0, pad), (0, 0)])
        log_g = jnp.pad(log_g, [(0, 0), (0, 0), (0, pad)])
    Sp = S + pad
    _emit_fwd(actx, actx.spec, actx.extra)
    fn = get_backend(actx.backend).attention_fn
    out, st = fn("linear_attention",
                 (q.reshape(B * H, Sp, dk), k.reshape(B * H, Sp, dk),
                  v.reshape(B * H, Sp, dv),
                  log_g.astype(jnp.float32).reshape(B * H, Sp)),
                 chunk=chunk)
    out = out.reshape(B, H, Sp, dv)[:, :, :S].astype(jnp.float32)
    return out, st.reshape(B, H, dk, dv)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _linear_attention_call(actx: _AttnCtx, q, k, v, log_g):
    return _linear_attention_kernel_dispatch(actx, q, k, v, log_g)


def _linear_attention_call_fwd(actx, q, k, v, log_g):
    out = _linear_attention_kernel_dispatch(actx, q, k, v, log_g)
    return out, (q, k, v, log_g)


def _linear_attention_call_bwd(actx, res, cts):
    q, k, v, log_g = res

    def ref(q_, k_, v_, g_):
        return _linear_attention_reference(
            q_, k_, v_, g_, chunk=actx.chunk, state=None,
            backend=actx.backend)

    with repeat(actx.count):
        _, vjp = jax.vjp(ref, q, k, v, log_g)
        grads = vjp(cts)
    return tuple(g.astype(p.dtype) for g, p in zip(grads, (q, k, v, log_g)))


_linear_attention_call.defvjp(_linear_attention_call_fwd,
                              _linear_attention_call_bwd)


# --------------------------------------------------------------------- #
# The Engine
# --------------------------------------------------------------------- #
class Engine:
    """Resolves :class:`GemmSpec`s to backends and dispatches them.

    The default instance (:data:`DEFAULT_ENGINE`, aliased by the
    module-level :func:`matmul` / :func:`linear` / :func:`grouped_matmul` /
    :func:`einsum2d`) carries no overrides; custom instances can pin a
    backend and/or precision policy for a subsystem::

        fp16_engine = Engine(policy=prec.PAPER_FP16)
        z = fp16_engine.matmul(x, w)
    """

    def __init__(self, *, backend: Optional[str] = None, policy=None):
        self._backend = backend
        self._policy = policy

    # -- resolution ---------------------------------------------------- #
    def resolve_backend(self, backend: Optional[str] = None) -> str:
        b = backend or self._backend or default_backend()
        spec = get_backend(b)
        # an explicit per-call argument or a constructor-pinned backend is
        # a deliberate choice — only implicitly resolved backends (context /
        # env / platform default) are availability-gated
        if backend is None and self._backend is None \
                and not spec.is_available():
            raise ValueError(
                f"default backend {b!r} is not available on this platform "
                f"(registered: {registered_backends()}); pass backend= "
                f"explicitly to override")
        return b

    def resolve_policy(self, policy=None) -> prec.Policy:
        return prec.resolve(policy if policy is not None else self._policy)

    def resolve_tile(
        self,
        tile: Optional[tiling.TileConfig],
        *,
        m: int,
        n: int,
        k: int,
        policy: prec.Policy,
        backend: str,
        epilogue: Optional[str] = None,
        layout: str = "nn",
        x_dtype: Optional[str] = None,
        w_dtype: Optional[str] = None,
    ) -> tiling.TileConfig:
        """Tile precedence: explicit arg > autotune cache > heuristic.

        Runs for every dispatch (so the emitted :class:`GemmEvent` always
        carries the tile the kernel would use); both fallbacks are cheap —
        the autotune lookup is a dict hit and ``choose_tiles`` is memoized.
        Backward dispatches resolve their own tiles with ``layout`` "nt" /
        "tn" and the transposed problem shape in the key; mixed-precision
        dispatches key (and size) their per-operand storage dtypes."""
        return _resolve_tile(tile, m=m, n=n, k=k, policy=policy,
                             backend=backend, epilogue=epilogue,
                             layout=layout, x_dtype=x_dtype,
                             w_dtype=w_dtype)

    # -- op family ----------------------------------------------------- #
    def matmul(
        self,
        x: jax.Array,
        w: jax.Array,
        *,
        policy=None,
        tile: Optional[tiling.TileConfig] = None,
        backend: Optional[str] = None,
    ) -> jax.Array:
        """Z = X @ W with the RedMulE dataflow.

        Shapes: ``x: (..., M, N)``, ``w: (N, K)`` (weight GEMM) or
        ``w: (..., N, K)`` with broadcast-compatible leading dims (batched
        GEMM, e.g. attention).  Output: ``(..., M, K)`` in the policy's
        output dtype.

        Differentiable end to end: ``jax.grad`` dispatches dX = dZ·Wᵀ and
        dW = Xᵀ·dZ through the backend registry as transpose-layout specs
        tagged ``matmul_dx`` / ``matmul_dw`` (see the module docstring's
        backward contract)."""
        policy = self.resolve_policy(policy)
        b = self.resolve_backend(backend)
        if x.ndim < 2 or w.ndim < 2:
            raise ValueError(f"matmul needs >=2D operands, got {x.shape} @ {w.shape}")
        if x.shape[-1] != w.shape[-2]:
            raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
        if w.ndim == 2:
            lead = x.shape[:-2]
            tag = "mn,nk->mk"
        else:
            lead = np.broadcast_shapes(x.shape[:-2], w.shape[:-2])
            tag = "bmn,bnk->bmk"
        m, n, k = x.shape[-2], x.shape[-1], w.shape[-1]
        xs, ws, _ = _dispatch_storage(policy, b)
        tile = self.resolve_tile(tile, m=m, n=n, k=k, policy=policy,
                                 backend=b, x_dtype=xs, w_dtype=ws)
        spec = GemmSpec(
            op="matmul", tag=tag, m=m, n=n, k=k,
            batch=int(np.prod(lead, dtype=np.int64)) if lead else 1,
            policy=policy, tile=tile, w_shared=(w.ndim == 2),
            x_dtype=xs, w_dtype=ws, scaled=policy.scaled,
        )
        return _gemm_call(_make_ctx(spec, b, x, w), x, w)

    def linear(
        self,
        x: jax.Array,
        w: jax.Array,
        b: Optional[jax.Array] = None,
        *,
        activation: Optional[str] = None,
        policy=None,
        tile: Optional[tiling.TileConfig] = None,
        backend: Optional[str] = None,
    ) -> jax.Array:
        """Affine layer with a *fused* epilogue: ``act(x @ w + b)``.

        On backends with the ``"fused_epilogue"`` capability ("pallas",
        "interpret") the bias add and activation execute inside the GEMM
        kernel, on the accumulator in the policy's accumulation dtype,
        immediately before the store-once HBM write — the affine layer
        costs exactly one output pass.  Other backends ("xla") fall back
        to the post-op path: the epilogue runs in the accumulation dtype
        on the backend's result, then one downcast.

        Numerics: under ``paper_fp16`` (accum == out dtype) the two paths
        are bitwise identical for bias-only and relu epilogues;
        transcendental epilogues (gelu/silu/tanh) may differ by ~2 ulp
        because XLA rounds fp16 transcendentals differently inside a
        compiled kernel than in an eager post-op pass.  Under fp32-accum
        policies the fused path additionally applies the epilogue *before*
        the out-dtype rounding while the unfused path re-widens the
        already-rounded store — results agree to ~2 ulp of the output
        dtype (the fused value is the more accurate one).  The equivalence
        suite in tests/test_engine.py pins exactly this contract.  Batched
        weights ``(..., N, K)`` get the same contract on the batched-grid
        kernel (bias row shared across the batch).

        FP8 rows of the same table (the mixed-precision policies): scaled
        specs always run the epilogue post-op — the per-tensor scale
        product must hit the accumulator before the bias — so there is no
        fused-vs-unfused gap to bound; the contract is instead
        *backend-invariance*: the engine quantizes once, every backend
        sees the same FP8 values, and results across backends agree to
        the compute-dtype tolerance (fp16 ~2e-2).  Each operand's
        quantize→dequantize round-trip is bounded by its format's
        relative epsilon (E4M3 2⁻³, E5M2 2⁻²) — pinned by
        tests/test_precision_fp8.py.

        Backward (see the module docstring): ``jax.grad`` dispatches dX/dW
        through the registry as ``matmul_dx`` / ``matmul_dw``
        transpose-layout GEMMs.  On backends with the
        ``"fused_bwd_epilogue"`` capability (2D weights) the backward is
        **one pass**: the kernels apply the activation derivative
        (registry in :mod:`repro.core.epilogues`) to the dZ tile on load
        and accumulate the accum-dtype bias grad inside the dW kernel —
        the pre-activation cotangent is never materialized.  Other
        backends (and batched weights) run the two-pass fallback
        (standalone ``ds = dZ·act'(s)`` multiply + separate bias-grad
        reduction, billed as ``linear_dact`` / ``linear_dbias`` pass
        events)."""
        policy = self.resolve_policy(policy)
        bk = self.resolve_backend(backend)
        epi.validate_epilogue(activation)
        if x.ndim < 2 or w.ndim < 2:
            raise ValueError(f"linear needs x>=2D, w>=2D; got {x.shape} @ {w.shape}")
        if x.shape[-1] != w.shape[-2]:
            raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
        if b is not None and b.shape != (w.shape[-1],):
            raise ValueError(
                f"bias must have shape ({w.shape[-1]},), got {b.shape}")
        if w.ndim == 2:
            lead = x.shape[:-2]
            tag = "mn,nk->mk"
        else:
            lead = np.broadcast_shapes(x.shape[:-2], w.shape[:-2])
            tag = "bmn,bnk->bmk"
        m, n, k = x.shape[-2], x.shape[-1], w.shape[-1]
        xs, ws, _ = _dispatch_storage(policy, bk)
        tile = self.resolve_tile(tile, m=m, n=n, k=k, policy=policy,
                                 backend=bk, epilogue=activation,
                                 x_dtype=xs, w_dtype=ws)
        spec = GemmSpec(
            op="linear", tag=tag, m=m, n=n, k=k,
            batch=int(np.prod(lead, dtype=np.int64)) if lead else 1,
            policy=policy, tile=tile, epilogue=activation,
            w_shared=(w.ndim == 2),
            x_dtype=xs, w_dtype=ws, scaled=policy.scaled,
        )
        has_epilogue = b is not None or activation is not None
        # scaled (FP8) policies run the epilogue post-op and the two-pass
        # backward: the per-tensor scale product must be undone on the
        # accumulator *before* the bias/activation (and the quantization
        # point of ds must be backend-invariant) — see _linear_bwd_core
        fuse = (has_epilogue and not policy.scaled
                and get_backend(bk).supports("fused_epilogue"))
        # one-pass backward: the dX/dW kernels apply act' to dZ on load and
        # accumulate db in the dW pass (2D weights; batched weights keep
        # the two-pass fallback)
        fuse_bwd = (has_epilogue and w.ndim == 2 and not policy.scaled
                    and get_backend(bk).supports("fused_bwd_epilogue"))
        if not has_epilogue:
            return _gemm_call(_make_ctx(spec, bk, x, w), x, w)
        ctx = _make_ctx(spec, bk, x, w, b, fuse=fuse, fuse_bwd=fuse_bwd)
        if b is None:
            return _linear_call_nobias(ctx, x, w)
        return _linear_call(ctx, x, w, b)

    def grouped_matmul(
        self,
        x: jax.Array,
        w: jax.Array,
        *,
        group_sizes: Optional[jax.Array] = None,
        policy=None,
        tile: Optional[tiling.TileConfig] = None,
        backend: Optional[str] = None,
    ) -> jax.Array:
        """Per-group GEMM: ``Z[g] = X[g] @ W[g]`` for every group at once.

        Shapes: ``x: (..., G, M, N)``, ``w: (G, N, K)``; output
        ``(..., G, M, K)``.  This is the MoE expert GEMM — all experts run
        as one fat batched RedMulE GEMM (the paper's Fig 4d batching
        restoration) instead of a per-expert Python loop.

        ``group_sizes`` (optional, shape ``(G,)`` int) marks the number of
        valid M rows per group for ragged workloads; output rows at or
        beyond a group's size are zeroed.  When the sizes are statically
        known (concrete at trace time) the emitted :class:`GemmEvent`
        carries ``valid_rows = sum(min(size, M))`` so flops/bytes scale
        with the *valid* work, not ``G * M`` — forward and backward alike.
        Traced (data-dependent) sizes fall back to the dense count.

        Backward: dX/dW run as batched transpose-layout dispatches per
        group (``matmul_dx`` / ``matmul_dw`` events); the masked rows'
        cotangent is zeroed by the ``where``'s own autodiff, so invalid
        rows contribute nothing to dW."""
        policy = self.resolve_policy(policy)
        b = self.resolve_backend(backend)
        if x.ndim < 3 or w.ndim != 3:
            raise ValueError(
                f"grouped_matmul needs x (..., G, M, N) and w (G, N, K); "
                f"got {x.shape} @ {w.shape}")
        if x.shape[-3] != w.shape[0]:
            raise ValueError(
                f"group mismatch: x has {x.shape[-3]} groups, w has {w.shape[0]}")
        if x.shape[-1] != w.shape[-2]:
            raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
        lead = x.shape[:-3]
        m, n, k = x.shape[-2], x.shape[-1], w.shape[-1]
        xs, ws, _ = _dispatch_storage(policy, b)
        tile = self.resolve_tile(tile, m=m, n=n, k=k, policy=policy,
                                 backend=b, x_dtype=xs, w_dtype=ws)
        spec = GemmSpec(
            op="grouped_matmul", tag="gmn,gnk->gmk", m=m, n=n, k=k,
            batch=int(np.prod(lead, dtype=np.int64)) if lead else 1,
            groups=w.shape[0],
            policy=policy, tile=tile, w_shared=True,
            valid_rows=_static_valid_rows(group_sizes, m), ragged_dim="m",
            x_dtype=xs, w_dtype=ws, scaled=policy.scaled,
        )
        z = _gemm_call(_make_ctx(spec, b, x, w), x, w)
        if group_sizes is not None:
            valid = (jnp.arange(spec.m)[None, :]
                     < jnp.asarray(group_sizes)[:, None])      # (G, M)
            z = jnp.where(valid[..., None], z, jnp.zeros((), z.dtype))
        return z

    def einsum2d(
        self,
        eq: str,
        x: jax.Array,
        w: jax.Array,
        *,
        policy=None,
        tile: Optional[tiling.TileConfig] = None,
        backend: Optional[str] = None,
    ) -> jax.Array:
        """Two-operand einsum lowered onto the engine's GEMM dispatch.

        Supports any equation with exactly two operands, single-letter
        axes, no repeated labels within an operand and no ellipses (e.g.
        ``"bhsd,rhd->bhsr"``).  Shared labels absent from the output are
        contracted; labels unique to one operand and absent from the
        output are summed out first."""
        policy = self.resolve_policy(policy)
        b = self.resolve_backend(backend)
        plan = _plan_einsum2d(eq, x.shape, w.shape)
        (batch_l, m_l, k_l, c_l, sum_x, sum_w, a_lab, b_lab, out_lab,
         dims) = plan
        if sum_x:
            x = jnp.sum(x, axis=tuple(a_lab.index(l) for l in sum_x))
            a_lab = [l for l in a_lab if l not in sum_x]
        if sum_w:
            w = jnp.sum(w, axis=tuple(b_lab.index(l) for l in sum_w))
            b_lab = [l for l in b_lab if l not in sum_w]
        xt = jnp.transpose(x, [a_lab.index(l) for l in batch_l + m_l + c_l])
        wt = jnp.transpose(w, [b_lab.index(l) for l in batch_l + c_l + k_l])
        bsz = int(np.prod([dims[l] for l in batch_l], dtype=np.int64)) \
            if batch_l else 1
        m = int(np.prod([dims[l] for l in m_l], dtype=np.int64)) if m_l else 1
        k = int(np.prod([dims[l] for l in k_l], dtype=np.int64)) if k_l else 1
        c = int(np.prod([dims[l] for l in c_l], dtype=np.int64)) if c_l else 1
        xs, ws, _ = _dispatch_storage(policy, b)
        tile = self.resolve_tile(tile, m=m, n=c, k=k, policy=policy,
                                 backend=b, x_dtype=xs, w_dtype=ws)
        spec = GemmSpec(
            op="einsum2d", tag=eq.replace(" ", ""),
            m=m, n=c, k=k, batch=bsz, policy=policy, tile=tile,
            w_shared=not batch_l,
            x_dtype=xs, w_dtype=ws, scaled=policy.scaled,
        )
        if batch_l:
            x2 = xt.reshape(bsz, m, c)
            w2 = wt.reshape(bsz, c, k)
        else:
            x2 = xt.reshape(m, c)
            w2 = wt.reshape(c, k)
        # the custom VJP lives on the inner 2D/batched dispatch; the
        # surrounding transposes/reshapes/sums are linear ops JAX
        # differentiates natively, so einsum2d's backward GEMMs are
        # matmul_dx / matmul_dw registry dispatches too
        z = _gemm_call(_make_ctx(spec, b, x2, w2), x2, w2)
        cur = batch_l + m_l + k_l
        z = z.reshape([dims[l] for l in cur])
        return jnp.transpose(z, [cur.index(l) for l in out_lab])

    def attention(
        self,
        q: jax.Array,
        k: jax.Array,
        v: jax.Array,
        *,
        causal: bool = True,
        scale: Optional[float] = None,
        q_offset: int = 0,
        t_valid: Optional[int] = None,
        bq: Optional[int] = None,
        bkv: Optional[int] = None,
        policy=None,
        backend: Optional[str] = None,
    ) -> jax.Array:
        """Fused scaled-dot-product attention as a first-class engine op.

        Shapes: ``q: (B, Hq, S, D)``, ``k/v: (B, Hkv, T, Dv)`` with
        ``Hq % Hkv == 0`` (GQA group = ``Hq // Hkv``; the kernel maps KV
        heads in its index maps, never materializing per-q-head copies).
        ``t_valid`` masks the padded KV tail (cols >= t_valid are dead),
        ``q_offset`` is the absolute position of query row 0 for the
        causal mask (``col <= q_offset + row``).  Fully-masked query rows
        return exact zeros.  Output: ``(B, Hq, S, Dv)`` in the policy's
        output dtype.

        Backends with the ``"attention"`` capability run the flash sweep
        (online softmax, store-once output, causally dead KV blocks
        skipped), billed as ``attention_score`` / ``attention_pv``
        :class:`GemmEvent` pairs whose ``groups`` count only executed
        blocks and whose ``io_bytes`` carry the sweep's true HBM traffic.
        Block sizes resolve explicit ``bq``/``bkv`` > the autotune cache
        (sweep key ``attnc``/``attn``) > a shape-fitted heuristic.  Other
        backends (XLA) get the reference :func:`einsum2d` composition —
        identical numerics contract, events self-billed by the inner
        dispatches.  ``jax.grad`` re-enters the registry either way (the
        kernel path's ``custom_vjp`` recomputes via the reference, flash
        style: no S×T tensor is saved between forward and backward)."""
        policy = self.resolve_policy(policy)
        b = self.resolve_backend(backend)
        if q.ndim != 4 or k.ndim != 4 or v.ndim != 4:
            raise ValueError(
                f"attention needs (B, H, S, D) operands, got "
                f"{q.shape} / {k.shape} / {v.shape}")
        B, Hq, S, D = q.shape
        _, Hkv, T, Dv = v.shape
        if k.shape != (B, Hkv, T, D):
            raise ValueError(f"k/v shape mismatch: {k.shape} vs {v.shape}")
        if q.shape[0] != k.shape[0] or q.shape[-1] != k.shape[-1]:
            raise ValueError(f"q/k shape mismatch: {q.shape} vs {k.shape}")
        if Hq % Hkv != 0:
            raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
        group = Hq // Hkv
        scale = float(D ** -0.5 if scale is None else scale)
        q_offset = int(q_offset)
        t_valid = T if t_valid is None else min(int(t_valid), T)
        if not (get_backend(b).supports("attention") and Dv == D):
            return _attention_reference(
                q, k, v, group=group, causal=causal, scale=scale,
                q_offset=q_offset, t_valid=t_valid, policy=policy,
                backend=b)
        if bq is None or bkv is None:
            t = autotune.cached_tile(
                S, T, D, policy=policy, backend=b,
                sweep="attnc" if causal else "attn")
            if t is not None:
                bq = bq or t.bm
                bkv = bkv or t.bn
        bq = int(bq) if bq else min(256, -(-S // 8) * 8)
        bkv = int(bkv) if bkv else min(512, -(-T // 8) * 8)
        specs = _attention_specs(
            B=B, Hq=Hq, S=S, T=T, D=D, Dv=Dv, bq=bq, bkv=bkv,
            causal=causal, q_offset=q_offset, policy=policy)
        actx = _AttnCtx(
            kind="attention", spec=specs[0], backend=b,
            count=_repeat_multiplier(), extra=specs[1:], group=group,
            causal=causal, scale=scale, q_offset=q_offset,
            t_valid=t_valid, bq=bq, bkv=bkv, policy=policy)
        return _attention_call(actx, q, k, v)

    def linear_attention(
        self,
        q: jax.Array,
        k: jax.Array,
        v: jax.Array,
        log_g: jax.Array,
        *,
        chunk: Optional[int] = None,
        state: Optional[jax.Array] = None,
        backend: Optional[str] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        """Chunked linear attention (mLSTM/SSD state sweep) as a
        first-class engine op.

        Shapes: ``q/k: (B, H, S, dk)``, ``v: (B, H, S, dv)``,
        ``log_g: (B, H, S)`` per-step log decay; optional ``state``
        carries an ``(B, H, dk, dv)`` fp32 recurrent state in (decode /
        chunked prefill).  Returns ``(out (B, H, S, dv) fp32,
        state (B, H, dk, dv) fp32)``.

        Backends with the ``"attention"`` capability run the fused sweep
        kernel when no state is carried in (the kernel owns the zero
        init), billed as four per-chunk GEMM events
        (``linear_attention_{score,pv,inter,state}``) with ``groups`` =
        number of chunks and exact ``io_bytes`` (the running state never
        leaves VMEM until its single final store).  The chunk size
        resolves explicit ``chunk`` > autotune cache (sweep key
        ``lattn``) > 64.  Other backends — and state carry-in — run the
        reference chunked scan, whose dispatches self-bill."""
        b = self.resolve_backend(backend)
        if q.ndim != 4 or k.ndim != 4 or v.ndim != 4 or log_g.ndim != 3:
            raise ValueError(
                f"linear_attention needs (B, H, S, d) q/k/v and "
                f"(B, H, S) log_g, got {q.shape} / {k.shape} / "
                f"{v.shape} / {log_g.shape}")
        B, H, S, dk = q.shape
        dv = v.shape[-1]
        if k.shape != q.shape or v.shape[:3] != q.shape[:3] \
                or log_g.shape != q.shape[:3]:
            raise ValueError(
                f"operand shape mismatch: {q.shape} / {k.shape} / "
                f"{v.shape} / {log_g.shape}")
        if chunk is None:
            t = autotune.cached_tile(S, dk, dv, policy=prec.FP32,
                                     backend=b, sweep="lattn")
            chunk = t.bm if t is not None else 64
        chunk = int(chunk)
        if not (get_backend(b).supports("attention") and state is None):
            return _linear_attention_reference(
                q, k, v, log_g, chunk=chunk, state=state, backend=b)
        specs = _linear_attention_specs(
            B=B, H=H, S=S, dk=dk, dv=dv, chunk=chunk,
            in_bytes=jnp.dtype(q.dtype).itemsize)
        actx = _AttnCtx(
            kind="linear_attention", spec=specs[0], backend=b,
            count=_repeat_multiplier(), extra=specs[1:], chunk=chunk,
            policy=prec.FP32)
        return _linear_attention_call(actx, q, k, v, log_g)

    # expose the collectors on the instance too, for discoverability
    instrument = staticmethod(instrument)
    repeat = staticmethod(repeat)


def _plan_einsum2d(eq: str, x_shape, w_shape):
    """Parse an einsum2d equation into (batch, m, k, contract, ...) labels."""
    e = eq.replace(" ", "")
    if "->" not in e or "..." in e:
        raise ValueError(f"einsum2d needs an explicit '->' and no ellipsis: {eq!r}")
    lhs, out = e.split("->")
    terms = lhs.split(",")
    if len(terms) != 2:
        raise ValueError(f"einsum2d takes exactly two operands: {eq!r}")
    a, bt = terms
    for t in (a, bt, out):
        if len(set(t)) != len(t):
            raise ValueError(f"repeated labels are not supported: {eq!r}")
    if len(a) != len(x_shape) or len(bt) != len(w_shape):
        raise ValueError(
            f"equation {eq!r} does not match operand ranks "
            f"{len(x_shape)} and {len(w_shape)}")
    dims: Dict[str, int] = {}
    for labels, shape in ((a, x_shape), (bt, w_shape)):
        for lab, s in zip(labels, shape):
            if lab in dims and dims[lab] != s:
                raise ValueError(
                    f"size mismatch for label {lab!r} in {eq!r}: "
                    f"{dims[lab]} vs {s}")
            dims[lab] = int(s)
    for lab in out:
        if lab not in dims:
            raise ValueError(f"output label {lab!r} not in any operand: {eq!r}")
    batch_l = [l for l in a if l in bt and l in out]
    c_l = [l for l in a if l in bt and l not in out]
    m_l = [l for l in a if l not in bt and l in out]
    k_l = [l for l in bt if l not in a and l in out]
    sum_x = [l for l in a if l not in bt and l not in out]
    sum_w = [l for l in bt if l not in a and l not in out]
    return (batch_l, m_l, k_l, c_l, sum_x, sum_w,
            list(a), list(bt), list(out), dims)


DEFAULT_ENGINE = Engine()


# --------------------------------------------------------------------- #
# Module-level conveniences (the framework-wide call surface)
# --------------------------------------------------------------------- #
def matmul(x, w, **kwargs) -> jax.Array:
    return DEFAULT_ENGINE.matmul(x, w, **kwargs)


def linear(x, w, b=None, **kwargs) -> jax.Array:
    return DEFAULT_ENGINE.linear(x, w, b, **kwargs)


def grouped_matmul(x, w, **kwargs) -> jax.Array:
    return DEFAULT_ENGINE.grouped_matmul(x, w, **kwargs)


def einsum2d(eq, x, w, **kwargs) -> jax.Array:
    return DEFAULT_ENGINE.einsum2d(eq, x, w, **kwargs)


def attention(q, k, v, **kwargs) -> jax.Array:
    return DEFAULT_ENGINE.attention(q, k, v, **kwargs)


def linear_attention(q, k, v, log_g, **kwargs):
    return DEFAULT_ENGINE.linear_attention(q, k, v, log_g, **kwargs)


matmul.__doc__ = Engine.matmul.__doc__
linear.__doc__ = Engine.linear.__doc__
grouped_matmul.__doc__ = Engine.grouped_matmul.__doc__
einsum2d.__doc__ = Engine.einsum2d.__doc__
attention.__doc__ = Engine.attention.__doc__
linear_attention.__doc__ = Engine.linear_attention.__doc__
