"""Precision policies for RedMulE-JAX — the **per-operand** storage model.

The source paper's RedMulE computes IEEE binary16 (FP16) FMAs end to end;
its successor ("RedMule: A Mixed-Precision Matrix-Matrix Operation Engine",
arXiv:2301.03904) generalizes the same datapath to mixed FP8/FP16
operation: operands may be *stored* narrower than the datapath *computes*,
and the engine widens them on the way into the array.  This module models
exactly that split.  A :class:`Policy` names five dtype roles:

* **storage** — ``x_dtype`` (activations / left operand), ``w_dtype``
  (weights / right operand) and ``grad_dtype`` (backward cotangents):
  the dtype each operand occupies in HBM.  ``None`` means "same as
  ``compute_dtype``" (the uniform-precision policies below).  FP8 storage
  (``float8_e4m3fn`` / ``float8_e5m2``) travels with a **per-tensor
  scale**: the engine quantizes ``q = v / s`` with ``s = amax(v)``
  (unit-max — see :func:`quantize_fp8` for why full-fp8-range scaling
  would overflow the binary16 datapath) around each dispatch and
  multiplies the scale product back into the accumulator afterwards,
  while capable kernels upcast the FP8 tiles *on load* inside the
  K-loop — HBM traffic shrinks to the storage width, the datapath never
  sees FP8 arithmetic.
* **compute_dtype** — the dtype tiles are widened to before the MXU.
* **accum_dtype** — the on-array accumulator (the Z-buffer).
* **out_dtype** — the dtype results are stored back to HBM in.

Shipped policies:

* ``PAPER_FP16``       — faithful to the source paper: fp16 storage,
  compute, accumulation and outputs.
* ``TPU_FP16``         — fp16 storage/compute, fp32 accumulation (the
  TPU-native realization; DESIGN.md §2, §8.3).
* ``TPU_BF16``         — bf16 storage/compute, fp32 accumulation (the LM
  default).
* ``FP32``             — reference precision for oracles and tests.
* ``MIXED_FP8_E4M3``   — the mixed-precision RedMulE point: E4M3 weights
  and activations, E5M2 gradients, per-tensor scales, FP16 compute and
  FP16 (in-datapath) accumulation.
* ``MIXED_FP8_E5M2``   — the wide-range variant: E5M2 storage everywhere,
  FP16 compute, FP32 accumulation (TPU-native mixed-precision training).

Every dtype field is validated at construction: a typo'd dtype raises a
``ValueError`` naming the offending field and the known-policy registry
instead of surfacing later as a deep Pallas lowering error.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Policy",
    "PAPER_FP16",
    "TPU_FP16",
    "TPU_BF16",
    "FP32",
    "MIXED_FP8_E4M3",
    "MIXED_FP8_E5M2",
    "FP8_FORMATS",
    "resolve",
    "known_policies",
    "is_fp8",
    "fp8_max",
    "quantize_fp8",
    "dequantize_fp8",
]

# The FP8 storage formats the engine understands (E4M3 for weights and
# activations — more mantissa; E5M2 for gradients — more range).
FP8_FORMATS = ("float8_e4m3fn", "float8_e5m2")


def is_fp8(dtype) -> bool:
    """True when ``dtype`` is one of the FP8 storage formats."""
    try:
        return jnp.dtype(dtype).name in FP8_FORMATS
    except TypeError:
        return False


def fp8_max(dtype) -> float:
    """Largest finite value of an FP8 format (448 for E4M3, 57344 for E5M2)."""
    return float(jnp.finfo(jnp.dtype(dtype)).max)


def _validate_dtype(owner: str, field: str, value, *,
                    optional: bool = False) -> None:
    """A dtype field must name a real floating dtype; fail loudly at
    construction (not as a deep Pallas lowering error) naming the field
    and the known-policy registry."""
    if value is None and optional:
        return
    try:
        dt = jnp.dtype(value)
        ok = jnp.issubdtype(dt, jnp.floating)
    except TypeError:
        ok = False
    if not ok:
        raise ValueError(
            f"{owner}.{field} = {value!r} is not a floating dtype; "
            f"known precision policies: {known_policies()}")


@dataclasses.dataclass(frozen=True)
class Policy:
    """A matmul precision policy with per-operand storage dtypes.

    Attributes:
      name: human-readable identifier.
      compute_dtype: dtype tiles are widened to before the MXU.
      accum_dtype: dtype of the on-array accumulator (the Z-buffer).
      output_dtype: dtype results are stored to HBM in. ``None`` means
        "same as compute_dtype".
      faithful_accum: when True, the accumulator is re-rounded to
        ``accum_dtype`` after every reduction block, emulating the paper's
        in-pipeline fp16 accumulation error model (rather than doing one
        final downcast from fp32).
      x_dtype / w_dtype / grad_dtype: HBM *storage* dtypes of the left
        operand, the right operand, and the backward cotangent (dZ).
        ``None`` means "same as compute_dtype".  FP8 storage dtypes make
        the policy *scaled*: the engine applies per-tensor scales around
        every dispatch (see the module docstring).
    """

    name: str
    compute_dtype: jnp.dtype
    accum_dtype: jnp.dtype
    output_dtype: Optional[jnp.dtype] = None
    faithful_accum: bool = False
    x_dtype: Optional[jnp.dtype] = None
    w_dtype: Optional[jnp.dtype] = None
    grad_dtype: Optional[jnp.dtype] = None

    def __post_init__(self):
        _validate_dtype("Policy", "compute_dtype", self.compute_dtype)
        _validate_dtype("Policy", "accum_dtype", self.accum_dtype)
        _validate_dtype("Policy", "output_dtype", self.output_dtype,
                        optional=True)
        for f in ("x_dtype", "w_dtype", "grad_dtype"):
            _validate_dtype("Policy", f, getattr(self, f), optional=True)

    @property
    def out_dtype(self) -> jnp.dtype:
        return self.output_dtype if self.output_dtype is not None else self.compute_dtype

    # -- per-operand storage resolution (None -> compute_dtype) -------- #
    @property
    def x_storage_dtype(self) -> jnp.dtype:
        return self.x_dtype if self.x_dtype is not None else self.compute_dtype

    @property
    def w_storage_dtype(self) -> jnp.dtype:
        return self.w_dtype if self.w_dtype is not None else self.compute_dtype

    @property
    def grad_storage_dtype(self) -> jnp.dtype:
        return (self.grad_dtype if self.grad_dtype is not None
                else self.compute_dtype)

    @property
    def mixed_storage(self) -> bool:
        """True when any operand is stored in a dtype other than
        ``compute_dtype`` (the engine's per-operand dispatch path)."""
        return any(getattr(self, f) is not None
                   for f in ("x_dtype", "w_dtype", "grad_dtype"))

    @property
    def scaled(self) -> bool:
        """True when any operand storage is FP8 — per-tensor scales are
        applied/undone by the engine around every dispatch."""
        return any(is_fp8(d) for d in (self.x_dtype, self.w_dtype,
                                       self.grad_dtype) if d is not None)


PAPER_FP16 = Policy(
    name="paper_fp16",
    compute_dtype=jnp.float16,
    accum_dtype=jnp.float16,
    output_dtype=jnp.float16,
    faithful_accum=True,
)

TPU_FP16 = Policy(
    name="tpu_fp16",
    compute_dtype=jnp.float16,
    accum_dtype=jnp.float32,
    output_dtype=jnp.float16,
)

TPU_BF16 = Policy(
    name="tpu_bf16",
    compute_dtype=jnp.bfloat16,
    accum_dtype=jnp.float32,
    output_dtype=jnp.bfloat16,
)

FP32 = Policy(
    name="fp32",
    compute_dtype=jnp.float32,
    accum_dtype=jnp.float32,
    output_dtype=jnp.float32,
)

# The mixed-precision RedMulE point (arXiv:2301.03904): FP8 storage with
# per-tensor scales, widened to the FP16 datapath on load, accumulated in
# the datapath precision (faithful to the engine's FMA feedback path).
MIXED_FP8_E4M3 = Policy(
    name="mixed_fp8_e4m3",
    compute_dtype=jnp.float16,
    accum_dtype=jnp.float16,
    output_dtype=jnp.float16,
    faithful_accum=True,
    x_dtype=jnp.float8_e4m3fn,
    w_dtype=jnp.float8_e4m3fn,
    grad_dtype=jnp.float8_e5m2,
)

# Wide-range FP8 everywhere, fp32 accumulation — the TPU-native mixed
# point for gradient-heavy workloads.
MIXED_FP8_E5M2 = Policy(
    name="mixed_fp8_e5m2",
    compute_dtype=jnp.float16,
    accum_dtype=jnp.float32,
    output_dtype=jnp.float16,
    x_dtype=jnp.float8_e5m2,
    w_dtype=jnp.float8_e5m2,
    grad_dtype=jnp.float8_e5m2,
)

_BY_NAME = {p.name: p for p in (PAPER_FP16, TPU_FP16, TPU_BF16, FP32,
                                MIXED_FP8_E4M3, MIXED_FP8_E5M2)}


def known_policies() -> Tuple[str, ...]:
    """Sorted names of the registered policies (for error messages)."""
    return tuple(sorted(_BY_NAME))


def resolve(policy) -> Policy:
    """Accept a Policy or its string name."""
    if isinstance(policy, Policy):
        return policy
    if policy is None:
        return TPU_BF16
    try:
        return _BY_NAME[str(policy)]
    except KeyError as e:
        raise ValueError(
            f"unknown precision policy {policy!r}; known: {sorted(_BY_NAME)}"
        ) from e


# --------------------------------------------------------------------- #
# Per-tensor FP8 quantization (the engine's around-dispatch scale model)
# --------------------------------------------------------------------- #
def quantize_fp8(v: jax.Array, dtype,
                 scale: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor amax quantization: ``q = v / s`` stored in ``dtype``.

    ``s = amax(|v|)`` (computed in fp32) unless an explicit ``scale`` is
    given (e.g. a delayed scale from :mod:`repro.optim.scale`) — the
    quantized values are normalized to ``[-1, 1]``, *not* stretched to
    the format's full range: this engine widens FP8 to a **binary16**
    datapath (the mixed-precision RedMulE), and full-range E4M3/E5M2
    values (448 / 57344) would overflow fp16 products and accumulators.
    Unit-max scaling keeps every product ≤ 1 and a K-long fp16
    accumulation safely below 65504; the format's constant relative
    precision (ε = 2⁻³ / 2⁻²) is unaffected by where the window sits.
    An all-zero or non-finite tensor gets ``s = 1`` so the quantized
    values stay well-defined.  Returns ``(q, s)`` with ``s`` an f32
    scalar; ``dequantize_fp8`` inverts it."""
    dt = jnp.dtype(dtype)
    if not is_fp8(dt):
        raise ValueError(
            f"quantize_fp8 target must be one of {FP8_FORMATS}, got "
            f"{dt.name!r}")
    vf = v.astype(jnp.float32)
    if scale is None:
        amax = jnp.max(jnp.abs(vf))
        scale = jnp.where((amax > 0) & jnp.isfinite(amax), amax, 1.0)
    scale = jnp.asarray(scale, jnp.float32)
    return (vf / scale).astype(dt), scale


def dequantize_fp8(q: jax.Array, scale: jax.Array,
                   dtype=jnp.float32) -> jax.Array:
    """Invert :func:`quantize_fp8`: widen and multiply the scale back."""
    return (q.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)).astype(dtype)
