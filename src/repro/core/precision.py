"""Precision policies for RedMulE-JAX.

RedMulE computes IEEE binary16 (FP16) FMAs end to end. On TPU the MXU
natively accumulates in fp32, so the framework exposes precision as an
explicit, first-class policy:

* ``PAPER_FP16``   — faithful to the paper: fp16 inputs, fp16 accumulation
  (emulated by re-rounding the accumulator), fp16 outputs.
* ``TPU_FP16``     — fp16 inputs, fp32 accumulation, fp16 outputs. The
  TPU-native realization of the paper's engine (DESIGN.md §2, §8.3).
* ``TPU_BF16``     — bf16 inputs, fp32 accumulation, bf16 outputs. The
  default for the LM architectures (TPU-native training precision).
* ``FP32``         — reference precision for oracles and tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

__all__ = [
    "Policy",
    "PAPER_FP16",
    "TPU_FP16",
    "TPU_BF16",
    "FP32",
    "resolve",
]


@dataclasses.dataclass(frozen=True)
class Policy:
    """A matmul precision policy.

    Attributes:
      name: human-readable identifier.
      compute_dtype: dtype operands are cast to before the MXU.
      accum_dtype: dtype of the on-array accumulator (the Z-buffer).
      output_dtype: dtype results are stored to HBM in. ``None`` means
        "same as compute_dtype".
      faithful_accum: when True, the accumulator is re-rounded to
        ``accum_dtype`` after every reduction block, emulating the paper's
        in-pipeline fp16 accumulation error model (rather than doing one
        final downcast from fp32).
    """

    name: str
    compute_dtype: jnp.dtype
    accum_dtype: jnp.dtype
    output_dtype: Optional[jnp.dtype] = None
    faithful_accum: bool = False

    @property
    def out_dtype(self) -> jnp.dtype:
        return self.output_dtype if self.output_dtype is not None else self.compute_dtype


PAPER_FP16 = Policy(
    name="paper_fp16",
    compute_dtype=jnp.float16,
    accum_dtype=jnp.float16,
    output_dtype=jnp.float16,
    faithful_accum=True,
)

TPU_FP16 = Policy(
    name="tpu_fp16",
    compute_dtype=jnp.float16,
    accum_dtype=jnp.float32,
    output_dtype=jnp.float16,
)

TPU_BF16 = Policy(
    name="tpu_bf16",
    compute_dtype=jnp.bfloat16,
    accum_dtype=jnp.float32,
    output_dtype=jnp.bfloat16,
)

FP32 = Policy(
    name="fp32",
    compute_dtype=jnp.float32,
    accum_dtype=jnp.float32,
    output_dtype=jnp.float32,
)

_BY_NAME = {p.name: p for p in (PAPER_FP16, TPU_FP16, TPU_BF16, FP32)}


def resolve(policy) -> Policy:
    """Accept a Policy or its string name."""
    if isinstance(policy, Policy):
        return policy
    if policy is None:
        return TPU_BF16
    try:
        return _BY_NAME[str(policy)]
    except KeyError as e:
        raise ValueError(
            f"unknown precision policy {policy!r}; known: {sorted(_BY_NAME)}"
        ) from e
