"""Tile-shape selection: the TPU analogue of RedMulE's (H, L, P) parameters.

RedMulE fixes the X-buffer row width to ``H*(P+1)`` elements so that one
288-bit TCDM port keeps the array saturated; changing H changes the number of
memory ports (paper Fig 4b).  On TPU the equivalent trade is the BlockSpec
tile shape: it fixes the VMEM working set (the X/W/Z buffers) and the
DMA-per-FLOP ratio.  This module picks tile shapes under an explicit VMEM
budget, with MXU alignment, mirroring the paper's "keep the port busy, keep
the array full" rule:

* the Z (output) tile is the accumulator held on-array for the whole
  N-reduction (store-once rule) — it pays ``accum_bytes`` per element;
* the X and W tiles are double-buffered (Pallas pipelining = the Streamer's
  interleaved load schedule), so they pay 2x their bytes;
* the lane dimension must be a multiple of 128 and the sublane dimension a
  multiple of the dtype packing (8 for fp32, 16 for 16-bit types).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax.numpy as jnp

__all__ = ["TileConfig", "choose_tiles", "vmem_bytes", "MXU_LANE", "sublane"]

# MXU systolic array is 128x128; lane dim of a VMEM tile must be 128-aligned.
MXU_LANE = 128
# Default VMEM budget we allow the GEMM working set to claim (v5e has ~16 MiB;
# leave headroom for Pallas pipeline bookkeeping and the caller's other ops).
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024


def sublane(dtype) -> int:
    """Minimum sublane multiple for a dtype (second-to-last dim packing)."""
    itemsize = jnp.dtype(dtype).itemsize
    return max(8, 32 // max(1, itemsize))


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """Block shapes for Z = X @ W with X:(M,N), W:(N,K)  [paper naming].

    bm tiles M (output rows, the "L" analogue), bk tiles K (output columns,
    the "H*(P+1)" analogue), bn tiles the contraction N (the dimension the
    paper streams W along and accumulates over).
    """

    bm: int = 256
    bn: int = 512
    bk: int = 256

    def __post_init__(self):
        for name in ("bm", "bn", "bk"):
            v = getattr(self, name)
            if v <= 0:
                raise ValueError(f"{name} must be positive, got {v}")

    def grid(self, M: int, N: int, K: int) -> Tuple[int, int, int]:
        return (_cdiv(M, self.bm), _cdiv(K, self.bk), _cdiv(N, self.bn))


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _round_up(x: int, m: int) -> int:
    return _cdiv(x, m) * m


def vmem_bytes(t: TileConfig, compute_dtype, accum_dtype,
               depth: int = 2, fused_bwd: bool = False,
               x_dtype=None, w_dtype=None) -> int:
    """VMEM working set: pipelined X & W tiles + resident Z accumulator.

    ``depth`` is the in-kernel K-loop's buffer-slot count (2 = classic
    double buffering, the kernel's default); each streamed operand holds
    ``depth`` tiles in VMEM so the next K-step's DMA can land while the
    current step's FMA runs.  ``fused_bwd`` adds the fused backward
    epilogue's third stream — the activation-derivative tile that shadows
    the dZ operand ((bm, bn) on "nt", (bn, bk) on "tn"; billed
    conservatively as the larger of the two so one budget covers both
    layouts) plus the db accumulator row.  ``x_dtype``/``w_dtype`` are
    the per-operand *storage* dtypes (None -> ``compute_dtype``): FP8
    operands occupy half the VMEM of FP16 ones, since the kernel DMAs
    tiles in storage width and upcasts on load."""
    cb = jnp.dtype(compute_dtype).itemsize
    ab = jnp.dtype(accum_dtype).itemsize
    xb = jnp.dtype(x_dtype).itemsize if x_dtype is not None else cb
    wb = jnp.dtype(w_dtype).itemsize if w_dtype is not None else cb
    x_tile = t.bm * t.bn * xb
    w_tile = t.bn * t.bk * wb
    z_acc = t.bm * t.bk * ab
    z_out = t.bm * t.bk * cb
    d_tile = max(t.bm * t.bn, t.bn * t.bk) * cb if fused_bwd else 0
    db_row = t.bk * ab if fused_bwd else 0
    return depth * (x_tile + w_tile + d_tile) + z_acc + z_out + db_row


def choose_tiles(
    M: int,
    N: int,
    K: int,
    *,
    compute_dtype=jnp.bfloat16,
    accum_dtype=jnp.float32,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    fused_bwd: bool = False,
    x_dtype=None,
    w_dtype=None,
) -> TileConfig:
    """Pick (bm, bn, bk) for a (M,N)x(N,K) GEMM.

    Policy (paper §II-C transposed to VMEM):
      1. never tile beyond the (aligned) problem size;
      2. prefer a large square-ish Z tile (maximizes X/W reuse per Z byte,
         the paper's store-once rule makes Z cheap to keep large);
      3. grow bn (the streamed dimension) with leftover budget — longer
         N-runs amortize the accumulator's fill latency, exactly like the
         paper's H*(P+1)-cycle pipeline fill;
      4. shrink in the order bn -> bk -> bm until the working set fits.

    ``fused_bwd`` sizes the working set for a fused-backward-epilogue
    dispatch (the derivative operand streams as a third pipelined tile —
    see :func:`vmem_bytes`), so the shrink loop never hands the kernel a
    tile whose fused variant would blow the budget.  ``x_dtype``/
    ``w_dtype`` are per-operand *storage* dtypes (None -> compute): FP8
    storage halves the streamed tiles' VMEM footprint, so narrower
    operands may earn larger tiles under the same budget.

    The Engine resolves a tile for every dispatch, at every trace, so the
    search is memoized on the canonicalized arguments (the returned
    TileConfig is frozen — sharing one instance across call sites is safe).
    """
    # degenerate (empty) dims still get a valid minimum tile — zero-size
    # operands pad up to one block and slice back to nothing
    return _choose_tiles_cached(
        max(int(M), 1), max(int(N), 1), max(int(K), 1),
        jnp.dtype(compute_dtype).name, jnp.dtype(accum_dtype).name,
        int(vmem_budget), bool(fused_bwd),
        None if x_dtype is None else jnp.dtype(x_dtype).name,
        None if w_dtype is None else jnp.dtype(w_dtype).name)


@functools.lru_cache(maxsize=4096)
def _choose_tiles_cached(
    M: int, N: int, K: int,
    compute_dtype: str, accum_dtype: str, vmem_budget: int,
    fused_bwd: bool = False,
    x_dtype: str | None = None, w_dtype: str | None = None,
) -> TileConfig:
    sl = sublane(compute_dtype)
    m_cap = _round_up(min(M, 512), sl)
    k_cap = _round_up(min(K, 512), MXU_LANE)
    n_cap = _round_up(min(N, 2048), MXU_LANE)

    bm, bk, bn = m_cap, k_cap, n_cap
    # Shrink until the VMEM working set fits the budget.
    while vmem_bytes(TileConfig(bm, bn, bk), compute_dtype, accum_dtype,
                     fused_bwd=fused_bwd, x_dtype=x_dtype,
                     w_dtype=w_dtype) > vmem_budget:
        if bn > MXU_LANE:
            bn //= 2
        elif bk > MXU_LANE:
            bk //= 2
        elif bm > sl:
            bm //= 2
        else:
            break
    bn = max(MXU_LANE, _round_up(bn, MXU_LANE))
    bk = max(MXU_LANE, _round_up(bk, MXU_LANE))
    bm = max(sl, _round_up(bm, sl))
    return TileConfig(bm=bm, bn=bn, bk=bk)
