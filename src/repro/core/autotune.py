"""Per-spec GEMM autotuning — measured tile selection for the Engine hot path.

RedMulE sizes its (H, L, P) buffer geometry against the memory system once,
at design time, by sweeping the area/port trade-off (paper Fig. 4b) and
ships the point that keeps the array 98.8% utilized.  A TPU program faces
the same trade at trace time: the :class:`~repro.core.tiling.TileConfig`
fixes the VMEM working set and the DMA-per-FLOP ratio, and the static
``choose_tiles`` heuristic never *measures* anything.  This module closes
that loop:

* :func:`candidate_tiles` enumerates MXU-aligned tile configs under the
  VMEM budget (the heuristic's pick is always among them);
* :func:`autotune_gemm` scores each candidate — wall-clock on a real TPU,
  or the deterministic :func:`predicted_cost_us` roofline cost model on CPU
  (where timing the Pallas *interpreter* would measure Python, not the
  schedule) — and records the winner;
* results are keyed on a canonicalized GEMM spec (:func:`canonical_key`:
  shape buckets, dtypes, epilogue, backend) and persisted through a
  two-level cache — an in-process LRU in front of a JSON file named by the
  ``REPRO_AUTOTUNE_CACHE`` env var — so one tuning run serves every later
  process.

Engine tile resolution consults this module on every dispatch:
explicit ``tile=`` arg > :func:`cached_tile` > the ``choose_tiles``
heuristic.  Lookups are cheap (dict hit); *tuning* only happens when
:func:`autotune_gemm` is called explicitly (benchmarks, CI smoke, a user
warming a cache for a deployment).
"""

from __future__ import annotations

import collections
import dataclasses
import json
import os
import tempfile
import threading
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import precision as prec
from repro.core import tiling

__all__ = [
    "ENV_VAR",
    "AutotuneKey",
    "AutotuneResult",
    "canonical_key",
    "candidate_tiles",
    "predicted_cost_us",
    "measured_cost_us",
    "autotune_gemm",
    "cached_tile",
    "record_tile",
    "clear_cache",
    "cache_stats",
    "attention_cost_us",
    "linear_attention_cost_us",
    "autotune_attention",
]

ENV_VAR = "REPRO_AUTOTUNE_CACHE"

# roofline constants for the cost model (TPU v5e, same as roofline/analysis)
_PEAK_FLOPS = 197e12
_HBM_BW = 819e9
# fixed cost per grid step (DMA issue + pipeline bubble), calibrated loosely;
# it only needs to penalize absurdly fine grids, not predict absolute time
_STEP_OVERHEAD_S = 1.5e-6

_LRU_CAPACITY = 512


# --------------------------------------------------------------------- #
# Canonical keys
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class AutotuneKey:
    """A canonicalized GEMM spec — the unit of autotune reuse.

    Shapes are bucketed (:func:`bucket_dim`) so e.g. every decode step of a
    ragged batch hits one entry; dtypes, epilogue and backend are part of
    the key because they change the working set, the store path and the
    kernel being timed.  ``layout`` ("nn" | "nt" | "tn") keys the operand
    storage: the Engine's backward dispatches (dX = dZ·Wᵀ as "nt", dW =
    Xᵀ·dZ as "tn") run a different BlockSpec walk than a forward GEMM of
    the same logical shape, so their tuned tiles must not collide — the
    transposed problem shapes (m/n/k swap roles between fwd and bwd) are
    already part of the key, the layout disambiguates the rest.

    ``fused_bwd`` keys fused-backward-epilogue dispatches (the
    ``"fused_bwd_epilogue"`` capability): the streamed derivative operand
    adds a third DMA stream per K-step, which changes both the VMEM
    working set and the bandwidth balance the tile must hit.  ``depth`` is
    the in-kernel K-loop's double-buffer slot count (2 = classic double
    buffering); deeper pipelines trade VMEM for more DMA overlap.
    ``xstore``/``wstore`` key per-operand *storage* dtypes ("" = same as
    ``compute``): an FP8-stored operand halves its DMA stream and VMEM
    tile, so mixed-precision dispatches must not share tuned tiles with
    uniform ones of the same logical shape.

    ``sweep`` ("" for plain GEMMs) keys the Engine's attention ops, whose
    tile is a *sweep* geometry rather than an M/N/K block: ``"attn"`` /
    ``"attnc"`` (non-causal / causal flash attention — the key's m/n/k
    carry bucketed S/D/T and the stored tile's bm/bn carry (bq, bkv)) and
    ``"lattn"`` (chunked linear attention — bm carries the chunk).  Sweep
    entries select a KV-walk schedule, not a VMEM-budgeted GEMM block, so
    artifact validation (``analysis.lint``) skips the VMEM check for
    them."""

    m: int
    n: int
    k: int
    compute: str
    accum: str
    out: str
    epilogue: str      # "" when the GEMM has no fused epilogue
    backend: str
    layout: str = "nn"
    fused_bwd: bool = False
    depth: int = 2
    xstore: str = ""   # "" = same as compute (uniform-precision policies)
    wstore: str = ""
    sweep: str = ""    # "" = plain GEMM; "attn"/"attnc"/"lattn" = attention

    def to_str(self) -> str:
        ep = self.epilogue or "none"
        base = (f"m{self.m}-n{self.n}-k{self.k}-{self.compute}-{self.accum}"
                f"-{self.out}-{ep}-{self.backend}")
        # forward keys keep the PR-2 format so shipped caches stay valid;
        # non-default flags append suffixes (PR-3 added "-nt"/"-tn",
        # PR-5 adds per-operand storage "-x<dtype>"/"-w<dtype>")
        if self.layout != "nn":
            base = f"{base}-{self.layout}"
        if self.fused_bwd:
            base = f"{base}-fbwd"
        if self.depth != 2:
            base = f"{base}-d{self.depth}"
        if self.xstore:
            base = f"{base}-x{self.xstore}"
        if self.wstore:
            base = f"{base}-w{self.wstore}"
        if self.sweep:
            base = f"{base}-S{self.sweep}"
        return base


def bucket_dim(v: int) -> int:
    """Round a problem dim up to its bucket: the next power of two below
    512, then the next multiple of 512 (the tile caps in ``choose_tiles``
    make finer distinctions irrelevant above that)."""
    v = max(int(v), 1)
    if v >= 512:
        return -(-v // 512) * 512
    b = 1
    while b < v:
        b *= 2
    return b


def _store_name(dtype, compute) -> str:
    """Canonical per-operand storage key component: "" when the operand is
    stored in the compute dtype (the uniform-precision default)."""
    if dtype is None:
        return ""
    name = jnp.dtype(dtype).name
    return "" if name == jnp.dtype(compute).name else name


def canonical_key(
    m: int, n: int, k: int, *,
    policy: prec.Policy,
    backend: str,
    epilogue: Optional[str] = None,
    layout: str = "nn",
    fused_bwd: bool = False,
    pipeline_depth: int = 2,
    x_dtype=None,
    w_dtype=None,
    sweep: str = "",
) -> AutotuneKey:
    return AutotuneKey(
        m=bucket_dim(m), n=bucket_dim(n), k=bucket_dim(k),
        compute=jnp.dtype(policy.compute_dtype).name,
        accum=jnp.dtype(policy.accum_dtype).name,
        out=jnp.dtype(policy.out_dtype).name,
        epilogue=epilogue or "",
        backend=backend,
        layout=layout,
        fused_bwd=fused_bwd,
        depth=pipeline_depth,
        xstore=_store_name(x_dtype, policy.compute_dtype),
        wstore=_store_name(w_dtype, policy.compute_dtype),
        sweep=sweep,
    )


# --------------------------------------------------------------------- #
# Two-level cache: in-process LRU over a JSON file (REPRO_AUTOTUNE_CACHE)
# --------------------------------------------------------------------- #
_lock = threading.Lock()
_lru: "collections.OrderedDict[str, tiling.TileConfig]" = collections.OrderedDict()
_disk_path: Optional[str] = None
_disk_mtime: Optional[float] = None
_hits = 0
_misses = 0
_evictions = 0


def _cache_path() -> Optional[str]:
    return os.environ.get(ENV_VAR) or None


def _load_disk_locked(path: str) -> None:
    """(Re)load the JSON cache into the LRU when the file is new or changed."""
    global _disk_path, _disk_mtime
    try:
        mtime = os.stat(path).st_mtime
    except OSError:
        _disk_path, _disk_mtime = path, None
        return
    if path == _disk_path and mtime == _disk_mtime:
        return
    try:
        with open(path) as fh:
            data = json.load(fh)
    except (OSError, ValueError):
        _disk_path, _disk_mtime = path, None
        return
    for key_str, entry in data.items():
        try:
            t = tiling.TileConfig(bm=int(entry["bm"]), bn=int(entry["bn"]),
                                  bk=int(entry["bk"]))
        except (KeyError, TypeError, ValueError):
            continue
        _lru[key_str] = t
        _lru.move_to_end(key_str)
    # trimming an over-capacity *loaded* file is not working-set pressure:
    # only record_tile() insertions count toward the evictions counter
    while len(_lru) > _LRU_CAPACITY:
        _lru.popitem(last=False)
    _disk_path, _disk_mtime = path, mtime


def _write_disk_locked(path: str, key: AutotuneKey, tile: tiling.TileConfig,
                       *, source: str, us: Optional[float]) -> None:
    """Read-modify-write the JSON file atomically (tempfile + rename)."""
    global _disk_path, _disk_mtime
    data: Dict[str, dict] = {}
    try:
        with open(path) as fh:
            data = json.load(fh)
    except (OSError, ValueError):
        pass
    entry = {"bm": tile.bm, "bn": tile.bn, "bk": tile.bk, "source": source}
    if us is not None:
        entry["us"] = round(float(us), 3)
    data[key.to_str()] = entry
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as fh:
            json.dump(data, fh, indent=2, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    _disk_path, _disk_mtime = path, os.stat(path).st_mtime


def cached_tile(
    m: int, n: int, k: int, *,
    policy: prec.Policy,
    backend: str,
    epilogue: Optional[str] = None,
    layout: str = "nn",
    fused_bwd: bool = False,
    pipeline_depth: int = 2,
    x_dtype=None,
    w_dtype=None,
    sweep: str = "",
) -> Optional[tiling.TileConfig]:
    """Cache-only lookup (LRU, then the JSON file).  Never tunes."""
    global _hits, _misses
    key = canonical_key(m, n, k, policy=policy, backend=backend,
                        epilogue=epilogue, layout=layout,
                        fused_bwd=fused_bwd,
                        pipeline_depth=pipeline_depth,
                        x_dtype=x_dtype, w_dtype=w_dtype,
                        sweep=sweep).to_str()
    with _lock:
        t = _lru.get(key)
        if t is None:
            path = _cache_path()
            if path:
                _load_disk_locked(path)
                t = _lru.get(key)
        if t is not None:
            _lru.move_to_end(key)
            _hits += 1
            return t
        _misses += 1
        return None


def record_tile(
    key: AutotuneKey, tile: tiling.TileConfig, *,
    source: str = "manual",
    us: Optional[float] = None,
) -> None:
    """Store a tile under ``key`` — LRU write-through to the JSON file."""
    global _evictions
    with _lock:
        _lru[key.to_str()] = tile
        _lru.move_to_end(key.to_str())
        while len(_lru) > _LRU_CAPACITY:
            _lru.popitem(last=False)
            _evictions += 1
        path = _cache_path()
        if path:
            _write_disk_locked(path, key, tile, source=source, us=us)


def clear_cache(*, memory_only: bool = True) -> None:
    """Drop the in-process LRU (tests; the JSON file is left alone unless
    ``memory_only=False``)."""
    global _disk_path, _disk_mtime, _hits, _misses, _evictions
    with _lock:
        _lru.clear()
        _disk_path = _disk_mtime = None
        _hits = _misses = _evictions = 0
        if not memory_only:
            path = _cache_path()
            if path and os.path.exists(path):
                os.unlink(path)


def cache_stats() -> Dict[str, int]:
    """In-process LRU observability: entry count plus hit/miss/evict
    counters since the last :func:`clear_cache` (surfaced in
    ``BENCH_engine.json`` and asserted by the CI autotuner smoke)."""
    with _lock:
        return {"entries": len(_lru), "hits": _hits, "misses": _misses,
                "evictions": _evictions}


# --------------------------------------------------------------------- #
# Candidate generation
# --------------------------------------------------------------------- #
_round_up = tiling._round_up


def candidate_tiles(
    m: int, n: int, k: int, *,
    policy: prec.Policy,
    vmem_budget: int = tiling.DEFAULT_VMEM_BUDGET,
    max_candidates: int = 16,
    fused_bwd: bool = False,
    pipeline_depth: int = 2,
    x_dtype=None,
    w_dtype=None,
) -> List[tiling.TileConfig]:
    """MXU-aligned tile candidates that fit the VMEM budget.

    Never tiles beyond the aligned problem (at most one padding tile per
    dim), always includes the ``choose_tiles`` heuristic pick, and returns
    at most ``max_candidates`` ordered by the cost model (cheapest first)
    so a truncated sweep still looks at the most promising configs.
    ``fused_bwd``/``pipeline_depth`` size the budget check for the fused
    backward epilogue's third stream and the K-loop's slot count, so a
    candidate validated here never over-allocates VMEM when dispatched
    with a derivative operand.  ``x_dtype``/``w_dtype`` size (and price)
    per-operand storage widths."""
    sl = tiling.sublane(policy.compute_dtype)
    m_cap = _round_up(max(int(m), 1), sl)
    n_cap = _round_up(max(int(n), 1), tiling.MXU_LANE)
    k_cap = _round_up(max(int(k), 1), tiling.MXU_LANE)

    bms = sorted({min(_round_up(c, sl), m_cap)
                  for c in (sl, 64, 128, 256, 512)})
    bns = sorted({min(c, n_cap) for c in (128, 256, 512, 1024, 2048)})
    bks = sorted({min(c, k_cap) for c in (128, 256, 512, 1024)})

    seen = set()
    out: List[tiling.TileConfig] = []

    def _add(t: tiling.TileConfig) -> None:
        key = (t.bm, t.bn, t.bk)
        if key in seen:
            return
        if tiling.vmem_bytes(t, policy.compute_dtype, policy.accum_dtype,
                             depth=pipeline_depth,
                             fused_bwd=fused_bwd,
                             x_dtype=x_dtype,
                             w_dtype=w_dtype) > vmem_budget:
            return
        seen.add(key)
        out.append(t)

    _add(tiling.choose_tiles(m, n, k, compute_dtype=policy.compute_dtype,
                             accum_dtype=policy.accum_dtype,
                             vmem_budget=vmem_budget, fused_bwd=fused_bwd,
                             x_dtype=x_dtype, w_dtype=w_dtype))
    for bm in bms:
        for bn in bns:
            for bk in bks:
                _add(tiling.TileConfig(bm=bm, bn=bn, bk=bk))
    out.sort(key=lambda t: predicted_cost_us(m, n, k, t, policy=policy,
                                             x_dtype=x_dtype,
                                             w_dtype=w_dtype))
    return out[:max_candidates]


# --------------------------------------------------------------------- #
# Scoring: analytic cost model (CPU) and wall clock (TPU)
# --------------------------------------------------------------------- #
def predicted_cost_us(
    m: int, n: int, k: int,
    tile: tiling.TileConfig, *,
    policy: prec.Policy,
    fused_bwd: bool = False,
    layout: str = "nn",
    bias_grad: bool = False,
    pipeline_depth: int = 2,
    x_dtype=None,
    w_dtype=None,
) -> float:
    """Deterministic roofline cost model of one kernel launch, in µs.

    Models the kernel's actual schedule on the *padded* problem (so tiles
    that over-pad a ragged shape pay for their wasted MACs): every K-step
    streams one X and one W tile from HBM, the Z tile is written once
    per (i, j), and each step carries a fixed issue overhead.  This is the
    CPU fallback — on CPU the Pallas interpreter's wall clock measures
    Python, not the schedule, exactly like timing RedMulE's RTL simulator
    would measure the simulator.

    ``fused_bwd`` prices the fused backward epilogue: a third tile stream
    (the activation derivative operand, shadowing the dZ operand — (bm,
    bn) on "nt", (bn, bk) on "tn") joins every K-step, and ``bias_grad``
    adds the db output row.  That extra streaming is what the fused path
    *pays*; what it saves — the two-pass path's 3-pass ``ds`` HBM
    round-trip plus the separate bias-grad re-read, ~``4·M·K`` compute
    elements per affine layer — is billed at the workload level by the
    engine's ``linear_dact`` / ``linear_dbias`` pass events
    (:class:`repro.core.engine.GemmSpec`), which this kernel-local model
    deliberately leaves out of a single launch's cost.  ``pipeline_depth``
    only changes VMEM occupancy (slots), not the steady-state stream time,
    so it rides in the key but not the time term.  ``x_dtype``/``w_dtype``
    price per-operand *storage* widths (None -> compute): FP8 storage
    halves that operand's stream bytes — flops are width-invariant, so
    narrow storage moves the launch toward the compute roof."""
    mp = _round_up(max(int(m), 1), tile.bm)
    np_ = _round_up(max(int(n), 1), tile.bn)
    kp = _round_up(max(int(k), 1), tile.bk)
    gm, gn, gk = mp // tile.bm, np_ // tile.bn, kp // tile.bk
    steps = gm * gk * gn
    cb = jnp.dtype(policy.compute_dtype).itemsize
    ob = jnp.dtype(policy.out_dtype).itemsize
    ab = jnp.dtype(policy.accum_dtype).itemsize
    xb = jnp.dtype(x_dtype).itemsize if x_dtype is not None else cb
    wb = jnp.dtype(w_dtype).itemsize if w_dtype is not None else cb
    step_bytes = tile.bm * tile.bn * xb + tile.bn * tile.bk * wb
    if fused_bwd:
        # the deriv stream shadows the dZ operand's tile walk (the saved
        # residual rides in the compute dtype)
        step_bytes += (tile.bn * tile.bk if layout == "tn"
                       else tile.bm * tile.bn) * cb
    hbm_bytes = (steps * step_bytes
                 + gm * gk * tile.bm * tile.bk * ob)
    if bias_grad:
        hbm_bytes += gm * gk * tile.bk * ab   # the fused db output row
    flops = 2.0 * mp * np_ * kp
    t = max(hbm_bytes / _HBM_BW, flops / _PEAK_FLOPS) + steps * _STEP_OVERHEAD_S
    return t * 1e6


def measured_cost_us(
    m: int, n: int, k: int,
    tile: tiling.TileConfig, *,
    policy: prec.Policy,
    epilogue: Optional[str] = None,
    with_bias: bool = False,
    layout: str = "nn",
    fused_bwd: bool = False,
    grad_epilogue: Optional[str] = None,
    bias_grad: bool = False,
    pipeline_depth: int = 2,
    warmup: int = 1,
    iters: int = 3,
    interpret: Optional[bool] = None,
) -> float:
    """Wall-clock one compiled kernel launch (µs).  Only meaningful on a
    real accelerator backend — see :func:`predicted_cost_us` for CPU
    (``interpret`` defaults to True off-TPU so the call still *runs*, but
    then it times the Pallas interpreter, not the schedule).

    ``fused_bwd`` times the fused-backward-epilogue kernel variant: a
    random derivative operand (``grad_epilogue``, default "gelu") streams
    alongside the dZ operand, and ``bias_grad`` adds the fused db output
    on "tn" dispatches."""
    from repro.kernels import ops  # local import: kernels depend on core

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    key = jax.random.PRNGKey(0)
    kx, kw, kb = jax.random.split(key, 3)
    x_shape = (n, m) if layout == "tn" else (m, n)
    w_shape = (k, n) if layout == "nt" else (n, k)
    x = jax.random.normal(kx, x_shape, policy.compute_dtype)
    w = jax.random.normal(kw, w_shape, policy.compute_dtype)
    bias = (jax.random.normal(kb, (k,), policy.accum_dtype)
            if with_bias else None)
    deriv = None
    if fused_bwd:
        grad_epilogue = grad_epilogue or "gelu"
        d_shape = x_shape if layout == "nt" else w_shape
        deriv = jax.random.normal(kb, d_shape, policy.compute_dtype)

    def run():
        out = ops.redmule_matmul(x, w, policy=policy, tile=tile,
                                 bias=bias, epilogue=epilogue,
                                 layout=layout, interpret=interpret,
                                 deriv=deriv,
                                 grad_epilogue=(grad_epilogue if fused_bwd
                                                else None),
                                 bias_grad=bias_grad,
                                 pipeline_depth=pipeline_depth)
        return out[0] if bias_grad else out

    for _ in range(warmup):
        jax.block_until_ready(run())
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(run())
    return (time.perf_counter() - t0) / iters * 1e6


# --------------------------------------------------------------------- #
# The tuner
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class AutotuneResult:
    key: AutotuneKey
    tile: tiling.TileConfig
    us: float            # winning score (wall-clock µs or model µs)
    source: str          # "measured" | "model"
    n_candidates: int
    scores: Tuple[Tuple[Tuple[int, int, int], float], ...] = ()


def autotune_gemm(
    m: int, n: int, k: int, *,
    policy=None,
    backend: str = "pallas",
    epilogue: Optional[str] = None,
    with_bias: bool = False,
    layout: str = "nn",
    fused_bwd: bool = False,
    bias_grad: bool = False,
    pipeline_depth: int = 2,
    vmem_budget: int = tiling.DEFAULT_VMEM_BUDGET,
    max_candidates: int = 16,
    mode: Optional[str] = None,
    record: bool = True,
    x_dtype=None,
    w_dtype=None,
) -> AutotuneResult:
    """Tune one GEMM shape and (by default) record the winner in the cache.

    ``mode``: "measured" forces wall-clock timing, "model" forces the
    analytic cost model; None picks "measured" exactly when the program is
    actually running on a TPU (anything else would time the interpreter).
    ``layout`` tunes (and keys) a transpose-layout dispatch — pass "nt" /
    "tn" to warm the cache for the Engine's backward GEMMs; add
    ``fused_bwd=True`` (and ``bias_grad=True`` for "tn") to tune the
    fused-backward-epilogue kernel variants the train loop dispatches.
    ``pipeline_depth`` tunes the kernel's K-loop slot count for direct
    ``ops.redmule_matmul`` callers; Engine dispatches currently resolve
    the default depth-2 keys (threading a tuned depth through the Engine
    is a ROADMAP follow-up), so non-default-depth entries serve
    kernel-level experiments only."""
    policy = prec.resolve(policy)
    if mode is None:
        mode = ("measured" if jax.default_backend() == "tpu"
                and backend == "pallas" else "model")
    if mode not in ("measured", "model"):
        raise ValueError(f"unknown autotune mode {mode!r}")

    cands = candidate_tiles(m, n, k, policy=policy, vmem_budget=vmem_budget,
                            max_candidates=max_candidates,
                            fused_bwd=fused_bwd,
                            pipeline_depth=pipeline_depth,
                            x_dtype=x_dtype, w_dtype=w_dtype)
    scores: List[Tuple[Tuple[int, int, int], float]] = []
    best: Optional[tiling.TileConfig] = None
    best_us = float("inf")
    for t in cands:
        if mode == "measured":
            us = measured_cost_us(m, n, k, t, policy=policy,
                                  epilogue=epilogue, with_bias=with_bias,
                                  layout=layout, fused_bwd=fused_bwd,
                                  bias_grad=bias_grad,
                                  pipeline_depth=pipeline_depth)
        else:
            us = predicted_cost_us(m, n, k, t, policy=policy,
                                   fused_bwd=fused_bwd, layout=layout,
                                   bias_grad=bias_grad,
                                   pipeline_depth=pipeline_depth,
                                   x_dtype=x_dtype, w_dtype=w_dtype)
        scores.append(((t.bm, t.bn, t.bk), us))
        if us < best_us:
            best, best_us = t, us
    assert best is not None, "no tile candidates fit the VMEM budget"

    key = canonical_key(m, n, k, policy=policy, backend=backend,
                        epilogue=epilogue, layout=layout,
                        fused_bwd=fused_bwd, pipeline_depth=pipeline_depth,
                        x_dtype=x_dtype, w_dtype=w_dtype)
    if record:
        record_tile(key, best, source=mode, us=best_us)
    return AutotuneResult(key=key, tile=best, us=best_us, source=mode,
                          n_candidates=len(cands), scores=tuple(scores))


# --------------------------------------------------------------------- #
# Attention sweep tuning (the Engine's "attention" capability)
# --------------------------------------------------------------------- #
def _attn_pairs(s: int, t: int, bq: int, bkv: int, *, causal: bool,
                q_offset: int = 0) -> int:
    """Executed (q-block, kv-block) pairs of one flash sweep — causally
    dead KV blocks are skipped by the kernel, so they cost nothing."""
    s_pad = _round_up(max(int(s), 1), bq)
    t_pad = _round_up(max(int(t), 1), bkv)
    if not causal:
        return (s_pad // bq) * (t_pad // bkv)
    pairs = 0
    for qi in range(s_pad // bq):
        for ki in range(t_pad // bkv):
            if ki * bkv < q_offset + qi * bq + bq:
                pairs += 1
    return pairs


def attention_cost_us(
    s: int, t: int, d: int, bq: int, bkv: int, *,
    policy: prec.Policy,
    causal: bool = True,
) -> float:
    """Roofline cost model of one flash-attention sweep, in µs.

    Per executed block pair: the score GEMM (2·bq·bkv·d) and the PV GEMM
    (2·bq·bkv·d) run on VMEM-resident tiles; K and V stream once per pair,
    Q and the output move once per Q block (the store-once schedule).
    Causally skipped pairs cost nothing (see :func:`_attn_pairs`)."""
    cb = jnp.dtype(policy.compute_dtype).itemsize
    pairs = _attn_pairs(s, t, bq, bkv, causal=causal)
    s_pad = _round_up(max(int(s), 1), bq)
    flops = pairs * 4.0 * bq * bkv * d
    hbm = (2 * s_pad * d * cb            # q in, out back
           + pairs * 2 * bkv * d * cb)   # k + v per executed pair
    cost = max(hbm / _HBM_BW, flops / _PEAK_FLOPS) + pairs * _STEP_OVERHEAD_S
    return cost * 1e6


def linear_attention_cost_us(
    s: int, dk: int, dv: int, chunk: int, *,
    policy: prec.Policy,
) -> float:
    """Roofline cost model of one chunked linear-attention sweep, in µs.

    The state lives in VMEM across the whole sweep (stored once); per
    chunk the four GEMMs (intra score/PV, inter, state update) run on
    streamed q/k/v/g tiles.  Chunks are sequential, so each pays the step
    overhead."""
    cb = jnp.dtype(policy.compute_dtype).itemsize
    s_pad = _round_up(max(int(s), 1), chunk)
    nc = s_pad // chunk
    flops = nc * 2.0 * chunk * (chunk * dk + chunk * dv + 2 * dk * dv)
    hbm = (s_pad * (2 * dk + 2 * dv) * cb  # q, k in; v in, out back
           + s_pad * 4                     # log-decay row (f32)
           + dk * dv * 4)                  # the state, stored once
    cost = max(hbm / _HBM_BW, flops / _PEAK_FLOPS) + nc * _STEP_OVERHEAD_S
    return cost * 1e6


def autotune_attention(
    s: int, t: int, d: int, *,
    policy=None,
    backend: str = "pallas",
    kind: str = "attention",
    causal: bool = True,
    record: bool = True,
) -> AutotuneResult:
    """Tune an attention sweep geometry and record it under its sweep key.

    ``kind="attention"`` sweeps (bq, bkv) block pairs for the flash kernel
    (``t`` is the KV length, ``d`` the head dim); ``kind="linear_attention"``
    sweeps the chunk size (``t`` is dk, ``d`` is dv).  Scored with the
    analytic cost models above — attention sweeps have no wall-clock mode
    yet (the winners ship via ``REPRO_AUTOTUNE_CACHE`` like GEMM tiles).
    The stored :class:`~repro.core.tiling.TileConfig` encodes the sweep:
    ``bm=bq, bn=bkv`` (flash) or ``bm=bn=bk=chunk`` (linear)."""
    policy = prec.resolve(policy)
    scores: List[Tuple[Tuple[int, int, int], float]] = []
    best: Optional[tiling.TileConfig] = None
    best_us = float("inf")
    if kind == "attention":
        sweep = "attnc" if causal else "attn"
        for bq in (128, 256, 512):
            if bq > _round_up(max(int(s), 1), 128):
                continue
            for bkv in (128, 256, 512, 1024):
                if bkv > _round_up(max(int(t), 1), 128):
                    continue
                us = attention_cost_us(s, t, d, bq, bkv, policy=policy,
                                       causal=causal)
                tile = tiling.TileConfig(bm=bq, bn=bkv, bk=bkv)
                scores.append(((tile.bm, tile.bn, tile.bk), us))
                if us < best_us:
                    best, best_us = tile, us
    elif kind == "linear_attention":
        sweep = "lattn"
        dk, dv = t, d
        for chunk in (32, 64, 128, 256):
            if chunk > _round_up(max(int(s), 1), 32):
                continue
            us = linear_attention_cost_us(s, dk, dv, chunk, policy=policy)
            tile = tiling.TileConfig(bm=chunk, bn=chunk, bk=chunk)
            scores.append(((chunk, chunk, chunk), us))
            if us < best_us:
                best, best_us = tile, us
    else:
        raise ValueError(f"unknown attention kind {kind!r}")
    assert best is not None, "no sweep candidates for this shape"
    key = canonical_key(s, t, d, policy=policy, backend=backend,
                        sweep=sweep)
    if record:
        record_tile(key, best, source="model", us=best_us)
    return AutotuneResult(key=key, tile=best, us=best_us, source="model",
                          n_candidates=len(scores), scores=tuple(scores))
