"""DEPRECATED free-function GEMM surface — use :mod:`repro.core.engine`.

This module was the framework-wide GEMM primitive.  The surface moved to
the first-class Engine API in :mod:`repro.core.engine`:

* ``engine.matmul / linear / grouped_matmul / einsum2d`` — the op family
  every model kernel routes through;
* ``engine.register_backend(name, fn, ...)`` — the pluggable backend
  registry that replaced this module's hard-coded backend tuple
  ("pallas", "interpret" and "xla" are ordinary registered entries);
* ``engine.instrument()`` — the thread-local GemmEvent collector the
  roofline and perf model consume;
* ``engine.use_backend / set_default_backend / default_backend`` — backend
  resolution (explicit arg > context > ``REPRO_MATMUL_BACKEND`` env var,
  validated at read time > platform default).

``redmule.matmul`` and ``redmule.linear`` remain as thin deprecation shims
for one release: they delegate to the default Engine and emit a
``DeprecationWarning`` on first use.  New code should import from
``repro.core.engine`` (or ``repro.core``, which re-exports the Engine
surface).
"""

from __future__ import annotations

import warnings
from typing import Optional

import jax

from repro.core import engine as _engine
from repro.core import tiling
from repro.core.engine import (  # noqa: F401  (compat re-exports)
    default_backend,
    set_default_backend,
    use_backend,
)

__all__ = [
    "matmul",
    "linear",
    "default_backend",
    "set_default_backend",
    "use_backend",
]

_warned: set = set()


def _warn(name: str) -> None:
    if name not in _warned:
        _warned.add(name)
        warnings.warn(
            f"repro.core.redmule.{name} is deprecated; use "
            f"repro.core.engine.{name} (the Engine API)",
            DeprecationWarning,
            stacklevel=3,
        )


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    policy=None,
    tile: Optional[tiling.TileConfig] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Deprecated shim for :func:`repro.core.engine.matmul`."""
    _warn("matmul")
    return _engine.matmul(x, w, policy=policy, tile=tile, backend=backend)


def linear(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    policy=None,
    tile: Optional[tiling.TileConfig] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Deprecated shim for :func:`repro.core.engine.linear`."""
    _warn("linear")
    return _engine.linear(x, w, b, policy=policy, tile=tile, backend=backend)
