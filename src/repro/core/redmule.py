"""RedMulE engine — the framework-wide GEMM primitive.

Every dense projection, attention score/context product, MoE expert and LM
head in this repo routes through :func:`matmul` (or its conveniences
:func:`linear` / :func:`einsum2d`).  The engine dispatches to one of three
backends:

* ``"pallas"``     — the TPU Pallas kernel (`kernels/redmule_matmul.py`):
                     X-stationary, W-streamed, Z accumulated in a VMEM fp32
                     scratch and stored once (the paper's dataflow).
* ``"interpret"``  — the *same* kernel body executed in interpreter mode
                     (CPU CI; bit-faithful to the kernel's schedule).
* ``"xla"``        — `lax.dot_general` with the engine's precision policy.
                     Used for the 512-device dry-run (XLA:CPU cannot lower
                     TPU Pallas) and as the production fallback; shares the
                     tiling policy so rooflines stay representative.

Backend resolution: explicit argument > ``set_default_backend`` context >
``REPRO_MATMUL_BACKEND`` env var > platform default ("pallas" on TPU, "xla"
elsewhere).
"""

from __future__ import annotations

import contextlib
import os
import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import precision as prec
from repro.core import tiling

__all__ = [
    "matmul",
    "linear",
    "default_backend",
    "set_default_backend",
    "use_backend",
]

_VALID_BACKENDS = ("pallas", "interpret", "xla")
_state = threading.local()


def _thread_backend() -> Optional[str]:
    return getattr(_state, "backend", None)


def default_backend() -> str:
    b = _thread_backend()
    if b is not None:
        return b
    b = os.environ.get("REPRO_MATMUL_BACKEND")
    if b:
        return b
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def set_default_backend(backend: Optional[str]) -> None:
    if backend is not None and backend not in _VALID_BACKENDS:
        raise ValueError(f"backend must be one of {_VALID_BACKENDS}, got {backend!r}")
    _state.backend = backend


@contextlib.contextmanager
def use_backend(backend: str):
    old = _thread_backend()
    set_default_backend(backend)
    try:
        yield
    finally:
        set_default_backend(old)


def _resolve_backend(backend: Optional[str]) -> str:
    b = backend or default_backend()
    if b not in _VALID_BACKENDS:
        raise ValueError(f"backend must be one of {_VALID_BACKENDS}, got {b!r}")
    return b


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    policy=None,
    tile: Optional[tiling.TileConfig] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Z = X @ W with the RedMulE dataflow.

    Shapes: ``x: (..., M, N)``, ``w: (N, K)`` (weight GEMM) or
    ``w: (..., N, K)`` with broadcast-compatible leading dims (batched GEMM,
    e.g. attention).  Output: ``(..., M, K)`` in the policy's output dtype.
    """
    policy = prec.resolve(policy)
    b = _resolve_backend(backend)

    if x.ndim < 2 or w.ndim < 2:
        raise ValueError(f"matmul needs >=2D operands, got {x.shape} @ {w.shape}")
    if x.shape[-1] != w.shape[-2]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")

    xc = x.astype(policy.compute_dtype)
    wc = w.astype(policy.compute_dtype)

    if b == "xla":
        out = _xla_matmul(xc, wc, policy)
        return out.astype(policy.out_dtype)

    # Pallas paths: flatten to 2D / batched-3D.
    interpret = b == "interpret"
    from repro.kernels import ops  # local import: kernels depend on core

    if w.ndim == 2:
        lead = x.shape[:-2]
        x2 = xc.reshape((-1, x.shape[-1])) if lead else xc
        z2 = ops.redmule_matmul(x2, wc, policy=policy, tile=tile, interpret=interpret)
        return z2.reshape((*lead, x.shape[-2], w.shape[-1]))

    # batched: broadcast leading dims, vmap the kernel
    lead = np.broadcast_shapes(x.shape[:-2], w.shape[:-2])
    xb = jnp.broadcast_to(xc, (*lead, *x.shape[-2:])).reshape((-1, *x.shape[-2:]))
    wb = jnp.broadcast_to(wc, (*lead, *w.shape[-2:])).reshape((-1, *w.shape[-2:]))
    z = ops.redmule_matmul_batched(xb, wb, policy=policy, tile=tile, interpret=interpret)
    return z.reshape((*lead, x.shape[-2], w.shape[-1]))


def _xla_matmul(xc: jax.Array, wc: jax.Array, policy: prec.Policy) -> jax.Array:
    """dot_general with the engine's accumulation policy."""
    nb = max(xc.ndim, wc.ndim) - 2
    x_batch = tuple(range(xc.ndim - 2)) if xc.ndim > 2 else ()
    w_batch = tuple(range(wc.ndim - 2)) if wc.ndim > 2 else ()
    if xc.ndim > 2 and wc.ndim == 2:
        # weight GEMM: single dot over collapsed leading dims
        out = jax.lax.dot_general(
            xc, wc,
            (((xc.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=policy.accum_dtype,
        )
        return out
    if x_batch != w_batch or xc.shape[:-2] != wc.shape[:-2]:
        lead = np.broadcast_shapes(xc.shape[:-2], wc.shape[:-2])
        xc = jnp.broadcast_to(xc, (*lead, *xc.shape[-2:]))
        wc = jnp.broadcast_to(wc, (*lead, *wc.shape[-2:]))
        nb = len(lead)
        x_batch = w_batch = tuple(range(nb))
    out = jax.lax.dot_general(
        xc, wc,
        (((xc.ndim - 1,), (wc.ndim - 2,)), (x_batch, w_batch)),
        preferred_element_type=policy.accum_dtype,
    )
    return out


def linear(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    policy=None,
    tile: Optional[tiling.TileConfig] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Affine layer on the RedMulE engine: ``x @ w + b``."""
    policy = prec.resolve(policy)
    z = matmul(x, w, policy=policy, tile=tile, backend=backend)
    if b is not None:
        z = (z.astype(policy.accum_dtype) + b.astype(policy.accum_dtype)).astype(policy.out_dtype)
    return z
