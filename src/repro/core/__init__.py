"""Core of the RedMulE-JAX framework: the paper's contribution.

* :mod:`repro.core.engine`    -- the first-class GEMM Engine: op family
  (matmul / linear / grouped_matmul / einsum2d), pluggable backend
  registry, per-dispatch GemmEvent instrumentation.
* :mod:`repro.core.tiling`    -- VMEM/MXU tile selection (H/L/P analogue).
* :mod:`repro.core.precision` -- FP16/BF16/FP32 precision policies.
* :mod:`repro.core.perf_model` -- calibrated machine model of the silicon.
* :mod:`repro.core.redmule`   -- deprecated free-function shims (one
  release); new code uses the Engine surface.
"""

from repro.core import engine, perf_model, precision, redmule, tiling
from repro.core.engine import (
    Engine,
    GemmEvent,
    GemmSpec,
    einsum2d,
    grouped_matmul,
    instrument,
    linear,
    matmul,
    register_backend,
    registered_backends,
    set_default_backend,
    use_backend,
)
from repro.core.precision import FP32, PAPER_FP16, TPU_BF16, TPU_FP16, Policy
from repro.core.tiling import TileConfig, choose_tiles

__all__ = [
    "engine", "perf_model", "precision", "redmule", "tiling",
    "Engine", "GemmSpec", "GemmEvent",
    "Policy", "PAPER_FP16", "TPU_FP16", "TPU_BF16", "FP32",
    "matmul", "linear", "grouped_matmul", "einsum2d",
    "register_backend", "registered_backends", "instrument",
    "set_default_backend", "use_backend",
    "TileConfig", "choose_tiles",
]
