"""Core of the RedMulE-JAX framework: the paper's contribution.

* :mod:`repro.core.engine`    -- the first-class GEMM Engine: op family
  (matmul / linear / grouped_matmul / einsum2d), pluggable backend
  registry with capability flags, per-dispatch GemmEvent instrumentation.
* :mod:`repro.core.tiling`    -- VMEM/MXU tile selection (H/L/P analogue).
* :mod:`repro.core.autotune`  -- measured per-spec tile autotuning with a
  persistent cache (the Fig. 4b sweep, run against the real memory system).
* :mod:`repro.core.epilogues` -- the fusable activation registry shared by
  the Engine and the Pallas kernels.
* :mod:`repro.core.precision` -- FP16/BF16/FP32 precision policies.
* :mod:`repro.core.perf_model` -- calibrated machine model of the silicon.

GEMM entry points live on the Engine surface: import them from
:mod:`repro.core.engine` (``engine.matmul`` / ``engine.linear`` / ...).
The PR-1 deprecation window is over — ``repro.core.redmule`` and the
``repro.core.matmul`` / ``repro.core.linear`` re-exports are gone.
"""

from repro.core import autotune, engine, epilogues, perf_model, precision, tiling
from repro.core.engine import (
    Engine,
    GemmEvent,
    GemmSpec,
    einsum2d,
    grouped_matmul,
    instrument,
    register_backend,
    registered_backends,
    set_default_backend,
    use_backend,
)
from repro.core.precision import FP32, PAPER_FP16, TPU_BF16, TPU_FP16, Policy
from repro.core.tiling import TileConfig, choose_tiles

__all__ = [
    "autotune", "engine", "epilogues", "perf_model", "precision", "tiling",
    "Engine", "GemmSpec", "GemmEvent",
    "Policy", "PAPER_FP16", "TPU_FP16", "TPU_BF16", "FP32",
    "grouped_matmul", "einsum2d",
    "register_backend", "registered_backends", "instrument",
    "set_default_backend", "use_backend",
    "TileConfig", "choose_tiles",
]
