"""Core of the RedMulE-JAX framework: the paper's contribution.

* :mod:`repro.core.redmule`   -- the framework-wide GEMM primitive (engine).
* :mod:`repro.core.tiling`    -- VMEM/MXU tile selection (H/L/P analogue).
* :mod:`repro.core.precision` -- FP16/BF16/FP32 precision policies.
* :mod:`repro.core.perf_model` -- calibrated machine model of the silicon.
"""

from repro.core import perf_model, precision, redmule, tiling
from repro.core.precision import FP32, PAPER_FP16, TPU_BF16, TPU_FP16, Policy
from repro.core.redmule import linear, matmul, set_default_backend, use_backend
from repro.core.tiling import TileConfig, choose_tiles

__all__ = [
    "perf_model", "precision", "redmule", "tiling",
    "Policy", "PAPER_FP16", "TPU_FP16", "TPU_BF16", "FP32",
    "matmul", "linear", "set_default_backend", "use_backend",
    "TileConfig", "choose_tiles",
]
