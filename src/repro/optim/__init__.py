"""Optimization substrate: AdamW/SGD, FP16 loss scaling, grad compression."""

from repro.optim.compression import Compressor
from repro.optim.optimizer import SGD, AdamW, OptState, clip_by_global_norm, global_norm
from repro.optim.scale import LossScaleState, adjust, init_scale, scale_loss, unscale_and_check

__all__ = [
    "AdamW", "SGD", "OptState", "clip_by_global_norm", "global_norm",
    "Compressor", "LossScaleState", "adjust", "init_scale", "scale_loss",
    "unscale_and_check",
]
