"""Optimization substrate: AdamW/SGD, FP16 loss scaling, FP8 per-tensor
delayed scaling, compressed gradient collectives (fp16/int8/fp8 wires)."""

from repro.optim.compression import (Compressor, Fp8LeafState,
                                     collective_wire_bytes,
                                     compressed_mean_allreduce)
from repro.optim.optimizer import SGD, AdamW, OptState, clip_by_global_norm, global_norm
from repro.optim.scale import (Fp8ScaleState, LossScaleState, adjust,
                               fp8_scale_of, init_fp8_scale,
                               init_fp8_scale_tree, init_scale, observe_amax,
                               observe_amax_tree, scale_loss,
                               unscale_and_check, update_fp8_scale)

__all__ = [
    "AdamW", "SGD", "OptState", "clip_by_global_norm", "global_norm",
    "Compressor", "Fp8LeafState", "collective_wire_bytes",
    "compressed_mean_allreduce",
    "LossScaleState", "adjust", "init_scale", "scale_loss",
    "unscale_and_check",
    "Fp8ScaleState", "init_fp8_scale", "observe_amax", "fp8_scale_of",
    "update_fp8_scale", "init_fp8_scale_tree", "observe_amax_tree",
]
