"""Optimizers: AdamW and SGD-momentum, pure-functional (optax-style).

Moments are fp32 regardless of parameter dtype (mixed-precision discipline —
the paper's FP16 regime keeps master state in the widest affordable type).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "SGD", "clip_by_global_norm", "global_norm", "OptState"]


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any  # None for SGD without second moment


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    # linear warmup then constant (cosine handled by the caller's schedule)
    warmup_steps: int = 0

    def init(self, params) -> OptState:
        zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))

    def schedule(self, step: jax.Array) -> jax.Array:
        if self.warmup_steps <= 0:
            return jnp.float32(self.lr)
        w = jnp.minimum(1.0, (step + 1) / self.warmup_steps)
        return jnp.float32(self.lr) * w

    def update(self, grads, state: OptState, params) -> Tuple[Any, OptState]:
        step = state.step + 1
        lr = self.schedule(step)
        b1, b2 = self.b1, self.b2

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** step.astype(jnp.float32))
            vhat = v / (1 - b2 ** step.astype(jnp.float32))
            u = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), m, v

        flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
        updates = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
        return updates, OptState(step=step, mu=mu, nu=nu)

    def apply(self, params, updates):
        return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: float = 1e-2
    momentum: float = 0.9

    def init(self, params) -> OptState:
        mu = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=None)

    def update(self, grads, state: OptState, params) -> Tuple[Any, OptState]:
        def upd(g, m):
            m = self.momentum * m + g.astype(jnp.float32)
            return (-self.lr * m), m

        flat = jax.tree.map(upd, grads, state.mu)
        updates = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return updates, OptState(step=state.step + 1, mu=mu, nu=None)

    def apply(self, params, updates):
        return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
