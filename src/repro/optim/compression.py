"""Gradient compression with error feedback (distributed-optimization trick).

For cross-pod data parallelism the gradient all-reduce dominates the slow
inter-pod links.  We compress per-leaf to fp16 or int8 (per-tensor scale)
*before* the manual ``psum`` in the shard_map DP step and keep the
quantization residual in an fp32 error-feedback buffer (EF-SGD), which keeps
convergence unbiased in expectation.

Used by ``launch/train.py --compress={none,fp16,int8}`` and benchmarked in
the §Perf collective-term hillclimb.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Compressor", "NONE", "FP16", "INT8"]


@dataclasses.dataclass(frozen=True)
class Compressor:
    kind: str = "none"  # none | fp16 | int8

    @property
    def wire_bits(self) -> int:
        return {"none": 32, "fp16": 16, "int8": 8}[self.kind]

    def init(self, params) -> Any:
        if self.kind == "none":
            return None
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(self, grads, ef) -> Tuple[Any, Any]:
        """Returns (wire_grads, new_error_feedback). wire_grads are what
        crosses the network; callers psum them and then ``decompress``."""
        if self.kind == "none":
            return grads, ef

        def comp(g, e):
            g = g.astype(jnp.float32) + e
            if self.kind == "fp16":
                wire = g.astype(jnp.float16)
                resid = g - wire.astype(jnp.float32)
                return wire, resid
            # int8: symmetric per-tensor scale
            amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
            scale = amax / 127.0
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            resid = g - q.astype(jnp.float32) * scale
            return (q, scale), resid

        flat = jax.tree.map(comp, grads, ef)
        is2 = lambda x: isinstance(x, tuple) and len(x) == 2
        wire = jax.tree.map(lambda t: t[0], flat, is_leaf=is2)
        new_ef = jax.tree.map(lambda t: t[1], flat, is_leaf=is2)
        return wire, new_ef

    def decompress(self, wire) -> Any:
        if self.kind == "none":
            return wire
        if self.kind == "fp16":
            return jax.tree.map(lambda w: w.astype(jnp.float32), wire)

        def dec(leaf):
            q, scale = leaf
            return q.astype(jnp.float32) * scale

        return jax.tree.map(
            dec, wire, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)

    def psum_wire(self, wire, axis_names) -> Any:
        """All-reduce the wire representation inside shard_map.  int8 sums in
        int32 (sums of +-127 over <=2^23 hosts cannot overflow)."""
        if self.kind == "int8":
            def ps(leaf):
                q, scale = leaf
                tot = jax.lax.psum(q.astype(jnp.int32), axis_names)
                # scales differ per host: psum the dequantized mean scale
                s = jax.lax.psum(scale, axis_names)
                n = jax.lax.psum(jnp.ones((), jnp.float32), axis_names)
                return tot.astype(jnp.float32) * (s / n) / n
            return jax.tree.map(
                ps, wire, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)
        def ps(g):
            # reduce on the 16-bit wire — upcasting first would defeat the
            # compression (EF bounds the f16 summation error over steps)
            tot = jax.lax.psum(g, axis_names)
            cnt = jax.lax.psum(jnp.ones((), jnp.float32), axis_names)
            return tot.astype(jnp.float32) / cnt
        return jax.tree.map(ps, wire)


def compressed_mean_allreduce(grads, ef, compressor: Compressor, mesh,
                              axis_names=("data",)):
    """Mean-all-reduce gradients across DP shards on a compressed wire.

    shard_map over the DP axes: each shard compresses (grads + error
    feedback), the psum crosses the network in fp16/int8, and the residual
    stays local for the next step.  For a p-bit wire this cuts the gradient
    collective bytes 32/p x at the cost of EF-bounded quantization error
    (unbiased over steps — tests/test_optim.py).

    grads must be replicated across the DP axes *within* each shard's view
    (i.e. per-shard local gradients); returns (mean_grads fp32, new_ef).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if compressor.kind == "none":
        def mean_fn(g):
            return jax.tree.map(
                lambda x: jax.lax.pmean(x.astype(jnp.float32), axis_names), g)
        f = shard_map(mean_fn, mesh,
                      in_specs=(jax.tree.map(lambda _: P(), grads),),
                      out_specs=jax.tree.map(lambda _: P(), grads),
                      check_rep=False)
        return f(grads), ef

    def local_fn(g, e):
        wire, e2 = compressor.compress(g, e)
        summed = compressor.psum_wire(wire, axis_names)
        return summed, e2

    specs_g = jax.tree.map(lambda _: P(), grads)
    specs_e = jax.tree.map(lambda _: P(), ef)
    f = shard_map(local_fn, mesh, in_specs=(specs_g, specs_e),
                  out_specs=(specs_g, specs_e), check_rep=False)
    return f(grads, ef)
