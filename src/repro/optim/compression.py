"""Gradient compression with error feedback (distributed-optimization trick).

For cross-pod data parallelism the gradient all-reduce dominates the slow
inter-pod links.  We compress per-leaf *before* the manual ``psum`` in the
shard_map DP step and keep the quantization residual in an fp32
error-feedback buffer (EF-SGD), which keeps convergence unbiased in
expectation.  Four wires:

* ``fp16``     — plain downcast; the psum itself runs on the 16-bit dtype.
* ``int8``     — symmetric per-tensor scale, quantized to ±127.
* ``fp8_e4m3`` — FP8 wire (``fp8`` is an alias), quantized through
  :func:`repro.core.precision.quantize_fp8` under **delayed scaling**: a
  per-leaf :class:`repro.optim.scale.Fp8ScaleState` rolling-amax window
  supplies the scale the *next* step divides by (one overflowed gradient
  cannot poison it; an all-zero run cannot collapse it), and the residual
  ``g - dequant(q)`` — including anything clipped at the format max —
  lands in the error-feedback buffer.
* ``fp8_e5m2`` — the wide-range FP8 variant (gradients span more orders
  of magnitude than they need mantissa).

Per-host scales (int8/fp8) are handled *per host*: the all-reduce sums the
dequantized per-host terms ``q_i * s_i`` so a host with tiny gradients is
never reweighted by another host's large scale (the seed version averaged
the scales into one shared divisor, which mis-weighted hosts with very
different gradient magnitudes by orders of magnitude — pinned against the
fp32 oracle in tests/test_optim.py).  In the simulation the summed term
travels as f32; on a real network the 8-bit payload crosses the wire and
each hop dequantizes locally, which is what :meth:`Compressor.wire_bytes`
prices — analytically, like GEMM bytes, and pinned in CI against
``benchmarks/baselines/collective_bytes.json``.

Used by ``launch/train.py --compress={none,fp16,int8,fp8,fp8_e4m3,
fp8_e5m2}``, the elastic worker (``runtime/elastic.py``), and the
``ft-gates`` CI job.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import precision as prec
from repro.optim.scale import (Fp8ScaleState, fp8_scale_of, init_fp8_scale,
                               update_fp8_scale)

__all__ = [
    "Compressor", "Fp8LeafState", "collective_wire_bytes",
    "NONE", "FP16", "INT8", "FP8_E4M3", "FP8_E5M2", "KINDS",
]

KINDS = ("none", "fp16", "int8", "fp8_e4m3", "fp8_e5m2")

_WIRE_BITS = {"none": 32, "fp16": 16, "int8": 8,
              "fp8_e4m3": 8, "fp8_e5m2": 8}
_FP8_DTYPES = {"fp8_e4m3": "float8_e4m3fn", "fp8_e5m2": "float8_e5m2"}


class Fp8LeafState(NamedTuple):
    """Per-leaf compressor state for the FP8 wires: the fp32 error-feedback
    buffer plus the delayed-scaling window the next quantization reads."""

    ef: jax.Array            # fp32, shape of the gradient leaf
    scale: Fp8ScaleState     # rolling-amax delayed scale


def _is_wire_pair(x) -> bool:
    # (q, scale) wire leaves; Fp8ScaleState is a 3-tuple so it never matches
    return isinstance(x, tuple) and len(x) == 2 and not isinstance(x, Fp8LeafState)


@dataclasses.dataclass(frozen=True)
class Compressor:
    kind: str = "none"  # none | fp16 | int8 | fp8[_e4m3] | fp8_e5m2
    history_len: int = 16  # delayed-scaling window (fp8 kinds)

    def __post_init__(self):
        kind = "fp8_e4m3" if self.kind == "fp8" else self.kind
        if kind not in KINDS:
            raise ValueError(
                f"unknown compression kind {self.kind!r}; known: "
                f"{KINDS + ('fp8',)}")
        object.__setattr__(self, "kind", kind)

    @property
    def is_fp8(self) -> bool:
        return self.kind in _FP8_DTYPES

    @property
    def fp8_dtype(self):
        return jnp.dtype(_FP8_DTYPES[self.kind])

    @property
    def wire_bits(self) -> int:
        return _WIRE_BITS[self.kind]

    @property
    def scaled(self) -> bool:
        """True when the wire carries a per-tensor f32 scale next to q."""
        return self.kind == "int8" or self.is_fp8

    # ------------------------------------------------------------- #
    def init(self, params) -> Any:
        if self.kind == "none":
            return None
        if self.is_fp8:
            return jax.tree.map(
                lambda p: Fp8LeafState(
                    ef=jnp.zeros(p.shape, jnp.float32),
                    scale=init_fp8_scale(self.history_len)),
                params)
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(self, grads, ef) -> Tuple[Any, Any]:
        """Returns (wire_grads, new_error_feedback). wire_grads are what
        crosses the network; callers psum them and then ``decompress``."""
        if self.kind == "none":
            return grads, ef
        if self.is_fp8:
            return self._compress_fp8(grads, ef)

        def comp(g, e):
            g = g.astype(jnp.float32) + e
            if self.kind == "fp16":
                wire = g.astype(jnp.float16)
                resid = g - wire.astype(jnp.float32)
                return wire, resid
            # int8: symmetric per-tensor scale
            amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
            scale = amax / 127.0
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            resid = g - q.astype(jnp.float32) * scale
            return (q, scale), resid

        flat = jax.tree.map(comp, grads, ef)
        wire = jax.tree.map(lambda t: t[0], flat, is_leaf=_is_wire_pair)
        new_ef = jax.tree.map(lambda t: t[1], flat, is_leaf=_is_wire_pair)
        return wire, new_ef

    def _compress_fp8(self, grads, state) -> Tuple[Any, Any]:
        """FP8 wire: delayed scale in, residual (incl. clipping) out."""
        dt = self.fp8_dtype
        fmax = prec.fp8_max(dt)

        def comp(g, st: Fp8LeafState):
            g32 = g.astype(jnp.float32) + st.ef
            s = fp8_scale_of(st.scale)
            # clip at the format max *under the delayed scale*: a sudden
            # amax growth saturates instead of overflowing; the clipped
            # mass rides in the error feedback until the window catches up
            q, s = prec.quantize_fp8(
                jnp.clip(g32, -fmax * s, fmax * s), dt, scale=s)
            resid = g32 - prec.dequantize_fp8(q, s)
            new_st = Fp8LeafState(
                ef=resid,
                scale=update_fp8_scale(st.scale, jnp.max(jnp.abs(g32))))
            return (q, s), new_st

        flat_g, gdef = jax.tree.flatten(grads)
        flat_s = jax.tree.flatten(
            state, is_leaf=lambda x: isinstance(x, Fp8LeafState))[0]
        pairs = [comp(g, st) for g, st in zip(flat_g, flat_s)]
        wire = jax.tree.unflatten(gdef, [p[0] for p in pairs])
        new_state = jax.tree.unflatten(gdef, [p[1] for p in pairs])
        return wire, new_state

    def decompress(self, wire) -> Any:
        if self.kind == "none":
            return wire
        if self.kind == "fp16":
            return jax.tree.map(lambda w: w.astype(jnp.float32), wire)

        def dec(leaf):
            q, scale = leaf
            return q.astype(jnp.float32) * scale

        return jax.tree.map(dec, wire, is_leaf=_is_wire_pair)

    def psum_wire(self, wire, axis_names) -> Any:
        """Mean-all-reduce the wire representation inside shard_map.

        Scaled wires (int8/fp8) reduce the *per-host dequantized* terms
        ``q_i * s_i``: each host's payload is weighted by its own scale, so
        hosts with very different gradient magnitudes contribute exactly
        (the seed averaged the scales into one shared divisor — a host with
        a 1e-4 amax next to a 1e3-amax host was inflated ~1e7x).  Wire cost
        is still billed at ``wire_bits`` per element (:meth:`wire_bytes`):
        the 8-bit payload is what a ring implementation moves, dequantizing
        locally at each hop."""
        if self.scaled:
            def ps(leaf):
                q, scale = leaf
                tot = jax.lax.psum(
                    q.astype(jnp.float32) * scale, axis_names)
                n = jax.lax.psum(jnp.ones((), jnp.float32), axis_names)
                return tot / n
            return jax.tree.map(ps, wire, is_leaf=_is_wire_pair)

        def ps(g):
            # reduce on the 16-bit wire — upcasting first would defeat the
            # compression (EF bounds the f16 summation error over steps)
            tot = jax.lax.psum(g, axis_names)
            cnt = jax.lax.psum(jnp.ones((), jnp.float32), axis_names)
            return tot.astype(jnp.float32) / cnt

        return jax.tree.map(ps, wire)

    # ------------------------------------------------------------- #
    def wire_bytes(self, tree) -> int:
        """Analytic network bytes one gradient all-reduce of ``tree`` puts
        on the wire under this compressor — priced like GEMM bytes (what
        the algorithm sends, not what the simulation materializes), over
        any pytree of arrays or ShapeDtypeStructs.  Scaled wires add one
        f32 scale per tensor.  Pinned in CI against
        ``benchmarks/baselines/collective_bytes.json`` (ft-gates)."""
        total = 0
        for leaf in jax.tree.leaves(tree):
            n = int(math.prod(getattr(leaf, "shape", ()) or (1,)))
            total += n * self.wire_bits // 8
            if self.scaled:
                total += 4
        return total


def collective_wire_bytes(kind: str, tree) -> int:
    """Convenience: :meth:`Compressor.wire_bytes` for a kind name."""
    return Compressor(kind).wire_bytes(tree)


NONE = Compressor("none")
FP16 = Compressor("fp16")
INT8 = Compressor("int8")
FP8_E4M3 = Compressor("fp8_e4m3")
FP8_E5M2 = Compressor("fp8_e5m2")


def compressed_mean_allreduce(grads, ef, compressor: Compressor, mesh,
                              axis_names=("data",)):
    """Mean-all-reduce gradients across DP shards on a compressed wire.

    shard_map over the DP axes: each shard compresses (grads + error
    feedback), the psum crosses the network in fp16/int8/fp8, and the
    residual stays local for the next step.  For a p-bit wire this cuts the
    gradient collective bytes 32/p x at the cost of EF-bounded quantization
    error (unbiased over steps — tests/test_optim.py).

    grads must be replicated across the DP axes *within* each shard's view
    (i.e. per-shard local gradients); returns (mean_grads fp32, new_ef).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if compressor.kind == "none":
        def mean_fn(g):
            return jax.tree.map(
                lambda x: jax.lax.pmean(x.astype(jnp.float32), axis_names), g)
        f = shard_map(mean_fn, mesh,
                      in_specs=(jax.tree.map(lambda _: P(), grads),),
                      out_specs=jax.tree.map(lambda _: P(), grads),
                      check_rep=False)
        return f(grads), ef

    def local_fn(g, e):
        wire, e2 = compressor.compress(g, e)
        summed = compressor.psum_wire(wire, axis_names)
        return summed, e2

    specs_g = jax.tree.map(lambda _: P(), grads)
    specs_e = jax.tree.map(lambda _: P(), ef)
    f = shard_map(local_fn, mesh, in_specs=(specs_g, specs_e),
                  out_specs=(specs_g, specs_e), check_rep=False)
    return f(grads, ef)
