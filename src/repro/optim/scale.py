"""Dynamic loss scaling for pure-FP16 training (the paper's regime).

binary16 overflows at 65504; gradients under- and overflow without scaling.
Standard dynamic scheme: multiply the loss by ``scale``; if any gradient is
non-finite, skip the step and halve the scale; after ``growth_interval``
consecutive finite steps, double it.  All state is traced (works inside jit).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["LossScaleState", "init_scale", "scale_loss", "unscale_and_check", "adjust"]


class LossScaleState(NamedTuple):
    scale: jax.Array          # fp32
    good_steps: jax.Array     # i32
    growth_interval: jax.Array
    overflow_count: jax.Array  # telemetry


def init_scale(initial: float = 2.0**15, growth_interval: int = 2000) -> LossScaleState:
    return LossScaleState(
        scale=jnp.float32(initial),
        good_steps=jnp.zeros((), jnp.int32),
        growth_interval=jnp.int32(growth_interval),
        overflow_count=jnp.zeros((), jnp.int32),
    )


def scale_loss(loss: jax.Array, state: LossScaleState) -> jax.Array:
    return loss * state.scale.astype(loss.dtype)


def unscale_and_check(grads: Any, state: LossScaleState) -> Tuple[Any, jax.Array]:
    """Divide grads by the scale; return (grads, all_finite)."""
    inv = 1.0 / state.scale
    grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * inv), grads)
    finite = jnp.all(
        jnp.asarray([jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads)])
    )
    return grads, finite


def adjust(state: LossScaleState, finite: jax.Array) -> LossScaleState:
    good = jnp.where(finite, state.good_steps + 1, 0)
    grow = good >= state.growth_interval
    scale = jnp.where(
        finite,
        jnp.where(grow, state.scale * 2.0, state.scale),
        jnp.maximum(state.scale * 0.5, 1.0),
    )
    good = jnp.where(grow, 0, good)
    return LossScaleState(
        scale=scale,
        good_steps=good,
        growth_interval=state.growth_interval,
        overflow_count=state.overflow_count + jnp.where(finite, 0, 1).astype(jnp.int32),
    )
