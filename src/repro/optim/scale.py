"""Dynamic scaling for reduced-precision training.

Two schemes live here, both fully traced (work inside jit):

* **FP16 loss scaling** (the source paper's regime): binary16 overflows at
  65504; gradients under- and overflow without scaling.  Standard dynamic
  scheme: multiply the loss by ``scale``; if any gradient is non-finite,
  skip the step and halve the scale; after ``growth_interval`` consecutive
  finite steps, double it.

* **FP8 per-tensor delayed scaling** (the mixed-precision regime, PR 5):
  the Engine's just-in-time quantization (:func:`repro.core.precision.
  quantize_fp8`) recomputes ``s = amax`` at every dispatch; a training
  loop that wants a *stable* scale instead tracks a rolling amax history
  per tensor (:class:`Fp8ScaleState`) and derives the scale from the
  window maximum — the delayed-scaling recipe of FP8 training systems.
  Robustness contract (pinned by tests/test_precision_fp8.py):
  **overflow** (a non-finite amax observation, e.g. an overflowed grad)
  is recorded as an overflow and *excluded* from the window, so one bad
  step cannot poison the scale; **underflow** (an all-zero window) keeps
  the previous scale, so a run of zero gradients cannot collapse it.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "LossScaleState", "init_scale", "scale_loss", "unscale_and_check",
    "adjust",
    "Fp8ScaleState", "init_fp8_scale", "observe_amax", "fp8_scale_of",
    "update_fp8_scale", "init_fp8_scale_tree", "observe_amax_tree",
]


class LossScaleState(NamedTuple):
    scale: jax.Array          # fp32
    good_steps: jax.Array     # i32
    growth_interval: jax.Array
    overflow_count: jax.Array  # telemetry


def init_scale(initial: float = 2.0**15, growth_interval: int = 2000) -> LossScaleState:
    return LossScaleState(
        scale=jnp.float32(initial),
        good_steps=jnp.zeros((), jnp.int32),
        growth_interval=jnp.int32(growth_interval),
        overflow_count=jnp.zeros((), jnp.int32),
    )


def scale_loss(loss: jax.Array, state: LossScaleState) -> jax.Array:
    return loss * state.scale.astype(loss.dtype)


def unscale_and_check(grads: Any, state: LossScaleState) -> Tuple[Any, jax.Array]:
    """Divide grads by the scale; return (grads, all_finite)."""
    inv = 1.0 / state.scale
    grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * inv), grads)
    finite = jnp.all(
        jnp.asarray([jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads)])
    )
    return grads, finite


def adjust(state: LossScaleState, finite: jax.Array) -> LossScaleState:
    good = jnp.where(finite, state.good_steps + 1, 0)
    grow = good >= state.growth_interval
    scale = jnp.where(
        finite,
        jnp.where(grow, state.scale * 2.0, state.scale),
        jnp.maximum(state.scale * 0.5, 1.0),
    )
    good = jnp.where(grow, 0, good)
    return LossScaleState(
        scale=scale,
        good_steps=good,
        growth_interval=state.growth_interval,
        overflow_count=state.overflow_count + jnp.where(finite, 0, 1).astype(jnp.int32),
    )


# --------------------------------------------------------------------- #
# FP8 per-tensor delayed scaling (the mixed-precision policies)
# --------------------------------------------------------------------- #
class Fp8ScaleState(NamedTuple):
    """Rolling per-tensor amax window for FP8 delayed scaling.

    ``scale`` is the divisor the next quantization should use
    (``q = v / scale`` — the Engine's convention, unit-max normalized so
    the FP16 datapath cannot overflow); ``amax_history`` is the rolling
    window of observed tensor maxima; ``overflow_count`` counts dropped
    non-finite observations (telemetry, like ``LossScaleState``)."""

    scale: jax.Array           # f32 scalar
    amax_history: jax.Array    # (H,) f32 rolling window
    overflow_count: jax.Array  # i32 telemetry


def init_fp8_scale(history_len: int = 16) -> Fp8ScaleState:
    return Fp8ScaleState(
        scale=jnp.float32(1.0),
        amax_history=jnp.zeros((history_len,), jnp.float32),
        overflow_count=jnp.zeros((), jnp.int32),
    )


def observe_amax(state: Fp8ScaleState, v: jax.Array) -> Fp8ScaleState:
    """Record ``amax(|v|)`` of one tensor into the rolling window."""
    return update_fp8_scale(state, jnp.max(jnp.abs(v.astype(jnp.float32))))


def fp8_scale_of(state: Fp8ScaleState, *, margin: float = 1.0) -> jax.Array:
    """The delayed scale the *next* quantization should divide by: the
    window maximum times a safety ``margin`` (>1 leaves headroom for a
    growing amax between updates).  An empty (all-zero) window yields the
    state's current scale — underflow never collapses the scale."""
    amax = jnp.max(state.amax_history)
    return jnp.where(amax > 0, amax * jnp.float32(margin), state.scale)


def update_fp8_scale(state: Fp8ScaleState, amax: jax.Array,
                     *, margin: float = 1.0) -> Fp8ScaleState:
    """Fold one amax observation into the window and refresh the scale.

    Overflow behavior: a non-finite or negative observation is dropped
    (recorded in ``overflow_count``) — the window keeps only trustworthy
    maxima, so one overflowed gradient cannot poison future scales.
    Underflow behavior: if the whole window is zero (e.g. a run of
    all-zero gradients) the previous scale is kept."""
    amax = jnp.asarray(amax, jnp.float32)
    bad = ~jnp.isfinite(amax) | (amax < 0)
    clean = jnp.where(bad, 0.0, amax)
    hist = jnp.roll(state.amax_history, 1).at[0].set(clean)
    new_scale = jnp.where(
        jnp.max(hist) > 0, jnp.max(hist) * jnp.float32(margin), state.scale)
    return Fp8ScaleState(
        scale=new_scale,
        amax_history=hist,
        overflow_count=state.overflow_count
        + jnp.where(bad, 1, 0).astype(jnp.int32),
    )


# --------------------------------------------------------------------- #
# Tree-level delayed scaling (one Fp8ScaleState per gradient leaf — the
# FP8 gradient wire in optim/compression.py hangs these off its
# error-feedback state; any per-tensor-scaled training loop can reuse them)
# --------------------------------------------------------------------- #
def init_fp8_scale_tree(tree: Any, history_len: int = 16) -> Any:
    """A pytree shaped like ``tree`` with one fresh :class:`Fp8ScaleState`
    per leaf (per-tensor delayed scaling over a whole parameter tree)."""
    return jax.tree.map(lambda _: init_fp8_scale(history_len), tree)


def observe_amax_tree(states: Any, tree: Any) -> Any:
    """Fold each leaf's amax into its matching scale state."""
    flat_t, tdef = jax.tree.flatten(tree)
    flat_s = jax.tree.flatten(
        states, is_leaf=lambda x: isinstance(x, Fp8ScaleState))[0]
    return jax.tree.unflatten(
        tdef, [observe_amax(s, t) for s, t in zip(flat_s, flat_t)])
