"""Audited entry points: the traces the static gates run against.

Each entry builds ``(fn, args)`` for :func:`repro.analysis.jaxpr_audit.
trace_entry` — abstract arguments (``ShapeDtypeStruct`` trees) wherever
the model exposes them, so the audit never materializes weights and runs
in seconds on CPU.  The registry mirrors the CI-gated workloads:

* ``ae_train`` — the AE train step (``value_and_grad`` of ``ae_loss``),
  the same trace the ``train-gates`` flop baseline pins;
* ``yi9b_decode`` — one continuous-batching decode step on reduced
  yi-9b with the FP8 KV cache, the ``serve-gates`` trace;
* ``serve_recover`` — the serving resilience rebuild path
  (docs/serving.md failure model): re-prefill of ``prompt + emitted``,
  the batch-1 replay decode step, and the slot re-insert into the FP8
  pool — the ``serve-resilience-gates`` trace;
* ``deepseek_moe_fwd`` — reduced deepseek-moe forward (router, grouped
  expert GEMMs, combiner);
* ``xlstm_fwd`` — reduced xlstm forward: mLSTM chunked linear attention
  (now the Engine's first-class ``linear_attention`` op) plus the sLSTM
  recurrent scan, whose per-timestep GEMM was the repo's last
  jaxpr-layer escape until it moved onto ``engine.einsum2d`` — every
  entry point now reconciles to zero escapes (see
  ``benchmarks/baselines/engine_escapes.json``).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

EntrySpec = Tuple[Callable[..., Any], Sequence[Any]]


def _ae_train() -> EntrySpec:
    from repro.core import precision as prec
    from repro.data import SyntheticAE
    from repro.models import autoencoder

    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    x = jnp.asarray(SyntheticAE(batch=16).sample(0))

    def step(p):
        return jax.value_and_grad(
            lambda q: autoencoder.ae_loss(q, x, policy=prec.PAPER_FP16)[0])(p)

    return step, (params,)


def _yi9b_decode() -> EntrySpec:
    from repro import configs
    from repro.models import transformer

    cfg = configs.get_reduced("yi-9b")
    params = transformer.abstract_params(cfg)
    n, max_len = 4, 32
    sizes = np.asarray([4, 9, 17, 0], np.int32)
    cache = jax.eval_shape(lambda: transformer.init_cache(
        cfg, n, max_len, dtype=cfg.policy.compute_dtype,
        storage_dtype="float8_e4m3fn"))
    tok = jax.ShapeDtypeStruct((n, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((n,), jnp.int32)

    def step(p, c, t, q):
        return transformer.serve_step(p, cfg, t, c, q, kv_group_sizes=sizes)

    return step, (params, cache, tok, pos)


def _serve_recover() -> EntrySpec:
    from repro import configs
    from repro.models import transformer
    from repro.serving import kv_cache

    cfg = configs.get_reduced("yi-9b")
    params = transformer.abstract_params(cfg)
    n, max_len, plen = 4, 32, 12
    pool = jax.eval_shape(lambda: transformer.init_cache(
        cfg, n, max_len, dtype=cfg.policy.compute_dtype,
        storage_dtype="float8_e4m3fn"))
    seq = jax.ShapeDtypeStruct((1, plen), jnp.int32)
    tok = jax.ShapeDtypeStruct((1, 1), jnp.int32)
    sizes = np.asarray([plen + 1], np.int32)  # static ragged billing

    def recover(p, pool_, seq_, tok_):
        # the scheduler's _rebuild_slot: re-prefill the absorbed tokens,
        # replay the poisoned decode step batch-1, re-insert the slot
        _, single = transformer.prefill(
            p, cfg, {"inputs": seq_}, max_len,
            storage_dtype="float8_e4m3fn")
        row, single = transformer.serve_step(
            p, cfg, tok_, single, jnp.int32(plen), kv_group_sizes=sizes)
        pool2 = kv_cache.insert_slot(pool_, single, jnp.int32(2),
                                     cfg.policy.compute_dtype)
        return row, pool2

    return recover, (params, pool, seq, tok)


def _lm_fwd(arch: str, batch: int, seq: int) -> EntrySpec:
    from repro import configs
    from repro.models import transformer

    cfg = configs.get_reduced(arch)
    params = transformer.abstract_params(cfg)
    feed = {"inputs": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}

    def fwd(p, b):
        return transformer.forward(p, cfg, b)[0]

    return fwd, (params, feed)


def _deepseek_moe_fwd() -> EntrySpec:
    return _lm_fwd("deepseek-moe-16b", batch=2, seq=16)


def _xlstm_fwd() -> EntrySpec:
    return _lm_fwd("xlstm-1.3b", batch=2, seq=16)


ENTRY_POINTS: Dict[str, Callable[[], EntrySpec]] = {
    "ae_train": _ae_train,
    "yi9b_decode": _yi9b_decode,
    "serve_recover": _serve_recover,
    "deepseek_moe_fwd": _deepseek_moe_fwd,
    "xlstm_fwd": _xlstm_fwd,
}


def get_entry(name: str) -> EntrySpec:
    try:
        build = ENTRY_POINTS[name]
    except KeyError:
        raise KeyError(
            f"unknown audit entry {name!r}; known: {sorted(ENTRY_POINTS)}"
        ) from None
    return build()
