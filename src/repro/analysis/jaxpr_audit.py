"""Jaxpr escape auditor: find contractions that bypass the Engine.

The Engine's whole perf story (roofline, cycle model, CI flop/byte gates)
is event-driven — a GEMM that does not dispatch through
:mod:`repro.core.engine` is invisible to all of it.  This module makes
that blindness checkable: trace an entry point to a closed jaxpr under
:func:`engine.instrument`, collect every ``dot_general`` equation
(recursing through ``pjit`` / ``scan`` / ``while`` / ``cond`` / ``remat``
/ ``custom_vjp`` sub-jaxprs, multiplying ``scan`` trip counts into the
static multiplicity), and reconcile the multiset against the
``GemmEvent`` stream from the very same trace.

Reconciliation is by **dense flops**: every non-pass engine dispatch on
the XLA backend lowers to exactly one ``dot_general`` costing
``GemmSpec.dense_flops`` (ragged grouped GEMMs bill ``valid_rows`` in
:attr:`GemmSpec.flops` but the lowered dot is dense, hence the separate
hook), with trace multiplicity ``GemmEvent.count``.  Equations left over
after subtracting the engine footprint are *escaped GEMMs* — reported
with operand shapes, dtypes, and the contraction's dimension numbers.

The audit must run with the XLA backend (the default off-TPU): a
``pallas_call`` hides its in-kernel dots from the outer jaxpr, so the
event↔equation bijection only holds for ``xla``.  :func:`trace_entry`
forces it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Iterable, List, Sequence, Tuple

import jax
from jax import core as jcore

from repro.core import engine


@dataclasses.dataclass(frozen=True)
class DotSite:
    """One ``dot_general`` equation observed in a walked jaxpr.

    ``count`` is the static trace multiplicity (product of enclosing
    ``scan`` lengths); ``unbounded`` marks sites under a ``while`` loop,
    whose trip count is not static — they reconcile at multiplicity 1 and
    are flagged in the report.  ``path`` names the enclosing call
    primitives, outermost first (e.g. ``('pjit', 'scan')``).
    """

    lhs_shape: Tuple[int, ...]
    rhs_shape: Tuple[int, ...]
    lhs_dtype: str
    rhs_dtype: str
    out_dtype: str
    dimension_numbers: Tuple[Tuple[Tuple[int, ...], Tuple[int, ...]],
                             Tuple[Tuple[int, ...], Tuple[int, ...]]]
    flops: int
    count: int
    path: Tuple[str, ...]
    unbounded: bool = False

    @property
    def fingerprint(self) -> str:
        """Stable identity for manifest matching: shapes, dtypes, and
        dimension numbers — everything but multiplicity and path."""
        (lc, rc), (lb, rb) = self.dimension_numbers
        return (f"{self.lhs_dtype}{list(self.lhs_shape)}·"
                f"{self.rhs_dtype}{list(self.rhs_shape)}->{self.out_dtype}"
                f" C{list(lc)};{list(rc)} B{list(lb)};{list(rb)}")

    def describe(self) -> str:
        where = "/".join(self.path) or "<top>"
        extra = " (inside while: trip count unknown)" if self.unbounded else ""
        return (f"{self.fingerprint} x{self.count} "
                f"[{self.flops} flops each, at {where}]{extra}")


def _dot_flops(lhs_shape, rhs_shape, dimension_numbers) -> int:
    (lc, rc), (lb, rb) = dimension_numbers
    b = math.prod(lhs_shape[i] for i in lb)
    k = math.prod(lhs_shape[i] for i in lc)
    m = math.prod(d for i, d in enumerate(lhs_shape) if i not in lb + lc)
    n = math.prod(d for i, d in enumerate(rhs_shape) if i not in rb + rc)
    return 2 * b * m * n * k


def _param_jaxprs(params: Dict[str, Any]) -> Iterable[jcore.Jaxpr]:
    """Yield every (sub-)jaxpr referenced by an equation's params —
    covers pjit (``jaxpr``), scan/while/cond (``jaxpr`` /
    ``cond_jaxpr``/``body_jaxpr`` / ``branches``), remat, custom_vjp/jvp
    call jaxprs, and any future call-like primitive, without naming them
    one by one."""
    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for item in vs:
            if isinstance(item, jcore.ClosedJaxpr):
                yield item.jaxpr
            elif isinstance(item, jcore.Jaxpr):
                yield item


def _iter_eqns(jaxpr: jcore.Jaxpr, mult: int, path: Tuple[str, ...],
               unbounded: bool):
    for eqn in jaxpr.eqns:
        yield eqn, mult, path, unbounded
        name = eqn.primitive.name
        sub_mult, sub_unb = mult, unbounded
        if name == "scan":
            sub_mult = mult * int(eqn.params.get("length", 1))
        elif name == "while":
            sub_unb = True
        for sub in _param_jaxprs(eqn.params):
            yield from _iter_eqns(sub, sub_mult, path + (name,), sub_unb)


def iter_eqns(closed: jcore.ClosedJaxpr):
    """Yield ``(eqn, multiplicity, path, unbounded)`` for every equation
    in a closed jaxpr, recursing through call-like primitives —
    multiplicity is the product of enclosing ``scan`` lengths, and
    ``unbounded`` marks equations under a ``while`` loop (also used by
    :mod:`repro.analysis.dtype_audit`)."""
    yield from _iter_eqns(closed.jaxpr, 1, (), False)


def _dot_site(eqn, mult: int, path: Tuple[str, ...],
              unbounded: bool) -> DotSite:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    return DotSite(
        lhs_shape=tuple(lhs.shape), rhs_shape=tuple(rhs.shape),
        lhs_dtype=str(lhs.dtype), rhs_dtype=str(rhs.dtype),
        out_dtype=str(eqn.outvars[0].aval.dtype),
        dimension_numbers=tuple((tuple(a), tuple(b)) for a, b in dnums),
        flops=_dot_flops(lhs.shape, rhs.shape, dnums),
        count=mult, path=path, unbounded=unbounded)


def collect_dots(closed: jcore.ClosedJaxpr) -> List[DotSite]:
    """All ``dot_general`` sites in a closed jaxpr, recursively, with
    fingerprint-identical sites merged (counts summed)."""
    raw = [_dot_site(eqn, mult, path, unb)
           for eqn, mult, path, unb in iter_eqns(closed)
           if eqn.primitive.name == "dot_general"]
    merged: Dict[Tuple[str, bool], DotSite] = {}
    for site in raw:
        key = (site.fingerprint, site.unbounded)
        if key in merged:
            prev = merged[key]
            merged[key] = dataclasses.replace(
                prev, count=prev.count + site.count)
        else:
            merged[key] = site
    return sorted(merged.values(),
                  key=lambda s: (-s.flops, s.fingerprint))


@dataclasses.dataclass(frozen=True)
class AuditResult:
    """Outcome of one entry-point reconciliation."""

    entry: str
    escapes: Tuple[DotSite, ...]       # jaxpr dots no event accounts for
    unmatched_events: Dict[int, int]   # dense_flops -> dispatch surplus
    n_dots: int                        # distinct dot sites walked
    n_events: int                      # engine events observed

    @property
    def clean(self) -> bool:
        return not self.escapes

    def to_json(self) -> Dict[str, Any]:
        return {
            "entry": self.entry,
            "n_dot_sites": self.n_dots,
            "n_engine_events": self.n_events,
            "escapes": [{
                "fingerprint": s.fingerprint,
                "flops": s.flops,
                "count": s.count,
                "path": list(s.path),
                "unbounded": s.unbounded,
            } for s in self.escapes],
            "unmatched_engine_dispatches": {
                str(f): n for f, n in sorted(self.unmatched_events.items())},
        }


def trace_entry(name: str, fn: Callable, args: Sequence[Any],
                ) -> Tuple[jcore.ClosedJaxpr, List[engine.GemmEvent]]:
    """Trace ``fn(*args)`` once, capturing the jaxpr and the engine events
    of the same trace, on the XLA backend (see module docstring)."""
    with engine.use_backend("xla"), engine.instrument() as events:
        closed = jax.make_jaxpr(fn)(*args)
    return closed, list(events)


def reconcile(entry: str, sites: Sequence[DotSite],
              events: Sequence[engine.GemmEvent]) -> AuditResult:
    """Subtract the engine dispatch footprint from the walked dot sites.

    Matching is greedy by dense flops: distinct GEMMs with identical
    dense flops are fungible (a swap would be flop-neutral by
    construction).  Sites under ``while`` match at multiplicity 1."""
    foot = engine.dispatch_footprint(events)
    escapes: List[DotSite] = []
    for site in sites:
        if site.flops <= 0:
            continue   # degenerate empty-dim contraction: no MACs to bill
        avail = foot.get(site.flops, 0)
        take = min(avail, site.count)
        foot[site.flops] = avail - take
        if take < site.count:
            escapes.append(dataclasses.replace(site, count=site.count - take))
    unmatched = {f: n for f, n in foot.items() if n > 0}
    return AuditResult(entry=entry, escapes=tuple(escapes),
                       unmatched_events=unmatched,
                       n_dots=len(sites), n_events=len(events))


def audit(entry: str, fn: Callable, args: Sequence[Any]) -> AuditResult:
    """Trace + walk + reconcile in one call (the test-facing surface)."""
    closed, events = trace_entry(entry, fn, args)
    return reconcile(entry, collect_dots(closed), events)
