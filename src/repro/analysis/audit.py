"""Escape-audit CLI: ``python -m repro.analysis.audit``.

Runs the jaxpr escape auditor (:mod:`repro.analysis.jaxpr_audit`) and the
precision conformance checks (:mod:`repro.analysis.dtype_audit`) over the
registered entry points (:mod:`repro.analysis.entries`) and reconciles
the escapes against the ratchet manifest
``benchmarks/baselines/engine_escapes.json``.

Exit status is non-zero when:

* an entry's trace contains a contraction neither an Engine dispatch nor
  the manifest accounts for (**the escape count grew** — route the GEMM
  through the Engine or, exceptionally, add a manifest entry with a
  justification note);
* a manifest entry is no longer observed (**stale** — the escape was
  fixed; delete its entry so the ratchet tightens);
* the dtype audit finds fp64, off-policy fp32 materialization, or raw
  FP8 operands in any entry's jaxpr;
* a shipped precision policy violates its static invariants.

``--json`` writes the full machine-readable report (uploaded as a CI
artifact by the ``static-gates`` job).  See ``docs/static_analysis.md``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List

from repro.analysis import dtype_audit, entries, jaxpr_audit

_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), *[os.pardir] * 3))
DEFAULT_MANIFEST = os.path.join(
    _REPO_ROOT, "benchmarks", "baselines", "engine_escapes.json")


def load_manifest(path: str) -> Dict[str, Any]:
    with open(path) as fh:
        m = json.load(fh)
    m.setdefault("jaxpr", {})
    m.setdefault("ast", [])
    return m


def ratchet_errors(entry: str, result: jaxpr_audit.AuditResult,
                   manifest: Dict[str, Any]) -> List[str]:
    """Compare one entry's escapes against its manifest section: new
    escapes and stale entries are both errors (the count only moves
    down, and it moves by editing the manifest in the same commit)."""
    known = {e["fingerprint"]: int(e.get("count", 1))
             for e in manifest.get("jaxpr", {}).get(entry, [])}
    found = {s.fingerprint: s.count for s in result.escapes}
    errors: List[str] = []
    for fp, n in sorted(found.items()):
        have = known.get(fp, 0)
        if n > have:
            errors.append(
                f"{entry}: NEW escaped contraction (+{n - have}): {fp} — "
                f"route it through the Engine (see docs/static_analysis.md)")
    for fp, have in sorted(known.items()):
        if found.get(fp, 0) < have:
            errors.append(
                f"{entry}: STALE manifest entry ({found.get(fp, 0)}/{have} "
                f"observed): {fp} — the escape was fixed, delete it from "
                f"engine_escapes.json so the ratchet tightens")
    return errors


def run(entry_names: List[str], manifest_path: str,
        json_path: str = "") -> int:
    manifest = load_manifest(manifest_path)
    errors: List[str] = []
    report: Dict[str, Any] = {"entries": {}, "errors": []}

    for name in entry_names:
        fn, args = entries.get_entry(name)
        closed, events = jaxpr_audit.trace_entry(name, fn, args)
        result = jaxpr_audit.reconcile(
            name, jaxpr_audit.collect_dots(closed), events)
        errors.extend(ratchet_errors(name, result, manifest))
        findings = dtype_audit.audit_dtypes(closed, events)
        errors.extend(f"{name}: dtype: {f.describe()}" for f in findings)
        report["entries"][name] = result.to_json()
        report["entries"][name]["dtype_findings"] = [
            f.describe() for f in findings]
        status = "clean" if not result.escapes else (
            f"{sum(s.count for s in result.escapes)} escaped contraction(s)")
        print(f"[audit] {name}: {result.n_dots} dot site(s), "
              f"{result.n_events} engine event(s), {status}, "
              f"{len(findings)} dtype finding(s)")
        for s in result.escapes:
            print(f"[audit]   escape: {s.describe()}")

    policy_problems = dtype_audit.check_shipped_policies()
    errors.extend(f"policy: {p}" for p in policy_problems)
    report["policy_problems"] = policy_problems
    report["errors"] = errors

    if json_path:
        with open(json_path, "w") as fh:
            json.dump(report, fh, indent=2)
        print(f"[audit] report written to {json_path}")

    if errors:
        print(f"[audit] FAIL — {len(errors)} error(s):", file=sys.stderr)
        for e in errors:
            print(f"[audit]   {e}", file=sys.stderr)
        return 1
    print("[audit] OK — every contraction is Engine-accounted or "
          "manifest-covered")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--entry", action="append", default=[],
                    choices=sorted(entries.ENTRY_POINTS),
                    help="entry point to audit (repeatable; default: all)")
    ap.add_argument("--manifest", default=DEFAULT_MANIFEST,
                    help="ratchet manifest path")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the machine-readable report here")
    args = ap.parse_args(argv)
    names = args.entry or sorted(entries.ENTRY_POINTS)
    return run(names, args.manifest, args.json)


if __name__ == "__main__":
    sys.exit(main())
