"""Repo-invariant linter: ``python -m repro.analysis.lint``.

AST-level invariants the Engine architecture depends on, checkable
without importing (let alone tracing) the code under test:

* **no raw GEMMs in ``src/repro/models/``** — ``jnp.dot`` / ``matmul`` /
  ``einsum`` / ``tensordot`` / ``lax.dot_general`` / the ``@`` operator
  bypass GemmEvents, the autotuner, and every CI baseline.  Known sites
  live in the ``"ast"`` section of the ratchet manifest
  (``benchmarks/baselines/engine_escapes.json``), matched by
  ``(file, call, equation)``; new sites and stale entries both fail.
* **``os._exit`` confinement** — hard process death is the fault-
  injection contract of ``runtime/fault_tolerance.py`` /
  ``runtime/elastic.py``; anywhere else it skips ``atexit``/flush and
  corrupts checkpoints outside the torn-write recovery path.
* **no mutation of frozen ``GemmSpec``** — ``object.__setattr__`` (the
  only way through a frozen dataclass) and attribute assignment to
  spec-typed names break the dispatch-cache and event-identity
  assumptions.
* **no module-level mutable event collectors** — instrumentation state
  is thread-local by contract (PR 1); a module-global list shared across
  threads double-counts concurrent traces.

Plus static validation of shipped artifacts:

* autotune-cache JSONs — every entry's ``TileConfig`` must fit
  ``tiling.vmem_bytes`` under the depth / fused-bwd / operand-storage
  flags declared in its own key string;
* baseline JSONs under ``benchmarks/baselines/`` — entries must satisfy
  the ``GemmSpec`` analytic flop/byte identities (train total = fwd+bwd
  = 3x inference, FP8 strictly below FP16 at equal flops, serve KV bytes
  equal to the analytic ``decode_step_kv_bytes``, collective wire bytes
  consistent with one parameter count across widths).
"""

from __future__ import annotations

import argparse
import ast
import glob
import json
import os
import sys
from typing import Any, Dict, List, Tuple

_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), *[os.pardir] * 3))
DEFAULT_MANIFEST = os.path.join(
    _REPO_ROOT, "benchmarks", "baselines", "engine_escapes.json")

# call sites that contract arrays without going through the Engine
_GEMM_ATTRS = {"dot", "matmul", "einsum", "tensordot", "vdot", "inner",
               "dot_general"}
_GEMM_MODULES = {"jnp", "np", "numpy", "lax", "jax"}
_OS_EXIT_ALLOWED = {
    os.path.join("runtime", "fault_tolerance.py"),
    os.path.join("runtime", "elastic.py"),
}
# fields of the frozen GemmSpec (kept literal so the linter never imports
# jax); drift is caught by tests/test_static_analysis.py
_GEMMSPEC_FIELDS = {
    "op", "tag", "m", "n", "k", "batch", "groups", "policy", "tile",
    "epilogue", "w_shared", "layout", "valid_rows", "ragged_dim",
    "grad_epilogue", "grad_mode", "fused_bwd", "fused_bias_grad",
    "x_dtype", "w_dtype", "scaled", "io_bytes",
}


class Violation(Tuple[str, int, str, str]):
    """(file, line, rule, message) — a plain tuple with a formatter."""

    def __str__(self) -> str:
        f, line, rule, msg = self
        return f"{f}:{line}: [{rule}] {msg}"


def _v(path: str, line: int, rule: str, msg: str) -> Violation:
    return Violation((os.path.relpath(path, _REPO_ROOT), line, rule, msg))


def _dotted(node: ast.AST) -> str:
    """'jnp.einsum' for Attribute(Name('jnp'), 'einsum'), '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _einsum_equation(call: ast.Call) -> str:
    if call.args and isinstance(call.args[0], ast.Constant) \
            and isinstance(call.args[0].value, str):
        return call.args[0].value
    return ""


# --------------------------------------------------------------------- #
# AST rules
# --------------------------------------------------------------------- #
def _find_gemm_calls(path: str, tree: ast.Module) -> List[Dict[str, Any]]:
    found: List[Dict[str, Any]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.MatMult):
            found.append({"file": os.path.relpath(path, _REPO_ROOT),
                          "call": "@", "equation": "",
                          "line": node.lineno})
        elif isinstance(node, ast.Call):
            name = _dotted(node.func)
            head, _, attr = name.rpartition(".")
            root = head.split(".")[0] if head else ""
            if attr in _GEMM_ATTRS and root in _GEMM_MODULES:
                found.append({
                    "file": os.path.relpath(path, _REPO_ROOT),
                    "call": f"{head.split('.')[-1]}.{attr}",
                    "equation": (_einsum_equation(node)
                                 if attr == "einsum" else ""),
                    "line": node.lineno})
    return found


def _check_os_exit(path: str, tree: ast.Module) -> List[Violation]:
    rel = os.path.relpath(path, os.path.join(_REPO_ROOT, "src", "repro"))
    if rel in _OS_EXIT_ALLOWED:
        return []
    return [
        _v(path, node.lineno, "os-exit",
           "os._exit outside runtime/fault_tolerance.py / "
           "runtime/elastic.py — hard death elsewhere skips flush/atexit "
           "and corrupts checkpoints outside the torn-write recovery path")
        for node in ast.walk(tree)
        if isinstance(node, ast.Call) and _dotted(node.func) == "os._exit"]


def _check_spec_mutation(path: str, tree: ast.Module) -> List[Violation]:
    # the one legitimate frozen-dataclass escape hatch: a class
    # normalizing ITSELF in __post_init__ via object.__setattr__(self, …)
    post_init_ok = set()
    for fn in ast.walk(tree):
        if isinstance(fn, ast.FunctionDef) and fn.name == "__post_init__":
            for node in ast.walk(fn):
                if (isinstance(node, ast.Call)
                        and _dotted(node.func) == "object.__setattr__"
                        and node.args
                        and isinstance(node.args[0], ast.Name)
                        and node.args[0].id == "self"):
                    post_init_ok.add(id(node))
    out: List[Violation] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) \
                and _dotted(node.func) == "object.__setattr__" \
                and id(node) not in post_init_ok:
            out.append(_v(
                path, node.lineno, "spec-mutation",
                "object.__setattr__ outside __post_init__(self) defeats "
                "frozen dataclasses (GemmSpec identity is load-bearing "
                "for dispatch caching and event accounting)"))
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id.lower().endswith("spec")
                        and t.attr in _GEMMSPEC_FIELDS):
                    out.append(_v(
                        path, node.lineno, "spec-mutation",
                        f"assignment to {t.value.id}.{t.attr} — GemmSpec "
                        f"is frozen; build a new spec with "
                        f"dataclasses.replace instead"))
    return out


def _check_module_collectors(path: str, tree: ast.Module) -> List[Violation]:
    """Instrumentation state must be thread-local (PR 1): a module-global
    mutable named like an event sink is shared across threads."""
    out: List[Violation] = []
    mutable_calls = {"list", "dict", "set", "defaultdict", "deque",
                     "OrderedDict", "Counter"}
    for node in tree.body:
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        else:
            continue
        mutable = isinstance(value, (ast.List, ast.Dict, ast.Set)) or (
            isinstance(value, ast.Call)
            and _dotted(value.func).split(".")[-1] in mutable_calls)
        if not mutable:
            continue
        for t in targets:
            if isinstance(t, ast.Name) and any(
                    w in t.id.lower() for w in ("event", "collector")):
                out.append(_v(
                    path, node.lineno, "module-collector",
                    f"module-level mutable {t.id!r} looks like an event "
                    f"collector — instrumentation state must live in "
                    f"threading.local (engine.instrument's contract)"))
    return out


def lint_sources(src_root: str = "",
                 manifest_path: str = DEFAULT_MANIFEST) -> List[Violation]:
    src_root = src_root or os.path.join(_REPO_ROOT, "src", "repro")
    with open(manifest_path) as fh:
        manifest_ast = json.load(fh).get("ast", [])
    allowed = {(e["file"], e["call"], e.get("equation", "")):
               int(e.get("count", 1)) for e in manifest_ast}

    violations: List[Violation] = []
    gemm_sites: List[Dict[str, Any]] = []
    for path in sorted(glob.glob(os.path.join(src_root, "**", "*.py"),
                                 recursive=True)):
        tree = ast.parse(open(path).read(), filename=path)
        violations += _check_os_exit(path, tree)
        violations += _check_spec_mutation(path, tree)
        violations += _check_module_collectors(path, tree)
        if os.path.sep + "models" + os.path.sep in path:
            gemm_sites += _find_gemm_calls(path, tree)

    # raw-GEMM ratchet: group found sites, diff against the manifest
    found: Dict[Tuple[str, str, str], List[int]] = {}
    for s in gemm_sites:
        found.setdefault((s["file"], s["call"], s["equation"]),
                         []).append(s["line"])
    for key, lines in sorted(found.items()):
        have = allowed.get(key, 0)
        if len(lines) > have:
            f, call, eq = key
            eqs = f" ({eq!r})" if eq else ""
            violations.append(Violation((
                f, lines[0], "models-gemm",
                f"raw {call}{eqs} x{len(lines)} at line(s) "
                f"{lines} but the manifest allows {have} — route it "
                f"through the Engine (engine.matmul/einsum2d) or, "
                f"exceptionally, add a manifest entry with a note")))
    for key, have in sorted(allowed.items()):
        got = len(found.get(key, []))
        if got < have:
            f, call, eq = key
            violations.append(Violation((
                f, 0, "models-gemm",
                f"STALE manifest entry: {call} {eq!r} ({got}/{have} "
                f"observed) — the escape was fixed, delete it from "
                f"engine_escapes.json so the ratchet tightens")))
    return violations


# --------------------------------------------------------------------- #
# Artifact validation
# --------------------------------------------------------------------- #
_KEY_RE = __import__("re").compile(
    r"^m(?P<m>\d+)-n(?P<n>\d+)-k(?P<k>\d+)"
    r"-(?P<compute>[^-]+)-(?P<accum>[^-]+)-(?P<out>[^-]+)"
    r"-(?P<epilogue>[^-]+)-(?P<backend>[^-]+)"
    r"(?:-(?P<layout>nt|tn))?(?:-(?P<fbwd>fbwd))?(?:-d(?P<depth>\d+))?"
    r"(?:-x(?P<xstore>[^-]+))?(?:-w(?P<wstore>[^-]+))?"
    r"(?:-S(?P<sweep>[^-]+))?$")


def validate_autotune_cache(path: str) -> List[Violation]:
    """Every cached tile must fit the VMEM budget under the flags its own
    key declares (depth, fused-bwd stream, per-operand storage)."""
    from repro.core import tiling  # deferred: needs jax

    out: List[Violation] = []
    try:
        with open(path) as fh:
            cache = json.load(fh)
    except (OSError, ValueError) as e:
        return [_v(path, 0, "autotune-cache", f"unreadable cache: {e}")]
    for key, entry in sorted(cache.items()):
        if key.startswith("_"):
            continue
        m = _KEY_RE.match(key)
        if not m:
            out.append(_v(path, 0, "autotune-cache",
                          f"unparseable key {key!r}"))
            continue
        try:
            tile = tiling.TileConfig(bm=int(entry["bm"]), bn=int(entry["bn"]),
                                     bk=int(entry["bk"]))
        except (KeyError, TypeError) as e:
            out.append(_v(path, 0, "autotune-cache",
                          f"{key!r}: malformed entry ({e})"))
            continue
        if m["sweep"]:
            # attention sweep keys: (bq, bkv) / chunk geometries ride in a
            # TileConfig but budget VMEM by the sweep kernels' own scratch
            # shapes, not the GEMM pipeline formula — skip the GEMM check
            continue
        need = tiling.vmem_bytes(
            tile, m["compute"], m["accum"],
            depth=int(m["depth"] or 2), fused_bwd=bool(m["fbwd"]),
            x_dtype=m["xstore"] or None, w_dtype=m["wstore"] or None)
        if need > tiling.DEFAULT_VMEM_BUDGET:
            out.append(_v(
                path, 0, "autotune-cache",
                f"{key!r}: tile ({tile.bm},{tile.bn},{tile.bk}) needs "
                f"{need} B of VMEM under depth={m['depth'] or 2}, over the "
                f"{tiling.DEFAULT_VMEM_BUDGET} B budget — this cache was "
                f"tuned against a different kernel geometry"))
    return out


def _load(base_dir: str, name: str) -> Any:
    with open(os.path.join(base_dir, name)) as fh:
        return json.load(fh)


def validate_baselines(base_dir: str = "") -> List[Violation]:
    """Cross-check the pinned baseline JSONs against the analytic
    identities they are derived from (GemmSpec flop/byte formulas)."""
    base_dir = base_dir or os.path.join(_REPO_ROOT, "benchmarks",
                                        "baselines")
    out: List[Violation] = []

    def bad(name: str, msg: str):
        out.append(_v(os.path.join(base_dir, name), 0, "baseline", msg))

    eng = _load(base_dir, "engine_flops.json")
    for k, v in eng.items():
        if not k.startswith("_") and (not isinstance(v, int) or v <= 0):
            bad("engine_flops.json", f"{k}: non-positive flops {v!r}")
    causal = eng["attn_flash_fwd_B2_H4_S256_D64_causal"]
    dense = eng["attn_flash_fwd_B2_H4_S256_D64_dense"]
    if not causal < dense:
        bad("engine_flops.json",
            "causal attention flops not below dense at the same geometry "
            "(causally dead KV blocks must be excluded from the bill)")

    tr = _load(base_dir, "train_flops.json")["ae_train_B16"]
    if tr["total"] != tr["fwd"] + tr["bwd"]:
        bad("train_flops.json", "total != fwd + bwd")
    if tr["bwd"] != 2 * tr["fwd"]:
        bad("train_flops.json",
            "bwd != 2*fwd (pure-GEMM model: dX + dW per affine layer)")
    if tr["fwd"] != eng["ae_fwd_B16"]:
        bad("train_flops.json",
            "train fwd != engine_flops.json ae_fwd_B16 (same trace)")

    tb = _load(base_dir, "train_bytes.json")
    fused, two = tb["ae_train_B16"]["fused"], tb["ae_train_B16"]["two_pass"]
    if not fused["bwd"] < two["bwd"]:
        bad("train_bytes.json", "fused bwd bytes not below two-pass")
    fp8 = tb["ae_train_fp8"]
    if not fp8["total"] < fp8["fp16_total"]:
        bad("train_bytes.json", "FP8 train bytes not below FP16")
    if fp8["engine_flops"] != tr["total"]:
        bad("train_bytes.json",
            "FP8 trace flops != FP16 train total (narrower storage drops "
            "bytes, never flops)")
    attn = tb["attn_fwd_B2_H4_S96_D16"]
    if not attn["kernel"]["bytes"] < attn["reference"]["bytes"]:
        bad("train_bytes.json",
            "attention kernel bytes not below the reference composition "
            "(the flash sweep must not round-trip the S x T score tensor)")
    if not attn["kernel"]["flops"] < attn["reference"]["flops"]:
        bad("train_bytes.json",
            "causal attention kernel flops not below the dense reference "
            "(skipped KV blocks must be excluded from the bill)")

    sv = _load(base_dir, "serve_bytes.json")
    try:
        from repro import configs            # deferred: needs jax
        from repro.serving import decode_step_kv_bytes
        for arch in ("yi-9b", "deepseek-moe-16b"):
            cfg = configs.get_reduced(arch)
            want16 = decode_step_kv_bytes(cfg, sv["lengths"])
            want8 = decode_step_kv_bytes(cfg, sv["lengths"],
                                         "float8_e4m3fn")
            if sv[arch]["fp16_bytes"] != want16:
                bad("serve_bytes.json",
                    f"{arch}: fp16_bytes {sv[arch]['fp16_bytes']} != "
                    f"analytic {want16}")
            if sv[arch]["fp8_bytes"] != want8:
                bad("serve_bytes.json",
                    f"{arch}: fp8_bytes {sv[arch]['fp8_bytes']} != "
                    f"analytic {want8}")
            if not sv[arch]["fp8_bytes"] < sv[arch]["fp16_bytes"]:
                bad("serve_bytes.json", f"{arch}: fp8 not below fp16")
    except ImportError as e:
        bad("serve_bytes.json", f"cannot recompute analytically: {e}")

    co = _load(base_dir, "collective_bytes.json")["collective_bytes"]
    n_params = co["fp32"] // 4
    if co["fp32"] != 4 * n_params:
        bad("collective_bytes.json", "fp32 bytes not 4 B/param")
    if co["fp16"] != 2 * n_params:
        bad("collective_bytes.json", "fp16 wire != 2 B/param of the fp32 "
            "wire's parameter count")
    for kind in ("fp8_e4m3", "int8"):
        if kind in co and not n_params < co[kind] < co["fp16"]:
            bad("collective_bytes.json",
                f"{kind} wire must be 1 B/param + per-leaf scales: "
                f"{n_params} < {co[kind]} < {co['fp16']} fails")
    return out


def validate_escape_manifest(path: str = DEFAULT_MANIFEST) -> List[Violation]:
    out: List[Violation] = []
    try:
        with open(path) as fh:
            m = json.load(fh)
    except (OSError, ValueError) as e:
        return [_v(path, 0, "manifest", f"unreadable manifest: {e}")]
    for entry in m.get("ast", []):
        f = entry.get("file", "")
        if not os.path.exists(os.path.join(_REPO_ROOT, f)):
            out.append(_v(path, 0, "manifest",
                          f"ast entry names missing file {f!r}"))
    for name, escapes in m.get("jaxpr", {}).items():
        for e in escapes:
            if "fingerprint" not in e or int(e.get("count", 0)) <= 0:
                out.append(_v(path, 0, "manifest",
                              f"jaxpr entry {name}: malformed {e!r}"))
    return out


# --------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------- #
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--manifest", default=DEFAULT_MANIFEST)
    ap.add_argument("--autotune-cache", action="append", default=[],
                    metavar="PATH", help="autotune cache JSON(s) to "
                    "validate (repeatable)")
    ap.add_argument("--no-artifacts", action="store_true",
                    help="AST rules only (skip baseline/cache validation)")
    args = ap.parse_args(argv)

    violations = lint_sources(manifest_path=args.manifest)
    violations += validate_escape_manifest(args.manifest)
    if not args.no_artifacts:
        violations += validate_baselines()
        for path in args.autotune_cache:
            violations += validate_autotune_cache(path)

    for v in violations:
        print(str(v), file=sys.stderr)
    if violations:
        print(f"[lint] FAIL — {len(violations)} violation(s)",
              file=sys.stderr)
        return 1
    print("[lint] OK — repo invariants and shipped artifacts hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
