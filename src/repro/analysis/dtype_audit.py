"""Precision-policy conformance over traced jaxprs.

The mixed-precision extension of the paper (arXiv:2301.03904) makes the
repro's precision story per-operand: storage dtypes (FP8/FP16) widen to
the policy's ``compute_dtype`` on load and accumulate in ``accum_dtype``
— never beyond.  Three things can silently violate that contract, and
all three are visible statically in a traced jaxpr:

* **fp64 anywhere** — nothing in the repo declares a float64 policy;
  any f64 value is an accidental promotion (a Python float leaking into
  a weak-typed op, a NumPy default) that doubles bytes on the affected
  path.
* **fp32 materialization off the accumulation path** — a ``dot_general``
  producing f32 is only conformant when some Engine policy observed in
  the same trace declares f32 as a compute/accum/output dtype (the
  router and attention-score policies do).  An f32 contraction with no
  such declaration is an escaped-precision GEMM.
* **FP8 operands reaching a non-capable backend** — an fp8-operand
  ``dot_general`` in the jaxpr means *someone* contracted raw FP8
  storage.  The Engine never does this on XLA (it widens to compute
  dtype around the dot; only backends declaring the
  ``"operand_dtypes"`` capability consume FP8 directly, inside their
  kernels where no outer ``dot_general`` exists).  Every such equation
  is therefore a conformance finding.

Findings carry the equation's primitive, dtypes, and call path; the
audit CLI (:mod:`repro.analysis.audit`) folds them into the
``static-gates`` report.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Set, Tuple

import jax.numpy as jnp
from jax import core as jcore

from repro.analysis import jaxpr_audit
from repro.core import engine
from repro.core import precision as prec

_F64 = ("float64", "complex128")
_FP8 = ("float8_e4m3fn", "float8_e5m2")


@dataclasses.dataclass(frozen=True)
class DtypeFinding:
    kind: str        # "fp64" | "fp32_materialization" | "fp8_uncovered"
    detail: str
    path: Tuple[str, ...]
    count: int = 1

    def describe(self) -> str:
        where = "/".join(self.path) or "<top>"
        return f"[{self.kind}] {self.detail} x{self.count} (at {where})"


def declared_dtypes(events: Sequence[engine.GemmEvent]) -> Set[str]:
    """Every dtype some Engine policy in the event stream declares —
    compute, accumulator, output, and per-operand storage slots."""
    out: Set[str] = set()
    for ev in events:
        p = ev.spec.policy
        out.update(str(jnp.dtype(d)) for d in (
            p.compute_dtype, p.accum_dtype, p.out_dtype,
            p.x_storage_dtype, p.w_storage_dtype, p.grad_storage_dtype))
    return out


def audit_dtypes(closed: jcore.ClosedJaxpr,
                 events: Sequence[engine.GemmEvent],
                 extra_allowed: Sequence[str] = (),
                 ) -> List[DtypeFinding]:
    """Run all three conformance checks over one traced jaxpr.

    ``extra_allowed`` admits additional f32-materialization dtypes for
    entry points with no engine events (pure-escape toy traces in
    tests)."""
    allowed = declared_dtypes(events) | set(extra_allowed)
    merged: Dict[Tuple[str, str, Tuple[str, ...]], int] = {}

    def add(kind: str, detail: str, path: Tuple[str, ...], count: int):
        key = (kind, detail, path)
        merged[key] = merged.get(key, 0) + count

    for eqn, mult, path, _unb in jaxpr_audit.iter_eqns(closed):
        name = eqn.primitive.name
        for ov in eqn.outvars:
            aval = getattr(ov, "aval", None)
            dt = str(getattr(aval, "dtype", ""))
            if dt in _F64:
                add("fp64", f"{name} -> {dt}", path, mult)
        if name != "dot_general":
            continue
        lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
        out_dt = str(eqn.outvars[0].aval.dtype)
        ldt, rdt = str(lhs.dtype), str(rhs.dtype)
        if ldt in _FP8 or rdt in _FP8:
            add("fp8_uncovered",
                f"dot_general {ldt}{list(lhs.shape)} x "
                f"{rdt}{list(rhs.shape)} contracts raw FP8 storage — only "
                f"backends declaring 'operand_dtypes' may consume FP8 "
                f"operands (and they do it in-kernel, not via dot_general)",
                path, mult)
        elif out_dt == "float32" and "float32" not in allowed:
            add("fp32_materialization",
                f"dot_general {ldt}{list(lhs.shape)} x "
                f"{rdt}{list(rhs.shape)} -> float32, but no Engine policy "
                f"in this trace declares an f32 compute/accum/output slot",
                path, mult)

    return sorted(
        (DtypeFinding(kind=k, detail=d, path=p, count=n)
         for (k, d, p), n in merged.items()),
        key=lambda f: (f.kind, f.detail))


def check_shipped_policies() -> List[str]:
    """Static invariants of every policy shipped in
    :mod:`repro.core.precision` — no trace required.  Returns a list of
    violation strings (empty = conformant)."""
    problems: List[str] = []
    for name in prec.known_policies():
        p = prec.resolve(name)
        for field in ("compute_dtype", "accum_dtype", "out_dtype",
                      "x_storage_dtype", "w_storage_dtype",
                      "grad_storage_dtype"):
            dt = jnp.dtype(getattr(p, field))
            if str(dt) in _F64:
                problems.append(f"policy {name!r}: {field} is {dt}")
        if (jnp.dtype(p.accum_dtype).itemsize
                < jnp.dtype(p.compute_dtype).itemsize):
            problems.append(
                f"policy {name!r}: accumulator {jnp.dtype(p.accum_dtype)} "
                f"narrower than compute {jnp.dtype(p.compute_dtype)}")
        if p.scaled:
            # FP8 storage needs an upcast-capable backend to exist
            capable = [b for b in engine.registered_backends()
                       if engine.backend_supports(b, "operand_dtypes")]
            if not capable:
                problems.append(
                    f"policy {name!r} declares FP8 storage but no "
                    f"registered backend supports 'operand_dtypes'")
    return problems
