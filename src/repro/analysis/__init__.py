"""Static analysis for the Engine's instrumentation contract.

Three layers, all runnable offline (no TPU):

* :mod:`repro.analysis.jaxpr_audit` — trace an entry point to a closed
  jaxpr, collect every ``dot_general`` (recursing through
  pjit/scan/while/remat/custom_vjp sub-jaxprs), and reconcile the multiset
  against the ``GemmEvent`` stream from the same trace.  Contractions not
  accounted by an Engine dispatch are *escaped GEMMs*.
* :mod:`repro.analysis.dtype_audit` — precision-policy conformance over
  the same jaxprs: fp64 anywhere, fp32 materialization off the declared
  accumulation path, FP8 operands reaching a backend without the
  ``"operand_dtypes"`` capability.
* :mod:`repro.analysis.lint` — AST-level repo invariants (no raw GEMMs in
  ``models/`` outside the manifest, ``os._exit`` confinement, frozen
  ``GemmSpec`` mutation, module-level mutable event collectors) plus
  static validation of shipped artifacts (autotune caches vs the VMEM
  budget, baseline JSONs vs the analytic formulas).

Known escapes live in the ratchet manifest
``benchmarks/baselines/engine_escapes.json`` — the count only goes down.
CLI entry points: ``python -m repro.analysis.audit`` and
``python -m repro.analysis.lint`` (both wired into the ``static-gates``
CI job).  See ``docs/static_analysis.md``.
"""

from repro.analysis.dtype_audit import DtypeFinding, audit_dtypes
from repro.analysis.entries import ENTRY_POINTS, get_entry
from repro.analysis.jaxpr_audit import (AuditResult, DotSite, collect_dots,
                                        reconcile, trace_entry)

__all__ = [
    "AuditResult", "DotSite", "DtypeFinding", "ENTRY_POINTS",
    "audit_dtypes", "collect_dots", "get_entry", "reconcile", "trace_entry",
]
