"""Launch layer: meshes, training/serving drivers, multi-pod dry-run."""
