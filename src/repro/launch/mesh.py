"""Production meshes.

Single pod  = 16 x 16 = 256 chips  ("data", "model").
Multi-pod   = 2 x 16 x 16 = 512 chips ("pod", "data", "model") — the "pod"
axis carries only data parallelism (gradient all-reduce) because inter-pod
links are the slowest tier; TP/EP never cross a pod boundary.

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

from typing import Tuple

from repro.runtime import compat

__all__ = ["make_production_mesh", "data_axes", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def data_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes present in this mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def make_host_mesh():
    """A 1-device mesh for CPU smoke tests (same axis names as single-pod)."""
    return compat.make_mesh((1, 1), ("data", "model"))
