"""Serving driver: sharded prefill + decode steps over the serving subsystem.

Decode shardings: KV caches shard over batch (DP axes) and, crucially, over
the *sequence* dimension on the model axis ("kv_seq" -> "model") — KV-head
counts (4-24) never divide a 16-way TP axis, so the cache's parallel dim at
32k-500k context is the sequence (DESIGN.md §5).

Generation routes through ``repro.serving`` (docs/serving.md): `generate`
is a thin fixed-batch client of the continuous-batching scheduler, and
``--sched`` runs the full Poisson loadgen sweep with the FP8 KV cache,
merging ``serve/*`` p50/p99 rows into ``BENCH_engine.json``:

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \\
        --batch 4 --prompt-len 32 --gen 16
    PYTHONPATH=src python -m repro.launch.serve --sched --arch yi-9b --reduced
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core import engine
from repro.models import transformer
from repro.runtime import sharding
from repro.serving import kv_cache as kv_lib
from repro.serving import loadgen as loadgen_lib
from repro.serving import scheduler as sched_lib
from repro.serving import specs as specs_lib

__all__ = [
    "serve_rules", "cache_spec_tree", "build_serve_step", "build_prefill",
    "make_sharded_serve_step", "generate", "main",
]


def serve_rules(base: Optional[sharding.Rules] = None) -> sharding.Rules:
    """Decode-time rules: shard the KV sequence over the model axis.

    KV-head counts (4-24) never divide the 16-way TP axis, so heads must be
    declared replicated *up front* — otherwise they'd claim the model axis in
    logical_spec and leave the sequence dim unsharded after sanitization."""
    base = base or sharding.Rules()
    return dataclasses.replace(
        base, serve_attention=True,
        overrides=base.overrides + (
            ("kv_heads", None),
            ("kv_seq", ("model",)),
        ))


def cache_spec_tree(cfg, rules, mesh, batch: int, max_len: int,
                    storage_dtype: Optional[str] = None):
    """Sanitized decode-cache PartitionSpecs (serving.specs is the source)."""
    return specs_lib.decode_cache_specs(
        cfg, rules, mesh, batch, max_len, storage_dtype=storage_dtype)[1]


def build_serve_step(cfg, rules: Optional[sharding.Rules]):
    def step(params, cache, tokens, pos):
        with sharding.use_rules(rules):
            return transformer.serve_step(params, cfg, tokens, cache, pos)
    return step


def build_prefill(cfg, rules: Optional[sharding.Rules], max_len: int):
    def pre(params, batch):
        with sharding.use_rules(rules):
            return transformer.prefill(params, cfg, batch, max_len)
    return pre


def make_sharded_serve_step(cfg, mesh, rules, *, batch: int, max_len: int,
                            donate: bool = True):
    rules = serve_rules(rules)
    step = build_serve_step(cfg, rules)
    pspec = transformer.param_specs(cfg, rules)
    pshape = transformer.abstract_params(cfg)
    pspec = jax.tree.map(
        lambda s, a: sharding.sanitize_spec(s, a.shape, mesh),
        pspec, pshape, is_leaf=lambda x: isinstance(x, P))
    cspec = cache_spec_tree(cfg, rules, mesh, batch, max_len)
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = dp[0] if len(dp) == 1 else dp
    tok_spec = P(dp, None) if batch % _axsize(mesh, dp) == 0 else P()
    ns = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P))
    jitted = jax.jit(
        step,
        in_shardings=(ns(pspec), ns(cspec), ns(tok_spec), None),
        out_shardings=(None, ns(cspec)),
        donate_argnums=(1,) if donate else (),
    )
    return jitted, pspec, cspec


def _axsize(mesh, name):
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= mesh.shape[a]
        return n
    return mesh.shape.get(name, 1) if hasattr(mesh.shape, "get") else mesh.shape[name]


# --------------------------------------------------------------------- #
# Generation: thin fixed-batch client of the scheduler
# --------------------------------------------------------------------- #
def generate(params, cfg, prompts: jax.Array, gen_len: int,
             rules: Optional[sharding.Rules] = None, *,
             storage_dtype: Optional[str] = None, return_state: bool = False):
    """prompts: (B, S) int32. Returns (B, S+gen_len) greedy continuations.

    Runs the serving scheduler with B slots and B simultaneous arrivals —
    every slot stays in lockstep, so this is the classic batched greedy
    loop, but with the scheduler's drain invariant: the final emitted
    token's KV is absorbed before eviction, so the returned cache (with
    ``return_state=True``: ``(seqs, cache, final_logits)``) is consistent
    with the emitted sequences — ``argmax(final_logits)`` is exactly the
    token a ``gen_len + 1`` run would emit next.  ``storage_dtype`` serves
    from the FP8 KV cache."""
    B, S = prompts.shape
    if gen_len < 1:
        raise ValueError("gen_len must be >= 1")
    scfg = sched_lib.SchedulerConfig(
        n_slots=B, max_len=S + gen_len, storage_dtype=storage_dtype)
    sched = sched_lib.Scheduler(params, cfg, scfg, rules=rules)
    pnp = np.asarray(prompts)
    sched.submit([
        sched_lib.Request(rid=i, arrival=0.0, prompt=pnp[i],
                          max_new_tokens=gen_len)
        for i in range(B)
    ])
    results = sched.run()
    seqs = jnp.asarray(np.concatenate(
        [pnp, np.array([r.tokens for r in results], np.int32)], axis=1))
    if return_state:
        final = np.stack([r.final_logits for r in results])
        return seqs, sched.cache, final
    return seqs


# --------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------- #
def _parse_inject(spec: str):
    """``MODE@STEP`` -> FailureInjector with a serving mode (docs/serving.md),
    e.g. ``nan_logits@2``, ``kv_corrupt@3``, ``prefill_crash@1``."""
    from repro.runtime.fault_tolerance import FailureInjector
    mode, _, at = spec.partition("@")
    if mode not in FailureInjector.SERVING_MODES or not at.isdigit():
        raise SystemExit(
            f"--inject wants MODE@STEP with MODE in "
            f"{FailureInjector.SERVING_MODES}, got {spec!r}")
    return FailureInjector(fail_at_step=int(at), mode=mode)


def _run_sched(cfg, params, args) -> None:
    if args.policy:
        # FP8 end to end: the decode GEMMs dispatch under the policy's
        # per-operand storage dtypes (MIXED_FP8_E4M3 by default), on top
        # of the FP8 KV cache selected by --storage
        cfg = dataclasses.replace(cfg, policy_name=args.policy)
    resilient = bool(args.inject or args.deadline or args.max_queue)
    audit = args.audit_every if args.audit_every is not None else \
        (1 if args.inject else 0)
    scfg = sched_lib.SchedulerConfig(
        n_slots=args.slots, max_len=args.prompt_len + args.gen + 4,
        storage_dtype=args.storage or None,
        max_queue=args.max_queue or None, audit_every=audit)
    rates = [float(r) for r in args.rates.split(",")]
    lc = loadgen_lib.LoadConfig(
        rate=rates[0], n_requests=args.requests,
        prompt_len=args.prompt_len, gen_len=args.gen, seed=args.seed,
        deadline_ticks=args.deadline or None, max_retries=args.retries)

    if args.instrument:
        # one sweep under instrumentation: the jit traces of the serving
        # path land here, tagged serve_prefill / serve_admit / serve_decode
        with engine.instrument() as events:
            sched = sched_lib.Scheduler(params, cfg, scfg)
            sched.submit(loadgen_lib.poisson_requests(cfg, lc))
            sched.run()
        for op, d in engine.summarize(events).items():
            print(f"[engine] {op}: calls={d['calls']} "
                  f"gflops={d['flops']/1e9:.3f} gbytes={d['bytes']/1e9:.3f}")
        print("[sched] tick queue pend active fill")
        for h in sched.health:
            print(f"[sched] {h['tick']:8.2f} {h['queue_depth']:5d} "
                  f"{h['pending']:4d} {h['active_slots']:6d} "
                  f"{h['batch_fill']:.2f}")
        for leaf, d in kv_lib.scale_health(sched.cache).items():
            print(f"[kv] {leaf}: max_scale={d['max_scale']:.3g} "
                  f"overflow={d['overflow_total']}")
        # one exactly-billed ragged decode step at the drained lengths
        lengths = [args.prompt_len + args.gen if i == 0 else 0
                   for i in range(scfg.n_slots)]
        ev = sched_lib.instrumented_decode_events(params, cfg, scfg, lengths)
        print(f"[kv] ragged decode step flops={engine.total_flops(ev)} "
              f"kv_bytes={kv_lib.decode_step_kv_bytes(cfg, [l for l in lengths if l], scfg.storage_dtype)}")

    rows = loadgen_lib.bench_rows(
        params, cfg, scfg, cfg.name, rates, lc)
    if resilient:
        # the SLO scenario: deadlines / bounded queue / injected fault at
        # the first offered rate — a fresh one-shot injector per run
        injector = _parse_inject(args.inject) if args.inject else None
        tag = f"slo_{injector.mode}" if injector else "slo"
        srows, m = loadgen_lib.slo_rows(
            params, cfg, scfg, cfg.name, lc, injector=injector, tag=tag)
        rows += srows
        print(f"[slo] goodput={m['slo_goodput']:.4f} "
              f"deadline_hit={m['deadline_hit_rate']:.3f} "
              f"finished={m['n_finished']}/{m['n_requests']} "
              f"retries={m['retries']} abandons={m['abandons']} "
              f"recoveries={m['slo_recoveries']:.0f} "
              f"shed={m['slo_shed']:.0f} expired={m['slo_expired']:.0f}")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    if args.json:
        loadgen_lib.merge_bench_json(args.json, rows)
        print(f"merged {len(rows)} serve/* rows into {args.json}")


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="yi-9b", choices=configs.ARCH_IDS)
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--full", dest="reduced", action="store_false")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--instrument", action="store_true",
                   help="trace the serving path under engine.instrument() "
                        "and print the GEMM summary; with --sched also the "
                        "per-step scheduler health (queue depth, slot "
                        "occupancy, batch fill) and KV scale state")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--sched", action="store_true",
                   help="run the continuous-batching scheduler + Poisson "
                        "loadgen sweep and merge serve/* rows into --json")
    p.add_argument("--slots", type=int, default=4,
                   help="--sched: decode slot pool size")
    p.add_argument("--requests", type=int, default=8,
                   help="--sched: requests per offered-load point")
    p.add_argument("--rates", default="0.25,1.0",
                   help="--sched: offered loads (requests/tick), comma-sep")
    p.add_argument("--storage", default="float8_e4m3fn",
                   help="--sched: KV cache storage dtype ('' for fp16)")
    p.add_argument("--policy", default="mixed_fp8_e4m3",
                   help="--sched: precision policy for the serve GEMMs "
                        "('' keeps the arch default)")
    p.add_argument("--json", default="BENCH_engine.json",
                   help="--sched: merge rows into this file ('' to skip)")
    p.add_argument("--inject", default="",
                   help="--sched: serving fault MODE@STEP "
                        "(nan_logits/kv_corrupt at the Nth decode step, "
                        "prefill_crash at the Nth prefill); adds the "
                        "serve/*/slo_* recovery rows")
    p.add_argument("--deadline", type=float, default=0.0,
                   help="--sched: per-request deadline budget in ticks "
                        "(0 = none); expired work is evicted")
    p.add_argument("--max-queue", type=int, default=0,
                   help="--sched: bounded admission queue (0 = unbounded); "
                        "overflow is rejected with retry_after")
    p.add_argument("--retries", type=int, default=2,
                   help="--sched: loadgen client retry budget per rejection")
    p.add_argument("--audit-every", type=int, default=None,
                   help="--sched: KV checksum audit cadence in decode steps "
                        "(default: 1 when --inject is set, else off)")
    args = p.parse_args(argv)

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    rng = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(rng, cfg)

    if args.sched:
        _run_sched(cfg, params, args)
        return

    prompts = jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)
    if args.instrument:
        max_len = args.prompt_len + args.gen
        cache_abs = jax.eval_shape(
            lambda: transformer.init_cache(cfg, args.batch, max_len))
        tok_abs = jax.ShapeDtypeStruct((args.batch, 1), jnp.int32)
        phases = {
            "prefill": lambda: jax.eval_shape(
                lambda p_, b_: transformer.prefill(p_, cfg, b_, max_len),
                params, {"inputs": prompts}),
            "decode": lambda: jax.eval_shape(
                lambda p_, c_, t_: transformer.serve_step(
                    p_, cfg, t_, c_, jnp.int32(args.prompt_len)),
                params, cache_abs, tok_abs),
        }
        for phase, trace in phases.items():
            with engine.instrument() as events:
                trace()
            for op, d in engine.summarize(events).items():
                print(f"[engine] {phase} {op}: calls={d['calls']} "
                      f"gflops={d['flops']/1e9:.3f} "
                      f"gbytes={d['bytes']/1e9:.3f}")
    t0 = time.perf_counter()
    seqs = generate(params, cfg, prompts, args.gen)
    jax.block_until_ready(seqs)
    dt = time.perf_counter() - t0
    tps = args.batch * args.gen / dt
    print(f"arch={cfg.name} batched-generate {seqs.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s)")
    print("sample:", np.asarray(seqs[0, args.prompt_len:]))


if __name__ == "__main__":
    main()
