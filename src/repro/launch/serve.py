"""Serving driver: sharded prefill + decode steps, batched greedy generation.

Decode shardings: KV caches shard over batch (DP axes) and, crucially, over
the *sequence* dimension on the model axis ("kv_seq" -> "model") — KV-head
counts (4-24) never divide a 16-way TP axis, so the cache's parallel dim at
32k-500k context is the sequence (DESIGN.md §5).

CLI (deliverable (b)): serve a reduced model with batched requests:

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \\
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core import engine
from repro.models import transformer
from repro.runtime import sharding

__all__ = [
    "serve_rules", "cache_spec_tree", "build_serve_step", "build_prefill",
    "make_sharded_serve_step", "generate", "main",
]


def serve_rules(base: Optional[sharding.Rules] = None) -> sharding.Rules:
    """Decode-time rules: shard the KV sequence over the model axis.

    KV-head counts (4-24) never divide the 16-way TP axis, so heads must be
    declared replicated *up front* — otherwise they'd claim the model axis in
    logical_spec and leave the sequence dim unsharded after sanitization."""
    base = base or sharding.Rules()
    return dataclasses.replace(
        base, serve_attention=True,
        overrides=base.overrides + (
            ("kv_heads", None),
            ("kv_seq", ("model",)),
        ))


def cache_spec_tree(cfg, rules, mesh, batch: int, max_len: int):
    axes = transformer.cache_axes(cfg)
    abstract = jax.eval_shape(
        lambda: transformer.init_cache(cfg, batch, max_len))
    spec = jax.tree.map(
        lambda ax: sharding.logical_spec(ax, rules),
        axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))
    return jax.tree.map(
        lambda s, a: sharding.sanitize_spec(s, a.shape, mesh),
        spec, abstract, is_leaf=lambda x: isinstance(x, P))


def build_serve_step(cfg, rules: Optional[sharding.Rules]):
    def step(params, cache, tokens, pos):
        with sharding.use_rules(rules):
            return transformer.serve_step(params, cfg, tokens, cache, pos)
    return step


def build_prefill(cfg, rules: Optional[sharding.Rules], max_len: int):
    def pre(params, batch):
        with sharding.use_rules(rules):
            return transformer.prefill(params, cfg, batch, max_len)
    return pre


def make_sharded_serve_step(cfg, mesh, rules, *, batch: int, max_len: int,
                            donate: bool = True):
    rules = serve_rules(rules)
    step = build_serve_step(cfg, rules)
    pspec = transformer.param_specs(cfg, rules)
    pshape = transformer.abstract_params(cfg)
    pspec = jax.tree.map(
        lambda s, a: sharding.sanitize_spec(s, a.shape, mesh),
        pspec, pshape, is_leaf=lambda x: isinstance(x, P))
    cspec = cache_spec_tree(cfg, rules, mesh, batch, max_len)
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = dp[0] if len(dp) == 1 else dp
    tok_spec = P(dp, None) if batch % _axsize(mesh, dp) == 0 else P()
    ns = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P))
    jitted = jax.jit(
        step,
        in_shardings=(ns(pspec), ns(cspec), ns(tok_spec), None),
        out_shardings=(None, ns(cspec)),
        donate_argnums=(1,) if donate else (),
    )
    return jitted, pspec, cspec


def _axsize(mesh, name):
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= mesh.shape[a]
        return n
    return mesh.shape.get(name, 1) if hasattr(mesh.shape, "get") else mesh.shape[name]


# --------------------------------------------------------------------- #
# Generation loop (greedy)
# --------------------------------------------------------------------- #
def generate(params, cfg, prompts: jax.Array, gen_len: int,
             rules: Optional[sharding.Rules] = None):
    """prompts: (B, S) int32. Returns (B, S+gen_len)."""
    B, S = prompts.shape
    max_len = S + gen_len
    pre = jax.jit(build_prefill(cfg, rules, max_len))
    step = jax.jit(build_serve_step(cfg, rules), donate_argnums=(1,))
    logits, cache = pre(params, {"inputs": prompts})
    out = [prompts]
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for i in range(gen_len):
        out.append(tok)
        if i == gen_len - 1:
            break
        logits, cache = step(params, cache, tok, jnp.int32(S + i))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="yi-9b", choices=configs.ARCH_IDS)
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--full", dest="reduced", action="store_false")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--instrument", action="store_true",
                   help="trace prefill + one decode step under "
                        "engine.instrument() and print the GEMM summary")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    rng = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(rng, cfg)
    prompts = jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)
    if args.instrument:
        max_len = args.prompt_len + args.gen
        cache_abs = jax.eval_shape(
            lambda: transformer.init_cache(cfg, args.batch, max_len))
        tok_abs = jax.ShapeDtypeStruct((args.batch, 1), jnp.int32)
        phases = {
            "prefill": lambda: jax.eval_shape(
                lambda p_, b_: transformer.prefill(p_, cfg, b_, max_len),
                params, {"inputs": prompts}),
            "decode": lambda: jax.eval_shape(
                lambda p_, c_, t_: transformer.serve_step(
                    p_, cfg, t_, c_, jnp.int32(args.prompt_len)),
                params, cache_abs, tok_abs),
        }
        for phase, trace in phases.items():
            with engine.instrument() as events:
                trace()
            for op, d in engine.summarize(events).items():
                print(f"[engine] {phase} {op}: calls={d['calls']} "
                      f"gflops={d['flops']/1e9:.3f} "
                      f"gbytes={d['bytes']/1e9:.3f}")
    t0 = time.perf_counter()
    seqs = generate(params, cfg, prompts, args.gen)
    jax.block_until_ready(seqs)
    dt = time.perf_counter() - t0
    tps = args.batch * args.gen / dt
    print(f"arch={cfg.name} batched-generate {seqs.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s)")
    print("sample:", np.asarray(seqs[0, args.prompt_len:]))


if __name__ == "__main__":
    main()
