"""Multi-pod dry-run: AOT lower+compile every (arch x shape x mesh) cell.

THE FIRST TWO LINES must run before any other import — jax locks the device
count at first init, and the production meshes need 512 host devices."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core import engine
from repro.launch import mesh as mesh_lib
from repro.launch import serve as serve_lib
from repro.launch import train as train_lib
from repro.models import transformer
from repro.optim import AdamW
from repro.roofline import analysis as roofline_lib
from repro.runtime import compat, sharding
from repro.serving import specs as serving_specs

__all__ = ["dryrun_cell", "main"]


def _ns(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def _sanitize(tree_spec, tree_abs, mesh):
    return jax.tree.map(
        lambda s, a: sharding.sanitize_spec(s, a.shape, mesh),
        tree_spec, tree_abs, is_leaf=lambda x: isinstance(x, P))


def dryrun_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    fsdp: bool = True,
    sequence_parallel: bool = False,
    remat: Optional[str] = None,
    policy: Optional[str] = None,
    q_chunk: Optional[int] = None,
    ce_chunk: Optional[int] = None,
    cast_params: bool = False,
    grad_accum: int = 1,
    moe_impl: Optional[str] = None,
    ssm_chunk: Optional[int] = None,
    donate: bool = True,
    verbose: bool = True,
) -> dict:
    """Lower + compile one cell; return the roofline/memory record."""
    cfg = configs.get(arch)
    overrides = {}
    if remat is not None:
        overrides["remat"] = remat
    if policy is not None:
        overrides["policy_name"] = policy
    if q_chunk is not None:
        overrides["q_chunk"] = q_chunk
    if ce_chunk is not None:
        overrides["ce_chunk"] = ce_chunk
    if moe_impl is not None:
        overrides["moe_impl"] = moe_impl
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if ssm_chunk is not None and cfg.ssm is not None:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk=ssm_chunk))
    shape = configs.SHAPES[shape_name]
    if shape.kind != "train":
        # serving stores parameters in the serving compute precision
        cfg = dataclasses.replace(
            cfg, param_dtype=jnp.dtype(cfg.policy.compute_dtype).name)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    n_dev = mesh.devices.size

    if shape.kind == "decode" and shape.name == "long_500k" \
            and not cfg.supports_long_context_decode:
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "skipped": "pure full-attention arch: quadratic 500k decode "
                       "(DESIGN.md §5)",
        }

    t0 = time.time()
    specs = configs.input_specs(cfg, shape)

    # every GEMM dispatched while the cell is traced lands in gemm_events;
    # the roofline consumes them instead of re-deriving shapes by hand
    with compat.set_mesh(mesh), engine.instrument() as gemm_events:
        if shape.kind == "train":
            rules = sharding.Rules(fsdp=fsdp, sequence_parallel=sequence_parallel)
            opt = AdamW(lr=1e-4)
            step = train_lib.build_train_step(cfg, opt, rules,
                                              cast_params=cast_params,
                                              grad_accum=grad_accum)
            state_abs = jax.eval_shape(
                lambda: train_lib.init_state(jax.random.PRNGKey(0), cfg, opt))
            sspec = train_lib.state_specs(cfg, rules, mesh, opt)
            bspec = _sanitize(train_lib.batch_specs(cfg, mesh), specs, mesh)
            jitted = jax.jit(
                step,
                in_shardings=(_ns(mesh, sspec), _ns(mesh, bspec)),
                out_shardings=(_ns(mesh, sspec), None),
                donate_argnums=(0,) if donate else (),
            )
            lowered = jitted.lower(state_abs, specs)
        elif shape.kind == "prefill":
            rules = serve_lib.serve_rules(
                sharding.Rules(sequence_parallel=sequence_parallel))
            pre = serve_lib.build_prefill(cfg, rules, max_len=shape.seq_len)
            pabs = transformer.abstract_params(cfg)
            pspec = _sanitize(transformer.param_specs(cfg, rules), pabs, mesh)
            bspec = _sanitize(train_lib.batch_specs(cfg, mesh), specs, mesh)
            bspec = {k: bspec[k] for k in specs}  # prefill has no labels
            jitted = jax.jit(
                pre,
                in_shardings=(_ns(mesh, pspec), _ns(mesh, bspec)),
            )
            lowered = jitted.lower(pabs, specs)
        else:  # decode
            rules = serve_lib.serve_rules(sharding.Rules())
            step = serve_lib.build_serve_step(cfg, rules)
            pabs = transformer.abstract_params(cfg)
            pspec = _sanitize(transformer.param_specs(cfg, rules), pabs, mesh)
            # one source of truth with serve.cache_spec_tree (serving.specs)
            cabs, cspec = serving_specs.decode_cache_specs(
                cfg, rules, mesh, shape.global_batch, shape.seq_len)
            dp = mesh_lib.data_axes(mesh)
            tok_spec = (P(dp, None)
                        if shape.global_batch % _prod(mesh, dp) == 0 else P())
            jitted = jax.jit(
                step,
                in_shardings=(_ns(mesh, pspec), _ns(mesh, cspec),
                              NamedSharding(mesh, tok_spec), None),
                out_shardings=(None, _ns(mesh, cspec)),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(
                pabs, cabs, specs["inputs"],
                jax.ShapeDtypeStruct((), jnp.int32))

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    report = roofline_lib.roofline(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        n_devices=n_dev,
        model_flops_val=roofline_lib.model_flops(cfg, shape), hlo_text=hlo,
        gemm_events=gemm_events)
    rec = report.to_json()
    rec.update(
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        hlo_bytes=len(hlo),
        fsdp=fsdp,
        sequence_parallel=sequence_parallel,
        remat=cfg.remat,
        policy=cfg.policy_name,
        ce_chunk=cfg.ce_chunk,
        cast_params=cast_params,
        grad_accum=grad_accum,
        per_device_hbm_gib=round(
            (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3),
    )
    if verbose:
        print(f"[{mesh_name}] {arch} x {shape_name}: "
              f"mem={rec['per_device_hbm_gib']:.2f} GiB/dev  "
              f"compute={report.compute_s*1e3:.2f}ms "
              f"memory={report.memory_s*1e3:.2f}ms "
              f"collective={report.collective_s*1e3:.2f}ms "
              f"-> {report.dominant}-bound  "
              f"(useful={report.useful_flops_ratio:.2f}, "
              f"roofline={report.roofline_fraction:.2%}; "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s)", flush=True)
    return rec


def _prod(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="all",
                   help="arch id or 'all'")
    p.add_argument("--shape", default="all",
                   choices=["all"] + list(configs.SHAPES))
    p.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--tag", default="baseline")
    p.add_argument("--no-fsdp", dest="fsdp", action="store_false")
    p.add_argument("--sp", dest="sequence_parallel", action="store_true")
    p.add_argument("--remat", default=None, choices=[None, "none", "dots", "full"])
    p.add_argument("--policy", default=None)
    p.add_argument("--q-chunk", type=int, default=None)
    p.add_argument("--ce-chunk", type=int, default=None)
    p.add_argument("--cast-params", action="store_true")
    p.add_argument("--grad-accum", type=int, default=1)
    p.add_argument("--moe-impl", default=None, choices=[None, "gspmd", "shard_map"])
    p.add_argument("--ssm-chunk", type=int, default=None)
    args = p.parse_args(argv)

    archs = list(configs.ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(configs.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                fname = os.path.join(
                    args.out, f"{args.tag}__{arch}__{shape_name}__{mesh_name}.json")
                try:
                    rec = dryrun_cell(
                        arch, shape_name, multi_pod=multi, fsdp=args.fsdp,
                        sequence_parallel=args.sequence_parallel,
                        remat=args.remat, policy=args.policy,
                        q_chunk=args.q_chunk, ce_chunk=args.ce_chunk,
                        cast_params=args.cast_params,
                        grad_accum=args.grad_accum,
                        moe_impl=args.moe_impl,
                        ssm_chunk=args.ssm_chunk)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape_name, mesh_name, repr(e)))
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "error": repr(e)}
                rec["tag"] = args.tag
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=1)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f_ in failures:
            print("  ", f_)
        raise SystemExit(1)
    print("\nall dry-run cells compiled OK")


if __name__ == "__main__":
    main()
