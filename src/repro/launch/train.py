"""Training driver: sharded train step + fault-tolerant loop.

``build_train_step`` assembles the paper's full recipe:
  * forward/backward with every GEMM on the RedMulE engine,
  * optional dynamic FP16 loss scaling (the paper's precision regime),
  * gradient clipping, AdamW, MoE aux losses,
  * non-finite-step skipping (scale halves, params untouched).

``make_sharded_train_step`` binds it to a mesh with logical-axis shardings
(DP/TP/EP/SP(/FSDP)) and donates the state buffers.

CLI (end-to-end driver, deliverable (b)): train a reduced or full arch on
synthetic data with checkpoint/restart:

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \\
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

``--arch ae`` trains the paper's TinyMLPerf AutoEncoder use case (§III-B)
in pure FP16 instead of an LM arch.  With ``--instrument``, one step is
traced under ``engine.instrument()`` first and the per-op GEMM summary is
printed with the fwd/bwd split — the Engine ops carry a custom VJP, so the
backward GEMMs (``matmul_dx`` / ``matmul_dw``) are counted too (the CI
train gate pins these totals against
``benchmarks/baselines/train_flops.json``).

``--compress {none,fp16,int8,fp8,fp8_e4m3,fp8_e5m2}`` (optionally with
``--dp-procs N``) switches to the data-parallel step with a compressed
gradient wire: each shard's gradients cross the all-reduce at the wire
width with fp32 error feedback kept locally (FP8 wires use
``Fp8ScaleState`` delayed scaling).  ``--instrument`` then also prints the
per-step collective wire bytes vs the fp32 wire, and — when a
``--ckpt-dir`` fault-tolerant loop ran — the goodput breakdown
(useful/wall, time lost to restarts, recomputed steps; the ft-gates CI job
floor-gates the injected-failure scenario).  Simulate N processes on one
machine with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""

from __future__ import annotations

import argparse
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core import engine
from repro.data import Prefetcher, SyntheticLM
from repro.models import transformer
from repro.optim import (AdamW, Compressor, OptState, adjust,
                         clip_by_global_norm, init_scale, scale_loss,
                         unscale_and_check)
from repro.runtime import sharding
from repro.runtime.fault_tolerance import TrainLoop

__all__ = [
    "TrainState", "build_train_step", "state_specs", "batch_specs",
    "make_sharded_train_step", "init_state", "main",
]


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    scale: Any          # LossScaleState or () when disabled


def init_state(rng, cfg, opt, *, use_scale: bool = False) -> TrainState:
    params = transformer.init_params(rng, cfg)
    return TrainState(
        params=params,
        opt=opt.init(params),
        scale=init_scale() if use_scale else (),
    )


def build_train_step(
    cfg,
    opt,
    rules: Optional[sharding.Rules],
    *,
    use_scale: bool = False,
    clip_norm: float = 1.0,
    cast_params: bool = False,
    grad_accum: int = 1,
):
    """(state, batch) -> (state, metrics); pure, jit-able, donate-able.

    cast_params: cast fp32 master params to the compute dtype at step entry —
    the FSDP all-gathers and gradient reductions then run on 16-bit wire
    (half the collective bytes; grads re-widen at the cast boundary).

    grad_accum: split the batch into microbatches and accumulate fp32 grads
    across a scan — the per-pass activation working set shrinks by the
    accumulation factor (the standard fit-big-models lever)."""

    def step(state: TrainState, batch) -> Tuple[TrainState, dict]:
        with sharding.use_rules(rules):
            def lf(p, b):
                if cast_params:
                    p = jax.tree.map(
                        lambda x: x.astype(cfg.policy.compute_dtype)
                        if jnp.issubdtype(x.dtype, jnp.floating) else x, p)
                loss, metrics = transformer.loss_fn(p, cfg, b)
                if use_scale:
                    loss = scale_loss(loss, state.scale)
                return loss, metrics

            if grad_accum > 1:
                mb = jax.tree.map(
                    lambda x: x.reshape(
                        grad_accum, x.shape[0] // grad_accum, *x.shape[1:]),
                    batch)

                def mb_body(carry, b):
                    g_acc, m_acc = carry
                    (_, m), g = jax.value_and_grad(
                        lf, has_aux=True)(state.params, b)
                    g_acc = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), g_acc, g)
                    m_acc = jax.tree.map(lambda a, x: a + x, m_acc, m)
                    return (g_acc, m_acc), 0

                g0 = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), state.params)
                with engine.paused():  # shape probe: don't double-count GEMMs
                    m0 = jax.eval_shape(
                        lambda: jax.value_and_grad(lf, has_aux=True)(
                            state.params, jax.tree.map(lambda x: x[0], mb))[0][1])
                m0 = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), m0)
                with engine.repeat(grad_accum):  # microbatch scan
                    (grads, metrics), _ = jax.lax.scan(mb_body, (g0, m0), mb)
                inv = 1.0 / grad_accum
                grads = jax.tree.map(lambda g: g * inv, grads)
                metrics = jax.tree.map(lambda x: x * inv, metrics)
            else:
                (_, metrics), grads = jax.value_and_grad(
                    lf, has_aux=True)(state.params, batch)

            if use_scale:
                grads, finite = unscale_and_check(grads, state.scale)
                new_scale = adjust(state.scale, finite)
            else:
                finite = jnp.bool_(True)
                new_scale = state.scale

            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            updates, new_opt = opt.update(grads, state.opt, state.params)

            # skip the update entirely on overflow (params AND moments)
            def apply(_):
                return opt.apply(state.params, updates), new_opt

            def keep(_):
                return state.params, state.opt

            new_params, new_opt = jax.lax.cond(finite, apply, keep, None)
            metrics = dict(metrics)
            metrics["grad_norm"] = gnorm
            if use_scale:
                metrics["loss_scale"] = new_scale.scale
                metrics["finite"] = finite.astype(jnp.float32)
        return TrainState(new_params, new_opt, new_scale), metrics

    return step


def build_compressed_dp_train_step(
    cfg, opt, mesh, compressor, *, clip_norm: float = 1.0,
):
    """Pure-DP train step with gradient compression on the wire.

    The per-shard gradient is computed inside shard_map over the data axes
    (params replicated, batch sharded); the cross-shard mean runs on the
    compressor's wire dtype (fp16/int8 + error feedback) instead of fp32 —
    the distributed-optimization trick for slow inter-pod links.

    The error-feedback state (fp32 residual + fp8 scale windows) is
    genuinely per-host — each host accumulates the residual of *its* batch
    shard — so it carries an explicit leading host axis, sharded over the
    data axes.  Storing it "replicated" would silently checkpoint only
    host 0's residual (shard_map's ``check_rep=False`` stamps the
    out-spec without verifying it), breaking bit-identical kill/resume.

    Returns (step, init_fn) where state = (TrainState, ef_hosts).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as Pspec

    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    ndev = 1
    for a in dp:
        ndev *= mesh.shape[a]

    def init_fn(rng):
        state = init_state(rng, cfg, opt)
        ef = compressor.init(state.params)
        if ef is not None:
            ef = jax.tree.map(lambda l: jnp.stack([l] * ndev), ef)
        return state, ef

    def step(state_and_ef, batch):
        state, ef_hosts = state_and_ef

        def local(params, ef_h, batch_l):
            loss, grads = jax.value_and_grad(
                lambda p: transformer.loss_fn(p, cfg, batch_l)[0])(params)
            # strip this host's slot off the leading host axis, compress,
            # and put the new residual back in the same slot
            ef_l = (jax.tree.map(lambda x: x[0], ef_h)
                    if ef_h is not None else None)
            wire, ef2 = compressor.compress(grads, ef_l)
            mean_g = compressor.psum_wire(wire, dp)
            ef2_h = (jax.tree.map(lambda x: x[None], ef2)
                     if ef2 is not None else None)
            loss = jax.lax.pmean(loss, dp)
            return mean_g, ef2_h, loss

        pspec = jax.tree.map(lambda _: Pspec(), state.params)
        espec = jax.tree.map(lambda _: Pspec(dp), ef_hosts)
        bspec = jax.tree.map(lambda _: Pspec(dp), batch)
        mean_g, ef_hosts, loss = shard_map(
            local, mesh,
            in_specs=(pspec, espec, bspec),
            out_specs=(pspec, espec, Pspec()),
            check_rep=False,
        )(state.params, ef_hosts, batch)

        mean_g, gnorm = clip_by_global_norm(mean_g, clip_norm)
        updates, new_opt = opt.update(mean_g, state.opt, state.params)
        params = opt.apply(state.params, updates)
        return (TrainState(params, new_opt, state.scale), ef_hosts), {
            "loss": loss, "grad_norm": gnorm}

    return step, init_fn


# --------------------------------------------------------------------- #
# Sharding plumbing
# --------------------------------------------------------------------- #
def _sanitize_tree(spec_tree, shape_tree, mesh):
    return jax.tree.map(
        lambda s, a: sharding.sanitize_spec(s, a.shape, mesh),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def state_specs(cfg, rules, mesh, opt, *, use_scale: bool = False) -> TrainState:
    pspec = transformer.param_specs(cfg, rules)
    pshape = transformer.abstract_params(cfg)
    pspec = _sanitize_tree(pspec, pshape, mesh)
    scalar = P()
    opt_spec = OptState(
        step=scalar,
        mu=jax.tree.map(lambda s: s, pspec),
        nu=jax.tree.map(lambda s: s, pspec),
    )
    scale_spec = (
        jax.tree.map(lambda _: scalar, init_scale()) if use_scale else ()
    )
    return TrainState(params=pspec, opt=opt_spec, scale=scale_spec)


def batch_specs(cfg, mesh) -> dict:
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = dp[0] if len(dp) == 1 else dp
    if cfg.input_mode == "embeddings":
        return {"embeddings": P(dp, None, None), "labels": P(dp, None)}
    return {"inputs": P(dp, None), "labels": P(dp, None)}


def make_sharded_train_step(
    cfg, mesh, rules, opt, *, use_scale: bool = False, donate: bool = True,
):
    step = build_train_step(cfg, opt, rules, use_scale=use_scale)
    sspec = state_specs(cfg, rules, mesh, opt, use_scale=use_scale)
    bspec = batch_specs(cfg, mesh)
    ns = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P))
    return jax.jit(
        step,
        in_shardings=(ns(sspec), ns(bspec)),
        out_shardings=(ns(sspec), None),
        donate_argnums=(0,) if donate else (),
    ), sspec


# --------------------------------------------------------------------- #
# CLI end-to-end driver
# --------------------------------------------------------------------- #
def _print_goodput(out):
    g = out.get("goodput")
    if not g:
        return
    print(f"[ft] goodput={g['goodput']:.3f} "
          f"useful={g['useful_time']:.2f}s wall={g['wall_time']:.2f}s "
          f"lost_to_restart={g['time_lost_to_restart']:.2f}s "
          f"recomputed_steps={g['recomputed_steps']} "
          f"restarts={g['restarts']}")


def _compressed_dp_main(args, cfg):
    """Data-parallel training with a compressed gradient wire (and the
    fault-tolerant loop when --ckpt-dir is set)."""
    import json

    from repro.optim import Compressor
    from repro.runtime import compat
    from repro.runtime.elastic import _digest
    from repro.runtime.fault_tolerance import FailureInjector

    ndev = args.dp_procs or len(jax.devices())
    if len(jax.devices()) < ndev:
        raise SystemExit(
            f"--dp-procs {ndev} but jax sees {len(jax.devices())} devices; "
            "simulate with XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={ndev}")
    if args.batch % ndev:
        raise SystemExit(f"--batch {args.batch} must divide by the "
                         f"{ndev}-way data mesh")
    mesh = compat.make_mesh((ndev,), ("data",))
    comp = Compressor(args.compress)
    opt = AdamW(lr=args.lr, warmup_steps=10)
    step, init_fn = build_compressed_dp_train_step(cfg, opt, mesh, comp)
    state = init_fn(jax.random.PRNGKey(args.seed))
    ds = SyntheticLM(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
        embed_dim=cfg.d_model if cfg.input_mode == "embeddings" else 0)

    if args.instrument:
        wire = comp.wire_bytes(state[0].params)
        full = Compressor("none").wire_bytes(state[0].params)
        print(f"[ft] gradient wire: kind={comp.kind} "
              f"bytes/step={wire} fp32_bytes/step={full} "
              f"ratio={full / max(wire, 1):.2f}x")

    # Canonical placement — the bit-identical-resume invariant (mirrors
    # runtime/elastic.py).  A resumed process's first step receives host
    # (np) arrays from the checkpoint while a clean run's steps receive
    # the previous step's device outputs; pinned in_/out_shardings force
    # every step of every incarnation through one executable and one
    # placement: TrainState replicated, EF sharded over the host axis,
    # batch sharded over data.
    rep = NamedSharding(mesh, P())
    dp_sh = NamedSharding(mesh, P("data"))
    ts0, ef0 = jax.eval_shape(init_fn, jax.random.PRNGKey(args.seed))
    state_sh = (jax.tree.map(lambda _: rep, ts0),
                jax.tree.map(lambda _: dp_sh, ef0))
    jstep = jax.jit(step, in_shardings=(state_sh, dp_sh),
                    out_shardings=(state_sh, rep))
    injector = None
    if args.fail_step is not None:
        injector = FailureInjector(fail_at_step=args.fail_step,
                                   mode=args.fail_mode)
    final_state, final_loss = state, float("nan")
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, keep=2)
        loop = TrainLoop(jstep, ckpt, save_every=args.save_every,
                         injector=injector)
        out = loop.run(state, ds.batch, args.steps)
        final_state = out["final_state"]
        final_loss = float(out["history"][-1]["loss"])
        print(f"final loss: {final_loss:.4f} "
              f"(stragglers: {out['straggler_steps']})")
        if args.instrument:
            _print_goodput(out)
    else:
        metrics = None
        for i in range(args.steps):
            state, metrics = jstep(state, ds.batch(i))
            if i % 10 == 0:
                print(f"[{i}] loss={float(metrics['loss']):.4f}")
        final_state, final_loss = state, float(metrics["loss"])
        print(f"final loss: {final_loss:.4f}")
    if args.result:
        res = {
            "digest": _digest(final_state[0].params),
            "ef_digest": _digest(final_state[1]),
            "opt_digest": _digest(final_state[0].opt),
            "loss": final_loss,
        }
        with open(args.result, "w") as f:
            json.dump(res, f, indent=1)
        print(f"[ft] result digests -> {args.result}")


def _print_instrument_summary(events):
    """Per-op engine summary + the fwd/bwd GEMM flop split of one step."""
    from repro.roofline import analysis

    for op, d in engine.summarize(events).items():
        print(f"[engine] {op}: calls={d['calls']} "
              f"gflops={d['flops']/1e9:.3f} gbytes={d['bytes']/1e9:.3f}")
    split = analysis.flops_by_direction(events)
    bsplit = analysis.bytes_by_direction(events)
    fwd, bwd = split["fwd"], split["bwd"]
    ratio = (fwd + bwd) / fwd if fwd else 0.0
    print(f"[engine] fwd_gflops={fwd/1e9:.3f} bwd_gflops={bwd/1e9:.3f} "
          f"train/inference={ratio:.2f}x")
    print(f"[engine] fwd_gbytes={bsplit['fwd']/1e9:.4f} "
          f"bwd_gbytes={bsplit['bwd']/1e9:.4f}")


def _ae_main(args):
    """The paper's §III-B use case on the CLI: AE training in pure FP16
    (default) or any registered precision policy — ``--policy
    mixed_fp8_e4m3`` trains with FP8 storage + per-tensor scales (the
    mixed-precision RedMulE regime; GEMM bytes drop, flops don't)."""
    from repro.core import precision as prec
    from repro.data import SyntheticAE
    from repro.models import autoencoder

    policy = prec.resolve(args.policy or "paper_fp16")
    params = autoencoder.init_ae(jax.random.PRNGKey(args.seed))
    opt = AdamW(lr=args.lr, warmup_steps=0)
    opt_state = opt.init(params)
    ds = SyntheticAE(batch=args.batch, seed=args.seed)

    def step(p_, s_, x):
        (loss, _), g = jax.value_and_grad(
            lambda q: autoencoder.ae_loss(q, x, policy=policy),
            has_aux=True)(p_)
        g, _ = clip_by_global_norm(g, 1.0)
        u, s_ = opt.update(g, s_, p_)
        return opt.apply(p_, u), s_, loss

    if args.instrument:
        with engine.instrument() as events:
            jax.eval_shape(step, params, opt_state,
                           jax.ShapeDtypeStruct((args.batch, ds.dim),
                                                jnp.float32))
        _print_instrument_summary(events)

    step = jax.jit(step, donate_argnums=(0, 1))
    loss = None
    for i in range(args.steps):
        x = jnp.asarray(ds.sample(i))
        params, opt_state, loss = step(params, opt_state, x)
        if i % 10 == 0:
            print(f"[{i}] mse={float(loss):.4f}")
    print(f"final mse: {float(loss):.4f}")


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="qwen3-1.7b",
                   choices=(*configs.ARCH_IDS, "ae"))
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--full", dest="reduced", action="store_false")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--save-every", type=int, default=50)
    p.add_argument("--fp16-scale", action="store_true",
                   help="pure-FP16 compute with dynamic loss scaling")
    p.add_argument("--policy", default=None,
                   help="precision policy for --arch ae (default "
                        "paper_fp16; mixed_fp8_e4m3 / mixed_fp8_e5m2 "
                        "train with FP8 storage + per-tensor scales)")
    p.add_argument("--instrument", action="store_true",
                   help="trace one step under engine.instrument() and print "
                        "the per-op GEMM flop/byte summary before training "
                        "(plus wire bytes / goodput on the DP paths)")
    p.add_argument("--compress", default="none",
                   choices=("none", "fp16", "int8", "fp8", "fp8_e4m3",
                            "fp8_e5m2"),
                   help="gradient all-reduce wire for data-parallel "
                        "training (fp8* = E4M3/E5M2 with delayed scaling "
                        "+ error feedback)")
    p.add_argument("--dp-procs", type=int, default=0,
                   help="data-parallel width; 0 = all visible devices "
                        "(simulate N on one host with XLA_FLAGS="
                        "--xla_force_host_platform_device_count=N)")
    p.add_argument("--fail-step", type=int, default=None,
                   help="inject a failure at this step on the compressed-DP "
                        "path (kill/resume digest testing; needs --ckpt-dir)")
    p.add_argument("--fail-mode", default="die",
                   choices=("raise", "die", "sigterm", "ckpt_crash"),
                   help="failure kind for --fail-step")
    p.add_argument("--result", default="",
                   help="write final params/EF/opt sha256 digests + loss as "
                        "JSON (compressed-DP path; bit-identical-resume "
                        "verification)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    if args.arch == "ae":
        return _ae_main(args)

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    if args.compress != "none" or args.dp_procs:
        return _compressed_dp_main(args, cfg)
    if args.fp16_scale:
        import dataclasses
        cfg = dataclasses.replace(cfg, policy_name="tpu_fp16")
    opt = AdamW(lr=args.lr, warmup_steps=10)
    step = build_train_step(cfg, opt, rules=None, use_scale=args.fp16_scale)
    step = jax.jit(step, donate_argnums=(0,))

    state = init_state(jax.random.PRNGKey(args.seed), cfg, opt,
                       use_scale=args.fp16_scale)
    ds = SyntheticLM(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
        embed_dim=cfg.d_model if cfg.input_mode == "embeddings" else 0)
    batches = Prefetcher(iter(ds), depth=2)

    if args.instrument:
        # abstract trace only — events are emitted at trace time; the
        # value_and_grad inside the step makes the custom-VJP backward
        # GEMMs (matmul_dx / matmul_dw) part of the trace too
        with engine.instrument() as events:
            jax.eval_shape(step, state, ds.batch(0))
        _print_instrument_summary(events)

    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, keep=2)
        loop = TrainLoop(step, ckpt, save_every=args.save_every)
        # step-indexed batches: the stream replays exactly after a restart
        out = loop.run(state, ds.batch, args.steps)
        print(f"final loss: {out['history'][-1]['loss']:.4f} "
              f"(stragglers: {out['straggler_steps']})")
        if args.instrument:
            _print_goodput(out)
    else:
        for i in range(args.steps):
            state, metrics = step(state, next(batches))
            if i % 10 == 0:
                print(f"[{i}] loss={float(metrics['loss']):.4f}")
        print(f"final loss: {float(metrics['loss']):.4f}")
    batches.close()


if __name__ == "__main__":
    main()
