"""Deterministic, sharded, prefetching synthetic data pipeline.

Every batch is a pure function of (seed, step, host) — so a restarted or
re-sharded job replays the exact token stream (the fault-tolerance story
depends on this), and no host ever materializes another host's shard.

Token streams are Zipf-distributed with document boundaries (EOS every
~doc_len tokens) so losses behave like real text rather than uniform noise.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator

import numpy as np

__all__ = ["SyntheticLM", "SyntheticAE", "Prefetcher"]


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    doc_len: int = 512
    embed_dim: int = 0          # >0 -> "embeddings" mode (audio/vlm stubs)
    num_hosts: int = 1
    host_id: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        b, s = self.local_batch, self.seq_len
        # Zipf tokens (clipped to vocab); EOS=0 at document boundaries
        toks = rng.zipf(1.2, size=(b, s + 1)).astype(np.int64)
        toks = np.minimum(toks, self.vocab_size - 1).astype(np.int32)
        doc_off = rng.integers(0, self.doc_len, size=(b, 1))
        pos = np.arange(s + 1)[None, :]
        toks = np.where((pos + doc_off) % self.doc_len == 0, 0, toks)
        out: Dict[str, np.ndarray] = {
            "inputs": toks[:, :-1],
            "labels": toks[:, 1:].copy(),
        }
        if self.embed_dim:
            emb = rng.standard_normal((b, s, self.embed_dim), dtype=np.float32)
            out = {"embeddings": emb, "labels": out["labels"]}
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class SyntheticAE:
    """ToyADMOS-like mel-frame windows for the AutoEncoder use case."""

    batch: int
    dim: int = 640
    seed: int = 0

    def sample(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        # smooth spectra: low-rank structure + noise, normalized
        base = rng.standard_normal((self.batch, 8)) @ rng.standard_normal((8, self.dim))
        x = base + 0.1 * rng.standard_normal((self.batch, self.dim))
        return (x / np.maximum(np.abs(x).max(axis=1, keepdims=True), 1e-6)).astype(np.float32)


class Prefetcher:
    """Background-thread prefetch (double-buffered host pipeline)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def run():
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)
            self._q.put(None)

        self._t = threading.Thread(target=run, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
