"""Data pipeline: deterministic sharded synthetic streams + prefetch."""

from repro.data.pipeline import Prefetcher, SyntheticAE, SyntheticLM

__all__ = ["SyntheticLM", "SyntheticAE", "Prefetcher"]
