"""Serving subsystem: the first layer above the Engine that models
production traffic — continuous-batching scheduler, FP8 KV cache
admission + byte accounting, Poisson load generation (docs/serving.md).
"""

from repro.serving.kv_cache import (cache_size_bytes, decode_step_kv_bytes,
                                    insert_slot, is_fp8_cache, scale_health)
from repro.serving.loadgen import (LoadConfig, bench_rows, merge_bench_json,
                                   poisson_requests, run_load)
from repro.serving.scheduler import (Request, RequestResult, Scheduler,
                                     SchedulerConfig,
                                     instrumented_decode_events)
from repro.serving.specs import decode_cache_specs

__all__ = [
    "cache_size_bytes", "decode_step_kv_bytes", "insert_slot",
    "is_fp8_cache", "scale_health",
    "LoadConfig", "bench_rows", "merge_bench_json", "poisson_requests",
    "run_load",
    "Request", "RequestResult", "Scheduler", "SchedulerConfig",
    "instrumented_decode_events",
    "decode_cache_specs",
]
