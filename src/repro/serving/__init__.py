"""Serving subsystem: the first layer above the Engine that models
production traffic — continuous-batching scheduler, FP8 KV cache
admission + byte accounting, Poisson load generation, and the
resilience layer (deadlines, admission control, fault recovery, serve
goodput) (docs/serving.md).
"""

from repro.serving.kv_cache import (cache_size_bytes, corrupt_slot_rows,
                                    decode_step_kv_bytes, insert_slot,
                                    is_fp8_cache, scale_health,
                                    slot_checksum)
from repro.serving.loadgen import (LoadConfig, bench_rows, merge_bench_json,
                                   poisson_requests, run_load, slo_rows)
from repro.serving.resilience import (Rejection, ServeGoodputMeter,
                                      ShedPolicy, SlotGuard)
from repro.serving.scheduler import (Request, RequestResult, Scheduler,
                                     SchedulerConfig,
                                     instrumented_decode_events)
from repro.serving.specs import decode_cache_specs

__all__ = [
    "cache_size_bytes", "corrupt_slot_rows", "decode_step_kv_bytes",
    "insert_slot", "is_fp8_cache", "scale_health", "slot_checksum",
    "LoadConfig", "bench_rows", "merge_bench_json", "poisson_requests",
    "run_load", "slo_rows",
    "Rejection", "ServeGoodputMeter", "ShedPolicy", "SlotGuard",
    "Request", "RequestResult", "Scheduler", "SchedulerConfig",
    "instrumented_decode_events",
    "decode_cache_specs",
]
