"""FP8 KV cache: slot admission + analytic byte accounting for serving.

The quantized cache itself lives in the model layer
(:func:`repro.models.transformer.init_cache` with ``storage_dtype``, the
attention step dequantizes on read and requantizes on write under the
per-head delayed scales).  This module owns the two serving-side pieces:

* :func:`insert_slot` — write a freshly prefilled single-request cache
  (batch == 1) into one slot of the pooled decode cache.  FP8 pools are
  merged *wide* and requantized under the ratcheted pool scale, so the
  admission is just another delayed-scaling observation: rows quantized
  under an older (smaller) scale can only shrink on requantization,
  never clip.

* Analytic KV byte accounting (:func:`decode_step_kv_bytes`,
  :func:`cache_size_bytes`) — the Engine's GemmEvents price the GEMM
  operand streams in the *compute* dtype (the datapath is binary16
  either way, which is also why flops are identical across storage
  dtypes), so cache-storage traffic needs its own model.  These feed the
  ``benchmarks/baselines/serve_bytes.json`` CI gate.

* Slot integrity (:func:`slot_checksum`, :func:`corrupt_slot_rows`) —
  CRC32 over one slot's *stored* KV rows (raw bytes, so FP8 and FP16
  pools are covered uniformly), used by the scheduler's audit cadence
  to detect bit-flipped cache state, plus the matching deterministic
  corruptor the fault injector uses (docs/serving.md failure model).
"""

from __future__ import annotations

import zlib
from typing import Any, Dict, Iterator, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import precision as prec
from repro.models import attention

CacheTree = Dict[str, Any]

__all__ = [
    "is_fp8_cache", "insert_slot", "n_cache_layers", "token_elems",
    "n_scale_elems", "storage_width", "decode_step_kv_bytes",
    "cache_size_bytes", "scale_health", "iter_kv_leaves",
    "slot_checksum", "corrupt_slot_rows",
]


# --------------------------------------------------------------------- #
# Slot admission
# --------------------------------------------------------------------- #
def is_fp8_cache(cache: CacheTree) -> bool:
    sub = cache.get("layers", cache.get("layer0", {}))
    return "k_scale" in sub or "ckv_scale" in sub


def _gqa_bcast(scale: jax.Array) -> jax.Array:
    # (..., Hkv) -> (..., 1, Hkv, 1, 1), aligned with k/v (..., B, Hkv, T, hd)
    return scale[..., None, :, None, None]


def _mla_bcast(scale: jax.Array) -> jax.Array:
    # (...,) -> (..., 1, 1, 1), aligned with ckv/kr (..., B, T, r)
    return scale[..., None, None, None]


def _gqa_reduce(ndim: int, bax: int):
    # keep (leading layers..., Hkv): fold batch, seq, head_dim
    return (bax, *range(bax + 2, ndim))


def _mla_reduce(ndim: int, bax: int):
    # per-tensor scales: fold everything from the batch axis on
    return tuple(range(bax, ndim))


def _insert_leaf(pool_sub, single_sub, name, slot, dtype, bcast, tail, reduce_of):
    fp8 = f"{name}_scale" in pool_sub
    p, s = pool_sub[name], single_sub[name]
    if fp8:
        pw = prec.dequantize_fp8(
            p, bcast(pool_sub[f"{name}_scale"]["scale"]), dtype)
        sw = prec.dequantize_fp8(
            s, bcast(single_sub[f"{name}_scale"]["scale"]), dtype)
    else:
        pw, sw = p, s
    bax = pw.ndim - tail
    merged = jax.lax.dynamic_update_slice_in_dim(
        pw, sw.astype(pw.dtype), slot, axis=bax)
    if not fp8:
        return {name: merged}
    sc2, applied = attention._refresh_scale(
        pool_sub[f"{name}_scale"], merged, reduce_of(merged.ndim, bax))
    q, _ = prec.quantize_fp8(merged, p.dtype, scale=bcast(applied))
    return {name: q, f"{name}_scale": sc2}


def insert_slot(pool: CacheTree, single: CacheTree, slot,
                dtype=jnp.float16) -> CacheTree:
    """Write a single-request cache (batch == 1) into ``slot`` of the pool.

    ``slot`` may be traced (one jit trace serves every slot).  Supports the
    attn/moe cache trees (gqa and MLA subtrees, stacked or not); FP8 pools
    dequantize both sides to ``dtype``, merge, refresh the pool's delayed
    scales with the merged amax, and requantize under the ratcheted scale.
    """
    def sub(ps, ss):
        if "k" in ps:
            out = {}
            for name in ("k", "v"):
                out.update(_insert_leaf(
                    ps, ss, name, slot, dtype, _gqa_bcast, 4, _gqa_reduce))
            return out
        if "ckv" in ps:
            out = {}
            for name in ("ckv", "kr"):
                out.update(_insert_leaf(
                    ps, ss, name, slot, dtype, _mla_bcast, 3, _mla_reduce))
            return out
        raise ValueError(
            "slot insertion supports attn/moe (gqa/MLA) caches only")

    return {key: sub(pool[key], single[key]) for key in pool}


# --------------------------------------------------------------------- #
# Slot integrity: checksums + deterministic corruption
# --------------------------------------------------------------------- #
def iter_kv_leaves(cache: CacheTree) -> Iterator[Tuple[str, str, Any, int]]:
    """Yield ``(key, name, leaf, batch_axis)`` for every KV data leaf.

    Covers the gqa (``k``/``v``, trailing ``(B, Hkv, T, hd)``) and MLA
    (``ckv``/``kr``, trailing ``(B, T, r)``) subtrees, stacked or not;
    scale-state leaves are skipped.  The sequence axis is always the
    second-to-last axis of the leaf.
    """
    for key, sub in cache.items():
        if not isinstance(sub, dict):
            continue
        if "k" in sub:
            names, tail = ("k", "v"), 4
        elif "ckv" in sub:
            names, tail = ("ckv", "kr"), 3
        else:
            continue
        for name in names:
            leaf = sub[name]
            yield key, name, leaf, leaf.ndim - tail


def slot_checksum(cache: CacheTree, slot: int, length: int) -> int:
    """CRC32 over the raw stored bytes of one slot's first ``length`` rows.

    Hashes the *storage* representation (FP8 codes or FP16 halves) of
    every cached layer, so any bit flip in the slot's valid rows changes
    the digest.  Pool-wide scale state is deliberately excluded: under
    ratcheted delayed scaling an unrelated slot's admission may requantize
    the whole pool, which is why the scheduler re-arms guards after every
    cache mutation rather than only at insert.
    """
    crc = 0
    for _key, _name, leaf, bax in iter_kv_leaves(cache):
        arr = np.asarray(leaf)
        rows = np.take(arr, int(slot), axis=bax)[..., :int(length), :]
        crc = zlib.crc32(np.ascontiguousarray(rows).tobytes(), crc)
    return crc


def corrupt_slot_rows(cache: CacheTree, slot: int,
                      rows: Sequence[int]) -> CacheTree:
    """Bit-flip the stored bytes of ``rows`` in one slot (fault injection).

    Deterministic (XOR ``0xFF`` on every byte of the named rows across
    all cached layers), dtype-agnostic, and confined to ``slot`` — the
    matching :func:`slot_checksum` audit must flag exactly this slot and
    no co-resident one.  Returns a new cache tree; scale state is left
    untouched (real corruption hits the payload, and detection must not
    depend on the corruptor being polite).
    """
    idx = np.asarray(sorted({int(r) for r in rows}), np.intp)

    def flip(leaf, bax):
        arr = np.array(leaf)  # host copy we can mutate in place
        sel: list = [slice(None)] * arr.ndim
        sel[bax] = int(slot)
        slot_view = arr[tuple(sel)]
        row_sel: list = [slice(None)] * slot_view.ndim
        row_sel[-2] = idx
        chunk = np.ascontiguousarray(slot_view[tuple(row_sel)])
        flipped = (chunk.view(np.uint8) ^ np.uint8(0xFF)).view(chunk.dtype)
        slot_view[tuple(row_sel)] = flipped
        return jnp.asarray(arr)

    out: CacheTree = {}
    flipped_leaves = {(k, n): flip(leaf, bax)
                      for k, n, leaf, bax in iter_kv_leaves(cache)}
    for key, sub in cache.items():
        if not isinstance(sub, dict):
            out[key] = sub
            continue
        out[key] = {name: flipped_leaves.get((key, name), leaf)
                    for name, leaf in sub.items()}
    return out


# --------------------------------------------------------------------- #
# Analytic byte accounting
# --------------------------------------------------------------------- #
def n_cache_layers(cfg) -> int:
    """Number of attention caches in the tree (mirror of ``init_cache``)."""
    if cfg.block_kind == "attn":
        return cfg.n_layers
    if cfg.block_kind == "moe":
        return 1 + (cfg.n_layers - cfg.moe.first_dense)
    raise ValueError(
        f"serving byte accounting supports attn/moe, not {cfg.block_kind!r}")


def token_elems(cfg) -> int:
    """KV-cache elements appended per token, summed across cached layers."""
    if cfg.mla:
        per = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
    else:
        per = 2 * cfg.n_kv_heads * cfg.head_dim
    return n_cache_layers(cfg) * per


def n_scale_elems(cfg) -> int:
    """Delayed-scale scalars across the tree (k+v per-head, or 2 per-tensor)."""
    per = 2 if cfg.mla else 2 * cfg.n_kv_heads
    return n_cache_layers(cfg) * per


def storage_width(cfg, storage_dtype=None) -> int:
    return jnp.dtype(storage_dtype or cfg.policy.compute_dtype).itemsize


def decode_step_kv_bytes(cfg, lengths: Sequence[int],
                         storage_dtype: Optional[str] = None) -> int:
    """Semantic KV traffic of one continuous-batching decode step.

    Each active slot with ``l`` tokens already cached reads its merged
    ``l + 1`` rows (history plus the freshly appended one) and writes 1
    new row, all at the storage width; an FP8 cache adds the f32 scale
    vectors' round-trip (read for dequant, write-back of the refreshed
    delayed scale).  This prices what a serving memory system *moves* —
    not the CPU emulation's whole-tensor requantize — and since the
    datapath dequantizes to the compute dtype before the GEMMs, flops
    are identical across storage dtypes: FP8 vs FP16 at equal lengths
    is a pure byte ratio.
    """
    w = storage_width(cfg, storage_dtype)
    rows = sum(int(l) + 2 for l in lengths)  # (l + 1) reads + 1 write
    data = w * token_elems(cfg) * rows
    if storage_dtype is None:
        return data
    return data + 2 * 4 * n_scale_elems(cfg)  # f32 scale read + write


def cache_size_bytes(cfg, batch: int, max_len: int,
                     storage_dtype: Optional[str] = None) -> int:
    """Resident bytes of ``init_cache``'s output (data + scale-state leaves)."""
    w = storage_width(cfg, storage_dtype)
    data = w * token_elems(cfg) * batch * max_len
    if storage_dtype is None:
        return data
    # scale + amax_history + overflow_count per quantized tensor (4 B each)
    state = n_scale_elems(cfg) * (1 + attention.SCALE_HISTORY + 1) * 4
    return data + state


def scale_health(cache: CacheTree) -> Dict[str, Dict[str, float]]:
    """Max applied scale + total overflow count per quantized cache leaf."""
    out: Dict[str, Dict[str, float]] = {}
    for key, sub in cache.items():
        for name in ("k", "v", "ckv", "kr"):
            sc = sub.get(f"{name}_scale") if isinstance(sub, dict) else None
            if sc is None:
                continue
            out[f"{key}/{name}"] = {
                "max_scale": float(jnp.max(sc["scale"])),
                "overflow_total": int(jnp.sum(sc["overflow_count"])),
            }
    return out
