"""FP8 KV cache: slot admission + analytic byte accounting for serving.

The quantized cache itself lives in the model layer
(:func:`repro.models.transformer.init_cache` with ``storage_dtype``, the
attention step dequantizes on read and requantizes on write under the
per-head delayed scales).  This module owns the two serving-side pieces:

* :func:`insert_slot` — write a freshly prefilled single-request cache
  (batch == 1) into one slot of the pooled decode cache.  FP8 pools are
  merged *wide* and requantized under the ratcheted pool scale, so the
  admission is just another delayed-scaling observation: rows quantized
  under an older (smaller) scale can only shrink on requantization,
  never clip.

* Analytic KV byte accounting (:func:`decode_step_kv_bytes`,
  :func:`cache_size_bytes`) — the Engine's GemmEvents price the GEMM
  operand streams in the *compute* dtype (the datapath is binary16
  either way, which is also why flops are identical across storage
  dtypes), so cache-storage traffic needs its own model.  These feed the
  ``benchmarks/baselines/serve_bytes.json`` CI gate.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import precision as prec
from repro.models import attention

CacheTree = Dict[str, Any]

__all__ = [
    "is_fp8_cache", "insert_slot", "n_cache_layers", "token_elems",
    "n_scale_elems", "storage_width", "decode_step_kv_bytes",
    "cache_size_bytes", "scale_health",
]


# --------------------------------------------------------------------- #
# Slot admission
# --------------------------------------------------------------------- #
def is_fp8_cache(cache: CacheTree) -> bool:
    sub = cache.get("layers", cache.get("layer0", {}))
    return "k_scale" in sub or "ckv_scale" in sub


def _gqa_bcast(scale: jax.Array) -> jax.Array:
    # (..., Hkv) -> (..., 1, Hkv, 1, 1), aligned with k/v (..., B, Hkv, T, hd)
    return scale[..., None, :, None, None]


def _mla_bcast(scale: jax.Array) -> jax.Array:
    # (...,) -> (..., 1, 1, 1), aligned with ckv/kr (..., B, T, r)
    return scale[..., None, None, None]


def _gqa_reduce(ndim: int, bax: int):
    # keep (leading layers..., Hkv): fold batch, seq, head_dim
    return (bax, *range(bax + 2, ndim))


def _mla_reduce(ndim: int, bax: int):
    # per-tensor scales: fold everything from the batch axis on
    return tuple(range(bax, ndim))


def _insert_leaf(pool_sub, single_sub, name, slot, dtype, bcast, tail, reduce_of):
    fp8 = f"{name}_scale" in pool_sub
    p, s = pool_sub[name], single_sub[name]
    if fp8:
        pw = prec.dequantize_fp8(
            p, bcast(pool_sub[f"{name}_scale"]["scale"]), dtype)
        sw = prec.dequantize_fp8(
            s, bcast(single_sub[f"{name}_scale"]["scale"]), dtype)
    else:
        pw, sw = p, s
    bax = pw.ndim - tail
    merged = jax.lax.dynamic_update_slice_in_dim(
        pw, sw.astype(pw.dtype), slot, axis=bax)
    if not fp8:
        return {name: merged}
    sc2, applied = attention._refresh_scale(
        pool_sub[f"{name}_scale"], merged, reduce_of(merged.ndim, bax))
    q, _ = prec.quantize_fp8(merged, p.dtype, scale=bcast(applied))
    return {name: q, f"{name}_scale": sc2}


def insert_slot(pool: CacheTree, single: CacheTree, slot,
                dtype=jnp.float16) -> CacheTree:
    """Write a single-request cache (batch == 1) into ``slot`` of the pool.

    ``slot`` may be traced (one jit trace serves every slot).  Supports the
    attn/moe cache trees (gqa and MLA subtrees, stacked or not); FP8 pools
    dequantize both sides to ``dtype``, merge, refresh the pool's delayed
    scales with the merged amax, and requantize under the ratcheted scale.
    """
    def sub(ps, ss):
        if "k" in ps:
            out = {}
            for name in ("k", "v"):
                out.update(_insert_leaf(
                    ps, ss, name, slot, dtype, _gqa_bcast, 4, _gqa_reduce))
            return out
        if "ckv" in ps:
            out = {}
            for name in ("ckv", "kr"):
                out.update(_insert_leaf(
                    ps, ss, name, slot, dtype, _mla_bcast, 3, _mla_reduce))
            return out
        raise ValueError(
            "slot insertion supports attn/moe (gqa/MLA) caches only")

    return {key: sub(pool[key], single[key]) for key in pool}


# --------------------------------------------------------------------- #
# Analytic byte accounting
# --------------------------------------------------------------------- #
def n_cache_layers(cfg) -> int:
    """Number of attention caches in the tree (mirror of ``init_cache``)."""
    if cfg.block_kind == "attn":
        return cfg.n_layers
    if cfg.block_kind == "moe":
        return 1 + (cfg.n_layers - cfg.moe.first_dense)
    raise ValueError(
        f"serving byte accounting supports attn/moe, not {cfg.block_kind!r}")


def token_elems(cfg) -> int:
    """KV-cache elements appended per token, summed across cached layers."""
    if cfg.mla:
        per = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
    else:
        per = 2 * cfg.n_kv_heads * cfg.head_dim
    return n_cache_layers(cfg) * per


def n_scale_elems(cfg) -> int:
    """Delayed-scale scalars across the tree (k+v per-head, or 2 per-tensor)."""
    per = 2 if cfg.mla else 2 * cfg.n_kv_heads
    return n_cache_layers(cfg) * per


def storage_width(cfg, storage_dtype=None) -> int:
    return jnp.dtype(storage_dtype or cfg.policy.compute_dtype).itemsize


def decode_step_kv_bytes(cfg, lengths: Sequence[int],
                         storage_dtype: Optional[str] = None) -> int:
    """Semantic KV traffic of one continuous-batching decode step.

    Each active slot with ``l`` tokens already cached reads its merged
    ``l + 1`` rows (history plus the freshly appended one) and writes 1
    new row, all at the storage width; an FP8 cache adds the f32 scale
    vectors' round-trip (read for dequant, write-back of the refreshed
    delayed scale).  This prices what a serving memory system *moves* —
    not the CPU emulation's whole-tensor requantize — and since the
    datapath dequantizes to the compute dtype before the GEMMs, flops
    are identical across storage dtypes: FP8 vs FP16 at equal lengths
    is a pure byte ratio.
    """
    w = storage_width(cfg, storage_dtype)
    rows = sum(int(l) + 2 for l in lengths)  # (l + 1) reads + 1 write
    data = w * token_elems(cfg) * rows
    if storage_dtype is None:
        return data
    return data + 2 * 4 * n_scale_elems(cfg)  # f32 scale read + write


def cache_size_bytes(cfg, batch: int, max_len: int,
                     storage_dtype: Optional[str] = None) -> int:
    """Resident bytes of ``init_cache``'s output (data + scale-state leaves)."""
    w = storage_width(cfg, storage_dtype)
    data = w * token_elems(cfg) * batch * max_len
    if storage_dtype is None:
        return data
    # scale + amax_history + overflow_count per quantized tensor (4 B each)
    state = n_scale_elems(cfg) * (1 + attention.SCALE_HISTORY + 1) * 4
    return data + state


def scale_health(cache: CacheTree) -> Dict[str, Dict[str, float]]:
    """Max applied scale + total overflow count per quantized cache leaf."""
    out: Dict[str, Dict[str, float]] = {}
    for key, sub in cache.items():
        for name in ("k", "v", "ckv", "kr"):
            sc = sub.get(f"{name}_scale") if isinstance(sub, dict) else None
            if sc is None:
                continue
            out[f"{key}/{name}"] = {
                "max_scale": float(jnp.max(sc["scale"])),
                "overflow_total": int(jnp.sum(sc["overflow_count"])),
            }
    return out
