"""Continuous-batching request scheduler over the Engine decode path.

State machine (docs/serving.md): requests enter a FIFO **admission
queue** stamped with arrival ticks; a free decode **slot** triggers a
single-request **prefill** (batch 1, the request's actual prompt length)
whose cache is inserted into the pooled decode cache at that slot; all
occupied slots then advance together through batched **decode** steps
with per-slot positions and per-slot kv lengths — the ragged
``grouped_matmul``/``valid_rows`` path bills exactly the valid rows, so
a half-empty batch is visibly half-billed.  A sequence that has emitted
its budget **drains**: one final step absorbs its last token's KV (the
cache-consistency invariant ``generate`` relies on), then the slot frees
for the next queued request mid-flight.

Time is a virtual clock: one tick per batched decode step,
``prefill_ticks`` per prefill.  Everything host-side is deterministic —
FIFO by ``(arrival, rid)``, lowest free slot wins, greedy argmax decode —
so a seeded arrival trace pins the full admit/prefill/finish event log.

Resilience layer (docs/serving.md failure model): requests may carry a
``deadline_ticks`` budget — expired work is evicted whether queued or
mid-decode (the drain invariant makes mid-flight eviction safe: the
cache is always consistent with the emitted sequence, so freeing the
slot never poisons the pool).  ``SchedulerConfig.max_queue`` bounds the
admission queue, rejecting overflow with a structured
:class:`~repro.serving.resilience.Rejection` carrying a ``retry_after``
backpressure hint, and an optional
:class:`~repro.serving.resilience.ShedPolicy` deterministically drops
deadline-infeasible / lowest-priority queued work under overload.  A
:class:`~repro.runtime.fault_tolerance.FailureInjector` with a serving
mode exercises the detectors: a per-step NaN/inf guard on decode logits
and per-slot KV checksums audited every ``audit_every`` decode steps.
Recovery quarantines the poisoned slot and rebuilds its cache by
re-prefilling ``prompt + emitted_tokens`` — sufficient by the drain
invariant, and bit-identical on FP16 because the decode-built cache
equals the full-prefill cache bitwise (pinned by
``test_generate_cache_consistent_with_emitted_sequence``).  Recovery
overlaps the virtual clock (co-resident ticks are unaffected); its cost
is billed as waste slot-ticks in the
:class:`~repro.serving.resilience.ServeGoodputMeter`.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.models import transformer
from repro.runtime import sharding
from repro.serving import kv_cache, resilience

__all__ = [
    "Request", "SchedulerConfig", "RequestResult", "Scheduler",
    "instrumented_decode_events",
]


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    arrival: float          # ticks
    prompt: np.ndarray      # (P,) int32 token ids
    max_new_tokens: int
    deadline_ticks: Optional[float] = None  # budget relative to arrival
    priority: int = 0       # higher survives load shedding longer

    @property
    def deadline(self) -> Optional[float]:
        if self.deadline_ticks is None:
            return None
        return self.arrival + self.deadline_ticks


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    n_slots: int = 4
    max_len: int = 64
    storage_dtype: Optional[str] = None  # e.g. "float8_e4m3fn" (FP8 KV cache)
    prefill_ticks: float = 1.0
    max_queue: Optional[int] = None      # bounded admission; None = unbounded
    audit_every: int = 0                 # KV checksum cadence; 0 = off
    shed: Optional[resilience.ShedPolicy] = None


@dataclasses.dataclass
class RequestResult:
    rid: int
    arrival: float
    first_token_tick: Optional[float] = None
    finish_tick: Optional[float] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    final_logits: Optional[np.ndarray] = None  # P(next token | full sequence)
    status: str = "pending"  # pending|finished|rejected|expired|shed

    @property
    def ttft(self) -> float:
        if self.first_token_tick is None:
            return float("nan")
        return self.first_token_tick - self.arrival

    @property
    def tokens_per_tick(self) -> float:
        if self.finish_tick is None:
            return float("nan")
        return len(self.tokens) / max(self.finish_tick - self.arrival, 1e-9)


@dataclasses.dataclass
class _Slot:
    rid: int
    pos: int        # next cache write position == rows currently valid
    emitted: int    # tokens emitted so far
    fed: int        # emitted tokens whose KV has been absorbed
    max_new: int
    last_token: int
    prompt: np.ndarray = dataclasses.field(default_factory=lambda: np.zeros(0))
    deadline: Optional[float] = None    # absolute tick
    priority: int = 0


class Scheduler:
    """FIFO admission → per-request prefill → pooled continuous decode."""

    def __init__(self, params, cfg, scfg: SchedulerConfig,
                 rules: Optional[sharding.Rules] = None,
                 injector=None):
        if cfg.block_kind not in ("attn", "moe"):
            raise ValueError(
                f"the serving scheduler drives attn/moe decode caches, "
                f"not {cfg.block_kind!r}")
        if scfg.n_slots < 1:
            raise ValueError("need at least one decode slot")
        if (injector is not None and injector.mode == "kv_corrupt"
                and scfg.audit_every < 1):
            raise ValueError(
                "kv_corrupt injection needs audit_every >= 1 — silent "
                "corruption with the checksum audit off is undetectable")
        self.params, self.cfg, self.scfg = params, cfg, scfg
        self.rules = rules
        self.injector = injector
        self.clock = 0.0
        self.decode_steps = 0
        self.prefill_count = 0
        self.compute_dtype = cfg.policy.compute_dtype
        self.cache = transformer.init_cache(
            cfg, scfg.n_slots, scfg.max_len, dtype=self.compute_dtype,
            storage_dtype=scfg.storage_dtype)
        self.slots: List[Optional[_Slot]] = [None] * scfg.n_slots
        self.pending: List[Request] = []       # submitted, arrival in future
        self.queue: deque = deque()            # admitted, waiting for a slot
        self.trace: List[Tuple] = []           # (event, tick, rid, ...)
        self.health: List[Dict[str, float]] = []
        self.results: Dict[int, RequestResult] = {}
        self.rejections: List[resilience.Rejection] = []
        self.guards: Dict[int, resilience.SlotGuard] = {}
        self.goodput = resilience.ServeGoodputMeter(n_slots=scfg.n_slots)
        self._prefills: Dict[int, Any] = {}
        self._recover_prefills: Dict[int, Any] = {}

        def _decode(params_, cache_, tokens_, pos_, sizes_):
            with sharding.use_rules(rules), engine.op_scope("serve_decode"):
                return transformer.serve_step(
                    params_, cfg, tokens_, cache_, pos_,
                    kv_group_sizes=sizes_)

        def _insert(pool_, single_, slot_):
            with engine.op_scope("serve_admit"):
                return kv_cache.insert_slot(
                    pool_, single_, slot_, self.compute_dtype)

        def _recover_decode(params_, cache_, tokens_, pos_, sizes_):
            # batch-1 replay of the poisoned step over the rebuilt cache
            with sharding.use_rules(rules), engine.op_scope("serve_recover"):
                return transformer.serve_step(
                    params_, cfg, tokens_, cache_, pos_,
                    kv_group_sizes=sizes_)

        def _recover_insert(pool_, single_, slot_):
            with engine.op_scope("serve_recover"):
                return kv_cache.insert_slot(
                    pool_, single_, slot_, self.compute_dtype)

        self._decode = jax.jit(_decode, donate_argnums=(1,))
        self._insert = jax.jit(_insert, donate_argnums=(0,))
        self._recover_decode = jax.jit(_recover_decode)
        self._recover_insert = jax.jit(_recover_insert, donate_argnums=(0,))

    # ----------------------------------------------------------------- #
    # Admission
    # ----------------------------------------------------------------- #
    def _reject(self, r: Request, reason: str,
                retry_after: Optional[float]) -> None:
        self.rejections.append(resilience.Rejection(
            rid=r.rid, tick=self.clock, reason=reason,
            retry_after=retry_after))
        self.results[r.rid] = RequestResult(
            rid=r.rid, arrival=r.arrival, status="rejected")
        self.goodput.on_reject()
        self.trace.append(("reject", self.clock, r.rid, reason))

    def submit(self, requests: Sequence[Request]) -> None:
        """Validate and enqueue; an invalid request is rejected per-request
        (structured ``Rejection``, ``retry_after=None`` — retrying cannot
        help) and never aborts the rest of the batch."""
        accepted = []
        for r in requests:
            if r.max_new_tokens < 1:
                self._reject(r, "invalid", None)
                continue
            if len(r.prompt) + r.max_new_tokens > self.scfg.max_len:
                self._reject(r, "oversized", None)
                continue
            self.results[r.rid] = RequestResult(rid=r.rid, arrival=r.arrival)
            accepted.append(r)
        self.pending.extend(accepted)
        self.pending.sort(key=lambda r: (r.arrival, r.rid))

    def _expire(self, r: Request, where: str) -> None:
        res = self.results[r.rid]
        res.status = "expired"
        self.goodput.on_expire(0)
        self.trace.append(("expire", self.clock, r.rid, where))

    def _admit(self) -> None:
        while self.pending and self.pending[0].arrival <= self.clock:
            r = self.pending.pop(0)
            if r.deadline is not None and self.clock >= r.deadline:
                self._expire(r, "pending")
                continue
            if self.scfg.max_queue is not None:
                # free slots count toward capacity: _start drains the queue
                # into them this very step, so only truly waiting work is
                # held against the bound
                cap = self.scfg.max_queue + sum(
                    1 for s in self.slots if s is None)
                if len(self.queue) >= cap:
                    self._reject(r, "queue_full", resilience.retry_after_hint(
                        len(self.queue), self.scfg.prefill_ticks))
                    continue
            self.queue.append(r)
            self.trace.append(("admit", self.clock, r.rid))

    def _shed(self) -> None:
        # runs after _start: only work still *waiting* once the free slots
        # were handed out is candidate shed material
        if self.scfg.shed is None or not self.queue:
            return
        victims = self.scfg.shed.select_shed(
            list(self.queue), self.clock, self.scfg.prefill_ticks)
        if not victims:
            return
        vids = {r.rid for r in victims}
        self.queue = deque(r for r in self.queue if r.rid not in vids)
        for r in sorted(victims, key=lambda v: v.rid):
            res = self.results[r.rid]
            res.status = "shed"
            self.goodput.on_shed()
            self.trace.append(("shed", self.clock, r.rid))

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _evict_expired(self) -> None:
        """Deadline enforcement: drop expired work, queued or mid-decode.

        Mid-flight eviction is safe under the drain invariant — the slot's
        cache rows always equal ``prompt + emitted[:fed]``, so freeing it
        leaves the pool consistent; tokens already emitted are billed as
        waste."""
        for i, s in enumerate(self.slots):
            if s is None or s.deadline is None or self.clock < s.deadline:
                continue
            res = self.results[s.rid]
            res.status = "expired"
            self.goodput.on_expire(len(res.tokens))
            self.trace.append(("evict", self.clock, s.rid, i))
            self.slots[i] = None
            self.guards.pop(i, None)
        if self.queue:
            keep: deque = deque()
            for r in self.queue:
                if r.deadline is not None and self.clock >= r.deadline:
                    self._expire(r, "queued")
                else:
                    keep.append(r)
            self.queue = keep

    # ----------------------------------------------------------------- #
    # Prefill (disaggregated: batch 1, the request's real prompt length)
    # ----------------------------------------------------------------- #
    def _prefill_fn(self, plen: int, *, recover: bool = False):
        table = self._recover_prefills if recover else self._prefills
        if plen not in table:
            cfg, scfg, rules = self.cfg, self.scfg, self.rules
            scope = "serve_recover" if recover else "serve_prefill"

            def pre(params_, prompt_):
                with sharding.use_rules(rules), engine.op_scope(scope):
                    return transformer.prefill(
                        params_, cfg, {"inputs": prompt_}, scfg.max_len,
                        storage_dtype=scfg.storage_dtype)

            table[plen] = jax.jit(pre)
        return table[plen]

    def _guarded_prefill(self, prompt: jax.Array, rid: int):
        """One prefill dispatch with crash-injection + single retry.

        ``prefill_crash`` counts prefill attempts; the injector's one-shot
        latch guarantees the retry runs clean, so a crashed prefill costs
        one extra prefill's worth of waste slot-ticks and nothing else."""
        self.prefill_count += 1
        pre = self._prefill_fn(prompt.shape[1])
        try:
            if (self.injector is not None and self.injector.fires(
                    self.prefill_count, "prefill_crash")):
                raise RuntimeError("injected prefill crash")
            return pre(self.params, prompt)
        except RuntimeError:
            self.trace.append(("prefill_retry", self.clock, rid))
            self.goodput.on_recovery(self.scfg.prefill_ticks)
            return pre(self.params, prompt)

    def _start(self) -> None:
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            r = self.queue.popleft()
            if r.deadline is not None and self.clock >= r.deadline:
                # expired while a co-resident prefill moved the clock
                self._expire(r, "queued")
                continue
            prompt_np = np.asarray(r.prompt, np.int32)
            prompt = jnp.asarray(prompt_np)[None]
            logits, single = self._guarded_prefill(prompt, r.rid)
            self.cache = self._insert(self.cache, single, jnp.int32(slot))
            tok = int(jnp.argmax(logits[0]))
            self.clock += self.scfg.prefill_ticks
            res = self.results[r.rid]
            res.first_token_tick = self.clock
            res.tokens.append(tok)
            self.slots[slot] = _Slot(
                rid=r.rid, pos=prompt.shape[1], emitted=1, fed=0,
                max_new=r.max_new_tokens, last_token=tok,
                prompt=prompt_np, deadline=r.deadline, priority=r.priority)
            self._arm_guards()
            self.trace.append(
                ("prefill", self.clock, r.rid, slot, prompt.shape[1]))
            self._admit()  # the clock moved; later arrivals may be due now

    # ----------------------------------------------------------------- #
    # Integrity: checksum guards, quarantine, slot rebuild
    # ----------------------------------------------------------------- #
    def _arm_guards(self) -> None:
        """(Re)checksum every occupied slot after a cache mutation.

        Re-arming must be global, not per-slot: under FP8 ratcheted
        delayed scaling any insert may requantize the *whole* pool, so a
        guard armed before someone else's admission would false-positive.
        """
        if self.scfg.audit_every < 1:
            return
        self.guards = {
            i: resilience.SlotGuard(
                rid=s.rid, length=s.pos,
                checksum=kv_cache.slot_checksum(self.cache, i, s.pos))
            for i, s in enumerate(self.slots) if s is not None}

    def _audit_slots(self) -> None:
        """Compare every armed guard; quarantine + rebuild mismatches.

        All checksums are compared *before* any rebuild: a rebuild's
        insert may ratchet the FP8 pool scale and requantize co-resident
        slots, which would trip their still-armed guards spuriously."""
        bad = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            g = self.guards.get(i)
            if g is None or g.rid != s.rid:
                continue
            if kv_cache.slot_checksum(self.cache, i, g.length) != g.checksum:
                bad.append(i)
        for i in bad:
            s = self.slots[i]
            self.trace.append(("kv_quarantine", self.clock, s.rid, i))
            self._rebuild_slot(i, s, rerun_decode=False)
            self.goodput.on_recovery(self.scfg.prefill_ticks)
            self.trace.append(("recover", self.clock, s.rid, i))
        if bad:
            self._arm_guards()

    def _rebuild_slot(self, slot: int, s: _Slot,
                      rerun_decode: bool) -> Optional[np.ndarray]:
        """Rebuild one slot's cache from scratch and re-insert it.

        Re-prefills ``prompt + emitted[:fed]`` — exactly the tokens whose
        KV the slot holds (rows valid ``[0, pos)``, ``pos == P + fed``) —
        which reproduces the decode-built cache bitwise on FP16.  With
        ``rerun_decode`` the poisoned decode step is replayed batch-1
        (feed ``last_token`` at ``pos``) and the recovered logits row is
        returned to replace the poisoned one; without it (checksum audit,
        which fires *before* the corrupt rows are ever read) the rebuilt
        cache alone restores the invariant.  The virtual clock does not
        advance — recovery overlaps the pool and is billed as waste
        slot-ticks by the caller."""
        res = self.results[s.rid]
        absorbed = np.concatenate(
            [np.asarray(s.prompt, np.int32),
             np.asarray(res.tokens[:s.fed], np.int32)])
        assert absorbed.shape[0] == s.pos, "slot rows out of sync"
        seq = jnp.asarray(absorbed)[None]
        _, single = self._prefill_fn(seq.shape[1], recover=True)(
            self.params, seq)
        row = None
        if rerun_decode:
            logits1, single = self._recover_decode(
                self.params, single,
                jnp.asarray([[s.last_token]], np.int32),
                jnp.asarray([s.pos], np.int32),
                jnp.asarray([s.pos + 1], np.int32))
            row = np.asarray(logits1[0])
        self.cache = self._recover_insert(self.cache, single, jnp.int32(slot))
        return row

    def _victim_slot(self) -> Optional[int]:
        active = self._active()
        if not active:
            return None
        target = getattr(self.injector, "target", None)
        if target is not None:
            for i in active:
                if self.slots[i].rid == target:
                    return i
        return active[0]

    # ----------------------------------------------------------------- #
    # Decode (the whole slot pool, ragged over per-slot kv lengths)
    # ----------------------------------------------------------------- #
    def _active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def _decode_once(self) -> None:
        if (self.scfg.audit_every >= 1
                and self.decode_steps % self.scfg.audit_every == 0):
            self._audit_slots()
        n = self.scfg.n_slots
        toks = np.zeros((n, 1), np.int32)
        pos = np.zeros((n,), np.int32)
        sizes = np.zeros((n,), np.int32)
        for i, s in enumerate(self.slots):
            if s is None:
                # parked: rewrites a row its next occupant overwrites anyway
                pos[i] = self.scfg.max_len - 1
                continue
            toks[i, 0] = s.last_token
            pos[i] = s.pos
            sizes[i] = s.pos + 1  # valid kv rows after this step's append
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(sizes))
        self.clock += 1.0
        self.decode_steps += 1
        self.goodput.on_decode_step()
        logits = np.array(logits)  # host copy: rows may be replaced below
        if (self.injector is not None
                and self.injector.mode == "nan_logits"
                and self._active()
                and self.injector.fires(self.decode_steps, "nan_logits")):
            logits[self._victim_slot(), :] = np.nan
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            if not np.all(np.isfinite(logits[i])):
                # NaN/inf guard: the slot's freshly appended KV row is as
                # suspect as the logits — quarantine, rebuild, replay.
                self.trace.append(("nan_detect", self.clock, s.rid, i))
                logits[i] = self._rebuild_slot(i, s, rerun_decode=True)
                self.goodput.on_recovery(self.scfg.prefill_ticks + 1.0)
                self.trace.append(("recover", self.clock, s.rid, i))
        active = 0
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            active += 1
            s.fed += 1
            s.pos += 1
            res = self.results[s.rid]
            if s.emitted < s.max_new:
                tok = int(np.argmax(logits[i]))
                s.emitted += 1
                s.last_token = tok
                res.tokens.append(tok)
            if s.emitted >= s.max_new and s.fed >= s.emitted:
                # the last emitted token's KV was absorbed this step: the
                # cache is consistent with the emitted sequence at eviction
                res.finish_tick = self.clock
                res.final_logits = logits[i]
                res.status = "finished"
                self.goodput.on_finish(len(res.tokens))
                self.trace.append(("finish", self.clock, s.rid, i))
                self.slots[i] = None
                self.guards.pop(i, None)
        self._arm_guards()
        if (self.injector is not None
                and self.injector.mode == "kv_corrupt"
                and self._active()
                and self.injector.fires(self.decode_steps, "kv_corrupt")):
            # silent bit flips after the guards armed; the next audit
            # (before the corrupt rows are read) must flag exactly this slot
            v = self._victim_slot()
            sv = self.slots[v]
            self.cache = kv_cache.corrupt_slot_rows(
                self.cache, v, [0, max(sv.pos - 1, 0)])
        self.health.append({
            "tick": self.clock,
            "queue_depth": len(self.queue),
            "pending": len(self.pending),
            "active_slots": active,
            "batch_fill": active / n,
            "goodput": self.goodput.goodput,
            "recoveries": self.goodput.recoveries,
            "expired": self.goodput.expired,
            "rejected": self.goodput.rejected,
        })

    # ----------------------------------------------------------------- #
    # Drive
    # ----------------------------------------------------------------- #
    def step(self) -> bool:
        """Advance one scheduler event; False once fully drained."""
        self._evict_expired()
        self._admit()
        self._start()
        self._shed()
        if self._active():
            self._decode_once()
            return True
        if self.pending:  # idle until the next arrival
            self.clock = max(self.clock, self.pending[0].arrival)
            return True
        return False

    def run(self) -> List[RequestResult]:
        while self.step():
            pass
        return [self.results[rid] for rid in sorted(self.results)]


# --------------------------------------------------------------------- #
# Instrumented (abstract) decode trace: exact ragged billing
# --------------------------------------------------------------------- #
def instrumented_decode_events(params, cfg, scfg: SchedulerConfig,
                               kv_lengths: Sequence[int]):
    """Trace one continuous-batching decode step abstractly and return the
    Engine events, tagged under the ``serve_decode`` op scope.

    ``kv_lengths`` are the per-slot valid kv rows *including* the token
    appended by the step (what the scheduler passes as group sizes; 0 for
    a parked slot).  Passing them concrete gives the grouped score GEMMs
    static ``valid_rows`` billing — the runtime path traces the same ops
    with traced sizes and falls back to dense billing.
    """
    n = scfg.n_slots
    sizes = np.asarray(kv_lengths, np.int32)
    if sizes.shape != (n,):
        raise ValueError(f"need {n} per-slot lengths, got {sizes.shape}")
    cabs = jax.eval_shape(lambda: transformer.init_cache(
        cfg, n, scfg.max_len, dtype=cfg.policy.compute_dtype,
        storage_dtype=scfg.storage_dtype))
    tok = jax.ShapeDtypeStruct((n, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((n,), jnp.int32)
    with engine.instrument() as events, engine.op_scope("serve_decode"):
        jax.eval_shape(
            lambda p_, c_, t_, q_: transformer.serve_step(
                p_, cfg, t_, c_, q_, kv_group_sizes=sizes),
            params, cabs, tok, pos)
    return events
