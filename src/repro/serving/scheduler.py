"""Continuous-batching request scheduler over the Engine decode path.

State machine (docs/serving.md): requests enter a FIFO **admission
queue** stamped with arrival ticks; a free decode **slot** triggers a
single-request **prefill** (batch 1, the request's actual prompt length)
whose cache is inserted into the pooled decode cache at that slot; all
occupied slots then advance together through batched **decode** steps
with per-slot positions and per-slot kv lengths — the ragged
``grouped_matmul``/``valid_rows`` path bills exactly the valid rows, so
a half-empty batch is visibly half-billed.  A sequence that has emitted
its budget **drains**: one final step absorbs its last token's KV (the
cache-consistency invariant ``generate`` relies on), then the slot frees
for the next queued request mid-flight.

Time is a virtual clock: one tick per batched decode step,
``prefill_ticks`` per prefill.  Everything host-side is deterministic —
FIFO by ``(arrival, rid)``, lowest free slot wins, greedy argmax decode —
so a seeded arrival trace pins the full admit/prefill/finish event log.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.models import transformer
from repro.runtime import sharding
from repro.serving import kv_cache

__all__ = [
    "Request", "SchedulerConfig", "RequestResult", "Scheduler",
    "instrumented_decode_events",
]


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    arrival: float          # ticks
    prompt: np.ndarray      # (P,) int32 token ids
    max_new_tokens: int


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    n_slots: int = 4
    max_len: int = 64
    storage_dtype: Optional[str] = None  # e.g. "float8_e4m3fn" (FP8 KV cache)
    prefill_ticks: float = 1.0


@dataclasses.dataclass
class RequestResult:
    rid: int
    arrival: float
    first_token_tick: Optional[float] = None
    finish_tick: Optional[float] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    final_logits: Optional[np.ndarray] = None  # P(next token | full sequence)

    @property
    def ttft(self) -> float:
        return self.first_token_tick - self.arrival

    @property
    def tokens_per_tick(self) -> float:
        return len(self.tokens) / max(self.finish_tick - self.arrival, 1e-9)


@dataclasses.dataclass
class _Slot:
    rid: int
    pos: int        # next cache write position == rows currently valid
    emitted: int    # tokens emitted so far
    fed: int        # emitted tokens whose KV has been absorbed
    max_new: int
    last_token: int


class Scheduler:
    """FIFO admission → per-request prefill → pooled continuous decode."""

    def __init__(self, params, cfg, scfg: SchedulerConfig,
                 rules: Optional[sharding.Rules] = None):
        if cfg.block_kind not in ("attn", "moe"):
            raise ValueError(
                f"the serving scheduler drives attn/moe decode caches, "
                f"not {cfg.block_kind!r}")
        if scfg.n_slots < 1:
            raise ValueError("need at least one decode slot")
        self.params, self.cfg, self.scfg = params, cfg, scfg
        self.rules = rules
        self.clock = 0.0
        self.compute_dtype = cfg.policy.compute_dtype
        self.cache = transformer.init_cache(
            cfg, scfg.n_slots, scfg.max_len, dtype=self.compute_dtype,
            storage_dtype=scfg.storage_dtype)
        self.slots: List[Optional[_Slot]] = [None] * scfg.n_slots
        self.pending: List[Request] = []       # submitted, arrival in future
        self.queue: deque = deque()            # admitted, waiting for a slot
        self.trace: List[Tuple] = []           # (event, tick, rid, ...)
        self.health: List[Dict[str, float]] = []
        self.results: Dict[int, RequestResult] = {}
        self._prefills: Dict[int, Any] = {}

        def _decode(params_, cache_, tokens_, pos_, sizes_):
            with sharding.use_rules(rules), engine.op_scope("serve_decode"):
                return transformer.serve_step(
                    params_, cfg, tokens_, cache_, pos_,
                    kv_group_sizes=sizes_)

        def _insert(pool_, single_, slot_):
            with engine.op_scope("serve_admit"):
                return kv_cache.insert_slot(
                    pool_, single_, slot_, self.compute_dtype)

        self._decode = jax.jit(_decode, donate_argnums=(1,))
        self._insert = jax.jit(_insert, donate_argnums=(0,))

    # ----------------------------------------------------------------- #
    # Admission
    # ----------------------------------------------------------------- #
    def submit(self, requests: Sequence[Request]) -> None:
        for r in requests:
            if r.max_new_tokens < 1:
                raise ValueError(f"request {r.rid}: max_new_tokens must be >= 1")
            if len(r.prompt) + r.max_new_tokens > self.scfg.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {len(r.prompt)} + gen "
                    f"{r.max_new_tokens} exceeds max_len {self.scfg.max_len}")
            self.results[r.rid] = RequestResult(rid=r.rid, arrival=r.arrival)
        self.pending.extend(requests)
        self.pending.sort(key=lambda r: (r.arrival, r.rid))

    def _admit(self) -> None:
        while self.pending and self.pending[0].arrival <= self.clock:
            r = self.pending.pop(0)
            self.queue.append(r)
            self.trace.append(("admit", self.clock, r.rid))

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    # ----------------------------------------------------------------- #
    # Prefill (disaggregated: batch 1, the request's real prompt length)
    # ----------------------------------------------------------------- #
    def _prefill_fn(self, plen: int):
        if plen not in self._prefills:
            cfg, scfg, rules = self.cfg, self.scfg, self.rules

            def pre(params_, prompt_):
                with sharding.use_rules(rules), engine.op_scope("serve_prefill"):
                    return transformer.prefill(
                        params_, cfg, {"inputs": prompt_}, scfg.max_len,
                        storage_dtype=scfg.storage_dtype)

            self._prefills[plen] = jax.jit(pre)
        return self._prefills[plen]

    def _start(self) -> None:
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            r = self.queue.popleft()
            prompt = jnp.asarray(np.asarray(r.prompt, np.int32))[None]
            logits, single = self._prefill_fn(prompt.shape[1])(
                self.params, prompt)
            self.cache = self._insert(self.cache, single, jnp.int32(slot))
            tok = int(jnp.argmax(logits[0]))
            self.clock += self.scfg.prefill_ticks
            res = self.results[r.rid]
            res.first_token_tick = self.clock
            res.tokens.append(tok)
            self.slots[slot] = _Slot(
                rid=r.rid, pos=prompt.shape[1], emitted=1, fed=0,
                max_new=r.max_new_tokens, last_token=tok)
            self.trace.append(
                ("prefill", self.clock, r.rid, slot, prompt.shape[1]))
            self._admit()  # the clock moved; later arrivals may be due now

    # ----------------------------------------------------------------- #
    # Decode (the whole slot pool, ragged over per-slot kv lengths)
    # ----------------------------------------------------------------- #
    def _active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def _decode_once(self) -> None:
        n = self.scfg.n_slots
        toks = np.zeros((n, 1), np.int32)
        pos = np.zeros((n,), np.int32)
        sizes = np.zeros((n,), np.int32)
        for i, s in enumerate(self.slots):
            if s is None:
                # parked: rewrites a row its next occupant overwrites anyway
                pos[i] = self.scfg.max_len - 1
                continue
            toks[i, 0] = s.last_token
            pos[i] = s.pos
            sizes[i] = s.pos + 1  # valid kv rows after this step's append
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(sizes))
        self.clock += 1.0
        logits = np.asarray(logits)
        active = 0
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            active += 1
            s.fed += 1
            s.pos += 1
            res = self.results[s.rid]
            if s.emitted < s.max_new:
                tok = int(np.argmax(logits[i]))
                s.emitted += 1
                s.last_token = tok
                res.tokens.append(tok)
            if s.emitted >= s.max_new and s.fed >= s.emitted:
                # the last emitted token's KV was absorbed this step: the
                # cache is consistent with the emitted sequence at eviction
                res.finish_tick = self.clock
                res.final_logits = logits[i]
                self.trace.append(("finish", self.clock, s.rid, i))
                self.slots[i] = None
        self.health.append({
            "tick": self.clock,
            "queue_depth": len(self.queue),
            "pending": len(self.pending),
            "active_slots": active,
            "batch_fill": active / n,
        })

    # ----------------------------------------------------------------- #
    # Drive
    # ----------------------------------------------------------------- #
    def step(self) -> bool:
        """Advance one scheduler event; False once fully drained."""
        self._admit()
        self._start()
        if self._active():
            self._decode_once()
            return True
        if self.pending:  # idle until the next arrival
            self.clock = max(self.clock, self.pending[0].arrival)
            return True
        return False

    def run(self) -> List[RequestResult]:
        while self.step():
            pass
        return [self.results[rid] for rid in sorted(self.results)]


# --------------------------------------------------------------------- #
# Instrumented (abstract) decode trace: exact ragged billing
# --------------------------------------------------------------------- #
def instrumented_decode_events(params, cfg, scfg: SchedulerConfig,
                               kv_lengths: Sequence[int]):
    """Trace one continuous-batching decode step abstractly and return the
    Engine events, tagged under the ``serve_decode`` op scope.

    ``kv_lengths`` are the per-slot valid kv rows *including* the token
    appended by the step (what the scheduler passes as group sizes; 0 for
    a parked slot).  Passing them concrete gives the grouped score GEMMs
    static ``valid_rows`` billing — the runtime path traces the same ops
    with traced sizes and falls back to dense billing.
    """
    n = scfg.n_slots
    sizes = np.asarray(kv_lengths, np.int32)
    if sizes.shape != (n,):
        raise ValueError(f"need {n} per-slot lengths, got {sizes.shape}")
    cabs = jax.eval_shape(lambda: transformer.init_cache(
        cfg, n, scfg.max_len, dtype=cfg.policy.compute_dtype,
        storage_dtype=scfg.storage_dtype))
    tok = jax.ShapeDtypeStruct((n, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((n,), jnp.int32)
    with engine.instrument() as events, engine.op_scope("serve_decode"):
        jax.eval_shape(
            lambda p_, c_, t_, q_: transformer.serve_step(
                p_, cfg, t_, c_, q_, kv_group_sizes=sizes),
            params, cabs, tok, pos)
    return events
