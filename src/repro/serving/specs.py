"""One source of truth for decode-cache sharding specs.

Both ``launch/serve.py`` (``cache_spec_tree``) and ``launch/dryrun.py``'s
decode cells route through :func:`decode_cache_specs`, so the cache's
abstract shapes and PartitionSpecs cannot drift between the two drivers.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.models import transformer
from repro.runtime import sharding

__all__ = ["decode_cache_specs"]


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x)


def decode_cache_specs(cfg, rules, mesh, batch: int, max_len: int, *,
                       dtype=None,
                       storage_dtype: Optional[str] = None) -> Tuple:
    """(abstract cache tree, sanitized PartitionSpec tree) for decode.

    ``storage_dtype`` grows the FP8 serving cache's per-head scale leaves
    in both trees (mirror of ``transformer.init_cache``).
    """
    axes = transformer.cache_axes(cfg, storage_dtype)
    abstract = jax.eval_shape(lambda: transformer.init_cache(
        cfg, batch, max_len, dtype=dtype, storage_dtype=storage_dtype))
    spec = jax.tree.map(
        lambda ax: sharding.logical_spec(ax, rules), axes, is_leaf=_is_axes)
    spec = jax.tree.map(
        lambda s, a: sharding.sanitize_spec(s, a.shape, mesh),
        spec, abstract, is_leaf=lambda x: isinstance(x, P))
    return abstract, spec
