"""Serving resilience: admission control, load shedding, serve goodput.

The policy layer of the serving failure model (docs/serving.md).  The
*mechanisms* — checksums, corruption, slot rebuild — live next to the
state they guard (``kv_cache.py``, ``scheduler.py``); this module owns
the host-side policy objects, all plain deterministic Python:

* :class:`Rejection` — the structured admission-control verdict.  A
  bounded queue never grows past ``SchedulerConfig.max_queue``; instead
  the scheduler records a rejection carrying ``retry_after``, the
  server-side hint a well-behaved client (``loadgen.run_load``) feeds
  into its exponential-backoff retry loop.

* :class:`ShedPolicy` — deterministic graceful degradation under
  sustained overload.  Two axes, both optional: drop queued work whose
  deadline is already infeasible (it would burn decode-slot ticks and
  then be evicted anyway), and trim the queue above a high-water mark
  by shedding the lowest-priority / youngest work first.

* :class:`ServeGoodputMeter` — the serving mirror of the training
  ``GoodputMeter``: **useful tokens ÷ total decode-slot-ticks**.  The
  denominator bills every slot of every batched decode step (an empty
  slot in a half-full batch is waste by construction) plus the
  slot-ticks spent on recovery re-prefills; the numerator counts only
  tokens of requests that *finished* — tokens emitted for a request
  that later expired or was evicted are sunk cost.  Emitted as
  ``serve/slo_*`` rows into ``BENCH_engine.json`` and floor-gated by
  ``benchmarks/baselines/serve_slo.json``.

* :class:`SlotGuard` — the armed checksum for one occupied decode slot
  (what :meth:`Scheduler._audit_slots` compares against).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

__all__ = [
    "Rejection", "ShedPolicy", "SlotGuard", "ServeGoodputMeter",
    "retry_after_hint",
]


@dataclasses.dataclass(frozen=True)
class Rejection:
    """One admission-control rejection, recorded in submission order.

    ``retry_after`` is the server's deterministic backpressure hint in
    ticks (``None`` means the request is invalid and retrying is
    pointless — oversized prompt+gen, non-positive token budget).
    """
    rid: int
    tick: float
    reason: str                        # "invalid" | "oversized" | "queue_full"
    retry_after: Optional[float] = None


def retry_after_hint(queue_depth: int, prefill_ticks: float) -> float:
    """Backpressure hint for a ``queue_full`` rejection: the ticks until
    the queue has plausibly drained one request per prefill, never less
    than one full prefill."""
    return max(1, queue_depth) * max(prefill_ticks, 1.0)


@dataclasses.dataclass(frozen=True)
class ShedPolicy:
    """Deterministic load shedding over the admitted queue.

    ``shed_infeasible`` drops queued requests whose deadline cannot be
    met even if a slot freed *right now* (one prefill plus one tick per
    remaining token still overruns the deadline).  ``queue_high_water``
    then trims the queue down to the mark, shedding lowest ``priority``
    first, then latest arrival, then highest rid — so under identical
    traffic two runs shed the identical set.
    """
    queue_high_water: Optional[int] = None
    shed_infeasible: bool = True

    def feasible(self, req, clock: float, prefill_ticks: float) -> bool:
        if req.deadline_ticks is None:
            return True
        finish_at_best = clock + prefill_ticks + req.max_new_tokens
        return finish_at_best <= req.arrival + req.deadline_ticks

    def select_shed(self, queue: Sequence, clock: float,
                    prefill_ticks: float) -> List:
        victims = []
        survivors = list(queue)
        if self.shed_infeasible:
            victims = [r for r in survivors
                       if not self.feasible(r, clock, prefill_ticks)]
            survivors = [r for r in survivors
                         if self.feasible(r, clock, prefill_ticks)]
        if (self.queue_high_water is not None
                and len(survivors) > self.queue_high_water):
            n_drop = len(survivors) - self.queue_high_water
            # lowest priority sheds first; ties broken against the
            # youngest (latest-arriving, highest-rid) request
            by_value = sorted(survivors,
                              key=lambda r: (r.priority, -r.arrival, -r.rid))
            victims.extend(by_value[:n_drop])
        return victims


@dataclasses.dataclass(frozen=True)
class SlotGuard:
    """Armed integrity state for one occupied slot: the CRC32 of its
    ``length`` valid KV rows as of the last healthy cache mutation."""
    rid: int
    length: int
    checksum: int


@dataclasses.dataclass
class ServeGoodputMeter:
    """Serve goodput: useful tokens ÷ total decode-slot-ticks.

    ``decode_steps × n_slots`` bills the whole pool for every batched
    decode step — idle slots in a ragged batch are structural waste —
    and ``recovery_slot_ticks`` adds the re-prefill / re-decode work a
    quarantined slot costs (recovery overlaps the pool's virtual clock,
    so it shows up here and nowhere else).  Tokens emitted by requests
    that later expired are counted as ``wasted_tokens``, not useful.
    """
    n_slots: int
    decode_steps: int = 0
    useful_tokens: int = 0
    wasted_tokens: int = 0
    recovery_slot_ticks: float = 0.0
    recoveries: int = 0
    expired: int = 0
    shed: int = 0
    rejected: int = 0

    def on_decode_step(self) -> None:
        self.decode_steps += 1

    def on_finish(self, n_tokens: int) -> None:
        self.useful_tokens += n_tokens

    def on_expire(self, n_tokens_emitted: int) -> None:
        self.expired += 1
        self.wasted_tokens += n_tokens_emitted

    def on_recovery(self, slot_ticks: float) -> None:
        self.recoveries += 1
        self.recovery_slot_ticks += slot_ticks

    def on_shed(self) -> None:
        self.shed += 1

    def on_reject(self) -> None:
        self.rejected += 1

    @property
    def slot_ticks(self) -> float:
        return self.decode_steps * self.n_slots + self.recovery_slot_ticks

    @property
    def goodput(self) -> float:
        return self.useful_tokens / max(self.slot_ticks, 1e-9)

    def report(self) -> Dict[str, float]:
        return {
            "goodput": self.goodput,
            "useful_tokens": float(self.useful_tokens),
            "wasted_tokens": float(self.wasted_tokens),
            "slot_ticks": float(self.slot_ticks),
            "recovery_slot_ticks": float(self.recovery_slot_ticks),
            "recoveries": float(self.recoveries),
            "expired": float(self.expired),
            "shed": float(self.shed),
            "rejected": float(self.rejected),
        }
