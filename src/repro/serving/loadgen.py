"""Poisson load generator + latency/throughput/SLO metrics for the scheduler.

Offered load is requests per *tick* (one tick == one batched decode
step); the seeded ``numpy.random.default_rng`` stream makes every sweep
reproducible bit for bit.  Per-request metrics are time-to-first-token
(ticks, includes queueing) and end-to-end tokens/tick; aggregation is
p50/p99 over the **finished** request population — rejected, shed, and
expired requests are excluded explicitly (their latency properties are
``nan`` by contract) and reported through their own counters.
:func:`bench_rows` converts a sweep into ``serve/*`` rows for
``benchmarks/run.py`` / ``BENCH_engine.json``, using the measured wall
seconds-per-tick to express throughput in tokens/s.

The generator is also the well-behaved *client* of the admission-control
loop (docs/serving.md): a ``queue_full`` rejection is retried up to
``max_retries`` times with exponential backoff seeded-jittered on top of
the server's ``retry_after`` hint; invalid rejections and exhausted
retry budgets count as abandons.  :func:`slo_rows` runs one (optionally
fault-injected) scenario and emits the CI-gated ``serve/*/slo_*`` rows.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.scheduler import Request, Scheduler, SchedulerConfig

__all__ = [
    "LoadConfig", "poisson_requests", "run_load", "bench_rows",
    "slo_rows", "merge_bench_json",
]


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    rate: float              # offered load: requests per tick
    n_requests: int = 8
    prompt_len: int = 8
    gen_len: int = 8
    seed: int = 0
    deadline_ticks: Optional[float] = None  # per-request budget from arrival
    n_priorities: int = 1    # round-robin priority classes (shed ordering)
    max_retries: int = 0     # client retry budget per rejected request
    backoff_base: float = 2.0
    backoff_init_ticks: float = 1.0
    jitter_ticks: float = 0.5


def poisson_requests(cfg, lc: LoadConfig) -> List[Request]:
    """Seeded Poisson arrivals with uniform random prompts over the vocab."""
    rng = np.random.default_rng(lc.seed)
    t, reqs = 0.0, []
    for i in range(lc.n_requests):
        t += float(rng.exponential(1.0 / lc.rate))
        prompt = rng.integers(
            0, cfg.vocab_size, size=lc.prompt_len).astype(np.int32)
        reqs.append(Request(rid=i, arrival=round(t, 6), prompt=prompt,
                            max_new_tokens=lc.gen_len,
                            deadline_ticks=lc.deadline_ticks,
                            priority=i % max(lc.n_priorities, 1)))
    return reqs


def _pct(values: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(values), q)) if len(values) \
        else float("nan")


def run_load(params, cfg, scfg: SchedulerConfig, lc: LoadConfig,
             rules=None, injector=None) -> Dict[str, float]:
    """One offered-load point: drive to drain with client-side retries.

    The drive loop steps the scheduler and, after every step, replays any
    new ``queue_full`` rejections as resubmissions delayed by the server's
    ``retry_after`` plus exponential backoff (``backoff_init_ticks *
    backoff_base**attempt``) plus seeded uniform jitter — deterministic
    end to end.  Aggregation skips unfinished requests explicitly.
    """
    sched = Scheduler(params, cfg, scfg, rules=rules, injector=injector)
    reqs = {r.rid: r for r in poisson_requests(cfg, lc)}
    sched.submit(list(reqs.values()))
    rng = np.random.default_rng(lc.seed + 0x5EED)
    attempts: Dict[int, int] = {}
    retries = abandons = seen = 0
    t0 = time.perf_counter()
    while True:
        progressed = sched.step()
        resubmit = []
        for rej in sched.rejections[seen:]:
            if rej.retry_after is None:  # invalid: retrying cannot help
                abandons += 1
                continue
            a = attempts.get(rej.rid, 0)
            if a >= lc.max_retries:
                abandons += 1
                continue
            attempts[rej.rid] = a + 1
            retries += 1
            delay = (rej.retry_after
                     + lc.backoff_init_ticks * lc.backoff_base ** a
                     + float(rng.uniform(0.0, lc.jitter_ticks)))
            resubmit.append(dataclasses.replace(
                reqs[rej.rid], arrival=round(rej.tick + delay, 6)))
        seen = len(sched.rejections)
        if resubmit:
            sched.submit(resubmit)
        if not progressed and not resubmit:
            break
    wall = time.perf_counter() - t0

    results = [sched.results[rid] for rid in sorted(sched.results)]
    finished = [r for r in results if r.status == "finished"]
    s_per_tick = wall / max(sched.clock, 1e-9)
    fill = np.array([h["batch_fill"] for h in sched.health])
    if lc.deadline_ticks is None:
        hits = len(finished)
    else:
        hits = sum(1 for r in finished
                   if r.finish_tick - r.arrival <= lc.deadline_ticks)
    ttft = [r.ttft for r in finished]
    tpt = [r.tokens_per_tick for r in finished]
    metrics = {
        "rate": lc.rate,
        "n_requests": lc.n_requests,
        "n_finished": len(finished),
        "n_unfinished": len(results) - len(finished),
        "total_tokens": int(sum(len(r.tokens) for r in results)),
        "ticks": float(sched.clock),
        "decode_steps": len(sched.health),
        "wall_s": wall,
        "s_per_tick": s_per_tick,
        "p50_ttft_ticks": _pct(ttft, 50),
        "p99_ttft_ticks": _pct(ttft, 99),
        "p50_tokens_per_s": _pct(tpt, 50) / s_per_tick,
        "p99_tokens_per_s": _pct(tpt, 99) / s_per_tick,
        "mean_batch_fill": float(fill.mean()) if len(fill) else 0.0,
        "retries": retries,
        "abandons": abandons,
        "retry_rate": retries / lc.n_requests,
        "abandon_rate": abandons / lc.n_requests,
        "deadline_hit_rate": hits / lc.n_requests,
    }
    for key, val in sched.goodput.report().items():
        metrics[f"slo_{key}"] = val
    return metrics


def bench_rows(params, cfg, scfg: SchedulerConfig, arch: str,
               rates: Sequence[float], lc: Optional[LoadConfig] = None,
               rules=None) -> List[tuple]:
    """Sweep offered loads into ``(name, us, derived)`` benchmark rows."""
    rows = []
    for rate in rates:
        point = dataclasses.replace(lc or LoadConfig(rate=rate), rate=rate)
        m = run_load(params, cfg, scfg, point, rules=rules)
        tag = f"serve/{arch}/r{rate:g}"
        rows.append((
            f"{tag}/ttft",
            m["p50_ttft_ticks"] * m["s_per_tick"] * 1e6,
            f"p50={m['p50_ttft_ticks']:.2f}t p99={m['p99_ttft_ticks']:.2f}t",
        ))
        rows.append((
            f"{tag}/tps",
            1e6 / max(m["p50_tokens_per_s"], 1e-9),  # us per token, p50
            f"p50={m['p50_tokens_per_s']:.1f}tok/s "
            f"p99={m['p99_tokens_per_s']:.1f}tok/s "
            f"fill={m['mean_batch_fill']:.2f}",
        ))
    return rows


def slo_rows(params, cfg, scfg: SchedulerConfig, arch: str, lc: LoadConfig,
             rules=None, injector=None,
             tag: str = "slo") -> Tuple[List[tuple], Dict[str, float]]:
    """One SLO scenario (deadlines / bounded queue / optional injected
    fault) as ``(name, us, derived)`` rows plus the raw metrics.

    The ``derived`` string carries the gated quantities —
    ``serve-resilience-gates`` parses ``goodput=``/``hit=`` against the
    floors in ``benchmarks/baselines/serve_slo.json``.
    """
    m = run_load(params, cfg, scfg, lc, rules=rules, injector=injector)
    derived = (
        f"goodput={m['slo_goodput']:.4f} hit={m['deadline_hit_rate']:.3f} "
        f"retries={m['retries']} abandons={m['abandons']} "
        f"recoveries={m['slo_recoveries']:.0f} shed={m['slo_shed']:.0f} "
        f"expired={m['slo_expired']:.0f} rejected={m['slo_rejected']:.0f}")
    rows = [(f"serve/{arch}/{tag}_goodput", m["wall_s"] * 1e6, derived)]
    return rows, m


def merge_bench_json(path: str, rows: Sequence[tuple],
                     module: str = "serve_loadgen") -> None:
    """Merge rows into ``BENCH_engine.json`` (same-name rows replaced)."""
    doc = {"benchmarks": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    names = {name for name, _, _ in rows}
    doc["benchmarks"] = [r for r in doc.get("benchmarks", [])
                         if r.get("name") not in names]
    for name, us, derived in rows:
        doc["benchmarks"].append({
            "name": name, "us_per_call": round(float(us), 3),
            "derived": derived, "module": module,
        })
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
