"""Poisson load generator + latency/throughput metrics for the scheduler.

Offered load is requests per *tick* (one tick == one batched decode
step); the seeded ``numpy.random.default_rng`` stream makes every sweep
reproducible bit for bit.  Per-request metrics are time-to-first-token
(ticks, includes queueing) and end-to-end tokens/tick; aggregation is
p50/p99 over the request population.  :func:`bench_rows` converts a
sweep into ``serve/*`` rows for ``benchmarks/run.py`` /
``BENCH_engine.json``, using the measured wall seconds-per-tick to
express throughput in tokens/s.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.scheduler import Request, Scheduler, SchedulerConfig

__all__ = [
    "LoadConfig", "poisson_requests", "run_load", "bench_rows",
    "merge_bench_json",
]


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    rate: float              # offered load: requests per tick
    n_requests: int = 8
    prompt_len: int = 8
    gen_len: int = 8
    seed: int = 0


def poisson_requests(cfg, lc: LoadConfig) -> List[Request]:
    """Seeded Poisson arrivals with uniform random prompts over the vocab."""
    rng = np.random.default_rng(lc.seed)
    t, reqs = 0.0, []
    for i in range(lc.n_requests):
        t += float(rng.exponential(1.0 / lc.rate))
        prompt = rng.integers(
            0, cfg.vocab_size, size=lc.prompt_len).astype(np.int32)
        reqs.append(Request(rid=i, arrival=round(t, 6), prompt=prompt,
                            max_new_tokens=lc.gen_len))
    return reqs


def run_load(params, cfg, scfg: SchedulerConfig, lc: LoadConfig,
             rules=None) -> Dict[str, float]:
    """One offered-load point: run the scheduler to drain, aggregate."""
    sched = Scheduler(params, cfg, scfg, rules=rules)
    sched.submit(poisson_requests(cfg, lc))
    t0 = time.perf_counter()
    results = sched.run()
    wall = time.perf_counter() - t0
    ttft = np.array([r.ttft for r in results])
    tpt = np.array([r.tokens_per_tick for r in results])
    s_per_tick = wall / max(sched.clock, 1e-9)
    fill = np.array([h["batch_fill"] for h in sched.health])
    return {
        "rate": lc.rate,
        "n_requests": lc.n_requests,
        "total_tokens": int(sum(len(r.tokens) for r in results)),
        "ticks": float(sched.clock),
        "decode_steps": len(sched.health),
        "wall_s": wall,
        "s_per_tick": s_per_tick,
        "p50_ttft_ticks": float(np.percentile(ttft, 50)),
        "p99_ttft_ticks": float(np.percentile(ttft, 99)),
        "p50_tokens_per_s": float(np.percentile(tpt, 50) / s_per_tick),
        "p99_tokens_per_s": float(np.percentile(tpt, 99) / s_per_tick),
        "mean_batch_fill": float(fill.mean()) if len(fill) else 0.0,
    }


def bench_rows(params, cfg, scfg: SchedulerConfig, arch: str,
               rates: Sequence[float], lc: Optional[LoadConfig] = None,
               rules=None) -> List[tuple]:
    """Sweep offered loads into ``(name, us, derived)`` benchmark rows."""
    rows = []
    for rate in rates:
        point = dataclasses.replace(lc or LoadConfig(rate=rate), rate=rate)
        m = run_load(params, cfg, scfg, point, rules=rules)
        tag = f"serve/{arch}/r{rate:g}"
        rows.append((
            f"{tag}/ttft",
            m["p50_ttft_ticks"] * m["s_per_tick"] * 1e6,
            f"p50={m['p50_ttft_ticks']:.2f}t p99={m['p99_ttft_ticks']:.2f}t",
        ))
        rows.append((
            f"{tag}/tps",
            1e6 / max(m["p50_tokens_per_s"], 1e-9),  # us per token, p50
            f"p50={m['p50_tokens_per_s']:.1f}tok/s "
            f"p99={m['p99_tokens_per_s']:.1f}tok/s "
            f"fill={m['mean_batch_fill']:.2f}",
        ))
    return rows


def merge_bench_json(path: str, rows: Sequence[tuple],
                     module: str = "serve_loadgen") -> None:
    """Merge rows into ``BENCH_engine.json`` (same-name rows replaced)."""
    doc = {"benchmarks": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    names = {name for name, _, _ in rows}
    doc["benchmarks"] = [r for r in doc.get("benchmarks", [])
                         if r.get("name") not in names]
    for name, us, derived in rows:
        doc["benchmarks"].append({
            "name": name, "us_per_call": round(float(us), 3),
            "derived": derived, "module": module,
        })
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
