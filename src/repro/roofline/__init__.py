"""Roofline analysis from compiled dry-run artifacts."""

from repro.roofline.analysis import (
    HBM_BW, ICI_BW, PEAK_FLOPS, CollectiveOp, RooflineReport,
    collective_bytes_per_device, model_flops, parse_collectives, roofline,
)

__all__ = [
    "PEAK_FLOPS", "HBM_BW", "ICI_BW", "CollectiveOp", "RooflineReport",
    "parse_collectives", "collective_bytes_per_device", "roofline",
    "model_flops",
]
