"""Three-term roofline from the compiled dry-run artifact.

Terms (per device, seconds) for TPU v5e targets:

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS      (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_device / HBM_BW          (819 GB/s)
    collective = link_bytes_per_device / ICI_BW         (~50 GB/s/link)

``cost_analysis()`` on this JAX/XLA build reports **per-device** "flops" and
"bytes accessed" after SPMD partitioning (measured — DESIGN.md §7), so the
first two terms read off directly.

Collective bytes are NOT in cost_analysis: we parse ``compiled.as_text()``.
The partitioned module shows per-device shapes; each collective is attributed
ring-model wire bytes:

    all-reduce        2 * R * (g-1)/g      (R = per-device tensor bytes)
    all-gather        R * (g-1)/g          (R = gathered result bytes)
    reduce-scatter    R * (g-1)            (R = scattered shard bytes)
    all-to-all        R * (g-1)/g
    collective-permute R

Collectives inside ``while`` bodies (lax.scan layers, q-chunk loops) execute
``known_trip_count`` times — we build the computation call graph (while
body/condition, fusion calls, conditionals) and multiply each computation's
collectives by its effective trip multiplier.

Relation to :mod:`repro.analysis.jaxpr_audit`: both walk a staged program,
but at different layers and for different questions.  This module parses
**post-compilation HLO text** — after SPMD partitioning, fusion, and
layout assignment — to estimate *cost* (seconds per device); it sees what
the hardware will actually run, but individual contractions have been
fused beyond recognition.  The jaxpr auditor walks the **pre-lowering
jaxpr** — before XLA touches it — to check *provenance*: every
``dot_general`` still corresponds 1:1 to a Python-level contraction
there, so it can be reconciled against the Engine's ``GemmEvent`` stream
and escapes attributed to a source path.  Use this module to ask "how
long", the auditor to ask "who issued this GEMM".
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = [
    "PEAK_FLOPS", "HBM_BW", "ICI_BW",
    "CollectiveOp", "parse_collectives", "collective_bytes_per_device",
    "RooflineReport", "roofline", "model_flops", "flops_from_events",
    "is_backward_event", "flops_by_direction", "bytes_by_direction",
]

PEAK_FLOPS = 197e12   # bf16 per chip, TPU v5e
HBM_BW = 819e9        # bytes/s per chip
ICI_BW = 50e9         # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int     # per-device result bytes (sum over tuple elements)
    group_size: int
    computation: str      # enclosing computation name
    multiplier: int = 1   # effective trip count

    @property
    def wire_bytes(self) -> float:
        g = max(self.group_size, 1)
        R = self.result_bytes
        if self.kind == "collective-permute":
            # pairwise sends, no group amortization
            return float(R)
        if g == 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * R * (g - 1) / g
        if self.kind == "all-gather":
            return R * (g - 1) / g
        if self.kind == "reduce-scatter":
            return float(R) * (g - 1)
        if self.kind == "all-to-all":
            return R * (g - 1) / g
        return float(R)  # collective-permute


_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    """Bytes of 'f32[16,512]{1,0}' or '(f32[64,512]{..}, f32[512,64]{..})'."""
    total = 0
    for dtype, dims in _ARRAY_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(attrs: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return 1


def _split_computations(hlo: str) -> Dict[str, str]:
    """Map computation name -> its body text."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    head_re = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
    entry_re = re.compile(r"^ENTRY\s+%?([\w.\-]+)")
    for line in hlo.splitlines():
        if cur is None:
            m = head_re.match(line) if "{" in line else None
            e = entry_re.match(line)
            if e:
                cur = e.group(1)
                comps[cur] = []
            elif m:
                cur = m.group(1)
                comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\]{},]+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^\n]*)")
_DONE_RE = re.compile(r"(all-reduce|all-gather|all-to-all|collective-permute)-done")
# the while operand may carry an inline tuple type (one nested paren level)
_WHILE_RE = re.compile(
    r"while\((?:[^()]|\([^()]*\))*\),\s*condition=%?([\w.\-]+),"
    r"\s*body=%?([\w.\-]+)([^\n]*)")
_TRIP_RE = re.compile(r"known_trip_count\\?\":\{\\?\"n\\?\":\\?\"(\d+)")
_CALL_RE = re.compile(r"(?:calls|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def parse_collectives(hlo: str) -> List[CollectiveOp]:
    comps = _split_computations(hlo)
    # entry = the computation not referenced by anyone (fallback: 'main')
    referenced = set()
    callers: Dict[str, List[Tuple[str, int]]] = {c: [] for c in comps}

    for name, body in comps.items():
        for m in _WHILE_RE.finditer(body):
            cond, wbody, rest = m.group(1), m.group(2), m.group(3)
            tm = _TRIP_RE.search(rest)
            t = int(tm.group(1)) if tm else 1
            for target, mult in ((cond, t), (wbody, t)):
                if target in callers:
                    callers[target].append((name, mult))
                    referenced.add(target)
        for m in _CALL_RE.finditer(body):
            for target in re.split(r",\s*%?", m.group(1)):
                target = target.strip().lstrip("%")
                if target in callers:
                    callers[target].append((name, 1))
                    referenced.add(target)

    entries = [c for c in comps if c not in referenced]
    memo: Dict[str, int] = {}

    def mult(name: str, seen=()) -> int:
        if name in memo:
            return memo[name]
        if name in entries or not callers.get(name):
            return 1
        if name in seen:
            return 1
        total = 0
        for caller, m in callers[name]:
            total += mult(caller, seen + (name,)) * m
        memo[name] = max(total, 1)
        return memo[name]

    ops: List[CollectiveOp] = []
    for name, body in comps.items():
        for m in _COLL_RE.finditer(body):
            type_str, kind, attrs = m.group(1), m.group(2), m.group(3)
            ops.append(CollectiveOp(
                kind=kind,
                result_bytes=_type_bytes(type_str),
                group_size=_group_size(attrs),
                computation=name,
                multiplier=mult(name),
            ))
    return ops


def collective_bytes_per_device(hlo: str) -> float:
    return sum(op.wire_bytes * op.multiplier for op in parse_collectives(hlo))


# --------------------------------------------------------------------- #
# Structural per-device costs (trip-count aware)
# --------------------------------------------------------------------- #
# XLA:CPU's cost_analysis() reports while bodies ONCE (measured: a 28-layer
# scan shows ~1 layer of flops), so the roofline derives compute/memory from
# the HLO structure itself, using the same call-graph multipliers as the
# collective parser:
#   * dot flops  = 2 * prod(result dims) * prod(contracted dims)  (x trips)
#   * HBM bytes  = per-instruction result + operand bytes in non-fusion
#     computations (post-fusion HLO: each instruction's I/O ~ HBM traffic),
#     skipping pure plumbing (parameter/constant/tuple/get-tuple-element).

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^=]*?\)|[\w\[\]{},.]+))\s+([\w\-]+)\(",
    re.M)
# operands may be printed bare ("dot(%a, %b)") or typed
# ("dot(f32[8,4]{1,0} %a, ...)") depending on the XLA version
_DOT_OPS_RE = re.compile(
    r"dot\((?:[\w\[\]{},]+\s+)?%([\w.\-]+),\s*(?:[\w\[\]{},]+\s+)?%([\w.\-]+)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_PLUMBING = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "call",
    "conditional", "custom-call",
}


def _shape_dims(type_str: str):
    m = _ARRAY_RE.search(type_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def structural_costs(hlo: str) -> Tuple[float, float]:
    """(dot_flops, traffic_bytes) per device, trip-count aware."""
    comps = _split_computations(hlo)

    # call graph multipliers (same walk as parse_collectives)
    referenced = set()
    callers: Dict[str, List[Tuple[str, int]]] = {c: [] for c in comps}
    fusion_only: Dict[str, bool] = {c: True for c in comps}
    for name, body in comps.items():
        for m in _WHILE_RE.finditer(body):
            cond, wbody, rest = m.group(1), m.group(2), m.group(3)
            tm = _TRIP_RE.search(rest)
            t = int(tm.group(1)) if tm else 1
            for target in (cond, wbody):
                if target in callers:
                    callers[target].append((name, t))
                    referenced.add(target)
                    fusion_only[target] = False
        for m in _CALL_RE.finditer(body):
            for target in re.split(r",\s*%?", m.group(1)):
                target = target.strip().lstrip("%")
                if target in callers:
                    callers[target].append((name, 1))
                    referenced.add(target)
                    # 'calls=' covers fusions AND call ops; treat called
                    # computations as fused (I/O counted at the call site)
    entries = [c for c in comps if c not in referenced]
    memo: Dict[str, int] = {}

    def mult(name: str, seen=()) -> int:
        if name in memo:
            return memo[name]
        if name in entries or not callers.get(name):
            return 1
        if name in seen:
            return 1
        total = sum(mult(c, seen + (name,)) * m for c, m in callers[name])
        memo[name] = max(total, 1)
        return memo[name]

    flops = 0.0
    byts = 0.0
    for name, body in comps.items():
        is_fusion_body = name in referenced and fusion_only.get(name, False)
        m_ = mult(name)
        # symbol table for operand byte lookups
        types: Dict[str, str] = {}
        for im in _INSTR_RE.finditer(body):
            types[im.group(1)] = im.group(2)
        for im in _INSTR_RE.finditer(body):
            iname, type_str, opcode = im.group(1), im.group(2), im.group(3)
            line_start = im.start()
            line_end = body.find("\n", line_start)
            line = body[line_start:line_end if line_end != -1 else None]
            if opcode == "dot":
                dm = _DOT_OPS_RE.search(line)
                cm = _LHS_CONTRACT_RE.search(line)
                _, rdims = _shape_dims(type_str)
                k = 1
                if dm and cm and dm.group(1) in types:
                    _, ldims = _shape_dims(types[dm.group(1)])
                    for ci in (int(c) for c in cm.group(1).split(",") if c):
                        if ci < len(ldims):
                            k *= ldims[ci]
                n = 1
                for d in rdims:
                    n *= d
                flops += 2.0 * n * k * m_
            if is_fusion_body or opcode in _PLUMBING:
                continue
            result_b = _type_bytes(type_str)
            operands = re.findall(r"%([\w.\-]+)", line.split("(", 1)[1]) \
                if "(" in line else []
            operand_b = [
                _type_bytes(types[o]) for o in operands if o in types]
            if opcode in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced region, not the whole buffer
                io = 2 * result_b
            elif opcode in ("dynamic-update-slice", "scatter"):
                # in-place region write: read+write the update, not the buffer
                upd = operand_b[1] if len(operand_b) > 1 else result_b
                io = 2 * upd
            elif opcode in ("broadcast", "reshape", "transpose", "copy",
                            "convert", "pad", "reverse"):
                io = result_b + (operand_b[0] if operand_b else 0)
            elif opcode == "fusion" and "dynamic-update-slice" in iname \
                    and m_ > 1:
                # fused in-place slice write inside a loop: the fusion's
                # result type is the whole buffer but each iteration only
                # touches buffer/trips bytes (scan-stacked outputs)
                io = 2 * result_b // m_
            elif opcode == "fusion" and "kind=kLoop" in line:
                # a kLoop fusion reads O(1) elements per operand per output
                # element — operands larger than the result are sliced views
                # of loop-invariant stacks (scan weights/residuals), so cap
                # each operand's traffic at the result size
                io = result_b + sum(min(b, result_b) for b in operand_b)
            else:
                io = result_b + sum(operand_b)
            byts += io * m_
    return flops, byts


# --------------------------------------------------------------------- #
# Report
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    collectives: Dict[str, float]
    memory_analysis: Dict[str, float]
    # global GEMM flops observed by the Engine's instrument() collector
    # while the program was traced.  Since the Engine op family carries a
    # custom VJP, a value_and_grad trace includes the backward GEMMs
    # (``matmul_dx`` / ``matmul_dw`` events) — engine_flops_fwd/_bwd split
    # the total by direction (a train step runs ~3x the inference flops:
    # fwd + dX + dW per layer).  0.0 when no events were supplied.
    engine_flops: float = 0.0
    engine_flops_fwd: float = 0.0
    engine_flops_bwd: float = 0.0
    # analytic HBM bytes of the same events, priced at each operand's
    # **true storage width** (``GemmSpec.x_dtype`` / ``w_dtype`` — FP8
    # operands under the mixed-precision policies pay one byte per
    # element while flops stay dtype-invariant), split by direction like
    # the flops.  0.0 when no events were supplied.
    engine_bytes: float = 0.0
    engine_bytes_fwd: float = 0.0
    engine_bytes_bwd: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_total = self.flops_per_device * self.n_devices
        return self.model_flops / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the program ran at
        the max-term bound: useful_model_flops / (bound_s * chips * peak)."""
        denom = self.bound_s * self.n_devices * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction, bound_s=self.bound_s)
        return d


def flops_from_events(events) -> float:
    """Total traced GEMM flops from Engine instrumentation events.

    The Engine emits one ``GemmEvent`` per dispatch at trace time (with a
    ``count`` multiplier for scan bodies), so this is the GEMM-only
    analytic flop count of the traced program — no HLO re-derivation.
    Backward dispatches (the Engine ops' custom-VJP rules) are ordinary
    events tagged ``matmul_dx`` / ``matmul_dw``, so a value_and_grad trace
    yields the full train-step GEMM count."""
    return float(sum(ev.flops * ev.count for ev in events))


def is_backward_event(ev) -> bool:
    """True for events emitted by the Engine's VJP rules (dX / dW GEMMs
    and the two-pass epilogue ``*_dact`` / ``*_dbias`` pass events) and
    for ``jax.checkpoint`` recompute events — the recompute re-forward
    executes during the backward pass, so its flops/bytes belong to the
    backward direction."""
    # lazy import: this module parses HLO text and has no engine dependency
    from repro.core.engine import is_backward_op

    return is_backward_op(ev.spec.op) or getattr(ev, "recompute", False)


def flops_by_direction(events) -> Dict[str, float]:
    """{"fwd": ..., "bwd": ...} GEMM flops of an instrumented trace."""
    fwd = bwd = 0.0
    for ev in events:
        if is_backward_event(ev):
            bwd += ev.flops * ev.count
        else:
            fwd += ev.flops * ev.count
    return {"fwd": fwd, "bwd": bwd}


def bytes_by_direction(events) -> Dict[str, float]:
    """{"fwd": ..., "bwd": ...} HBM bytes of an instrumented trace.

    Backward bytes include the epilogue-handling traffic wherever it
    flows: the two-pass fallback's ``ds`` materialization round-trip and
    separate bias-grad reduction ride on ``*_dact`` / ``*_dbias`` pass
    events, the fused one-pass backward's derivative stream and db output
    ride on the dX/dW events themselves — so this split is the honest
    basis for comparing the two (CI's bwd-perf gate pins the fused path
    strictly below the two-pass path on the AE train step)."""
    fwd = bwd = 0.0
    for ev in events:
        if is_backward_event(ev):
            bwd += ev.bytes * ev.count
        else:
            fwd += ev.bytes * ev.count
    return {"fwd": fwd, "bwd": bwd}


def roofline(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    n_devices: int,
    model_flops_val: float,
    hlo_text: Optional[str] = None,
    gemm_events=None,
) -> RooflineReport:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per device
        ca = ca[0] if ca else {}
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    hlo = hlo_text if hlo_text is not None else compiled.as_text()
    # trip-count-aware structural costs (XLA:CPU counts while bodies once)
    flops, byts = structural_costs(hlo)
    flops = max(flops, xla_flops)
    byts = max(byts, xla_bytes)
    ops = parse_collectives(hlo)
    coll = sum(op.wire_bytes * op.multiplier for op in ops)
    per_kind: Dict[str, float] = {}
    for op in ops:
        per_kind[op.kind] = per_kind.get(op.kind, 0.0) + op.wire_bytes * op.multiplier

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
        "output_bytes": getattr(ma, "output_size_in_bytes", 0),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
        "xla_flops": xla_flops,
        "xla_bytes": xla_bytes,
    }
    direction = (flops_by_direction(gemm_events) if gemm_events
                 else {"fwd": 0.0, "bwd": 0.0})
    bdirection = (bytes_by_direction(gemm_events) if gemm_events
                  else {"fwd": 0.0, "bwd": 0.0})
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=flops, bytes_per_device=byts,
        coll_bytes_per_device=coll,
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll / ICI_BW,
        model_flops=model_flops_val,
        collectives=per_kind,
        memory_analysis=mem,
        engine_flops=flops_from_events(gemm_events) if gemm_events else 0.0,
        engine_flops_fwd=direction["fwd"],
        engine_flops_bwd=direction["bwd"],
        engine_bytes=bdirection["fwd"] + bdirection["bwd"],
        engine_bytes_fwd=bdirection["fwd"],
        engine_bytes_bwd=bdirection["bwd"],
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode),
    N = active non-embedding params (MoE counts top-k + shared only)."""
    n_active = cfg.active_param_count() if cfg.moe else cfg.param_count()
    # drop the embedding gather (not a GEMM) but keep the LM-head GEMM;
    # with tied embeddings the one table IS the head, so nothing is dropped
    n_embed = 0 if cfg.tie_embeddings else cfg.vocab_size * cfg.d_model
    n = max(n_active - n_embed, 1)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
