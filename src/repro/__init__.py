"""RedMulE-JAX: a TPU-native, multi-pod reproduction of RedMulE
(Tortorella et al., 2022) — reduced-precision GEMM as the universal
engine of training and inference, scaled from a 32-FMA array to a
512-chip pod pair. See DESIGN.md."""

__version__ = "1.0.0"
