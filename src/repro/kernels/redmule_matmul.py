"""RedMulE matmul as a Pallas TPU kernel.

The paper's dataflow (§II-B/C), re-derived for the TPU memory hierarchy
(DESIGN.md §2):

* grid = (M/bm, K/bk, N/bn) with the contraction (N) innermost and marked
  ``arbitrary`` — the X tile for a given (m, k) stays resident across the
  whole N sweep (X-stationary) while W tiles stream through VMEM
  (W-streaming), double-buffered by the Pallas pipeline (the Streamer's
  interleaved load schedule);
* the Z tile lives in a VMEM scratch accumulator for the entire reduction
  and is written to HBM exactly once, on the last N step (the Z-buffer
  store-once rule);
* the accumulator is fp32 by default (MXU-native) or fp16 re-rounded per
  N-block in ``paper_faithful`` mode (the binary16 in-pipeline accumulation
  error model);
* the **epilogue is fused**: when a bias row and/or activation name is
  given, ``act(acc + bias)`` is applied to the accumulator *in the
  accumulation dtype* inside the store-once step, so an affine layer costs
  exactly one HBM write — the GEMM-*layer* datapath of the follow-up
  RedMule engine paper (arXiv:2301.03904), not a GEMM unit plus a separate
  HBM round-trip;
* batched operands get a leading **batch grid dimension**
  (:func:`redmule_matmul_batched_pallas`) instead of a ``vmap`` wrapper, so
  the tile choice and the Pallas pipeline see the true per-core working set
  (one X/W/Z tile set, not B concurrent copies);
* **transpose layouts** serve the backward pass without materialized
  transposes: the logical GEMM is always ``Z[M, K] = Σ_N X·W``, and
  ``layout`` names how the operands are *stored* — ``"nn"`` (x: (M, N),
  w: (N, K), the forward), ``"nt"`` (w stored (K, N); dX = dZ·Wᵀ reads W
  in its forward layout) and ``"tn"`` (x stored (N, M); dW = Xᵀ·dZ reads
  the saved activations in their forward layout).  Only the BlockSpec
  index maps and the in-kernel ``dot_general`` dimension numbers change;
  the X-stationary / store-once schedule — and therefore the accumulator
  error model — is identical in all three.

Shapes must be pre-padded to tile multiples by ``ops.py``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

from repro.core import epilogues as epi
from repro.core import precision as prec
from repro.core import tiling

__all__ = ["redmule_matmul_pallas", "redmule_matmul_batched_pallas", "LAYOUTS"]

# storage layouts of the logical Z[M,K] = X[M,N] @ W[N,K] contraction:
#   nn: x (M, N), w (N, K)   — forward
#   nt: x (M, N), w (K, N)   — dX = dZ @ W^T (w in forward storage)
#   tn: x (N, M), w (N, K)   — dW = X^T @ dZ (x in forward storage)
LAYOUTS = ("nn", "nt", "tn")

# in-kernel contraction dimension numbers per layout (2D tiles)
_DIMS = {
    "nn": (((1,), (0,)), ((), ())),
    "nt": (((1,), (1,)), ((), ())),
    "tn": (((0,), (0,)), ((), ())),
}


def _check_layout(layout: str) -> None:
    if layout not in LAYOUTS:
        raise ValueError(f"unknown layout {layout!r}; known: {LAYOUTS}")


def _store_value(acc, bias, *, epilogue, out_dtype):
    """The fused store-once epilogue: ``act(acc + bias)`` in the accumulator
    dtype, then a single downcast to the stored dtype.

    In ``paper_faithful`` mode the accumulator is fp16, so the epilogue runs
    in binary16 too — the whole layer stays inside the paper's datapath."""
    if bias is not None:
        acc = acc + bias.astype(acc.dtype)
    acc = epi.apply_epilogue(epilogue, acc)
    return acc.astype(out_dtype)


def _kernel(x_ref, w_ref, z_ref, acc_ref, *, n_tiles: int, out_dtype,
            epilogue: Optional[str], layout: str):
    """One (bm, bk) Z tile; invoked n_tiles times along the reduction."""

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The MXU step: X tile (held steady) x streamed W tile. The partial
    # product is accumulated on-array; in faithful-fp16 mode acc_ref is
    # fp16 so the += re-rounds to binary16 every block, like the paper's
    # FMA feedback path.  The layout only changes which operand axes
    # contract — the schedule (and the error model) is layout-invariant.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], _DIMS[layout],
        preferred_element_type=acc_ref.dtype,
    )

    @pl.when(pl.program_id(2) == n_tiles - 1)
    def _store_once():
        z_ref[...] = _store_value(acc_ref[...], None, epilogue=epilogue,
                                  out_dtype=out_dtype)


def _kernel_bias(x_ref, w_ref, bias_ref, z_ref, acc_ref, *, n_tiles: int,
                 out_dtype, epilogue: Optional[str], layout: str):
    """Same schedule with a (1, bk) bias tile folded into the store."""

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], _DIMS[layout],
        preferred_element_type=acc_ref.dtype,
    )

    @pl.when(pl.program_id(2) == n_tiles - 1)
    def _store_once():
        z_ref[...] = _store_value(acc_ref[...], bias_ref[...],
                                  epilogue=epilogue, out_dtype=out_dtype)


def _operand_specs(tile: tiling.TileConfig, layout: str):
    """(x BlockSpec, w BlockSpec) for one layout; grid is (i, j, r) =
    (M-tile, K-tile, reduction)."""
    if layout == "nn":
        return (pl.BlockSpec((tile.bm, tile.bn), lambda i, j, k: (i, k)),
                pl.BlockSpec((tile.bn, tile.bk), lambda i, j, k: (k, j)))
    if layout == "nt":
        return (pl.BlockSpec((tile.bm, tile.bn), lambda i, j, k: (i, k)),
                pl.BlockSpec((tile.bk, tile.bn), lambda i, j, k: (j, k)))
    # tn
    return (pl.BlockSpec((tile.bn, tile.bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((tile.bn, tile.bk), lambda i, j, k: (k, j)))


def _logical_dims(x_shape, w_shape, layout: str):
    """(M, N, K) of the logical contraction from stored operand shapes."""
    if layout == "nn":
        (M, N), (_, K) = x_shape, w_shape
    elif layout == "nt":
        (M, N), (K, _) = x_shape, w_shape
    else:  # tn
        (N, M), (_, K) = x_shape, w_shape
    return M, N, K


@functools.partial(
    jax.jit,
    static_argnames=("tile", "policy", "epilogue", "layout", "interpret"),
)
def redmule_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    tile: tiling.TileConfig,
    policy: prec.Policy,
    epilogue: Optional[str] = None,
    layout: str = "nn",
    interpret: bool = False,
) -> jax.Array:
    """Z = act(X @ W + bias) for 2D operands already padded to tile multiples.

    ``bias`` (optional) is a ``(1, K)`` row in the accumulation dtype;
    ``epilogue`` (optional) names an activation from
    :mod:`repro.core.epilogues`.  Both are applied inside the kernel's
    store-once step (no extra HBM pass).  ``layout`` selects the operand
    storage (see module docstring); the output is always ``(M, K)``."""
    _check_layout(layout)
    M, N, K = _logical_dims(x.shape, w.shape, layout)
    if layout == "nn":
        assert x.shape[1] == w.shape[0], (x.shape, w.shape)
    elif layout == "nt":
        assert x.shape[1] == w.shape[1], (x.shape, w.shape)
    else:
        assert x.shape[0] == w.shape[0], (x.shape, w.shape)
    assert M % tile.bm == 0 and N % tile.bn == 0 and K % tile.bk == 0, (
        f"shapes {(M, N, K)} not padded to tiles {tile}"
    )
    if bias is not None:
        assert bias.shape == (1, K), (bias.shape, K)
    grid = (M // tile.bm, K // tile.bk, N // tile.bn)

    in_specs = list(_operand_specs(tile, layout))
    operands = [x, w]
    if bias is None:
        kernel = functools.partial(_kernel, n_tiles=grid[2],
                                   out_dtype=policy.out_dtype,
                                   epilogue=epilogue, layout=layout)
    else:
        kernel = functools.partial(_kernel_bias, n_tiles=grid[2],
                                   out_dtype=policy.out_dtype,
                                   epilogue=epilogue, layout=layout)
        in_specs.append(pl.BlockSpec((1, tile.bk), lambda i, j, k: (0, j)))
        operands.append(bias)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile.bm, tile.bk), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, K), policy.out_dtype),
        scratch_shapes=[pltpu.VMEM((tile.bm, tile.bk), policy.accum_dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name=f"redmule_matmul_{layout}",
    )(*operands)


def _kernel_batched(x_ref, w_ref, z_ref, acc_ref, *, n_tiles: int, out_dtype,
                    epilogue: Optional[str], layout: str):
    """The same X-stationary schedule under a leading batch grid dim.

    Block refs carry a unit batch dim ((1, bm, bn) etc.); the reduction is
    grid axis 3."""

    @pl.when(pl.program_id(3) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], w_ref[0], _DIMS[layout],
        preferred_element_type=acc_ref.dtype,
    )

    @pl.when(pl.program_id(3) == n_tiles - 1)
    def _store_once():
        z_ref[0] = _store_value(acc_ref[...], None, epilogue=epilogue,
                                out_dtype=out_dtype)


def _kernel_batched_bias(x_ref, w_ref, bias_ref, z_ref, acc_ref, *,
                         n_tiles: int, out_dtype, epilogue: Optional[str],
                         layout: str):
    """Batched schedule with the shared (1, 1, bk) bias row in the store."""

    @pl.when(pl.program_id(3) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], w_ref[0], _DIMS[layout],
        preferred_element_type=acc_ref.dtype,
    )

    @pl.when(pl.program_id(3) == n_tiles - 1)
    def _store_once():
        z_ref[0] = _store_value(acc_ref[...], bias_ref[0],
                                epilogue=epilogue, out_dtype=out_dtype)


def _operand_specs_batched(tile: tiling.TileConfig, layout: str):
    if layout == "nn":
        return (pl.BlockSpec((1, tile.bm, tile.bn),
                             lambda b, i, j, k: (b, i, k)),
                pl.BlockSpec((1, tile.bn, tile.bk),
                             lambda b, i, j, k: (b, k, j)))
    if layout == "nt":
        return (pl.BlockSpec((1, tile.bm, tile.bn),
                             lambda b, i, j, k: (b, i, k)),
                pl.BlockSpec((1, tile.bk, tile.bn),
                             lambda b, i, j, k: (b, j, k)))
    # tn
    return (pl.BlockSpec((1, tile.bn, tile.bm),
                         lambda b, i, j, k: (b, k, i)),
            pl.BlockSpec((1, tile.bn, tile.bk),
                         lambda b, i, j, k: (b, k, j)))


@functools.partial(
    jax.jit,
    static_argnames=("tile", "policy", "epilogue", "layout", "interpret"),
)
def redmule_matmul_batched_pallas(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    tile: tiling.TileConfig,
    policy: prec.Policy,
    epilogue: Optional[str] = None,
    layout: str = "nn",
    interpret: bool = False,
) -> jax.Array:
    """Z[b] = act(X[b] @ W[b] + bias) with the batch as a leading grid dim.

    Unlike a ``vmap`` wrapper (which multiplies the VMEM working set by B
    and hides the batch from the scheduler), the batch here is just the
    outermost parallel grid axis: one X/W/Z tile set is live at a time, so
    the tile choice sees the true per-core working set.

    ``bias`` (optional) is a ``(1, 1, K)`` row in the accumulation dtype,
    shared across the batch, folded — with ``epilogue`` — into the
    store-once step exactly like the 2D kernel (the PR-2 follow-up gap:
    the batched grid fuses the full bias+activation epilogue now)."""
    _check_layout(layout)
    B = x.shape[0]
    assert w.shape[0] == B, (x.shape, w.shape)
    M, N, K = _logical_dims(x.shape[1:], w.shape[1:], layout)
    assert M % tile.bm == 0 and N % tile.bn == 0 and K % tile.bk == 0, (
        f"shapes {(M, N, K)} not padded to tiles {tile}"
    )
    if bias is not None:
        assert bias.shape == (1, 1, K), (bias.shape, K)
    grid = (B, M // tile.bm, K // tile.bk, N // tile.bn)

    in_specs = list(_operand_specs_batched(tile, layout))
    operands = [x, w]
    if bias is None:
        kernel = functools.partial(_kernel_batched, n_tiles=grid[3],
                                   out_dtype=policy.out_dtype,
                                   epilogue=epilogue, layout=layout)
    else:
        kernel = functools.partial(_kernel_batched_bias, n_tiles=grid[3],
                                   out_dtype=policy.out_dtype,
                                   epilogue=epilogue, layout=layout)
        in_specs.append(pl.BlockSpec((1, 1, tile.bk),
                                     lambda b, i, j, k: (0, 0, j)))
        operands.append(bias)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tile.bm, tile.bk),
                               lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, M, K), policy.out_dtype),
        scratch_shapes=[pltpu.VMEM((tile.bm, tile.bk), policy.accum_dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
        name=f"redmule_matmul_batched_{layout}",
    )(*operands)
