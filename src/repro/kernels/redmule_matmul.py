"""RedMulE matmul as a Pallas TPU kernel.

The paper's dataflow (§II-B/C), re-derived for the TPU memory hierarchy
(DESIGN.md §2):

* the 2D kernel runs a grid of (M/bm, K/bk) Z tiles; the contraction (N)
  is an **in-kernel double-buffered K-loop**: every reduction step's X and
  W tiles are DMA'd from HBM into ``pipeline_depth`` VMEM scratch slots,
  and the DMA for step ``r+1`` is issued *before* the FMA step for ``r``
  runs — an explicit re-creation of the Streamer's interleaved load
  schedule (the X tile for a given (m, k) stays resident logically; the
  prefetch hides the W-stream latency behind the MXU);
* the Z tile lives in a VMEM scratch accumulator for the entire reduction
  and is written to HBM exactly once, after the loop (the Z-buffer
  store-once rule);
* the accumulator is fp32 by default (MXU-native) or fp16 re-rounded per
  N-block in ``paper_faithful`` mode (the binary16 in-pipeline accumulation
  error model);
* the **forward epilogue is fused**: when a bias row and/or activation name
  is given, ``act(acc + bias)`` is applied to the accumulator *in the
  accumulation dtype* inside the store-once step, so an affine layer costs
  exactly one HBM write — the GEMM-*layer* datapath of the follow-up
  RedMule engine paper (arXiv:2301.03904), not a GEMM unit plus a separate
  HBM round-trip;
* the **backward epilogue is fused too** (the ``"fused_bwd_epilogue"``
  backend capability): a backward dispatch may carry a ``deriv`` operand —
  the fused forward output (``grad_from_output=True``: relu/tanh) or the
  saved pre-activation (gelu/silu) — and the kernel applies ``ds = dZ *
  act'(deriv)`` to the dZ tile **on load**, in the accumulation dtype, so
  the pre-activation cotangent ``ds`` is never materialized in HBM.  With
  ``bias_grad=True`` (the dW "tn" dispatch) the kernel also accumulates
  ``db = Σ_rows ds`` into a second accum-dtype output in the same pass,
  eliminating the separate bias-grad reduction;
* **per-operand storage dtypes** (the mixed-precision RedMulE,
  arXiv:2301.03904): operands may arrive narrower than the compute dtype
  (FP8 ``float8_e4m3fn`` / ``float8_e5m2`` under the mixed policies) —
  tiles DMA from HBM in their storage width and are upcast to the compute
  dtype **on load**, inside the K-loop, so the HBM stream (and the VMEM
  slots) stay narrow and no cast pass ever materializes the wide operand.
  This composes with the fused backward epilogue (an FP8 dZ stream is
  widened, multiplied by ``act'`` and fed to the MXU tile-wise) and with
  every layout.  Per-tensor scales are the *engine's* job
  (:mod:`repro.core.engine` applies/undoes them around the dispatch) —
  the kernel only ever sees the already-quantized integers-in-fp8;
* batched operands get a leading **batch grid dimension**
  (:func:`redmule_matmul_batched_pallas`) instead of a ``vmap`` wrapper, so
  the tile choice and the Pallas pipeline see the true per-core working set
  (one X/W/Z tile set, not B concurrent copies);
* **transpose layouts** serve the backward pass without materialized
  transposes: the logical GEMM is always ``Z[M, K] = Σ_N X·W``, and
  ``layout`` names how the operands are *stored* — ``"nn"`` (x: (M, N),
  w: (N, K), the forward), ``"nt"`` (w stored (K, N); dX = dZ·Wᵀ reads W
  in its forward layout) and ``"tn"`` (x stored (N, M); dW = Xᵀ·dZ reads
  the saved activations in their forward layout).  Only the DMA index
  arithmetic and the in-kernel ``dot_general`` dimension numbers change;
  the store-once schedule — and therefore the accumulator error model —
  is identical in all three.

Shapes must be pre-padded to tile multiples by ``ops.py``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import epilogues as epi
from repro.core import precision as prec
from repro.core import tiling
from repro.kernels import CompilerParams as _CompilerParams

__all__ = ["redmule_matmul_pallas", "redmule_matmul_batched_pallas", "LAYOUTS"]

# storage layouts of the logical Z[M,K] = X[M,N] @ W[N,K] contraction:
#   nn: x (M, N), w (N, K)   — forward
#   nt: x (M, N), w (K, N)   — dX = dZ @ W^T (w in forward storage)
#   tn: x (N, M), w (N, K)   — dW = X^T @ dZ (x in forward storage)
LAYOUTS = ("nn", "nt", "tn")

# in-kernel contraction dimension numbers per layout (2D tiles)
_DIMS = {
    "nn": (((1,), (0,)), ((), ())),
    "nt": (((1,), (1,)), ((), ())),
    "tn": (((0,), (0,)), ((), ())),
}


def _check_layout(layout: str) -> None:
    if layout not in LAYOUTS:
        raise ValueError(f"unknown layout {layout!r}; known: {LAYOUTS}")


def _store_value(acc, bias, *, epilogue, out_dtype):
    """The fused store-once epilogue: ``act(acc + bias)`` in the accumulator
    dtype, then a single downcast to the stored dtype.

    In ``paper_faithful`` mode the accumulator is fp16, so the epilogue runs
    in binary16 too — the whole layer stays inside the paper's datapath."""
    if bias is not None:
        acc = acc + bias.astype(acc.dtype)
    acc = epi.apply_epilogue(epilogue, acc)
    return acc.astype(out_dtype)


def _logical_dims(x_shape, w_shape, layout: str):
    """(M, N, K) of the logical contraction from stored operand shapes."""
    if layout == "nn":
        (M, N), (_, K) = x_shape, w_shape
    elif layout == "nt":
        (M, N), (K, _) = x_shape, w_shape
    else:  # tn
        (N, M), (_, K) = x_shape, w_shape
    return M, N, K


def _deriv_on(layout: str) -> Optional[str]:
    """Which operand slot holds dZ in a backward dispatch: the x slot for
    "nt" (dX = dZ·Wᵀ), the w slot for "tn" (dW = Xᵀ·dZ)."""
    return {"nt": "x", "tn": "w"}.get(layout)


def _pipelined_kernel(*refs, n_steps: int, depth: int, tile, layout: str,
                      out_dtype, compute_dtype, epilogue: Optional[str],
                      grad_epilogue: Optional[str], grad_from_output: bool,
                      bias_grad: bool, has_bias: bool):
    """One (bm, bk) Z tile: the whole N-reduction as a double-buffered
    in-kernel loop.

    Operand tiles are DMA'd from HBM into ``depth`` VMEM slots; the copy
    for step ``r+1`` is issued before the FMA for step ``r`` runs, so the
    load of the next K-step overlaps the MXU (the Streamer's interleaved
    schedule, made explicit).  When ``grad_epilogue`` is set the dZ tile is
    multiplied by ``act'(deriv tile)`` in the accumulation dtype right
    after its load — ``ds`` exists only tile-wise in VMEM, never in HBM;
    ``bias_grad`` additionally accumulates ``db = Σ_rows ds`` into a second
    accum-dtype output in the same pass."""
    bm, bn, bk = tile.bm, tile.bn, tile.bk
    has_deriv = grad_epilogue is not None
    # positional ref parse: inputs, outputs, scratch (pallas ordering)
    x_hbm, w_hbm = refs[0], refs[1]
    pos = 2
    bias_ref = None
    if has_bias:
        bias_ref = refs[pos]
        pos += 1
    d_hbm = None
    if has_deriv:
        d_hbm = refs[pos]
        pos += 1
    z_ref = refs[pos]
    pos += 1
    db_ref = None
    if bias_grad:
        db_ref = refs[pos]
        pos += 1
    acc_ref, xbuf, wbuf = refs[pos], refs[pos + 1], refs[pos + 2]
    pos += 3
    dbuf = None
    if has_deriv:
        dbuf = refs[pos]
        pos += 1
    db_acc = None
    if bias_grad:
        db_acc = refs[pos]
        pos += 1
    sems = refs[pos]

    i = pl.program_id(0)
    j = pl.program_id(1)
    deriv_on = _deriv_on(layout)

    def _x_dma(slot, r):
        if layout == "tn":
            src = x_hbm.at[pl.ds(r * bn, bn), pl.ds(i * bm, bm)]
        else:
            src = x_hbm.at[pl.ds(i * bm, bm), pl.ds(r * bn, bn)]
        return pltpu.make_async_copy(src, xbuf.at[slot], sems.at[slot, 0])

    def _w_dma(slot, r):
        if layout == "nt":
            src = w_hbm.at[pl.ds(j * bk, bk), pl.ds(r * bn, bn)]
        else:
            src = w_hbm.at[pl.ds(r * bn, bn), pl.ds(j * bk, bk)]
        return pltpu.make_async_copy(src, wbuf.at[slot], sems.at[slot, 1])

    def _d_dma(slot, r):
        # the deriv tile shadows the dZ operand's walk exactly
        if deriv_on == "x":
            src = d_hbm.at[pl.ds(i * bm, bm), pl.ds(r * bn, bn)]
        else:
            src = d_hbm.at[pl.ds(r * bn, bn), pl.ds(j * bk, bk)]
        return pltpu.make_async_copy(src, dbuf.at[slot], sems.at[slot, 2])

    def _dmas(slot, r):
        cps = [_x_dma(slot, r), _w_dma(slot, r)]
        if has_deriv:
            cps.append(_d_dma(slot, r))
        return cps

    acc_ref[...] = jnp.zeros_like(acc_ref)
    if db_acc is not None:
        db_acc[...] = jnp.zeros_like(db_acc)
    # pipeline prologue: fill depth-1 slots ahead (the classic schedule —
    # at steady state depth-1 DMAs are in flight while one slot computes)
    for r0 in range(min(depth - 1, n_steps)):
        for c in _dmas(r0, r0):
            c.start()

    def _step(r, carry):
        slot = jax.lax.rem(r, depth)
        ahead = r + depth - 1

        # prefetch the step that lands in the slot just freed by step r-1,
        # keeping the pipeline depth-1 steps ahead of the FMA
        @pl.when(ahead < n_steps)
        def _prefetch():
            for c in _dmas(jax.lax.rem(ahead, depth), ahead):
                c.start()

        for c in _dmas(slot, r):
            c.wait()
        # per-operand storage: tiles DMA in their HBM dtype (FP8 under the
        # mixed-precision policies) and are upcast to the compute dtype
        # **on load**, right here in VMEM — no HBM-side cast pass ever
        # materializes the wide operand (the mixed-precision RedMulE's
        # input-cast stage, arXiv:2301.03904)
        xt = xbuf[slot]
        wt = wbuf[slot]
        if xt.dtype != compute_dtype:
            xt = xt.astype(compute_dtype)
        if wt.dtype != compute_dtype:
            wt = wt.astype(compute_dtype)
        if has_deriv or bias_grad:
            # the fused backward epilogue: ds = dZ * act'(deriv), applied
            # on load in the accumulation dtype (the same dtype chain as
            # the engine's two-pass fallback), then one downcast feeds the
            # MXU.  ds never exists outside this VMEM tile.
            dz_t = xt if deriv_on == "x" else wt
            dsa = dz_t.astype(acc_ref.dtype)
            if has_deriv:
                g = epi.epilogue_grad(grad_epilogue)
                d = dbuf[slot].astype(acc_ref.dtype)
                dsa = dsa * (g.deriv_from_output(d) if grad_from_output
                             else g.deriv(d))
            if db_acc is not None:
                db_acc[...] += jnp.sum(dsa, axis=0, keepdims=True)
            ds_t = dsa.astype(compute_dtype)
            if deriv_on == "x":
                xt = ds_t
            else:
                wt = ds_t
        # The MXU step; in faithful-fp16 mode acc_ref is fp16 so the +=
        # re-rounds to binary16 every block, like the paper's FMA feedback
        # path.  The layout only changes which operand axes contract.
        acc_ref[...] += jax.lax.dot_general(
            xt, wt, _DIMS[layout],
            preferred_element_type=acc_ref.dtype,
        )
        return carry

    jax.lax.fori_loop(0, n_steps, _step, 0)
    z_ref[...] = _store_value(
        acc_ref[...], None if bias_ref is None else bias_ref[...],
        epilogue=epilogue, out_dtype=out_dtype)
    if db_ref is not None:
        db_ref[...] = db_acc[...]


def _stored_tile_shapes(tile: tiling.TileConfig, layout: str):
    """((x tile), (w tile)) in *stored* orientation for one layout."""
    if layout == "nn":
        return (tile.bm, tile.bn), (tile.bn, tile.bk)
    if layout == "nt":
        return (tile.bm, tile.bn), (tile.bk, tile.bn)
    return (tile.bn, tile.bm), (tile.bn, tile.bk)  # tn


@functools.partial(
    jax.jit,
    static_argnames=("tile", "policy", "epilogue", "layout", "grad_epilogue",
                     "grad_from_output", "bias_grad", "pipeline_depth",
                     "interpret"),
)
def redmule_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    deriv: Optional[jax.Array] = None,
    *,
    tile: tiling.TileConfig,
    policy: prec.Policy,
    epilogue: Optional[str] = None,
    layout: str = "nn",
    grad_epilogue: Optional[str] = None,
    grad_from_output: bool = False,
    bias_grad: bool = False,
    pipeline_depth: int = 2,
    interpret: bool = False,
):
    """Z = act(X @ W + bias) for 2D operands already padded to tile multiples.

    ``bias`` (optional) is a ``(1, K)`` row in the accumulation dtype;
    ``epilogue`` (optional) names an activation from
    :mod:`repro.core.epilogues`.  Both are applied inside the kernel's
    store-once step (no extra HBM pass).  ``layout`` selects the operand
    storage (see module docstring); the output is always ``(M, K)``.

    Backward fusion (the Engine's ``"fused_bwd_epilogue"`` capability):
    ``grad_epilogue`` + ``deriv`` apply ``act'`` to the dZ operand's tiles
    on load (``grad_from_output`` picks the output-form derivative;
    ``deriv`` must be stored exactly like the dZ operand — the x slot for
    "nt", the w slot for "tn").  ``bias_grad=True`` (only meaningful on the
    "tn" dW dispatch) returns ``(Z, db)`` where ``db`` is a
    ``(M/bm, K)`` accum-dtype array whose every row is the full
    ``Σ_rows ds`` (each grid row sweeps the whole reduction; callers take
    row 0).  ``pipeline_depth`` sets the number of buffer slots of the
    in-kernel K-loop: 1 = single-buffered (each step's DMA issues and
    completes before its FMA — no overlap, the minimal-VMEM schedule),
    2 = classic double buffering, deeper = more DMAs in flight."""
    _check_layout(layout)
    M, N, K = _logical_dims(x.shape, w.shape, layout)
    if layout == "nn":
        assert x.shape[1] == w.shape[0], (x.shape, w.shape)
    elif layout == "nt":
        assert x.shape[1] == w.shape[1], (x.shape, w.shape)
    else:
        assert x.shape[0] == w.shape[0], (x.shape, w.shape)
    assert M % tile.bm == 0 and N % tile.bn == 0 and K % tile.bk == 0, (
        f"shapes {(M, N, K)} not padded to tiles {tile}"
    )
    if bias is not None:
        assert bias.shape == (1, K), (bias.shape, K)
    if grad_epilogue is not None:
        assert layout in ("nt", "tn"), \
            "the fused backward epilogue is a transpose-layout contract"
        want = x.shape if _deriv_on(layout) == "x" else w.shape
        assert deriv is not None and deriv.shape == want, \
            (None if deriv is None else deriv.shape, want)
    if bias_grad:
        assert layout == "tn", "bias_grad rides on the dW (tn) dispatch"
    depth = max(1, int(pipeline_depth))
    grid = (M // tile.bm, K // tile.bk)
    n_steps = N // tile.bn
    x_tile, w_tile = _stored_tile_shapes(tile, layout)

    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY)]
    operands = [x, w]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, tile.bk), lambda i, j: (0, j)))
        operands.append(bias)
    if grad_epilogue is not None:
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
        operands.append(deriv)

    out_shape = [jax.ShapeDtypeStruct((M, K), policy.out_dtype)]
    out_specs = [pl.BlockSpec((tile.bm, tile.bk), lambda i, j: (i, j))]
    if bias_grad:
        out_shape.append(
            jax.ShapeDtypeStruct((grid[0], K), policy.accum_dtype))
        out_specs.append(pl.BlockSpec((1, tile.bk), lambda i, j: (i, j)))

    scratch = [pltpu.VMEM((tile.bm, tile.bk), policy.accum_dtype),
               pltpu.VMEM((depth, *x_tile), x.dtype),
               pltpu.VMEM((depth, *w_tile), w.dtype)]
    n_streams = 2
    if grad_epilogue is not None:
        d_tile = x_tile if _deriv_on(layout) == "x" else w_tile
        scratch.append(pltpu.VMEM((depth, *d_tile), deriv.dtype))
        n_streams = 3
    if bias_grad:
        scratch.append(pltpu.VMEM((1, tile.bk), policy.accum_dtype))
    scratch.append(pltpu.SemaphoreType.DMA((depth, n_streams)))

    kernel = functools.partial(
        _pipelined_kernel, n_steps=n_steps, depth=depth, tile=tile,
        layout=layout, out_dtype=policy.out_dtype,
        compute_dtype=policy.compute_dtype, epilogue=epilogue,
        grad_epilogue=grad_epilogue, grad_from_output=grad_from_output,
        bias_grad=bias_grad, has_bias=bias is not None)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs if bias_grad else out_specs[0],
        out_shape=out_shape if bias_grad else out_shape[0],
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name=f"redmule_matmul_{layout}",
    )(*operands)
    return out


def _load_compute(ref_tile, compute_dtype):
    """Upcast a loaded operand tile to the compute dtype (FP8 storage under
    the mixed-precision policies; a no-op for uniform policies)."""
    if ref_tile.dtype != compute_dtype:
        return ref_tile.astype(compute_dtype)
    return ref_tile


def _kernel_batched(x_ref, w_ref, z_ref, acc_ref, *, n_tiles: int, out_dtype,
                    compute_dtype, epilogue: Optional[str], layout: str):
    """The same X-stationary schedule under a leading batch grid dim.

    Block refs carry a unit batch dim ((1, bm, bn) etc.); the reduction is
    grid axis 3.  Operand tiles arrive in their storage dtype and are
    upcast to ``compute_dtype`` on load."""

    @pl.when(pl.program_id(3) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        _load_compute(x_ref[0], compute_dtype),
        _load_compute(w_ref[0], compute_dtype), _DIMS[layout],
        preferred_element_type=acc_ref.dtype,
    )

    @pl.when(pl.program_id(3) == n_tiles - 1)
    def _store_once():
        z_ref[0] = _store_value(acc_ref[...], None, epilogue=epilogue,
                                out_dtype=out_dtype)


def _kernel_batched_bias(x_ref, w_ref, bias_ref, z_ref, acc_ref, *,
                         n_tiles: int, out_dtype, compute_dtype,
                         epilogue: Optional[str], layout: str):
    """Batched schedule with the shared (1, 1, bk) bias row in the store."""

    @pl.when(pl.program_id(3) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        _load_compute(x_ref[0], compute_dtype),
        _load_compute(w_ref[0], compute_dtype), _DIMS[layout],
        preferred_element_type=acc_ref.dtype,
    )

    @pl.when(pl.program_id(3) == n_tiles - 1)
    def _store_once():
        z_ref[0] = _store_value(acc_ref[...], bias_ref[0],
                                epilogue=epilogue, out_dtype=out_dtype)


def _operand_specs_batched(tile: tiling.TileConfig, layout: str):
    if layout == "nn":
        return (pl.BlockSpec((1, tile.bm, tile.bn),
                             lambda b, i, j, k: (b, i, k)),
                pl.BlockSpec((1, tile.bn, tile.bk),
                             lambda b, i, j, k: (b, k, j)))
    if layout == "nt":
        return (pl.BlockSpec((1, tile.bm, tile.bn),
                             lambda b, i, j, k: (b, i, k)),
                pl.BlockSpec((1, tile.bk, tile.bn),
                             lambda b, i, j, k: (b, j, k)))
    # tn
    return (pl.BlockSpec((1, tile.bn, tile.bm),
                         lambda b, i, j, k: (b, k, i)),
            pl.BlockSpec((1, tile.bn, tile.bk),
                         lambda b, i, j, k: (b, k, j)))


@functools.partial(
    jax.jit,
    static_argnames=("tile", "policy", "epilogue", "layout", "interpret"),
)
def redmule_matmul_batched_pallas(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    tile: tiling.TileConfig,
    policy: prec.Policy,
    epilogue: Optional[str] = None,
    layout: str = "nn",
    interpret: bool = False,
) -> jax.Array:
    """Z[b] = act(X[b] @ W[b] + bias) with the batch as a leading grid dim.

    Unlike a ``vmap`` wrapper (which multiplies the VMEM working set by B
    and hides the batch from the scheduler), the batch here is just the
    outermost parallel grid axis: one X/W/Z tile set is live at a time, so
    the tile choice sees the true per-core working set.

    ``bias`` (optional) is a ``(1, 1, K)`` row in the accumulation dtype,
    shared across the batch, folded — with ``epilogue`` — into the
    store-once step exactly like the 2D kernel (the PR-2 follow-up gap:
    the batched grid fuses the full bias+activation epilogue now)."""
    _check_layout(layout)
    B = x.shape[0]
    assert w.shape[0] == B, (x.shape, w.shape)
    M, N, K = _logical_dims(x.shape[1:], w.shape[1:], layout)
    assert M % tile.bm == 0 and N % tile.bn == 0 and K % tile.bk == 0, (
        f"shapes {(M, N, K)} not padded to tiles {tile}"
    )
    if bias is not None:
        assert bias.shape == (1, 1, K), (bias.shape, K)
    grid = (B, M // tile.bm, K // tile.bk, N // tile.bn)

    in_specs = list(_operand_specs_batched(tile, layout))
    operands = [x, w]
    if bias is None:
        kernel = functools.partial(_kernel_batched, n_tiles=grid[3],
                                   out_dtype=policy.out_dtype,
                                   compute_dtype=policy.compute_dtype,
                                   epilogue=epilogue, layout=layout)
    else:
        kernel = functools.partial(_kernel_batched_bias, n_tiles=grid[3],
                                   out_dtype=policy.out_dtype,
                                   compute_dtype=policy.compute_dtype,
                                   epilogue=epilogue, layout=layout)
        in_specs.append(pl.BlockSpec((1, 1, tile.bk),
                                     lambda b, i, j, k: (0, 0, j)))
        operands.append(bias)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tile.bm, tile.bk),
                               lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, M, K), policy.out_dtype),
        scratch_shapes=[pltpu.VMEM((tile.bm, tile.bk), policy.accum_dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
        name=f"redmule_matmul_batched_{layout}",
    )(*operands)
