"""RedMulE matmul as a Pallas TPU kernel.

The paper's dataflow (§II-B/C), re-derived for the TPU memory hierarchy
(DESIGN.md §2):

* grid = (M/bm, K/bk, N/bn) with the contraction (N) innermost and marked
  ``arbitrary`` — the X tile for a given (m, k) stays resident across the
  whole N sweep (X-stationary) while W tiles stream through VMEM
  (W-streaming), double-buffered by the Pallas pipeline (the Streamer's
  interleaved load schedule);
* the Z tile lives in a VMEM scratch accumulator for the entire reduction
  and is written to HBM exactly once, on the last N step (the Z-buffer
  store-once rule);
* the accumulator is fp32 by default (MXU-native) or fp16 re-rounded per
  N-block in ``paper_faithful`` mode (the binary16 in-pipeline accumulation
  error model).

Shapes must be pre-padded to tile multiples by ``ops.py``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

from repro.core import precision as prec
from repro.core import tiling

__all__ = ["redmule_matmul_pallas"]


def _kernel(x_ref, w_ref, z_ref, acc_ref, *, n_tiles: int, out_dtype):
    """One (bm, bk) Z tile; invoked n_tiles times along the reduction."""

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The MXU step: X tile (held steady) x streamed W tile. The partial
    # product is accumulated on-array; in faithful-fp16 mode acc_ref is
    # fp16 so the += re-rounds to binary16 every block, like the paper's
    # FMA feedback path.
    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=acc_ref.dtype
    )

    @pl.when(pl.program_id(2) == n_tiles - 1)
    def _store_once():
        z_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("tile", "policy", "interpret"),
)
def redmule_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    tile: tiling.TileConfig,
    policy: prec.Policy,
    interpret: bool = False,
) -> jax.Array:
    """Z = X @ W for 2D operands already padded to tile multiples."""
    M, N = x.shape
    N2, K = w.shape
    assert N == N2, (x.shape, w.shape)
    assert M % tile.bm == 0 and N % tile.bn == 0 and K % tile.bk == 0, (
        f"shapes {(M, N, K)} not padded to tiles {tile}"
    )
    grid = (M // tile.bm, K // tile.bk, N // tile.bn)

    return pl.pallas_call(
        functools.partial(_kernel, n_tiles=grid[2], out_dtype=policy.out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile.bm, tile.bn), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile.bn, tile.bk), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tile.bm, tile.bk), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, K), policy.out_dtype),
        scratch_shapes=[pltpu.VMEM((tile.bm, tile.bk), policy.accum_dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="redmule_matmul",
    )(x, w)
