"""Pallas TPU kernels — the Engine's accelerator backends.

The GEMM surface lives in :mod:`repro.core.engine`; this package provides
the kernel bodies the registered "pallas" and "interpret" backends execute
(the registry entry, not this package, is the dispatch point — third-party
backends register alongside these without touching kernel code).

* redmule_matmul.py -- the paper's engine: X-stationary / W-streamed tiled
  GEMM with a VMEM scratch accumulator (store-once Z), the bias+activation
  epilogue fused into the store step, and a leading batch grid dimension
  for batched operands.  ops.py wraps it (padding, tile choice, epilogue
  plumbing); ref.py holds the pure-jnp oracles.
* flash_attention.py -- RedMulE-tiled attention (Q-stationary, K/V streamed,
  online-softmax accumulator) for long-context prefill.
* chunked_linear_attention.py -- VMEM-resident-state chunked recurrence
  (mLSTM / SSD), the store-once rule applied to linear attention.
"""

from jax.experimental.pallas import tpu as _pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases; every
# kernel in this package uses this one alias
CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or _pltpu.TPUCompilerParams
