"""Pallas TPU kernels for the paper's compute hot-spots.

* redmule_matmul.py -- the paper's engine: X-stationary / W-streamed tiled
  GEMM with a VMEM scratch accumulator (store-once Z).  ops.py wraps it
  (padding, tile choice, batching); ref.py holds the pure-jnp oracles.
* flash_attention.py -- RedMulE-tiled attention (Q-stationary, K/V streamed,
  online-softmax accumulator) for long-context prefill.
"""
