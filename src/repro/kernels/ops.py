"""jit'd wrappers around the Pallas GEMM kernel — the bodies behind the
Engine's registered "pallas" / "interpret" backends.

Handles padding to tile multiples (zeros are accumulation-neutral), tile
selection via :mod:`repro.core.tiling`, and batching (vmap adds a leading
grid dimension to the kernel).  Model code should not call these directly:
route through :mod:`repro.core.engine` so dispatches are instrumented and
backend-switchable.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import precision as prec
from repro.core import tiling
from repro.kernels.redmule_matmul import redmule_matmul_pallas

__all__ = ["redmule_matmul", "redmule_matmul_batched"]


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - x.shape[-2], cols - x.shape[-1]
    if pr == 0 and pc == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 2) + [(0, pr), (0, pc)]
    return jnp.pad(x, pad)


def _padded_dims(M: int, N: int, K: int, t: tiling.TileConfig):
    up = lambda v, b: -(-v // b) * b
    return up(M, t.bm), up(N, t.bn), up(K, t.bk)


def redmule_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    policy: prec.Policy,
    tile: Optional[tiling.TileConfig] = None,
    interpret: bool = False,
) -> jax.Array:
    """2D Z = X @ W on the RedMulE kernel (pads, runs, slices)."""
    M, N = x.shape
    K = w.shape[1]
    if tile is None:
        tile = tiling.choose_tiles(
            M, N, K, compute_dtype=policy.compute_dtype, accum_dtype=policy.accum_dtype
        )
    Mp, Np, Kp = _padded_dims(M, N, K, tile)
    xp = _pad_to(x, Mp, Np)
    wp = _pad_to(w, Np, Kp)
    z = redmule_matmul_pallas(xp, wp, tile=tile, policy=policy, interpret=interpret)
    return z[:M, :K]


def redmule_matmul_batched(
    x: jax.Array,
    w: jax.Array,
    *,
    policy: prec.Policy,
    tile: Optional[tiling.TileConfig] = None,
    interpret: bool = False,
) -> jax.Array:
    """Batched Z[b] = X[b] @ W[b]; x: (B, M, N), w: (B, N, K)."""
    B, M, N = x.shape
    K = w.shape[2]
    if tile is None:
        tile = tiling.choose_tiles(
            M, N, K, compute_dtype=policy.compute_dtype, accum_dtype=policy.accum_dtype
        )
    Mp, Np, Kp = _padded_dims(M, N, K, tile)
    xp = _pad_to(x, Mp, Np)
    wp = _pad_to(w, Np, Kp)
    run = functools.partial(
        redmule_matmul_pallas, tile=tile, policy=policy, interpret=interpret
    )
    z = jax.vmap(run)(xp, wp)
    return z[:, :M, :K]
