"""jit'd wrappers around the Pallas GEMM kernel — the bodies behind the
Engine's registered "pallas" / "interpret" backends.

Handles padding to tile multiples (zeros are accumulation-neutral and the
registered epilogues all map 0 -> finite values that the final slice
discards), tile selection via :mod:`repro.core.tiling`, the fused
bias+activation epilogue, batching (a leading batch grid dimension inside
the kernel — not a ``vmap`` wrapper — so the tile choice sees the true
per-core working set), the transpose **layouts** the Engine's backward
pass dispatches (``"nt"`` for dX = dZ·Wᵀ, ``"tn"`` for dW = Xᵀ·dZ — the
operands stay in their forward storage, no materialized transpose), and
the **fused backward epilogue** (``deriv``/``grad_epilogue``/``bias_grad``:
act′ applied to the dZ tiles on load, the bias grad accumulated as a
second output of the dW pass — the Engine's ``"fused_bwd_epilogue"``
capability; see :mod:`repro.kernels.redmule_matmul`), and **per-operand
storage dtypes** (the ``"operand_dtypes"`` capability: FP8 operands pad
and stream at one byte per element, the kernel upcasts tiles to the
compute dtype on load; the tile chooser sizes the VMEM working set at the
true storage widths).  Model code should
not call these directly: route through :mod:`repro.core.engine` so
dispatches are instrumented and backend-switchable.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import precision as prec
from repro.core import tiling
from repro.kernels.redmule_matmul import (_check_layout,
                                          _logical_dims as _kernel_logical_dims,
                                          redmule_matmul_batched_pallas,
                                          redmule_matmul_pallas)

__all__ = ["redmule_matmul", "redmule_matmul_batched"]


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - x.shape[-2], cols - x.shape[-1]
    if pr == 0 and pc == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 2) + [(0, pr), (0, pc)]
    return jnp.pad(x, pad)


def _padded_dims(M: int, N: int, K: int, t: tiling.TileConfig):
    up = lambda v, b: -(-v // b) * b
    return up(M, t.bm), up(N, t.bn), up(K, t.bk)


def _logical_dims(x: jax.Array, w: jax.Array, layout: str) -> Tuple[int, int, int]:
    """(M, N, K) of the logical Z[M,K] = Σ_N X·W from stored shapes —
    the kernel module's mapping, applied to the trailing 2D of each
    operand (one source of truth for what each layout stores where)."""
    _check_layout(layout)
    return _kernel_logical_dims(x.shape[-2:], w.shape[-2:], layout)


def _pad_operands(x: jax.Array, w: jax.Array, layout: str,
                  Mp: int, Np: int, Kp: int) -> Tuple[jax.Array, jax.Array]:
    """Pad each *stored* operand so the logical dims hit (Mp, Np, Kp)."""
    if layout == "nn":
        return _pad_to(x, Mp, Np), _pad_to(w, Np, Kp)
    if layout == "nt":
        return _pad_to(x, Mp, Np), _pad_to(w, Kp, Np)
    return _pad_to(x, Np, Mp), _pad_to(w, Np, Kp)  # tn


def redmule_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    policy: prec.Policy,
    tile: Optional[tiling.TileConfig] = None,
    bias: Optional[jax.Array] = None,
    epilogue: Optional[str] = None,
    layout: str = "nn",
    deriv: Optional[jax.Array] = None,
    grad_epilogue: Optional[str] = None,
    grad_from_output: bool = False,
    bias_grad: bool = False,
    pipeline_depth: int = 2,
    interpret: bool = False,
):
    """2D Z = act(X @ W + bias) on the RedMulE kernel (pads, runs, slices).

    ``bias`` (optional, shape ``(K,)`` or ``(1, K)``) and ``epilogue``
    (optional activation name) are fused into the kernel's store-once step
    in the accumulation dtype — the affine layer costs one HBM write.
    ``layout`` names the operand storage of the logical contraction
    ("nn" | "nt" | "tn"); the result is always the logical ``(M, K)``.

    Backward fusion (the ``"fused_bwd_epilogue"`` capability, transpose
    layouts only): ``grad_epilogue``/``grad_from_output`` + ``deriv``
    multiply the dZ operand's tiles by ``act'(deriv)`` on load inside the
    kernel (``deriv`` stored exactly like the dZ operand: the x slot for
    "nt", the w slot for "tn"); ``bias_grad=True`` (the dW "tn" dispatch)
    returns ``(dW, db)`` with ``db`` the accum-dtype ``(K,)`` row sum of
    the (derivative-adjusted) dZ rows, accumulated in the same pass."""
    M, N, K = _logical_dims(x, w, layout)
    if M == 0 or K == 0 or N == 0:
        # degenerate GEMM (e.g. an empty ragged group): an empty — or, for
        # N == 0, all-zero — result with no kernel launch.  The fused
        # epilogue still applies (act(0 + bias) for N == 0).
        z = jnp.zeros((M, K), policy.accum_dtype)
        if bias is not None:
            z = z + bias.reshape(1, K).astype(policy.accum_dtype)
        if epilogue is not None:
            from repro.core import epilogues as epi
            z = epi.apply_epilogue(epilogue, z)
        if bias_grad:
            # db = Σ_rows ds is independent of the degenerate output dims
            # (m == 0 just means dW has no rows); reduce eagerly.
            dsa = w.astype(policy.accum_dtype)   # tn: the dZ operand
            if grad_epilogue is not None:
                from repro.core import epilogues as epi
                g = epi.epilogue_grad(grad_epilogue)
                d = deriv.astype(policy.accum_dtype)
                dsa = dsa * (g.deriv_from_output(d) if grad_from_output
                             else g.deriv(d))
            db = (dsa.sum(axis=0) if dsa.size
                  else jnp.zeros((K,), policy.accum_dtype))
            return z.astype(policy.out_dtype), db
        return z.astype(policy.out_dtype)
    if tile is None:
        tile = tiling.choose_tiles(
            M, N, K, compute_dtype=policy.compute_dtype,
            accum_dtype=policy.accum_dtype,
            fused_bwd=grad_epilogue is not None or bias_grad,
            x_dtype=x.dtype, w_dtype=w.dtype,
        )
    Mp, Np, Kp = _padded_dims(M, N, K, tile)
    xp, wp = _pad_operands(x, w, layout, Mp, Np, Kp)
    bp = None
    if bias is not None:
        bp = _pad_to(bias.reshape(1, K).astype(policy.accum_dtype), 1, Kp)
    dp = None
    if grad_epilogue is not None:
        # the deriv operand pads like the dZ operand it shadows (zero rows
        # multiply a zero dZ padding, so the padding stays neutral)
        dp = (_pad_to(deriv, Mp, Np) if layout == "nt"
              else _pad_to(deriv, Np, Kp))
    out = redmule_matmul_pallas(xp, wp, bp, dp, tile=tile, policy=policy,
                                epilogue=epilogue, layout=layout,
                                grad_epilogue=grad_epilogue,
                                grad_from_output=grad_from_output,
                                bias_grad=bias_grad,
                                pipeline_depth=pipeline_depth,
                                interpret=interpret)
    if bias_grad:
        z, db = out
        return z[:M, :K], db[0, :K]
    return out[:M, :K]


def redmule_matmul_batched(
    x: jax.Array,
    w: jax.Array,
    *,
    policy: prec.Policy,
    tile: Optional[tiling.TileConfig] = None,
    bias: Optional[jax.Array] = None,
    epilogue: Optional[str] = None,
    layout: str = "nn",
    interpret: bool = False,
) -> jax.Array:
    """Batched Z[b] = act(X[b] @ W[b] + bias); e.g. x: (B, M, N), w: (B, N, K).

    The batch rides as the kernel's leading grid dimension (one tile set
    live at a time), not as a ``vmap`` that would multiply the VMEM
    working set by B behind the tile chooser's back.  ``bias`` (optional,
    shape ``(K,)`` or ``(1, K)``, shared across the batch) and ``epilogue``
    are fused into the store-once step like the 2D path; ``layout`` selects
    the operand storage ("nn" | "nt" | "tn")."""
    B = x.shape[0]
    M, N, K = _logical_dims(x, w, layout)
    if B == 0 or M == 0 or K == 0 or N == 0:
        z = jnp.zeros((B, M, K), policy.accum_dtype)
        if bias is not None:
            z = z + bias.reshape(1, 1, K).astype(policy.accum_dtype)
        if epilogue is not None:
            from repro.core import epilogues as epi
            z = epi.apply_epilogue(epilogue, z)
        return z.astype(policy.out_dtype)
    if tile is None:
        tile = tiling.choose_tiles(
            M, N, K, compute_dtype=policy.compute_dtype,
            accum_dtype=policy.accum_dtype,
            x_dtype=x.dtype, w_dtype=w.dtype,
        )
    Mp, Np, Kp = _padded_dims(M, N, K, tile)
    xp, wp = _pad_operands(x, w, layout, Mp, Np, Kp)
    bp = None
    if bias is not None:
        bp = _pad_to(bias.reshape(1, 1, K).astype(policy.accum_dtype),
                     1, Kp)
    z = redmule_matmul_batched_pallas(xp, wp, bp, tile=tile, policy=policy,
                                      epilogue=epilogue, layout=layout,
                                      interpret=interpret)
    return z[:, :M, :K]
