"""Chunked linear attention (mLSTM / Mamba2-SSD) as a Pallas TPU kernel.

The §Perf-3 analysis showed the xLSTM chunked engine's dominant HBM traffic
is the (dk x dv) matrix state crossing HBM once per chunk.  This kernel is
the RedMulE store-once rule applied to the *state*: the running state lives
in a VMEM fp32 scratch across the entire sequence sweep and is written to
HBM exactly once, at the last chunk — the same schedule the paper's Z-buffer
uses for the GEMM accumulator, generalized to a decaying recurrence:

    S_t = exp(g_t) * S_{t-1} + k_t v_t^T ;   out_t = q_t @ S_t

Per (head, chunk) step (all in VMEM, grid = (BH, S/chunk), chunk axis
sequential):
    L      = cumsum(g_chunk)                       (c,)
    intra  = ((q k^T) * exp(L_i - L_j) * [i>=j]) v
    inter  = (q * exp(L)) @ S
    S     <- exp(L_c) S + (k * exp(L_c - L))^T v

With log-decays g <= 0 every factor is exp(<=0): numerically stable with no
extra stabilizer (same argument as models/ssm.py, which is the oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

__all__ = ["chunked_linear_attention_pallas"]


def _kernel(q_ref, k_ref, v_ref, g_ref, o_ref, state_out_ref, state_ref,
            *, n_chunks: int, chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _reset():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0].astype(jnp.float32)          # (c, dk)
    k = k_ref[0].astype(jnp.float32)          # (c, dk)
    v = v_ref[0].astype(jnp.float32)          # (c, dv)
    g = g_ref[0].astype(jnp.float32)          # (c,)

    L = jnp.cumsum(g)                          # (c,) inclusive
    Ltot = L[-1]

    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = idx >= jdx
    A = jnp.where(causal, jnp.exp(L[:, None] - L[None, :]), 0.0)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * A
    out = jnp.dot(s, v, preferred_element_type=jnp.float32)
    out = out + jnp.dot(q * jnp.exp(L)[:, None], state_ref[...],
                        preferred_element_type=jnp.float32)

    kdec = k * jnp.exp(Ltot - L)[:, None]
    state_ref[...] = (
        jnp.exp(Ltot) * state_ref[...]
        + jax.lax.dot_general(kdec, v, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32))

    o_ref[0] = out.astype(o_ref.dtype)

    @pl.when(j == n_chunks - 1)
    def _store_state_once():
        state_out_ref[0] = state_ref[...]


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret"))
def chunked_linear_attention_pallas(
    q: jax.Array,      # (BH, S, dk)
    k: jax.Array,      # (BH, S, dk)
    v: jax.Array,      # (BH, S, dv)
    log_g: jax.Array,  # (BH, S), <= 0
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (out (BH, S, dv), final_state (BH, dk, dv) fp32).

    S must be a multiple of ``chunk`` (callers pad with g=0, k=0 — inert)."""
    BH, S, dk = q.shape
    dv = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    grid = (BH, n_chunks)

    out, state = pl.pallas_call(
        functools.partial(_kernel, n_chunks=n_chunks, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, chunk, dk), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, chunk, dv), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, chunk), lambda h, j: (h, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, dk, dv), lambda h, j: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, dv), q.dtype),
            jax.ShapeDtypeStruct((BH, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="redmule_chunked_linear_attention",
    )(q, k, v, log_g)
    return out, state
