"""RedMulE-tiled flash attention (Pallas TPU).

The paper's store-once Z-buffer rule generalizes to attention: the output
tile (and the online-softmax running max/sum) stay in VMEM scratch across
the whole KV sweep and are written to HBM exactly once.  Q tiles are held
stationary (the X-buffer role) while K/V tiles stream (the W-buffer role),
double-buffered by the Pallas pipeline.

Layout: q (BH, S, D) queries, k/v (BH_kv, T, D); GQA is expressed in the
index maps (kv head = q head // group) so K/V are never materialized per
q-head.  Causal masking skips fully-masked KV blocks via ``pl.when``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams as _CompilerParams

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, kv_tiles: int, bq: int, bkv: int, causal: bool, scale: float,
    t_valid: int, q_offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    kv_start = ki * bkv

    # Causal: a KV block strictly after the last query row of this Q block
    # contributes nothing — skip it (the grid-restriction optimization is
    # handled by the wrapper for the common S == T case).
    run = (not causal) or (kv_start < q_offset + q_start + bq)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bkv, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                    # (bq, bkv)

        col = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = col < t_valid                         # padded tail of KV
        if causal:
            row = q_offset + q_start + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            mask = mask & (col <= row)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                          # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # (bq, bkv)
        # A fully-masked row has m_new == _NEG_INF, so exp(s - m_new) above
        # evaluates to exp(0) == 1 on its masked columns; zero them so l and
        # acc stay exactly 0 for rows with no visible KV position.
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)              # (bq, 1)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)             # (bkv, d)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == kv_tiles - 1)
    def _store_once():
        # Fully-masked rows (t_valid == 0, or every KV block causally
        # skipped) have l == 0 AND acc == 0: guard the divide so they store
        # exact zeros instead of NaN.
        l = l_ref[...]
        l_inv = jnp.where(l == 0.0, 1.0, 1.0 / l)
        o_ref[0] = (acc_ref[...] * l_inv).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("group", "causal", "scale", "bq", "bkv", "t_valid",
                     "q_offset", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    group: int = 1,
    causal: bool = True,
    scale: Optional[float] = None,
    bq: int = 256,
    bkv: int = 512,
    t_valid: Optional[int] = None,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """q: (BHq, S, D), k/v: (BHkv, T, D) with BHq == BHkv * group.

    S and T must be multiples of bq / bkv (the ops wrapper pads); ``t_valid``
    marks the unpadded KV length for masking, ``q_offset`` the absolute
    position of query row 0 (causal mask: col <= q_offset + row).
    Returns (BHq, S, D).
    """
    BHq, S, D = q.shape
    BHkv, T, _ = k.shape
    assert BHq == BHkv * group, (q.shape, k.shape, group)
    assert S % bq == 0 and T % bkv == 0, ((S, bq), (T, bkv))
    if scale is None:
        scale = D ** -0.5
    if t_valid is None:
        t_valid = T
    grid = (BHq, S // bq, T // bkv)

    kernel = functools.partial(
        _kernel,
        kv_tiles=grid[2], bq=bq, bkv=bkv, causal=causal,
        scale=float(scale), t_valid=int(t_valid), q_offset=int(q_offset),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BHq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="redmule_flash_attention",
    )(q, k, v)
