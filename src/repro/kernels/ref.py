"""Pure-jnp oracles for every kernel in this package.

These are the "software counterpart" of the paper's experiments and the
correctness references for the Pallas kernels.  They are policy-aware: the
faithful-fp16 oracle reproduces the kernel's per-N-block re-rounding
semantics so kernel-vs-ref comparisons are tight for every policy.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import precision as prec
from repro.core import tiling

__all__ = ["matmul_ref", "matmul_exact", "attention_ref"]


def matmul_exact(x: jax.Array, w: jax.Array) -> jax.Array:
    """fp32 ground truth, ignoring the policy (for error measurements)."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def matmul_ref(
    x: jax.Array,
    w: jax.Array,
    *,
    policy: prec.Policy,
    tile: Optional[tiling.TileConfig] = None,
) -> jax.Array:
    """Oracle for ``kernels.redmule_matmul`` with identical accumulation
    semantics.

    * fp32 accumulation: one dot in fp32, downcast once (store-once).
    * faithful fp16 accumulation: partial products per bn-block are
      re-rounded to the accumulator dtype before the running sum, exactly
      like the kernel's ``acc_ref[...] += dot(...)`` with an fp16 scratch.
    """
    xc = x.astype(policy.compute_dtype)
    wc = w.astype(policy.compute_dtype)
    if not policy.faithful_accum:
        z = jnp.dot(xc, wc, preferred_element_type=policy.accum_dtype)
        return z.astype(policy.out_dtype)

    bn = tile.bn if tile is not None else 128
    N = x.shape[-1]
    n_blocks = -(-N // bn)
    pad = n_blocks * bn - N
    if pad:
        xc = jnp.pad(xc, [(0, 0)] * (xc.ndim - 1) + [(0, pad)])
        wc = jnp.pad(wc, [(0, pad)] + [(0, 0)] * (wc.ndim - 1))
    acc = jnp.zeros((*xc.shape[:-1], wc.shape[-1]), policy.accum_dtype)
    for b in range(n_blocks):
        xs = xc[..., b * bn : (b + 1) * bn]
        ws = wc[b * bn : (b + 1) * bn]
        part = jnp.dot(xs, ws, preferred_element_type=policy.accum_dtype)
        acc = (acc + part).astype(policy.accum_dtype)
    return acc.astype(policy.out_dtype)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
) -> jax.Array:
    """Plain softmax attention oracle. q,k,v: (B, H, S, D) (k/v may have
    fewer heads — GQA broadcast is the caller's job). fp32 softmax."""
    if scale is None:
        scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if causal:
        S, T = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
