"""yi-9b — dense llama-arch GQA LM [arXiv:2403.04652; hf]."""

import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "yi-9b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=48,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        d_ff=11008,
        vocab_size=64000,
        rope_theta=5e6,
        notes="llama-arch GQA; 01.AI Yi-9B per arXiv:2403.04652",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        full(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=0, q_chunk=64,
    )
