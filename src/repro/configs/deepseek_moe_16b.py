"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066; hf]."""

import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "deepseek-moe-16b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,  # MHA (GQA kv=16 == n_heads)
        head_dim=128,
        d_ff=1408,
        vocab_size=102400,
        moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_expert=1408,
                      dense_ff=10944, first_dense=1),
        rope_theta=1e4,
        notes="fine-grained expert segmentation; first layer dense FFN",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        full(), n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=32, vocab_size=512, q_chunk=64,
        moe=MoEConfig(n_routed=8, n_shared=2, top_k=2, d_expert=32,
                      dense_ff=128, first_dense=1),
    )
