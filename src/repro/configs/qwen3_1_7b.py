"""qwen3-1.7b — dense GQA LM with qk-norm [hf:Qwen/Qwen3-1.7B; hf]."""

import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-1.7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6144,
        vocab_size=151936,
        qk_norm=True,
        tie_embeddings=True,
        rope_theta=1e6,
        notes="qk_norm per-head RMSNorm; tied embeddings (sub-8B Qwen3)",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        full(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, q_chunk=64,
    )
