"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517].

xLSTM[7:1]: one sLSTM block per 8 (the rest mLSTM), 48 blocks total.
d_ff=0 per the assignment — blocks carry their own projections (mLSTM
pf=2 up/down, sLSTM ffn pf=4/3).  Sub-quadratic: runs the long_500k cell.
"""

import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "xlstm-1.3b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        ssm=SSMConfig(chunk=64, mlstm_proj_factor=2, slstm_period=8),
        notes="matrix-memory mLSTM chunkwise (GEMM form); sLSTM sequential "
              "scan (RedMulE-inapplicable recurrence, see DESIGN.md)",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        full(), n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        vocab_size=512, q_chunk=64,
        ssm=SSMConfig(chunk=16, mlstm_proj_factor=2, slstm_period=2),
    )
