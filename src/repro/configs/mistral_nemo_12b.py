"""mistral-nemo-12b — dense GQA, 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407]."""

import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "mistral-nemo-12b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        rope_theta=1e6,
        notes="head_dim 128 (q-proj 4096 < d_model); 128k context via rope 1e6",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        full(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, q_chunk=64,
    )
