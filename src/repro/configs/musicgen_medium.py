"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only per the assignment: the EnCodec frontend (and the 4-codebook
delay-pattern embedding sum) is a stub — ``input_specs`` provides precomputed
frame embeddings (B, S, d_model); the LM head targets the 2048-entry codec
vocabulary.  Decode consumes codec token ids directly.
"""

import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "musicgen-medium"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        norm="layernorm",
        act="gelu",
        mlp="plain",
        input_mode="embeddings",
        rope_theta=1e4,
        notes="MHA, layernorm, plain GELU FFN (4x); frontend stubbed",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        full(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=0, q_chunk=64,
    )
