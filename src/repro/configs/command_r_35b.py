"""command-r-35b — large dense GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

Note: the HF model uses a parallel attn+FFN block and layernorm; we keep the
framework's sequential pre-norm block (backbone-equivalent GEMM volume) —
recorded in DESIGN.md §Arch-applicability.
"""

import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "command-r-35b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22528,
        vocab_size=256000,
        use_bias=False,
        norm="layernorm",
        rope_theta=8e6,
        notes="largest dense cell; TP stress case (256k vocab head)",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        full(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=0, q_chunk=64,
    )
