"""pixtral-12b — pixtral-ViT + mistral-nemo backbone [hf:mistralai/Pixtral-12B-2409].

Backbone only per the assignment: the 400M ViT frontend is a stub —
``input_specs`` provides precomputed patch+text embeddings (B, S, d_model)
for train/prefill; decode consumes text token ids against the 131072 vocab.
"""

import dataclasses

from repro.configs.base import ModelConfig

ARCH_ID = "pixtral-12b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        input_mode="embeddings",
        rope_theta=1e6,
        notes="mistral-nemo decoder; ViT frontend stubbed per assignment",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        full(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, q_chunk=64,
    )
