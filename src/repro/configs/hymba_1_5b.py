"""hymba-1.5b — parallel attention + Mamba(SSD) heads [arXiv:2411.13676; hf].

Hybrid-head block: attention and SSM branches read the same normed input;
their normalized outputs are averaged.  Most layers use sliding-window
attention, three use full attention (first/middle/last).  Meta-tokens from
the paper are omitted (noted in DESIGN.md).  Sub-quadratic: runs long_500k.
"""

import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "hymba-1.5b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        sliding_window=1024,
        full_attn_layers=(0, 15, 31),
        ssm=SSMConfig(state_dim=16, chunk=64, mamba_expand=1),
        rope_theta=1e4,
        notes="25 attn heads + 25 SSD heads in parallel; ssm_state=16",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        full(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, q_chunk=64,
        sliding_window=32, full_attn_layers=(0,),
        ssm=SSMConfig(state_dim=8, chunk=16, mamba_expand=1),
    )
