"""Architecture registry: ``get(arch_id)`` / ``get_reduced(arch_id)``."""

from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.configs import (
    base,
    command_r_35b,
    deepseek_moe_16b,
    deepseek_v2_lite_16b,
    hymba_1_5b,
    mistral_nemo_12b,
    musicgen_medium,
    pixtral_12b,
    qwen3_1_7b,
    xlstm_1_3b,
    yi_9b,
)
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, input_specs

_MODULES = (
    yi_9b,
    qwen3_1_7b,
    mistral_nemo_12b,
    command_r_35b,
    deepseek_v2_lite_16b,
    deepseek_moe_16b,
    musicgen_medium,
    xlstm_1_3b,
    hymba_1_5b,
    pixtral_12b,
)

REGISTRY: Dict[str, Tuple[Callable[[], ModelConfig], Callable[[], ModelConfig]]] = {
    m.ARCH_ID: (m.full, m.reduced) for m in _MODULES
}

ARCH_IDS = tuple(REGISTRY)


def get(arch_id: str) -> ModelConfig:
    try:
        return REGISTRY[arch_id][0]()
    except KeyError as e:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}") from e


def get_reduced(arch_id: str) -> ModelConfig:
    try:
        return REGISTRY[arch_id][1]()
    except KeyError as e:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}") from e


def cells(cfg: ModelConfig):
    """The assigned (shape) cells for an architecture (with skip notes)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_long_context_decode:
            out.append((s, "skip: pure full-attention arch (quadratic 500k)"))
        else:
            out.append((s, None))
    return out


__all__ = [
    "REGISTRY", "ARCH_IDS", "get", "get_reduced", "cells",
    "SHAPES", "ModelConfig", "ShapeSpec", "input_specs", "base",
]
