"""Architecture config schema + the assigned input-shape suite.

Every assigned architecture provides:
  * ``full()``    — the exact published configuration;
  * ``reduced()`` — a same-family miniature for CPU smoke tests;
  * shapes come from ``SHAPES`` (train_4k / prefill_32k / decode_32k /
    long_500k) and ``input_specs(cfg, shape)`` builds the
    ShapeDtypeStruct stand-ins the dry-run lowers against.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import precision as prec

__all__ = [
    "MLAConfig", "MoEConfig", "SSMConfig", "ModelConfig",
    "ShapeSpec", "SHAPES", "input_specs", "cache_specs",
]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    n_shared: int
    top_k: int
    d_expert: int
    dense_ff: int            # FFN width of the leading dense layer(s)
    first_dense: int = 1
    capacity_factor: float = 1.25
    norm_topk_prob: bool = True
    aux_weight: float = 0.01
    z_weight: float = 1e-4


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    chunk: int = 64
    mlstm_proj_factor: int = 2
    mamba_expand: int = 1
    slstm_period: int = 8     # one sLSTM per this many blocks (xLSTM [7:1])

    def slstm_ffn_dim(self, d: int) -> int:
        return -(-(4 * d) // (3 * 64)) * 64  # ceil(4d/3) to a 64 multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    qk_norm: bool = False
    use_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None
    full_attn_layers: Tuple[int, ...] = ()
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    norm: str = "rmsnorm"     # rmsnorm | layernorm
    act: str = "silu"
    mlp: str = "glu"          # glu | plain
    input_mode: str = "tokens"   # tokens | embeddings (audio/vlm stubs)
    tie_embeddings: bool = False
    policy_name: str = "tpu_bf16"
    param_dtype: str = "float32"
    q_chunk: int = 1024
    # fused CE: batch rows per chunk; 0 = materialize (B, S, V) logits
    ce_chunk: int = 0
    # MoE expert parallelism: gspmd (auto) | shard_map (manual all_to_all)
    moe_impl: str = "gspmd"
    remat: str = "full"       # none | dots | full
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def policy(self) -> prec.Policy:
        return prec.resolve(self.policy_name)

    @property
    def compute_dtype(self):
        return self.policy.compute_dtype

    @property
    def block_kind(self) -> str:
        if self.family == "moe":
            return "moe"
        if self.family == "ssm":
            return "xlstm"
        if self.family == "hybrid":
            return "hymba"
        return "attn"

    @property
    def supports_long_context_decode(self) -> bool:
        """True for sub-quadratic (SSM/hybrid) families — long_500k cells."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Total parameters (embedding included), for MODEL_FLOPS."""
        from repro.models import transformer  # local: avoid import cycle
        return transformer.count_params(self)

    def active_param_count(self) -> int:
        from repro.models import transformer
        return transformer.count_params(self, active_only=True)


# --------------------------------------------------------------------- #
# Assigned shape suite
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the step function's data arguments."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.input_mode == "embeddings":
            return {
                "embeddings": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.compute_dtype),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        return {
            "inputs": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    # decode: one new token against a cache of length S
    return {
        "inputs": jax.ShapeDtypeStruct((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def cache_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    """Abstract KV/state cache for decode shapes (built in transformer.py)."""
    from repro.models import transformer
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, shape.global_batch, shape.seq_len)
    )
