"""deepseek-v2-lite-16b — MoE with MLA [arXiv:2405.04434; hf].

Assignment note: the task sheet says both "MoE 64e top-6" and "160 routed";
the published DeepSeek-V2-Lite has 64 routed experts (160 belongs to full
V2) — we follow the published 64e config, as the "MoE 64e top-6" field says.
"""

import dataclasses

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

ARCH_ID = "deepseek-v2-lite-16b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,  # routed-expert width (per assignment)
        vocab_size=102400,
        mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_expert=1408,
                      dense_ff=10944, first_dense=1),
        rope_theta=1e4,
        notes="MLA compressed KV cache (r=512); fine-grained 64e MoE; "
              "the paper-representative cell (small-GEMM regime)",
    )


def reduced() -> ModelConfig:
    return dataclasses.replace(
        full(), n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=32, vocab_size=512, q_chunk=64,
        mla=MLAConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                      v_head_dim=16),
        moe=MoEConfig(n_routed=8, n_shared=2, top_k=2, d_expert=32,
                      dense_ff=128, first_dense=1),
    )
