"""Primitive layers + the parameter-schema machinery.

Every parameter in the framework is declared as a :class:`Param` — shape plus
*logical* sharding axes — inside a nested-dict schema.  One schema drives
three things (MaxText-style, so ``init_params`` and ``param_specs`` can never
drift apart):

  * ``init_tree``  — materializes arrays (deterministic per-path RNG);
  * ``spec_tree``  — the matching pytree of ``PartitionSpec`` for pjit;
  * ``abstract_tree`` — ShapeDtypeStructs for the AOT dry-run.

All GEMMs route through the RedMulE Engine (:mod:`repro.core.engine`).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.core import engine
from repro.runtime import sharding

__all__ = [
    "Param",
    "init_tree",
    "spec_tree",
    "abstract_tree",
    "stack_schema",
    "rmsnorm",
    "layernorm",
    "rope",
    "apply_rope",
    "mlp_glu",
    "activation",
    "cross_entropy",
]


# --------------------------------------------------------------------- #
# Parameter schema
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Param:
    """Declares one parameter: shape, logical axes, initializer."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "proj"  # proj | embed | zeros | ones
    fan_in_dim: int = -2

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def _is_param(x) -> bool:
    return isinstance(x, Param)


def _path_fold(path: Tuple[str, ...]) -> int:
    """Deterministic across processes — Python's hash() is salted."""
    import zlib

    return zlib.crc32("/".join(path).encode()) & 0x7FFFFFFF


def init_tree(rng: jax.Array, schema: Dict[str, Any], dtype=jnp.float32):
    """Materialize a schema. RNG is folded per path, so adding a parameter
    never reshuffles its siblings (stable across config evolution)."""

    def go(node, path):
        if _is_param(node):
            key = jax.random.fold_in(rng, _path_fold(path))
            if node.init == "zeros":
                return jnp.zeros(node.shape, dtype)
            if node.init == "ones":
                return jnp.ones(node.shape, dtype)
            if node.init == "embed":
                return (jax.random.normal(key, node.shape) * 0.02).astype(dtype)
            fan_in = node.shape[node.fan_in_dim] if node.shape else 1
            scale = (2.0 / fan_in) ** 0.5 if node.init == "he" else fan_in**-0.5
            return (jax.random.normal(key, node.shape) * scale).astype(dtype)
        return {k: go(v, path + (k,)) for k, v in node.items()}

    return go(schema, ())


def spec_tree(schema: Dict[str, Any], rules: Optional[sharding.Rules]):
    def go(node):
        if _is_param(node):
            return sharding.logical_spec(node.axes, rules) if rules else PartitionSpec()
        return {k: go(v) for k, v in node.items()}

    return go(schema)


def abstract_tree(schema: Dict[str, Any], dtype=jnp.float32):
    def go(node):
        if _is_param(node):
            return jax.ShapeDtypeStruct(node.shape, dtype)
        return {k: go(v) for k, v in node.items()}

    return go(schema)


def stack_schema(schema: Dict[str, Any], n: int, axis_name: str = "layers"):
    """Prepend a stacked-layers dimension to every Param (for lax.scan)."""

    def go(node):
        if _is_param(node):
            return Param(
                shape=(n, *node.shape),
                axes=(axis_name, *node.axes),
                init=node.init,
                fan_in_dim=node.fan_in_dim if node.fan_in_dim < 0 else node.fan_in_dim + 1,
            )
        return {k: go(v) for k, v in node.items()}

    return go(schema)


# --------------------------------------------------------------------- #
# Norms / activations / embeddings
# --------------------------------------------------------------------- #
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    # statistics in fp32, application in the native dtype: the full-width
    # fp32 upcast must never exist as a tensor — XLA hoists it out of remat
    # regions and saves an fp32 copy of every residual otherwise
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: Optional[jax.Array] = None,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True) - jnp.square(mu)
    inv = jax.lax.rsqrt(var + eps)
    y = (x - mu.astype(x.dtype)) * inv.astype(x.dtype) * scale.astype(x.dtype)
    if bias is not None:
        y = y + bias.astype(x.dtype)
    return y


def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {kind!r}")


# --------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------- #
def rope(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin tables (..., dim/2)."""
    freqs = theta ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, H, S, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    if cos.ndim == 2:
        cos, sin = cos[None, None], sin[None, None]
    else:
        cos, sin = cos[:, None], sin[:, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# MLP (gated) on the RedMulE engine
# --------------------------------------------------------------------- #
def mlp_glu(params: Dict[str, jax.Array], x: jax.Array, *, act: str, policy) -> jax.Array:
    """Gated MLP: (act(x @ w_gate) * (x @ w_up)) @ w_down.  ``w_in`` fuses
    gate+up as (d, 2*ff) — one fat RedMulE GEMM instead of two."""
    h = engine.matmul(x, params["w_in"], policy=policy)
    gate, up = jnp.split(h, 2, axis=-1)
    h = activation(gate, act) * up
    h = sharding.constrain(h, "batch", None, "ff")
    return engine.matmul(h, params["w_out"], policy=policy)


# --------------------------------------------------------------------- #
# Loss
# --------------------------------------------------------------------- #
def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 0.0) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Token-level CE in fp32; labels < 0 are masked out."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse**2
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    return loss, {"loss": loss, "ntokens": denom}
