"""SSM blocks: xLSTM (mLSTM + sLSTM) and Mamba2/SSD (for Hymba).

One *chunkwise linear-attention engine* serves both mLSTM and SSD: the
recurrence ``S_t = exp(g_t) * S_{t-1} + k_t v_t^T`` is evaluated in chunks —
intra-chunk terms become dense GEMMs (RedMulE territory; this is the
GEMM-dominated form claimed in DESIGN.md §5) and only the chunk-to-chunk
state crosses the scan.  With log-decays g <= 0 every factor is exp(<=0),
so the chunked form is numerically stable without a separate stabilizer.

sLSTM is inherently sequential (scalar-state recurrence with a stabilizer,
paper-inapplicable — no GEMM shape in the recurrence); it runs as a
``lax.scan`` over time with its input projections hoisted into one big GEMM.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core import precision as prec
from repro.models import layers
from repro.models.layers import Param

__all__ = [
    "chunked_linear_attention",
    "linear_attention_step",
    "mlstm_schema",
    "mlstm_block",
    "slstm_schema",
    "slstm_block",
    "mamba_schema",
    "mamba_mixer",
]

_F32 = prec.FP32


# --------------------------------------------------------------------- #
# Chunkwise linear attention engine
# --------------------------------------------------------------------- #
def chunked_linear_attention(
    q: jax.Array,        # (B, H, S, dk)
    k: jax.Array,        # (B, H, S, dk)
    v: jax.Array,        # (B, H, S, dv)
    log_g: jax.Array,    # (B, H, S) log-decay, <= 0
    *,
    chunk: int = 64,
    state: Optional[jax.Array] = None,  # (B, H, dk, dv)
    backend: Optional[str] = None,      # xla | pallas | interpret
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,H,S,dv), final_state (B,H,dk,dv)).

    A thin wrapper over the Engine's first-class ``linear_attention`` op:
    backends with the ``"attention"`` capability (pallas / interpret) run
    the VMEM-resident-state kernel (the store-once rule applied to the
    recurrence) when no initial state is carried in; everything else —
    including state carry-in (decode prefix) — runs the engine's
    reference chunked scan.  Either way every GEMM of the sweep is billed
    through the registry."""
    return engine.linear_attention(
        q, k, v, log_g, chunk=chunk, state=state, backend=backend)


def linear_attention_step(
    state: jax.Array,  # (B, H, dk, dv)
    q: jax.Array,      # (B, H, dk)
    k: jax.Array,
    v: jax.Array,      # (B, H, dv)
    log_g: jax.Array,  # (B, H)
) -> Tuple[jax.Array, jax.Array]:
    """One decode step: S' = exp(g) S + k v^T; out = q @ S'."""
    state = (
        jnp.exp(log_g.astype(jnp.float32))[..., None, None] * state
        + k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :]
    )
    out = engine.einsum2d("bhk,bhkv->bhv", q.astype(jnp.float32), state,
                          policy=_F32)
    return out, state


def _per_head_rmsnorm(x: jax.Array, scale: jax.Array, H: int) -> jax.Array:
    """Group-norm over each head's channels. x: (B, S, di), scale: (di,)."""
    B, S, di = x.shape
    xh = x.reshape(B, S, H, di // H)
    xh = layers.rmsnorm(xh, jnp.ones((di // H,), x.dtype))
    return xh.reshape(B, S, di) * scale.astype(x.dtype)


# --------------------------------------------------------------------- #
# mLSTM block (xLSTM)
# --------------------------------------------------------------------- #
def mlstm_schema(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    di = cfg.ssm.mlstm_proj_factor * d
    H = cfg.n_heads
    hd = di // H
    return {
        "w_up": Param((d, 2 * di), ("embed", "ff")),
        # block-diagonal per-head q/k/v (xLSTM's linear_headwise): H
        # independent hd->3hd projections, not one dense di->3di
        "w_qkv": Param((H, hd, 3 * hd), (None, None, None)),
        "w_if": Param((di, 2 * H), (None, None)),
        "b_if": Param((2 * H,), (None,), init="zeros"),
        "norm": Param((di,), (None,), init="ones"),
        "w_down": Param((di, d), ("ff", "embed")),
    }


def mlstm_block(
    params, x, cfg, *, policy, state=None,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """x: (B, S, d). state: (B, H, hd, hd) carried across decode steps."""
    B, S, d = x.shape
    H = cfg.n_heads
    di = cfg.ssm.mlstm_proj_factor * d
    hd = di // H

    u = engine.matmul(x, params["w_up"], policy=policy)
    xin, z = jnp.split(u, 2, axis=-1)
    xh = xin.reshape(B, S, H, hd).transpose(2, 0, 1, 3).reshape(H, B * S, hd)
    qkv = engine.matmul(xh, params["w_qkv"], policy=policy)  # (H, B*S, 3hd)
    qkv = qkv.reshape(H, B, S, 3 * hd).transpose(1, 0, 2, 3)
    q, k, v = jnp.split(qkv, 3, axis=-1)                  # (B, H, S, hd)
    q = q * hd**-0.5

    gates = engine.matmul(xin, params["w_if"], policy=_F32) + params["b_if"].astype(jnp.float32)
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)          # (B, S, H)
    log_f = -jax.nn.softplus(-(f_raw + 3.0))             # log sigmoid(f+3) <= 0
    i_gate = jax.nn.sigmoid(i_raw)
    k = k * i_gate.transpose(0, 2, 1)[..., None].astype(k.dtype)
    log_g = log_f.transpose(0, 2, 1)                     # (B, H, S)

    if S == 1 and state is not None:
        o, state = linear_attention_step(
            state, q[:, :, 0], k[:, :, 0], v[:, :, 0], log_g[:, :, 0])
        o = o[:, :, None]
    else:
        o, state = chunked_linear_attention(
            q, k, v, log_g, chunk=cfg.ssm.chunk, state=state)

    o = o.transpose(0, 2, 1, 3).reshape(B, S, di).astype(x.dtype)
    o = _per_head_rmsnorm(o, params["norm"], H)
    o = o * jax.nn.silu(z)
    return engine.matmul(o, params["w_down"], policy=policy), state


# --------------------------------------------------------------------- #
# sLSTM block (xLSTM) — sequential scalar recurrence
# --------------------------------------------------------------------- #
def slstm_schema(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ff = cfg.ssm.slstm_ffn_dim(d)
    return {
        "w_gates": Param((d, 4 * d), ("embed", "ff")),
        "r_gates": Param((H, hd, 4 * hd), (None, None, None)),
        "b_gates": Param((4 * d,), (None,), init="zeros"),
        "norm": Param((d,), (None,), init="ones"),
        "ffn": {
            "w_in": Param((d, 2 * ff), ("embed", "ff")),
            "w_out": Param((ff, d), ("ff", "embed")),
        },
    }


def slstm_block(
    params, x, cfg, *, policy, state=None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """state: dict(c, n, h, m) each (B, H, hd)."""
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H

    wx = engine.matmul(x, params["w_gates"], policy=policy)  # (B, S, 4d) — one GEMM
    wx = wx.reshape(B, S, 4, H, hd).astype(jnp.float32)
    if state is None:
        zeros = jnp.zeros((B, H, hd), jnp.float32)
        state = {"c": zeros, "n": zeros, "h": zeros,
                 "m": jnp.full((B, H, hd), -1e30, jnp.float32)}
    b = params["b_gates"].astype(jnp.float32).reshape(4, H, hd)
    r = params["r_gates"].astype(jnp.float32)

    def step(st, wx_t):  # wx_t: (B, 4, H, hd)
        rec = engine.einsum2d("bhd,hde->bhe", st["h"], r,
                              policy=_F32).reshape(B, H, 4, hd)
        g = wx_t + rec.transpose(0, 2, 1, 3) + b[None]
        z_t, i_t, f_t, o_t = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        log_f = -jax.nn.softplus(-(f_t + 3.0))
        m_new = jnp.maximum(log_f + st["m"], i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(log_f + st["m"] - m_new)
        c = f_p * st["c"] + i_p * jnp.tanh(z_t)
        n = f_p * st["n"] + i_p
        h = jax.nn.sigmoid(o_t) * c / jnp.maximum(jnp.abs(n), 1.0)
        return {"c": c, "n": n, "h": h, "m": m_new}, h

    with engine.repeat(S):  # time scan: body traced once, runs S times
        state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    h = layers.rmsnorm(h, params["norm"])
    y = h + layers.mlp_glu(params["ffn"], h, act=cfg.act, policy=policy)
    return y, state


# --------------------------------------------------------------------- #
# Mamba2 / SSD mixer (Hymba's SSM heads)
# --------------------------------------------------------------------- #
def mamba_schema(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    di = cfg.ssm.mamba_expand * d
    H, N = cfg.n_heads, cfg.ssm.state_dim
    return {
        "w_xz": Param((d, 2 * di), ("embed", "ff")),
        "w_bcdt": Param((d, 2 * N + H), ("embed", None)),
        "a_log": Param((H,), (None,), init="zeros"),
        "skip_d": Param((H,), (None,), init="ones"),
        "dt_bias": Param((H,), (None,), init="zeros"),
        "norm": Param((di,), (None,), init="ones"),
        "w_out": Param((di, d), ("ff", "embed")),
    }


def mamba_mixer(
    params, x, cfg, *, policy, state=None,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """SSD: linear attention with q=C, k=B, v=dt*x, decay=exp(-exp(A)dt)."""
    B_, S, d = x.shape
    H, N = cfg.n_heads, cfg.ssm.state_dim
    di = cfg.ssm.mamba_expand * d
    P = di // H

    xz = engine.matmul(x, params["w_xz"], policy=policy)
    xin, z = jnp.split(xz, 2, axis=-1)
    bcdt = engine.matmul(x, params["w_bcdt"], policy=_F32)   # (B, S, 2N + H)
    bmat, cmat, dt = jnp.split(bcdt, [N, 2 * N], axis=-1)
    dt = jax.nn.softplus(dt + params["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    log_g = (dt * a[None, None]).transpose(0, 2, 1)      # (B, H, S) <= 0

    v = xin.reshape(B_, S, H, P).transpose(0, 2, 1, 3)   # (B, H, S, P)
    v_in = v * dt.transpose(0, 2, 1)[..., None].astype(v.dtype)
    q = jnp.broadcast_to(cmat[:, None], (B_, H, S, N))
    k = jnp.broadcast_to(bmat[:, None], (B_, H, S, N))

    if S == 1 and state is not None:
        o, state = linear_attention_step(
            state, q[:, :, 0], k[:, :, 0], v_in[:, :, 0], log_g[:, :, 0])
        o = o[:, :, None]
    else:
        o, state = chunked_linear_attention(
            q, k, v_in, log_g, chunk=cfg.ssm.chunk, state=state)

    o = o + v.astype(jnp.float32) * params["skip_d"].astype(jnp.float32)[None, :, None, None]
    o = o.transpose(0, 2, 1, 3).reshape(B_, S, di).astype(x.dtype)
    o = _per_head_rmsnorm(o, params["norm"], H)
    o = o * jax.nn.silu(z)
    return engine.matmul(o, params["w_out"], policy=policy), state
