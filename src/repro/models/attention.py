"""Attention on the RedMulE engine: GQA (+qk-norm, sliding window) and MLA.

Prefill/train uses a q-chunked online attention (flash-style in pure jnp) so
32k-sequence score tensors are never materialized whole; on TPU the Pallas
``flash_attention`` kernel implements the same schedule.  Decode attends one
query against the KV cache.

Caches:
  * GQA — k/v tensors (B, Hkv, T, hd), updated in place at ``pos``;
  * MLA — the *compressed* (c_kv, k_rope) pair (B, T, r[+dr]): the paper's
    store-small / recompute-fat trade, k_nope/v re-expanded on the fly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core import precision as prec
from repro.models import layers
from repro.models.layers import Param
from repro.optim import scale as oscale
from repro.runtime import sharding

__all__ = [
    "gqa_schema",
    "mla_schema",
    "gqa_attention",
    "mla_attention",
    "init_gqa_cache",
    "init_mla_cache",
    "chunked_attention",
]

NEG_INF = jnp.float32(-1e30)


def _static_int(x) -> Optional[int]:
    """Concrete scalar -> int; None for traced values or per-slot arrays
    (those keep the mask-driven chunked path)."""
    try:
        return int(x)
    except Exception:
        return None


# --------------------------------------------------------------------- #
# Schemas
# --------------------------------------------------------------------- #
def gqa_schema(cfg) -> Dict[str, Any]:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s: Dict[str, Any] = {
        # fused qkv: one fat RedMulE GEMM; split after
        "wqkv": Param((d, (hq + 2 * hkv) * hd), ("embed", "heads")),
        "wo": Param((hq * hd, d), ("heads", "embed")),
    }
    if cfg.use_bias:
        s["bqkv"] = Param(((hq + 2 * hkv) * hd,), ("heads",), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = Param((hd,), (None,), init="ones")
        s["k_norm"] = Param((hd,), (None,), init="ones")
    return s


def mla_schema(cfg) -> Dict[str, Any]:
    m = cfg.mla
    d, hq = cfg.d_model, cfg.n_heads
    return {
        "wq": Param((d, hq * (m.qk_nope_dim + m.qk_rope_dim)), ("embed", "heads")),
        # fused down-projection: compressed kv rank + shared rope key
        "wdkv": Param((d, m.kv_lora_rank + m.qk_rope_dim), ("embed", "kv_rank")),
        "kv_norm": Param((m.kv_lora_rank,), (None,), init="ones"),
        "wuk": Param((m.kv_lora_rank, hq * m.qk_nope_dim), ("kv_rank", "heads")),
        "wuv": Param((m.kv_lora_rank, hq * m.v_head_dim), ("kv_rank", "heads")),
        "wo": Param((hq * m.v_head_dim, d), ("heads", "embed")),
    }


# --------------------------------------------------------------------- #
# Caches
# --------------------------------------------------------------------- #
SCALE_HISTORY = 16  # delayed-scaling amax window per cache scale leaf


def _init_scale_leaves(lead_shape: Tuple[int, ...]) -> Dict[str, jax.Array]:
    """Per-head (or per-tensor, ``lead_shape == ()``) delayed-scaling state,
    stored as plain cache leaves so it rides the cache pytree through
    jit/scan/donation: the three fields of :class:`repro.optim.scale.
    Fp8ScaleState`, broadcast over the leading head dim."""
    return {
        "scale": jnp.ones(lead_shape, jnp.float32),
        "amax_history": jnp.zeros((*lead_shape, SCALE_HISTORY), jnp.float32),
        "overflow_count": jnp.zeros(lead_shape, jnp.int32),
    }


def _scale_leaf_axes(head_axes: Tuple) -> Dict[str, Tuple]:
    return {
        "scale": head_axes,
        "amax_history": (*head_axes, None),
        "overflow_count": head_axes,
    }


def _refresh_scale(sc: Dict[str, jax.Array], new_rows: jax.Array,
                   reduce_axes: Tuple[int, ...]
                   ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Fold the new rows' amax into the delayed-scaling window
    (:func:`repro.optim.scale.update_fp8_scale`, vmapped over heads) and
    return ``(updated leaves, applied scale)``.  The applied scale
    *ratchets* (``max`` with the stored scale): rows quantized under an
    older scale can only shrink on requantization, never clip."""
    st = oscale.Fp8ScaleState(
        sc["scale"], sc["amax_history"], sc["overflow_count"])
    amax = jnp.max(jnp.abs(new_rows.astype(jnp.float32)), axis=reduce_axes)
    upd = oscale.update_fp8_scale
    for _ in range(amax.ndim):   # nest over (layers, heads) leading dims
        upd = jax.vmap(upd)
    st2 = upd(st, amax)
    applied = jnp.maximum(sc["scale"], st2.scale)
    return ({"scale": applied, "amax_history": st2.amax_history,
             "overflow_count": st2.overflow_count}, applied)


def init_gqa_cache(cfg, batch: int, max_len: int, dtype,
                   storage_dtype=None) -> Dict[str, jax.Array]:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (batch, hkv, max_len, hd)
    if storage_dtype is None:
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    st = jnp.dtype(storage_dtype)
    if not prec.is_fp8(st):
        raise ValueError(
            f"storage_dtype must be an FP8 format {prec.FP8_FORMATS}, "
            f"got {st.name!r}")
    return {
        "k": jnp.zeros(shape, st), "v": jnp.zeros(shape, st),
        "k_scale": _init_scale_leaves((hkv,)),
        "v_scale": _init_scale_leaves((hkv,)),
    }


def init_mla_cache(cfg, batch: int, max_len: int, dtype,
                   storage_dtype=None) -> Dict[str, jax.Array]:
    m = cfg.mla
    if storage_dtype is None:
        return {
            "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
        }
    st = jnp.dtype(storage_dtype)
    if not prec.is_fp8(st):
        raise ValueError(
            f"storage_dtype must be an FP8 format {prec.FP8_FORMATS}, "
            f"got {st.name!r}")
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), st),
        "kr": jnp.zeros((batch, max_len, m.qk_rope_dim), st),
        # MLA scales are per-tensor: the compressed latent has no head dim
        "ckv_scale": _init_scale_leaves(()),
        "kr_scale": _init_scale_leaves(()),
    }


# --------------------------------------------------------------------- #
# Core attention math (q-chunked online)
# --------------------------------------------------------------------- #
def _masked_softmax_block(
    s: jax.Array,  # (B, Hkv, G, qc, T) fp32 scores
    rows: jax.Array,  # (qc,) or (B, qc) absolute query positions
    kv_valid: jax.Array,  # scalar or (B,): number of valid kv slots
    causal: bool,
    window: Optional[jax.Array],
) -> jax.Array:
    # Serving decode batches carry per-slot positions: rows/kv_valid grow a
    # leading batch dim and the mask broadcasts (Bm, 1, 1, qc, T) over the
    # scores; single-sequence callers keep Bm == 1.
    cols = jnp.arange(s.shape[-1])
    rows2 = rows if rows.ndim == 2 else rows[None]            # (Bm, qc)
    kv = jnp.reshape(jnp.asarray(kv_valid), (-1, 1, 1))       # (Bm, 1, 1)
    mask = cols[None, None, :] < kv
    if causal:
        mask = mask & (cols[None, None, :] <= rows2[:, :, None])
    if window is not None:
        mask = mask & (cols[None, None, :] > rows2[:, :, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    return jax.nn.softmax(s, axis=-1)


def chunked_attention(
    q: jax.Array,  # (B, Hkv, G, S, hd)
    k: jax.Array,  # (B, Hkv, T, hd)
    v: jax.Array,  # (B, Hkv, T, hdv)
    *,
    q_offset: jax.Array,  # scalar or (B,): absolute position of q[..., 0, :]
    kv_valid: jax.Array,  # scalar or (B,): valid kv length
    causal: bool = True,
    window: Optional[jax.Array] = None,
    q_chunk: int = 1024,
    scale: Optional[float] = None,
    kv_group_sizes: Optional[Any] = None,
    policy: prec.Policy,
) -> jax.Array:
    """Returns (B, Hkv, G, S, hdv). Scores fp32, never materialized beyond
    one q-chunk (the RedMulE store-once rule applied to attention).

    ``kv_group_sizes`` (serving decode, S == 1 only): per-batch-slot valid
    kv lengths.  The score GEMM then dispatches through the Engine's
    ragged ``grouped_matmul`` path — one group per (slot, kv-head), group
    size = that slot's kv length — so mixed-length decode batches bill
    flops/bytes for the *valid* kv rows only.  Concrete sizes (numpy, at
    an instrumentation trace) pin ``valid_rows`` on the event; traced
    sizes fall back to dense billing with identical numerics."""
    B, Hkv, G, S, hd = q.shape
    if scale is None:
        scale = hd**-0.5
    scores_policy = dataclasses.replace(
        policy, name=policy.name + "_scores", output_dtype=jnp.float32,
        faithful_accum=False,
    )
    if kv_group_sizes is not None:
        if S != 1:
            raise ValueError("kv_group_sizes is a decode-only (S == 1) path")
        return _ragged_decode_attention(
            q, k, v, q_offset=q_offset, kv_valid=kv_valid, window=window,
            kv_group_sizes=kv_group_sizes, scale=scale,
            scores_policy=scores_policy, policy=policy)
    # Decode: pin the attention dots to the sequence-sharded KV layout —
    # scores/pv become partial over the seq shards (small softmax
    # all-reduces) instead of GSPMD "involuntarily rematerializing" the
    # whole cache to match the head-sharded output (a 537 MB x layers
    # all-gather).  Training keeps GSPMD's head-sharded schedule.
    rules = sharding.current_rules()
    pin = rules is not None and rules.serve_attention
    if (window is None and not pin and v.shape[-1] == hd
            and engine.backend_supports(engine.default_backend(),
                                        "attention")):
        # First-class engine op: the backend's fused flash sweep (same
        # numerics contract and identical billed flops as the q-chunked
        # path below, but online-softmax in VMEM with causally dead KV
        # blocks skipped).  Backends without the capability keep the
        # q-chunked path — the engine's reference composition would
        # materialize the full S x T score tensor.  Traced
        # offsets/lengths (serving's per-slot decode) also stay here.
        off_i = _static_int(q_offset)
        kvv_i = _static_int(kv_valid)
        if off_i is not None and kvv_i is not None:
            out = engine.attention(
                q.reshape(B, Hkv * G, S, hd), k, v, causal=causal,
                scale=scale, q_offset=off_i, t_valid=kvv_i, policy=policy)
            return out.reshape(B, Hkv, G, S, -1)
    kt = jnp.swapaxes(k, -1, -2)[:, :, None]  # (B, Hkv, 1, hd, T)
    vb = v[:, :, None]

    def c(x, *axes):
        return sharding.constrain(x, *axes) if pin else x

    kt = c(kt, "batch", "kv_heads", None, None, "kv_seq")
    vb = c(vb, "batch", "kv_heads", None, "kv_seq", None)

    def rows_at(start):
        off = jnp.asarray(q_offset)
        n = min(q_chunk, S)
        r = jnp.arange(n) + start
        return off[:, None] + r[None] if off.ndim == 1 else off + r

    def block(q_blk: jax.Array, rows: jax.Array) -> jax.Array:
        q_blk = c(q_blk, "batch", "kv_heads", None, None, None)
        s = engine.matmul(q_blk, kt, policy=scores_policy) * scale
        s = c(s, "batch", "kv_heads", None, None, "kv_seq")
        p = _masked_softmax_block(s, rows, kv_valid, causal, window)
        out = engine.matmul(p.astype(policy.compute_dtype), vb, policy=policy)
        return c(out, "batch", "kv_heads", None, None, None)

    if S <= q_chunk:
        return block(q, rows_at(0))

    n = -(-S // q_chunk)
    pad = n * q_chunk - S
    if pad:
        q = jnp.pad(q, [(0, 0)] * 3 + [(0, pad), (0, 0)])
    qs = jnp.moveaxis(q.reshape(B, Hkv, G, n, q_chunk, hd), 3, 0)

    def step(_, xs):
        q_blk, idx = xs
        return None, block(q_blk, rows_at(idx * q_chunk))

    with engine.repeat(n):  # body traced once, runs n q-chunks
        _, out = jax.lax.scan(step, None, (qs, jnp.arange(n)))
    out = jnp.moveaxis(out, 0, 3).reshape(B, Hkv, G, n * q_chunk, -1)
    return out[:, :, :, :S]


def _ragged_decode_attention(
    q: jax.Array,  # (B, Hkv, G, 1, hd)
    k: jax.Array,  # (B, Hkv, T, hd)
    v: jax.Array,  # (B, Hkv, T, hdv)
    *,
    q_offset: jax.Array,
    kv_valid: jax.Array,
    window: Optional[jax.Array],
    kv_group_sizes: Any,
    scale: float,
    scores_policy: prec.Policy,
    policy: prec.Policy,
) -> jax.Array:
    """Mixed-length decode batch through the ragged grouped-GEMM path.

    The score contraction runs transposed — ``scores^T[g] = K[g] @ q[g]^T``
    with one group per (slot, kv-head) and ``group_sizes`` = the slot's
    valid kv length — so the Engine's ``valid_rows`` accounting bills only
    the rows each slot actually attends, not ``B * T`` dense.  Rows at or
    beyond a group's size come back zeroed and are re-masked to -inf by
    the softmax mask, so numerics match the dense block exactly.  The PV
    contraction keeps the dense batched dispatch: its ragged dim is the
    *contraction* (masked probabilities are exact zeros), which forward
    grouped GEMMs cannot bill raggedly."""
    B, Hkv, G, S, hd = q.shape
    T = k.shape[2]
    x = k.reshape(B * Hkv, T, hd)
    w = jnp.transpose(q[:, :, :, 0, :], (0, 1, 3, 2)).reshape(B * Hkv, hd, G)
    sizes = kv_group_sizes
    if isinstance(sizes, (list, tuple)):
        sizes = np.asarray(sizes, np.int32)
    gs = (np.repeat(sizes, Hkv) if isinstance(sizes, np.ndarray)
          else jnp.repeat(jnp.asarray(sizes), Hkv))
    st = engine.grouped_matmul(x, w, group_sizes=gs, policy=scores_policy)
    s = jnp.transpose(st.reshape(B, Hkv, T, G), (0, 1, 3, 2))[:, :, :, None, :]
    s = s * scale
    off = jnp.asarray(q_offset)
    rows = off[:, None] if off.ndim == 1 else off + jnp.arange(1)
    p = _masked_softmax_block(s, rows, kv_valid, True, window)
    return engine.matmul(
        p.astype(policy.compute_dtype), v[:, :, None], policy=policy)


# --------------------------------------------------------------------- #
# GQA forward
# --------------------------------------------------------------------- #
def gqa_attention(
    params: Dict[str, jax.Array],
    x: jax.Array,  # (B, S, d)
    cfg,
    *,
    pos_offset: jax.Array,
    cache: Optional[Dict[str, jax.Array]] = None,
    window: Optional[jax.Array] = None,
    policy: prec.Policy,
    q_chunk: int = 1024,
    kv_group_sizes: Optional[Any] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = hq // hkv
    off = jnp.asarray(pos_offset)
    if off.ndim == 1 and S != 1:
        raise ValueError("per-slot pos_offset is a decode-only (S == 1) path")

    qkv = engine.matmul(x, params["wqkv"], policy=policy)
    if "bqkv" in params:
        qkv = qkv + params["bqkv"].astype(qkv.dtype)
    q, kk, vv = jnp.split(qkv, [hq * hd, (hq + hkv) * hd], axis=-1)
    q = q.reshape(B, S, hq, hd).transpose(0, 2, 1, 3)       # (B, Hq, S, hd)
    kk = kk.reshape(B, S, hkv, hd).transpose(0, 2, 1, 3)    # (B, Hkv, S, hd)
    vv = vv.reshape(B, S, hkv, hd).transpose(0, 2, 1, 3)

    if cfg.qk_norm:
        q = layers.rmsnorm(q, params["q_norm"])
        kk = layers.rmsnorm(kk, params["k_norm"])

    positions = (off[:, None] + jnp.arange(S)[None] if off.ndim == 1
                 else off + jnp.arange(S))
    cos, sin = layers.rope(positions, hd, cfg.rope_theta)
    q = layers.apply_rope(q, cos, sin)
    kk = layers.apply_rope(kk, cos, sin)

    if cache is not None:
        fp8 = prec.is_fp8(cache["k"].dtype)
        if fp8:
            # upcast on read: E4M3 tensors widen to the compute dtype
            # against the per-head delayed scales stored alongside them
            ks = cache["k_scale"]["scale"].reshape(1, -1, 1, 1)
            vs = cache["v_scale"]["scale"].reshape(1, -1, 1, 1)
            k_prev = prec.dequantize_fp8(cache["k"], ks, kk.dtype)
            v_prev = prec.dequantize_fp8(cache["v"], vs, vv.dtype)
        else:
            k_prev, v_prev = cache["k"], cache["v"]
        if S == 1:
            # decode: masked merge — elementwise over the (possibly
            # TP-sharded) cache sequence dim, so no gather is forced the way
            # a dynamic-update-slice at a traced position would; a per-slot
            # (B,) pos_offset broadcasts each slot's own hit row
            T = k_prev.shape[2]
            hit = (jnp.arange(T)[None, :]
                   == jnp.reshape(off, (-1, 1)))[:, None, :, None]
            k_all = jnp.where(hit, kk.astype(k_prev.dtype), k_prev)
            v_all = jnp.where(hit, vv.astype(v_prev.dtype), v_prev)
        else:
            zero = jnp.zeros((), jnp.int32)
            k_all = jax.lax.dynamic_update_slice(
                k_prev, kk.astype(k_prev.dtype),
                (zero, zero, pos_offset, zero))
            v_all = jax.lax.dynamic_update_slice(
                v_prev, vv.astype(v_prev.dtype),
                (zero, zero, pos_offset, zero))
        if fp8:
            # write-back: refresh the per-head delayed scales with the new
            # rows' amax, requantize under the (ratcheted) applied scale
            k_sc, k_as = _refresh_scale(cache["k_scale"], kk, (0, 2, 3))
            v_sc, v_as = _refresh_scale(cache["v_scale"], vv, (0, 2, 3))
            k_q, _ = prec.quantize_fp8(
                k_all, cache["k"].dtype, scale=k_as.reshape(1, -1, 1, 1))
            v_q, _ = prec.quantize_fp8(
                v_all, cache["v"].dtype, scale=v_as.reshape(1, -1, 1, 1))
            new_cache = {"k": k_q, "v": v_q,
                         "k_scale": k_sc, "v_scale": v_sc}
        else:
            new_cache = {"k": k_all, "v": v_all}
        kv_valid = pos_offset + S
    else:
        k_all, v_all, new_cache, kv_valid = kk, vv, None, jnp.int32(S)

    k_all = sharding.constrain(k_all, "batch", "kv_heads", "kv_seq", None)
    v_all = sharding.constrain(v_all, "batch", "kv_heads", "kv_seq", None)

    qg = q.reshape(B, hkv, g, S, hd)
    o = chunked_attention(
        qg, k_all, v_all,
        q_offset=pos_offset, kv_valid=kv_valid, causal=True,
        window=window, q_chunk=q_chunk, policy=policy,
        kv_group_sizes=kv_group_sizes,
    )
    o = o.reshape(B, hq, S, hd).transpose(0, 2, 1, 3).reshape(B, S, hq * hd)
    o = sharding.constrain(o, "batch", None, "heads")
    out = engine.matmul(o, params["wo"], policy=policy)
    return out, new_cache


# --------------------------------------------------------------------- #
# MLA forward (DeepSeek-V2 family)
# --------------------------------------------------------------------- #
def mla_attention(
    params: Dict[str, jax.Array],
    x: jax.Array,
    cfg,
    *,
    pos_offset: jax.Array,
    cache: Optional[Dict[str, jax.Array]] = None,
    policy: prec.Policy,
    q_chunk: int = 1024,
    kv_group_sizes: Optional[Any] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    # kv_group_sizes is accepted for API parity with gqa_attention; the
    # absorbed MLA decode is einsum-shaped (no grouped ragged form), so
    # per-slot lengths only drive the mask here, not the billing.
    del kv_group_sizes
    m = cfg.mla
    B, S, d = x.shape
    hq = cfg.n_heads
    dn, dr, dv, r = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim, m.kv_lora_rank
    off = jnp.asarray(pos_offset)
    if off.ndim == 1 and S != 1:
        raise ValueError("per-slot pos_offset is a decode-only (S == 1) path")

    q = engine.matmul(x, params["wq"], policy=policy).reshape(B, S, hq, dn + dr)
    q = q.transpose(0, 2, 1, 3)  # (B, Hq, S, dn+dr)
    qn, qr = q[..., :dn], q[..., dn:]

    dkv = engine.matmul(x, params["wdkv"], policy=policy)  # (B, S, r + dr)
    ckv, kr = dkv[..., :r], dkv[..., r:]
    ckv = layers.rmsnorm(ckv, params["kv_norm"])

    positions = (off[:, None] + jnp.arange(S)[None] if off.ndim == 1
                 else off + jnp.arange(S))
    cos, sin = layers.rope(positions, dr, cfg.rope_theta)
    qr = layers.apply_rope(qr, cos, sin)
    kr = layers.apply_rope(kr[:, None], cos, sin)[:, 0]  # (B, S, dr)

    if cache is not None:
        fp8 = prec.is_fp8(cache["ckv"].dtype)
        if fp8:
            ckv_prev = prec.dequantize_fp8(
                cache["ckv"], cache["ckv_scale"]["scale"], ckv.dtype)
            kr_prev = prec.dequantize_fp8(
                cache["kr"], cache["kr_scale"]["scale"], kr.dtype)
        else:
            ckv_prev, kr_prev = cache["ckv"], cache["kr"]
        if S == 1:
            T = ckv_prev.shape[1]
            hit = (jnp.arange(T)[None, :]
                   == jnp.reshape(off, (-1, 1)))[:, :, None]
            ckv_all = jnp.where(hit, ckv.astype(ckv_prev.dtype), ckv_prev)
            kr_all = jnp.where(hit, kr.astype(kr_prev.dtype), kr_prev)
        else:
            zero = jnp.zeros((), jnp.int32)
            ckv_all = jax.lax.dynamic_update_slice(
                ckv_prev, ckv.astype(ckv_prev.dtype),
                (zero, pos_offset, zero))
            kr_all = jax.lax.dynamic_update_slice(
                kr_prev, kr.astype(kr_prev.dtype),
                (zero, pos_offset, zero))
        if fp8:
            c_sc, c_as = _refresh_scale(cache["ckv_scale"], ckv, (0, 1, 2))
            r_sc, r_as = _refresh_scale(cache["kr_scale"], kr, (0, 1, 2))
            ckv_q, _ = prec.quantize_fp8(
                ckv_all, cache["ckv"].dtype, scale=c_as)
            kr_q, _ = prec.quantize_fp8(kr_all, cache["kr"].dtype, scale=r_as)
            new_cache = {"ckv": ckv_q, "kr": kr_q,
                         "ckv_scale": c_sc, "kr_scale": r_sc}
        else:
            new_cache = {"ckv": ckv_all, "kr": kr_all}
        kv_valid = pos_offset + S
    else:
        ckv_all, kr_all, new_cache, kv_valid = ckv, kr, None, jnp.int32(S)

    ckv_all = sharding.constrain(ckv_all, "batch", "kv_seq", None)
    T = ckv_all.shape[1]

    if S == 1 and cache is not None:
        # Absorbed decode: fold W_uk into the query and W_uv into the
        # context so the compressed cache is attended DIRECTLY — no
        # per-step (T, Hq*dn) k/v re-expansion (saves a factor of dn=128
        # on the T-dependent FLOPs; this was the useful~0 diagnosis of the
        # MLA decode cells in EXPERIMENTS.md §Roofline).
        # fp32-out engine policy: every absorbed contraction accumulates
        # (and is returned) in fp32, exactly like the old preferred_element_type
        abs_policy = prec.Policy(
            policy.name + "_absorbed", policy.compute_dtype,
            jnp.float32, jnp.float32)
        wuk = params["wuk"].reshape(r, hq, dn)
        wuv = params["wuv"].reshape(r, hq, dv)
        q_abs = engine.einsum2d("bhsd,rhd->bhsr", qn, wuk, policy=abs_policy)
        s = engine.einsum2d("bhsr,btr->bhst", q_abs, ckv_all, policy=abs_policy)
        s = s + engine.einsum2d("bhsd,btd->bhst", qr, kr_all, policy=abs_policy)
        s = s * (dn + dr) ** -0.5
        mask = (jnp.arange(T)[None, None, None, :]
                < jnp.reshape(jnp.asarray(kv_valid), (-1, 1, 1, 1)))
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx = engine.einsum2d("bhst,btr->bhsr", p, ckv_all, policy=abs_policy)
        o = engine.einsum2d("bhsr,rhd->bhsd", ctx, wuv, policy=abs_policy)
        o = o.astype(policy.compute_dtype)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, hq * dv)
        o = sharding.constrain(o, "batch", None, "heads")
        return engine.matmul(o, params["wo"], policy=policy), new_cache

    # Prefill/train: re-expand the compressed cache (the MLA trade:
    # small cache, extra GEMM)
    kn = engine.matmul(ckv_all, params["wuk"], policy=policy).reshape(B, T, hq, dn)
    vv = engine.matmul(ckv_all, params["wuv"], policy=policy).reshape(B, T, hq, dv)
    kn = kn.transpose(0, 2, 1, 3)  # (B, Hq, T, dn)
    vv = vv.transpose(0, 2, 1, 3)
    k_full = jnp.concatenate(
        [kn, jnp.broadcast_to(kr_all[:, None], (B, hq, T, dr))], axis=-1)
    q_full = jnp.concatenate([qn, qr], axis=-1)

    o = chunked_attention(
        q_full[:, :, None], k_full, vv,
        q_offset=pos_offset, kv_valid=kv_valid, causal=True,
        q_chunk=q_chunk, scale=(dn + dr) ** -0.5, policy=policy,
    )
    o = o[:, :, 0].transpose(0, 2, 1, 3).reshape(B, S, hq * dv)
    o = sharding.constrain(o, "batch", None, "heads")
    out = engine.matmul(o, params["wo"], policy=policy)
    return out, new_cache
