"""Attention on the RedMulE engine: GQA (+qk-norm, sliding window) and MLA.

Prefill/train uses a q-chunked online attention (flash-style in pure jnp) so
32k-sequence score tensors are never materialized whole; on TPU the Pallas
``flash_attention`` kernel implements the same schedule.  Decode attends one
query against the KV cache.

Caches:
  * GQA — k/v tensors (B, Hkv, T, hd), updated in place at ``pos``;
  * MLA — the *compressed* (c_kv, k_rope) pair (B, T, r[+dr]): the paper's
    store-small / recompute-fat trade, k_nope/v re-expanded on the fly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core import precision as prec
from repro.models import layers
from repro.models.layers import Param
from repro.runtime import sharding

__all__ = [
    "gqa_schema",
    "mla_schema",
    "gqa_attention",
    "mla_attention",
    "init_gqa_cache",
    "init_mla_cache",
    "chunked_attention",
]

NEG_INF = jnp.float32(-1e30)


# --------------------------------------------------------------------- #
# Schemas
# --------------------------------------------------------------------- #
def gqa_schema(cfg) -> Dict[str, Any]:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s: Dict[str, Any] = {
        # fused qkv: one fat RedMulE GEMM; split after
        "wqkv": Param((d, (hq + 2 * hkv) * hd), ("embed", "heads")),
        "wo": Param((hq * hd, d), ("heads", "embed")),
    }
    if cfg.use_bias:
        s["bqkv"] = Param(((hq + 2 * hkv) * hd,), ("heads",), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = Param((hd,), (None,), init="ones")
        s["k_norm"] = Param((hd,), (None,), init="ones")
    return s


def mla_schema(cfg) -> Dict[str, Any]:
    m = cfg.mla
    d, hq = cfg.d_model, cfg.n_heads
    return {
        "wq": Param((d, hq * (m.qk_nope_dim + m.qk_rope_dim)), ("embed", "heads")),
        # fused down-projection: compressed kv rank + shared rope key
        "wdkv": Param((d, m.kv_lora_rank + m.qk_rope_dim), ("embed", "kv_rank")),
        "kv_norm": Param((m.kv_lora_rank,), (None,), init="ones"),
        "wuk": Param((m.kv_lora_rank, hq * m.qk_nope_dim), ("kv_rank", "heads")),
        "wuv": Param((m.kv_lora_rank, hq * m.v_head_dim), ("kv_rank", "heads")),
        "wo": Param((hq * m.v_head_dim, d), ("heads", "embed")),
    }


# --------------------------------------------------------------------- #
# Caches
# --------------------------------------------------------------------- #
def init_gqa_cache(cfg, batch: int, max_len: int, dtype) -> Dict[str, jax.Array]:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (batch, hkv, max_len, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_mla_cache(cfg, batch: int, max_len: int, dtype) -> Dict[str, jax.Array]:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
    }


# --------------------------------------------------------------------- #
# Core attention math (q-chunked online)
# --------------------------------------------------------------------- #
def _masked_softmax_block(
    s: jax.Array,  # (B, Hkv, G, qc, T) fp32 scores
    rows: jax.Array,  # (qc,) absolute query positions
    kv_valid: jax.Array,  # scalar: number of valid kv slots
    causal: bool,
    window: Optional[jax.Array],
) -> jax.Array:
    cols = jnp.arange(s.shape[-1])
    mask = cols[None, :] < kv_valid
    if causal:
        mask = mask & (cols[None, :] <= rows[:, None])
    if window is not None:
        mask = mask & (cols[None, :] > rows[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    return jax.nn.softmax(s, axis=-1)


def chunked_attention(
    q: jax.Array,  # (B, Hkv, G, S, hd)
    k: jax.Array,  # (B, Hkv, T, hd)
    v: jax.Array,  # (B, Hkv, T, hdv)
    *,
    q_offset: jax.Array,  # scalar: absolute position of q[..., 0, :]
    kv_valid: jax.Array,  # scalar: valid kv length
    causal: bool = True,
    window: Optional[jax.Array] = None,
    q_chunk: int = 1024,
    scale: Optional[float] = None,
    policy: prec.Policy,
) -> jax.Array:
    """Returns (B, Hkv, G, S, hdv). Scores fp32, never materialized beyond
    one q-chunk (the RedMulE store-once rule applied to attention)."""
    B, Hkv, G, S, hd = q.shape
    if scale is None:
        scale = hd**-0.5
    scores_policy = dataclasses.replace(
        policy, name=policy.name + "_scores", output_dtype=jnp.float32,
        faithful_accum=False,
    )
    kt = jnp.swapaxes(k, -1, -2)[:, :, None]  # (B, Hkv, 1, hd, T)
    vb = v[:, :, None]
    # Decode: pin the attention dots to the sequence-sharded KV layout —
    # scores/pv become partial over the seq shards (small softmax
    # all-reduces) instead of GSPMD "involuntarily rematerializing" the
    # whole cache to match the head-sharded output (a 537 MB x layers
    # all-gather).  Training keeps GSPMD's head-sharded schedule.
    rules = sharding.current_rules()
    pin = rules is not None and rules.serve_attention

    def c(x, *axes):
        return sharding.constrain(x, *axes) if pin else x

    kt = c(kt, "batch", "kv_heads", None, None, "kv_seq")
    vb = c(vb, "batch", "kv_heads", None, "kv_seq", None)

    def block(q_blk: jax.Array, rows: jax.Array) -> jax.Array:
        q_blk = c(q_blk, "batch", "kv_heads", None, None, None)
        s = engine.matmul(q_blk, kt, policy=scores_policy) * scale
        s = c(s, "batch", "kv_heads", None, None, "kv_seq")
        p = _masked_softmax_block(s, rows, kv_valid, causal, window)
        out = engine.matmul(p.astype(policy.compute_dtype), vb, policy=policy)
        return c(out, "batch", "kv_heads", None, None, None)

    if S <= q_chunk:
        return block(q, q_offset + jnp.arange(S))

    n = -(-S // q_chunk)
    pad = n * q_chunk - S
    if pad:
        q = jnp.pad(q, [(0, 0)] * 3 + [(0, pad), (0, 0)])
    qs = jnp.moveaxis(q.reshape(B, Hkv, G, n, q_chunk, hd), 3, 0)

    def step(_, xs):
        q_blk, idx = xs
        rows = q_offset + idx * q_chunk + jnp.arange(q_chunk)
        return None, block(q_blk, rows)

    with engine.repeat(n):  # body traced once, runs n q-chunks
        _, out = jax.lax.scan(step, None, (qs, jnp.arange(n)))
    out = jnp.moveaxis(out, 0, 3).reshape(B, Hkv, G, n * q_chunk, -1)
    return out[:, :, :, :S]


# --------------------------------------------------------------------- #
# GQA forward
# --------------------------------------------------------------------- #
def gqa_attention(
    params: Dict[str, jax.Array],
    x: jax.Array,  # (B, S, d)
    cfg,
    *,
    pos_offset: jax.Array,
    cache: Optional[Dict[str, jax.Array]] = None,
    window: Optional[jax.Array] = None,
    policy: prec.Policy,
    q_chunk: int = 1024,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = hq // hkv

    qkv = engine.matmul(x, params["wqkv"], policy=policy)
    if "bqkv" in params:
        qkv = qkv + params["bqkv"].astype(qkv.dtype)
    q, kk, vv = jnp.split(qkv, [hq * hd, (hq + hkv) * hd], axis=-1)
    q = q.reshape(B, S, hq, hd).transpose(0, 2, 1, 3)       # (B, Hq, S, hd)
    kk = kk.reshape(B, S, hkv, hd).transpose(0, 2, 1, 3)    # (B, Hkv, S, hd)
    vv = vv.reshape(B, S, hkv, hd).transpose(0, 2, 1, 3)

    if cfg.qk_norm:
        q = layers.rmsnorm(q, params["q_norm"])
        kk = layers.rmsnorm(kk, params["k_norm"])

    positions = pos_offset + jnp.arange(S)
    cos, sin = layers.rope(positions, hd, cfg.rope_theta)
    q = layers.apply_rope(q, cos, sin)
    kk = layers.apply_rope(kk, cos, sin)

    if cache is not None:
        if S == 1:
            # decode: masked merge — elementwise over the (possibly
            # TP-sharded) cache sequence dim, so no gather is forced the way
            # a dynamic-update-slice at a traced position would
            T = cache["k"].shape[2]
            hit = (jnp.arange(T) == pos_offset)[None, None, :, None]
            k_all = jnp.where(hit, kk.astype(cache["k"].dtype), cache["k"])
            v_all = jnp.where(hit, vv.astype(cache["v"].dtype), cache["v"])
        else:
            zero = jnp.zeros((), jnp.int32)
            k_all = jax.lax.dynamic_update_slice(
                cache["k"], kk.astype(cache["k"].dtype),
                (zero, zero, pos_offset, zero))
            v_all = jax.lax.dynamic_update_slice(
                cache["v"], vv.astype(cache["v"].dtype),
                (zero, zero, pos_offset, zero))
        new_cache = {"k": k_all, "v": v_all}
        kv_valid = pos_offset + S
    else:
        k_all, v_all, new_cache, kv_valid = kk, vv, None, jnp.int32(S)

    k_all = sharding.constrain(k_all, "batch", "kv_heads", "kv_seq", None)
    v_all = sharding.constrain(v_all, "batch", "kv_heads", "kv_seq", None)

    qg = q.reshape(B, hkv, g, S, hd)
    o = chunked_attention(
        qg, k_all, v_all,
        q_offset=pos_offset, kv_valid=kv_valid, causal=True,
        window=window, q_chunk=q_chunk, policy=policy,
    )
    o = o.reshape(B, hq, S, hd).transpose(0, 2, 1, 3).reshape(B, S, hq * hd)
    o = sharding.constrain(o, "batch", None, "heads")
    out = engine.matmul(o, params["wo"], policy=policy)
    return out, new_cache


# --------------------------------------------------------------------- #
# MLA forward (DeepSeek-V2 family)
# --------------------------------------------------------------------- #
def mla_attention(
    params: Dict[str, jax.Array],
    x: jax.Array,
    cfg,
    *,
    pos_offset: jax.Array,
    cache: Optional[Dict[str, jax.Array]] = None,
    policy: prec.Policy,
    q_chunk: int = 1024,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    m = cfg.mla
    B, S, d = x.shape
    hq = cfg.n_heads
    dn, dr, dv, r = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim, m.kv_lora_rank

    q = engine.matmul(x, params["wq"], policy=policy).reshape(B, S, hq, dn + dr)
    q = q.transpose(0, 2, 1, 3)  # (B, Hq, S, dn+dr)
    qn, qr = q[..., :dn], q[..., dn:]

    dkv = engine.matmul(x, params["wdkv"], policy=policy)  # (B, S, r + dr)
    ckv, kr = dkv[..., :r], dkv[..., r:]
    ckv = layers.rmsnorm(ckv, params["kv_norm"])

    positions = pos_offset + jnp.arange(S)
    cos, sin = layers.rope(positions, dr, cfg.rope_theta)
    qr = layers.apply_rope(qr, cos, sin)
    kr = layers.apply_rope(kr[:, None], cos, sin)[:, 0]  # (B, S, dr)

    if cache is not None:
        if S == 1:
            T = cache["ckv"].shape[1]
            hit = (jnp.arange(T) == pos_offset)[None, :, None]
            ckv_all = jnp.where(hit, ckv.astype(cache["ckv"].dtype), cache["ckv"])
            kr_all = jnp.where(hit, kr.astype(cache["kr"].dtype), cache["kr"])
        else:
            zero = jnp.zeros((), jnp.int32)
            ckv_all = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype),
                (zero, pos_offset, zero))
            kr_all = jax.lax.dynamic_update_slice(
                cache["kr"], kr.astype(cache["kr"].dtype),
                (zero, pos_offset, zero))
        new_cache = {"ckv": ckv_all, "kr": kr_all}
        kv_valid = pos_offset + S
    else:
        ckv_all, kr_all, new_cache, kv_valid = ckv, kr, None, jnp.int32(S)

    ckv_all = sharding.constrain(ckv_all, "batch", "kv_seq", None)
    T = ckv_all.shape[1]

    if S == 1 and cache is not None:
        # Absorbed decode: fold W_uk into the query and W_uv into the
        # context so the compressed cache is attended DIRECTLY — no
        # per-step (T, Hq*dn) k/v re-expansion (saves a factor of dn=128
        # on the T-dependent FLOPs; this was the useful~0 diagnosis of the
        # MLA decode cells in EXPERIMENTS.md §Roofline).
        # fp32-out engine policy: every absorbed contraction accumulates
        # (and is returned) in fp32, exactly like the old preferred_element_type
        abs_policy = prec.Policy(
            policy.name + "_absorbed", policy.compute_dtype,
            jnp.float32, jnp.float32)
        wuk = params["wuk"].reshape(r, hq, dn)
        wuv = params["wuv"].reshape(r, hq, dv)
        q_abs = engine.einsum2d("bhsd,rhd->bhsr", qn, wuk, policy=abs_policy)
        s = engine.einsum2d("bhsr,btr->bhst", q_abs, ckv_all, policy=abs_policy)
        s = s + engine.einsum2d("bhsd,btd->bhst", qr, kr_all, policy=abs_policy)
        s = s * (dn + dr) ** -0.5
        mask = jnp.arange(T)[None, None, None, :] < kv_valid
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx = engine.einsum2d("bhst,btr->bhsr", p, ckv_all, policy=abs_policy)
        o = engine.einsum2d("bhsr,rhd->bhsd", ctx, wuv, policy=abs_policy)
        o = o.astype(policy.compute_dtype)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, hq * dv)
        o = sharding.constrain(o, "batch", None, "heads")
        return engine.matmul(o, params["wo"], policy=policy), new_cache

    # Prefill/train: re-expand the compressed cache (the MLA trade:
    # small cache, extra GEMM)
    kn = engine.matmul(ckv_all, params["wuk"], policy=policy).reshape(B, T, hq, dn)
    vv = engine.matmul(ckv_all, params["wuv"], policy=policy).reshape(B, T, hq, dv)
    kn = kn.transpose(0, 2, 1, 3)  # (B, Hq, T, dn)
    vv = vv.transpose(0, 2, 1, 3)
    k_full = jnp.concatenate(
        [kn, jnp.broadcast_to(kr_all[:, None], (B, hq, T, dr))], axis=-1)
    q_full = jnp.concatenate([qn, qr], axis=-1)

    o = chunked_attention(
        q_full[:, :, None], k_full, vv,
        q_offset=pos_offset, kv_valid=kv_valid, causal=True,
        q_chunk=q_chunk, scale=(dn + dr) ** -0.5, policy=policy,
    )
    o = o[:, :, 0].transpose(0, 2, 1, 3).reshape(B, S, hq * dv)
    o = sharding.constrain(o, "batch", None, "heads")
    out = engine.matmul(o, params["wo"], policy=policy)
    return out, new_cache
