"""Mixture-of-Experts on the RedMulE engine (DeepSeek-style).

Fine-grained experts are exactly the small-GEMM regime where the paper shows
utilization collapse (Fig 3d): a single 1408-wide expert GEMM over a few
tokens cannot fill the array.  The dispatch below therefore *groups* tokens
by expert (sort + capacity buffer) and runs all experts as one batched
RedMulE GEMM (E, C, d) x (E, d, f) — the fat-GEMM restoration the paper's
batching experiment (Fig 4d) performs for the AutoEncoder.

Expert-parallel sharding: the (E, ...) dimension carries the "experts"
logical axis -> the mesh "model" axis; GSPMD inserts the token all-to-all.

Dispatch is the sort-based, dropping implementation (MaxText/Switch style):
top-k -> stable sort by expert -> per-expert rank via one-hot cumsum ->
capacity clamp -> scatter into (E*C, d) -> batched GEMMs -> gather+combine.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core import precision as prec
from repro.models import layers
from repro.models.layers import Param
from repro.runtime import compat, sharding

__all__ = ["moe_schema", "moe_forward"]


def _combine_policy(policy: prec.Policy) -> prec.Policy:
    """Combiner precision: gate-weighted slot reduction in the datapath
    compute dtype with an fp32 accumulator/output (like the router, the
    combine wants full-precision arithmetic regardless of any FP8
    storage the expert GEMMs declare)."""
    return prec.Policy("moe_combine", policy.compute_dtype,
                       jnp.float32, jnp.float32)


def moe_schema(cfg) -> Dict[str, Any]:
    mo = cfg.moe
    d, E, f = cfg.d_model, mo.n_routed, mo.d_expert
    s: Dict[str, Any] = {
        "router": Param((d, E), ("embed", None)),
        "w_in": Param((E, d, 2 * f), ("experts", "embed_unsharded", "expert_ff")),
        "w_out": Param((E, f, d), ("experts", "expert_ff", "embed_unsharded")),
    }
    if mo.n_shared:
        fs = mo.n_shared * f
        s["shared"] = {
            "w_in": Param((d, 2 * fs), ("embed", "ff")),
            "w_out": Param((fs, d), ("ff", "embed")),
        }
    return s


def _dispatch_row(xs, ids, gate, *, E: int, k: int, C: int, dtype):
    """Dispatch one batch row. xs: (S, d), ids/gate: (S, k).

    Only *permutation* gathers/scatters are used (no duplicate-index
    scatter-adds): their transposes are permutations too, so the backward
    pass stays shard-local instead of lowering to full-tensor fp32
    all-reduces (observed with the classic token-indexed combine).

    Returns (buf (E, C, d), dest (S*k,), inv (S*k,), w_slot (S*k,))."""
    S = xs.shape[0]
    flat_e = ids.reshape(-1)                              # (S*k,)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    oh = (se[:, None] == jnp.arange(E, dtype=se.dtype)[None, :]).astype(jnp.int32)
    rank = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1      # rank within expert
    keep = rank < C
    dest = jnp.where(keep, se * C + rank, E * C)          # dropped -> spill row
    # token t occupies slots t*k..t*k+k-1: replicate rows, then permute
    x_rep = jnp.broadcast_to(xs[:, None], (S, k, xs.shape[1])).reshape(S * k, -1)
    x_sorted = jnp.take(x_rep, order, axis=0)             # permutation gather
    buf = jnp.zeros((E * C + 1, xs.shape[1]), dtype)
    buf = buf.at[dest].set(x_sorted.astype(dtype), mode="drop")
    inv = jnp.argsort(order)                              # sorted -> slot order
    w_slot = (gate.reshape(-1)[order] * keep).astype(jnp.float32)
    return buf[: E * C].reshape(E, C, -1), dest, inv, w_slot


def moe_forward(
    params: Dict[str, Any],
    x: jax.Array,  # (B, S, d)
    cfg,
    *,
    policy: prec.Policy,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Per-row dispatch (DP-local routing) + one EP layout change.

    Routing, sort and scatter are vmapped over the batch dim, so every DP
    shard dispatches its own tokens with zero cross-shard traffic; the only
    communication is the (B, E, C, d) -> expert-sharded constraint (the MoE
    all-to-all) around the batched expert GEMM.
    """
    mo = cfg.moe
    B, S, d = x.shape
    E, k, f = mo.n_routed, mo.top_k, mo.d_expert
    # dispatch math must be batch-local: pin x here (upstream attention
    # leaves the hidden d-sharded over TP, which would turn every gather
    # below into a cross-shard select+all-reduce)
    x = sharding.constrain_both(x, "batch", None, None)

    # ---- router (fp32 logits — routing decisions want full precision) ----
    logits = engine.matmul(
        x, params["router"],
        policy=prec.Policy("router", policy.compute_dtype, jnp.float32, jnp.float32),
    )                                                     # (B, S, E) fp32
    logits = sharding.constrain(logits, "batch", None, None)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)                   # (B, S, k)
    if mo.norm_topk_prob:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux (Switch-style) + router z-loss ----
    counts = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    frac_tokens = counts / (B * S * k)
    mean_prob = probs.mean(axis=(0, 1))
    aux_loss = E * jnp.sum(frac_tokens * mean_prob)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- per-row sort-based dispatch with capacity ----
    C = int(math.ceil(S * k / E * mo.capacity_factor))
    C = -(-C // 8) * 8  # sublane-align the expert batch
    bufs, dest, inv, w_slot = jax.vmap(
        functools.partial(_dispatch_row, E=E, k=k, C=C,
                          dtype=policy.compute_dtype))(x, ids, gate)
    # EP layout change: batch-sharded rows -> expert-sharded GEMM operands
    # (value expert-sharded; cotangent must re-enter the dispatch-scatter
    #  transpose batch-local, hence the asymmetric pin)
    bufs = sharding.constrain_fb(
        bufs, ("batch", "experts", None, None), ("batch", None, None, None))

    # ---- all experts as ONE grouped RedMulE GEMM (fat-GEMM restoration) ----
    h = engine.grouped_matmul(bufs, params["w_in"], policy=policy)  # (B, E, C, 2f)
    g_, u_ = jnp.split(h, 2, axis=-1)
    h = layers.activation(g_, cfg.act) * u_
    h = sharding.constrain(h, "batch", "experts", None, "expert_ff")
    out = engine.grouped_matmul(h, params["w_out"], policy=policy)  # (B, E, C, d)
    # return all-to-all: expert-sharded -> batch-local BEFORE the combine
    # gather, else GSPMD lowers the gather-from-sharded as fp32 partial
    # all-reduces of the full (S*k, d) slot tensor (7x the traffic)
    out = sharding.constrain_fb(
        out, ("batch", None, None, None), ("batch", "experts", None, None))

    # ---- combine: ONE permutation gather + a local k-reduction ----
    flat = jnp.concatenate(
        [out.reshape(B, E * C, d), jnp.zeros((B, 1, d), out.dtype)], axis=1)
    flat = sharding.constrain_both(flat, "batch", None, None)
    # fold the inverse sort into the slot indices (index gathers are cheap)
    dest_u = jnp.take_along_axis(dest, inv, axis=1)             # (B, S*k)
    w_u = jnp.take_along_axis(w_slot, inv, axis=1)
    slot_u = jnp.take_along_axis(flat, dest_u[..., None], axis=1)  # (B,S*k,d)
    slot_u = sharding.constrain_both(slot_u, "batch", None, None)
    # combine is a contraction over the k routed slots — an Engine GEMM
    # like any other (events, autotuned tiles), fp32-accumulated with the
    # operands staying in the 16-bit compute dtype
    y = engine.einsum2d(
        "bskd,bsk->bsd", slot_u.reshape(B, S, k, d), w_u.reshape(B, S, k),
        policy=_combine_policy(policy)).astype(x.dtype)
    y = sharding.constrain_both(y, "batch", None, None)

    if "shared" in params:
        y = y + layers.mlp_glu(params["shared"], x, act=cfg.act, policy=policy)

    metrics = {
        "moe_aux_loss": aux_loss,
        "moe_z_loss": z_loss,
        "moe_drop_frac": (dest >= E * C).astype(jnp.float32).mean(),
    }
    return y, metrics


# --------------------------------------------------------------------- #
# Manual expert parallelism (shard_map) — the production EP path
# --------------------------------------------------------------------- #
def moe_forward_shard_map(
    params: Dict[str, Any],
    x: jax.Array,  # (B, S, d) — batch sharded over DP axes
    cfg,
    *,
    policy: prec.Policy,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """EP with explicit ``all_to_all``s inside ``shard_map``.

    GSPMD's transposed scatter/gathers for the sort-based dispatch lower to
    full-tensor fp32 all-reduces (§Perf, measured ~7x the necessary wire).
    Under shard_map the only collectives are the two token all-to-alls whose
    transposes are all-to-alls again — backward traffic == forward traffic
    by construction.

    Requires: mesh with a "model" axis dividing n_routed; tokens already
    batch-sharded.  Falls back to ``moe_forward`` outside a mesh.
    """
    mesh = compat.current_abstract_mesh()
    dp_size = 1
    if mesh is not None and not mesh.empty:
        for a in ("pod", "data"):
            dp_size *= mesh.shape.get(a, 1)
    if (mesh is None or mesh.empty or "model" not in mesh.shape
            or cfg.moe.n_routed % mesh.shape["model"] != 0
            or (x.shape[0] // max(dp_size, 1)) % mesh.shape["model"] != 0):
        return moe_forward(params, x, cfg, policy=policy)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mo = cfg.moe
    B, S, d = x.shape
    E, k = mo.n_routed, mo.top_k
    ep = mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = dp_axes[0] if len(dp_axes) == 1 else dp_axes

    def local_fn(w_in_l, w_out_l, router_w, x_full):
        # x_full: (B_loc, S, d), replicated over the model axis.  Slice the
        # rows across model peers FIRST — otherwise every TP peer would
        # dispatch and compute the same tokens (16x redundant work+wire).
        Bfull = x_full.shape[0]
        mi = jax.lax.axis_index("model")
        rows = Bfull // ep
        x_l = jax.lax.dynamic_slice_in_dim(x_full, mi * rows, rows, axis=0)
        Bl = x_l.shape[0]
        logits = engine.matmul(
            x_l, router_w,
            policy=prec.Policy("router", policy.compute_dtype,
                               jnp.float32, jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate, ids = jax.lax.top_k(probs, k)
        if mo.norm_topk_prob:
            gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        C = int(math.ceil(S * k / E * mo.capacity_factor))
        C = -(-C // 8) * 8
        bufs, dest, inv, w_slot = jax.vmap(
            functools.partial(_dispatch_row, E=E, k=k, C=C,
                              dtype=policy.compute_dtype))(x_l, ids, gate)
        # (B_loc, E, C, d) -> exchange expert shards over the model axis:
        # peer-major layout + symmetric tiled all_to_all (its transpose is
        # an all_to_all of identical shape — backward wire == forward wire)
        t = bufs.reshape(Bl, ep, E // ep, C, d)
        t = jnp.moveaxis(t, 1, 0)                          # (ep, Bl, E/ep, C, d)
        t = jax.lax.all_to_all(t, "model", split_axis=0, concat_axis=0,
                               tiled=True)                 # axis0 now = source peer
        t = jnp.moveaxis(t, 2, 0)                          # (E/ep, ep, Bl, C, d)

        h = engine.grouped_matmul(
            t.reshape(E // ep, -1, d), w_in_l, policy=policy)
        g_, u_ = jnp.split(h, 2, axis=-1)
        h = layers.activation(g_, cfg.act) * u_
        out = engine.grouped_matmul(h, w_out_l, policy=policy)  # (E/ep, ep*Bl*C, d)

        out = out.reshape(E // ep, ep, Bl, C, d)
        out = jnp.moveaxis(out, 0, 2)                      # (ep, Bl, E/ep, C, d)
        out = jax.lax.all_to_all(out, "model", split_axis=0, concat_axis=0,
                                 tiled=True)               # back to expert-major
        out = jnp.moveaxis(out, 0, 1).reshape(Bl, E, C, d)

        flat = jnp.concatenate(
            [out.reshape(Bl, E * C, d), jnp.zeros((Bl, 1, d), out.dtype)],
            axis=1)
        dest_u = jnp.take_along_axis(dest, inv, axis=1)
        w_u = jnp.take_along_axis(w_slot, inv, axis=1)
        slot_u = jnp.take_along_axis(flat, dest_u[..., None], axis=1)
        y = engine.einsum2d(
            "bskd,bsk->bsd", slot_u.reshape(Bl, S, k, d),
            w_u.reshape(Bl, S, k),
            policy=_combine_policy(policy)).astype(x_l.dtype)
        # restore the model-replicated row layout
        y = jax.lax.all_gather(y, "model", axis=0, tiled=True)  # (B_loc, S, d)

        # every device now routes a distinct token slice: stats reduce over
        # data AND model axes
        all_axes = dp_axes + ("model",)
        counts = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
        aux = E * jnp.sum(
            jax.lax.psum(counts, all_axes) /
            jax.lax.psum(jnp.float32(S * k * Bl), all_axes)
            * jax.lax.pmean(probs.mean(axis=(0, 1)), all_axes))
        z = jax.lax.pmean(
            jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2), ("model",))
        drop = jax.lax.pmean(
            (dest >= E * C).astype(jnp.float32).mean(), ("model",))
        return y, aux, z, drop

    in_specs = (
        P("model", None, None),   # w_in  (E, d, 2f)
        P("model", None, None),   # w_out (E, f, d)
        P(),                      # router (replicated)
        P(dp, None, None),        # x
    )
    out_specs = (P(dp, None, None), P(), P(), P())
    # instrumentation: local_fn is traced once with per-shard shapes but
    # executes once per (dp x model) shard — the axes in_specs partitions
    # over — so carry that count as the event multiplier; engine_flops
    # stays a *global* count, consistent with the globally-shaped GEMMs
    # traced outside shard_map
    n_shards = dp_size * ep
    with engine.repeat(n_shards):
        y, aux, z, drop = shard_map(
            local_fn, mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )(params["w_in"], params["w_out"], params["router"], x)

    if "shared" in params:
        y = y + layers.mlp_glu(params["shared"], x, act=cfg.act, policy=policy)
    metrics = {"moe_aux_loss": aux, "moe_z_loss": z, "moe_drop_frac": drop}
    return y, metrics
