"""Model composition: decoder LMs (dense / MoE / xLSTM / Hymba / audio / VLM).

Layers are scanned (stacked params) so HLO size is O(1) in depth.
Heterogeneous stacks are expressed structurally:

  * dense/audio/vlm — one scanned stack of attention blocks;
  * moe (DeepSeek)  — an unstacked ``layer0`` (dense FFN) + scanned MoE stack;
  * xlstm           — scanned super-blocks of (7 mLSTM + 1 sLSTM);
  * hymba           — one scanned stack of parallel attn+SSM blocks with a
                      per-layer sliding-window array (full-attn layers get a
                      2^30 window).

Public API: ``schema / init_params / param_specs / abstract_params /
forward / loss_fn / serve_step / init_cache / count_params``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.models import attention, layers, moe, ssm
from repro.models.layers import Param
from repro.runtime import sharding

__all__ = [
    "schema", "init_params", "param_specs", "abstract_params",
    "forward", "loss_fn", "serve_step", "init_cache", "count_params",
    "window_array",
]

BIG_WINDOW = 1 << 30


# --------------------------------------------------------------------- #
# Schemas
# --------------------------------------------------------------------- #
def _norm_param(cfg) -> Param:
    return Param((cfg.d_model,), (None,), init="ones")


def _mlp_schema(cfg, d_ff: int) -> Dict[str, Any]:
    d = cfg.d_model
    if cfg.mlp == "glu":
        return {
            "w_in": Param((d, 2 * d_ff), ("embed", "ff")),
            "w_out": Param((d_ff, d), ("ff", "embed")),
        }
    return {
        "w_in": Param((d, d_ff), ("embed", "ff")),
        "w_out": Param((d_ff, d), ("ff", "embed")),
    }


def _attn_schema(cfg) -> Dict[str, Any]:
    return attention.mla_schema(cfg) if cfg.mla else attention.gqa_schema(cfg)


def _attn_block_schema(cfg, d_ff: Optional[int] = None) -> Dict[str, Any]:
    return {
        "ln1": _norm_param(cfg),
        "attn": _attn_schema(cfg),
        "ln2": _norm_param(cfg),
        "mlp": _mlp_schema(cfg, d_ff or cfg.d_ff),
    }


def _moe_block_schema(cfg) -> Dict[str, Any]:
    return {
        "ln1": _norm_param(cfg),
        "attn": _attn_schema(cfg),
        "ln2": _norm_param(cfg),
        "moe": moe.moe_schema(cfg),
    }


def _hymba_block_schema(cfg) -> Dict[str, Any]:
    return {
        "ln1": _norm_param(cfg),
        "attn": attention.gqa_schema(cfg),
        "attn_out_norm": _norm_param(cfg),
        "mamba": ssm.mamba_schema(cfg),
        "mamba_out_norm": _norm_param(cfg),
        "ln2": _norm_param(cfg),
        "mlp": _mlp_schema(cfg, cfg.d_ff),
    }


def _xlstm_super_schema(cfg) -> Dict[str, Any]:
    n_m = cfg.ssm.slstm_period - 1
    m_block = {"ln": _norm_param(cfg), "cell": ssm.mlstm_schema(cfg)}
    s_block = {"ln": _norm_param(cfg), "cell": ssm.slstm_schema(cfg)}
    return {
        "mlstm": layers.stack_schema(m_block, n_m),
        "slstm": s_block,
    }


def schema(cfg) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    s: Dict[str, Any] = {
        "embed": Param((v, d), ("vocab", "embed"), init="embed"),
        "final_norm": _norm_param(cfg),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = Param((d, v), ("embed", "vocab"))

    kind = cfg.block_kind
    if kind == "attn":
        s["layers"] = layers.stack_schema(_attn_block_schema(cfg), cfg.n_layers)
    elif kind == "moe":
        nd = cfg.moe.first_dense
        s["layer0"] = _attn_block_schema(cfg, cfg.moe.dense_ff)
        assert nd == 1, "only first_dense=1 supported"
        s["layers"] = layers.stack_schema(_moe_block_schema(cfg), cfg.n_layers - nd)
    elif kind == "hymba":
        s["layers"] = layers.stack_schema(_hymba_block_schema(cfg), cfg.n_layers)
    elif kind == "xlstm":
        n_super, rem = divmod(cfg.n_layers, cfg.ssm.slstm_period)
        assert rem == 0, f"n_layers {cfg.n_layers} % period {cfg.ssm.slstm_period}"
        s["layers"] = layers.stack_schema(_xlstm_super_schema(cfg), n_super)
    else:
        raise ValueError(kind)
    return s


def init_params(rng: jax.Array, cfg):
    return layers.init_tree(rng, schema(cfg), dtype=jnp.dtype(cfg.param_dtype))


def param_specs(cfg, rules: Optional[sharding.Rules]):
    return layers.spec_tree(schema(cfg), rules)


def abstract_params(cfg):
    return layers.abstract_tree(schema(cfg), dtype=jnp.dtype(cfg.param_dtype))


def count_params(cfg, active_only: bool = False) -> int:
    import numpy as np

    total = 0
    routed = 0

    def go(node, path):
        nonlocal total, routed
        if isinstance(node, Param):
            n = int(np.prod(node.shape)) if node.shape else 1
            total += n
            if "experts" in node.axes:
                routed += n
            return
        for k, v in node.items():
            go(v, path + (k,))

    go(schema(cfg), ())
    if active_only and cfg.moe:
        inactive = routed * (cfg.moe.n_routed - cfg.moe.top_k) / cfg.moe.n_routed
        return int(total - inactive)
    return total


def window_array(cfg) -> Optional[jax.Array]:
    """Per-layer attention windows (hymba); None when not applicable."""
    if cfg.sliding_window is None:
        return None
    w = [
        BIG_WINDOW if i in cfg.full_attn_layers else cfg.sliding_window
        for i in range(cfg.n_layers)
    ]
    return jnp.asarray(w, jnp.int32)


# --------------------------------------------------------------------- #
# Blocks
# --------------------------------------------------------------------- #
def _norm(cfg, x, scale):
    if cfg.norm == "layernorm":
        return layers.layernorm(x, scale)
    return layers.rmsnorm(x, scale)


def _run_attn(cfg, p, h, *, pos, cache, window, policy, kv_group_sizes=None):
    if cfg.mla:
        return attention.mla_attention(
            p, h, cfg, pos_offset=pos, cache=cache, policy=policy,
            q_chunk=cfg.q_chunk, kv_group_sizes=kv_group_sizes)
    return attention.gqa_attention(
        p, h, cfg, pos_offset=pos, cache=cache, window=window, policy=policy,
        q_chunk=cfg.q_chunk, kv_group_sizes=kv_group_sizes)


def _attn_block(p, h, cfg, *, pos, cache, window, policy, d_ff=None,
                kv_group_sizes=None):
    a, cache = _run_attn(cfg, p["attn"], _norm(cfg, h, p["ln1"]),
                         pos=pos, cache=cache, window=window, policy=policy,
                         kv_group_sizes=kv_group_sizes)
    h = h + a
    if cfg.mlp == "glu":
        m = layers.mlp_glu(p["mlp"], _norm(cfg, h, p["ln2"]), act=cfg.act, policy=policy)
    else:
        hh = engine.linear(_norm(cfg, h, p["ln2"]), p["mlp"]["w_in"],
                           activation=cfg.act, policy=policy)
        m = engine.matmul(hh, p["mlp"]["w_out"], policy=policy)
    return h + m, cache, {}


def _moe_block(p, h, cfg, *, pos, cache, policy, kv_group_sizes=None):
    a, cache = _run_attn(cfg, p["attn"], _norm(cfg, h, p["ln1"]),
                         pos=pos, cache=cache, window=None, policy=policy,
                         kv_group_sizes=kv_group_sizes)
    h = h + a
    moe_fn = (moe.moe_forward_shard_map if cfg.moe_impl == "shard_map"
              else moe.moe_forward)
    m, metrics = moe_fn(p["moe"], _norm(cfg, h, p["ln2"]), cfg, policy=policy)
    return h + m, cache, metrics


def _hymba_block(p, h, cfg, *, pos, cache, window, policy):
    hn = _norm(cfg, h, p["ln1"])
    a, attn_cache = attention.gqa_attention(
        p["attn"], hn, cfg, pos_offset=pos,
        cache=None if cache is None else cache["attn"],
        window=window, policy=policy, q_chunk=cfg.q_chunk)
    m, ssm_state = ssm.mamba_mixer(
        p["mamba"], hn, cfg, policy=policy,
        state=None if cache is None else cache["ssm"])
    fused = 0.5 * (_norm(cfg, a, p["attn_out_norm"]) + _norm(cfg, m, p["mamba_out_norm"]))
    h = h + fused
    mlp_out = layers.mlp_glu(p["mlp"], _norm(cfg, h, p["ln2"]), act=cfg.act, policy=policy)
    new_cache = None if cache is None else {"attn": attn_cache, "ssm": ssm_state}
    return h + mlp_out, new_cache, {}


def _xlstm_super_block(p, h, cfg, *, cache, policy):
    """7 scanned mLSTM blocks + 1 sLSTM block."""

    m_cache = None if cache is None else cache["mlstm"]
    if m_cache is None:
        # training/prefill-from-zero: in-sequence state starts at zero
        # inside the chunked engine; nothing is carried across layers
        def m_body(hh, lp):
            out, _ = ssm.mlstm_block(
                lp["cell"], _norm(cfg, hh, lp["ln"]), cfg, policy=policy)
            return hh + out, 0
        n_m = jax.tree_util.tree_leaves(p["mlstm"])[0].shape[0]
        with engine.repeat(n_m):
            h, m_states = jax.lax.scan(m_body, h, p["mlstm"])
        m_states = None
    else:
        def m_body(hh, xs):
            lp, st = xs
            out, st_new = ssm.mlstm_block(
                lp["cell"], _norm(cfg, hh, lp["ln"]), cfg, policy=policy, state=st)
            return hh + out, st_new
        n_m = jax.tree_util.tree_leaves(p["mlstm"])[0].shape[0]
        with engine.repeat(n_m):
            h, m_states = jax.lax.scan(m_body, h, (p["mlstm"], m_cache))

    s_cache = None if cache is None else cache["slstm"]
    out, s_state = ssm.slstm_block(
        p["slstm"]["cell"], _norm(cfg, h, p["slstm"]["ln"]), cfg,
        policy=policy, state=s_cache)
    h = h + out
    new_cache = None if cache is None else {"mlstm": m_states, "slstm": s_state}
    return h, new_cache, {}


# --------------------------------------------------------------------- #
# Stacks
# --------------------------------------------------------------------- #
def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def _scan_stack(cfg, block_fn, stack_params, h, cache_stack, windows):
    """Generic layer scan. cache_stack/windows may be None."""
    has_cache = cache_stack is not None
    has_win = windows is not None

    def body(carry, xs):
        h, aux = carry
        lp = xs[0]
        lc = xs[1] if has_cache else None
        win = xs[1 + has_cache] if has_win else None
        # sequence parallelism: residual stream (and the per-layer saved
        # activations) live sequence-sharded over the TP axis between
        # blocks; no-op unless rules enable seq_sharded
        h = sharding.constrain(h, "batch", "seq_sharded", None)
        h, lc_new, m = block_fn(lp, h, cache=lc, window=win)
        h = sharding.constrain(h, "batch", "seq_sharded", None)
        aux = {k: aux[k] + m.get(k, 0.0) for k in aux}
        return (h, aux), (lc_new if has_cache else 0)

    aux0 = (
        {k: jnp.zeros((), jnp.float32)
         for k in ("moe_aux_loss", "moe_z_loss", "moe_drop_frac")}
        if cfg.block_kind == "moe" else {}
    )
    xs: Tuple = (stack_params,)
    if has_cache:
        xs = xs + (cache_stack,)
    if has_win:
        xs = xs + (windows,)
    n_layers = jax.tree_util.tree_leaves(stack_params)[0].shape[0]
    with engine.repeat(n_layers):  # body traced once, runs n_layers times
        (h, aux), new_cache = jax.lax.scan(_remat(cfg, body), (h, aux0), xs)
    return h, (new_cache if has_cache else None), aux


# --------------------------------------------------------------------- #
# Forward / loss / serve
# --------------------------------------------------------------------- #
def forward(
    params: Dict[str, Any],
    cfg,
    batch: Dict[str, jax.Array],
    *,
    cache: Optional[Dict[str, Any]] = None,
    pos: jax.Array | int = 0,
    last_only: bool = False,
    head: bool = True,
    kv_group_sizes: Optional[Any] = None,
) -> Tuple[jax.Array, Optional[Dict[str, Any]], Dict[str, jax.Array]]:
    policy = cfg.policy
    pos = jnp.asarray(pos, jnp.int32)

    if "embeddings" in batch:
        h = batch["embeddings"].astype(policy.compute_dtype)
    else:
        h = params["embed"][batch["inputs"]].astype(policy.compute_dtype)
    h = sharding.constrain(h, "batch", "seq_sharded", None)

    kind = cfg.block_kind
    new_cache: Dict[str, Any] = {}
    if kind == "attn":
        fn = lambda lp, hh, *, cache, window: _attn_block(
            lp, hh, cfg, pos=pos, cache=cache, window=window, policy=policy,
            kv_group_sizes=kv_group_sizes)
        h, nc, aux = _scan_stack(
            cfg, fn, params["layers"], h,
            None if cache is None else cache["layers"], window_array(cfg))
        new_cache["layers"] = nc
    elif kind == "moe":
        c0 = None if cache is None else cache["layer0"]
        h, nc0, _ = _attn_block(
            params["layer0"], h, cfg, pos=pos, cache=c0, window=None,
            policy=policy, d_ff=cfg.moe.dense_ff,
            kv_group_sizes=kv_group_sizes)
        fn = lambda lp, hh, *, cache, window: _moe_block(
            lp, hh, cfg, pos=pos, cache=cache, policy=policy,
            kv_group_sizes=kv_group_sizes)
        h, nc, aux = _scan_stack(
            cfg, fn, params["layers"], h,
            None if cache is None else cache["layers"], None)
        new_cache["layer0"] = nc0
        new_cache["layers"] = nc
    elif kind == "hymba":
        fn = lambda lp, hh, *, cache, window: _hymba_block(
            lp, hh, cfg, pos=pos, cache=cache, window=window, policy=policy)
        h, nc, aux = _scan_stack(
            cfg, fn, params["layers"], h,
            None if cache is None else cache["layers"], window_array(cfg))
        new_cache["layers"] = nc
    elif kind == "xlstm":
        fn = lambda lp, hh, *, cache, window: _xlstm_super_block(
            lp, hh, cfg, cache=cache, policy=policy)
        h, nc, aux = _scan_stack(
            cfg, fn, params["layers"], h,
            None if cache is None else cache["layers"], None)
        new_cache["layers"] = nc
    else:
        raise ValueError(kind)

    if last_only:
        h = h[:, -1:]  # serving: never materialize (B, S, V) prompt logits
    h = _norm(cfg, h, params["final_norm"])
    if not head:
        return h, (new_cache if cache is not None else None), aux
    w_head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = engine.matmul(h, w_head, policy=policy)
    logits = sharding.constrain(logits, "batch", "seq_sharded", "vocab")
    return logits, (new_cache if cache is not None else None), aux


def _chunked_ce(params, cfg, h, labels) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Fused/chunked CE: the (B, S, V) logits tensor is never materialized.

    Scans batch-row chunks; each chunk's vocab GEMM + log-softmax is inside
    a jax.checkpoint so backward recomputes the chunk logits instead of
    storing them.  Peak extra memory: one chunk of fp32 logits."""
    policy = cfg.policy
    w_head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    B = h.shape[0]
    c = max(1, min(cfg.ce_chunk, B))
    n = -(-B // c)
    pad = n * c - B
    if pad:
        h = jnp.concatenate([h, jnp.zeros((pad, *h.shape[1:]), h.dtype)])
        labels = jnp.concatenate(
            [labels, jnp.full((pad, labels.shape[1]), -1, labels.dtype)])

    @jax.checkpoint
    def chunk(h_c, y_c):
        logits = engine.matmul(h_c, w_head, policy=policy)
        logits = sharding.constrain(logits, "batch", "seq_sharded", "vocab")
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(
            lf, jnp.maximum(y_c, 0)[..., None], axis=-1)[..., 0]
        mask = (y_c >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * mask), jnp.sum(mask)

    def body(carry, xs):
        tot, cnt = carry
        s, m = chunk(*xs)
        return (tot + s, cnt + m), 0

    hs = h.reshape(n, c, *h.shape[1:])
    ys = labels.reshape(n, c, labels.shape[1])
    with engine.repeat(n):  # CE chunks: body traced once, runs n times
        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.float32(0), jnp.float32(0)), (hs, ys))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss, {"loss": loss, "ntokens": cnt}


def loss_fn(params, cfg, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    if cfg.ce_chunk:
        h, _, aux = forward(params, cfg, batch, head=False)
        loss, metrics = _chunked_ce(params, cfg, h, batch["labels"])
    else:
        logits, _, aux = forward(params, cfg, batch)
        loss, metrics = layers.cross_entropy(logits, batch["labels"])
    if cfg.moe:
        loss = loss + cfg.moe.aux_weight * aux["moe_aux_loss"] / max(cfg.n_layers - 1, 1)
        loss = loss + cfg.moe.z_weight * aux["moe_z_loss"] / max(cfg.n_layers - 1, 1)
        metrics.update({k: v for k, v in aux.items()})
    metrics["loss"] = loss
    return loss, metrics


def serve_step(params, cfg, tokens, cache, pos, *, kv_group_sizes=None):
    """One decode step: tokens (B, 1) + cache @ pos -> (logits (B, V), cache').

    ``pos`` may be a scalar (uniform batch — the classic greedy loop) or a
    per-slot ``(B,)`` vector (the serving scheduler's continuous batch).
    ``kv_group_sizes`` (optional, per-slot valid kv lengths) routes the
    decode score GEMMs through the Engine's ragged grouped path — see
    :func:`repro.models.attention.chunked_attention`."""
    logits, new_cache, _ = forward(
        params, cfg, {"inputs": tokens}, cache=cache, pos=pos,
        kv_group_sizes=kv_group_sizes)
    return logits[:, -1], new_cache


def prefill(params, cfg, batch, max_len: int, storage_dtype=None):
    """Prefill: run the prompt, build the cache, return last-token logits.

    ``storage_dtype`` (an FP8 format name) builds the quantized serving
    cache — the prompt's k/v rows are quantized on write with per-head
    delayed scales (see :func:`init_cache`)."""
    some = batch.get("inputs", batch.get("embeddings"))
    B = some.shape[0]
    cache = init_cache(cfg, B, max_len, dtype=cfg.policy.compute_dtype,
                       storage_dtype=storage_dtype)
    logits, cache, _ = forward(params, cfg, batch, cache=cache, pos=0,
                               last_only=True)
    return logits[:, -1], cache


# --------------------------------------------------------------------- #
# Caches
# --------------------------------------------------------------------- #
def cache_axes(cfg, storage_dtype=None):
    """Logical sharding axes for every leaf of ``init_cache``'s output.

    With ``storage_dtype`` set (FP8 serving cache) the tree grows the
    per-head delayed-scaling leaves next to each quantized tensor —
    mirror of :func:`init_cache`'s structure, leaf for leaf."""
    kind = cfg.block_kind
    gqa = {"k": ("batch", "kv_heads", "kv_seq", None),
           "v": ("batch", "kv_heads", "kv_seq", None)}
    mla = {"ckv": ("batch", "kv_seq", None), "kr": ("batch", "kv_seq", None)}
    if storage_dtype is not None:
        gqa = dict(gqa,
                   k_scale=attention._scale_leaf_axes(("kv_heads",)),
                   v_scale=attention._scale_leaf_axes(("kv_heads",)))
        mla = dict(mla,
                   ckv_scale=attention._scale_leaf_axes(()),
                   kr_scale=attention._scale_leaf_axes(()))
    attn = mla if cfg.mla else gqa
    stackax = lambda tree: jax.tree.map(
        lambda ax: ("layers", *ax), tree, is_leaf=lambda x: isinstance(x, tuple))
    if kind == "attn":
        return {"layers": stackax(attn)}
    if kind == "moe":
        return {"layer0": attn, "layers": stackax(attn)}
    if kind == "hymba":
        one = {"attn": gqa, "ssm": ("batch", None, None, None)}
        return {"layers": stackax(one)}
    if kind == "xlstm":
        one = {
            "mlstm": (None, "batch", None, None, None),
            "slstm": {k: ("batch", None, None) for k in ("c", "n", "h", "m")},
        }
        return {"layers": stackax(one)}
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_len: int, dtype=None, storage_dtype=None):
    """Build the decode cache.

    ``storage_dtype`` (an FP8 format name, serving) stores the attention
    k/v tensors narrow with per-head delayed-scaling leaves alongside —
    the RedMulE mixed-precision trade (narrow storage, wide datapath)
    applied to the KV cache.  Only attention caches quantize; SSM/xLSTM
    state stays wide (attn/moe block kinds only)."""
    dtype = dtype or cfg.policy.compute_dtype
    kind = cfg.block_kind
    if storage_dtype is not None and kind not in ("attn", "moe"):
        raise ValueError(
            f"FP8 cache storage supports attn/moe block kinds, not {kind!r}")

    def stack(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), tree)

    if kind == "attn":
        one = (attention.init_mla_cache if cfg.mla else attention.init_gqa_cache)(
            cfg, batch, max_len, dtype, storage_dtype)
        return {"layers": stack(one, cfg.n_layers)}
    if kind == "moe":
        one = (attention.init_mla_cache if cfg.mla else attention.init_gqa_cache)(
            cfg, batch, max_len, dtype, storage_dtype)
        return {"layer0": one, "layers": stack(one, cfg.n_layers - cfg.moe.first_dense)}
    if kind == "hymba":
        di = cfg.ssm.mamba_expand * cfg.d_model
        one = {
            "attn": attention.init_gqa_cache(cfg, batch, max_len, dtype),
            "ssm": jnp.zeros(
                (batch, cfg.n_heads, cfg.ssm.state_dim, di // cfg.n_heads),
                jnp.float32),
        }
        return {"layers": stack(one, cfg.n_layers)}
    if kind == "xlstm":
        n_super = cfg.n_layers // cfg.ssm.slstm_period
        n_m = cfg.ssm.slstm_period - 1
        hd_m = cfg.ssm.mlstm_proj_factor * cfg.d_model // cfg.n_heads
        hd_s = cfg.d_model // cfg.n_heads
        z = lambda *s: jnp.zeros(s, jnp.float32)
        one = {
            "mlstm": z(n_m, batch, cfg.n_heads, hd_m, hd_m),
            "slstm": {
                "c": z(batch, cfg.n_heads, hd_s),
                "n": z(batch, cfg.n_heads, hd_s),
                "h": z(batch, cfg.n_heads, hd_s),
                "m": jnp.full((batch, cfg.n_heads, hd_s), -1e30, jnp.float32),
            },
        }
        return {"layers": stack(one, n_super)}
    raise ValueError(kind)
