"""Model zoo: every GEMM routes through the RedMulE engine."""
