"""TinyMLPerf deep AutoEncoder — the paper's end-to-end use case (§III-B).

MLPerf Tiny anomaly detection (ToyADMOS): 640 -> [128 x4] -> 8 -> [128 x4]
-> 640, trained with MSE.  Every layer runs on the RedMulE engine in pure
FP16 (the paper's precision regime) — this is the "adaptive deep learning /
online fine-tuning on device" story, functional end to end.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core import precision as prec
from repro.core.perf_model import AE_DIMS
from repro.models.layers import Param, init_tree

__all__ = ["ae_schema", "init_ae", "ae_forward", "ae_loss", "AE_DIMS"]


def ae_schema() -> Dict[str, Any]:
    s: Dict[str, Any] = {}
    n = len(AE_DIMS) - 1
    for i in range(n):
        s[f"fc{i}"] = {
            # He init: 10 stacked ReLU layers vanish under 1/sqrt(fan_in)
            "w": Param((AE_DIMS[i], AE_DIMS[i + 1]), ("ae_hidden", "ae_hidden"),
                       init="he"),
            "b": Param((AE_DIMS[i + 1],), ("ae_hidden",), init="zeros"),
        }
        if i != n - 1:
            # the MLPerf Tiny AD reference model has BatchNorm after every
            # hidden dense layer (also prevents the 8-wide bottleneck dying)
            s[f"fc{i}"]["gamma"] = Param((AE_DIMS[i + 1],), ("ae_hidden",),
                                         init="ones")
            s[f"fc{i}"]["beta"] = Param((AE_DIMS[i + 1],), ("ae_hidden",),
                                        init="zeros")
    return s


def init_ae(rng: jax.Array, dtype=jnp.float32):
    return init_tree(rng, ae_schema(), dtype=dtype)


def ae_forward(params, x: jax.Array, *, policy: prec.Policy = prec.PAPER_FP16,
               backend=None) -> jax.Array:
    """x: (B, 640) -> reconstruction (B, 640). Dense->BN->ReLU hidden blocks
    (the MLPerf Tiny AD reference structure); BN statistics in fp32."""
    h = x
    n = len(AE_DIMS) - 1
    for i in range(n):
        p = params[f"fc{i}"]
        h = engine.linear(h, p["w"], p["b"], policy=policy, backend=backend)
        if i != n - 1:
            hf = h.astype(jnp.float32)
            mu = hf.mean(axis=0, keepdims=True)
            var = hf.var(axis=0, keepdims=True)
            hf = (hf - mu) * jax.lax.rsqrt(var + 1e-5)
            hf = hf * p["gamma"].astype(jnp.float32) + p["beta"].astype(jnp.float32)
            h = jax.nn.relu(hf).astype(h.dtype)
    return h


def ae_loss(params, x: jax.Array, *, policy: prec.Policy = prec.PAPER_FP16,
            backend=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    rec = ae_forward(params, x, policy=policy, backend=backend)
    err = (rec.astype(jnp.float32) - x.astype(jnp.float32))
    loss = jnp.mean(err * err)
    return loss, {"mse": loss}
