"""Autotuner: candidates, cost model, canonical keys, cache round-trip.

The acceptance loop: cold miss -> tuned pick (cost model on CPU) -> warm
hit from the in-memory LRU -> warm hit from the JSON file in a fresh
cache (cross-process persistence) -> the Engine's tile resolution serves
the tuned tile and stamps it on the GemmEvent.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, engine, tiling
from repro.core import precision as prec


@pytest.fixture(autouse=True)
def _fresh_cache(monkeypatch, tmp_path):
    """Every test gets an empty LRU and its own JSON cache file."""
    monkeypatch.setenv(autotune.ENV_VAR, str(tmp_path / "autotune.json"))
    autotune.clear_cache()
    yield
    autotune.clear_cache()


# ------------------------------------------------------------------ #
# Candidates and the cost model
# ------------------------------------------------------------------ #
def test_candidates_fit_budget_and_alignment():
    pol = prec.TPU_BF16
    budget = tiling.DEFAULT_VMEM_BUDGET
    cands = autotune.candidate_tiles(512, 2048, 512, policy=pol,
                                     vmem_budget=budget)
    assert 1 < len(cands) <= 16
    sl = tiling.sublane(pol.compute_dtype)
    for t in cands:
        assert tiling.vmem_bytes(t, pol.compute_dtype, pol.accum_dtype) <= budget
        assert t.bm % sl == 0
        assert t.bn % tiling.MXU_LANE == 0
        assert t.bk % tiling.MXU_LANE == 0
    # no duplicates
    assert len({(t.bm, t.bn, t.bk) for t in cands}) == len(cands)


def test_candidates_include_heuristic_pick():
    pol = prec.TPU_FP16
    h = tiling.choose_tiles(300, 700, 300, compute_dtype=pol.compute_dtype,
                            accum_dtype=pol.accum_dtype)
    cands = autotune.candidate_tiles(300, 700, 300, policy=pol,
                                     max_candidates=10_000)
    assert h in cands


def test_cost_model_penalizes_overpadding():
    """A ragged M=100 problem: a bm=512 tile wastes 4x the MACs of bm=128
    and must never be scored cheaper."""
    pol = prec.TPU_BF16
    fat = tiling.TileConfig(bm=512, bn=512, bk=256)
    fit = tiling.TileConfig(bm=128, bn=512, bk=256)
    assert autotune.predicted_cost_us(100, 2048, 256, fit, policy=pol) < \
        autotune.predicted_cost_us(100, 2048, 256, fat, policy=pol)


def test_cost_model_penalizes_tiny_grids():
    """Per-step overhead: shredding a big GEMM into minimum tiles must be
    scored worse than the fat heuristic pick."""
    pol = prec.TPU_BF16
    tiny = tiling.TileConfig(bm=16, bn=128, bk=128)
    fat = tiling.choose_tiles(4096, 4096, 4096,
                              compute_dtype=pol.compute_dtype)
    assert autotune.predicted_cost_us(4096, 4096, 4096, fat, policy=pol) < \
        autotune.predicted_cost_us(4096, 4096, 4096, tiny, policy=pol)


# ------------------------------------------------------------------ #
# Canonical keys
# ------------------------------------------------------------------ #
def test_bucketing_pow2_below_512_coarse_above():
    assert autotune.bucket_dim(1) == 1
    assert autotune.bucket_dim(3) == 4
    assert autotune.bucket_dim(100) == 128
    assert autotune.bucket_dim(512) == 512
    assert autotune.bucket_dim(513) == 1024
    assert autotune.bucket_dim(1500) == 1536


def test_key_separates_dtype_epilogue_backend():
    mk = lambda **kw: autotune.canonical_key(
        256, 512, 256,
        policy=kw.pop("policy", prec.TPU_BF16),
        backend=kw.pop("backend", "pallas"),
        **kw)
    base = mk()
    assert mk() == base                       # deterministic
    assert mk(policy=prec.PAPER_FP16) != base # dtypes in the key
    assert mk(epilogue="gelu") != base        # epilogue in the key
    assert mk(backend="interpret") != base    # backend in the key
    # nearby shapes share a bucket (reuse), distant ones don't
    near = autotune.canonical_key(250, 500, 250, policy=prec.TPU_BF16,
                                  backend="pallas")
    assert near == base
    far = autotune.canonical_key(4096, 512, 256, policy=prec.TPU_BF16,
                                 backend="pallas")
    assert far != base


# ------------------------------------------------------------------ #
# The acceptance round-trip: cold miss -> tuned pick -> warm hits
# ------------------------------------------------------------------ #
def test_cache_roundtrip_cold_miss_pick_warm_hit():
    pol = prec.TPU_BF16
    look = lambda: autotune.cached_tile(256, 512, 256, policy=pol,
                                        backend="interpret")
    assert look() is None                                   # cold miss
    res = autotune.autotune_gemm(256, 512, 256, policy=pol,
                                 backend="interpret", mode="model")
    assert res.source == "model" and res.n_candidates >= 1
    assert look() == res.tile                               # LRU warm hit

    path = os.environ[autotune.ENV_VAR]
    data = json.load(open(path))                            # persisted
    (entry,) = data.values()
    assert (entry["bm"], entry["bn"], entry["bk"]) == \
        (res.tile.bm, res.tile.bn, res.tile.bk)
    assert entry["source"] == "model"

    # observability counters so far: 1 cold miss + 1 warm hit, no evictions
    stats = autotune.cache_stats()
    assert stats["misses"] >= 1 and stats["hits"] >= 1
    assert stats["evictions"] == 0
    assert set(stats) == {"entries", "hits", "misses", "evictions"}

    autotune.clear_cache()                                  # "new process"
    assert look() == res.tile                               # disk warm hit
    stats = autotune.cache_stats()
    assert stats["hits"] >= 1
    assert stats["evictions"] == 0


def test_key_separates_fused_bwd_and_depth():
    """The fused-backward-epilogue kernel streams a third operand and the
    pipeline depth changes the VMEM slot count — both key separately (and
    the default key string stays PR-2/PR-3 compatible)."""
    mk = lambda **kw: autotune.canonical_key(
        256, 512, 256, policy=prec.TPU_BF16, backend="pallas", **kw)
    base = mk(layout="tn")
    assert mk(layout="tn", fused_bwd=True) != base
    assert mk(layout="tn", pipeline_depth=3) != base
    assert "fbwd" in mk(layout="tn", fused_bwd=True).to_str()
    assert "-d3" in mk(layout="tn", pipeline_depth=3).to_str()
    # defaults keep the historical key format (shipped caches stay valid)
    assert mk().to_str() == mk(fused_bwd=False, pipeline_depth=2).to_str()
    assert "fbwd" not in mk().to_str() and "-d2" not in mk().to_str()
    # the cost model prices the extra deriv stream: a fused-bwd launch is
    # never cheaper than the same tile without it
    t = tiling.TileConfig(bm=128, bn=512, bk=256)
    plain = autotune.predicted_cost_us(512, 2048, 512, t,
                                       policy=prec.TPU_BF16)
    fused = autotune.predicted_cost_us(512, 2048, 512, t,
                                       policy=prec.TPU_BF16,
                                       fused_bwd=True, layout="tn",
                                       bias_grad=True)
    assert fused >= plain


def test_lru_eviction_counter():
    cap = autotune._LRU_CAPACITY
    pol = prec.TPU_BF16
    for i in range(cap + 5):
        key = autotune.AutotuneKey(
            m=8 * (i + 1), n=128, k=128, compute="bfloat16",
            accum="float32", out="bfloat16", epilogue="",
            backend="interpret")
        autotune.record_tile(key, tiling.TileConfig(8, 128, 128))
    stats = autotune.cache_stats()
    assert stats["entries"] == cap
    assert stats["evictions"] == 5


def test_engine_resolution_prefers_autotuned_tile():
    """explicit arg > autotune cache > heuristic, end to end."""
    pol = prec.TPU_BF16
    M, N, K = 256, 512, 256
    x = jnp.zeros((M, N), pol.compute_dtype)
    w = jnp.zeros((N, K), pol.compute_dtype)

    def traced_tile(**kwargs):
        with engine.instrument() as ev:
            jax.eval_shape(lambda a, b: engine.matmul(
                a, b, policy=pol, backend="interpret", **kwargs), x, w)
        (event,) = ev
        return event.spec.tile

    heuristic = tiling.choose_tiles(M, N, K, compute_dtype=pol.compute_dtype,
                                    accum_dtype=pol.accum_dtype)
    assert traced_tile() == heuristic           # nothing tuned yet

    tuned = tiling.TileConfig(bm=64, bn=256, bk=128)
    autotune.record_tile(
        autotune.canonical_key(M, N, K, policy=pol, backend="interpret"),
        tuned, source="manual")
    assert traced_tile() == tuned               # cache beats heuristic

    explicit = tiling.TileConfig(bm=32, bn=128, bk=128)
    assert traced_tile(tile=explicit) == explicit  # arg beats cache


def test_autotuned_tile_produces_correct_result():
    """The tuned tile is not just recorded — the kernel runs with it."""
    pol = prec.TPU_FP16
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(100, 200)), pol.compute_dtype)
    w = jnp.asarray(rng.normal(size=(200, 50)), pol.compute_dtype)
    res = autotune.autotune_gemm(100, 200, 50, policy=pol,
                                 backend="interpret", mode="model")
    z = engine.matmul(x, w, policy=pol, backend="interpret")
    ref = np.asarray(x, np.float32) @ np.asarray(w, np.float32)
    np.testing.assert_allclose(np.asarray(z, np.float32), ref,
                               rtol=2e-3, atol=5e-2)
    assert res.tile is not None


def test_measured_mode_times_the_kernel():
    """measured_cost_us runs the real (interpret-mode here) kernel; it only
    needs to return a positive wall-clock figure on tiny shapes."""
    pol = prec.FP32
    t = tiling.TileConfig(bm=8, bn=128, bk=128)
    us = autotune.measured_cost_us(8, 16, 8, t, policy=pol, epilogue="relu",
                                   with_bias=True, warmup=0, iters=1)
    assert us > 0.0


def test_corrupt_cache_file_is_ignored(tmp_path, monkeypatch):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    monkeypatch.setenv(autotune.ENV_VAR, str(bad))
    autotune.clear_cache()
    assert autotune.cached_tile(64, 64, 64, policy=prec.TPU_BF16,
                                backend="interpret") is None
