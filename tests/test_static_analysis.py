"""Static-analysis subsystem tests (PR 8).

* the jaxpr escape auditor detects a planted raw ``dot_general`` with
  the correct shape/flops, and audits a clean Engine-only model to zero
  escapes (including through ``lax.scan`` multiplicity);
* the ratchet: a manifest-covered escape passes, a NEW escape fails,
  a STALE manifest entry fails;
* the dtype auditor flags planted fp64 and a planted FP8 contraction
  that no capable backend accounts for — and stays silent on the
  Engine's own FP8 dispatches (which widen before the dot);
* the AST linter rules and artifact validators on planted violations,
  plus green runs over the real repo and shipped baselines.
"""

import dataclasses
import json
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.analysis import audit as audit_cli
from repro.analysis import dtype_audit, entries, jaxpr_audit, lint
from repro.core import engine
from repro.core import precision as prec

F16 = jnp.float16
DNUMS = (((1,), (0,)), ((), ()))


def _sds(*shape, dtype=F16):
    return jax.ShapeDtypeStruct(shape, dtype)


# --------------------------------------------------------------------- #
# jaxpr escape auditor
# --------------------------------------------------------------------- #
def test_planted_dot_general_detected_with_shape_and_flops():
    def model(x, w, v):
        h = engine.matmul(x, w, policy=prec.PAPER_FP16)
        return lax.dot_general(h, v, DNUMS)   # planted escape

    res = jaxpr_audit.audit(
        "toy", model, (_sds(8, 16), _sds(16, 32), _sds(32, 4)))
    assert not res.clean
    assert len(res.escapes) == 1
    esc = res.escapes[0]
    assert esc.lhs_shape == (8, 32) and esc.rhs_shape == (32, 4)
    assert esc.flops == 2 * 8 * 32 * 4
    assert esc.count == 1
    assert "float16" in esc.fingerprint


def test_clean_engine_only_model_zero_escapes():
    def model(x, w, v):
        h = engine.matmul(x, w, policy=prec.PAPER_FP16)
        return engine.matmul(h, v, policy=prec.PAPER_FP16)

    res = jaxpr_audit.audit(
        "toy", model, (_sds(8, 16), _sds(16, 32), _sds(32, 4)))
    assert res.clean and not res.unmatched_events
    assert res.n_events == 2


def test_scan_multiplicity_reconciles_and_escapes():
    w_sd = _sds(16, 16)

    def clean(x, w):
        with engine.repeat(5):
            y, _ = lax.scan(
                lambda c, _: (engine.matmul(c, w, policy=prec.PAPER_FP16),
                              None),
                x, None, length=5)
        return y

    res = jaxpr_audit.audit("toy", clean, (_sds(4, 16), w_sd))
    assert res.clean and not res.unmatched_events

    def planted(x, w):
        y, _ = lax.scan(lambda c, _: (lax.dot_general(c, w, DNUMS), None),
                        x, None, length=5)
        return y

    res = jaxpr_audit.audit("toy", planted, (_sds(4, 16), w_sd))
    assert len(res.escapes) == 1
    assert res.escapes[0].count == 5          # scan length multiplies in
    assert res.escapes[0].path == ("scan",)


def test_value_and_grad_backward_gemms_reconcile():
    """The Engine's custom-vjp backward dots must all be event-accounted —
    a grad trace is where escapes would silently double."""
    def loss(x, w):
        y = engine.matmul(x, w, policy=prec.PAPER_FP16)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    x = jnp.ones((8, 16), F16)

    def step(w):
        return jax.value_and_grad(lambda q: loss(x, q))(w)

    res = jaxpr_audit.audit("toy", step, (jnp.ones((16, 32), F16),))
    assert res.clean, [s.describe() for s in res.escapes]
    assert not res.unmatched_events


# --------------------------------------------------------------------- #
# ratchet semantics
# --------------------------------------------------------------------- #
def _toy_result(planted: bool):
    def model(x, w, v):
        h = engine.matmul(x, w, policy=prec.PAPER_FP16)
        return lax.dot_general(h, v, DNUMS) if planted else h

    return jaxpr_audit.audit(
        "toy", model, (_sds(8, 16), _sds(16, 32), _sds(32, 4)))


def test_ratchet_new_escape_fails():
    errors = audit_cli.ratchet_errors(
        "toy", _toy_result(planted=True), {"jaxpr": {"toy": []}})
    assert errors and "NEW escaped contraction" in errors[0]


def test_ratchet_manifest_covered_escape_passes():
    res = _toy_result(planted=True)
    manifest = {"jaxpr": {"toy": [
        {"fingerprint": res.escapes[0].fingerprint, "count": 1}]}}
    assert audit_cli.ratchet_errors("toy", res, manifest) == []


def test_ratchet_stale_entry_fails():
    manifest = {"jaxpr": {"toy": [
        {"fingerprint": "float16[1, 1]·float16[1, 1]->float16 "
                        "C[1];[0] B[];[]", "count": 1}]}}
    errors = audit_cli.ratchet_errors(
        "toy", _toy_result(planted=False), manifest)
    assert errors and "STALE manifest entry" in errors[0]


# --------------------------------------------------------------------- #
# dtype auditor
# --------------------------------------------------------------------- #
def test_dtype_audit_flags_planted_fp64():
    with jax.experimental.enable_x64():
        def model(x):
            return jnp.sum(x.astype(jnp.float64) * 2.0)

        closed, events = jaxpr_audit.trace_entry(
            "toy", model, (_sds(4, 4, dtype=jnp.float32),))
    findings = dtype_audit.audit_dtypes(closed, events)
    assert any(f.kind == "fp64" for f in findings), findings


def test_dtype_audit_flags_raw_fp8_contraction():
    def model(x, w):
        x8 = x.astype(jnp.float8_e4m3fn)
        w8 = w.astype(jnp.float8_e4m3fn)
        return lax.dot_general(x8, w8, DNUMS,
                               preferred_element_type=jnp.float32)

    closed, events = jaxpr_audit.trace_entry(
        "toy", model, (_sds(8, 16, dtype=jnp.float32),
                       _sds(16, 8, dtype=jnp.float32)))
    findings = dtype_audit.audit_dtypes(
        closed, events, extra_allowed=("float32",))
    assert [f.kind for f in findings] == ["fp8_uncovered"]


def test_dtype_audit_silent_on_engine_fp8_dispatch():
    """The Engine widens FP8 storage to the compute dtype around the XLA
    dot — a scaled dispatch must produce zero conformance findings."""
    def model(x, w):
        return engine.matmul(x, w, policy=prec.MIXED_FP8_E4M3)

    closed, events = jaxpr_audit.trace_entry(
        "toy", model, (_sds(8, 16), _sds(16, 32)))
    assert events, "scaled dispatch emitted no events"
    assert dtype_audit.audit_dtypes(closed, events) == []
    # and the escape audit still reconciles through the quantize ops
    res = jaxpr_audit.reconcile("toy", jaxpr_audit.collect_dots(closed),
                                events)
    assert res.clean


def test_shipped_policies_conform():
    assert dtype_audit.check_shipped_policies() == []


# --------------------------------------------------------------------- #
# registered entries + CLI acceptance
# --------------------------------------------------------------------- #
def test_ae_train_entry_audits_clean_against_manifest():
    """Acceptance: `python -m repro.analysis.audit --entry ae_train` exits
    zero on the manifest-covered tree."""
    assert audit_cli.run(["ae_train"], audit_cli.DEFAULT_MANIFEST) == 0


def test_cli_nonzero_on_planted_escape(monkeypatch, tmp_path):
    """Acceptance: a planted escaped dot_general makes the CLI exit
    non-zero (the manifest does not cover it)."""
    def build():
        def model(x, w):
            return lax.dot_general(x, w, DNUMS)
        return model, (_sds(8, 16), _sds(16, 4))

    monkeypatch.setitem(entries.ENTRY_POINTS, "toy_planted", build)
    manifest = tmp_path / "escapes.json"
    manifest.write_text(json.dumps({"jaxpr": {}, "ast": []}))
    report = tmp_path / "report.json"
    assert audit_cli.run(["toy_planted"], str(manifest),
                         str(report)) == 1
    rep = json.loads(report.read_text())
    assert rep["errors"] and rep["entries"]["toy_planted"]["escapes"]


def test_every_registered_entry_builds():
    for name in entries.ENTRY_POINTS:
        fn, args = entries.get_entry(name)
        assert callable(fn) and len(args) >= 1
    with pytest.raises(KeyError):
        entries.get_entry("nope")


# --------------------------------------------------------------------- #
# AST linter
# --------------------------------------------------------------------- #
def _plant_tree(tmp_path, rel, source):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    return path


def test_lint_flags_planted_violations(tmp_path):
    _plant_tree(tmp_path, "models/bad.py", """
        import os
        import jax.numpy as jnp

        EVENT_LOG = []

        def f(x, w, spec):
            spec.m = 5
            os._exit(1)
            y = jnp.einsum("ij,jk->ik", x, w)
            return y @ w
    """)
    manifest = tmp_path / "escapes.json"
    manifest.write_text(json.dumps({"jaxpr": {}, "ast": []}))
    rules = {v[2] for v in lint.lint_sources(str(tmp_path), str(manifest))}
    assert rules == {"models-gemm", "os-exit", "spec-mutation",
                     "module-collector"}


def test_lint_manifest_allows_and_ratchets(tmp_path):
    _plant_tree(tmp_path, "models/ok.py", """
        import jax.numpy as jnp

        def f(x, w):
            return jnp.einsum("ij,jk->ik", x, w)
    """)
    allow = {"jaxpr": {}, "ast": [{"file": "models/ok.py",
                                   "call": "jnp.einsum",
                                   "equation": "ij,jk->ik", "count": 1}]}
    # manifest-covered: clean — but the same manifest against a tree
    # where the site was fixed reports the entry as stale
    m = tmp_path / "escapes.json"

    def _relativize(entries_):
        # lint reports files relative to the repo root; point the
        # manifest at the planted tree's actual relpath
        rel = os.path.relpath(tmp_path, lint._REPO_ROOT)
        return [dict(e, file=os.path.join(rel, e["file"]))
                for e in entries_]

    m.write_text(json.dumps({"jaxpr": {},
                             "ast": _relativize(allow["ast"])}))
    assert lint.lint_sources(str(tmp_path), str(m)) == []

    (tmp_path / "models" / "ok.py").write_text("def f():\n    return 0\n")
    stale = lint.lint_sources(str(tmp_path), str(m))
    assert stale and stale[0][2] == "models-gemm" \
        and "STALE" in stale[0][3]


def test_lint_real_repo_is_clean():
    assert lint.lint_sources() == []


def test_gemmspec_field_list_in_sync():
    """The linter keeps GemmSpec's field names as literals (it must not
    import jax); fail here if the dataclass drifts."""
    assert lint._GEMMSPEC_FIELDS == {
        f.name for f in dataclasses.fields(engine.GemmSpec)}


# --------------------------------------------------------------------- #
# artifact validation
# --------------------------------------------------------------------- #
def test_autotune_cache_validation(tmp_path):
    good = {"m256-n512-k256-float16-float32-float16-none-xla":
            {"bm": 128, "bn": 128, "bk": 128, "source": "heuristic",
             "us": 1.0}}
    p = tmp_path / "cache.json"
    p.write_text(json.dumps(good))
    assert lint.validate_autotune_cache(str(p)) == []

    bad = {"m4096-n4096-k4096-float32-float32-float32-none-pallas-d4":
           {"bm": 2048, "bn": 2048, "bk": 2048, "source": "measured",
            "us": 1.0},
           "not a key": {"bm": 1, "bn": 1, "bk": 1}}
    p.write_text(json.dumps(bad))
    rules = [v[2] for v in lint.validate_autotune_cache(str(p))]
    assert rules == ["autotune-cache", "autotune-cache"]


def test_shipped_baselines_satisfy_analytic_identities():
    assert lint.validate_baselines() == []


def test_baseline_validation_catches_broken_identity(tmp_path):
    src = os.path.join(lint._REPO_ROOT, "benchmarks", "baselines")
    for name in os.listdir(src):
        if name.endswith(".json"):
            (tmp_path / name).write_text(
                open(os.path.join(src, name)).read())
    tf = json.loads((tmp_path / "train_flops.json").read_text())
    tf["ae_train_B16"]["bwd"] += 2          # break bwd == 2*fwd and total
    (tmp_path / "train_flops.json").write_text(json.dumps(tf))
    probs = lint.validate_baselines(str(tmp_path))
    assert any("total != fwd + bwd" in v[3] for v in probs)


def test_shipped_escape_manifest_is_well_formed():
    assert lint.validate_escape_manifest() == []
