"""Backend-conformance harness: the registry contract as executable tests.

Every registered backend is swept against every contract its capability
flags declare, so a future backend (the ROADMAP's Triton/GPU entry, a
user-registered runtime backend) plugs into ready-made tests instead of
discovering the contract by breaking models:

* **base** (every backend): ``fn(x, w, *, spec) -> (..., M, K)`` matches
  the FP32 oracle to the policy's documented tolerance, for weight and
  batched GEMMs;
* **fused_epilogue**: ``fn(..., bias=..., fuse_epilogue=True)`` applies
  the accum-dtype bias row and ``spec.epilogue`` *before* the store —
  bitwise-equal to the post-op path under ``paper_fp16`` for bias/relu
  (the PR-2 pinned contract);
* **layouts**: "nt" / "tn" dispatches on forward-storage operands equal
  the pre-transposed "nn" dispatch;
* **fused_bwd_epilogue**: ``fn(a, b, *, spec, deriv=..., bias_grad=True)``
  returns ``(grad, db)`` with the documented shapes/dtypes, ``db`` the
  row-sum of the derivative-adjusted dZ, and ``act'`` applied on load;
* **operand_dtypes**: FP8-stored operands (upcast-on-load) produce the
  same result as pre-upcast compute-dtype operands.
* **attention** (every backend — capable backends answer with their
  fused sweep kernels, the rest through the engine's reference
  composition): ``engine.attention`` over {dense, causal, GQA} and
  ``engine.linear_attention`` over {fresh, chunked-state carry-in}
  match fp32 numpy oracles (materialized-softmax attention; the
  token-by-token decay recurrence).

Each check raises ``AssertionError`` with a readable message naming the
backend and the violated clause; the negative tests register
deliberately contract-violating dummy backends and assert the harness
catches them with exactly such a message.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core import epilogues as epi
from repro.core import precision as prec
from repro.core import tiling

RNG = np.random.default_rng(5)

# shapes deliberately off tile multiples so padding is part of the contract
M, N, K = 24, 33, 17
BATCH = 3

_TOL = {"float32": 1e-5, "float16": 2e-2, "bfloat16": 1e-1}


def _rand(shape, dtype, scale=0.3):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


def _mk_spec(policy, *, op="matmul", m=M, n=N, k=K, batch=1, layout="nn",
             epilogue=None, **kw):
    return engine.GemmSpec(
        op=op, tag="conformance", m=m, n=n, k=k, batch=batch,
        policy=policy, epilogue=epilogue, layout=layout,
        w_shared=(batch == 1), **kw)


def _f32(a):
    return np.asarray(a, np.float32)


def _close(got, want, policy, *, what, backend):
    tol = _TOL[jnp.dtype(policy.compute_dtype).name]
    if not np.allclose(_f32(got), _f32(want), rtol=tol, atol=tol):
        err = float(np.max(np.abs(_f32(got) - _f32(want))))
        raise AssertionError(
            f"backend {backend!r} violates the {what} contract under "
            f"policy {policy.name!r}: max abs error {err:.4g} exceeds the "
            f"documented tolerance {tol} (see repro.core.engine "
            f"BackendSpec)")


# ------------------------------------------------------------------ #
# Contract checks — each takes a backend name, raises AssertionError
# ------------------------------------------------------------------ #
def check_base(backend: str) -> None:
    """fn(x, w, *, spec) matches the FP32 oracle (weight + batched GEMM)."""
    fn = engine.get_backend(backend).fn
    for policy in (prec.FP32, prec.PAPER_FP16, prec.TPU_FP16):
        x = _rand((M, N), policy.compute_dtype)
        w = _rand((N, K), policy.compute_dtype)
        z = fn(x, w, spec=_mk_spec(policy))
        if z.shape != (M, K):
            raise AssertionError(
                f"backend {backend!r} violates the base contract: output "
                f"shape {z.shape} != {(M, K)} for x{x.shape} @ w{w.shape}")
        oracle = _f32(x) @ _f32(w)
        _close(z, oracle, policy, what="base (weight GEMM vs FP32 oracle)",
               backend=backend)
        # batched operands, broadcast-compatible leading dims
        xb = _rand((BATCH, M, N), policy.compute_dtype)
        wb = _rand((BATCH, N, K), policy.compute_dtype)
        zb = fn(xb, wb, spec=_mk_spec(policy, batch=BATCH))
        _close(zb, np.einsum("bmn,bnk->bmk", _f32(xb), _f32(wb)), policy,
               what="base (batched GEMM vs FP32 oracle)", backend=backend)


def check_fused_epilogue(backend: str) -> None:
    """bias + activation applied to the accumulator before the store;
    bitwise vs the post-op path under paper_fp16 for bias/relu."""
    fn = engine.get_backend(backend).fn
    policy = prec.PAPER_FP16
    x = _rand((M, N), policy.compute_dtype)
    w = _rand((N, K), policy.compute_dtype)
    b = _rand((1, K), policy.accum_dtype, 0.1)
    for act in (None, "relu"):
        spec = _mk_spec(policy, op="linear", epilogue=act)
        fused = fn(x, w, spec=spec, bias=b, fuse_epilogue=True)
        plain = fn(x, w, spec=_mk_spec(policy))
        post = jnp.asarray(plain).astype(policy.accum_dtype) + b
        post = epi.apply_epilogue(act, post).astype(policy.out_dtype)
        if not np.array_equal(_f32(fused), _f32(post)):
            raise AssertionError(
                f"backend {backend!r} violates the fused_epilogue "
                f"contract: fuse_epilogue=True with epilogue={act!r} is "
                f"not bitwise-equal to the post-op path under paper_fp16 "
                f"(bias row must be added in the accum dtype before the "
                f"single store)")


def check_layouts(backend: str) -> None:
    """"nt"/"tn" dispatches on forward-storage operands equal the
    pre-transposed "nn" dispatch."""
    fn = engine.get_backend(backend).fn
    for policy in (prec.FP32, prec.PAPER_FP16):
        x = _rand((M, N), policy.compute_dtype)
        w = _rand((N, K), policy.compute_dtype)
        want = fn(x, w, spec=_mk_spec(policy))
        znt = fn(x, jnp.swapaxes(w, -1, -2),
                 spec=_mk_spec(policy, layout="nt"))
        _close(znt, want, policy,
               what='layouts ("nt" vs pre-transposed "nn")', backend=backend)
        ztn = fn(jnp.swapaxes(x, -1, -2), w,
                 spec=_mk_spec(policy, layout="tn"))
        _close(ztn, want, policy,
               what='layouts ("tn" vs pre-transposed "nn")', backend=backend)


def check_fused_bwd_epilogue(backend: str) -> None:
    """(grad, db) shape/dtype and value: act' applied to dZ on load, db
    the accum-dtype row sum of the derivative-adjusted dZ."""
    fn = engine.get_backend(backend).fn
    policy = prec.FP32
    # the dW ("tn") dispatch: a = X stored (rows, n_features),
    # b = dZ (rows, k), deriv stored like dZ
    rows = M
    xs = _rand((rows, N), policy.compute_dtype)
    dz = _rand((rows, K), policy.compute_dtype)
    d = _rand((rows, K), policy.compute_dtype)
    spec = _mk_spec(policy, op="matmul_dw", m=N, n=rows, k=K, layout="tn",
                    grad_epilogue="tanh", grad_mode="output",
                    fused_bwd=True, fused_bias_grad=True)
    out = fn(xs, dz, spec=spec, deriv=d, bias_grad=True)
    if not (isinstance(out, tuple) and len(out) == 2):
        raise AssertionError(
            f"backend {backend!r} violates the fused_bwd_epilogue "
            f"contract: bias_grad=True must return (grad, db), got "
            f"{type(out).__name__}")
    dw, db = out
    grad = epi.epilogue_grad("tanh")
    ds = _f32(dz) * _f32(grad.deriv_from_output(d))
    if dw.shape != (N, K) or db.shape != (K,):
        raise AssertionError(
            f"backend {backend!r} violates the fused_bwd_epilogue "
            f"contract: shapes (grad, db) = ({dw.shape}, {db.shape}), "
            f"want (({N}, {K}), ({K},))")
    if jnp.dtype(db.dtype) != jnp.dtype(policy.accum_dtype):
        raise AssertionError(
            f"backend {backend!r} violates the fused_bwd_epilogue "
            f"contract: db dtype {db.dtype} is not the accum dtype "
            f"{jnp.dtype(policy.accum_dtype).name}")
    _close(dw, _f32(xs).T @ ds, policy,
           what="fused_bwd_epilogue (act' on dZ load)", backend=backend)
    _close(db, ds.sum(axis=0), policy,
           what="fused_bwd_epilogue (fused db row sum)", backend=backend)


def check_operand_dtypes(backend: str) -> None:
    """FP8-stored operands (upcast on load) == pre-upcast dispatch."""
    fn = engine.get_backend(backend).fn
    policy = prec.MIXED_FP8_E4M3
    xq = _rand((M, N), jnp.float8_e4m3fn)
    wq = _rand((N, K), jnp.float8_e4m3fn)
    spec = _mk_spec(policy, x_dtype="float8_e4m3fn",
                    w_dtype="float8_e4m3fn", scaled=True)
    narrow = fn(xq, wq, spec=spec)
    wide = fn(xq.astype(policy.compute_dtype),
              wq.astype(policy.compute_dtype), spec=_mk_spec(policy))
    if not np.allclose(_f32(narrow), _f32(wide), rtol=1e-3, atol=1e-3):
        err = float(np.max(np.abs(_f32(narrow) - _f32(wide))))
        raise AssertionError(
            f"backend {backend!r} violates the operand_dtypes contract: "
            f"dispatching FP8 storage directly differs from upcasting "
            f"before dispatch by {err:.4g} — the kernel must upcast tiles "
            f"to the compute dtype on load, changing bytes, not values")


def _attention_oracle(q, k, v, *, group, causal, scale, t_valid):
    """fp32 numpy oracle: materialized K/V per q-head, dense softmax,
    fully-masked rows exact zeros (the engine's documented contract)."""
    qf, kf, vf = _f32(q), _f32(k), _f32(v)
    S, T = qf.shape[2], kf.shape[2]
    kr = np.repeat(kf, group, axis=1)
    vr = np.repeat(vf, group, axis=1)
    s = np.einsum("bhsd,bhtd->bhst", qf, kr) * scale
    mask = np.arange(T)[None, :] < t_valid
    if causal:
        mask = mask & (np.arange(T)[None, :] <= np.arange(S)[:, None])
    else:
        mask = np.broadcast_to(mask, (S, T))
    s = np.where(mask, s, -1e30)
    p = np.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    p = np.where(mask.any(axis=-1)[..., None], p, 0.0)
    return np.einsum("bhst,bhtd->bhsd", p, vr)


def _linear_attention_oracle(q, k, v, log_g, state=None):
    """fp32 numpy oracle: the token-by-token recurrence
    S_t = exp(g_t) S_{t-1} + k_t v_t^T, out_t = q_t @ S_t."""
    qf, kf, vf, gf = _f32(q), _f32(k), _f32(v), _f32(log_g)
    B, H, S, dk = qf.shape
    dv = vf.shape[-1]
    st = np.zeros((B, H, dk, dv), np.float32) if state is None else _f32(state)
    outs = []
    for t in range(S):
        st = (np.exp(gf[:, :, t])[..., None, None] * st
              + np.einsum("bhk,bhv->bhkv", kf[:, :, t], vf[:, :, t]))
        outs.append(np.einsum("bhk,bhkv->bhv", qf[:, :, t], st))
    return np.stack(outs, axis=2), st


def check_attention(backend: str) -> None:
    """engine.attention / engine.linear_attention on this backend match
    the fp32 oracles for {dense, causal, GQA, chunked-state}.  Every
    backend must answer: capable ones with their fused sweep kernels,
    the rest through the engine's reference einsum2d composition."""
    policy = prec.FP32
    B, Hkv, S, T, D = 2, 2, 19, 26, 8
    rng = np.random.default_rng(11)

    def arr(shape):
        return jnp.asarray(rng.normal(size=shape) * 0.3, jnp.float32)

    k = arr((B, Hkv, T, D))
    v = arr((B, Hkv, T, D))
    for what, group, causal, t_valid in (
            ("attention (dense)", 1, False, T),
            ("attention (causal)", 1, True, T),
            ("attention (GQA, causal, ragged t_valid)", 3, True, T - 5)):
        q = arr((B, Hkv * group, S, D))
        got = engine.attention(q, k, v, causal=causal, t_valid=t_valid,
                               policy=policy, backend=backend)
        want = _attention_oracle(q, k, v, group=group, causal=causal,
                                 scale=D**-0.5, t_valid=t_valid)
        _close(got, want, policy, what=what, backend=backend)

    H, dk, dv, Sl = 2, 6, 10, 23
    q2, k2 = arr((B, H, Sl, dk)), arr((B, H, Sl, dk))
    v2 = arr((B, H, Sl, dv))
    g2 = -jnp.abs(arr((B, H, Sl))) * 0.3
    want_o, want_s = _linear_attention_oracle(q2, k2, v2, g2)
    got_o, got_s = engine.linear_attention(q2, k2, v2, g2, chunk=8,
                                           backend=backend)
    _close(got_o, want_o, policy, what="attention (linear, chunked sweep)",
           backend=backend)
    _close(got_s, want_s, policy, what="attention (linear, final state)",
           backend=backend)
    state0 = arr((B, H, dk, dv))
    want_o, want_s = _linear_attention_oracle(q2, k2, v2, g2, state=state0)
    got_o, got_s = engine.linear_attention(q2, k2, v2, g2, chunk=8,
                                           state=state0, backend=backend)
    _close(got_o, want_o, policy, what="attention (linear, state carry-in)",
           backend=backend)
    _close(got_s, want_s, policy,
           what="attention (linear, carried final state)", backend=backend)


CONTRACT_CHECKS = {
    "base": check_base,
    "fused_epilogue": check_fused_epilogue,
    "layouts": check_layouts,
    "fused_bwd_epilogue": check_fused_bwd_epilogue,
    "operand_dtypes": check_operand_dtypes,
    "attention": check_attention,
}

# "tiled" has no standalone value contract: it only promises spec.tile is
# honored as block geometry, which the base check already exercises by
# resolving real tiles.  Everything else is executable above.
CONTRACTS = ("base", "fused_epilogue", "layouts", "fused_bwd_epilogue",
             "operand_dtypes", "attention")


def run_contract(backend: str, contract: str) -> None:
    """Run one contract check against one backend (raises AssertionError
    with a readable message on violation) — the entry point a third-party
    backend's own test suite can call directly."""
    CONTRACT_CHECKS[contract](backend)


# ------------------------------------------------------------------ #
# The sweep: every registered backend x its declared capabilities
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("contract", CONTRACTS)
@pytest.mark.parametrize("backend", engine.registered_backends())
def test_backend_conformance(backend, contract):
    spec = engine.get_backend(backend)
    if not spec.is_available():
        pytest.skip(f"backend {backend!r} not available on this platform")
    if contract not in ("base", "attention") and not spec.supports(contract):
        pytest.skip(f"backend {backend!r} does not declare {contract!r}")
    # "attention" runs on every backend: the engine serves non-capable
    # backends through its reference composition, so all must answer
    run_contract(backend, contract)


def test_every_declared_capability_has_a_check():
    """No registered backend may declare a capability the harness cannot
    exercise (except "tiled", covered via the base check's real tiles)."""
    for name in engine.registered_backends():
        for cap in engine.get_backend(name).capabilities:
            assert cap == "tiled" or cap in CONTRACT_CHECKS, (
                f"backend {name!r} declares capability {cap!r} with no "
                f"conformance check — extend tests/test_backend_conformance")


# ------------------------------------------------------------------ #
# Negative test: a deliberately contract-violating backend must fail
# with a readable message
# ------------------------------------------------------------------ #
def test_violating_backend_fails_readably():
    def broken_fn(x, w, *, spec, bias=None, fuse_epilogue=False,
                  deriv=None, bias_grad=False):
        # claims fused_epilogue but silently ignores the bias row
        z = jnp.matmul(x, w,
                       preferred_element_type=spec.policy.accum_dtype)
        if fuse_epilogue:
            z = epi.apply_epilogue(spec.epilogue, z)
        return z.astype(spec.policy.out_dtype)

    engine.register_backend(
        "broken-dummy", broken_fn,
        capabilities=("fused_epilogue",),
        description="conformance negative test: drops the bias row")
    try:
        # base still passes: the pure GEMM is fine
        run_contract("broken-dummy", "base")
        with pytest.raises(AssertionError) as e:
            run_contract("broken-dummy", "fused_epilogue")
        msg = str(e.value)
        assert "broken-dummy" in msg and "fused_epilogue" in msg, (
            f"violation message must name the backend and the contract, "
            f"got: {msg}")
    finally:
        engine.unregister_backend("broken-dummy")


def test_violating_attention_backend_fails_readably():
    def ok_gemm(x, w, *, spec):
        return jnp.matmul(
            x, w, preferred_element_type=spec.policy.accum_dtype
        ).astype(spec.policy.out_dtype)

    def broken_attention(kind, operands, **params):
        # claims the attention capability but returns zeros for the flash
        # sweep (and a zero state for the linear sweep)
        q = operands[0]
        if kind == "attention":
            return jnp.zeros_like(q)
        dk, dv = operands[1].shape[-1], operands[2].shape[-1]
        return (jnp.zeros(operands[2].shape, q.dtype),
                jnp.zeros((q.shape[0], dk, dv), jnp.float32))

    engine.register_backend(
        "broken-attn", ok_gemm,
        capabilities=("attention",), attention_fn=broken_attention,
        description="conformance negative test: attention returns zeros")
    try:
        run_contract("broken-attn", "base")  # the pure GEMM is fine
        with pytest.raises(AssertionError) as e:
            run_contract("broken-attn", "attention")
        msg = str(e.value)
        assert "broken-attn" in msg and "attention" in msg, (
            f"violation message must name the backend and the contract, "
            f"got: {msg}")
    finally:
        engine.unregister_backend("broken-attn")


def test_attention_capability_requires_attention_fn():
    with pytest.raises(ValueError, match="attention"):
        engine.register_backend("attn-no-fn", lambda x, w, *, spec: x,
                                capabilities=("attention",))
    assert "attn-no-fn" not in engine.registered_backends()


def test_unknown_capability_rejected_at_registration():
    # register_backend validates before touching the registry, so the
    # failed registration leaves no state behind
    with pytest.raises(ValueError, match="unknown backend capabilities"):
        engine.register_backend("bad-caps", lambda x, w, *, spec: x,
                                capabilities=("warp_speed",))
    assert "bad-caps" not in engine.registered_backends()
