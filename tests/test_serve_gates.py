"""CI serve-gates: the serving subsystem's perf contract.

* loadgen produces p50/p99 TTFT + tokens/s rows (interpret backend);
* decode GEMM events carry the serve op tags and exact ragged
  valid_rows billing;
* per-decode-step KV bytes match benchmarks/baselines/serve_bytes.json,
  with the FP8 cache strictly below FP16 at identical engine flops
  (same style as the PR-5 train-bytes gate).
"""

import json
import os

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import engine
from repro.models import transformer
from repro.serving import (LoadConfig, SchedulerConfig, bench_rows,
                           cache_size_bytes, decode_step_kv_bytes,
                           instrumented_decode_events)

BASELINE = os.path.join(os.path.dirname(__file__), os.pardir,
                        "benchmarks", "baselines", "serve_bytes.json")
FP8 = "float8_e4m3fn"
SLOTS, MAX_LEN = 4, 32


def _baseline():
    with open(BASELINE) as f:
        return json.load(f)


@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-moe-16b"])
def test_kv_bytes_pinned_fp8_below_fp16_same_flops(arch):
    base = _baseline()
    lengths = base["lengths"]
    cfg = configs.get_reduced(arch)
    fp16 = decode_step_kv_bytes(cfg, lengths)
    fp8 = decode_step_kv_bytes(cfg, lengths, FP8)
    assert fp16 == base[arch]["fp16_bytes"]
    assert fp8 == base[arch]["fp8_bytes"]
    assert fp8 < fp16  # strictly below, at identical flops (next assert)
    params = transformer.abstract_params(cfg)
    sizes = list(lengths) + [0] * (SLOTS - len(lengths))
    flops = set()
    for sd in (None, FP8):
        scfg = SchedulerConfig(n_slots=SLOTS, max_len=MAX_LEN,
                               storage_dtype=sd)
        ev = instrumented_decode_events(params, cfg, scfg, sizes)
        flops.add(int(engine.total_flops(ev)))
    assert flops == {base[arch]["engine_flops"]}


def test_fp8_cache_resident_bytes_below_fp16():
    cfg = configs.get_reduced("yi-9b")
    assert (cache_size_bytes(cfg, SLOTS, MAX_LEN, FP8)
            < cache_size_bytes(cfg, SLOTS, MAX_LEN))


def test_decode_events_serve_tagged_and_ragged_billing():
    """Every GEMM of the scheduler's decode step is tagged serve_decode/*
    and the grouped score GEMMs bill exactly sum(sizes) * Hkv rows."""
    cfg = configs.get_reduced("yi-9b")
    params = transformer.abstract_params(cfg)
    sizes = [5, 10, 0, 18]
    scfg = SchedulerConfig(n_slots=SLOTS, max_len=MAX_LEN, storage_dtype=FP8)
    ev = instrumented_decode_events(params, cfg, scfg, sizes)
    assert ev, "no engine events traced"
    assert all(e.spec.op.startswith("serve_decode/") for e in ev)
    grouped = [e for e in ev if e.spec.op.endswith("grouped_matmul")]
    assert grouped, "decode did not dispatch the ragged grouped path"
    want = sum(sizes) * cfg.n_kv_heads
    assert all(e.spec.valid_rows == want for e in grouped)


def test_decode_gemms_under_mixed_fp8_policy():
    """FP8 end to end (tentpole part 3): with cfg under MIXED_FP8_E4M3 the
    decode GEMMs carry E4M3 operand dtypes on top of the FP8 KV cache."""
    import dataclasses
    cfg = dataclasses.replace(configs.get_reduced("yi-9b"),
                              policy_name="mixed_fp8_e4m3")
    params = transformer.abstract_params(cfg)
    scfg = SchedulerConfig(n_slots=2, max_len=16, storage_dtype=FP8)
    ev = instrumented_decode_events(params, cfg, scfg, [6, 0])
    assert ev
    assert all(e.spec.op.startswith("serve_decode/") for e in ev)
    assert all(e.spec.x_dtype == FP8 and e.spec.w_dtype == FP8 for e in ev)


def test_loadgen_emits_p50_p99_rows_interpret_backend():
    """The acceptance sweep on the interpret (Pallas interpreter) backend:
    ttft + tps rows per offered load, each carrying p50= and p99=."""
    cfg = configs.get_reduced("yi-9b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    scfg = SchedulerConfig(n_slots=2, max_len=8, storage_dtype=FP8)
    lc = LoadConfig(rate=0.5, n_requests=3, prompt_len=4, gen_len=3, seed=0)
    with engine.use_backend("interpret"):
        rows = bench_rows(params, cfg, scfg, "yi-9b", [0.5], lc)
    names = [name for name, _, _ in rows]
    assert any(n.endswith("/ttft") for n in names)
    assert any(n.endswith("/tps") for n in names)
    for name, us, derived in rows:
        assert name.startswith("serve/yi-9b/")
        assert np.isfinite(us) and us > 0
        assert "p50=" in derived and "p99=" in derived
