"""Serving subsystem (PR 6): FP8 KV cache numerics, slot admission,
scheduler determinism, and the generate() cache-consistency invariant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import precision as prec
from repro.launch import serve
from repro.models import transformer
from repro.serving import (LoadConfig, Request, Scheduler, SchedulerConfig,
                           insert_slot, poisson_requests)

FP8 = "float8_e4m3fn"
E4M3_EPS = 2.0 ** -3  # same bound as tests/test_precision_fp8.py::_EPS


@pytest.fixture(scope="module")
def yi():
    cfg = configs.get_reduced("yi-9b")
    return cfg, transformer.init_params(jax.random.PRNGKey(0), cfg)


# --------------------------------------------------------------------- #
# FP8 KV cache numerics
# --------------------------------------------------------------------- #
def test_fp8_kv_roundtrip_per_head_bounds():
    """Per-head quantize -> upcast round-trip stays inside the E4M3
    relative-precision bound for values within 2^-6 of the head amax."""
    rng = np.random.default_rng(0)
    mag = np.array([0.05, 1.0, 30.0, 400.0])  # per-head dynamic ranges
    x = (rng.standard_normal((2, 4, 16, 8)) * mag[None, :, None, None]
         ).astype(np.float32)
    amax = np.abs(x).max(axis=(0, 2, 3))
    scale = jnp.asarray(amax)[None, :, None, None]
    q, _ = prec.quantize_fp8(jnp.asarray(x), FP8, scale=scale)
    dq = np.asarray(prec.dequantize_fp8(q, scale, jnp.float32))
    err = np.abs(dq - x)
    for h in range(4):
        m = np.abs(x[:, h]) >= amax[h] * 2.0 ** -6
        assert np.all(err[:, h][m] <= E4M3_EPS * np.abs(x[:, h][m]) * 1.001), \
            f"head {h}: relative error above 2^-3"


def test_prefill_fp8_cache_rows_match_fp16_within_bound(yi):
    """The FP8 prefill cache's dequantized k/v rows match the FP16 cache
    within the per-head E4M3 bound (upcast-on-read inside attention)."""
    cfg, params = yi
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab_size, jnp.int32)
    _, c16 = transformer.prefill(params, cfg, {"inputs": prompts}, 8)
    _, c8 = transformer.prefill(params, cfg, {"inputs": prompts}, 8,
                                storage_dtype=FP8)
    for name in ("k", "v"):
        wide = np.asarray(c16["layers"][name], np.float32)
        scale = np.asarray(c8["layers"][f"{name}_scale"]["scale"])
        dq = np.asarray(prec.dequantize_fp8(
            c8["layers"][name], jnp.asarray(scale)[:, None, :, None, None],
            jnp.float32))
        # rows past the prompt are zero in both caches; bound on the rest:
        # relative 2^-3 for normalized values plus the subnormal grid's
        # absolute term (scale * 2^-9) for values below scale * 2^-6
        err = np.abs(dq - wide)[:, :, :, :6]
        ref = np.abs(wide)[:, :, :, :6]
        sub = scale[:, None, :, None, None] * 2.0 ** -9
        assert np.all(err <= E4M3_EPS * ref + sub), name


def test_fp8_decode_logits_vs_fp16_oracle(yi):
    """Multi-step decode from the FP8 cache tracks the FP16-cache oracle:
    same greedy token stream fed to both, logits stay within a small
    absolute band of the oracle's (scale ~4)."""
    cfg, params = yi
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab_size, jnp.int32)
    lg16, c16 = transformer.prefill(params, cfg, {"inputs": prompts}, 14)
    lg8, c8 = transformer.prefill(params, cfg, {"inputs": prompts}, 14,
                                  storage_dtype=FP8)
    np.testing.assert_allclose(np.asarray(lg16, np.float32),
                               np.asarray(lg8, np.float32), atol=1e-2)
    tok = jnp.argmax(lg16, -1)[:, None].astype(jnp.int32)
    diffs = []
    for i in range(6):
        lg16, c16 = transformer.serve_step(params, cfg, tok, c16,
                                           jnp.int32(6 + i))
        lg8, c8 = transformer.serve_step(params, cfg, tok, c8,
                                         jnp.int32(6 + i))
        diffs.append(float(np.abs(
            np.asarray(lg16, np.float32) - np.asarray(lg8, np.float32)).max()))
        tok = jnp.argmax(lg16, -1)[:, None].astype(jnp.int32)
    assert max(diffs) < 0.5, diffs
    assert sum(diffs) / len(diffs) < 0.3, diffs


# --------------------------------------------------------------------- #
# Slot admission
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("storage", [None, FP8])
def test_insert_slot_preserves_other_slots(yi, storage):
    cfg, params = yi
    pool = transformer.init_cache(cfg, 3, 8, dtype=cfg.policy.compute_dtype,
                                  storage_dtype=storage)
    prompts = jax.random.randint(
        jax.random.PRNGKey(2), (1, 5), 0, cfg.vocab_size, jnp.int32)
    _, single = transformer.prefill(params, cfg, {"inputs": prompts}, 8,
                                    storage_dtype=storage)
    out = insert_slot(pool, single, jnp.int32(1),
                      dtype=cfg.policy.compute_dtype)
    for name in ("k", "v"):
        if storage is None:
            got = np.asarray(out["layers"][name], np.float32)
            want = np.asarray(single["layers"][name], np.float32)[:, 0]
            np.testing.assert_array_equal(got[:, 1], want)
            assert np.all(got[:, 0] == 0) and np.all(got[:, 2] == 0)
        else:
            sc = jnp.asarray(out["layers"][f"{name}_scale"]["scale"])
            got = np.asarray(prec.dequantize_fp8(
                out["layers"][name], sc[:, None, :, None, None], jnp.float32))
            ssc = jnp.asarray(single["layers"][f"{name}_scale"]["scale"])
            want = np.asarray(prec.dequantize_fp8(
                single["layers"][name], ssc[:, None, :, None, None],
                jnp.float32))[:, 0]
            # inserted slot within quant tolerance (relative + subnormal
            # grid at the pool's per-head scale); empty slots stay zero
            sub = np.asarray(sc)[:, None, :, None, None] * 2.0 ** -9
            assert np.all(np.abs(got[:, 1] - want)
                          <= E4M3_EPS * np.abs(want) + sub[:, 0])
            assert np.all(got[:, 0] == 0) and np.all(got[:, 2] == 0)


# --------------------------------------------------------------------- #
# Scheduler: determinism + pinned trace
# --------------------------------------------------------------------- #
PINNED_TRACE = [
    ("admit", 1.415059, 0),
    ("prefill", 2.415059, 0, 0, 5),
    ("admit", 2.415059, 1),
    ("prefill", 3.415059, 1, 1, 5),
    ("finish", 7.415059, 0, 0),
    ("finish", 7.415059, 1, 1),
    ("admit", 7.446556, 2),
    ("prefill", 8.446556, 2, 0, 5),
    ("admit", 8.446556, 3),
    ("prefill", 9.446556, 3, 1, 5),
    ("admit", 12.446556, 4),
    ("finish", 13.446556, 2, 0),
    ("finish", 13.446556, 3, 1),
    ("prefill", 14.446556, 4, 0, 5),
    ("finish", 18.446556, 4, 0),
]


def _run_sched(cfg, params):
    scfg = SchedulerConfig(n_slots=2, max_len=16)
    lc = LoadConfig(rate=0.5, n_requests=5, prompt_len=5, gen_len=4, seed=7)
    sched = Scheduler(params, cfg, scfg)
    sched.submit(poisson_requests(cfg, lc))
    results = sched.run()
    return sched, results


def test_scheduler_trace_pinned(yi):
    """Seeded arrivals -> exact slot-assignment/eviction trace.  Continuous
    batching is visible in the pin: rid 2 takes slot 0 the tick after rid
    0 finishes, mid-flight of rid 3."""
    cfg, params = yi
    sched, results = _run_sched(cfg, params)
    got = [(e[0], round(e[1], 6), *e[2:]) for e in sched.trace]
    assert got == PINNED_TRACE
    assert all(len(r.tokens) == 4 and r.finish_tick is not None
               for r in results)


def test_scheduler_deterministic(yi):
    """Two fresh runs of the same seeded load: identical traces, identical
    emitted tokens, identical health logs."""
    cfg, params = yi
    s1, r1 = _run_sched(cfg, params)
    s2, r2 = _run_sched(cfg, params)
    assert s1.trace == s2.trace
    assert [r.tokens for r in r1] == [r.tokens for r in r2]
    assert s1.health == s2.health


def test_scheduler_moe_fp8_smoke():
    cfg = configs.get_reduced("deepseek-moe-16b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    scfg = SchedulerConfig(n_slots=2, max_len=12, storage_dtype=FP8)
    sched = Scheduler(params, cfg, scfg)
    sched.submit(poisson_requests(
        cfg, LoadConfig(rate=1.0, n_requests=3, prompt_len=4, gen_len=3,
                        seed=1)))
    results = sched.run()
    assert all(len(r.tokens) == 3 for r in results)
    assert all(0 <= t < cfg.vocab_size for r in results for t in r.tokens)


def test_scheduler_rejects_oversized_request(yi):
    """An invalid request is rejected per-request — structured Rejection,
    trace event, results entry — and never aborts the rest of the batch
    (the old contract raised out of submit() and dropped everything)."""
    cfg, params = yi
    sched = Scheduler(params, cfg, SchedulerConfig(n_slots=1, max_len=8))
    good = Request(rid=2, arrival=0.0, prompt=np.zeros(3, np.int32),
                   max_new_tokens=3)
    sched.submit([
        Request(rid=0, arrival=0.0, prompt=np.zeros(6, np.int32),
                max_new_tokens=4),                      # oversized
        Request(rid=1, arrival=0.0, prompt=np.zeros(3, np.int32),
                max_new_tokens=0),                      # invalid budget
        good,                                           # must still run
    ])
    assert [(r.rid, r.reason) for r in sched.rejections] == \
        [(0, "oversized"), (1, "invalid")]
    assert all(r.retry_after is None for r in sched.rejections)
    assert sched.results[0].status == "rejected"
    assert sched.results[1].status == "rejected"
    assert ("reject", 0.0, 0, "oversized") in sched.trace
    results = sched.run()
    ok = sched.results[good.rid]
    assert ok.status == "finished" and len(ok.tokens) == 3
    assert len(results) == 3


# --------------------------------------------------------------------- #
# generate(): thin scheduler client + satellite-1 bugfix pin
# --------------------------------------------------------------------- #
def test_generate_cache_consistent_with_emitted_sequence(yi):
    """The pre-PR-6 generate() broke out of the loop before the final
    step, leaving the cache stale by one token.  Pin the fix two ways:
    (a) the returned final logits are exactly the next-token distribution
    a longer run continues with, (b) the returned cache equals a full
    prefill over the emitted sequences bit for bit."""
    cfg, params = yi
    prompts = jax.random.randint(
        jax.random.PRNGKey(3), (2, 6), 0, cfg.vocab_size, jnp.int32)
    seqs, cache, final = serve.generate(params, cfg, prompts, 4,
                                        return_state=True)
    assert seqs.shape == (2, 10)
    seqs5 = np.asarray(serve.generate(params, cfg, prompts, 5))
    np.testing.assert_array_equal(np.asarray(seqs), seqs5[:, :10])
    np.testing.assert_array_equal(np.argmax(final, axis=-1), seqs5[:, -1])
    _, oracle = transformer.prefill(params, cfg, {"inputs": seqs}, 10)
    for name in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(cache["layers"][name], np.float32),
            np.asarray(oracle["layers"][name], np.float32))


def test_generate_fp8_storage(yi):
    cfg, params = yi
    prompts = jax.random.randint(
        jax.random.PRNGKey(3), (2, 6), 0, cfg.vocab_size, jnp.int32)
    seqs = serve.generate(params, cfg, prompts, 4, storage_dtype=FP8)
    assert seqs.shape == (2, 10)
    assert np.array_equal(np.asarray(seqs)[:, :6], np.asarray(prompts))
