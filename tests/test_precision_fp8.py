"""Mixed-precision (per-operand FP8) numerics — the PR-5 tentpole's tests.

* quantize→dequantize round-trip error bounded per format (E4M3 ε=2⁻³,
  E5M2 ε=2⁻²) — the unit-max scaling keeps the FP16 datapath
  overflow-free without touching the formats' relative precision;
* interpret-vs-xla gradients under the FP8 policies agree to the
  compute-dtype tolerance on kink-free sweeps (the engine quantizes once,
  so the FP8 rounding is backend-invariant by construction);
* per-tensor scale robustness in optim/scale.py: overflowed amax
  observations are dropped (never poison the scale), all-zero windows
  keep the previous scale (never collapse it);
* pipeline-depth ∈ {1, 2, 3} kernel equivalence under FP8 storage;
* Policy/GemmSpec dtype validation fails at construction with a message
  naming the offending field and the known-policy registry;
* the byte-accounting acceptance: an FP8 AE train trace carries strictly
  fewer engine bytes than the FP16 one at identical engine flops.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core import precision as prec
from repro.kernels import ops
from repro.optim import scale as oscale

RNG = np.random.default_rng(3)

FP8_POLICIES = [prec.MIXED_FP8_E4M3, prec.MIXED_FP8_E5M2]

# round-trip relative error bound: one rounding step at the format's
# machine epsilon (ε/2 for round-to-nearest; ε is the loose bound we pin)
_EPS = {"float8_e4m3fn": 2.0 ** -3, "float8_e5m2": 2.0 ** -2}


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# ------------------------------------------------------------------ #
# quantize / dequantize round trips
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("fmt", prec.FP8_FORMATS)
def test_fp8_roundtrip_error_bound(fmt):
    eps = _EPS[fmt]
    v = _rand((64, 64), jnp.float32, 2.5)
    q, s = prec.quantize_fp8(v, fmt)
    assert q.dtype == jnp.dtype(fmt)
    back = np.asarray(prec.dequantize_fp8(q, s), np.float32)
    vf = np.asarray(v, np.float32)
    # values within the format's normal window below the tensor amax
    # round-trip with relative error <= eps; tinier values hit the
    # subnormal floor (absolute error <= eps * 2^-6 * s)
    amax = np.abs(vf).max()
    normal = np.abs(vf) >= amax * 2.0 ** -6
    rel = np.abs(back - vf) / np.maximum(np.abs(vf), 1e-30)
    assert rel[normal].max() <= eps, (
        f"{fmt} round-trip relative error {rel[normal].max():.4g} > {eps}")
    np.testing.assert_allclose(back, vf, atol=float(amax) * eps,
                               rtol=eps)


@pytest.mark.parametrize("fmt", prec.FP8_FORMATS)
def test_fp8_quantized_values_unit_max(fmt):
    """Unit-max scaling: |q| <= 1, so FP16 products cannot overflow."""
    v = _rand((32, 32), jnp.float32, 123.0)
    q, s = prec.quantize_fp8(v, fmt)
    assert float(jnp.max(jnp.abs(q.astype(jnp.float32)))) <= 1.0
    assert float(s) == pytest.approx(float(jnp.max(jnp.abs(v))), rel=1e-6)


def test_fp8_quantize_degenerate_tensors():
    zq, zs = prec.quantize_fp8(jnp.zeros((4, 4)), "float8_e4m3fn")
    assert float(zs) == 1.0 and not np.any(np.asarray(zq, np.float32))
    bad = jnp.full((4, 4), np.inf, jnp.float32)
    _, bs = prec.quantize_fp8(bad, "float8_e5m2")
    assert float(bs) == 1.0  # non-finite amax falls back to s=1
    with pytest.raises(ValueError, match="quantize_fp8 target"):
        prec.quantize_fp8(jnp.zeros(3), jnp.float16)


# ------------------------------------------------------------------ #
# interpret-vs-xla grads under the FP8 policies (kink-free sweeps)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("policy", FP8_POLICIES, ids=lambda p: p.name)
@pytest.mark.parametrize("act", [None, "tanh", "gelu"])
def test_fp8_linear_grads_interpret_vs_xla(policy, act):
    x = _rand((9, 33), jnp.float32, 0.3)
    w = _rand((33, 12), jnp.float32, 0.3)
    b = _rand((12,), jnp.float32, 0.1)

    def loss(p, backend):
        z = engine.linear(p["x"], p["w"], p["b"], activation=act,
                          policy=policy, backend=backend)
        return jnp.sum(z.astype(jnp.float32) ** 2)

    p = {"x": x, "w": w, "b": b}
    gi = jax.grad(lambda q: loss(q, "interpret"))(p)
    gx = jax.grad(lambda q: loss(q, "xla"))(p)
    # the engine quantizes once (backend-invariant FP8 rounding), so the
    # cross-backend gap is only the fp16 accumulation-order difference
    jax.tree.map(
        lambda a, bb: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(bb, np.float32),
            rtol=2e-2, atol=2e-2), gi, gx)


@pytest.mark.parametrize("policy", FP8_POLICIES, ids=lambda p: p.name)
def test_fp8_matmul_grads_close_to_f32_reference(policy):
    """FP8 grads track the FP32 reference within the quantization bound:
    one E5M2 rounding of the cotangent (ε=2⁻²) plus operand roundings."""
    x = _rand((8, 16), jnp.float32, 0.5)
    w = _rand((16, 8), jnp.float32, 0.5)

    g8 = jax.grad(lambda q: jnp.sum(engine.matmul(
        q, w, policy=policy, backend="interpret").astype(jnp.float32) ** 2))(x)
    gr = jax.grad(lambda q: jnp.sum((q @ w) ** 2))(x)
    ref = np.asarray(gr, np.float32)
    bound = 0.5 * max(1.0, float(np.abs(ref).max()))
    np.testing.assert_allclose(np.asarray(g8, np.float32), ref, atol=bound)
    assert g8.dtype == x.dtype


def test_fp8_events_carry_per_operand_dtypes_and_scaled_flag():
    x = _rand((8, 16), jnp.float32)
    w = _rand((16, 8), jnp.float32)
    b = _rand((8,), jnp.float32)
    with engine.instrument() as ev:
        jax.eval_shape(lambda xx, ww, bb: jax.value_and_grad(
            lambda q: jnp.sum(engine.linear(
                xx, q, bb, policy=prec.MIXED_FP8_E4M3,
                backend="interpret").astype(jnp.float32) ** 2))(ww),
            x, w, b)
    by_op = {e.spec.op: e.spec for e in ev}
    fwd = by_op["linear"]
    assert fwd.x_dtype == "float8_e4m3fn" and fwd.w_dtype == "float8_e4m3fn"
    assert fwd.scaled
    # backward: dZ rides in the grad storage (E5M2) — the x slot on dX,
    # the w slot on dW; the residual slots keep the forward storage
    assert by_op["matmul_dx"].x_dtype == "float8_e5m2"
    assert by_op["matmul_dx"].w_dtype == "float8_e4m3fn"
    assert by_op["matmul_dw"].x_dtype == "float8_e4m3fn"
    assert by_op["matmul_dw"].w_dtype == "float8_e5m2"
    # scaled specs take the two-pass backward: the bias grad is its own
    # pass event, reduced from the wide cotangent
    assert "linear_dbias" in by_op


def test_fp8_bytes_drop_flops_dont_on_ae_train():
    """The acceptance criterion: the FP8 AE train trace carries strictly
    fewer engine bytes than the FP16 one at identical engine flops."""
    from repro.data import SyntheticAE
    from repro.models import autoencoder

    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    x = jnp.asarray(SyntheticAE(batch=16).sample(0))

    def trace(policy):
        with engine.instrument() as events:
            jax.eval_shape(lambda p: jax.value_and_grad(
                lambda q: autoencoder.ae_loss(
                    q, x, policy=policy, backend="interpret")[0])(p), params)
        return events

    ev8, ev16 = trace(prec.MIXED_FP8_E4M3), trace(prec.PAPER_FP16)
    assert engine.total_flops(ev8) == engine.total_flops(ev16)
    assert engine.total_bytes(ev8) < engine.total_bytes(ev16)


def test_fp8_postep_pass_classifies_like_its_gemm_under_remat():
    """The forced post-op pass event rides through the same remat
    classification as the GEMM it accompanies: one primal + one
    recompute-tagged emission per checkpoint region, no partial-eval
    phantoms — so FP8 byte totals stay honest under jax.checkpoint."""
    x = _rand((8, 16), jnp.float32, 0.3)
    w = _rand((16, 8), jnp.float32, 0.3)
    b = _rand((8,), jnp.float32, 0.1)

    def f(q):
        h = jax.checkpoint(lambda ww: engine.linear(
            x, ww, b, activation="gelu", policy=prec.MIXED_FP8_E4M3,
            backend="interpret"))(q)
        return jnp.sum(h.astype(jnp.float32) ** 2)

    with engine.instrument() as ev:
        jax.eval_shape(lambda q: jax.value_and_grad(f)(q), w)
    postep = [e for e in ev if e.spec.op == "linear_postep"]
    assert [(e.count, e.recompute) for e in postep] == \
        [(1, False), (1, True)]
    gemm = [e for e in ev if e.spec.op == "linear"]
    assert [(e.count, e.recompute) for e in gemm] == \
        [(e.count, e.recompute) for e in postep]


# ------------------------------------------------------------------ #
# optim/scale.py: FP8 per-tensor delayed scaling robustness
# ------------------------------------------------------------------ #
def test_fp8_scale_tracks_amax_window():
    st = oscale.init_fp8_scale(history_len=4)
    for amax in (1.0, 4.0, 2.0):
        st = oscale.update_fp8_scale(st, jnp.float32(amax))
    assert float(st.scale) == 4.0            # window max
    # 4.0 rolls out of the window after 4 more observations
    for _ in range(4):
        st = oscale.update_fp8_scale(st, jnp.float32(0.5))
    assert float(st.scale) == 0.5
    assert int(st.overflow_count) == 0


def test_fp8_scale_overflow_observation_is_dropped():
    st = oscale.init_fp8_scale(history_len=4)
    st = oscale.update_fp8_scale(st, jnp.float32(2.0))
    before = float(st.scale)
    for bad in (np.inf, np.nan, -1.0):
        st = oscale.update_fp8_scale(st, jnp.float32(bad))
        assert np.isfinite(float(st.scale))
        assert float(st.scale) == before, (
            "an overflowed amax observation must not poison the scale")
    assert int(st.overflow_count) == 3


def test_fp8_scale_underflow_keeps_previous_scale():
    st = oscale.init_fp8_scale(history_len=2)
    st = oscale.update_fp8_scale(st, jnp.float32(8.0))
    # a run of all-zero grads longer than the window
    for _ in range(5):
        st = oscale.update_fp8_scale(st, jnp.float32(0.0))
    assert float(st.scale) == 8.0, (
        "an all-zero window must keep the previous scale, not collapse it")
    st = oscale.observe_amax(st, jnp.zeros((3, 3)))
    assert float(oscale.fp8_scale_of(st)) == 8.0


def test_fp8_scale_margin_headroom():
    st = oscale.init_fp8_scale(history_len=2)
    st = oscale.update_fp8_scale(st, jnp.float32(2.0), margin=1.5)
    assert float(st.scale) == 3.0
    # works inside jit (all state traced); margin is per-update, so the
    # default-margin refresh re-derives scale = window max = 2.0
    st2 = jax.jit(oscale.update_fp8_scale)(st, jnp.float32(1.0))
    assert float(st2.scale) == pytest.approx(2.0)


# ------------------------------------------------------------------ #
# pipeline-depth equivalence under FP8 storage
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("layout", ["nn", "nt", "tn"])
def test_pipeline_depth_equivalence_under_fp8(layout):
    pol = prec.MIXED_FP8_E4M3
    M, N, K = 24, 33, 17
    shapes = {"nn": ((M, N), (N, K)), "nt": ((M, N), (K, N)),
              "tn": ((N, M), (N, K))}
    xs, ws = shapes[layout]
    x = _rand(xs, jnp.float8_e4m3fn, 0.3)
    w = _rand(ws, jnp.float8_e4m3fn, 0.3)
    outs = [np.asarray(ops.redmule_matmul(
        x, w, policy=pol, layout=layout, pipeline_depth=d,
        interpret=True), np.float32) for d in (1, 2, 3)]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


# ------------------------------------------------------------------ #
# construction-time dtype validation (Policy and GemmSpec)
# ------------------------------------------------------------------ #
def test_policy_validates_dtypes_at_construction():
    with pytest.raises(ValueError) as e:
        prec.Policy(name="typo", compute_dtype="floatt16",
                    accum_dtype=jnp.float32)
    msg = str(e.value)
    assert "Policy.compute_dtype" in msg and "floatt16" in msg
    assert "mixed_fp8_e4m3" in msg  # names the known-policy registry
    with pytest.raises(ValueError, match="Policy.grad_dtype"):
        prec.Policy(name="typo", compute_dtype=jnp.float16,
                    accum_dtype=jnp.float32, grad_dtype="fp8_e5m2")
    with pytest.raises(ValueError, match="Policy.accum_dtype"):
        prec.Policy(name="typo", compute_dtype=jnp.float16,
                    accum_dtype=jnp.int32)  # not a floating dtype


def test_gemmspec_validates_dtypes_and_enums_at_construction():
    with pytest.raises(ValueError) as e:
        engine.GemmSpec(op="matmul", tag="t", m=8, n=8, k=8,
                        x_dtype="float8_e4m3fnuz_typo")
    msg = str(e.value)
    assert "GemmSpec.x_dtype" in msg and "known precision policies" in msg
    with pytest.raises(ValueError, match="GemmSpec.layout"):
        engine.GemmSpec(op="matmul", tag="t", m=8, n=8, k=8, layout="tt")
    with pytest.raises(ValueError, match="GemmSpec.ragged_dim"):
        engine.GemmSpec(op="matmul", tag="t", m=8, n=8, k=8, ragged_dim="k")


def test_resolve_rejects_unknown_policy_naming_registry():
    with pytest.raises(ValueError) as e:
        prec.resolve("mixed_fp9")
    assert "mixed_fp8_e4m3" in str(e.value)


def test_fp8_policy_properties():
    p = prec.MIXED_FP8_E4M3
    assert p.mixed_storage and p.scaled
    assert jnp.dtype(p.x_storage_dtype) == jnp.dtype(jnp.float8_e4m3fn)
    assert jnp.dtype(p.grad_storage_dtype) == jnp.dtype(jnp.float8_e5m2)
    assert not prec.PAPER_FP16.mixed_storage
    assert not prec.PAPER_FP16.scaled
    # the grad policy replace() used by the engine keeps validity
    g = dataclasses.replace(p, name="g", output_dtype=p.accum_dtype)
    assert g.scaled
