"""Optimizer substrate: AdamW/SGD, dynamic loss scaling, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamW, SGD, Compressor, adjust, clip_by_global_norm,
                         global_norm, init_scale, scale_loss,
                         unscale_and_check)


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        u, s = opt.update(g, s, p)
        return opt.apply(p, u), s

    for _ in range(200):
        params, state = step(params, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_sgd_momentum_converges():
    opt = SGD(lr=0.05, momentum=0.9)
    params = jnp.asarray([4.0, -4.0])
    state = opt.init(params)
    for _ in range(150):
        g = jax.grad(lambda q: jnp.sum(q ** 2))(params)
        u, state = opt.update(g, state, params)
        params = opt.apply(params, u)
    assert float(jnp.max(jnp.abs(params))) < 1e-2


def test_weight_decay_shrinks_params():
    opt = AdamW(lr=1e-2, weight_decay=0.1)
    p = {"w": jnp.ones(4)}
    s = opt.init(p)
    zero_g = {"w": jnp.zeros(4)}
    for _ in range(50):
        u, s = opt.update(zero_g, s, p)
        p = opt.apply(p, u)
    assert float(p["w"][0]) < 1.0


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - np.sqrt(10 * 9 + 10 * 16)) < 1e-4
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    # under the limit -> untouched
    same, _ = clip_by_global_norm(tree, 1e9)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(tree["a"]))


# ------------------------------------------------------------------ #
# Dynamic loss scaling (the paper's FP16 training regime)
# ------------------------------------------------------------------ #
def test_loss_scale_halves_on_overflow_and_skips():
    s = init_scale(initial=2.0**15)
    grads = {"w": jnp.asarray([jnp.inf, 1.0])}
    g2, finite = unscale_and_check(grads, s)
    assert not bool(finite)
    s2 = adjust(s, finite)
    assert float(s2.scale) == 2.0**14
    assert int(s2.overflow_count) == 1
    assert int(s2.good_steps) == 0


def test_loss_scale_grows_after_interval():
    s = init_scale(initial=1024.0, growth_interval=3)
    for _ in range(3):
        s = adjust(s, jnp.bool_(True))
    assert float(s.scale) == 2048.0
    assert int(s.good_steps) == 0  # reset after growth


def test_scale_roundtrip():
    s = init_scale(initial=512.0)
    loss = jnp.float32(0.25)
    scaled = scale_loss(loss, s)
    assert float(scaled) == 128.0
    grads = {"w": jnp.asarray([512.0])}
    g, finite = unscale_and_check(grads, s)
    assert bool(finite)
    np.testing.assert_allclose(np.asarray(g["w"]), [1.0])


def test_fp16_training_with_scaling_survives_overflow():
    """End-to-end: a step that overflows is skipped, training continues."""
    from repro import configs
    from repro.launch.train import build_train_step, init_state

    cfg = configs.get_reduced("qwen3-1.7b")
    import dataclasses
    cfg = dataclasses.replace(cfg, policy_name="tpu_fp16")
    opt = AdamW(lr=1e-3)
    step = jax.jit(build_train_step(cfg, opt, rules=None, use_scale=True))
    state = init_state(jax.random.PRNGKey(0), cfg, opt, use_scale=True)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    batch = {"inputs": toks, "labels": toks}
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]


# ------------------------------------------------------------------ #
# Gradient compression with error feedback
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("kind", ["fp16", "int8", "fp8_e4m3", "fp8_e5m2"])
def test_compression_roundtrip_error_bounded(kind):
    comp = Compressor(kind)
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(256,)), jnp.float32)}
    ef = comp.init(g)
    wire, ef = comp.compress(g, ef)
    rec = comp.decompress(wire)
    err = float(jnp.max(jnp.abs(rec["w"] - g["w"])))
    bound = {"fp16": 1e-2, "int8": 0.1,
             "fp8_e4m3": 0.25, "fp8_e5m2": 0.5}[kind]
    assert err < bound


def test_fp8_alias_and_unknown_kind():
    assert Compressor("fp8").kind == "fp8_e4m3"
    with pytest.raises(ValueError, match="unknown compression kind"):
        Compressor("fp7")


@pytest.mark.parametrize("kind", ["fp8_e4m3", "fp8_e5m2"])
def test_fp8_error_feedback_recovers_clipped_mass(kind):
    """The delayed scale starts at 1.0, so a first step with |g| above the
    format max clips hard — the clipped mass must land in the EF buffer
    and drain over the following steps as the amax window catches up."""
    comp = Compressor(kind)
    g_true = jnp.full((32,), 900.0, jnp.float32)  # above e4m3's 448 max
    ef = comp.init({"w": g_true})
    total_sent = jnp.zeros_like(g_true)
    for _ in range(8):
        wire, ef = comp.compress({"w": g_true}, ef)
        total_sent = total_sent + comp.decompress(wire)["w"]
    # over 8 steps the transmitted mean tracks the true gradient closely
    rel = float(jnp.max(jnp.abs(total_sent / 8 - g_true))) / 900.0
    assert rel < 0.05, rel
    # and the residual is what is still in flight, not lost
    resid = jax.tree.leaves(ef)[0]
    np.testing.assert_allclose(
        np.asarray(total_sent + resid), np.asarray(8 * g_true), rtol=1e-4)


@pytest.mark.parametrize("kind", ["fp16", "int8"])
def test_error_feedback_is_unbiased_over_steps(kind):
    """EF property: sum of decompressed grads ~= sum of true grads (the
    residual is carried, not lost)."""
    comp = Compressor(kind)
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32) * 1e-3
    ef = comp.init({"w": g_true})
    total_sent = jnp.zeros_like(g_true)
    n = 50
    for _ in range(n):
        wire, ef = comp.compress({"w": g_true}, ef)
        total_sent = total_sent + comp.decompress(wire)["w"]
    # accumulated transmission error == final residual, which is bounded
    resid = float(jnp.max(jnp.abs(total_sent - n * g_true)))
    one_step_q = float(jnp.max(jnp.abs(g_true))) * (2**-10 if kind == "fp16" else 1/127)
    assert resid < 4 * one_step_q * 1.5 + 1e-6


def test_compression_wire_sizes():
    assert Compressor("none").wire_bits == 32
    assert Compressor("fp16").wire_bits == 16
    assert Compressor("int8").wire_bits == 8
    assert Compressor("fp8_e4m3").wire_bits == 8
    assert Compressor("fp8_e5m2").wire_bits == 8


def test_wire_bytes_analytic():
    """wire_bytes prices what a ring all-reduce moves: wire_bits/8 per
    element plus one f32 scale per tensor on the scaled wires."""
    tree = {"w": jnp.zeros((16, 16)), "b": jnp.zeros((16,))}  # 272 elems
    assert Compressor("none").wire_bytes(tree) == 272 * 4
    assert Compressor("fp16").wire_bytes(tree) == 272 * 2
    assert Compressor("int8").wire_bytes(tree) == 272 + 2 * 4
    assert Compressor("fp8_e4m3").wire_bytes(tree) == 272 + 2 * 4
    # ShapeDtypeStructs price identically (no materialization needed)
    import jax
    abstract = jax.eval_shape(lambda: tree)
    assert (Compressor("fp8_e5m2").wire_bytes(abstract)
            == Compressor("fp8_e5m2").wire_bytes(tree))


def test_per_host_scales_match_fp32_oracle():
    """Multi-device (subprocess): hosts with gradient magnitudes 7 orders
    of magnitude apart.  The all-reduce must weight each host's payload by
    its OWN scale — the seed averaged the per-host scales into one shared
    divisor, inflating the small-gradient host's contribution ~1e7x.  Both
    8-bit wires are pinned against the fp32 oracle."""
    import os
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.optim import Compressor
from repro.runtime import compat

mesh = compat.make_mesh((2,), ("data",))
rng = np.random.default_rng(0)
# host 0: tiny gradients; host 1: huge gradients
g = np.stack([rng.normal(size=256).astype(np.float32) * 1e-4,
              rng.normal(size=256).astype(np.float32) * 1e3])
oracle = g.astype(np.float64).mean(axis=0)

for kind in ("int8", "fp8_e4m3", "fp8_e5m2"):
    comp = Compressor(kind)
    ef0 = comp.init({"w": jnp.zeros(256, jnp.float32)})
    n_steps = 6

    def local(gs, ef):
        sent = jnp.zeros(256, jnp.float32)
        for _ in range(n_steps):  # EF drains over steps (delayed fp8 scale)
            wire, ef = comp.compress({"w": gs[0]}, ef)
            sent = sent + comp.psum_wire(wire, ("data",))["w"]
        return sent / n_steps

    espec = jax.tree.map(lambda _: P(), ef0)
    f = shard_map(local, mesh, in_specs=(P("data"), espec),
                  out_specs=P(), check_rep=False)
    out = np.asarray(jax.jit(f)(jnp.asarray(g), ef0))
    rel = float(np.max(np.abs(out - oracle)) / np.max(np.abs(oracle)))
    print(kind, "rel_err_vs_oracle:", rel)
    assert rel < 0.02, (kind, rel)
print("OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"})
    assert "OK" in out.stdout, (out.stdout[-1000:], out.stderr[-2000:])


def test_compressed_dp_train_step_matches_uncompressed():
    """Multi-device (subprocess): fp16-wire DP training tracks fp32-wire DP,
    and the all-reduce in the compiled module really runs on the 16-bit
    wire dtype."""
    import os
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, re
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.launch.train import build_compressed_dp_train_step
from repro.optim import AdamW, Compressor
from repro.runtime import compat

cfg = configs.get_reduced("qwen3-1.7b")
mesh = compat.make_mesh((4, 1), ("data", "model"))
opt = AdamW(lr=1e-3)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
batch = {"inputs": toks, "labels": toks}

results = {}
with compat.set_mesh(mesh):
    for kind in ("none", "fp16"):
        comp = Compressor(kind)
        step, init_fn = build_compressed_dp_train_step(cfg, opt, mesh, comp)
        state = init_fn(jax.random.PRNGKey(0))
        jstep = jax.jit(step)
        if kind == "fp16":
            hlo = jstep.lower(state, batch).compile().as_text()
            # XLA merges psums into variadic all-reduces: check the result
            # tuple dtypes on every all-reduce line
            lines = [l for l in hlo.splitlines()
                     if " all-reduce(" in l and "= " in l]
            assert lines, "no all-reduce found"
            assert any("f16[" in l.split(" all-reduce(")[0] for l in lines), \\
                "no f16 wire: " + lines[0][:200]
        for _ in range(5):
            state, metrics = jstep(state, batch)
        results[kind] = (jax.tree.leaves(state[0].params), float(metrics["loss"]))

d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(*[results[k][0] for k in ("none", "fp16")]))
print("param divergence:", d, "losses:", results["none"][1], results["fp16"][1])
assert d < 5e-3, d
assert abs(results["none"][1] - results["fp16"][1]) < 0.05
print("OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"})
    assert "OK" in out.stdout, (out.stdout[-1000:], out.stderr[-2000:])
