"""The Engine's backward contract: custom-VJP GEMMs through the registry.

Covers the train-side half of the Engine API:
  * jax.grad of every op family member (matmul weight/batched, linear with
    bias+activation epilogues, grouped_matmul dense/ragged, einsum2d) on
    the pallas-kernel "interpret" backend matches the "xla" reference
    grads to the documented tolerance, under paper_fp16 and fp32-accum
    policies (relu's kink at 0 excluded by construction);
  * backward dispatches emit GemmEvents tagged matmul_dx / matmul_dw with
    transpose layouts ("nt"/"tn"), resolved tiles, and accum-dtype grad
    policies — three events per affine layer (fwd, dX, dW);
  * backward events inherit the repeat() multiplicity captured at forward
    trace time (scanned layer bodies, grad-accumulation microbatch scans);
  * ragged grouped_matmul events carry valid_rows so flops/bytes scale
    with sum(group_sizes), not G*M — forward and backward (the satellite
    regression);
  * a value_and_grad trace totals exactly 3x the inference GEMM flops for
    a pure-GEMM model (the AE), and backends without the "layouts"
    capability still differentiate (engine pre-transposes for them).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core import epilogues as epi
from repro.core import precision as prec

RNG = np.random.default_rng(7)


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


def _tol(policy):
    # fp16/bf16 compute: grads go through two half-precision GEMMs; the
    # xla and pallas backends accumulate in different orders
    return {"float32": (1e-5, 1e-5), "float16": (2e-2, 2e-2),
            "bfloat16": (1e-1, 1e-1)}[jnp.dtype(policy.compute_dtype).name]


def _assert_grads_close(got, want, policy):
    rtol, atol = _tol(policy)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=rtol, atol=atol),
        got, want)


POLICIES = [prec.PAPER_FP16, prec.TPU_FP16, prec.FP32]


# ------------------------------------------------------------------ #
# VJP numerics: interpret (Pallas kernels) vs xla reference
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("policy", POLICIES, ids=lambda p: p.name)
def test_matmul_weight_gemm_grads_match_xla(policy):
    x = _rand((3, 9, 16), policy.compute_dtype, 0.3)
    w = _rand((16, 12), policy.compute_dtype, 0.3)

    def loss(p, backend):
        z = engine.matmul(p["x"], p["w"], policy=policy, backend=backend)
        return jnp.sum(z.astype(jnp.float32) ** 2)

    p = {"x": x, "w": w}
    g_int = jax.grad(lambda q: loss(q, "interpret"))(p)
    g_xla = jax.grad(lambda q: loss(q, "xla"))(p)
    assert g_int["x"].dtype == x.dtype and g_int["w"].dtype == w.dtype
    _assert_grads_close(g_int, g_xla, policy)


@pytest.mark.parametrize("policy", POLICIES, ids=lambda p: p.name)
def test_matmul_batched_grads_match_xla(policy):
    x = _rand((4, 6, 10), policy.compute_dtype, 0.3)
    w = _rand((4, 10, 8), policy.compute_dtype, 0.3)

    def loss(p, backend):
        z = engine.matmul(p["x"], p["w"], policy=policy, backend=backend)
        return jnp.sum(z.astype(jnp.float32) ** 2)

    p = {"x": x, "w": w}
    _assert_grads_close(jax.grad(lambda q: loss(q, "interpret"))(p),
                        jax.grad(lambda q: loss(q, "xla"))(p), policy)


@pytest.mark.parametrize("policy", [prec.PAPER_FP16, prec.FP32],
                         ids=lambda p: p.name)
@pytest.mark.parametrize("act", [None, "relu", "gelu", "silu", "tanh"])
def test_linear_epilogue_grads_match_xla(policy, act):
    # inputs bounded away from 0 pre-activation so relu's kink (where the
    # two backends may legitimately disagree) is excluded
    x = _rand((8, 24), policy.compute_dtype, 0.5)
    w = _rand((24, 16), policy.compute_dtype, 0.5)
    b = _rand((16,), policy.compute_dtype, 0.5)
    if act == "relu":
        s = np.asarray(x, np.float32) @ np.asarray(w, np.float32) \
            + np.asarray(b, np.float32)
        assert np.abs(s).min() > 1e-3, "test inputs landed on the relu kink"

    def loss(p, backend):
        z = engine.linear(p["x"], p["w"], p["b"], activation=act,
                          policy=policy, backend=backend)
        return jnp.sum(z.astype(jnp.float32) ** 2)

    p = {"x": x, "w": w, "b": b}
    g_int = jax.grad(lambda q: loss(q, "interpret"))(p)
    g_xla = jax.grad(lambda q: loss(q, "xla"))(p)
    assert g_int["b"].dtype == b.dtype
    _assert_grads_close(g_int, g_xla, policy)


@pytest.mark.parametrize("act", ["relu", "gelu", "silu", "tanh"])
def test_epilogue_derivative_registry_matches_autodiff(act):
    """The closed-form derivatives (and output-form variants) equal
    jax.grad of the registered activation, pointwise."""
    s = jnp.linspace(-3.0, 3.0, 101)
    s = s[jnp.abs(s) > 1e-6]  # exclude the relu kink
    fn = epi.EPILOGUES[act]
    want = jax.vmap(jax.grad(fn))(s)
    grad = epi.epilogue_grad(act)
    np.testing.assert_allclose(np.asarray(grad.deriv(s)), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    if grad.deriv_from_output is not None:
        np.testing.assert_allclose(np.asarray(grad.deriv_from_output(fn(s))),
                                   np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("policy", [prec.PAPER_FP16, prec.FP32],
                         ids=lambda p: p.name)
def test_grouped_matmul_ragged_grads(policy):
    G, M, N, K = 3, 8, 16, 12
    sizes = jnp.asarray([5, 0, 8])
    x = _rand((G, M, N), policy.compute_dtype, 0.3)
    w = _rand((G, N, K), policy.compute_dtype, 0.3)

    def loss(p, backend):
        z = engine.grouped_matmul(p["x"], p["w"], group_sizes=sizes,
                                  policy=policy, backend=backend)
        return jnp.sum(z.astype(jnp.float32) ** 2)

    p = {"x": x, "w": w}
    g_int = jax.grad(lambda q: loss(q, "interpret"))(p)
    g_xla = jax.grad(lambda q: loss(q, "xla"))(p)
    _assert_grads_close(g_int, g_xla, policy)
    # masked rows contribute nothing: dX beyond each group's size is zero
    gx = np.asarray(g_int["x"], np.float32)
    for g in range(G):
        assert np.all(gx[g, int(sizes[g]):] == 0.0)


def test_einsum2d_grads_match_jnp_einsum():
    eqs = [("mn,nk->mk", (6, 5), (5, 4)),
           ("bij,bjk->bik", (2, 6, 5), (2, 5, 4)),
           ("bhsd,rhd->bhsr", (2, 3, 5, 7), (4, 3, 7))]
    for eq, xs, ws in eqs:
        x, w = _rand(xs), _rand(ws)

        def loss(p, f):
            return jnp.sum(jnp.sin(f(p["x"], p["w"])))

        p = {"x": x, "w": w}
        got = jax.grad(lambda q: loss(
            q, lambda a, b: engine.einsum2d(eq, a, b, policy=prec.FP32)))(p)
        want = jax.grad(lambda q: loss(
            q, lambda a, b: jnp.einsum(eq, a, b)))(p)
        _assert_grads_close(got, want, prec.FP32)


def test_linear_batched_weights_fused_matches_postop():
    """Satellite: linear lifted to (..., N, K) weights — the batched-grid
    kernel fuses bias+activation with the same equivalence contract as
    the 2D path (vs the xla post-op reference)."""
    pol = prec.PAPER_FP16
    x = _rand((3, 8, 24), pol.compute_dtype, 0.5)
    w = _rand((3, 24, 16), pol.compute_dtype, 0.5)
    b = _rand((16,), pol.compute_dtype, 0.5)
    for act in (None, "relu", "gelu"):
        zi = engine.linear(x, w, b, activation=act, policy=pol,
                           backend="interpret")
        zx = engine.linear(x, w, b, activation=act, policy=pol,
                           backend="xla")
        assert zi.shape == (3, 8, 16) and zi.dtype == pol.out_dtype
        np.testing.assert_allclose(np.asarray(zi, np.float32),
                                   np.asarray(zx, np.float32),
                                   rtol=2e-2, atol=2e-2)
    # and it differentiates: batched dW sums nothing away, bias grad does
    g = jax.grad(lambda q: jnp.sum(engine.linear(
        x, q["w"], q["b"], activation="gelu", policy=pol,
        backend="interpret").astype(jnp.float32) ** 2))({"w": w, "b": b})
    assert g["w"].shape == w.shape and g["b"].shape == b.shape


# ------------------------------------------------------------------ #
# Event tags, layouts, grad policy
# ------------------------------------------------------------------ #
def test_backward_events_tagged_and_layout_dispatched():
    pol = prec.TPU_FP16
    x, w, b = _rand((4, 8, 16)), _rand((16, 12)), _rand((12,))
    with engine.instrument() as events:
        jax.eval_shape(lambda p: jax.value_and_grad(
            lambda q: jnp.sum(engine.linear(
                q["x"], q["w"], q["b"], activation="gelu", policy=pol,
                backend="xla").astype(jnp.float32)))(p),
            {"x": x, "w": w, "b": b})
    ops = [ev.spec.op for ev in events]
    # xla lacks "fused_bwd_epilogue": the two-pass fallback bills its
    # standalone ds multiply and separate bias-grad reduction as zero-flop
    # pass events alongside the two backward GEMMs
    assert ops == ["linear", "linear_dact", "linear_dbias",
                   "matmul_dx", "matmul_dw"]
    by_op = {ev.spec.op: ev.spec for ev in events}
    fwd, dx, dw = by_op["linear"], by_op["matmul_dx"], by_op["matmul_dw"]
    for pass_op in ("linear_dact", "linear_dbias"):
        s = by_op[pass_op]
        assert engine.is_pass_op(s.op) and engine.is_backward_op(s.op)
        assert s.flops == 0 and s.bytes > 0
    # transposed problem shapes: dX contracts K, dW contracts batch*M
    assert (dx.layout, dx.m, dx.n, dx.k) == ("nt", fwd.m, fwd.k, fwd.n)
    assert (dw.layout, dw.m, dw.n, dw.k) == ("tn", fwd.n,
                                             fwd.batch * fwd.m, fwd.k)
    # grads held in the accum dtype; every event carries a resolved tile
    for s in (dx, dw):
        assert jnp.dtype(s.policy.out_dtype) == jnp.dtype(pol.accum_dtype)
        assert s.tile is not None
    # flop accounting: dX + dW together equal 2x the forward GEMM
    assert dx.flops + dw.flops == 2 * fwd.flops


def test_backward_dispatch_through_runtime_registered_backend():
    """A backend without the "layouts" capability still differentiates:
    the engine pre-transposes and dispatches equivalent "nn" specs."""
    xla_fn = engine.get_backend("xla").fn
    seen = []

    def recorder(x, w, *, spec):
        seen.append((spec.op, spec.layout, x.shape, w.shape))
        return xla_fn(x, w, spec=dict_spec_nn(spec))

    def dict_spec_nn(spec):
        return spec  # layout already "nn" by the engine's contract

    engine.register_backend("recorder-vjp", recorder)
    try:
        x, w = _rand((6, 10)), _rand((10, 4))
        g = jax.grad(lambda p: jnp.sum(engine.matmul(
            p["x"], p["w"], policy=prec.FP32, backend="recorder-vjp") ** 2)
        )({"x": x, "w": w})
        ref = jax.grad(lambda p: jnp.sum(engine.matmul(
            p["x"], p["w"], policy=prec.FP32, backend="xla") ** 2)
        )({"x": x, "w": w})
        _assert_grads_close(g, ref, prec.FP32)
    finally:
        engine.unregister_backend("recorder-vjp")
    assert [s[:2] for s in seen] == [
        ("matmul", "nn"), ("matmul_dx", "nn"), ("matmul_dw", "nn")]
    # pre-transposed operands: dX got W^T (4, 10); dW got X^T (10, 6)
    assert seen[1][3] == (10, 4) or seen[1][2] == (6, 4)
    assert seen[2][2] == (10, 6) or seen[2][3] == (6, 4)


# ------------------------------------------------------------------ #
# repeat() multiplicity in backward traces
# ------------------------------------------------------------------ #
def test_scanned_body_backward_inherits_repeat_multiplier():
    """A GEMM traced in a scanned layer body: its dX/dW events must carry
    the same count=n the forward event does, even though JAX traces the
    backward scan outside the repeat() context."""
    n = 5
    ws = _rand((n, 8, 8), scale=0.2)
    x0 = _rand((4, 8))

    def loss(ws_):
        def body(h, w):
            return engine.matmul(h, w, policy=prec.FP32, backend="xla"), 0

        with engine.repeat(n):
            h, _ = jax.lax.scan(body, x0, ws_)
        return jnp.sum(h ** 2)

    with engine.instrument() as events:
        jax.eval_shape(lambda p: jax.value_and_grad(loss)(p), ws)
    counts = {ev.spec.op: ev.count for ev in events}
    assert counts == {"matmul": n, "matmul_dx": n, "matmul_dw": n}


def test_grad_accum_scan_event_totals_scale():
    """Satellite: a grad-accumulated microbatch scan (value_and_grad inside
    the scanned body, engine.repeat(G) around the scan) reports G x the
    per-microbatch totals — fwd and bwd alike — and G=2 at half the batch
    equals G=1 at the full batch after scaling."""
    from repro.roofline import analysis

    w = _rand((16, 16), scale=0.2)

    def totals(batch, accum):
        xb = _rand((batch, 16))
        mb = xb.reshape(accum, batch // accum, 16)

        def lf(w_, b_):
            z = engine.matmul(b_, w_, policy=prec.FP32, backend="xla")
            return jnp.sum(z ** 2)

        def step(w_):
            def body(g_acc, b_):
                _, g = jax.value_and_grad(lf)(w_, b_)
                return g_acc + g, 0

            with engine.repeat(accum):
                g, _ = jax.lax.scan(body, jnp.zeros_like(w_), mb)
            return g

        with engine.instrument() as events:
            jax.eval_shape(step, w)
        split = analysis.flops_by_direction(events)
        return split, events

    s1, ev1 = totals(8, 1)
    s2, ev2 = totals(8, 2)
    # same global batch: the microbatch GEMM is half the rows but runs
    # twice — totals must agree exactly, for fwd AND backward events
    assert s2 == s1
    assert {ev.count for ev in ev2} == {2}
    assert {ev.count for ev in ev1} == {1}


# ------------------------------------------------------------------ #
# Ragged accounting (the grouped_matmul satellite)
# ------------------------------------------------------------------ #
def test_ragged_grouped_event_flops_scale_with_group_sizes():
    G, M, N, K = 4, 8, 16, 12
    x, w = _rand((G, M, N)), _rand((G, N, K))
    sizes = jnp.asarray([8, 3, 0, 5])

    with engine.instrument() as dense_ev:
        engine.grouped_matmul(x, w, policy=prec.FP32, backend="xla")
    with engine.instrument() as ragged_ev:
        engine.grouped_matmul(x, w, group_sizes=sizes, policy=prec.FP32,
                              backend="xla")
    (de,), (re_,) = dense_ev, ragged_ev
    assert de.spec.valid_rows is None
    assert re_.spec.valid_rows == int(sizes.sum()) == 16
    # flops scale with sum(group_sizes) / (G * M), exactly
    assert de.flops == 2 * G * M * N * K
    assert re_.flops == 2 * int(sizes.sum()) * N * K
    assert re_.flops * G * M == de.flops * int(sizes.sum())
    # bytes: ragged x reads and z writes scale; the shared w does not
    itm = 4
    assert re_.bytes == (16 * N + 16 * K) * itm + G * N * K * itm
    # oversized and negative sizes clamp
    with engine.instrument() as ev:
        engine.grouped_matmul(x, w, group_sizes=jnp.asarray([100, -1, 8, 0]),
                              policy=prec.FP32, backend="xla")
    assert ev[0].spec.valid_rows == M + 0 + 8 + 0


def test_ragged_backward_events_carry_valid_rows():
    G, M, N, K = 3, 8, 16, 12
    x, w = _rand((G, M, N)), _rand((G, N, K))
    sizes = jnp.asarray([5, 0, 8])
    with engine.instrument() as events:
        jax.eval_shape(lambda p: jax.value_and_grad(
            lambda q: jnp.sum(engine.grouped_matmul(
                q, w, group_sizes=sizes, policy=prec.FP32,
                backend="xla") ** 2))(p), x)
    by_op = {ev.spec.op: ev.spec for ev in events}
    vr = int(sizes.sum())
    assert by_op["grouped_matmul"].valid_rows == vr
    dx, dw = by_op["matmul_dx"], by_op["matmul_dw"]
    assert (dx.valid_rows, dx.ragged_dim) == (vr, "m")
    assert (dw.valid_rows, dw.ragged_dim) == (vr, "n")
    # dX masks output rows, dW masks contraction rows — same flop total
    assert dx.flops == 2 * vr * dx.n * dx.k
    assert dw.flops == 2 * dw.m * vr * dw.k


# ------------------------------------------------------------------ #
# The 3x acceptance: train trace = fwd + dX + dW
# ------------------------------------------------------------------ #
def test_ae_train_trace_is_three_x_inference():
    from repro.data import SyntheticAE
    from repro.models import autoencoder
    from repro.roofline import analysis

    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    x = jnp.asarray(SyntheticAE(batch=16).sample(0))

    with engine.instrument() as fwd_ev:
        jax.eval_shape(lambda p: autoencoder.ae_forward(
            p, x, policy=prec.PAPER_FP16), params)
    with engine.instrument() as train_ev:
        jax.eval_shape(lambda p: jax.value_and_grad(
            lambda q: autoencoder.ae_loss(q, x, policy=prec.PAPER_FP16)[0]
        )(p), params)

    infer = engine.total_flops(fwd_ev)
    split = analysis.flops_by_direction(train_ev)
    assert split["fwd"] == infer
    assert split["bwd"] == 2 * infer        # dX + dW per layer
    assert engine.total_flops(train_ev) == 3 * infer
    # every affine layer contributes exactly (fwd, dX, dW)
    ops = [ev.spec.op for ev in train_ev]
    assert ops.count("linear") == ops.count("matmul_dx") \
        == ops.count("matmul_dw") == 10
