"""Per-architecture smoke tests (deliverable f).

Every assigned architecture instantiates its REDUCED config and runs:
  * a forward pass + CE loss          (shape + finiteness asserts)
  * one gradient step                 (finite grads)
  * prefill + 3 decode steps          (cache path)
on CPU.  The FULL configs are exercised only via the dry-run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer

RNG = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, rng, seq=S):
    toks = jax.random.randint(rng, (B, seq), 0, cfg.vocab_size, jnp.int32)
    if cfg.input_mode == "embeddings":
        emb = jax.random.normal(rng, (B, seq, cfg.d_model), jnp.float32)
        return {"embeddings": emb, "labels": toks}
    return {"inputs": toks, "labels": toks}


@pytest.fixture(scope="module", params=list(configs.ARCH_IDS))
def arch(request):
    return request.param


def test_forward_loss(arch):
    cfg = configs.get_reduced(arch)
    params = transformer.init_params(RNG, cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(lambda p, b: transformer.loss_fn(p, cfg, b))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert float(loss) > 0
    logits, cache, _ = jax.jit(
        lambda p, b: transformer.forward(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_grad_step(arch):
    cfg = configs.get_reduced(arch)
    params = transformer.init_params(RNG, cfg)
    batch = _batch(cfg, jax.random.PRNGKey(2))
    grads = jax.jit(jax.grad(lambda p: transformer.loss_fn(p, cfg, batch)[0]))(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat)
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in flat) ** 0.5
    assert gnorm > 0, f"{arch}: zero gradient"


def test_prefill_then_decode(arch):
    cfg = configs.get_reduced(arch)
    params = transformer.init_params(RNG, cfg)
    plen, total = 8, 16
    cache = transformer.init_cache(cfg, B, total)
    pb = _batch(cfg, jax.random.PRNGKey(3), seq=plen)
    pb.pop("labels")
    logits, cache, _ = jax.jit(
        lambda p, b, c: transformer.forward(p, cfg, b, cache=c, pos=0)
    )(params, pb, cache)
    step = jax.jit(lambda p, t, c, pos: transformer.serve_step(p, cfg, t, c, pos))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for i in range(3):
        lg, cache = step(params, tok, cache, jnp.int32(plen + i))
        assert lg.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(lg).all()), f"{arch}: non-finite decode logits"
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)


def test_decode_matches_prefill(arch):
    """Teacher-forced decode must reproduce the prefill logits (cache
    correctness): feed tokens one at a time and compare with full forward."""
    if arch == "hymba-1.5b":
        pytest.xfail("hymba combines per-chunk SSD with per-step decode: "
                     "equal only in exact arithmetic, checked loosely below")
    cfg = configs.get_reduced(arch)
    if cfg.input_mode == "embeddings":
        pytest.skip("embeddings-mode archs decode from tokens only")
    if cfg.moe:
        # capacity depends on the chunk length (C = f(S)); equality between
        # stepwise and full passes requires the no-drop regime
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = transformer.init_params(RNG, cfg)
    T = 8
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, T), 0, cfg.vocab_size)
    full_logits, _, _ = transformer.forward(params, cfg, {"inputs": toks})
    cache = transformer.init_cache(cfg, B, T)
    # prefill first token, then decode the rest step by step
    logits0, cache, _ = transformer.forward(
        params, cfg, {"inputs": toks[:, :1]}, cache=cache, pos=0)
    outs = [logits0[:, -1]]
    for i in range(1, T):
        lg, cache = transformer.serve_step(params, cfg, toks[:, i:i+1], cache, jnp.int32(i))
        outs.append(lg)
    stepwise = jnp.stack(outs, axis=1)
    diff = jnp.max(jnp.abs(stepwise.astype(jnp.float32)
                           - full_logits.astype(jnp.float32)))
    assert float(diff) < 0.15, f"{arch}: decode/prefill mismatch {float(diff)}"


def test_full_config_parameter_counts():
    """Full configs must be in the published parameter-count ballpark."""
    expect = {
        "yi-9b": (8.0e9, 10.0e9),
        "qwen3-1.7b": (1.5e9, 2.3e9),
        "mistral-nemo-12b": (11.0e9, 13.5e9),
        "command-r-35b": (31.0e9, 39.0e9),
        "deepseek-v2-lite-16b": (13.0e9, 17.5e9),
        "deepseek-moe-16b": (14.0e9, 18.5e9),
        "musicgen-medium": (1.2e9, 2.2e9),
        # the assignment pins 48L x d2048, which a faithful xLSTM block
        # arithmetic puts at ~2B (the published 1.3B uses a narrower stack)
        "xlstm-1.3b": (1.6e9, 2.4e9),
        "hymba-1.5b": (1.2e9, 2.0e9),
        "pixtral-12b": (11.0e9, 13.5e9),  # backbone only (ViT stubbed)
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_cells_assignment():
    """40 cells total; long_500k only for sub-quadratic families."""
    total = 0
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        cells = configs.cells(cfg)
        assert len(cells) == 4
        for spec, skip in cells:
            total += 1
            if spec.name == "long_500k":
                if cfg.family in ("ssm", "hybrid"):
                    assert skip is None
                else:
                    assert skip is not None
    assert total == 40
