"""CI ft-gates: elastic fault-tolerant training acceptance tests.

The contracts these pin (see docs/fault_tolerance.md):

* **kill-and-resume bit-identical** — a worker process hard-killed at
  step k (``os._exit``, no cleanup) and relaunched reaches a final params
  digest identical to an uninterrupted run, on the fp32 wire AND on the
  FP8-compressed wire (per-host error feedback and delayed-scale windows
  are checkpointed with an explicit host axis, so the wire's history
  survives the crash).
* **torn checkpoint write** — dying mid-save leaves a ``.tmp`` payload the
  atomic rename never published; resume lands on the previous complete
  checkpoint and still converges to the reference digest.
* **elastic resume** — a 4-process checkpoint continues on a 2-process
  mesh: the per-host compression state is regrouped (residuals summed —
  uncommunicated gradient mass conserved — scale stats take the group
  max) and training keeps descending.
* **collective bytes** — analytic wire bytes per gradient all-reduce are
  pinned exactly against benchmarks/baselines/collective_bytes.json with
  the strict ordering fp8 < fp16 < fp32.
* **goodput floor** — the injected-failure benchmark scenario's goodput
  (useful/wall across incarnations) stays above a pinned floor.
"""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINES = os.path.join(ROOT, "benchmarks", "baselines",
                         "collective_bytes.json")


def _worker(ckpt, *, steps=8, save_every=2, dp=2, compress="none",
            fail_step=None, fail_mode="die", result=None, extra=(),
            timeout=300):
    cmd = [sys.executable, "-m", "repro.runtime.elastic",
           "--ckpt", str(ckpt), "--steps", str(steps),
           "--save-every", str(save_every), "--dp", str(dp),
           "--compress", compress, "--log-every", "100"]
    if fail_step is not None:
        cmd += ["--fail-step", str(fail_step), "--fail-mode", fail_mode]
    if result is not None:
        cmd += ["--result", str(result)]
    cmd += list(extra)
    env = {**os.environ,
           "PYTHONPATH": os.path.join(ROOT, "src"),
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={dp}",
           "JAX_PLATFORMS": "cpu"}
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=timeout, cwd=ROOT)


def _result(path):
    with open(path) as f:
        return json.load(f)


@pytest.fixture(scope="module", params=["none", "fp8_e4m3"])
def reference_run(request, tmp_path_factory):
    """Uninterrupted 8-step reference digest, one per wire kind."""
    kind = request.param
    d = tmp_path_factory.mktemp(f"ref_{kind}")
    r = _worker(d / "ckpt", compress=kind, result=d / "out.json")
    assert r.returncode == 0, (r.stdout[-800:], r.stderr[-800:])
    return kind, _result(d / "out.json")


def test_kill_and_resume_bit_identical(reference_run, tmp_path):
    """Hard process death at step 5, relaunch, digest must match the
    uninterrupted run exactly."""
    kind, ref = reference_run
    r = _worker(tmp_path / "ckpt", compress=kind, fail_step=5,
                fail_mode="die")
    assert r.returncode == 13, (r.returncode, r.stderr[-800:])

    r2 = _worker(tmp_path / "ckpt", compress=kind,
                 result=tmp_path / "out.json")
    assert r2.returncode == 0, (r2.stdout[-800:], r2.stderr[-800:])
    assert "resumed from checkpoint" in r2.stdout
    out = _result(tmp_path / "out.json")
    assert out["digest"] == ref["digest"], (
        f"{kind}: resumed digest diverged from the uninterrupted run")
    g = out["goodput"]
    assert g["restarts"] == 1
    assert g["recomputed_steps"] >= 1  # died at 5, last checkpoint at 4


def test_torn_checkpoint_write_recovers(reference_run, tmp_path):
    """Dying *inside* the step-4 checkpoint write leaves only a torn .tmp;
    resume lands on step 2 and still reaches the reference digest."""
    kind, ref = reference_run
    r = _worker(tmp_path / "ckpt", compress=kind, fail_step=4,
                fail_mode="ckpt_crash")
    assert r.returncode == 13, (r.returncode, r.stderr[-800:])
    names = os.listdir(tmp_path / "ckpt")
    assert any(n.endswith(".tmp") for n in names), names
    assert "step_000004" not in names  # the torn write was never published

    r2 = _worker(tmp_path / "ckpt", compress=kind,
                 result=tmp_path / "out.json")
    assert r2.returncode == 0, (r2.stdout[-800:], r2.stderr[-800:])
    assert "resumed from checkpoint step 2" in r2.stdout
    assert _result(tmp_path / "out.json")["digest"] == ref["digest"]


def test_elastic_resume_4_to_2(tmp_path):
    """A dp=4 checkpoint continues on a dp=2 mesh: the per-host EF state
    is regrouped on attach and the loss keeps falling."""
    r4 = _worker(tmp_path / "ckpt", steps=4, dp=4, compress="fp8_e4m3",
                 result=tmp_path / "out4.json")
    assert r4.returncode == 0, (r4.stdout[-800:], r4.stderr[-800:])
    out4 = _result(tmp_path / "out4.json")

    r2 = _worker(tmp_path / "ckpt", steps=8, dp=2, compress="fp8_e4m3",
                 result=tmp_path / "out2.json")
    assert r2.returncode == 0, (r2.stdout[-800:], r2.stderr[-800:])
    assert "elastic attach: regrouping" in r2.stdout
    assert "resumed from checkpoint step 4" in r2.stdout
    out2 = _result(tmp_path / "out2.json")
    assert out2["dp"] == 2 and out2["last_step"] == 7
    assert out2["loss"] < out4["loss"]


def _lm_worker(ckpt, *, steps=6, save_every=2, dp=2, fail_step=None,
               result=None, timeout=420):
    """launch/train.py compressed-DP LM path (vs elastic's toy MLP)."""
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen3-1.7b", "--reduced", "--steps", str(steps),
           "--batch", "4", "--seq", "16", "--compress", "fp8_e4m3",
           "--dp-procs", str(dp), "--ckpt-dir", str(ckpt),
           "--save-every", str(save_every), "--seed", "0"]
    if fail_step is not None:
        cmd += ["--fail-step", str(fail_step), "--fail-mode", "die"]
    if result is not None:
        cmd += ["--result", str(result)]
    env = {**os.environ,
           "PYTHONPATH": os.path.join(ROOT, "src"),
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={dp}",
           "JAX_PLATFORMS": "cpu"}
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=timeout, cwd=ROOT)


def test_lm_compressed_dp_kill_resume_bit_identical(tmp_path):
    """The LM training CLI (launch/train.py --compress fp8_e4m3
    --ckpt-dir) carries the per-host EF axis and the pinned canonical
    placement, so a worker hard-killed mid-run resumes to params, EF,
    and optimizer digests identical to an uninterrupted run."""
    ref = _lm_worker(tmp_path / "ref", result=tmp_path / "ref.json")
    assert ref.returncode == 0, (ref.stdout[-800:], ref.stderr[-800:])
    want = _result(tmp_path / "ref.json")

    r = _lm_worker(tmp_path / "ckpt", fail_step=5)
    assert r.returncode == 13, (r.returncode, r.stderr[-800:])

    r2 = _lm_worker(tmp_path / "ckpt", result=tmp_path / "out.json")
    assert r2.returncode == 0, (r2.stdout[-800:], r2.stderr[-800:])
    assert "resumed from checkpoint" in r2.stdout
    out = _result(tmp_path / "out.json")
    assert out["digest"] == want["digest"], "params diverged after resume"
    assert out["ef_digest"] == want["ef_digest"], \
        "per-host error-feedback state diverged after resume"
    assert out["opt_digest"] == want["opt_digest"], \
        "optimizer moments diverged after resume"
    assert out["loss"] == want["loss"]


# ------------------------------------------------------------------ #
# Wire bytes + goodput gates (in-process)
# ------------------------------------------------------------------ #
def test_collective_bytes_pinned_and_ordered():
    from repro import configs
    from repro.models import transformer
    from repro.optim import collective_wire_bytes

    with open(BASELINES) as f:
        base = json.load(f)
    params = transformer.abstract_params(configs.get_reduced(base["arch"]))
    got = {
        "fp32": collective_wire_bytes("none", params),
        "fp16": collective_wire_bytes("fp16", params),
        "int8": collective_wire_bytes("int8", params),
        "fp8_e4m3": collective_wire_bytes("fp8_e4m3", params),
        "fp8_e5m2": collective_wire_bytes("fp8_e5m2", params),
    }
    assert got == base["collective_bytes"], (got, base["collective_bytes"])
    assert got["fp8_e4m3"] < got["fp16"] < got["fp32"]
    assert got["fp8_e5m2"] < got["fp16"] < got["fp32"]


def test_bench_rows_and_goodput_floor():
    """The ft_goodput benchmark module emits the ft/* rows ft-gates ships
    into BENCH_engine.json, with bytes matching the baseline and the
    injected-failure goodput above the pinned floor."""
    from benchmarks import ft_goodput

    with open(BASELINES) as f:
        base = json.load(f)
    rows = {name: (us, derived) for name, us, derived in ft_goodput.run()}
    for kind, want in base["collective_bytes"].items():
        assert rows[f"ft/collective_bytes_{kind}"][1] == str(want)
    us, derived = rows["ft/goodput_injected"]
    fields = dict(kv.split("=") for kv in derived.split())
    assert float(fields["goodput"]) > base["goodput_floor_injected"], derived
    assert int(fields["restarts"]) == 1
    assert int(fields["recomputed"]) >= 1
