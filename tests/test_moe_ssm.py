"""MoE dispatch/combine and SSM chunked-engine correctness vs naive refs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import precision as prec
from repro.models import moe, ssm
from repro.models.layers import init_tree


# ------------------------------------------------------------------ #
# MoE: sort-based capacity dispatch == naive per-token mixture
# ------------------------------------------------------------------ #
def _moe_cfg(capacity_factor=64.0):
    cfg = configs.get_reduced("deepseek-moe-16b")
    return dataclasses.replace(
        cfg, policy_name="fp32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=capacity_factor,
                                n_shared=0))


def _naive_moe(params, x, cfg):
    """Per-token dense mixture over top-k experts (no capacity)."""
    B, S, d = x.shape
    logits = x.reshape(-1, d) @ params["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate, ids = jax.lax.top_k(probs, cfg.moe.top_k)
    if cfg.moe.norm_topk_prob:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    w_in, w_out = params["w_in"], params["w_out"]
    outs = []
    for t in range(B * S):
        acc = jnp.zeros((d,), jnp.float32)
        for j in range(cfg.moe.top_k):
            e = int(ids[t, j])
            h = x.reshape(-1, d)[t] @ w_in[e]
            g_, u_ = jnp.split(h, 2)
            h = jax.nn.silu(g_) * u_
            acc = acc + gate[t, j] * (h @ w_out[e]).astype(jnp.float32)
        outs.append(acc)
    return jnp.stack(outs).reshape(B, S, d)


def test_moe_matches_naive_when_capacity_unbounded():
    cfg = _moe_cfg(capacity_factor=64.0)  # nothing dropped
    rng = jax.random.PRNGKey(0)
    params = init_tree(rng, moe.moe_schema(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, metrics = moe.moe_forward(params, x, cfg, policy=prec.FP32)
    y_ref = _naive_moe(params, x, cfg)
    assert float(metrics["moe_drop_frac"]) == 0.0
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(capacity_factor=0.1)
    params = init_tree(jax.random.PRNGKey(0), moe.moe_schema(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    _, metrics = moe.moe_forward(params, x, cfg, policy=prec.FP32)
    assert float(metrics["moe_drop_frac"]) > 0.2
    assert float(metrics["moe_aux_loss"]) > 0.0


def test_moe_aux_loss_balanced_router_is_minimal():
    """A perfectly uniform router gives aux loss == 1 (the E * (1/E * 1/E) * E
    identity); a collapsed router gives > 1."""
    cfg = _moe_cfg()
    E, k = cfg.moe.n_routed, cfg.moe.top_k
    params = init_tree(jax.random.PRNGKey(0), moe.moe_schema(cfg))
    # uniform logits -> top_k ties broken by index, but mean_prob uniform
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    _, m_uniform = moe.moe_forward(params, x, cfg, policy=prec.FP32)
    # collapsed: all mass on expert 0
    params["router"] = params["router"].at[:, 0].set(100.0)
    _, m_collapsed = moe.moe_forward(params, x, cfg, policy=prec.FP32)
    assert float(m_collapsed["moe_aux_loss"]) > float(m_uniform["moe_aux_loss"])


# ------------------------------------------------------------------ #
# SSM engine: chunked form == exact recurrence
# ------------------------------------------------------------------ #
def _naive_linear_attention(q, k, v, log_g):
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    state = np.zeros((B, H, dk, dv), np.float32)
    outs = np.zeros((B, H, S, dv), np.float32)
    qf, kf, vf = (np.asarray(t, np.float32) for t in (q, k, v))
    gf = np.asarray(log_g, np.float32)
    for t in range(S):
        state = np.exp(gf[:, :, t])[..., None, None] * state + np.einsum(
            "bhk,bhv->bhkv", kf[:, :, t], vf[:, :, t])
        outs[:, :, t] = np.einsum("bhk,bhkv->bhv", qf[:, :, t], state)
    return outs, state


@pytest.mark.parametrize("chunk", [4, 16, 64])
@pytest.mark.parametrize("seq", [32, 50])
def test_chunked_linear_attention_matches_recurrence(chunk, seq):
    rng = np.random.default_rng(0)
    B, H, dk, dv = 2, 3, 8, 16
    q = jnp.asarray(rng.normal(size=(B, H, seq, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, seq, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, seq, dv)), jnp.float32)
    log_g = jnp.asarray(-np.abs(rng.normal(size=(B, H, seq))) * 0.2, jnp.float32)
    out, state = ssm.chunked_linear_attention(q, k, v, log_g, chunk=chunk)
    out_ref, state_ref = _naive_linear_attention(q, k, v, log_g)
    np.testing.assert_allclose(np.asarray(out), out_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), state_ref, rtol=2e-4, atol=2e-4)


def test_decode_step_continues_chunked_state():
    rng = np.random.default_rng(1)
    B, H, S, dk, dv = 1, 2, 16, 4, 8
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    q, k, v = mk(B, H, S, dk), mk(B, H, S, dk), mk(B, H, S, dv)
    log_g = jnp.asarray(-np.abs(rng.normal(size=(B, H, S))) * 0.1, jnp.float32)
    # full sequence in one chunked call
    out_full, state_full = ssm.chunked_linear_attention(q, k, v, log_g, chunk=8)
    # prefix chunked + last step via decode
    out_pre, state_pre = ssm.chunked_linear_attention(
        q[:, :, :-1], k[:, :, :-1], v[:, :, :-1], log_g[:, :, :-1], chunk=8)
    out_last, state_last = ssm.linear_attention_step(
        state_pre, q[:, :, -1], k[:, :, -1], v[:, :, -1], log_g[:, :, -1])
    np.testing.assert_allclose(np.asarray(out_last),
                               np.asarray(out_full[:, :, -1]), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state_last), np.asarray(state_full),
                               rtol=1e-4, atol=1e-5)


def test_slstm_stability_long_sequence():
    """Exp-gating with the stabilizer must stay finite over long scans."""
    cfg = configs.get_reduced("xlstm-1.3b")
    params = init_tree(jax.random.PRNGKey(0), ssm.slstm_schema(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 256, cfg.d_model)) * 5.0
    y, state = ssm.slstm_block(params, x.astype(jnp.float32), cfg,
                               policy=prec.FP32)
    assert bool(jnp.isfinite(y).all())
    assert bool(jnp.isfinite(state["c"]).all())
    assert bool(jnp.isfinite(state["m"]).all())


def test_mamba_mixer_state_decode_consistency():
    cfg = configs.get_reduced("hymba-1.5b")
    params = init_tree(jax.random.PRNGKey(0), ssm.mamba_schema(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 17, cfg.d_model),
                          jnp.float32)
    cfgf = dataclasses.replace(cfg, policy_name="fp32")
    y_full, st_full = ssm.mamba_mixer(params, x, cfgf, policy=prec.FP32)
    # replay: prefix then one decode step
    y_pre, st_pre = ssm.mamba_mixer(params, x[:, :-1], cfgf, policy=prec.FP32)
    y_last, st_last = ssm.mamba_mixer(params, x[:, -1:], cfgf,
                                      policy=prec.FP32, state=st_pre)
    np.testing.assert_allclose(np.asarray(y_last[0, 0]),
                               np.asarray(y_full[0, -1]), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_last), np.asarray(st_full),
                               rtol=2e-3, atol=2e-3)


def test_engine_pallas_backend_matches_xla():
    """The engine's pallas backend (interpret) == xla path."""
    rng = np.random.default_rng(3)
    B, H, S, dk, dv = 2, 2, 128, 16, 32
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    q, k, v = mk(B, H, S, dk), mk(B, H, S, dk), mk(B, H, S, dv)
    g = jnp.asarray(-np.abs(rng.normal(size=(B, H, S))) * 0.1, jnp.float32)
    o_x, s_x = ssm.chunked_linear_attention(q, k, v, g, chunk=32, backend="xla")
    o_p, s_p = ssm.chunked_linear_attention(q, k, v, g, chunk=32,
                                            backend="interpret")
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_x),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_x),
                               rtol=1e-5, atol=1e-5)


def test_engine_pallas_backend_fallbacks():
    """Kernel backend falls back to xla when an initial state is carried or
    the sequence is not chunk-aligned (decode prefixes)."""
    rng = np.random.default_rng(5)
    B, H, S, dk, dv = 1, 2, 30, 8, 8  # 30 % 16 != 0 -> fallback
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    q, k, v = mk(B, H, S, dk), mk(B, H, S, dk), mk(B, H, S, dv)
    g = jnp.asarray(-np.abs(rng.normal(size=(B, H, S))) * 0.1, jnp.float32)
    o1, s1 = ssm.chunked_linear_attention(q, k, v, g, chunk=16,
                                          backend="interpret")
    o2, s2 = ssm.chunked_linear_attention(q, k, v, g, chunk=16, backend="xla")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)
    # with carried state -> also fallback, must match continuing the xla path
    st0 = jnp.asarray(rng.normal(size=(B, H, dk, dv)), jnp.float32)
    o3, _ = ssm.chunked_linear_attention(q, k, v, g, chunk=16,
                                         backend="interpret", state=st0)
    o4, _ = ssm.chunked_linear_attention(q, k, v, g, chunk=16,
                                         backend="xla", state=st0)
    np.testing.assert_allclose(np.asarray(o3), np.asarray(o4),
                               rtol=1e-5, atol=1e-5)
