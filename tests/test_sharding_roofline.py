"""Sharding rules, spec sanitization, and the roofline HLO parser."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.roofline import analysis as A
from repro.runtime import compat, sharding


def _mesh(shape=(1, 1), axes=("data", "model")):
    return compat.make_mesh(shape, axes)


def _abstract_mesh(shape=(2, 2), axes=("data", "model")):
    """Shape-only mesh stand-in (tests run on 1 CPU device)."""
    return compat.abstract_mesh(shape, axes)


# ------------------------------------------------------------------ #
# Logical axis rules
# ------------------------------------------------------------------ #
def test_logical_spec_basic():
    rules = sharding.Rules()
    spec = sharding.logical_spec(("batch", None, "ff"), rules)
    assert spec == P(("pod", "data"), None, "model")


def test_logical_spec_no_axis_reuse():
    """Two logical axes can't claim the same mesh axis in one spec."""
    rules = sharding.Rules()
    spec = sharding.logical_spec(("heads", "ff"), rules)
    assert spec == P("model", None)


def test_fsdp_shards_embed_axis():
    spec = sharding.logical_spec(("embed", "ff"), sharding.Rules(fsdp=True))
    assert spec == P(("pod", "data"), "model")
    spec = sharding.logical_spec(("embed", "ff"), sharding.Rules(fsdp=False))
    assert spec == P(None, "model")


def test_overrides_win():
    rules = sharding.Rules(overrides=(("kv_seq", ("model",)),))
    assert sharding.logical_spec(("kv_seq",), rules) == P("model")


def test_sanitize_drops_indivisible_and_unknown_axes():
    mesh = _abstract_mesh((2, 2))
    # 'pod' unknown on this mesh -> filtered; 5 not divisible by 2 -> dropped
    spec = P(("pod", "data"), "model")
    out = sharding.sanitize_spec(spec, (4, 5), mesh)
    assert out == P("data")
    out2 = sharding.sanitize_spec(P("model"), (6,), mesh)
    assert out2 == P("model")


def test_constrain_noop_outside_rules():
    x = jnp.ones((4, 4))
    assert sharding.constrain(x, "batch", None) is x


def test_constrain_inside_jit_applies():
    mesh = _mesh((1, 1))
    rules = sharding.Rules()

    def f(x):
        with sharding.use_rules(rules):
            return sharding.constrain(x * 1.0, "batch", "ff")

    with compat.set_mesh(mesh):
        txt = jax.jit(f).lower(jax.ShapeDtypeStruct((4, 4), jnp.float32)).as_text()
    assert "sharding" in txt.lower()


def test_constrain_fb_grad_path():
    """constrain_fb must be transparent to values and gradients."""
    x = jnp.arange(8.0)
    mesh = _mesh((1, 1))
    rules = sharding.Rules()

    def f(v):
        with sharding.use_rules(rules):
            y = sharding.constrain_fb(v * 2.0, ("batch",), (None,))
            return jnp.sum(y ** 2)

    with compat.set_mesh(mesh):
        g = jax.jit(jax.grad(f))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(8.0 * x))


# ------------------------------------------------------------------ #
# Roofline HLO parsing
# ------------------------------------------------------------------ #
SYNTH_HLO = """
HloModule jit_step

%wide.body (p: (s32[], f32[16,512])) -> (s32[], f32[16,512]) {
  %p = (s32[], f32[16,512]) parameter(0)
  %ar = f32[16,512]{1,0} all-reduce(%gte), channel_id=1, replica_groups=[4,16]<=[64], to_apply=%add
  ROOT %t = (s32[], f32[16,512]) tuple(%c, %ar)
}

%wide.cond (p: (s32[], f32[16,512])) -> pred[] {
  %p = (s32[], f32[16,512]) parameter(0)
  ROOT %cmp = pred[] compare(%a, %b), direction=LT
}

ENTRY %main (x: f32[16,512]) -> f32[16,512] {
  %x = f32[16,512] parameter(0)
  %ag = f32[64,512]{1,0} all-gather(%x), channel_id=2, replica_groups=[16,4]<=[64], dimensions={0}
  %w = (s32[], f32[16,512]) while(%init), condition=%wide.cond, body=%wide.body, backend_config={"known_trip_count":{"n":"28"}}
  %rs = f32[4,512]{1,0} reduce-scatter(%ag2), channel_id=3, replica_groups=[16,4]<=[64], dimensions={0}
  %cp = f32[16,512]{1,0} collective-permute(%x), channel_id=4, source_target_pairs={{0,1}}
  ROOT %out = f32[16,512] add(%a, %b)
}
"""


def test_parse_collectives_kinds_groups_trips():
    ops = A.parse_collectives(SYNTH_HLO)
    by_kind = {o.kind: o for o in ops}
    ar = by_kind["all-reduce"]
    assert ar.group_size == 16
    assert ar.multiplier == 28           # inside the while body
    assert ar.result_bytes == 16 * 512 * 4
    ag = by_kind["all-gather"]
    assert ag.group_size == 4 and ag.multiplier == 1
    rs = by_kind["reduce-scatter"]
    assert rs.result_bytes == 4 * 512 * 4
    cp = by_kind["collective-permute"]
    assert cp.wire_bytes == 16 * 512 * 4


def test_ring_cost_model():
    op = A.CollectiveOp("all-reduce", result_bytes=1000, group_size=4,
                        computation="x")
    assert op.wire_bytes == 2 * 1000 * 3 / 4
    op = A.CollectiveOp("all-gather", result_bytes=1000, group_size=4,
                        computation="x")
    assert op.wire_bytes == 1000 * 3 / 4
    op = A.CollectiveOp("reduce-scatter", result_bytes=250, group_size=4,
                        computation="x")
    assert op.wire_bytes == 250 * 3
    op = A.CollectiveOp("all-reduce", result_bytes=1000, group_size=1,
                        computation="x")
    assert op.wire_bytes == 0.0


def test_collective_parser_on_real_module():
    """Compile a sharded matmul+psum step (in a 2-device subprocess — the
    test env itself sees 1 device) and check the parser finds the
    all-reduce."""
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline import analysis as A
from repro.runtime import compat
mesh = compat.make_mesh((1, 2), ("data", "model"))
def f(x, w):
    return jnp.sum((x @ w).astype(jnp.float32))
with compat.set_mesh(mesh):
    c = jax.jit(f,
        in_shardings=(NamedSharding(mesh, P(None, None)),
                      NamedSharding(mesh, P(None, "model"))),
        out_shardings=NamedSharding(mesh, P())).lower(
        jax.ShapeDtypeStruct((8, 16), jnp.float32),
        jax.ShapeDtypeStruct((16, 32), jnp.float32)).compile()
ops = A.parse_collectives(c.as_text())
ars = [o for o in ops if o.kind == "all-reduce"]
assert ars, "expected an all-reduce"
assert all(o.group_size == 2 for o in ars)
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={**__import__("os").environ,
                                          "PYTHONPATH": "src"})
    assert "OK" in out.stdout, out.stderr[-2000:]


def test_structural_costs_count_dot_flops():
    mesh = _mesh((1, 1))
    from jax.sharding import NamedSharding

    M, N, K = 64, 128, 32

    def f(x, w):
        return x @ w

    with compat.set_mesh(mesh):
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((M, N), jnp.float32),
            jax.ShapeDtypeStruct((N, K), jnp.float32)).compile()
        flops, byts = A.structural_costs(c.as_text())
    assert abs(flops - 2 * M * N * K) / (2 * M * N * K) < 0.05
    io = 4 * (M * N + N * K + M * K)
    assert byts >= io  # at least the operand+result traffic


def test_structural_costs_scan_trip_multiplier():
    """A scanned matmul must count layers x body flops."""
    mesh = _mesh((1, 1))
    L, D = 7, 32

    def f(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), 0
        h, _ = jax.lax.scan(body, x, ws)
        return h

    with compat.set_mesh(mesh):
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((L, D, D), jnp.float32),
            jax.ShapeDtypeStruct((D, D), jnp.float32)).compile()
        flops, _ = A.structural_costs(c.as_text())
    expect = L * 2 * D * D * D
    assert abs(flops - expect) / expect < 0.1


def test_model_flops_conventions():
    from repro import configs
    from repro.roofline.analysis import model_flops

    cfg = configs.get("yi-9b")
    tr = model_flops(cfg, configs.SHAPES["train_4k"])
    pf = model_flops(cfg, configs.SHAPES["prefill_32k"])
    dc = model_flops(cfg, configs.SHAPES["decode_32k"])
    n = cfg.param_count() - cfg.vocab_size * cfg.d_model
    assert tr == pytest.approx(6.0 * n * 256 * 4096)
    assert pf == pytest.approx(2.0 * n * 32 * 32768)
    assert dc == pytest.approx(2.0 * n * 128)
    # MoE uses active params only
    ds = configs.get("deepseek-v2-lite-16b")
    assert ds.active_param_count() < 0.4 * ds.param_count()
