"""Engine API: backend registry/resolution, grouped GEMM, instrumentation.

Covers the redesign's contract:
  * resolution precedence (explicit arg > use_backend context > env var >
    platform default) and thread-locality of the context;
  * REPRO_MATMUL_BACKEND validated at read time with a helpful error;
  * runtime-registered backends dispatch by name with no core edits;
  * grouped_matmul == per-expert loop oracle (PAPER_FP16 / TPU_BF16),
    dense and ragged;
  * linear's fused bias+activation epilogue;
  * einsum2d == jnp.einsum for the contraction family the models use;
  * instrument(): a transformer forward's summed GemmEvent flops match the
    perf model's analytic enumeration to within 1%;
  * fused-vs-unfused epilogue equivalence for every registered epilogue
    and precision policy (the "fused_epilogue" capability contract);
  * tile resolution (explicit > autotune cache > heuristic) and the
    resolved tile riding on GemmEvents;
  * the PR-1 deprecation shims (repro.core.redmule, repro.core.matmul /
    linear re-exports) are gone now the one-release window has lapsed.
"""

import contextlib
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import engine, perf_model
from repro.core import precision as prec

RNG = np.random.default_rng(0)


def _rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ------------------------------------------------------------------ #
# Backend resolution
# ------------------------------------------------------------------ #
def test_platform_default_backend(monkeypatch):
    monkeypatch.delenv(engine.ENV_VAR, raising=False)
    want = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert engine.default_backend() == want


def test_env_var_beats_platform_default(monkeypatch):
    monkeypatch.setenv(engine.ENV_VAR, "interpret")
    assert engine.default_backend() == "interpret"


def test_context_beats_env_var(monkeypatch):
    monkeypatch.setenv(engine.ENV_VAR, "interpret")
    with engine.use_backend("xla"):
        assert engine.default_backend() == "xla"
    assert engine.default_backend() == "interpret"


def test_explicit_arg_beats_context():
    seen = []

    def recorder(x, w, *, spec):
        seen.append(spec)
        return jnp.zeros((*x.shape[:-1], w.shape[-1]), jnp.float32)

    engine.register_backend("recorder", recorder)
    try:
        with engine.use_backend("xla"):
            engine.matmul(_rand((4, 8)), _rand((8, 4)), backend="recorder")
    finally:
        engine.unregister_backend("recorder")
    assert len(seen) == 1 and seen[0].op == "matmul"


def test_invalid_env_var_names_source_and_backends(monkeypatch):
    monkeypatch.setenv(engine.ENV_VAR, "not-a-backend")
    with pytest.raises(ValueError) as ei:
        engine.default_backend()
    msg = str(ei.value)
    assert "REPRO_MATMUL_BACKEND" in msg and "xla" in msg


def test_invalid_explicit_backend_lists_registry():
    with pytest.raises(ValueError, match="registered"):
        engine.matmul(_rand((4, 8)), _rand((8, 4)), backend="nope")


def test_set_default_backend_validates():
    with pytest.raises(ValueError):
        engine.set_default_backend("nope")
    engine.set_default_backend(None)  # clearing is always allowed


def test_use_backend_is_thread_local(monkeypatch):
    monkeypatch.delenv(engine.ENV_VAR, raising=False)
    base = engine.default_backend()
    results = {}

    def child():
        results["before"] = engine.default_backend()
        with engine.use_backend("interpret"):
            results["inside"] = engine.default_backend()
        results["after"] = engine.default_backend()

    with engine.use_backend("xla"):
        t = threading.Thread(target=child)
        t.start()
        t.join()
        assert engine.default_backend() == "xla"
    # the child never saw the parent's context, and its own context
    # neither leaked out nor persisted
    assert results == {"before": base, "inside": "interpret", "after": base}
    assert engine.default_backend() == base


# ------------------------------------------------------------------ #
# Runtime-pluggable backends (no edits to core/engine.py)
# ------------------------------------------------------------------ #
def test_runtime_registered_backend_dispatches_by_name():
    xla_fn = engine.get_backend("xla").fn
    calls = []

    def dummy(x, w, *, spec):
        calls.append((spec.op, spec.m, spec.n, spec.k))
        return xla_fn(x, w, spec=spec)

    engine.register_backend("dummy-xla", dummy, description="test-only")
    try:
        assert "dummy-xla" in engine.registered_backends()
        x, w = _rand((8, 16)), _rand((16, 8))
        z = engine.matmul(x, w, policy=prec.TPU_BF16, backend="dummy-xla")
        z_ref = engine.matmul(x, w, policy=prec.TPU_BF16, backend="xla")
        np.testing.assert_allclose(np.asarray(z, np.float32),
                                   np.asarray(z_ref, np.float32))
        # the same name also resolves through the context path
        with engine.use_backend("dummy-xla"):
            engine.linear(x, w, policy=prec.FP32)
    finally:
        engine.unregister_backend("dummy-xla")
    assert calls == [("matmul", 8, 16, 8), ("linear", 8, 16, 8)]
    assert "dummy-xla" not in engine.registered_backends()


def test_unavailable_backend_rejected_when_implicit():
    engine.register_backend("never", lambda x, w, *, spec: x,
                            available=False)
    try:
        with engine.use_backend("never"):
            with pytest.raises(ValueError, match="not available"):
                engine.matmul(_rand((4, 4)), _rand((4, 4)))
        # explicit selection is the escape hatch (caller takes the risk) —
        # both per-call and pinned on an Engine instance
        z = engine.matmul(_rand((2, 2)), _rand((2, 2)), backend="never")
        assert z.shape == (2, 2)
        pinned = engine.Engine(backend="never")
        assert pinned.matmul(_rand((2, 2)), _rand((2, 2))).shape == (2, 2)
    finally:
        engine.unregister_backend("never")


# ------------------------------------------------------------------ #
# grouped_matmul vs the per-expert loop oracle
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("policy", [prec.PAPER_FP16, prec.TPU_BF16],
                         ids=lambda p: p.name)
def test_grouped_matmul_matches_per_expert_loop(policy):
    G, M, N, K = 4, 16, 48, 24
    x = _rand((G, M, N), policy.compute_dtype)
    w = _rand((G, N, K), policy.compute_dtype)
    z = engine.grouped_matmul(x, w, policy=policy, backend="interpret")
    z_loop = jnp.stack([
        engine.matmul(x[g], w[g], policy=policy, backend="interpret")
        for g in range(G)
    ])
    assert z.dtype == policy.out_dtype
    np.testing.assert_allclose(np.asarray(z, np.float32),
                               np.asarray(z_loop, np.float32),
                               rtol=2e-3, atol=2e-2)


@pytest.mark.parametrize("policy", [prec.PAPER_FP16, prec.TPU_BF16],
                         ids=lambda p: p.name)
def test_grouped_matmul_ragged_matches_loop(policy):
    G, M, N, K = 3, 8, 32, 16
    sizes = jnp.asarray([5, 0, 8])
    x = _rand((G, M, N), policy.compute_dtype)
    w = _rand((G, N, K), policy.compute_dtype)
    z = engine.grouped_matmul(x, w, group_sizes=sizes, policy=policy,
                              backend="xla")
    zf = np.asarray(z, np.float32)
    for g in range(G):
        s = int(sizes[g])
        ref = engine.matmul(x[g, :s], w[g], policy=policy, backend="xla")
        np.testing.assert_allclose(zf[g, :s], np.asarray(ref, np.float32),
                                   rtol=2e-3, atol=2e-2)
        assert np.all(zf[g, s:] == 0.0)  # rows beyond the group size


def test_grouped_matmul_with_leading_batch():
    B, G, M, N, K = 2, 3, 4, 8, 5
    x = _rand((B, G, M, N))
    w = _rand((G, N, K))
    z = engine.grouped_matmul(x, w, policy=prec.FP32)
    ref = jnp.einsum("bgmn,gnk->bgmk", x, w)
    np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ #
# linear: fused epilogue (the "fused_epilogue" capability contract)
# ------------------------------------------------------------------ #
def test_linear_fused_bias_activation():
    x, w = _rand((8, 16)), _rand((16, 8))
    b = _rand((8,))
    z = engine.linear(x, w, b, activation="relu", policy=prec.FP32)
    ref = jax.nn.relu(jnp.dot(x, w) + b)
    np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="epilogue"):
        engine.linear(x, w, activation="not-an-act")
    with pytest.raises(ValueError, match="bias"):
        engine.linear(x, w, _rand((4,)))


def test_backend_capability_flags():
    for name in ("pallas", "interpret"):
        assert engine.backend_supports(name, "fused_epilogue")
        assert engine.backend_supports(name, "tiled")
    assert not engine.backend_supports("xla", "fused_epilogue")
    with pytest.raises(ValueError, match="capabilities"):
        engine.register_backend("bad-caps", lambda x, w, *, spec: x,
                                capabilities=("warp_drive",))


@contextlib.contextmanager
def _unfused_interpret():
    """The same Pallas kernel, registered WITHOUT the fused_epilogue
    capability — forces the engine's post-op fallback path."""
    fn = engine.get_backend("interpret").fn

    def plain(x, w, *, spec):
        return fn(x, w, spec=spec)   # never receives bias/fuse_epilogue

    engine.register_backend("interpret-unfused", plain,
                            capabilities=("tiled",))
    try:
        yield "interpret-unfused"
    finally:
        engine.unregister_backend("interpret-unfused")


@pytest.mark.parametrize("policy", [prec.PAPER_FP16, prec.TPU_BF16],
                         ids=lambda p: p.name)
@pytest.mark.parametrize("act", [None, "relu", "gelu", "silu", "tanh"])
def test_linear_fused_matches_unfused_every_epilogue(policy, act):
    """Acceptance: in-kernel epilogue == post-op epilogue on the same
    kernel, for every registered epilogue and both precision policies.

    Documented tolerance (see linear's docstring): under paper_fp16
    (accum dtype == out dtype) bias-only and relu are *bitwise* identical;
    transcendental epilogues (gelu/silu/tanh) may differ by ~2 ulp because
    XLA rounds fp16 transcendentals differently inside a compiled kernel
    than in the eager post-op pass (jax.jit(gelu) vs gelu shows the same
    delta with no Pallas involved).  Under fp32-accum policies the fused
    path additionally applies the epilogue before the out-dtype rounding,
    so agreement is to ~2 ulp of the output dtype."""
    rng = np.random.default_rng(hash((policy.name, act)) % 2**32)
    x = jnp.asarray(rng.normal(size=(33, 70)), policy.compute_dtype)
    w = jnp.asarray(rng.normal(size=(70, 40)), policy.compute_dtype)
    b = jnp.asarray(rng.normal(size=(40,)), policy.compute_dtype)
    z_fused = engine.linear(x, w, b, activation=act, policy=policy,
                            backend="interpret")
    with _unfused_interpret() as unfused:
        z_post = engine.linear(x, w, b, activation=act, policy=policy,
                               backend=unfused)
    assert z_fused.dtype == policy.out_dtype == z_post.dtype
    zf = np.asarray(z_fused, np.float32)
    zp = np.asarray(z_post, np.float32)
    exact = (policy.accum_dtype == policy.out_dtype
             and act in (None, "relu"))
    if exact:
        np.testing.assert_array_equal(zf, zp)     # bitwise
    else:
        eps = {"float16": 1e-3, "bfloat16": 8e-3}[
            jnp.dtype(policy.out_dtype).name]
        denom = max(np.abs(zp).max(), 1.0)
        assert np.max(np.abs(zf - zp)) / denom < 2 * eps


@pytest.mark.parametrize("policy", [prec.PAPER_FP16, prec.TPU_BF16],
                         ids=lambda p: p.name)
def test_linear_fused_matches_xla_reference(policy):
    """Cross-backend: the fused kernel tracks the xla post-op path within
    the policies' accumulation tolerance (different accumulators)."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(16, 64)), policy.compute_dtype)
    w = jnp.asarray(rng.normal(size=(64, 24)), policy.compute_dtype)
    b = jnp.asarray(rng.normal(size=(24,)), policy.compute_dtype)
    zi = engine.linear(x, w, b, activation="gelu", policy=policy,
                       backend="interpret")
    zx = engine.linear(x, w, b, activation="gelu", policy=policy,
                       backend="xla")
    np.testing.assert_allclose(np.asarray(zi, np.float32),
                               np.asarray(zx, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("policy", [prec.PAPER_FP16, prec.TPU_BF16],
                         ids=lambda p: p.name)
def test_grouped_matmul_ragged_fused_backend_matches_loop(policy):
    """grouped_matmul with ragged group_sizes on the Pallas (interpret)
    backend — exercising the batched-grid kernel — matches the per-group
    loop and zeroes rows beyond each group's size."""
    G, M, N, K = 3, 8, 32, 16
    sizes = jnp.asarray([5, 0, 8])
    x = _rand((G, M, N), policy.compute_dtype)
    w = _rand((G, N, K), policy.compute_dtype)
    z = engine.grouped_matmul(x, w, group_sizes=sizes, policy=policy,
                              backend="interpret")
    zf = np.asarray(z, np.float32)
    for g in range(G):
        s = int(sizes[g])
        ref = engine.matmul(x[g, :s], w[g], policy=policy,
                            backend="interpret")
        np.testing.assert_allclose(zf[g, :s], np.asarray(ref, np.float32),
                                   rtol=2e-3, atol=2e-2)
        assert np.all(zf[g, s:] == 0.0)


# ------------------------------------------------------------------ #
# einsum2d
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("eq,xs,ws", [
    ("mn,nk->mk", (6, 5), (5, 4)),
    ("bij,bjk->bik", (2, 6, 5), (2, 5, 4)),
    ("ms,ns->mn", (6, 5), (4, 5)),          # transposed weight
    ("bhsd,rhd->bhsr", (2, 3, 5, 7), (4, 3, 7)),   # MLA absorbed q
    ("bhsr,btr->bhst", (2, 3, 5, 7), (2, 6, 7)),   # MLA absorbed scores
    ("bhik,bhjk->bhij", (2, 3, 5, 7), (2, 3, 6, 7)),  # SSM intra-chunk
    ("abc,cd->abd", (2, 3, 4), (4, 5)),
], ids=lambda v: v if isinstance(v, str) else str(v))
def test_einsum2d_matches_jnp_einsum(eq, xs, ws):
    x, w = _rand(xs), _rand(ws)
    z = engine.einsum2d(eq, x, w, policy=prec.FP32)
    ref = jnp.einsum(eq, x, w)
    assert z.shape == ref.shape
    np.testing.assert_allclose(np.asarray(z), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_einsum2d_rejects_bad_equations():
    x, w = _rand((4, 4)), _rand((4, 4))
    for eq in ("mn,nk", "mn,nk,kl->ml", "mm,mk->mk", "mn,nk->mq"):
        with pytest.raises(ValueError):
            engine.einsum2d(eq, x, w)


# ------------------------------------------------------------------ #
# Instrumentation
# ------------------------------------------------------------------ #
def test_instrument_transformer_forward_matches_perf_model():
    """Acceptance: summed GemmEvent flops over one transformer forward ==
    the machine model's analytic enumeration, within 1%."""
    from repro.models import transformer

    cfg = configs.get_reduced("yi-9b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 64
    batch = {"inputs": jnp.zeros((B, S), jnp.int32)}
    with engine.instrument() as events:
        jax.eval_shape(lambda p, b: transformer.forward(p, cfg, b)[0],
                       params, batch)
    assert events, "no GemmEvents collected"
    got = engine.total_flops(events)
    want = perf_model.workload_flops(perf_model.dense_forward_gemms(cfg, B, S))
    assert want > 0
    assert abs(got - want) / want < 0.01, (got, want)
    # scanned layers carry the layer-count multiplier, not n_layers copies
    assert all(ev.count in (1, cfg.n_layers) for ev in events)
    # and the event stream drives the machine model directly
    hw, sw = perf_model.workload_cycles_from_events(
        perf_model.DEFAULT_MODEL, events)
    assert hw > 0 and sw > hw


def test_instrument_collects_thread_locally():
    other = {}

    def child():
        with engine.instrument() as ev:
            engine.matmul(_rand((4, 4)), _rand((4, 4)), policy=prec.FP32)
        other["n"] = len(ev)

    with engine.instrument() as events:
        t = threading.Thread(target=child)
        t.start()
        t.join()
    assert events == []          # the child's dispatch stayed in its thread
    assert other["n"] == 1


def test_nested_empty_collectors_unwind_by_identity():
    # two equal (empty) lists: exiting the inner context must not remove
    # the outer collector from the stack
    with engine.instrument() as outer:
        with engine.instrument() as inner:
            pass
        engine.matmul(_rand((4, 4)), _rand((4, 4)), policy=prec.FP32)
    assert len(outer) == 1 and inner == []


def test_paused_suppresses_emission():
    with engine.instrument() as events:
        with engine.paused():
            engine.matmul(_rand((4, 4)), _rand((4, 4)), policy=prec.FP32)
        engine.matmul(_rand((4, 4)), _rand((4, 4)), policy=prec.FP32)
    assert len(events) == 1


def test_weight_gemm_bytes_not_scaled_by_batch():
    B, S, d, k = 8, 16, 32, 64
    with engine.instrument() as events:
        # weight GEMM: (B, S, d) @ (d, k) — w is read once, not B times
        engine.matmul(_rand((B, S, d)), _rand((d, k)), policy=prec.FP32)
    (ev,) = events
    itm = 4  # fp32
    want = B * (S * d + S * k) * itm + d * k * itm
    assert ev.bytes == want


def test_repeat_multiplies_counts():
    with engine.instrument() as events:
        with engine.repeat(3), engine.repeat(4):
            engine.matmul(_rand((4, 4)), _rand((4, 4)), policy=prec.FP32)
    (ev,) = events
    assert ev.count == 12
    assert ev.total_flops == 12 * ev.flops


def test_summarize_shape():
    with engine.instrument() as events:
        engine.matmul(_rand((4, 4)), _rand((4, 4)), policy=prec.FP32)
        engine.linear(_rand((4, 4)), _rand((4, 4)), policy=prec.FP32)
    s = engine.summarize(events)
    assert set(s) == {"matmul", "linear", "total"}
    assert s["total"]["flops"] == engine.total_flops(events)


# ------------------------------------------------------------------ #
# Deprecation window closed (PR 1's one-release shims are gone)
# ------------------------------------------------------------------ #
def test_redmule_shim_module_removed():
    with pytest.raises(ImportError):
        from repro.core import redmule  # noqa: F401


def test_old_core_reexports_removed():
    import repro.core as core

    # the Engine surface is the only GEMM entry point now
    assert not hasattr(core, "matmul")
    assert not hasattr(core, "linear")
    with pytest.raises(ImportError):
        from repro.core import matmul  # noqa: F401
