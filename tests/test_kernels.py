"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracles.

Shape/dtype sweeps per the deliverable: every kernel is checked against
ref.py across aligned, ragged and degenerate shapes, plus hypothesis
property tests on the GEMM.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install (requirements-dev.txt)
    st = None

from repro.core import precision as prec
from repro.core import tiling
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas

RNG = np.random.default_rng(0)


def _rand(shape, dtype=np.float16):
    return jnp.asarray(RNG.normal(size=shape), dtype)


SHAPES = [
    (256, 512, 256),   # aligned
    (128, 128, 128),   # single tile
    (100, 200, 50),    # ragged everywhere
    (8, 8, 8),         # tiny
    (1, 640, 128),     # skinny M (the paper's AE fwd regime, K==B)
    (640, 1, 128),     # skinny N
    (33, 129, 257),    # prime-ish
]
POLICIES = [prec.TPU_FP16, prec.TPU_BF16, prec.FP32, prec.PAPER_FP16]


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("policy", POLICIES, ids=lambda p: p.name)
def test_redmule_matmul_vs_ref(shape, policy):
    M, N, K = shape
    x = _rand((M, N))
    w = _rand((N, K))
    t = tiling.choose_tiles(M, N, K, compute_dtype=policy.compute_dtype,
                            accum_dtype=policy.accum_dtype)
    z = ops.redmule_matmul(x, w, policy=policy, tile=t, interpret=True)
    zr = ref.matmul_ref(x, w, policy=policy, tile=t)
    assert z.shape == (M, K)
    assert z.dtype == policy.out_dtype
    zf, zrf = np.asarray(z, np.float32), np.asarray(zr, np.float32)
    # tolerance: 2 ulp of the output dtype at the result magnitude
    eps = {"float16": 1e-3, "bfloat16": 8e-3, "float32": 1e-6}[
        jnp.dtype(policy.out_dtype).name]
    denom = max(np.abs(zrf).max(), 1.0)
    assert np.max(np.abs(zf - zrf)) / denom < 2 * eps


def test_redmule_matmul_against_fp32_ground_truth():
    """The fp32-accum policies must track the exact result closely."""
    x = _rand((128, 1024))
    w = _rand((1024, 128))
    exact = np.asarray(ref.matmul_exact(x, w))
    z = ops.redmule_matmul(x, w, policy=prec.TPU_FP16, interpret=True)
    rel = np.abs(np.asarray(z, np.float32) - exact) / np.maximum(np.abs(exact), 1.0)
    assert rel.max() < 2e-3


def test_paper_faithful_accum_differs_from_fp32():
    """binary16 in-pipeline accumulation (the paper's FMA chain) must show
    measurable rounding vs fp32 accumulation on long reductions."""
    x = _rand((64, 4096))
    w = _rand((4096, 64))
    z16 = ops.redmule_matmul(x, w, policy=prec.PAPER_FP16, interpret=True)
    z32 = ops.redmule_matmul(x, w, policy=prec.TPU_FP16, interpret=True)
    diff = np.abs(np.asarray(z16, np.float32) - np.asarray(z32, np.float32))
    assert diff.max() > 0.0  # the error model is real...
    exact = np.asarray(ref.matmul_exact(x, w))
    # ...but bounded: fp16 accum of ~4k terms stays within ~1% relative
    rel = diff.max() / np.maximum(np.abs(exact).max(), 1.0)
    assert rel < 2e-2


def test_batched_matmul():
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.normal(size=(4, 64, 96)), jnp.float16)
    w = jnp.asarray(rng.normal(size=(4, 96, 32)), jnp.float16)
    z = ops.redmule_matmul_batched(x, w, policy=prec.TPU_FP16, interpret=True)
    zr = jnp.stack([ref.matmul_ref(x[i], w[i], policy=prec.TPU_FP16)
                    for i in range(4)])
    # fp16 output: tolerance ~2 ulp at the observed magnitudes
    np.testing.assert_allclose(np.asarray(z, np.float32),
                               np.asarray(zr, np.float32),
                               rtol=2e-3, atol=5e-2)


def test_batched_grid_matches_per_slice_kernel():
    """The leading batch *grid* dimension must be schedule-equivalent to
    running the 2D kernel per slice (same tiles, same store order) —
    bitwise, since both accumulate identically."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(3, 40, 200)), jnp.float16)
    w = jnp.asarray(rng.normal(size=(3, 200, 72)), jnp.float16)
    t = tiling.TileConfig(bm=16, bn=128, bk=128)
    for pol in (prec.PAPER_FP16, prec.TPU_FP16):
        zb = ops.redmule_matmul_batched(x, w, policy=pol, tile=t,
                                        interpret=True)
        z2 = jnp.stack([ops.redmule_matmul(x[i], w[i], policy=pol, tile=t,
                                           interpret=True)
                        for i in range(3)])
        np.testing.assert_array_equal(np.asarray(zb, np.float32),
                                      np.asarray(z2, np.float32))


# ------------------------------------------------------------------ #
# Fused epilogue (bias + activation inside the store-once step)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("policy", [prec.PAPER_FP16, prec.TPU_BF16],
                         ids=lambda p: p.name)
@pytest.mark.parametrize("act", [None, "relu", "gelu", "silu", "tanh"])
def test_fused_epilogue_vs_oracle(policy, act):
    """act(X @ W + b) fused into the kernel == oracle computed post-op in
    the accumulation dtype, on a ragged (padded) shape."""
    M, N, K = 33, 70, 40
    x = _rand((M, N), policy.compute_dtype)
    w = _rand((N, K), policy.compute_dtype)
    b = _rand((K,), policy.compute_dtype)
    z = ops.redmule_matmul(x, w, policy=policy, bias=b, epilogue=act,
                           interpret=True)
    assert z.shape == (M, K) and z.dtype == policy.out_dtype
    zr = ref.matmul_ref(x, w, policy=policy).astype(policy.accum_dtype)
    zr = zr + b.astype(policy.accum_dtype)
    if act is not None:
        import repro.core.epilogues as epi
        zr = epi.apply_epilogue(act, zr)
    zr = zr.astype(policy.out_dtype)
    eps = {"float16": 1e-3, "bfloat16": 8e-3}[jnp.dtype(policy.out_dtype).name]
    zf, zrf = np.asarray(z, np.float32), np.asarray(zr, np.float32)
    denom = max(np.abs(zrf).max(), 1.0)
    assert np.max(np.abs(zf - zrf)) / denom < 2 * eps


def test_fused_epilogue_padding_stays_clean():
    """Padding rows/cols never leak: a relu-fused GEMM on a ragged shape
    must carry no trace of the padded K columns (where act(0 + bias_pad)
    would be nonzero if the pad were kept)."""
    M, N, K = 10, 50, 30
    x = _rand((M, N), np.float32)
    w = _rand((N, K), np.float32)
    b = jnp.full((K,), 5.0, jnp.float32)  # relu(0 + 5) != 0 in the pad
    t = tiling.TileConfig(bm=8, bn=128, bk=128)
    z = ops.redmule_matmul(x, w, policy=prec.FP32, tile=t, bias=b,
                           epilogue="relu", interpret=True)
    assert z.shape == (M, K)
    zr = jax.nn.relu(jnp.dot(x, w, preferred_element_type=jnp.float32) + b)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ #
# Padding edge cases (zeros must be accumulation-neutral)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("shape", [(1, 1, 1), (3, 5, 7), (7, 130, 2)],
                         ids=str)
def test_sub_sublane_shapes(shape):
    """Shapes below one sublane/lane tile: the kernel pads to a single
    (minimum) tile and slices back."""
    M, N, K = shape
    for policy in (prec.PAPER_FP16, prec.FP32):
        x = _rand((M, N), policy.compute_dtype)
        w = _rand((N, K), policy.compute_dtype)
        z = ops.redmule_matmul(x, w, policy=policy, interpret=True)
        zr = ref.matmul_ref(x, w, policy=policy)
        assert z.shape == (M, K)
        np.testing.assert_allclose(np.asarray(z, np.float32),
                                   np.asarray(zr, np.float32),
                                   rtol=2e-3, atol=2e-2)


def test_zero_padding_accumulation_neutral_paper_fp16():
    """The paper-faithful fp16 accumulator re-rounds after every N-block;
    zero blocks must be identity under that re-rounding.  Explicitly
    extending N with zeros (one extra full reduction block) must produce
    a bitwise-identical result."""
    M, N, K = 32, 100, 48
    x = _rand((M, N))
    w = _rand((N, K))
    t = tiling.TileConfig(bm=16, bn=128, bk=128)
    z = ops.redmule_matmul(x, w, policy=prec.PAPER_FP16, tile=t,
                           interpret=True)
    # same problem with N zero-extended across a block boundary (100 -> 256:
    # the in-block pad grows and a whole extra zero block is appended)
    xz = jnp.pad(x, ((0, 0), (0, 156)))
    wz = jnp.pad(w, ((0, 156), (0, 0)))
    zz = ops.redmule_matmul(xz, wz, policy=prec.PAPER_FP16, tile=t,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(z, np.float32),
                                  np.asarray(zz, np.float32))
    # and the padded run still matches the faithful oracle bitwise
    zr = ref.matmul_ref(x, w, policy=prec.PAPER_FP16, tile=t)
    np.testing.assert_array_equal(np.asarray(z, np.float32),
                                  np.asarray(zr, np.float32))


def test_non_multiple_dims_every_policy():
    """M/N/K all indivisible by their tiles, across every policy."""
    M, N, K = 45, 333, 67
    t = tiling.TileConfig(bm=16, bn=128, bk=128)
    for policy in POLICIES:
        x = _rand((M, N), policy.compute_dtype)
        w = _rand((N, K), policy.compute_dtype)
        z = ops.redmule_matmul(x, w, policy=policy, tile=t, interpret=True)
        zr = ref.matmul_ref(x, w, policy=policy, tile=t)
        assert z.shape == (M, K)
        eps = {"float16": 1e-3, "bfloat16": 8e-3, "float32": 1e-6}[
            jnp.dtype(policy.out_dtype).name]
        zf, zrf = np.asarray(z, np.float32), np.asarray(zr, np.float32)
        denom = max(np.abs(zrf).max(), 1.0)
        assert np.max(np.abs(zf - zrf)) / denom < 2 * eps


if st is None:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_matmul_property_any_shape_any_tile():
        pass
else:
    @settings(max_examples=15, deadline=None)
    @given(
        m=st.integers(1, 96), n=st.integers(1, 96), k=st.integers(1, 96),
        bm=st.sampled_from([8, 16, 32]), bn=st.sampled_from([128]),
        bk=st.sampled_from([128]),
    )
    def test_matmul_property_any_shape_any_tile(m, n, k, bm, bn, bk):
        """Property: for ANY shape and tile config, kernel == oracle."""
        rng = np.random.default_rng(m * 10007 + n * 101 + k)
        x = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
        t = tiling.TileConfig(bm=bm, bn=bn, bk=bk)
        z = ops.redmule_matmul(x, w, policy=prec.FP32, tile=t, interpret=True)
        zr = ref.matmul_ref(x, w, policy=prec.FP32)
        np.testing.assert_allclose(np.asarray(z), np.asarray(zr),
                                   rtol=1e-5, atol=1e-4)


# ------------------------------------------------------------------ #
# Flash attention kernel
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("group", [1, 2, 4])
def test_flash_attention_vs_ref(causal, group):
    B, HKV, S, D = 2, 2, 256, 64
    HQ = HKV * group
    q = _rand((B, HQ, S, D), np.float32)
    k = _rand((B, HKV, S, D), np.float32)
    v = _rand((B, HKV, S, D), np.float32)
    o = flash_attention_pallas(
        q.reshape(B * HQ, S, D), k.reshape(B * HKV, S, D),
        v.reshape(B * HKV, S, D), group=group, causal=causal,
        bq=128, bkv=128, interpret=True).reshape(B, HQ, S, D)
    kb = jnp.repeat(k, group, axis=1)
    vb = jnp.repeat(v, group, axis=1)
    oref = ref.attention_ref(q, kb, vb, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_padded_kv():
    """t_valid masking: padded KV tail must not contribute."""
    B, S, D = 1, 128, 64
    q = _rand((B, S, D), np.float32)
    k = _rand((B, 2 * S, D), np.float32)
    v = _rand((B, 2 * S, D), np.float32)
    o_pad = flash_attention_pallas(q, k, v, causal=True, bq=128, bkv=128,
                                   t_valid=S, interpret=True)
    o_exact = flash_attention_pallas(q, k[:, :S], v[:, :S], causal=True,
                                     bq=128, bkv=128, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pad), np.asarray(o_exact),
                               rtol=1e-6, atol=1e-6)


def test_flash_attention_dead_rows_exact_zero():
    """l == 0 guard: with t_valid=0 every softmax row is empty; the
    store-once epilogue must write exact zeros, never NaN from 0/0."""
    q = _rand((2, 128, 64), np.float32)
    k = _rand((2, 128, 64), np.float32)
    v = _rand((2, 128, 64), np.float32)
    o = flash_attention_pallas(q, k, v, causal=False, bq=128, bkv=128,
                               t_valid=0, interpret=True)
    assert np.all(np.asarray(o) == 0.0)


def test_flash_attention_short_t_valid_ragged():
    """A freshly admitted slot: 3 live KV tokens inside a 128-wide block.
    Must match the kernel on the truncated KV, and the causal rows that
    precede any live token must be finite (the l == 0 path)."""
    B, S, D, tv = 1, 128, 64, 3
    q = _rand((B, S, D), np.float32)
    k = _rand((B, S, D), np.float32)
    v = _rand((B, S, D), np.float32)
    o = flash_attention_pallas(q, k, v, causal=False, bq=128, bkv=128,
                               t_valid=tv, interpret=True)
    o_exact = flash_attention_pallas(
        q, jnp.pad(k[:, :tv], [(0, 0), (0, 128 - tv), (0, 0)]),
        jnp.pad(v[:, :tv], [(0, 0), (0, 128 - tv), (0, 0)]),
        causal=False, bq=128, bkv=128, t_valid=tv, interpret=True)
    assert np.all(np.isfinite(np.asarray(o)))
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_exact),
                               rtol=1e-6, atol=1e-6)


def test_flash_attention_q_offset_decode_window():
    """q_offset shifts the causal mask: the last bq rows of a full sweep
    equal a windowed sweep whose query block starts at that offset."""
    B, S, D, bq = 1, 256, 64, 128
    q = _rand((B, S, D), np.float32)
    k = _rand((B, S, D), np.float32)
    v = _rand((B, S, D), np.float32)
    full = flash_attention_pallas(q, k, v, causal=True, bq=bq, bkv=128,
                                  interpret=True)
    tail = flash_attention_pallas(q[:, -bq:], k, v, causal=True, bq=bq,
                                  bkv=128, q_offset=S - bq, interpret=True)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, -bq:]),
                               rtol=1e-6, atol=1e-6)


def test_flash_attention_bf16():
    B, S, D = 2, 256, 64
    q = _rand((B, S, D), jnp.bfloat16)
    k = _rand((B, S, D), jnp.bfloat16)
    v = _rand((B, S, D), jnp.bfloat16)
    o = flash_attention_pallas(q, k, v, causal=True, bq=128, bkv=128,
                               interpret=True)
    oref = ref.attention_ref(q[:, None], k[:, None], v[:, None], causal=True)[:, 0]
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), atol=3e-2)


# ------------------------------------------------------------------ #
# Chunked linear attention kernel (mLSTM / SSD state in VMEM)
# ------------------------------------------------------------------ #
from repro.kernels.chunked_linear_attention import chunked_linear_attention_pallas
from repro.models import ssm as _ssm


@pytest.mark.parametrize("shape", [(2, 128, 16, 32), (1, 256, 64, 64),
                                   (3, 64, 8, 128)], ids=str)
@pytest.mark.parametrize("chunk", [32, 64])
def test_chunked_linear_attention_vs_engine(shape, chunk):
    BH, S, dk, dv = shape
    rng = np.random.default_rng(BH * 1000 + S)
    q = jnp.asarray(rng.normal(size=(BH, S, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BH, S, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BH, S, dv)), jnp.float32)
    g = jnp.asarray(-np.abs(rng.normal(size=(BH, S))) * 0.1, jnp.float32)
    o, st = chunked_linear_attention_pallas(q, k, v, g, chunk=chunk,
                                            interpret=True)
    # engine oracle with a (1, BH, S, d) layout
    o2, st2 = _ssm.chunked_linear_attention(
        q[None], k[None], v[None], g[None], chunk=chunk)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o2[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st2[0]),
                               rtol=1e-5, atol=1e-5)


def test_chunked_linear_attention_bf16_inputs():
    BH, S, dk, dv = 2, 128, 32, 32
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(BH, S, dk)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(BH, S, dk)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(BH, S, dv)), jnp.bfloat16)
    g = jnp.asarray(-np.abs(rng.normal(size=(BH, S))) * 0.1, jnp.float32)
    o, st = chunked_linear_attention_pallas(q, k, v, g, chunk=64,
                                            interpret=True)
    o2, st2 = _ssm.chunked_linear_attention(q[None], k[None], v[None],
                                            g[None], chunk=64)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o2[0], np.float32),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st2[0]),
                               rtol=3e-2, atol=3e-2)
