"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracles.

Shape/dtype sweeps per the deliverable: every kernel is checked against
ref.py across aligned, ragged and degenerate shapes, plus hypothesis
property tests on the GEMM.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install (requirements-dev.txt)
    st = None

from repro.core import precision as prec
from repro.core import tiling
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas

RNG = np.random.default_rng(0)


def _rand(shape, dtype=np.float16):
    return jnp.asarray(RNG.normal(size=shape), dtype)


SHAPES = [
    (256, 512, 256),   # aligned
    (128, 128, 128),   # single tile
    (100, 200, 50),    # ragged everywhere
    (8, 8, 8),         # tiny
    (1, 640, 128),     # skinny M (the paper's AE fwd regime, K==B)
    (640, 1, 128),     # skinny N
    (33, 129, 257),    # prime-ish
]
POLICIES = [prec.TPU_FP16, prec.TPU_BF16, prec.FP32, prec.PAPER_FP16]


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("policy", POLICIES, ids=lambda p: p.name)
def test_redmule_matmul_vs_ref(shape, policy):
    M, N, K = shape
    x = _rand((M, N))
    w = _rand((N, K))
    t = tiling.choose_tiles(M, N, K, compute_dtype=policy.compute_dtype,
                            accum_dtype=policy.accum_dtype)
    z = ops.redmule_matmul(x, w, policy=policy, tile=t, interpret=True)
    zr = ref.matmul_ref(x, w, policy=policy, tile=t)
    assert z.shape == (M, K)
    assert z.dtype == policy.out_dtype
    zf, zrf = np.asarray(z, np.float32), np.asarray(zr, np.float32)
    # tolerance: 2 ulp of the output dtype at the result magnitude
    eps = {"float16": 1e-3, "bfloat16": 8e-3, "float32": 1e-6}[
        jnp.dtype(policy.out_dtype).name]
    denom = max(np.abs(zrf).max(), 1.0)
    assert np.max(np.abs(zf - zrf)) / denom < 2 * eps


def test_redmule_matmul_against_fp32_ground_truth():
    """The fp32-accum policies must track the exact result closely."""
    x = _rand((128, 1024))
    w = _rand((1024, 128))
    exact = np.asarray(ref.matmul_exact(x, w))
    z = ops.redmule_matmul(x, w, policy=prec.TPU_FP16, interpret=True)
    rel = np.abs(np.asarray(z, np.float32) - exact) / np.maximum(np.abs(exact), 1.0)
    assert rel.max() < 2e-3


def test_paper_faithful_accum_differs_from_fp32():
    """binary16 in-pipeline accumulation (the paper's FMA chain) must show
    measurable rounding vs fp32 accumulation on long reductions."""
    x = _rand((64, 4096))
    w = _rand((4096, 64))
    z16 = ops.redmule_matmul(x, w, policy=prec.PAPER_FP16, interpret=True)
    z32 = ops.redmule_matmul(x, w, policy=prec.TPU_FP16, interpret=True)
    diff = np.abs(np.asarray(z16, np.float32) - np.asarray(z32, np.float32))
    assert diff.max() > 0.0  # the error model is real...
    exact = np.asarray(ref.matmul_exact(x, w))
    # ...but bounded: fp16 accum of ~4k terms stays within ~1% relative
    rel = diff.max() / np.maximum(np.abs(exact).max(), 1.0)
    assert rel < 2e-2


def test_batched_matmul():
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.normal(size=(4, 64, 96)), jnp.float16)
    w = jnp.asarray(rng.normal(size=(4, 96, 32)), jnp.float16)
    z = ops.redmule_matmul_batched(x, w, policy=prec.TPU_FP16, interpret=True)
    zr = jnp.stack([ref.matmul_ref(x[i], w[i], policy=prec.TPU_FP16)
                    for i in range(4)])
    # fp16 output: tolerance ~2 ulp at the observed magnitudes
    np.testing.assert_allclose(np.asarray(z, np.float32),
                               np.asarray(zr, np.float32),
                               rtol=2e-3, atol=5e-2)


if st is None:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_matmul_property_any_shape_any_tile():
        pass
else:
    @settings(max_examples=15, deadline=None)
    @given(
        m=st.integers(1, 96), n=st.integers(1, 96), k=st.integers(1, 96),
        bm=st.sampled_from([8, 16, 32]), bn=st.sampled_from([128]),
        bk=st.sampled_from([128]),
    )
    def test_matmul_property_any_shape_any_tile(m, n, k, bm, bn, bk):
        """Property: for ANY shape and tile config, kernel == oracle."""
        rng = np.random.default_rng(m * 10007 + n * 101 + k)
        x = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
        t = tiling.TileConfig(bm=bm, bn=bn, bk=bk)
        z = ops.redmule_matmul(x, w, policy=prec.FP32, tile=t, interpret=True)
        zr = ref.matmul_ref(x, w, policy=prec.FP32)
        np.testing.assert_allclose(np.asarray(z), np.asarray(zr),
                                   rtol=1e-5, atol=1e-4)


# ------------------------------------------------------------------ #
# Flash attention kernel
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("group", [1, 2, 4])
def test_flash_attention_vs_ref(causal, group):
    B, HKV, S, D = 2, 2, 256, 64
    HQ = HKV * group
    q = _rand((B, HQ, S, D), np.float32)
    k = _rand((B, HKV, S, D), np.float32)
    v = _rand((B, HKV, S, D), np.float32)
    o = flash_attention_pallas(
        q.reshape(B * HQ, S, D), k.reshape(B * HKV, S, D),
        v.reshape(B * HKV, S, D), group=group, causal=causal,
        bq=128, bkv=128, interpret=True).reshape(B, HQ, S, D)
    kb = jnp.repeat(k, group, axis=1)
    vb = jnp.repeat(v, group, axis=1)
    oref = ref.attention_ref(q, kb, vb, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_padded_kv():
    """t_valid masking: padded KV tail must not contribute."""
    B, S, D = 1, 128, 64
    q = _rand((B, S, D), np.float32)
    k = _rand((B, 2 * S, D), np.float32)
    v = _rand((B, 2 * S, D), np.float32)
    o_pad = flash_attention_pallas(q, k, v, causal=True, bq=128, bkv=128,
                                   t_valid=S, interpret=True)
    o_exact = flash_attention_pallas(q, k[:, :S], v[:, :S], causal=True,
                                     bq=128, bkv=128, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pad), np.asarray(o_exact),
                               rtol=1e-6, atol=1e-6)


def test_flash_attention_bf16():
    B, S, D = 2, 256, 64
    q = _rand((B, S, D), jnp.bfloat16)
    k = _rand((B, S, D), jnp.bfloat16)
    v = _rand((B, S, D), jnp.bfloat16)
    o = flash_attention_pallas(q, k, v, causal=True, bq=128, bkv=128,
                               interpret=True)
    oref = ref.attention_ref(q[:, None], k[:, None], v[:, None], causal=True)[:, 0]
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), atol=3e-2)


# ------------------------------------------------------------------ #
# Chunked linear attention kernel (mLSTM / SSD state in VMEM)
# ------------------------------------------------------------------ #
from repro.kernels.chunked_linear_attention import chunked_linear_attention_pallas
from repro.models import ssm as _ssm


@pytest.mark.parametrize("shape", [(2, 128, 16, 32), (1, 256, 64, 64),
                                   (3, 64, 8, 128)], ids=str)
@pytest.mark.parametrize("chunk", [32, 64])
def test_chunked_linear_attention_vs_engine(shape, chunk):
    BH, S, dk, dv = shape
    rng = np.random.default_rng(BH * 1000 + S)
    q = jnp.asarray(rng.normal(size=(BH, S, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BH, S, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BH, S, dv)), jnp.float32)
    g = jnp.asarray(-np.abs(rng.normal(size=(BH, S))) * 0.1, jnp.float32)
    o, st = chunked_linear_attention_pallas(q, k, v, g, chunk=chunk,
                                            interpret=True)
    # engine oracle with a (1, BH, S, d) layout
    o2, st2 = _ssm.chunked_linear_attention(
        q[None], k[None], v[None], g[None], chunk=chunk)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o2[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st2[0]),
                               rtol=1e-5, atol=1e-5)


def test_chunked_linear_attention_bf16_inputs():
    BH, S, dk, dv = 2, 128, 32, 32
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(BH, S, dk)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(BH, S, dk)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(BH, S, dv)), jnp.bfloat16)
    g = jnp.asarray(-np.abs(rng.normal(size=(BH, S))) * 0.1, jnp.float32)
    o, st = chunked_linear_attention_pallas(q, k, v, g, chunk=64,
                                            interpret=True)
    o2, st2 = _ssm.chunked_linear_attention(q[None], k[None], v[None],
                                            g[None], chunk=64)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o2[0], np.float32),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st2[0]),
                               rtol=3e-2, atol=3e-2)
