"""The analytical machine model must reproduce the paper's numbers.

Every quantitative claim from the paper is asserted here (EXPERIMENTS.md
quotes these same checks as the faithful-reproduction validation).
"""

import pytest

from repro.core.perf_model import (DEFAULT_MODEL, GEMM, autoencoder_gemms,
                                   autoencoder_report)

M = DEFAULT_MODEL


def test_peak_utilization_98_8pct():
    """Paper: 31.6 MAC/cycle = 98.8% of the 32-FMA ideal at large sizes."""
    g = GEMM(304, 304, 304)
    assert abs(M.hw_macs_per_cycle(g) - 31.6) < 0.15
    assert M.utilization(g) > 0.985
    # asymptotically it only improves
    assert M.utilization(GEMM(1024, 1024, 1024)) > M.utilization(g)


def test_speedup_22x_over_software():
    g = GEMM(1024, 1024, 1024)
    assert abs(M.speedup(g) - 22.0) < 0.5


def test_energy_efficiency_gain_4_65x():
    g = GEMM(1024, 1024, 1024)
    assert abs(M.efficiency_gain_vs_sw(g) - 4.65) < 0.25


def test_table1_throughput_42gflops_at_666mhz():
    g = GEMM(1024, 1024, 1024)
    assert abs(M.gflops(g, M.freq_peak_perf_mhz) - 42.0) < 1.0


def test_table1_efficiency_688_and_462_gflops_per_watt():
    g = GEMM(1024, 1024, 1024)
    assert abs(M.gflops_per_watt(g) - 688.0) < 25.0
    assert abs(M.gflops_per_watt(g, peak_perf=True) - 462.0) < 15.0


def test_area_0_07mm2_14pct_of_cluster():
    assert abs(M.area_mm2() - 0.07) < 0.005
    assert abs(M.area_fraction_of_cluster() - 0.14) < 0.01


def test_area_sweep_fig4b():
    """256 FMAs ~ cluster area; 512 ~ 2x cluster (Fig 4b)."""
    assert abs(M.area_mm2(8, 32) - M.cluster_area_mm2) < 0.02
    assert abs(M.area_mm2(16, 32) - 2 * M.cluster_area_mm2) < 0.03


def test_ports_step_h4_to_h5():
    """Paper: H=4 -> 9 ports; H=5 adds two more."""
    assert M.ports(4) == 9
    assert M.ports(5) == 11


def test_utilization_collapses_for_skinny_k():
    """Fig 3d / Fig 4c: K == batch == 1 starves the pipeline slots."""
    skinny = GEMM(128, 640, 1)
    assert M.utilization(skinny) < 0.10
    fat = GEMM(128, 640, 128)
    assert M.utilization(fat) > 0.8


def test_autoencoder_b1_speedup_2_6x():
    r = autoencoder_report(M, 1)
    assert 2.3 < r["speedup"] < 3.1           # paper: 2.6x
    assert r["speedup_bwd"] > r["speedup_fwd"]  # "advantages in backward"


def test_autoencoder_b16_speedup_and_batching_gain():
    r1 = autoencoder_report(M, 1)
    r16 = autoencoder_report(M, 16)
    assert 18.0 < r16["speedup"] < 27.0        # paper: 24.4x
    gain = r16["hw_macs_per_cycle"] / r1["hw_macs_per_cycle"]
    assert 10.0 < gain < 16.5                  # paper: "almost 16x"
    # SW does not benefit from batching (same throughput per MAC)
    sw_thr1 = sum(g.macs for gs in autoencoder_gemms(1).values() for g in gs) / r1["sw_cycles"]
    sw_thr16 = sum(g.macs for gs in autoencoder_gemms(16).values() for g in gs) / r16["sw_cycles"]
    assert sw_thr16 / sw_thr1 < 1.6


def test_energy_per_mac_decreases_with_size():
    """Fig 3c: energy/MAC falls monotonically with the computational burden."""
    sizes = [16, 32, 64, 128, 256, 512]
    e = [M.energy_per_mac_pj(GEMM(s, s, s)) for s in sizes]
    assert all(a > b for a, b in zip(e, e[1:]))
    assert e[-1] < 3.2  # ~2.9 pJ/MAC at the 0.65 V point


def test_monotone_utilization_in_each_dim():
    base = GEMM(64, 64, 64)
    assert M.utilization(GEMM(256, 64, 64)) >= M.utilization(base)
    assert M.utilization(GEMM(64, 256, 64)) >= M.utilization(base)
    assert M.utilization(GEMM(64, 64, 256)) >= M.utilization(base)
