"""Event-driven flops regression gate (CI).

The Engine emits one GemmEvent per dispatch at trace time; the roofline
report carries their summed flops as ``RooflineReport.engine_flops``.
These tests re-trace two fixed workloads and compare against the
checked-in baseline (``benchmarks/baselines/engine_flops.json``) —
**exactly**, since event flops are analytic (2*B*G*M*N*K), not measured.
A mismatch means the GEMM workload itself changed: either a real
regression (an op fell off the Engine, a shape drifted) or an intentional
architecture change, in which case the baseline is updated in the same
commit with a note.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core import engine
from repro.core import precision as prec
from repro.roofline import analysis

BASELINE_PATH = os.path.join(
    os.path.dirname(__file__), "..", "benchmarks", "baselines",
    "engine_flops.json")

with open(BASELINE_PATH) as fh:
    BASELINE = json.load(fh)


def _ae_events():
    from repro.data import SyntheticAE
    from repro.models import autoencoder

    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    x = jnp.asarray(SyntheticAE(batch=16).sample(0))
    with engine.instrument() as events:
        jax.eval_shape(
            lambda p, xx: autoencoder.ae_forward(p, xx, policy=prec.PAPER_FP16),
            params, x)
    return events


def _lm_events():
    from repro.models import transformer

    cfg = configs.get_reduced("yi-9b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"inputs": jnp.zeros((2, 64), jnp.int32)}
    with engine.instrument() as events:
        jax.eval_shape(lambda p, b: transformer.forward(p, cfg, b)[0],
                       params, batch)
    return events


def _attn_events(causal):
    rng = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (2, 4, 256, 64), jnp.float32)
    k = jax.random.normal(kk, (2, 4, 256, 64), jnp.float32)
    v = jax.random.normal(kv, (2, 4, 256, 64), jnp.float32)
    with engine.instrument() as events:
        # the "attention"-capable interpret backend: the flash sweep's
        # attention_score / attention_pv events carry the exact bill
        jax.eval_shape(lambda a, b, c: engine.attention(
            a, b, c, causal=causal, bq=128, bkv=128, policy=prec.FP32,
            backend="interpret"), q, k, v)
    return events


def _lattn_events():
    rng = jax.random.PRNGKey(4)
    kq, kk, kv, kg = jax.random.split(rng, 4)
    q = jax.random.normal(kq, (2, 4, 256, 32), jnp.float32)
    k = jax.random.normal(kk, (2, 4, 256, 32), jnp.float32)
    v = jax.random.normal(kv, (2, 4, 256, 64), jnp.float32)
    g = -jnp.abs(jax.random.normal(kg, (2, 4, 256), jnp.float32)) * 0.1
    with engine.instrument() as events:
        jax.eval_shape(lambda a, b, c, d: engine.linear_attention(
            a, b, c, d, chunk=64, backend="interpret"), q, k, v, g)
    return events


@pytest.mark.parametrize("name,collect", [
    ("ae_fwd_B16", _ae_events),
    ("yi-9b-reduced_fwd_B2_S64", _lm_events),
    ("attn_flash_fwd_B2_H4_S256_D64_causal", lambda: _attn_events(True)),
    ("attn_flash_fwd_B2_H4_S256_D64_dense", lambda: _attn_events(False)),
    ("attn_linear_fwd_B2_H4_S256_dk32_dv64", _lattn_events),
])
def test_engine_flops_match_baseline(name, collect):
    events = collect()
    assert events, "no GemmEvents collected"
    got = analysis.flops_from_events(events)
    want = BASELINE[name]
    assert got == want, (
        f"{name}: engine_flops {got} != baseline {want} "
        f"(delta {got - want:+}). If the GEMM workload changed on purpose, "
        f"update benchmarks/baselines/engine_flops.json in this commit.")


def test_roofline_report_carries_engine_flops():
    """The gate consumes RooflineReport.engine_flops — compile a small cell
    end-to-end so the report path itself is covered, not just the summer."""
    from repro.data import SyntheticAE
    from repro.models import autoencoder

    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    x = jnp.asarray(SyntheticAE(batch=16).sample(0))

    fn = jax.jit(lambda p, xx: autoencoder.ae_forward(
        p, xx, policy=prec.PAPER_FP16))
    with engine.instrument() as events:
        lowered = fn.lower(params, x)
    compiled = lowered.compile()
    report = analysis.roofline(
        compiled, arch="ae", shape="fwd_B16", mesh_name="single",
        n_devices=1, model_flops_val=float(BASELINE["ae_fwd_B16"]),
        gemm_events=events)
    assert report.engine_flops == BASELINE["ae_fwd_B16"]
