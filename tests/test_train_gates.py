"""Train-step flop regression gate (CI `train-gates` step).

The Engine ops carry a custom VJP, so a ``jax.value_and_grad`` trace emits
GemmEvents for the backward GEMMs (``matmul_dx`` / ``matmul_dw``) alongside
the forward — these tests re-trace the AutoEncoder train step (the paper's
§III-B on-device-training use case) and pin the instrumented fwd+bwd
``engine_flops`` against the checked-in baseline
(``benchmarks/baselines/train_flops.json``) — **exactly**, since event
flops are analytic.  A mismatch means the train-side GEMM workload changed:
either a regression (a backward GEMM fell off the Engine) or an intentional
architecture change, in which case the baseline is updated in the same
commit with a note.

Also covers the acceptance criterion end to end: a 2-step
``launch/train.py --arch ae`` run works, and ``RooflineReport.engine_flops``
for the train step is 3x the inference value (pure-GEMM model: the
bias-grad reduction and BatchNorm backward carry no GEMM flops).
"""

import json
import os

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core import precision as prec
from repro.roofline import analysis

BASELINE_PATH = os.path.join(
    os.path.dirname(__file__), "..", "benchmarks", "baselines",
    "train_flops.json")

with open(BASELINE_PATH) as fh:
    BASELINE = json.load(fh)


def _ae_train_events(batch=16):
    from repro.data import SyntheticAE
    from repro.models import autoencoder

    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    x = jnp.asarray(SyntheticAE(batch=batch).sample(0))
    with engine.instrument() as events:
        jax.eval_shape(lambda p: jax.value_and_grad(
            lambda q: autoencoder.ae_loss(q, x, policy=prec.PAPER_FP16)[0]
        )(p), params)
    return events


def test_ae_train_flops_match_baseline():
    events = _ae_train_events()
    assert events, "no GemmEvents collected"
    want = BASELINE["ae_train_B16"]
    split = analysis.flops_by_direction(events)
    got = {"fwd": int(split["fwd"]), "bwd": int(split["bwd"]),
           "total": int(analysis.flops_from_events(events))}
    assert got == want, (
        f"ae_train_B16: engine train flops {got} != baseline {want}. "
        f"If the GEMM workload changed on purpose, update "
        f"benchmarks/baselines/train_flops.json in this commit.")


def test_train_step_roofline_engine_flops_is_3x_inference():
    """Acceptance: RooflineReport.engine_flops for a train step is 3x the
    inference value (fwd + dX + dW per affine layer), with the fwd/bwd
    split carried on the report."""
    from repro.data import SyntheticAE
    from repro.models import autoencoder

    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    x = jnp.asarray(SyntheticAE(batch=16).sample(0))

    fn = jax.jit(lambda p: jax.value_and_grad(
        lambda q: autoencoder.ae_loss(q, x, policy=prec.PAPER_FP16)[0])(p))
    with engine.instrument() as events:
        lowered = fn.lower(params)
    report = analysis.roofline(
        lowered.compile(), arch="ae", shape="train_B16", mesh_name="single",
        n_devices=1,
        model_flops_val=float(BASELINE["ae_train_B16"]["total"]),
        gemm_events=events)
    want = BASELINE["ae_train_B16"]
    assert report.engine_flops == want["total"] == 3 * want["fwd"]
    assert report.engine_flops_fwd == want["fwd"]
    assert report.engine_flops_bwd == want["bwd"] == 2 * want["fwd"]


def test_lm_train_backward_flops_are_2x_inference():
    """A dense LM (remat="none"): the value_and_grad trace's backward
    GEMMs total exactly 2x the inference forward — one dX and one dW per
    forward GEMM, scan multiplicity included.  The chunked-CE head always
    runs under jax.checkpoint; its recompute re-forward is tagged
    ``recompute=True`` (PR-4 closed the count=1 limitation), executes
    during the backward pass, and is counted on the bwd side *separately*
    from the dX/dW GEMMs — this pins the refined contract."""
    import dataclasses

    from repro import configs
    from repro.models import transformer

    cfg = dataclasses.replace(configs.get_reduced("yi-9b"), remat="none")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"inputs": jnp.zeros((2, 64), jnp.int32),
             "labels": jnp.zeros((2, 64), jnp.int32)}

    with engine.instrument() as fwd_ev:
        jax.eval_shape(lambda p: transformer.forward(p, cfg, batch)[0],
                       params)
    with engine.instrument() as train_ev:
        jax.eval_shape(lambda p: jax.value_and_grad(
            lambda q: transformer.loss_fn(q, cfg, batch)[0])(p), params)
    infer = engine.total_flops(fwd_ev)
    split = analysis.flops_by_direction(train_ev)
    recompute = sum(ev.total_flops for ev in train_ev if ev.recompute)
    grads = sum(ev.total_flops for ev in train_ev
                if engine.is_backward_op(ev.spec.op))
    assert infer > 0
    assert recompute > 0            # the chunked-CE checkpoint region
    # dX + dW = exactly 2x inference; the recompute re-forward rides on
    # the bwd side because it executes during the backward pass
    assert grads == 2 * infer
    assert split["bwd"] == 2 * infer + recompute
    assert split["fwd"] == infer
    # every backward event is registry-dispatched with a transpose layout
    # (or pre-transposed "nn" on layout-capable xla — never untagged);
    # the two-pass epilogue pass events are legal backward events too
    for ev in train_ev:
        if analysis.is_backward_event(ev) and not ev.recompute:
            assert ev.spec.op in ("matmul_dx", "matmul_dw") \
                or engine.is_pass_op(ev.spec.op)
            assert ev.spec.layout in ("nt", "tn", "nn")
            assert ev.backend in engine.registered_backends()


def test_train_cli_two_step_smoke(capsys):
    """The CI gate's CLI path: 2 steps of `launch/train.py --arch ae
    --instrument` run end to end and print the instrumented fwd/bwd
    summary with the matmul_dx / matmul_dw rows."""
    from repro.launch import train

    train.main(["--arch", "ae", "--steps", "2", "--batch", "16",
                "--instrument"])
    out = capsys.readouterr().out
    assert "matmul_dx" in out and "matmul_dw" in out
    assert "train/inference=3.00x" in out
    assert "final mse:" in out
