"""Tile-selection invariants (hypothesis property tests)."""

import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install (requirements-dev.txt)
    st = None

from repro.core import tiling


if st is None:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_choose_tiles_invariants():
        pass
else:
    @settings(max_examples=60, deadline=None)
    @given(
        m=st.integers(1, 100_000),
        n=st.integers(1, 100_000),
        k=st.integers(1, 300_000),
        dtype=st.sampled_from([jnp.bfloat16, jnp.float16, jnp.float32]),
    )
    def test_choose_tiles_invariants(m, n, k, dtype):
        t = tiling.choose_tiles(m, n, k, compute_dtype=dtype)
        # MXU alignment
        assert t.bk % tiling.MXU_LANE == 0
        assert t.bn % tiling.MXU_LANE == 0
        assert t.bm % tiling.sublane(dtype) == 0
        # VMEM budget respected
        assert tiling.vmem_bytes(t, dtype, jnp.float32) \
            <= tiling.DEFAULT_VMEM_BUDGET
        # grid covers the problem
        gm, gk, gn = t.grid(m, n, k)
        assert gm * t.bm >= m and gk * t.bk >= k and gn * t.bn >= n
        # no grossly-oversized tiles (max one padding tile per dim)
        assert (gm - 1) * t.bm < m and (gk - 1) * t.bk < k \
            and (gn - 1) * t.bn < n


def test_large_gemm_gets_fat_tiles():
    t = tiling.choose_tiles(8192, 8192, 8192, compute_dtype=jnp.bfloat16)
    assert t.bm >= 256 and t.bk >= 256
    assert t.bn >= 512


def test_paper_mapping_streaming_dim_longest():
    """The streamed (reduction) dim gets the longest run — the analogue of
    the paper amortizing pipeline fill over the full N reduction."""
    t = tiling.choose_tiles(512, 8192, 512, compute_dtype=jnp.bfloat16)
    assert t.bn >= t.bm and t.bn >= t.bk


def test_tiny_budget_degrades_gracefully():
    t = tiling.choose_tiles(
        4096, 4096, 4096, compute_dtype=jnp.bfloat16, vmem_budget=256 * 1024)
    assert tiling.vmem_bytes(t, jnp.bfloat16, jnp.float32) <= 256 * 1024 or (
        t.bm == tiling.sublane(jnp.bfloat16)
        and t.bn == tiling.MXU_LANE
        and t.bk == tiling.MXU_LANE
    )


# ------------------------------------------------------------------ #
# Memoization (the Engine resolves a tile at every trace)
# ------------------------------------------------------------------ #
def test_choose_tiles_is_memoized():
    before = tiling._choose_tiles_cached.cache_info()
    a = tiling.choose_tiles(640, 768, 320, compute_dtype=jnp.float16)
    b = tiling.choose_tiles(640, 768, 320, compute_dtype=jnp.float16)
    assert a is b          # lru_cache returns the same frozen instance
    after = tiling._choose_tiles_cached.cache_info()
    assert after.hits > before.hits
    # dtype objects and their string names canonicalize to one entry
    c = tiling.choose_tiles(640, 768, 320, compute_dtype="float16")
    assert c is a


def test_choose_tiles_dtype_still_distinguished():
    a = tiling.choose_tiles(4096, 4096, 4096, compute_dtype=jnp.float32)
    b = tiling.choose_tiles(4096, 4096, 4096, compute_dtype=jnp.bfloat16)
    assert a.bm % tiling.sublane(jnp.float32) == 0
    assert b.bm % tiling.sublane(jnp.bfloat16) == 0


# ------------------------------------------------------------------ #
# Degenerate shapes (below one sublane/lane, empty dims)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("shape", [(1, 1, 1), (3, 5, 7), (0, 64, 0)], ids=str)
def test_sub_tile_shapes_get_minimum_valid_tiles(shape):
    m, n, k = shape
    for dtype in (jnp.float16, jnp.float32):
        t = tiling.choose_tiles(m, n, k, compute_dtype=dtype)
        assert t.bm == tiling.sublane(dtype)
        assert t.bn == tiling.MXU_LANE and t.bk == tiling.MXU_LANE
        # exactly one (padding) tile per dim
        assert t.grid(max(m, 1), max(n, 1), max(k, 1)) == (1, 1, 1)
