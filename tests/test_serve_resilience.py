"""Serving resilience (PR 9): deadlines, admission control, fault-injected
decode recovery, and SLO-gated degradation (docs/serving.md failure model).

The recovery contract pinned here is the tentpole: with ``nan_logits`` or
``kv_corrupt`` injected at step k, the victim request's emitted tokens
and final logits are **bit-identical** to an uninjected run on the FP16
cache (rebuild = re-prefill of ``prompt + emitted`` reproduces the
decode-built cache bitwise — the PR-6 drain invariant), co-resident
slots bitwise unaffected; on the FP8 cache the rebuilt slot stays within
the documented E4M3 bound of the FP16 oracle.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import precision as prec
from repro.models import transformer
from repro.runtime.fault_tolerance import FailureInjector
from repro.serving import (LoadConfig, Request, Scheduler, SchedulerConfig,
                           ShedPolicy, run_load, slo_rows)
from repro.serving import kv_cache

FP8 = "float8_e4m3fn"
E4M3_EPS = 2.0 ** -3  # same bound as tests/test_precision_fp8.py::_EPS


@pytest.fixture(scope="module")
def yi():
    cfg = configs.get_reduced("yi-9b")
    return cfg, transformer.init_params(jax.random.PRNGKey(0), cfg)


def _requests(cfg, n=2, plen=5, gen=5, arrival=0.0, **kw):
    rng = np.random.default_rng(11)
    return [Request(rid=i, arrival=arrival,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=plen + i).astype(np.int32),
                    max_new_tokens=gen, **kw)
            for i in range(n)]


def _drain(sched):
    results = sched.run()
    return {r.rid: r for r in results}


# --------------------------------------------------------------------- #
# Satellite: nan-safe RequestResult metrics on an undrained scheduler
# --------------------------------------------------------------------- #
def test_undrained_result_metrics_are_nan(yi):
    """Regression: .ttft/.tokens_per_tick used to raise TypeError while a
    request was still queued or mid-decode (None ticks)."""
    cfg, params = yi
    sched = Scheduler(params, cfg, SchedulerConfig(n_slots=1, max_len=16))
    sched.submit(_requests(cfg, n=2))
    assert math.isnan(sched.results[0].ttft)
    assert math.isnan(sched.results[0].tokens_per_tick)
    sched.step()  # rid 0 prefilled; rid 1 still queued, rid 0 unfinished
    assert math.isnan(sched.results[0].tokens_per_tick)
    assert math.isnan(sched.results[1].ttft)
    assert sched.results[1].status == "pending"


# --------------------------------------------------------------------- #
# Deadlines: queued + mid-decode eviction under the drain invariant
# --------------------------------------------------------------------- #
def test_deadline_evicts_queued_and_mid_decode(yi):
    """One slot, two requests: rid 1 expires in the queue behind rid 0's
    long decode; a third with a budget too small to decode is evicted
    mid-flight, and the freed slot still serves later work."""
    cfg, params = yi
    sched = Scheduler(params, cfg, SchedulerConfig(n_slots=1, max_len=24))
    rng = np.random.default_rng(3)
    mk = lambda rid, arr, gen, dl: Request(
        rid=rid, arrival=arr,
        prompt=rng.integers(0, cfg.vocab_size, size=4).astype(np.int32),
        max_new_tokens=gen, deadline_ticks=dl)
    sched.submit([
        mk(0, 0.0, 8, None),   # hogs the single slot for ~9 ticks
        mk(1, 0.0, 2, 3.0),    # queued behind rid 0 -> expires waiting
        mk(2, 20.0, 8, 3.0),   # starts, but cannot finish in 3 ticks
        mk(3, 40.0, 2, None),  # proves the evicted slot is reusable
    ])
    res = _drain(sched)
    assert res[0].status == "finished" and len(res[0].tokens) == 8
    assert res[1].status == "expired" and res[1].finish_tick is None
    assert res[2].status == "expired" and 0 < len(res[2].tokens) < 8
    assert res[3].status == "finished" and len(res[3].tokens) == 2
    kinds = [(e[0], e[2]) for e in sched.trace]
    assert ("expire", 1) in kinds   # queued expiry
    assert ("evict", 2) in kinds    # mid-decode eviction
    assert kinds.index(("evict", 2)) < kinds.index(("prefill", 3))
    # eviction is billed: rid 2's emitted tokens are waste, not useful
    assert sched.goodput.expired == 2
    assert sched.goodput.wasted_tokens == len(res[2].tokens)
    assert sched.goodput.useful_tokens == 10


# --------------------------------------------------------------------- #
# Bounded admission + client retry/backoff
# --------------------------------------------------------------------- #
def test_bounded_queue_rejects_with_retry_after(yi):
    cfg, params = yi
    scfg = SchedulerConfig(n_slots=1, max_len=16, max_queue=1)
    sched = Scheduler(params, cfg, scfg)
    sched.submit(_requests(cfg, n=4, plen=4, gen=2))
    sched.step()
    rej = {r.rid: r for r in sched.rejections}
    # slot takes rid 0, queue holds rid 1; rids 2-3 bounce with a hint
    assert set(rej) == {2, 3}
    assert all(r.reason == "queue_full" and r.retry_after >= 1.0
               for r in rej.values())
    assert sched.results[2].status == "rejected"


def test_loadgen_retries_until_served_and_reports_rates(yi):
    """Client-side retry with exponential backoff + jitter turns
    queue_full rejections into eventual service; rates are reported and
    unfinished requests are skipped (nan-free aggregation)."""
    cfg, params = yi
    scfg = SchedulerConfig(n_slots=1, max_len=16, max_queue=1)
    lc = LoadConfig(rate=4.0, n_requests=5, prompt_len=4, gen_len=2, seed=0,
                    max_retries=4)
    m = run_load(params, cfg, scfg, lc)
    assert m["retries"] > 0 and m["retry_rate"] > 0
    assert m["n_finished"] + m["abandons"] >= lc.n_requests
    assert np.isfinite(m["p50_ttft_ticks"])
    assert m["slo_rejected"] == m["retries"] + m["abandons"]
    # deterministic end to end: same seed, same story
    m2 = run_load(params, cfg, scfg, lc)
    for k in ("retries", "abandons", "n_finished", "total_tokens", "ticks",
              "p50_ttft_ticks", "deadline_hit_rate", "slo_goodput"):
        assert m[k] == m2[k], k


# --------------------------------------------------------------------- #
# Load shedding
# --------------------------------------------------------------------- #
def test_shed_policy_deterministic_ordering():
    reqs = [Request(rid=i, arrival=float(i % 3),
                    prompt=np.zeros(4, np.int32), max_new_tokens=4,
                    priority=i % 2) for i in range(6)]
    pol = ShedPolicy(queue_high_water=2, shed_infeasible=False)
    victims = pol.select_shed(reqs, clock=10.0, prefill_ticks=1.0)
    # lowest priority first, youngest (latest-arriving) first in a class
    assert [r.rid for r in victims] == [2, 4, 0, 5]
    assert victims == pol.select_shed(reqs, clock=10.0, prefill_ticks=1.0)


def test_scheduler_sheds_infeasible_and_overflow(yi):
    """Deadline-infeasible queued work is shed outright; the high-water
    mark then trims the lowest-priority tail."""
    cfg, params = yi
    scfg = SchedulerConfig(
        n_slots=1, max_len=24, shed=ShedPolicy(queue_high_water=1))
    sched = Scheduler(params, cfg, scfg)
    rng = np.random.default_rng(5)
    mk = lambda rid, gen, dl, pr: Request(
        rid=rid, arrival=0.0,
        prompt=rng.integers(0, cfg.vocab_size, size=4).astype(np.int32),
        max_new_tokens=gen, deadline_ticks=dl, priority=pr)
    sched.submit([
        mk(0, 4, None, 0),   # takes the slot
        mk(1, 8, 5.0, 1),    # infeasible: 1 + 8 > 5 -> shed
        mk(2, 4, None, 1),   # queued, high priority -> survives
        mk(3, 4, None, 0),   # overflow beyond high water, low pri -> shed
    ])
    res = _drain(sched)
    assert res[1].status == "shed" and res[3].status == "shed"
    assert res[0].status == "finished" and res[2].status == "finished"
    assert sched.goodput.shed == 2
    shed_events = [e for e in sched.trace if e[0] == "shed"]
    assert [e[2] for e in shed_events] == [1, 3]


# --------------------------------------------------------------------- #
# Fault injection: prefill_crash
# --------------------------------------------------------------------- #
def test_prefill_crash_retries_and_matches_uninjected(yi):
    cfg, params = yi
    scfg = SchedulerConfig(n_slots=2, max_len=16)
    base = Scheduler(params, cfg, scfg)
    base.submit(_requests(cfg))
    rb = _drain(base)
    inj = Scheduler(params, cfg, scfg,
                    injector=FailureInjector(fail_at_step=1,
                                             mode="prefill_crash"))
    inj.submit(_requests(cfg))
    ri = _drain(inj)
    assert any(e[0] == "prefill_retry" for e in inj.trace)
    for rid in rb:
        assert rb[rid].tokens == ri[rid].tokens
        np.testing.assert_array_equal(rb[rid].final_logits,
                                      ri[rid].final_logits)
    assert inj.goodput.recoveries == 1
    assert inj.goodput.goodput < base.goodput.goodput  # retry billed waste


# --------------------------------------------------------------------- #
# Fault injection: checksum plumbing
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("storage", [None, FP8])
def test_slot_checksum_flags_exactly_the_corrupted_slot(yi, storage):
    cfg, params = yi
    pool = transformer.init_cache(cfg, 3, 8, dtype=cfg.policy.compute_dtype,
                                  storage_dtype=storage)
    rng = np.random.default_rng(7)
    seq = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(1, 5)).astype(np.int32))
    _, single = transformer.prefill(params, cfg, {"inputs": seq}, 8,
                                    storage_dtype=storage)
    pool = kv_cache.insert_slot(pool, single, 1, cfg.policy.compute_dtype)
    before = {i: kv_cache.slot_checksum(pool, i, 5) for i in range(3)}
    corrupted = kv_cache.corrupt_slot_rows(pool, 1, [0, 4])
    after = {i: kv_cache.slot_checksum(corrupted, i, 5) for i in range(3)}
    assert after[1] != before[1]
    assert after[0] == before[0] and after[2] == before[2]
    # the flip is confined to the named rows: a checksum over rows [1, 4)
    assert (kv_cache.slot_checksum(corrupted, 1, 4)
            != kv_cache.slot_checksum(pool, 1, 4))  # row 0 is inside
    twice = kv_cache.corrupt_slot_rows(corrupted, 1, [0, 4])
    assert kv_cache.slot_checksum(twice, 1, 5) == before[1]  # involution


# --------------------------------------------------------------------- #
# The recovery contract (acceptance): bit-identical continuation on FP16
# --------------------------------------------------------------------- #
def _paired_run(cfg, params, scfg, mode, step, target=0):
    base = Scheduler(params, cfg, scfg)
    base.submit(_requests(cfg, n=2, plen=4, gen=6))
    rb = _drain(base)
    inj = Scheduler(params, cfg, scfg,
                    injector=FailureInjector(fail_at_step=step, mode=mode,
                                             target=target))
    inj.submit(_requests(cfg, n=2, plen=4, gen=6))
    ri = _drain(inj)
    return base, rb, inj, ri


@pytest.mark.parametrize("mode,detect", [("nan_logits", "nan_detect"),
                                         ("kv_corrupt", "kv_quarantine")])
def test_recovery_bit_identical_fp16(yi, mode, detect):
    """nan_logits / kv_corrupt at decode step 2: the victim's emitted
    tokens and final logits are bit-identical to the uninjected run, and
    the co-resident slot is bitwise unaffected (FP16 cache)."""
    cfg, params = yi
    scfg = SchedulerConfig(n_slots=2, max_len=16, audit_every=1)
    base, rb, inj, ri = _paired_run(cfg, params, scfg, mode, 2)
    ev = [e[0] for e in inj.trace]
    assert detect in ev and "recover" in ev
    victim = [e for e in inj.trace if e[0] == detect][0][2]
    assert victim == 0  # the injector's target rid
    for rid in (0, 1):
        assert ri[rid].status == "finished"
        assert rb[rid].tokens == ri[rid].tokens, f"rid {rid} tokens diverge"
        np.testing.assert_array_equal(
            rb[rid].final_logits, ri[rid].final_logits,
            err_msg=f"rid {rid} final logits not bit-identical")
        assert rb[rid].finish_tick == ri[rid].finish_tick
    # recovery overlapped the clock but was billed as waste slot-ticks
    assert inj.goodput.recoveries == 1
    assert inj.goodput.slot_ticks > base.goodput.slot_ticks
    assert inj.goodput.goodput < base.goodput.goodput
    # the event log is the uninjected one plus the quarantine/recovery pair
    assert [e for e in inj.trace
            if e[0] not in (detect, "recover")] == base.trace


def test_recovery_trace_deterministic_two_runs(yi):
    """Two fresh runs of the same injected-fault + eviction scenario:
    identical trace, health log, tokens, and rejections (seeded injector,
    one-shot latch re-created per run)."""
    cfg, params = yi

    def once():
        scfg = SchedulerConfig(n_slots=2, max_len=16, max_queue=2,
                               audit_every=1)
        lc = LoadConfig(rate=2.0, n_requests=6, prompt_len=4, gen_len=4,
                        seed=9, deadline_ticks=10.0, max_retries=1)
        inj = FailureInjector(fail_at_step=2, mode="kv_corrupt")
        sched = Scheduler(params, cfg, scfg, injector=inj)
        m = run_load(params, cfg, scfg, lc,
                     injector=FailureInjector(fail_at_step=2,
                                              mode="nan_logits"))
        del sched
        return m

    m1, m2 = once(), once()
    for k in sorted(m1):
        if k in ("wall_s", "s_per_tick", "p50_tokens_per_s",
                 "p99_tokens_per_s"):
            continue  # wall-clock derived
        assert m1[k] == m2[k], k


def _run_traced(cfg, params, scfg, lc, injector):
    """run_load plus the scheduler's full event log, for determinism pins."""
    sched = Scheduler(params, cfg, scfg, injector=injector)
    from repro.serving import poisson_requests
    sched.submit(poisson_requests(cfg, lc))
    while sched.step():
        pass
    return (sched.trace, sched.health,
            {rid: r.tokens for rid, r in sched.results.items()},
            sched.rejections)


def test_full_event_log_deterministic_under_faults_and_eviction(yi):
    cfg, params = yi
    scfg = SchedulerConfig(n_slots=2, max_len=16, audit_every=1)
    lc = LoadConfig(rate=1.0, n_requests=5, prompt_len=4, gen_len=5,
                    seed=13, deadline_ticks=9.0)
    runs = [_run_traced(cfg, params, scfg, lc,
                        FailureInjector(fail_at_step=3, mode="kv_corrupt"))
            for _ in range(2)]
    assert runs[0][0] == runs[1][0]    # trace
    assert runs[0][1] == runs[1][1]    # health
    assert runs[0][2] == runs[1][2]    # tokens
    assert runs[0][3] == runs[1][3]    # rejections
    ev = [e[0] for e in runs[0][0]]
    assert "kv_quarantine" in ev       # the fault actually fired
    assert "evict" in ev or "expire" in ev  # and the deadline bit


# --------------------------------------------------------------------- #
# FP8: quarantine/rebuild within the E4M3 bound vs the FP16 oracle
# --------------------------------------------------------------------- #
def test_fp8_rebuild_within_e4m3_bound_vs_fp16_oracle(yi):
    """Corrupt an FP8 slot mid-flight, let the audit quarantine and
    rebuild it, then check the rebuilt rows against the FP16 oracle
    (full prefill over prompt + emitted) within the E4M3 bound, with the
    co-resident slot bitwise untouched."""
    cfg, params = yi
    scfg = SchedulerConfig(n_slots=2, max_len=16, storage_dtype=FP8,
                           audit_every=1)
    sched = Scheduler(params, cfg, scfg)
    sched.submit(_requests(cfg, n=2, plen=4, gen=6))
    for _ in range(4):  # both slots prefillled + a couple decode steps
        sched.step()
    s0, s1 = sched.slots[0], sched.slots[1]
    assert s0 is not None and s1 is not None
    other_before = {
        name: np.asarray(leaf).copy()
        for _k, name, leaf, bax in kv_cache.iter_kv_leaves(sched.cache)}
    sched.cache = kv_cache.corrupt_slot_rows(sched.cache, 0,
                                             [0, s0.pos - 1])
    sched._audit_slots()
    assert any(e[0] == "kv_quarantine" and e[2] == s0.rid
               for e in sched.trace)
    # co-resident slot 1: bitwise identical storage (ratchet unmoved —
    # the rebuilt rows carry the same values, so no pool requantize)
    for _k, name, leaf, bax in kv_cache.iter_kv_leaves(sched.cache):
        got = np.take(np.asarray(leaf), 1, axis=bax)
        want = np.take(other_before[name], 1, axis=bax)
        np.testing.assert_array_equal(got.view(np.uint8),
                                      want.view(np.uint8), err_msg=name)
    # victim slot 0 vs the FP16 oracle of exactly its absorbed tokens
    absorbed = np.concatenate(
        [s0.prompt, np.asarray(sched.results[s0.rid].tokens[:s0.fed],
                               np.int32)])
    _, oracle = transformer.prefill(
        params, cfg, {"inputs": jnp.asarray(absorbed)[None]}, scfg.max_len)
    n = absorbed.shape[0]
    sub = sched.cache["layers"]
    for name in ("k", "v"):
        sc = np.asarray(sub[f"{name}_scale"]["scale"])
        dq = np.asarray(prec.dequantize_fp8(
            sub[name], jax.numpy.asarray(sc)[:, None, :, None, None],
            jax.numpy.float32))
        got = dq[:, 0, :, :n]
        want = np.asarray(oracle["layers"][name], np.float32)[:, 0, :, :n]
        bound = (E4M3_EPS * np.abs(want)
                 + sc[:, None, :, None][..., None] * 2.0 ** -9)
        assert np.all(np.abs(got - want) <= bound), name


def test_fp8_recovery_continues_and_is_deterministic(yi):
    """End-to-end FP8 injected run: recovery completes every request and
    two runs agree exactly (the within-bound FP8 analogue of the FP16
    bit-identical pin)."""
    cfg, params = yi
    scfg = SchedulerConfig(n_slots=2, max_len=16, storage_dtype=FP8,
                           audit_every=1)

    def once(mode):
        inj = Scheduler(params, cfg, scfg,
                        injector=FailureInjector(fail_at_step=2, mode=mode,
                                                 target=0))
        inj.submit(_requests(cfg, n=2, plen=4, gen=6))
        return inj, _drain(inj)

    for mode in ("nan_logits", "kv_corrupt"):
        i1, r1 = once(mode)
        i2, r2 = once(mode)
        assert any(e[0] == "recover" for e in i1.trace), mode
        assert i1.trace == i2.trace, mode
        for rid in r1:
            assert r1[rid].status == "finished"
            assert r1[rid].tokens == r2[rid].tokens
            np.testing.assert_array_equal(r1[rid].final_logits,
                                          r2[rid].final_logits)


# --------------------------------------------------------------------- #
# CI serve-resilience-gates: SLO floors on the interpret backend
# --------------------------------------------------------------------- #
def _slo_baseline():
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "benchmarks", "baselines", "serve_slo.json")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("mode", [None, "nan_logits", "kv_corrupt"])
def test_slo_gate_injected_run_above_floors(mode):
    """The pinned SLO scenario (benchmarks/baselines/serve_slo.json) on
    the interpret backend: the injected run's serve goodput and deadline
    hit rate land above the floors, with the fault demonstrably fired and
    recovered.  This is what the serve-resilience-gates CI job runs."""
    from repro.core import engine
    base = _slo_baseline()
    sc = base["scenario"]
    cfg = configs.get_reduced(sc["arch"])
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    scfg = SchedulerConfig(
        n_slots=sc["n_slots"], max_len=sc["max_len"],
        storage_dtype=sc["storage_dtype"], max_queue=sc["max_queue"],
        audit_every=sc["audit_every"])
    lc = LoadConfig(
        rate=sc["rate"], n_requests=sc["n_requests"],
        prompt_len=sc["prompt_len"], gen_len=sc["gen_len"], seed=sc["seed"],
        deadline_ticks=sc["deadline_ticks"], max_retries=sc["max_retries"])
    injector = None if mode is None else FailureInjector(
        fail_at_step=sc["inject_step"], mode=mode)
    with engine.use_backend("interpret"):
        rows, m = slo_rows(params, cfg, scfg, sc["arch"], lc,
                           injector=injector,
                           tag=f"slo_{mode}" if mode else "slo")
    floor = base["goodput_floor_uninjected"] if mode is None \
        else base["goodput_floor_injected"]
    assert m["slo_goodput"] >= floor, \
        f"serve goodput {m['slo_goodput']:.4f} below floor {floor}"
    assert m["deadline_hit_rate"] >= base["deadline_hit_rate_floor"]
    if mode is not None:
        assert m["slo_recoveries"] >= base["recoveries_min"], \
            "the injected fault never fired/recovered — the gate is vacuous"
        assert injector.fired
    assert m["n_finished"] == sc["n_requests"]
    (name, us, derived), = rows
    assert name.startswith(f"serve/{sc['arch']}/slo")
    assert "goodput=" in derived and "hit=" in derived


# --------------------------------------------------------------------- #
# Guardrails
# --------------------------------------------------------------------- #
def test_kv_corrupt_without_audit_is_refused(yi):
    cfg, params = yi
    with pytest.raises(ValueError, match="audit_every"):
        Scheduler(params, cfg, SchedulerConfig(n_slots=1, max_len=8),
                  injector=FailureInjector(fail_at_step=1,
                                           mode="kv_corrupt"))


def test_injector_serving_modes_noop_in_training_path():
    inj = FailureInjector(fail_at_step=1, mode="nan_logits")
    inj.maybe_fail(1)  # must not raise/exit
    assert not inj.fired
    assert inj.fires(1, "kv_corrupt") is False  # wrong mode
    assert inj.fires(1, "nan_logits") is True
    assert inj.fires(2, "nan_logits") is False  # one-shot latch
