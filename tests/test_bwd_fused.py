"""The one-pass backward contract: fused epilogue derivatives + bias grads.

PR-4's tentpole: on backends with the ``"fused_bwd_epilogue"`` capability
("pallas"/"interpret") the linear VJP's dX/dW kernels apply ``act'`` to the
dZ tile on load and accumulate ``db = Σ_rows ds`` inside the dW pass, so
the pre-activation cotangent ``ds`` never round-trips HBM.  Covered here:

  * property tests sweeping odd / non-multiple M/N/K shapes through the
    "nt"/"tn" layout kernels with and without fused backward epilogues —
    interpret (fused one-pass) vs xla (two-pass) grads per precision
    policy (relu kept out of the random sweep: its kink is the documented
    tolerance exclusion, pinned by the fixed-shape test instead);
  * event accounting: fused dispatches carry ``fused_bwd`` /
    ``fused_bias_grad`` and the derivative-operand bytes; the two-pass
    fallback bills ``linear_dact`` / ``linear_dbias`` pass events (zero
    flops, real bytes); fused backward bytes are strictly below two-pass;
  * the CI bwd-perf gate: AE train-step byte totals pinned exactly
    against benchmarks/baselines/train_bytes.json, fused < two-pass;
  * jax.checkpoint recompute events: tagged ``recompute=True``, inherit
    the primal trace's repeat() multiplicity, classified as backward
    (the PR-3 count=1 limitation, closed);
  * degenerate 0-row ragged *backward* grouped GEMMs short-circuit (the
    forward already did) — no backend dispatch, no events, zero grads.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install (requirements-dev.txt)
    st = None

from repro.core import engine
from repro.core import epilogues as epi
from repro.core import precision as prec
from repro.roofline import analysis

RNG = np.random.default_rng(11)

BASELINE_PATH = os.path.join(
    os.path.dirname(__file__), "..", "benchmarks", "baselines",
    "train_bytes.json")

with open(BASELINE_PATH) as fh:
    BASELINE = json.load(fh)


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


def _tol(policy):
    return {"float32": 1e-5, "float16": 2e-2,
            "bfloat16": 1e-1}[jnp.dtype(policy.compute_dtype).name]


def _grads(x, w, b, act, policy, backend):
    def loss(p):
        z = engine.linear(p["x"], p["w"], p["b"], activation=act,
                          policy=policy, backend=backend)
        return jnp.sum(z.astype(jnp.float32) ** 2)
    return jax.grad(loss)({"x": x, "w": w, "b": b})


def _assert_close(got, want, policy):
    tol = _tol(policy)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=tol, atol=tol),
        got, want)


# ------------------------------------------------------------------ #
# Property sweep: odd shapes through the fused nt/tn backward kernels
# ------------------------------------------------------------------ #
def _check_fused_vs_xla(m, n, k, act, policy):
    """interpret runs the one-pass fused backward (act' on load, db in
    the dW kernel); xla runs the two-pass fallback — grads must agree to
    the policy tolerance on arbitrary non-multiple shapes.  relu is kept
    out of the random sweep (kink); the fixed-shape test covers it with
    inputs bounded away from zero."""
    rng = np.random.default_rng(m * 10007 + n * 101 + k)
    dt = policy.compute_dtype
    x = jnp.asarray(rng.normal(size=(m, n)) * 0.5, dt)
    w = jnp.asarray(rng.normal(size=(n, k)) * 0.5, dt)
    b = jnp.asarray(rng.normal(size=(k,)) * 0.5, dt)
    g_int = _grads(x, w, b, act, policy, "interpret")
    g_xla = _grads(x, w, b, act, policy, "xla")
    for kk in ("x", "w", "b"):
        assert g_int[kk].shape == g_xla[kk].shape
    _assert_close(g_int, g_xla, policy)


def _check_plain_layouts_vs_xla(m, n, k, batch, policy):
    """Epilogue-free backward ("nt"/"tn" without the fused derivative):
    the pipelined kernels' padding must stay accumulation-neutral on odd
    shapes, batched leading dims included."""
    rng = np.random.default_rng(m * 7919 + n * 31 + k + batch)
    dt = policy.compute_dtype
    x = jnp.asarray(rng.normal(size=(batch, m, n)) * 0.4, dt)
    w = jnp.asarray(rng.normal(size=(n, k)) * 0.4, dt)

    def loss(p, backend):
        z = engine.matmul(p["x"], p["w"], policy=policy, backend=backend)
        return jnp.sum(z.astype(jnp.float32) ** 2)

    p = {"x": x, "w": w}
    _assert_close(jax.grad(lambda q: loss(q, "interpret"))(p),
                  jax.grad(lambda q: loss(q, "xla"))(p), policy)


# deterministic odd/non-multiple corner sweep — always runs, even on
# minimal installs where the hypothesis sweep below is skipped
_ODD_SHAPES = [(1, 1, 1), (1, 33, 5), (7, 3, 13), (9, 17, 1), (21, 35, 19)]


@pytest.mark.parametrize("policy", [prec.PAPER_FP16, prec.FP32],
                         ids=lambda p: p.name)
@pytest.mark.parametrize("act", [None, "gelu", "silu", "tanh"])
@pytest.mark.parametrize("shape", _ODD_SHAPES)
def test_fused_bwd_odd_shape_corners_match_xla(shape, act, policy):
    _check_fused_vs_xla(*shape, act, policy)


@pytest.mark.parametrize("policy", [prec.PAPER_FP16, prec.FP32],
                         ids=lambda p: p.name)
@pytest.mark.parametrize("shape,batch",
                         [((1, 40, 17), 2), ((33, 7, 5), 3), ((8, 9, 1), 1)])
def test_plain_transpose_layout_corners_match_xla(shape, batch, policy):
    _check_plain_layouts_vs_xla(*shape, batch, policy)


if st is None:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fused_bwd_grads_odd_shapes_match_xla():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_plain_transpose_layouts_odd_shapes_match_xla():
        pass
else:
    @settings(max_examples=12, deadline=None)
    @given(
        m=st.integers(1, 21),
        n=st.integers(1, 35),
        k=st.integers(1, 19),
        act=st.sampled_from([None, "gelu", "silu", "tanh"]),
        policy=st.sampled_from([prec.PAPER_FP16, prec.FP32]),
    )
    def test_fused_bwd_grads_odd_shapes_match_xla(m, n, k, act, policy):
        _check_fused_vs_xla(m, n, k, act, policy)

    @settings(max_examples=10, deadline=None)
    @given(
        m=st.integers(1, 33),
        n=st.integers(1, 40),
        k=st.integers(1, 17),
        batch=st.integers(1, 3),
        policy=st.sampled_from([prec.PAPER_FP16, prec.FP32]),
    )
    def test_plain_transpose_layouts_odd_shapes_match_xla(m, n, k, batch,
                                                          policy):
        _check_plain_layouts_vs_xla(m, n, k, batch, policy)


def test_fused_bwd_relu_fixed_shape_matches_xla():
    """relu (output-form derivative) away from the kink, odd shapes."""
    pol = prec.PAPER_FP16
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(11, 26)) * 0.5, pol.compute_dtype)
    w = jnp.asarray(rng.normal(size=(26, 13)) * 0.5, pol.compute_dtype)
    b = jnp.asarray(rng.normal(size=(13,)) * 0.5, pol.compute_dtype)
    s = np.asarray(x, np.float32) @ np.asarray(w, np.float32) \
        + np.asarray(b, np.float32)
    assert np.abs(s).min() > 1e-2, "test inputs landed on the relu kink"
    _assert_close(_grads(x, w, b, "relu", pol, "interpret"),
                  _grads(x, w, b, "relu", pol, "xla"), pol)


# ------------------------------------------------------------------ #
# Event accounting: fused flags, pass events, byte ordering
# ------------------------------------------------------------------ #
def _trace_linear_train(backend, act="gelu", with_bias=True):
    x = _rand((4, 8, 16), jnp.float16)
    w = _rand((16, 12), jnp.float16)
    b = _rand((12,), jnp.float16) if with_bias else None
    with engine.instrument() as events:
        jax.eval_shape(lambda p: jax.value_and_grad(
            lambda q: jnp.sum(engine.linear(
                q["x"], q["w"], q.get("b"), activation=act,
                policy=prec.TPU_FP16, backend=backend
            ).astype(jnp.float32)))(p),
            {"x": x, "w": w, **({"b": b} if with_bias else {})})
    return events


def test_fused_backward_events_carry_flags_and_deriv_bytes():
    events = _trace_linear_train("interpret")
    ops = [ev.spec.op for ev in events]
    # one-pass: no *_dact / *_dbias pass events at all
    assert ops == ["linear", "matmul_dx", "matmul_dw"]
    by_op = {ev.spec.op: ev.spec for ev in events}
    dx, dw = by_op["matmul_dx"], by_op["matmul_dw"]
    assert dx.fused_bwd and dx.grad_epilogue == "gelu" \
        and dx.grad_mode == "preact" and not dx.fused_bias_grad
    assert dw.fused_bwd and dw.fused_bias_grad \
        and dw.grad_epilogue == "gelu"
    # deriv operand billed: strictly more bytes than the same GEMM unfused
    import dataclasses
    plain_dx = dataclasses.replace(dx, grad_epilogue=None, grad_mode=None,
                                   fused_bwd=False)
    plain_dw = dataclasses.replace(dw, grad_epilogue=None, grad_mode=None,
                                   fused_bwd=False, fused_bias_grad=False)
    cb = jnp.dtype(dx.policy.compute_dtype).itemsize
    ab = jnp.dtype(dw.policy.accum_dtype).itemsize
    assert dx.bytes == plain_dx.bytes + dx.batch * dx.m * dx.n * cb
    assert dw.bytes == plain_dw.bytes + dw.n * dw.k * cb + dw.k * ab


def test_fused_backward_bytes_strictly_below_two_pass():
    for act, with_bias in ((None, True), ("gelu", True), ("tanh", False)):
        evi = _trace_linear_train("interpret", act=act, with_bias=with_bias)
        evx = _trace_linear_train("xla", act=act, with_bias=with_bias)
        bi = analysis.bytes_by_direction(evi)
        bx = analysis.bytes_by_direction(evx)
        fi = analysis.flops_by_direction(evi)
        fx = analysis.flops_by_direction(evx)
        assert fi == fx, (act, with_bias)       # pass events are zero-flop
        assert bi["bwd"] < bx["bwd"], (act, with_bias)
        # and the two-pass path actually billed the ds round-trip
        pass_bytes = sum(ev.spec.bytes for ev in evx
                         if engine.is_pass_op(ev.spec.op))
        assert pass_bytes > 0


def test_batched_weights_fall_back_to_two_pass():
    """The fused backward is a 2D-weight contract: (..., N, K) weights on
    a capable backend keep the two-pass path (and still differentiate)."""
    pol = prec.PAPER_FP16
    x = _rand((3, 8, 24), pol.compute_dtype, 0.5)
    w = _rand((3, 24, 16), pol.compute_dtype, 0.5)
    b = _rand((16,), pol.compute_dtype, 0.5)
    with engine.instrument() as events:
        jax.eval_shape(lambda p: jax.value_and_grad(
            lambda q: jnp.sum(engine.linear(
                q["x"], q["w"], q["b"], activation="gelu", policy=pol,
                backend="interpret").astype(jnp.float32)))(p),
            {"x": x, "w": w, "b": b})
    ops = [ev.spec.op for ev in events]
    assert "linear_dact" in ops and "linear_dbias" in ops
    assert not any(ev.spec.fused_bwd for ev in events)


# ------------------------------------------------------------------ #
# The CI bwd-perf gate: AE train-step bytes vs the checked-in baseline
# ------------------------------------------------------------------ #
def _ae_train_bytes(backend, batch=16):
    from repro.data import SyntheticAE
    from repro.models import autoencoder

    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    x = jnp.asarray(SyntheticAE(batch=batch).sample(0))
    with engine.instrument() as events:
        jax.eval_shape(lambda p: jax.value_and_grad(
            lambda q: autoencoder.ae_loss(q, x, policy=prec.PAPER_FP16,
                                          backend=backend)[0])(p), params)
    return events


def test_ae_train_bytes_match_baseline_and_fused_is_below():
    want = BASELINE["ae_train_B16"]
    evi = _ae_train_bytes("interpret")
    evx = _ae_train_bytes("xla")
    bi = analysis.bytes_by_direction(evi)
    bx = analysis.bytes_by_direction(evx)
    got = {
        "fused": {"fwd": int(bi["fwd"]), "bwd": int(bi["bwd"])},
        "two_pass": {"fwd": int(bx["fwd"]), "bwd": int(bx["bwd"])},
    }
    assert got == want, (
        f"ae_train_B16: engine train bytes {got} != baseline {want}. "
        f"If the byte accounting changed on purpose, update "
        f"benchmarks/baselines/train_bytes.json in this commit.")
    # the acceptance criterion: the ds round-trip / separate bias-grad
    # pass is gone on the fused backend — bwd bytes strictly below
    assert got["fused"]["bwd"] < got["two_pass"]["bwd"]
    # the separate bias-grad pass exists only on the two-pass path
    assert not any(engine.is_pass_op(ev.spec.op) for ev in evi)
    assert any(ev.spec.op == "linear_dbias" for ev in evx)
    # identical GEMM flops either way
    assert analysis.flops_by_direction(evi) == \
        analysis.flops_by_direction(evx)


# ------------------------------------------------------------------ #
# jax.checkpoint recompute tagging (the closed count=1 limitation)
# ------------------------------------------------------------------ #
def test_checkpoint_recompute_events_tagged():
    w = _rand((8, 8), scale=0.2)
    x = _rand((4, 8))

    def f(w_):
        g = jax.checkpoint(lambda a: engine.matmul(
            a, w_, policy=prec.FP32, backend="xla"))
        return jnp.sum(g(x) ** 2)

    with engine.instrument() as ev:
        jax.eval_shape(lambda p: jax.value_and_grad(f)(p), w)
    kinds = [(e.spec.op, e.recompute) for e in ev]
    assert kinds == [("matmul", False), ("matmul", True),
                     ("matmul_dx", False), ("matmul_dw", False)]
    # the recompute executes during the backward pass: classified bwd
    split = analysis.flops_by_direction(ev)
    infer = ev[0].total_flops
    assert split["fwd"] == infer
    assert split["bwd"] == 3 * infer       # recompute + dX + dW


def test_checkpoint_recompute_inherits_scan_multiplicity():
    """A checkpointed GEMM inside a repeat(n) scan: the recompute event
    carries the same count=n as the primal (the PR-3 limitation was
    count=1 *and* untagged *and* overcounted by partial-eval re-traces)."""
    n = 4
    ws = _rand((n, 8, 8), scale=0.2)
    x0 = _rand((4, 8))

    def loss(ws_):
        def body(h, w):
            h = jax.checkpoint(lambda a, b: engine.matmul(
                a, b, policy=prec.FP32, backend="xla"))(h, w)
            return h, 0

        with engine.repeat(n):
            h, _ = jax.lax.scan(body, x0, ws_)
        return jnp.sum(h ** 2)

    with engine.instrument() as events:
        jax.eval_shape(lambda p: jax.value_and_grad(loss)(p), ws)
    fwd = [e for e in events if e.spec.op == "matmul" and not e.recompute]
    rec = [e for e in events if e.recompute]
    assert [e.count for e in fwd] == [n]
    assert [(e.spec.op, e.count) for e in rec] == [("matmul", n)]
    counts = {e.spec.op: e.count for e in events if not e.recompute}
    assert counts == {"matmul": n, "matmul_dx": n, "matmul_dw": n}


def test_checkpoint_grads_unchanged_by_tagging():
    w = _rand((8, 8), scale=0.3)
    x = _rand((4, 8))
    g_ck = jax.grad(lambda w_: jnp.sum(jax.checkpoint(
        lambda a: engine.matmul(a, w_, policy=prec.FP32,
                                backend="xla"))(x) ** 2))(w)
    g_plain = jax.grad(lambda w_: jnp.sum(engine.matmul(
        x, w_, policy=prec.FP32, backend="xla") ** 2))(w)
    np.testing.assert_allclose(np.asarray(g_ck), np.asarray(g_plain),
                               rtol=1e-6)


# ------------------------------------------------------------------ #
# Degenerate 0-row ragged backward short-circuit (satellite regression)
# ------------------------------------------------------------------ #
def test_zero_row_ragged_backward_short_circuits():
    G, M, N, K = 3, 8, 16, 12
    x = _rand((G, M, N), scale=0.3)
    w = _rand((G, N, K), scale=0.3)
    sizes = jnp.asarray([0, 0, 0])

    def loss(p):
        z = engine.grouped_matmul(p["x"], p["w"], group_sizes=sizes,
                                  policy=prec.FP32, backend="xla")
        return jnp.sum(z ** 2)

    with engine.instrument() as events:
        g = jax.grad(loss)({"x": x, "w": w})
    ops = [ev.spec.op for ev in events]
    # forward dispatches (its own masking handles the zeros); backward
    # short-circuits: no dX/dW dispatches, no events
    assert "matmul_dx" not in ops and "matmul_dw" not in ops
    assert np.all(np.asarray(g["x"]) == 0.0)
    assert np.all(np.asarray(g["w"]) == 0.0)
    assert g["x"].dtype == x.dtype and g["w"].dtype == w.dtype
    # partially-empty stays dispatched (only the all-empty case skips)
    with engine.instrument() as ev2:
        jax.eval_shape(lambda p: jax.grad(lambda q: jnp.sum(
            engine.grouped_matmul(q["x"], q["w"],
                                  group_sizes=jnp.asarray([2, 0, 0]),
                                  policy=prec.FP32,
                                  backend="xla") ** 2))(p),
            {"x": x, "w": w})
    ops2 = [ev.spec.op for ev in ev2]
    assert "matmul_dx" in ops2 and "matmul_dw" in ops2


def test_ae_train_fp8_bytes_match_baseline_and_below_fp16():
    """The PR-5 mixed-precision gate: the same AE train trace under the
    ``mixed_fp8_e4m3`` policy (per-operand FP8 storage, per-tensor
    scales) is pinned exactly against the ``ae_train_fp8`` baseline and
    must carry strictly fewer engine bytes than the FP16 trace
    (``engine/ae_train_bytes_B16``'s fused run) at **identical** engine
    flops — bytes drop, flops don't."""
    from repro.data import SyntheticAE
    from repro.models import autoencoder

    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    x = jnp.asarray(SyntheticAE(batch=16).sample(0))

    def trace(policy):
        with engine.instrument() as events:
            jax.eval_shape(lambda p: jax.value_and_grad(
                lambda q: autoencoder.ae_loss(q, x, policy=policy,
                                              backend="interpret")[0])(p),
                params)
        return events

    from repro.core import perf_model

    ev8 = trace(prec.MIXED_FP8_E4M3)
    ev16 = trace(prec.PAPER_FP16)
    b8 = perf_model.workload_hbm_bytes_from_events(ev8)
    want = BASELINE["ae_train_fp8"]
    got = {
        "fwd": b8["fwd"], "bwd": b8["bwd"], "total": b8["total"],
        "fp16_total": int(engine.total_bytes(ev16)),
        "engine_flops": int(engine.total_flops(ev8)),
    }
    assert got == want, (
        f"ae_train_fp8: engine train bytes {got} != baseline {want}. "
        f"If the byte accounting changed on purpose, update "
        f"benchmarks/baselines/train_bytes.json in this commit.")
    # the acceptance criterion, stated directly
    assert got["total"] < got["fp16_total"]
    assert engine.total_flops(ev8) == engine.total_flops(ev16)
    # every GEMM dispatch carries the narrow per-operand storage and the
    # scaled flag; the epilogue runs two-pass (quantization point is
    # backend-invariant), so the forced post-op forward pass and the
    # bias-grad reduction are billed as their own pass events
    for ev in ev8:
        if not engine.is_pass_op(ev.spec.op):
            assert ev.spec.scaled
            assert "float8" in (ev.spec.x_dtype or "") \
                or "float8" in (ev.spec.w_dtype or "")
    assert any(ev.spec.op == "linear_dbias" for ev in ev8)
    assert any(ev.spec.op == "linear_postep" for ev in ev8)
    # postep is a *forward* pass event (zero flops, real bytes)
    for ev in ev8:
        if ev.spec.op == "linear_postep":
            assert not analysis.is_backward_event(ev)
            assert ev.spec.flops == 0 and ev.spec.bytes > 0
