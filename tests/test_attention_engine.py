"""Attention as a first-class engine op: numerics, events, autotune.

The contract under test (docs/attention.md): ``engine.attention`` and
``engine.linear_attention`` dispatch through the backend registry's
``"attention"`` capability — the interpret backend runs the fused Pallas
sweeps, XLA runs the reference :func:`einsum2d` composition — and both
paths agree with a dense-plus-mask fp32 oracle, under ``jax.grad``, and
on the billed :class:`GemmEvent` footprints (causally skipped KV blocks
excluded, flops hand-counted here independently of the engine's own
``_attn_pairs``).
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    st = None

from repro.core import autotune, engine
from repro.core import precision as prec

RNG = np.random.default_rng(7)

# interpret runs the real Pallas flash/chunked kernels (emulated on CPU);
# xla runs the engine's reference einsum2d composition.
KERNEL, REF = "interpret", "xla"

POLICIES = [prec.FP32, prec.TPU_BF16, prec.TPU_FP16]
_TOL = {"float32": 2e-5, "bfloat16": 1e-1, "float16": 3e-2}


def _tol(policy):
    return _TOL[jnp.dtype(policy.compute_dtype).name]


def _qkv(B=2, Hq=4, Hkv=2, S=37, T=53, D=16, dtype=np.float32):
    q = jnp.asarray(RNG.standard_normal((B, Hq, S, D)).astype(dtype))
    k = jnp.asarray(RNG.standard_normal((B, Hkv, T, D)).astype(dtype))
    v = jnp.asarray(RNG.standard_normal((B, Hkv, T, D)).astype(dtype))
    return q, k, v


def _oracle(q, k, v, *, causal, t_valid=None, q_offset=0, scale=None):
    """Dense-plus-mask fp32 attention oracle (numpy, no engine code)."""
    q, k, v = (np.asarray(x, np.float64) for x in (q, k, v))
    B, Hq, S, D = q.shape
    _, Hkv, T, _ = k.shape
    group = Hq // Hkv
    k = np.repeat(k, group, axis=1)
    v = np.repeat(v, group, axis=1)
    scale = D ** -0.5 if scale is None else scale
    s = np.einsum("bhsd,bhtd->bhst", q, k) * scale
    rows = q_offset + np.arange(S)[:, None]
    cols = np.arange(T)[None, :]
    mask = cols < (T if t_valid is None else t_valid)
    if causal:
        mask = mask & (cols <= rows)
    else:
        mask = np.broadcast_to(mask, (S, T))
    s = np.where(mask, s, -np.inf)
    s = s - np.max(s, axis=-1, keepdims=True)
    with np.errstate(invalid="ignore"):  # all -inf rows -> nan, zeroed next
        p = np.exp(s)
        p = p / np.sum(p, axis=-1, keepdims=True)
    p = np.where(mask.any(axis=-1)[:, None], np.nan_to_num(p), 0.0)
    return np.einsum("bhst,bhtd->bhsd", p, v)


def _hand_pairs(S, T, bq, bkv, *, causal, q_offset=0):
    """Independent count of executed (Q-block, KV-block) pairs: a pair
    runs unless every one of its columns is strictly causal-dead."""
    nq = math.ceil(S / bq)
    nkv = math.ceil(T / bkv)
    if not causal:
        return nq * nkv
    return sum(1 for qi in range(nq) for ki in range(nkv)
               if ki * bkv <= q_offset + qi * bq + bq - 1)


def _linear_oracle(q, k, v, log_g, state=None):
    """Token-by-token mLSTM/SSD recurrence (numpy fp64)."""
    q, k, v, g = (np.asarray(x, np.float64) for x in (q, k, v, log_g))
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    st_ = np.zeros((B, H, dk, dv)) if state is None \
        else np.asarray(state, np.float64)
    out = np.zeros((B, H, S, dv))
    for t in range(S):
        st_ = np.exp(g[:, :, t])[..., None, None] * st_ + \
            np.einsum("bhk,bhv->bhkv", k[:, :, t], v[:, :, t])
        out[:, :, t] = np.einsum("bhk,bhkv->bhv", q[:, :, t], st_)
    return out, st_


def _lg(B=2, H=2, S=23, lo=-0.2):
    return jnp.asarray(
        RNG.uniform(lo, 0.0, (B, H, S)).astype(np.float32))


# ------------------------------------------------------------------ #
# Cache isolation: engine tile resolution consults the autotune cache
# ------------------------------------------------------------------ #
@pytest.fixture(autouse=True)
def _fresh_cache(monkeypatch, tmp_path):
    monkeypatch.setenv(autotune.ENV_VAR, str(tmp_path / "autotune.json"))
    autotune.clear_cache()
    yield
    autotune.clear_cache()


# ------------------------------------------------------------------ #
# Forward numerics: kernel path vs reference path vs oracle
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("policy", POLICIES, ids=lambda p: p.name)
@pytest.mark.parametrize("causal", [False, True])
def test_forward_backends_agree_and_match_oracle(policy, causal):
    q, k, v = _qkv()
    kw = dict(causal=causal, t_valid=48, policy=policy, bq=16, bkv=16)
    got_k = np.asarray(engine.attention(q, k, v, backend=KERNEL, **kw),
                       np.float32)
    got_r = np.asarray(engine.attention(q, k, v, backend=REF, **kw),
                       np.float32)
    want = _oracle(q, k, v, causal=causal, t_valid=48)
    tol = _tol(policy)
    np.testing.assert_allclose(got_k, got_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(got_k, want, rtol=tol, atol=tol)


def test_gqa_head_mapping_equals_materialized_kv():
    """The kernel maps q head h -> kv head h // group in its index maps;
    that must equal attention against jnp.repeat-materialized K/V."""
    q, k, v = _qkv(Hq=6, Hkv=2)
    km = jnp.repeat(k, 3, axis=1)
    vm = jnp.repeat(v, 3, axis=1)
    for b in (KERNEL, REF):
        got = engine.attention(q, k, v, backend=b, bq=16, bkv=16)
        want = engine.attention(q, km, vm, backend=b, bq=16, bkv=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


def test_q_offset_matches_decode_window_oracle():
    """A decode-style tail: 5 query rows at absolute offset 48 over a
    53-token KV must equal the oracle's shifted causal mask."""
    q, k, v = _qkv(S=5, T=53)
    for b in (KERNEL, REF):
        got = engine.attention(q, k, v, backend=b, causal=True,
                               q_offset=48, bq=8, bkv=16,
                               policy=prec.FP32)
        want = _oracle(q, k, v, causal=True, q_offset=48)
        np.testing.assert_allclose(np.asarray(got, np.float32), want,
                                   rtol=2e-5, atol=2e-5)


def test_fully_masked_rows_are_exact_zeros():
    """t_valid=0 kills every KV column; the l == 0 guard must return
    exact zeros (not NaN from 0/0) on both paths."""
    q, k, v = _qkv(S=9, T=24)
    for b in (KERNEL, REF):
        out = np.asarray(engine.attention(q, k, v, backend=b, t_valid=0,
                                          bq=8, bkv=8, policy=prec.FP32))
        assert np.all(out == 0.0), f"backend {b}: NaN/garbage in dead rows"


# ------------------------------------------------------------------ #
# Gradients: the custom_vjp re-enters the registry
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("policy", [prec.FP32, prec.TPU_FP16],
                         ids=lambda p: p.name)
def test_grad_backends_agree(policy):
    q, k, v = _qkv(S=21, T=29, D=8)

    def loss(b):
        def f(q_, k_, v_):
            o = engine.attention(q_, k_, v_, causal=True, t_valid=26,
                                 policy=policy, bq=8, bkv=8, backend=b)
            return jnp.sum(jnp.square(o.astype(jnp.float32)))
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    tol = _tol(policy)
    for gk, gr in zip(loss(KERNEL), loss(REF)):
        np.testing.assert_allclose(np.asarray(gk, np.float32),
                                   np.asarray(gr, np.float32),
                                   rtol=tol, atol=tol)


def test_linear_attention_grad_backends_agree():
    q, k, v = _qkv(B=2, Hq=2, Hkv=2, S=19, T=19, D=6)
    v = v[..., :10]  # dv != dk exercises the rectangular state
    lg = _lg(S=19)

    def loss(b):
        def f(q_, k_, v_, g_):
            o, s_ = engine.linear_attention(q_, k_, v_, g_, chunk=8,
                                            backend=b)
            return jnp.sum(jnp.square(o)) + jnp.sum(jnp.square(s_))
        return jax.grad(f, argnums=(0, 1, 2, 3))(q, k, v, lg)

    for gk, gr in zip(loss(KERNEL), loss(REF)):
        np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                                   rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ #
# Linear attention: kernel vs reference vs oracle, state carry, chunks
# ------------------------------------------------------------------ #
def test_linear_attention_backends_agree_and_match_oracle():
    q, k, v = _qkv(B=2, Hq=2, Hkv=2, S=23, T=23, D=6)
    v = v[..., :10]
    lg = _lg(S=23)
    want_o, want_s = _linear_oracle(q, k, v, lg)
    for b in (KERNEL, REF):
        out, st_ = engine.linear_attention(q, k, v, lg, chunk=8, backend=b)
        np.testing.assert_allclose(np.asarray(out), want_o,
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st_), want_s,
                                   rtol=1e-4, atol=1e-4)


def test_linear_attention_chunk_invariance_and_state_carry():
    """The chunk size is a tiling choice, not semantics; and a split
    sweep with state carry-in must equal the unsplit sweep."""
    q, k, v = _qkv(B=1, Hq=2, Hkv=2, S=32, T=32, D=6)
    lg = _lg(B=1, S=32)
    o64, s64 = engine.linear_attention(q, k, v, lg, chunk=64, backend=REF)
    o8, s8 = engine.linear_attention(q, k, v, lg, chunk=8, backend=KERNEL)
    np.testing.assert_allclose(np.asarray(o8), np.asarray(o64),
                               rtol=1e-5, atol=1e-5)
    h = 20  # odd split: second half starts mid-chunk
    o1, s1 = engine.linear_attention(
        q[:, :, :h], k[:, :, :h], v[:, :, :h], lg[:, :, :h],
        chunk=8, backend=REF)
    o2, s2 = engine.linear_attention(
        q[:, :, h:], k[:, :, h:], v[:, :, h:], lg[:, :, h:],
        chunk=8, state=s1, backend=REF)
    np.testing.assert_allclose(np.concatenate([o1, o2], axis=2),
                               np.asarray(o64), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s64),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ #
# Event footprints: billed flops/bytes are exact, skipped blocks free
# ------------------------------------------------------------------ #
def test_attention_event_flops_hand_counted():
    B, Hq, S, T, D, bq, bkv = 2, 4, 100, 150, 16, 32, 48
    q, k, v = _qkv(B=B, Hq=Hq, Hkv=Hq, S=S, T=T, D=D)
    totals = {}
    for causal in (False, True):
        with engine.instrument() as ev:
            engine.attention(q, k, v, causal=causal, bq=bq, bkv=bkv,
                             policy=prec.FP32,
                             backend=KERNEL).block_until_ready()
        ev = [e for e in ev if e.spec.op.startswith("attention_")]
        assert sorted(e.spec.op for e in ev) == \
            ["attention_pv", "attention_score"]
        pairs = _hand_pairs(S, T, bq, bkv, causal=causal)
        for e in ev:
            # each executed pair runs one bq x bkv x D score GEMM and one
            # bq x D x bkv PV GEMM: identical flop bills
            assert e.spec.groups == pairs, e.spec
            assert e.flops == 2 * B * Hq * pairs * bq * bkv * D, e.spec
            assert e.count == 1 and not e.recompute
        s_pad = math.ceil(S / bq) * bq
        score = next(e for e in ev if e.spec.op == "attention_score")
        pv = next(e for e in ev if e.spec.op == "attention_pv")
        # fp32 policy: Q once + K per executed pair in; V in + out back
        assert score.bytes == B * Hq * (s_pad * D + pairs * bkv * D) * 4
        assert pv.bytes == B * Hq * (pairs * bkv * D + s_pad * D) * 4
        totals[causal] = sum(e.flops for e in ev)
    # causally dead KV blocks are excluded from the bill
    assert totals[True] < totals[False]
    dense_pairs = _hand_pairs(S, T, bq, bkv, causal=False)
    causal_pairs = _hand_pairs(S, T, bq, bkv, causal=True)
    assert totals[True] * dense_pairs == totals[False] * causal_pairs


def test_linear_attention_event_flops_hand_counted():
    B, H, S, dk, dv, chunk = 2, 3, 50, 8, 12, 16
    q, k, _ = _qkv(B=B, Hq=H, Hkv=H, S=S, T=S, D=dk)
    v = jnp.asarray(RNG.standard_normal((B, H, S, dv)).astype(np.float32))
    lg = _lg(B=B, H=H, S=S)
    with engine.instrument() as ev:
        out, st_ = engine.linear_attention(q, k, v, lg, chunk=chunk,
                                           backend=KERNEL)
        out.block_until_ready()
    ev = [e for e in ev if e.spec.op.startswith("linear_attention_")]
    n = math.ceil(S / chunk)
    want = {
        "linear_attention_score": 2 * B * H * n * chunk * dk * chunk,
        "linear_attention_pv": 2 * B * H * n * chunk * chunk * dv,
        "linear_attention_inter": 2 * B * H * n * chunk * dk * dv,
        "linear_attention_state": 2 * B * H * n * dk * chunk * dv,
    }
    got = {e.spec.op: e.flops for e in ev}
    assert got == want
    state = next(e for e in ev if e.spec.op == "linear_attention_state")
    # the running state lives in VMEM all sweep; one final fp32 store
    assert state.bytes == B * H * dk * dv * 4
    assert all(e.spec.groups == n for e in ev)


# ------------------------------------------------------------------ #
# Autotune: sweep keys, cache round-trip, engine pickup
# ------------------------------------------------------------------ #
def test_autotune_attention_records_and_engine_serves_it():
    res = autotune.autotune_attention(512, 512, 64, policy=prec.FP32,
                                      backend=KERNEL, causal=True)
    assert res.key.to_str().endswith("-Sattnc")
    assert res.n_candidates > 1
    tile = autotune.cached_tile(512, 512, 64, policy=prec.FP32,
                                backend=KERNEL, sweep="attnc")
    assert tile is not None and (tile.bm, tile.bn) == \
        (res.tile.bm, res.tile.bn)
    # no cross-talk: the dense sweep and plain GEMM keys stay cold
    assert autotune.cached_tile(512, 512, 64, policy=prec.FP32,
                                backend=KERNEL, sweep="attn") is None
    assert autotune.cached_tile(512, 512, 64, policy=prec.FP32,
                                backend=KERNEL) is None
    q, k, v = _qkv(B=1, Hq=1, Hkv=1, S=512, T=512, D=64)
    with engine.instrument() as ev:
        engine.attention(q, k, v, causal=True, policy=prec.FP32,
                         backend=KERNEL).block_until_ready()
    score = next(e for e in ev if e.spec.op == "attention_score")
    assert (score.spec.tile.bm, score.spec.tile.bn) == \
        (res.tile.bm, res.tile.bn)


def test_autotune_linear_attention_records_chunk():
    res = autotune.autotune_attention(4096, 64, 128, policy=prec.FP32,
                                      backend=KERNEL,
                                      kind="linear_attention")
    assert res.key.to_str().endswith("-Slattn")
    assert res.tile.bm == res.tile.bn == res.tile.bk
    tile = autotune.cached_tile(4096, 64, 128, policy=prec.FP32,
                                backend=KERNEL, sweep="lattn")
    assert tile is not None and tile.bm == res.tile.bm


def test_sweep_cache_file_passes_lint():
    """The persisted sweep keys must parse under the repo linter's key
    grammar (validate_autotune_cache skips the GEMM-fit check for them)."""
    from repro.analysis import lint

    autotune.autotune_attention(1024, 1024, 64, policy=prec.TPU_BF16,
                                backend="pallas", causal=True)
    autotune.autotune_attention(1024, 1024, 64, policy=prec.TPU_BF16,
                                backend="pallas", causal=False)
    autotune.autotune_attention(2048, 64, 64, policy=prec.FP32,
                                backend="pallas", kind="linear_attention")
    autotune.autotune_gemm(256, 256, 256, policy=prec.TPU_BF16,
                           backend="pallas", mode="model")
    import os
    path = os.environ[autotune.ENV_VAR]
    assert os.path.exists(path)
    assert lint.validate_autotune_cache(path) == []


def test_attention_bytes_match_pinned_baseline():
    """benchmarks/baselines/train_bytes.json pins one causal attention
    forward on both paths: the kernel's io_bytes-billed flash sweep must
    stay strictly below the reference einsum2d composition in both bytes
    (no S x T score round-trip) and flops (skipped KV blocks), and both
    rows must re-trace exactly."""
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "baselines", "train_bytes.json")
    with open(path) as fh:
        want = json.load(fh)["attn_fwd_B2_H4_S96_D16"]
    q, k, v = _qkv(B=2, Hq=4, Hkv=4, S=96, T=96, D=16)
    got = {}
    for row, b in (("kernel", KERNEL), ("reference", REF)):
        with engine.instrument() as ev:
            jax.eval_shape(lambda a, b_, c: engine.attention(
                a, b_, c, causal=True, bq=32, bkv=32, policy=prec.FP32,
                backend=b), q, k, v)
        got[row] = {"bytes": int(sum(e.total_bytes for e in ev)),
                    "flops": int(sum(e.total_flops for e in ev))}
    assert got == want, (
        f"attention byte/flop bill drifted: {got} != pinned {want}. If "
        f"the sweep accounting changed on purpose, update "
        f"benchmarks/baselines/train_bytes.json in this commit.")
    assert got["kernel"]["bytes"] < got["reference"]["bytes"]
    assert got["kernel"]["flops"] < got["reference"]["flops"]


def test_attention_cost_model_prefers_causal_skips():
    """The cost model must see causal sweeps as cheaper than dense at the
    same geometry — that is the whole point of billing skipped blocks."""
    pol = prec.TPU_BF16
    assert autotune.attention_cost_us(4096, 4096, 128, 256, 512,
                                      policy=pol, causal=True) < \
        autotune.attention_cost_us(4096, 4096, 128, 256, 512,
                                   policy=pol, causal=False)


# ------------------------------------------------------------------ #
# Property sweeps: odd/non-multiple shapes never change semantics
# ------------------------------------------------------------------ #
if st is None:  # pragma: no cover

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_attention_odd_shapes_property():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_linear_attention_odd_chunks_property():
        pass

else:

    @settings(max_examples=15, deadline=None)
    @given(
        s=st.integers(1, 40),
        t=st.integers(1, 56),
        d=st.sampled_from([3, 8, 16]),
        bq=st.sampled_from([8, 16, 24]),
        bkv=st.sampled_from([8, 16, 24]),
        causal=st.booleans(),
        group=st.sampled_from([1, 2]),
        data=st.data(),
    )
    def test_attention_odd_shapes_property(s, t, d, bq, bkv, causal,
                                           group, data):
        t_valid = data.draw(st.integers(0, t), label="t_valid")
        rng = np.random.default_rng(s * 1000 + t * 10 + d)
        q = jnp.asarray(rng.standard_normal((1, 2 * group, s, d)),
                        jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 2, t, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 2, t, d)), jnp.float32)
        kw = dict(causal=causal, t_valid=t_valid, bq=bq, bkv=bkv,
                  policy=prec.FP32)
        got = engine.attention(q, k, v, backend=KERNEL, **kw)
        want = _oracle(q, k, v, causal=causal, t_valid=t_valid)
        np.testing.assert_allclose(np.asarray(got, np.float32), want,
                                   rtol=3e-5, atol=3e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        s=st.integers(1, 33),
        chunk=st.integers(1, 17),
        dk=st.integers(2, 10),
        dv=st.integers(2, 12),
    )
    def test_linear_attention_odd_chunks_property(s, chunk, dk, dv):
        rng = np.random.default_rng(s * 100 + chunk)
        q = jnp.asarray(rng.standard_normal((1, 2, s, dk)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 2, s, dk)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 2, s, dv)), jnp.float32)
        lg = jnp.asarray(rng.uniform(-0.3, 0.0, (1, 2, s)), jnp.float32)
        out, st_ = engine.linear_attention(q, k, v, lg, chunk=chunk,
                                           backend=KERNEL)
        want_o, want_s = _linear_oracle(q, k, v, lg)
        np.testing.assert_allclose(np.asarray(out), want_o,
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st_), want_s,
                                   rtol=1e-4, atol=1e-4)
