"""End-to-end system behaviour: training converges, serving generates,
data pipeline is deterministic, the AE use case trains in pure FP16."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import precision as prec
from repro.data import Prefetcher, SyntheticAE, SyntheticLM
from repro.launch.train import build_train_step, init_state
from repro.models import autoencoder, transformer
from repro.optim import AdamW


def test_train_loss_decreases_dense():
    cfg = configs.get_reduced("yi-9b")
    opt = AdamW(lr=3e-3, warmup_steps=5)
    step = jax.jit(build_train_step(cfg, opt, rules=None), donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(0), cfg, opt)
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)
    losses = []
    for i, batch in zip(range(30), ds):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5


def test_train_loss_decreases_moe():
    cfg = configs.get_reduced("deepseek-moe-16b")
    opt = AdamW(lr=3e-3, warmup_steps=5)
    step = jax.jit(build_train_step(cfg, opt, rules=None), donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(0), cfg, opt)
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)
    losses = []
    drop0 = None
    for i, batch in zip(range(30), ds):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        if drop0 is None:
            drop0 = float(metrics["moe_drop_frac"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5
    # dispatch not fully degenerate at init (Zipf data is top-heavy, so
    # near-identical tokens legitimately route together); later steps may
    # collapse the toy router entirely
    assert drop0 < 0.9


def test_train_loss_decreases_ssm():
    cfg = configs.get_reduced("xlstm-1.3b")
    opt = AdamW(lr=3e-3, warmup_steps=5)
    step = jax.jit(build_train_step(cfg, opt, rules=None), donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(0), cfg, opt)
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)
    losses = []
    for i, batch in zip(range(30), ds):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_generation_end_to_end():
    from repro.launch.serve import generate

    cfg = configs.get_reduced("qwen3-1.7b")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0,
                                 cfg.vocab_size, jnp.int32)
    seqs = generate(params, cfg, prompts, gen_len=6)
    assert seqs.shape == (3, 14)
    assert bool((seqs[:, :8] == prompts).all())
    assert bool((seqs >= 0).all()) and bool((seqs < cfg.vocab_size).all())


# ------------------------------------------------------------------ #
# Data pipeline
# ------------------------------------------------------------------ #
def test_data_deterministic_replay():
    ds = SyntheticLM(vocab_size=1000, seq_len=32, global_batch=4, seed=7)
    a = ds.batch(5)
    b = ds.batch(5)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    c = ds.batch(6)
    assert not np.array_equal(a["inputs"], c["inputs"])


def test_data_host_sharding_disjoint():
    d0 = SyntheticLM(vocab_size=1000, seq_len=32, global_batch=8,
                     num_hosts=2, host_id=0)
    d1 = SyntheticLM(vocab_size=1000, seq_len=32, global_batch=8,
                     num_hosts=2, host_id=1)
    assert d0.local_batch == 4
    a, b = d0.batch(0), d1.batch(0)
    assert not np.array_equal(a["inputs"], b["inputs"])


def test_labels_are_shifted_inputs():
    ds = SyntheticLM(vocab_size=1000, seq_len=16, global_batch=2)
    b = ds.batch(0)
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


def test_prefetcher_order_and_close():
    items = iter(range(10))
    pf = Prefetcher(items, depth=2)
    got = [next(pf) for _ in range(5)]
    assert got == list(range(5))
    pf.close()


# ------------------------------------------------------------------ #
# Paper use case: AutoEncoder trains in pure FP16 (+ loss scaling story)
# ------------------------------------------------------------------ #
def test_autoencoder_trains_fp16():
    """Pure-FP16 AE training (Dense->BN->ReLU per the MLPerf Tiny reference)
    is stable and converges; fp32-parity checked in the test below."""
    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3)
    state = opt.init(params)
    ds = SyntheticAE(batch=64)
    xs = [jnp.asarray(ds.sample(i)) for i in range(4)]

    @jax.jit
    def step(p, s, x):
        (loss, _), g = jax.value_and_grad(
            lambda q: autoencoder.ae_loss(q, x, policy=prec.PAPER_FP16),
            has_aux=True)(p)
        u, s = opt.update(g, s, p)
        return opt.apply(p, u), s, loss

    losses = []
    for i in range(100):
        params, state, loss = step(params, state, xs[i % 4])
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-10:]) < 0.5 * np.mean(losses[:10])


def test_autoencoder_fp16_vs_fp32_numerics():
    """The paper-faithful fp16-accumulation path tracks fp32 closely on the
    AE's GEMM sizes (N <= 640)."""
    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    x = jnp.asarray(SyntheticAE(batch=16).sample(0))
    y16 = autoencoder.ae_forward(params, x, policy=prec.PAPER_FP16)
    y32 = autoencoder.ae_forward(params, x, policy=prec.FP32)
    err = float(jnp.max(jnp.abs(y16.astype(jnp.float32) - y32)))
    assert err < 5e-2
