"""Shared test configuration: deterministic CI profile.

Two flake sources are pinned here so property sweeps and autotune tests
cannot flake CI:

* **hypothesis**: a registered ``repro-ci`` profile with a fixed
  derandomized seed (examples are a pure function of the test body), no
  deadline (CI machines stall arbitrarily under load — a wall-clock
  deadline on a correctness test is noise, not signal) and a bounded
  example count.  Loaded unconditionally; skipped gracefully on minimal
  installs without hypothesis (the property tests themselves already
  ``importorskip``).
* **the autotune cache**: ``REPRO_AUTOTUNE_CACHE`` is pointed at a
  per-test ``tmp_path`` file and the in-process LRU is cleared around
  every test, so no test can observe (or poison) another test's tuned
  tiles — tests that manage the env var themselves (tests/test_autotune)
  simply override the fixture's value with their own ``monkeypatch``.
"""

import pytest

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "repro-ci",
        derandomize=True,          # fixed seed: examples are reproducible
        deadline=None,             # no wall-clock flakes on loaded CI boxes
        max_examples=25,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.load_profile("repro-ci")
except ImportError:  # minimal install: property tests skip themselves
    pass


@pytest.fixture(autouse=True)
def _isolated_autotune_cache(tmp_path, monkeypatch):
    """Point the autotune JSON cache at a per-test temp file and reset the
    in-process LRU on both sides of the test."""
    from repro.core import autotune

    monkeypatch.setenv(autotune.ENV_VAR, str(tmp_path / "autotune_cache.json"))
    autotune.clear_cache()
    yield
    autotune.clear_cache()
