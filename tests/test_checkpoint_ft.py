"""Checkpointing + fault tolerance: atomicity, resume, stragglers, elasticity."""

import json
import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointCorruptError, CheckpointManager
from repro.runtime.fault_tolerance import (FailureInjector, StragglerWatchdog,
                                           TrainLoop, reshard)


def _tree(x=1.0):
    return {"a": jnp.full((4, 3), x), "b": {"c": jnp.arange(5, dtype=jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    t = _tree(2.5)
    mgr.save(7, t, {"note": "hi"})
    restored, meta = mgr.restore(7, jax.tree.map(np.asarray, t))
    assert meta["note"] == "hi"
    np.testing.assert_allclose(restored["a"], np.asarray(t["a"]))
    np.testing.assert_array_equal(restored["b"]["c"], np.asarray(t["b"]["c"]))


def test_keep_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save(s, _tree(float(s)))
    assert mgr.all_steps() == [3, 4]


def test_atomicity_no_tmp_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]
    assert mgr.latest() == 1


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save_async(3, _tree(9.0))
    mgr.wait()
    assert mgr.latest() == 3


def test_restore_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree())
    bad = {"a": np.zeros((2, 2)), "b": {"c": np.zeros(5, np.int32)}}
    with pytest.raises(ValueError):
        mgr.restore(1, bad)


# ------------------------------------------------------------------ #
# Fault-tolerant loop
# ------------------------------------------------------------------ #
def _toy_step():
    @jax.jit
    def step(state, batch):
        w = state["w"] - 0.1 * (state["w"] - batch)
        return {"w": w, "step": state["step"] + 1}, {"loss": jnp.sum((w - batch) ** 2)}
    return step


def _batches():
    while True:
        yield jnp.ones(3)


def test_crash_and_resume_bit_identical(tmp_path):
    """Kill at step 7, restart, and the final state must equal the
    uninterrupted run (deterministic data + checkpointed state)."""
    step = _toy_step()
    init = {"w": jnp.zeros(3), "step": jnp.int32(0)}
    batch_fn = lambda i: jnp.ones(3)  # step-indexed: replays after restart

    # uninterrupted reference
    ref = CheckpointManager(str(tmp_path / "ref"), keep=2)
    out_ref = TrainLoop(step, ref, save_every=5).run(
        init, batch_fn, 12, log=lambda s: None)

    # crashing run
    mgr = CheckpointManager(str(tmp_path / "crash"), keep=2)
    inj = FailureInjector(fail_at_step=7)
    loop = TrainLoop(step, mgr, save_every=5, injector=inj)
    with pytest.raises(RuntimeError):
        loop.run(init, batch_fn, 12, log=lambda s: None)
    assert mgr.latest() == 5  # last complete checkpoint

    # resumed run — data stream replays deterministically from step 5
    loop2 = TrainLoop(step, mgr, save_every=5)
    out = loop2.run(init, batch_fn, 12, log=lambda s: None)
    np.testing.assert_allclose(
        np.asarray(out["final_state"]["w"]),
        np.asarray(out_ref["final_state"]["w"]), rtol=1e-7)
    assert int(out["final_state"]["step"]) == int(out_ref["final_state"]["step"])


def test_resume_with_plain_iterator_rejected(tmp_path):
    """Resuming from a checkpoint with a plain iterator would replay the
    stream from batch 0 against a mid-run state — rejected loudly instead
    of silently corrupting the data/step alignment."""
    step = _toy_step()
    init = {"w": jnp.zeros(3), "step": jnp.int32(0)}
    mgr = CheckpointManager(str(tmp_path), keep=2)
    TrainLoop(step, mgr, save_every=2).run(
        init, lambda i: jnp.ones(3), 4, log=lambda s: None)
    assert mgr.latest() == 4

    with pytest.raises(TypeError, match="plain iterator"):
        TrainLoop(step, mgr, save_every=2).run(
            init, _batches(), 8, log=lambda s: None)
    # fresh runs (no checkpoint yet) still accept iterators
    fresh = CheckpointManager(str(tmp_path / "fresh"), keep=2)
    out = TrainLoop(step, fresh, save_every=100).run(
        init, _batches(), 3, log=lambda s: None)
    assert out["last_step"] == 2


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(threshold=2.0, ema_decay=0.5)
    for _ in range(5):
        assert not wd.observe(0.10)
    assert wd.observe(0.50)           # 5x the EMA -> straggler
    assert wd.straggler_steps == 1
    assert not wd.observe(0.10)       # EMA not poisoned by the straggler


def test_straggler_detection_in_loop(tmp_path):
    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 9:
            time.sleep(0.25)
        return state, {"loss": jnp.float32(0.0)}

    loop = TrainLoop(slow_step, CheckpointManager(str(tmp_path), keep=1),
                     save_every=100,
                     watchdog=StragglerWatchdog(threshold=3.0))
    out = loop.run({"w": jnp.zeros(1)}, _batches(), 12, log=lambda s: None)
    assert out["straggler_steps"] >= 1


# ------------------------------------------------------------------ #
# Checksums + self-healing restore
# ------------------------------------------------------------------ #
def test_manifest_carries_per_leaf_checksums(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(3.0))
    with open(os.path.join(mgr._dir(1), "manifest.json")) as f:
        manifest = json.load(f)
    assert set(manifest["checksums"]) == {"leaf_0", "leaf_1"}
    assert all(isinstance(v, int) for v in manifest["checksums"].values())


def test_corrupt_payload_raises_corrupt_error(tmp_path):
    """A valid-looking npz whose bytes changed after the manifest was
    written (silent corruption) fails the checksum, not the tests 10k
    steps later."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    t = _tree(1.5)
    mgr.save(2, t)
    leaves = {f"leaf_{i}": np.asarray(x)
              for i, x in enumerate(jax.tree.leaves(t))}
    leaves["leaf_0"] = np.zeros_like(leaves["leaf_0"])  # flipped block
    np.savez(os.path.join(mgr._dir(2), "arrays.npz"), **leaves)
    with pytest.raises(CheckpointCorruptError, match="checksum mismatch"):
        mgr.restore(2, jax.tree.map(np.asarray, t))


def test_restore_latest_skips_corrupt_to_previous_valid(tmp_path):
    """Truncate the newest checkpoint's payload: restore_latest must warn
    and fall back to the previous valid step instead of crashing."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    t = _tree(0.0)
    for s in (1, 2, 3):
        mgr.save(s, _tree(float(s)))
    with open(os.path.join(mgr._dir(3), "arrays.npz"), "wb") as f:
        f.write(b"PK\x03\x04torn")  # truncated mid-write
    warnings = []
    got = mgr.restore_latest(jax.tree.map(np.asarray, t), log=warnings.append)
    assert got is not None
    step, tree, _ = got
    assert step == 2
    np.testing.assert_allclose(tree["a"], np.full((4, 3), 2.0))
    assert any("skipping corrupt checkpoint step 3" in w for w in warnings)


def test_restore_latest_all_corrupt_returns_none(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    t = _tree(1.0)
    mgr.save(1, t)
    with open(os.path.join(mgr._dir(1), "arrays.npz"), "wb") as f:
        f.write(b"garbage")
    assert mgr.restore_latest(jax.tree.map(np.asarray, t),
                              log=lambda s: None) is None


# ------------------------------------------------------------------ #
# Failure injector semantics
# ------------------------------------------------------------------ #
def test_failure_injector_is_one_shot():
    inj = FailureInjector(fail_at_step=3, mode="raise")
    inj.maybe_fail(2)  # not yet
    with pytest.raises(RuntimeError, match="injected failure at step 3"):
        inj.maybe_fail(3)
    assert inj.fired
    inj.maybe_fail(3)  # the latch holds: a survivor does not re-die


def test_failure_injector_rejects_unknown_mode():
    with pytest.raises(ValueError, match="unknown failure mode"):
        FailureInjector(fail_at_step=1, mode="meteor")


def test_straggler_ema_not_poisoned_numerically():
    wd = StragglerWatchdog(threshold=2.0, ema_decay=0.5)
    for _ in range(4):
        wd.observe(0.10)
    ema_before = wd.ema
    assert wd.observe(10.0)            # extreme straggler
    assert wd.observe(10.0)            # and again — still flagged
    assert wd.ema == ema_before        # EMA untouched by either
    assert wd.straggler_steps == 2


# ------------------------------------------------------------------ #
# Preemption: a real SIGTERM delivered to a real worker process
# ------------------------------------------------------------------ #
def test_sigterm_worker_checkpoints_and_exits_clean(tmp_path):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    ckpt = tmp_path / "ckpt"
    result = tmp_path / "out.json"
    env = {**os.environ, "PYTHONPATH": os.path.join(root, "src"),
           "JAX_PLATFORMS": "cpu"}
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.runtime.elastic",
         "--ckpt", str(ckpt), "--steps", "500", "--save-every", "1",
         "--dp", "1", "--compress", "none", "--handle-sigterm",
         "--step-ms", "100", "--result", str(result), "--log-every", "1000"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env,
        cwd=root)
    try:
        # wait until the loop is live (first heartbeat), then preempt it
        hb = ckpt / "heartbeat.json"
        for _ in range(600):
            if hb.exists():
                break
            time.sleep(0.1)
        else:
            proc.kill()
            pytest.fail("worker never reached its first step: "
                        + proc.communicate()[0][-800:])
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=120)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert proc.returncode == 0, out[-1200:]
    assert "preempted: checkpointed at step" in out
    with open(result) as f:
        res = json.load(f)
    assert res["preempted"] is True
    assert res["last_step"] < 499  # it really stopped early


def test_elastic_reshard_across_meshes(tmp_path):
    """An N-host checkpoint restores onto a different mesh layout."""
    from jax.sharding import PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path), keep=1)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree)
    from repro.runtime import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    restored, _ = mgr.restore(1, jax.tree.map(np.asarray, tree))
    placed = reshard(restored, mesh, {"w": P("data", None)})
    np.testing.assert_allclose(np.asarray(placed["w"]), np.asarray(tree["w"]))
