"""Checkpointing + fault tolerance: atomicity, resume, stragglers, elasticity."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime.fault_tolerance import (FailureInjector, StragglerWatchdog,
                                           TrainLoop, reshard)


def _tree(x=1.0):
    return {"a": jnp.full((4, 3), x), "b": {"c": jnp.arange(5, dtype=jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    t = _tree(2.5)
    mgr.save(7, t, {"note": "hi"})
    restored, meta = mgr.restore(7, jax.tree.map(np.asarray, t))
    assert meta["note"] == "hi"
    np.testing.assert_allclose(restored["a"], np.asarray(t["a"]))
    np.testing.assert_array_equal(restored["b"]["c"], np.asarray(t["b"]["c"]))


def test_keep_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save(s, _tree(float(s)))
    assert mgr.all_steps() == [3, 4]


def test_atomicity_no_tmp_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]
    assert mgr.latest() == 1


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save_async(3, _tree(9.0))
    mgr.wait()
    assert mgr.latest() == 3


def test_restore_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree())
    bad = {"a": np.zeros((2, 2)), "b": {"c": np.zeros(5, np.int32)}}
    with pytest.raises(ValueError):
        mgr.restore(1, bad)


# ------------------------------------------------------------------ #
# Fault-tolerant loop
# ------------------------------------------------------------------ #
def _toy_step():
    @jax.jit
    def step(state, batch):
        w = state["w"] - 0.1 * (state["w"] - batch)
        return {"w": w, "step": state["step"] + 1}, {"loss": jnp.sum((w - batch) ** 2)}
    return step


def _batches():
    while True:
        yield jnp.ones(3)


def test_crash_and_resume_bit_identical(tmp_path):
    """Kill at step 7, restart, and the final state must equal the
    uninterrupted run (deterministic data + checkpointed state)."""
    step = _toy_step()
    init = {"w": jnp.zeros(3), "step": jnp.int32(0)}

    # uninterrupted reference
    ref = CheckpointManager(str(tmp_path / "ref"), keep=2)
    out_ref = TrainLoop(step, ref, save_every=5).run(
        init, _batches(), 12, log=lambda s: None)

    # crashing run
    mgr = CheckpointManager(str(tmp_path / "crash"), keep=2)
    inj = FailureInjector(fail_at_step=7)
    loop = TrainLoop(step, mgr, save_every=5, injector=inj)
    with pytest.raises(RuntimeError):
        loop.run(init, _batches(), 12, log=lambda s: None)
    assert mgr.latest() == 5  # last complete checkpoint

    # resumed run — data stream replays deterministically from step 5
    loop2 = TrainLoop(step, mgr, save_every=5)
    out = loop2.run(init, _batches(), 12, log=lambda s: None)
    np.testing.assert_allclose(
        np.asarray(out["final_state"]["w"]),
        np.asarray(out_ref["final_state"]["w"]), rtol=1e-7)
    assert int(out["final_state"]["step"]) == int(out_ref["final_state"]["step"])


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(threshold=2.0, ema_decay=0.5)
    for _ in range(5):
        assert not wd.observe(0.10)
    assert wd.observe(0.50)           # 5x the EMA -> straggler
    assert wd.straggler_steps == 1
    assert not wd.observe(0.10)       # EMA not poisoned by the straggler


def test_straggler_detection_in_loop(tmp_path):
    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 9:
            time.sleep(0.25)
        return state, {"loss": jnp.float32(0.0)}

    loop = TrainLoop(slow_step, CheckpointManager(str(tmp_path), keep=1),
                     save_every=100,
                     watchdog=StragglerWatchdog(threshold=3.0))
    out = loop.run({"w": jnp.zeros(1)}, _batches(), 12, log=lambda s: None)
    assert out["straggler_steps"] >= 1


def test_elastic_reshard_across_meshes(tmp_path):
    """An N-host checkpoint restores onto a different mesh layout."""
    from jax.sharding import PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path), keep=1)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree)
    from repro.runtime import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    restored, _ = mgr.restore(1, jax.tree.map(np.asarray, tree))
    placed = reshard(restored, mesh, {"w": P("data", None)})
    np.testing.assert_allclose(np.asarray(placed["w"]), np.asarray(tree["w"]))
