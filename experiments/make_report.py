"""Generate the §Dry-run / §Roofline markdown tables from dryrun JSONs.

    PYTHONPATH=src python experiments/make_report.py [--tag final]
"""

import argparse
import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "yi-9b", "qwen3-1.7b", "mistral-nemo-12b", "command-r-35b",
    "deepseek-v2-lite-16b", "deepseek-moe-16b", "musicgen-medium",
    "xlstm-1.3b", "hymba-1.5b", "pixtral-12b",
]


def load(tag, out_dir):
    recs = {}
    for f in glob.glob(os.path.join(out_dir, f"{tag}__*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_cell(r):
    if r is None:
        return "| (missing) " * 7 + "|"
    if r.get("skipped"):
        return "| — skipped: quadratic 500k decode on full attention " + "| — " * 6 + "|"
    if r.get("error"):
        return f"| ERROR {r['error'][:40]} " + "| — " * 6 + "|"
    return (f"| {r['compute_s']*1e3:,.1f} | {r['memory_s']*1e3:,.1f} "
            f"| {r['collective_s']*1e3:,.1f} | **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']*100:.3f}% "
            f"| {r['per_device_hbm_gib']:.2f} |")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--tag", default="final")
    p.add_argument("--out", default="experiments/dryrun")
    args = p.parse_args()
    recs = load(args.tag, args.out)

    for mesh in ("pod16x16", "pod2x16x16"):
        print(f"\n### Mesh `{mesh}` "
              f"({'256 chips, single pod' if mesh == 'pod16x16' else '512 chips, 2 pods'})\n")
        print("| arch | shape | compute ms | memory ms | collective ms "
              "| dominant | useful | roofline | HBM GiB/dev |")
        print("|---|---|---|---|---|---|---|---|---|")
        for arch in ARCH_ORDER:
            for shape in SHAPE_ORDER:
                r = recs.get((arch, shape, mesh))
                print(f"| {arch} | {shape} {fmt_cell(r)}")

    # dry-run compile record
    print("\n### Compile record (multi-pod mesh)\n")
    print("| arch | shape | lower s | compile s | HLO MB | collectives (GB/dev wire) |")
    print("|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, "pod2x16x16"))
            if not r or r.get("skipped") or r.get("error"):
                continue
            colls = " ".join(
                f"{k.split('-')[-1] if '-' in k else k}:{v/1e9:.1f}"
                for k, v in sorted(r["collectives"].items()) if v > 1e6)
            print(f"| {arch} | {shape} | {r['lower_s']} | {r['compile_s']} "
                  f"| {r['hlo_bytes']/1e6:.1f} | {colls} |")


if __name__ == "__main__":
    main()
