"""Quickstart: the RedMulE Engine in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PAPER_FP16, TPU_BF16, engine
from repro.core.perf_model import DEFAULT_MODEL, GEMM
from repro.core.tiling import choose_tiles

# ---------------------------------------------------------------- #
# 1. Z = X @ W on the Engine (Pallas kernel in interpret mode on
#    CPU; the real TPU lowering uses the same kernel body).  The
#    backends are ordinary registry entries — engine.registered_backends()
# ---------------------------------------------------------------- #
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(256, 640)), jnp.float16)
w = jnp.asarray(rng.normal(size=(640, 128)), jnp.float16)

print("backends:", engine.registered_backends())
with engine.use_backend("interpret"):   # execute the kernel body on CPU
    z_kernel = engine.matmul(x, w, policy=PAPER_FP16)
with engine.use_backend("xla"):         # the production XLA path
    z_xla = engine.matmul(x, w, policy=PAPER_FP16)
print("kernel vs xla max|diff|:",
      float(jnp.max(jnp.abs(z_kernel.astype(jnp.float32)
                            - z_xla.astype(jnp.float32)))))

# ---------------------------------------------------------------- #
# 2. Instrumentation: every dispatch emits a GemmEvent
# ---------------------------------------------------------------- #
with engine.instrument() as events:
    engine.linear(x, w, jnp.zeros((128,), jnp.float16),
                  activation="relu", policy=PAPER_FP16)
    engine.grouped_matmul(                      # 4 experts in one dispatch
        jnp.zeros((4, 32, 640), jnp.float16),
        jnp.zeros((4, 640, 128), jnp.float16), policy=PAPER_FP16)
for ev in events:
    print(f"event: {ev.spec.op:16s} {ev.spec.tag:14s} "
          f"M/N/K={ev.spec.m}/{ev.spec.n}/{ev.spec.k} "
          f"groups={ev.spec.groups} backend={ev.backend} "
          f"flops={ev.total_flops}")

# ---------------------------------------------------------------- #
# 3. Tiling: the TPU analogue of the paper's (H, L, P) parameters
# ---------------------------------------------------------------- #
t = choose_tiles(4096, 4096, 4096, compute_dtype=jnp.bfloat16)
print(f"4096^3 GEMM tiles: bm={t.bm} bn={t.bn} bk={t.bk} "
      f"(X-stationary, W-streamed along bn, Z stored once)")

# ---------------------------------------------------------------- #
# 4. The calibrated machine model (every Table-I number)
# ---------------------------------------------------------------- #
m = DEFAULT_MODEL
g = GEMM(512, 512, 512)
print(f"RedMulE 32-FMA @ 512^3: {m.hw_macs_per_cycle(g):.2f} MAC/cycle "
      f"({m.utilization(g)*100:.1f}% of ideal), "
      f"{m.speedup(g):.1f}x over 8-core SW, "
      f"{m.gflops_per_watt(g):.0f} GFLOPS/W @ 0.65 V")

# ---------------------------------------------------------------- #
# 5. Precision policies
# ---------------------------------------------------------------- #
for policy in (PAPER_FP16, TPU_BF16):
    z = engine.matmul(x, w, policy=policy)
    print(f"policy={policy.name:12s} out_dtype={z.dtype} "
          f"accum={jnp.dtype(policy.accum_dtype).name}")
