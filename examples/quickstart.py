"""Quickstart: the RedMulE engine in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PAPER_FP16, TPU_BF16, matmul, use_backend
from repro.core.perf_model import DEFAULT_MODEL, GEMM
from repro.core.tiling import choose_tiles

# ---------------------------------------------------------------- #
# 1. Z = X @ W on the RedMulE engine (Pallas kernel in interpret
#    mode on CPU; the real TPU lowering uses the same kernel body)
# ---------------------------------------------------------------- #
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(256, 640)), jnp.float16)
w = jnp.asarray(rng.normal(size=(640, 128)), jnp.float16)

with use_backend("interpret"):          # execute the kernel body on CPU
    z_kernel = matmul(x, w, policy=PAPER_FP16)
with use_backend("xla"):                # the production XLA path
    z_xla = matmul(x, w, policy=PAPER_FP16)
print("kernel vs xla max|diff|:",
      float(jnp.max(jnp.abs(z_kernel.astype(jnp.float32)
                            - z_xla.astype(jnp.float32)))))

# ---------------------------------------------------------------- #
# 2. Tiling: the TPU analogue of the paper's (H, L, P) parameters
# ---------------------------------------------------------------- #
t = choose_tiles(4096, 4096, 4096, compute_dtype=jnp.bfloat16)
print(f"4096^3 GEMM tiles: bm={t.bm} bn={t.bn} bk={t.bk} "
      f"(X-stationary, W-streamed along bn, Z stored once)")

# ---------------------------------------------------------------- #
# 3. The calibrated machine model (every Table-I number)
# ---------------------------------------------------------------- #
m = DEFAULT_MODEL
g = GEMM(512, 512, 512)
print(f"RedMulE 32-FMA @ 512^3: {m.hw_macs_per_cycle(g):.2f} MAC/cycle "
      f"({m.utilization(g)*100:.1f}% of ideal), "
      f"{m.speedup(g):.1f}x over 8-core SW, "
      f"{m.gflops_per_watt(g):.0f} GFLOPS/W @ 0.65 V")

# ---------------------------------------------------------------- #
# 4. Precision policies
# ---------------------------------------------------------------- #
for policy in (PAPER_FP16, TPU_BF16):
    z = matmul(x, w, policy=policy)
    print(f"policy={policy.name:12s} out_dtype={z.dtype} "
          f"accum={jnp.dtype(policy.accum_dtype).name}")
