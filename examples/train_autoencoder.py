"""The paper's use case, end to end: TinyMLPerf AutoEncoder trained in pure
FP16 on the RedMulE engine with dynamic loss scaling (§III-B, Fig 4c/4d).

    PYTHONPATH=src python examples/train_autoencoder.py [--steps 400]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import precision as prec
from repro.core.perf_model import DEFAULT_MODEL, autoencoder_report
from repro.data import SyntheticAE
from repro.models import autoencoder
from repro.optim import AdamW, adjust, init_scale, scale_loss, unscale_and_check


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=400)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--lr", type=float, default=3e-3)
    args = p.parse_args()

    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    opt = AdamW(lr=args.lr)
    opt_state = opt.init(params)
    scale = init_scale(initial=2.0**12, growth_interval=200)
    ds = SyntheticAE(batch=args.batch)

    @jax.jit
    def step(p_, s_, sc, x):
        def lf(q):
            loss, _ = autoencoder.ae_loss(q, x, policy=prec.PAPER_FP16)
            return scale_loss(loss, sc), loss

        (scaled, loss), g = jax.value_and_grad(lf, has_aux=True)(p_)
        g, finite = unscale_and_check(g, sc)
        sc = adjust(sc, finite)
        u, s_ = opt.update(g, s_, p_)
        p_ = jax.lax.cond(finite, lambda _: opt.apply(p_, u), lambda _: p_, None)
        return p_, s_, sc, loss, finite

    losses = []
    for i in range(args.steps):
        x = jnp.asarray(ds.sample(i % 8))
        params, opt_state, scale, loss, finite = step(params, opt_state, scale, x)
        losses.append(float(loss))
        if i % 50 == 0:
            print(f"[{i:4d}] mse={losses[-1]:.4f} "
                  f"loss_scale={float(scale.scale):.0f} finite={bool(finite)}")

    print(f"\nfinal mse: {np.mean(losses[-10:]):.4f} "
          f"(from {np.mean(losses[:10]):.4f}); overflows seen: "
          f"{int(scale.overflow_count)}")

    # the paper's Fig 4c/4d numbers for this exact workload
    print("\npaper reproduction (calibrated machine model):")
    for B in (1, 16):
        r = autoencoder_report(DEFAULT_MODEL, B)
        print(f"  B={B:2d}: RedMulE speedup {r['speedup']:.1f}x over 8-core SW "
              f"(paper: {'2.6x' if B == 1 else '24.4x'}), "
              f"fwd {r['speedup_fwd']:.1f}x / bwd {r['speedup_bwd']:.1f}x, "
              f"{r['hw_macs_per_cycle']:.1f} MAC/cycle")


if __name__ == "__main__":
    main()
