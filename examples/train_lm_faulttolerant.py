"""End-to-end LM pretraining with the fault-tolerant loop: checkpoints,
auto-resume, straggler watchdog.  Kill it mid-run (Ctrl-C / kill) and run
again — it resumes from the last complete checkpoint and replays the exact
data stream.

    PYTHONPATH=src python examples/train_lm_faulttolerant.py \\
        --arch qwen3-1.7b --steps 150 --ckpt /tmp/repro_ckpt
"""

import argparse

import jax

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.launch.train import build_train_step, init_state
from repro.optim import AdamW
from repro.runtime.fault_tolerance import StragglerWatchdog, TrainLoop


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-1.7b", choices=configs.ARCH_IDS)
    p.add_argument("--steps", type=int, default=150)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--ckpt", default="/tmp/repro_ckpt")
    p.add_argument("--save-every", type=int, default=25)
    args = p.parse_args()

    cfg = configs.get_reduced(args.arch)
    opt = AdamW(lr=3e-3, warmup_steps=10)
    step = jax.jit(build_train_step(cfg, opt, rules=None), donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(0), cfg, opt)
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                     global_batch=args.batch,
                     embed_dim=cfg.d_model if cfg.input_mode == "embeddings" else 0)

    loop = TrainLoop(
        step,
        CheckpointManager(args.ckpt, keep=2),
        save_every=args.save_every,
        watchdog=StragglerWatchdog(threshold=3.0),
        handle_sigterm=True,
    )
    out = loop.run(state, ds.batch, args.steps)  # step-indexed: exact replay
    g = out["goodput"]
    print(f"\ndone at step {out['last_step']}: "
          f"loss {out['history'][-1]['loss']:.4f}, "
          f"stragglers flagged: {out['straggler_steps']}")
    print(f"goodput {g['goodput']:.3f} "
          f"(useful {g['useful_time']:.1f}s / wall {g['wall_time']:.1f}s, "
          f"{g['restarts']} restart(s), "
          f"{g['recomputed_steps']} recomputed step(s), "
          f"{g['time_lost_to_restart']:.1f}s lost to restarts)")


if __name__ == "__main__":
    main()
