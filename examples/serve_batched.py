"""Batched serving: prefill once, decode greedily with a donated KV cache.

    PYTHONPATH=src python examples/serve_batched.py --arch deepseek-v2-lite-16b
    (MLA archs serve from the compressed c_kv cache — the r=512 trick.)
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.serve import generate
from repro.models import transformer


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="deepseek-v2-lite-16b",
                   choices=configs.ARCH_IDS)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=24)
    p.add_argument("--gen", type=int, default=24)
    args = p.parse_args()

    cfg = configs.get_reduced(args.arch)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size, jnp.int32)

    t0 = time.perf_counter()
    seqs = generate(params, cfg, prompts, args.gen)
    jax.block_until_ready(seqs)
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: {args.batch} requests x {args.gen} tokens "
          f"in {dt:.2f}s ({args.batch*args.gen/dt:.1f} tok/s incl. compile)")
    print("first completion:", np.asarray(seqs[0, args.prompt_len:]))

    if cfg.mla:
        c = transformer.init_cache(cfg, args.batch,
                                   args.prompt_len + args.gen)
        kv = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c))
        naive = (cfg.n_layers * args.batch * (args.prompt_len + args.gen)
                 * cfg.n_heads * (cfg.mla.qk_nope_dim + cfg.mla.v_head_dim) * 2 * 2)
        print(f"MLA compressed cache: {kv/1e6:.2f} MB "
              f"vs naive GQA cache ~{naive/1e6:.2f} MB "
              f"({naive/kv:.1f}x smaller)")


if __name__ == "__main__":
    main()
