"""Fault-tolerance benchmark rows (beyond-paper §Fault tolerance).

Two families of ``ft/*`` rows land in BENCH_engine.json:

* ``ft/collective_bytes_*`` — analytic gradient-all-reduce wire bytes for
  one step of the reduced qwen3-1.7b under each compression kind (priced
  like GEMM bytes: what a ring all-reduce moves, not what the CPU
  simulation materializes).  Pinned exactly against
  ``benchmarks/baselines/collective_bytes.json`` by the ft-gates CI job,
  which also requires the strict ordering fp8 < fp16 < fp32.
* ``ft/goodput_injected`` — an in-process crash/resume scenario (injected
  failure at step 6 of 12, checkpoint every 4): the resumed incarnation's
  goodput breakdown (useful/wall, recomputed steps, time lost to the
  restart).  Wall-clock based, so CI only floors it
  (``goodput_floor_injected`` in the same baselines file) rather than
  pinning it.
"""

import tempfile

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer
from repro.optim import Compressor
from repro.checkpoint import CheckpointManager
from repro.runtime.fault_tolerance import FailureInjector, TrainLoop

WIRE_KINDS = ("none", "fp16", "int8", "fp8_e4m3", "fp8_e5m2")
ARCH = "qwen3-1.7b"


def wire_label(kind: str) -> str:
    return "fp32" if kind == "none" else kind


def _wire_rows():
    params = transformer.abstract_params(configs.get_reduced(ARCH))
    rows = []
    for kind in WIRE_KINDS:
        b = Compressor(kind).wire_bytes(params)
        rows.append((f"ft/collective_bytes_{wire_label(kind)}", 0.0, str(b)))
    return rows


def _goodput_row():
    @jax.jit
    def step(state, batch):
        w = state["w"] - 0.1 * (state["w"] - batch)
        return {"w": w}, {"loss": jnp.sum((w - batch) ** 2)}

    def batches(i):
        return jnp.full((64,), 1.0)

    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d, keep=2)
        init = {"w": jnp.zeros(64)}
        crash = TrainLoop(step, ckpt, save_every=4,
                          injector=FailureInjector(fail_at_step=6))
        try:
            crash.run(init, batches, 12, log=lambda s: None)
        except RuntimeError:
            pass  # the injected failure
        out = TrainLoop(step, ckpt, save_every=4).run(
            init, batches, 12, log=lambda s: None)
    g = out["goodput"]
    derived = (f"goodput={g['goodput']:.3f} restarts={g['restarts']} "
               f"recomputed={g['recomputed_steps']} "
               f"lost={g['time_lost_to_restart']:.3f}s")
    return [("ft/goodput_injected", g["wall_time"] * 1e6, derived)]


def run():
    return _wire_rows() + _goodput_row()
