"""Beyond-paper: TPU-pod roofline summary from the dry-run artifacts.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
one row per (arch x shape x mesh): the three terms, the dominant
bottleneck, and the roofline fraction.  This is the §Roofline table's
source of truth.
"""

import glob
import json
import os

from benchmarks.common import Row

OUT_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def run(tag: str = "final") -> list[Row]:
    rows: list[Row] = []
    files = sorted(glob.glob(os.path.join(OUT_DIR, f"{tag}__*.json")))
    if not files:
        return [("roofline/none", 0.0,
                 f"no dry-run artifacts under {OUT_DIR} — run "
                 "python -m repro.launch.dryrun first")]
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("skipped"):
            rows.append((name, 0.0, "SKIP " + r["skipped"]))
            continue
        if r.get("error"):
            rows.append((name, 0.0, "ERROR " + r["error"][:80]))
            continue
        rows.append((
            name,
            r["compile_s"] * 1e6,
            f"compute={r['compute_s']*1e3:.1f}ms "
            f"memory={r['memory_s']*1e3:.1f}ms "
            f"collective={r['collective_s']*1e3:.1f}ms "
            f"dominant={r['dominant']} "
            f"useful={r['useful_flops_ratio']:.2f} "
            f"roofline={r['roofline_fraction']*100:.2f}% "
            f"hbm={r['per_device_hbm_gib']:.2f}GiB"))
    return rows
