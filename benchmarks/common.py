"""Shared benchmark utilities: wall-clock timing of the software path.

On this CPU container the "software counterpart" (pure-jnp GEMM, the 8-core
RISC-V baseline's role) is *measured*; RedMulE-side numbers are *derived*
from the calibrated machine model (no 22 nm silicon here) — mirroring how
the paper pairs measured SW with the accelerator.
"""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

Row = Tuple[str, float, str]


def time_us(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
