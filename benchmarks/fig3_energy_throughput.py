"""Fig 3c (cluster energy per MAC vs size) + Fig 3d (throughput vs size).

The derived column carries the model's pJ/MAC and MAC/cycle; the measured
column times the matching pure-jnp GEMM on this host (software-counterpart
role).  The paper's qualitative claims — energy/op falls and throughput
rises monotonically with matrix size, skinny-K collapses utilization — are
visible directly in the emitted table.
"""

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_us
from repro.core.perf_model import DEFAULT_MODEL, GEMM

SIZES = [16, 32, 64, 96, 128, 192, 256, 384, 512]


def run() -> list[Row]:
    m = DEFAULT_MODEL
    rows: list[Row] = []
    f = jax.jit(lambda a, b: (a @ b).astype(jnp.float16))
    for s in SIZES:
        g = GEMM(s, s, s)
        x = jnp.ones((s, s), jnp.float16)
        us = time_us(f, x, x)
        rows.append((
            f"fig3c/energy_per_mac_{s}x{s}x{s}", us,
            f"{m.energy_per_mac_pj(g):.2f}pJ/MAC"))
        rows.append((
            f"fig3d/throughput_{s}x{s}x{s}", us,
            f"{m.hw_macs_per_cycle(g):.2f}MAC/cyc "
            f"util={m.utilization(g)*100:.1f}%"))
    # the skinny-K regime of Fig 3d (K == batch)
    for k in (1, 2, 4, 8, 16):
        g = GEMM(128, 640, k)
        rows.append((
            f"fig3d/skinny_k{k}", 0.0,
            f"{m.hw_macs_per_cycle(g):.2f}MAC/cyc "
            f"util={m.utilization(g)*100:.1f}%"))
    return rows
