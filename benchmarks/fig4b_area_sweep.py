"""Fig 4b — RedMulE area vs array shape (H, L) at P=3.

Reproduces the sweep: area grows ~linearly in FMA count, matches the whole
PULP cluster at 256 FMAs and doubles it at 512; the memory-port count steps
9 -> 11 when H goes 4 -> 5 (the bandwidth wall the paper calls out).
"""

from benchmarks.common import Row
from repro.core.perf_model import DEFAULT_MODEL

SWEEP = [(4, 4), (4, 8), (8, 8), (8, 16), (8, 32), (16, 32)]


def run() -> list[Row]:
    m = DEFAULT_MODEL
    rows: list[Row] = []
    for H, L in SWEEP:
        area = m.area_mm2(H, L)
        rows.append((
            f"fig4b/area_H{H}_L{L}", 0.0,
            f"{H*L}FMA area={area:.3f}mm2 "
            f"vs_cluster={area/m.cluster_area_mm2:.2f}x ports={m.ports(H)}"))
    rows.append(("fig4b/ports_step_H4_H5", 0.0,
                 f"H4={m.ports(4)} H5={m.ports(5)} (paper: 9 -> 11)"))
    return rows
